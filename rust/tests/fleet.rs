//! Fleet-layer behaviour: placement policies and mixed CC/No-CC
//! device sets over the DES backend.
//!
//! Pins the headline fleet scenarios:
//! * `affinity` placement performs strictly fewer swaps than
//!   `round-robin` under identical traffic (2-device fleet);
//! * a mixed CC/No-CC fleet's per-device load split reflects the
//!   ~2.7× CC load-cost ratio;
//! * a `devices=1` fleet is placement-invariant (the backward-parity
//!   guarantee: every policy degenerates to the single-GPU engine);
//! * more devices complete more work under overload.

mod common;

use std::path::PathBuf;
use std::sync::OnceLock;

use sincere::config::RunConfig;
use sincere::engine::{EngineBuilder, RunSummary};
use sincere::runtime::Manifest;
use sincere::sim::calib::CostModel;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn manifest() -> &'static Manifest {
    static M: OnceLock<Manifest> = OnceLock::new();
    M.get_or_init(|| Manifest::load(&artifacts_dir()).expect(
        "artifacts missing: run tools/gen_artifacts.py"))
}

/// The shared toy cost table (`tests/common/mod.rs`): ~2.83× CC/No-CC
/// load ratio (the paper's ~2.7× regime) so per-device splits are
/// deterministic.
fn toy_costs() -> CostModel {
    common::toy_costs(manifest())
}

fn fleet_cfg(devices: usize, placement: &str) -> RunConfig {
    RunConfig {
        duration_s: 90.0,
        drain_s: 10.0,
        mean_rps: 7.0,
        sla_s: 6.0,
        strategy: "select-batch+timer".into(),
        devices,
        placement: placement.to_string(),
        models: vec!["llama-sim".into(), "gemma-sim".into()],
        ..RunConfig::default()
    }
}

fn run(cfg: &RunConfig) -> RunSummary {
    let cm = toy_costs();
    EngineBuilder::new(cfg).des(manifest(), &cm).unwrap()
        .run().unwrap().0
}

/// Headline scenario 1: under identical traffic, affinity routing
/// avoids the residency ping-pong round-robin causes, so it performs
/// strictly fewer swaps on a 2-device fleet.
#[test]
fn affinity_performs_fewer_swaps_than_round_robin() {
    let affinity = run(&fleet_cfg(2, "affinity"));
    let rr = run(&fleet_cfg(2, "round-robin"));
    assert_eq!(affinity.generated, rr.generated,
               "same seed, same schedule");
    assert!(affinity.completed > 0 && rr.completed > 0);
    assert!(affinity.swap_count < rr.swap_count,
            "affinity must swap strictly less: affinity {} vs \
             round-robin {}", affinity.swap_count, rr.swap_count);
    // fewer swaps means less dead load time, so latency cannot be
    // meaningfully worse
    assert!(affinity.latency_mean_s <= rr.latency_mean_s * 1.05,
            "affinity latency {} vs round-robin {}",
            affinity.latency_mean_s, rr.latency_mean_s);
}

/// Headline scenario 2: in a mixed CC/No-CC fleet serving one model
/// through round-robin, each device loads the model exactly once, so
/// the per-device load-time split is exactly the CC/No-CC load-cost
/// ratio (~2.83× in the toy table, the paper's ~2.7× regime).
#[test]
fn mixed_fleet_load_split_reflects_cc_ratio() {
    let mut cfg = fleet_cfg(2, "round-robin");
    cfg.models = vec!["llama-sim".into()];
    cfg.set("device-modes", "cc,no-cc").unwrap();
    let s = run(&cfg);
    assert_eq!(s.mode, "mixed");
    assert_eq!(s.devices, 2);
    assert_eq!(s.per_device.len(), 2);
    let cc = &s.per_device[0];
    let nocc = &s.per_device[1];
    assert_eq!(cc.mode, "cc");
    assert_eq!(nocc.mode, "no-cc");
    assert_eq!(cc.swap_count, 1, "one model: one load per device");
    assert_eq!(nocc.swap_count, 1);
    let ratio = cc.load_s / nocc.load_s;
    assert!((2.5..3.2).contains(&ratio),
            "per-device load split {ratio:.2}x should reflect the \
             ~2.7x CC load-cost ratio");
    // both devices serve traffic and report utilization
    assert!(cc.batches > 0 && nocc.batches > 0);
    assert!(cc.util > 0.0 && nocc.util > 0.0);
    // the CC device sinks strictly more seconds into loading — the
    // utilization split the mixed fleet exists to expose
    assert!(cc.load_s > nocc.load_s);
    // per-device completions add up to the fleet aggregate
    assert_eq!(cc.completed + nocc.completed, s.completed);
}

/// Backward parity: on a devices=1 fleet every placement policy is a
/// constant, so the whole `RunSummary` is placement-invariant — the
/// fleet engine degenerates to the paper's single-GPU loop.
#[test]
fn single_device_runs_are_placement_invariant() {
    let base = run(&fleet_cfg(1, "affinity"));
    assert_eq!(base.devices, 1);
    assert_eq!(base.per_device.len(), 1);
    // the single device carries all fleet aggregates
    assert_eq!(base.per_device[0].swap_count, base.swap_count);
    assert_eq!(base.per_device[0].completed, base.completed);
    for placement in ["round-robin", "least-loaded", "cc-aware"] {
        let other = run(&fleet_cfg(1, placement));
        assert_eq!(base.generated, other.generated, "{placement}");
        assert_eq!(base.completed, other.completed, "{placement}");
        assert_eq!(base.swap_count, other.swap_count, "{placement}");
        assert!((base.latency_mean_s - other.latency_mean_s).abs()
                < 1e-12, "{placement}");
        assert!((base.runtime_s - other.runtime_s).abs() < 1e-12,
                "{placement}");
    }
}

/// Scaling sanity: under overload, a 4-device fleet completes strictly
/// more requests than a single device from the same arrival schedule.
#[test]
fn fleet_scales_completions_under_overload() {
    // one device peaks near 50 rps with the toy exec table (batches of
    // 8 at ~0.16 s) before swap losses; 80 rps saturates it while a
    // 4-device fleet absorbs the load
    let overload = |devices: usize| {
        let mut cfg = fleet_cfg(devices, "affinity");
        cfg.mean_rps = 80.0;
        cfg.sla_s = 4.0;
        cfg.duration_s = 60.0;
        run(&cfg)
    };
    let one = overload(1);
    let four = overload(4);
    assert_eq!(one.generated, four.generated);
    assert!(four.completed > one.completed,
            "4 devices must complete more: {} vs {}", four.completed,
            one.completed);
    assert!(four.sla_attainment >= one.sla_attainment - 0.01,
            "attainment fell with more devices: {} vs {}",
            four.sla_attainment, one.sla_attainment);
    // work actually spread across the fleet
    assert!(four.per_device.iter().filter(|d| d.batches > 0).count()
            >= 2);
}

/// cc-aware placement on a mixed fleet must not do worse on SLA
/// attainment than residency-blind round-robin under pressure.
#[test]
fn cc_aware_attainment_not_worse_than_round_robin_on_mixed_fleet() {
    let run_mixed = |placement: &str| {
        let mut cfg = fleet_cfg(2, placement);
        cfg.set("device-modes", "cc,no-cc").unwrap();
        cfg.mean_rps = 10.0;
        cfg.sla_s = 4.0;
        run(&cfg)
    };
    let aware = run_mixed("cc-aware");
    let rr = run_mixed("round-robin");
    assert!(aware.completed > 0);
    assert!(aware.sla_attainment >= rr.sla_attainment - 0.02,
            "cc-aware {} vs round-robin {}", aware.sla_attainment,
            rr.sla_attainment);
}
