//! Integration: manifest -> weights -> PJRT compile -> execute, across
//! all three model families.  Requires `make artifacts`.

use std::path::PathBuf;
use std::sync::OnceLock;

use sincere::runtime::registry::SharedRegistry;
use sincere::runtime::{Manifest, Registry};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn registry() -> &'static SharedRegistry {
    static REG: OnceLock<SharedRegistry> = OnceLock::new();
    REG.get_or_init(|| {
        let m = Manifest::load(&artifacts_dir()).expect(
            "run `make artifacts` before cargo test");
        SharedRegistry::new(Registry::load(&m, &[], &[1, 2, 4]).unwrap())
    })
}

#[test]
fn all_families_compile_and_execute() {
    registry().with(|reg| {
        assert_eq!(reg.names().len(), 3);
        for name in reg.names() {
            let spec = reg.entry(&name).unwrap().spec.clone();
            let rows = vec![vec![3i32; spec.prompt_len]; 2];
            let rep = reg.execute(&name, &rows).unwrap();
            assert_eq!(rep.tokens.len(), 2, "{name}");
            assert_eq!(rep.tokens[0].len(), spec.decode_len, "{name}");
            for row in &rep.tokens {
                assert!(row.iter().all(|&t| (0..spec.vocab as i32)
                                       .contains(&t)),
                        "{name}: token out of vocab");
            }
        }
    });
}

#[test]
fn families_differ_behaviourally() {
    // same prompt into different families must generate different tokens
    // (independent weights): guards against artifact mixups.
    registry().with(|reg| {
        let mut outputs = Vec::new();
        for name in reg.names() {
            let spec = reg.entry(&name).unwrap().spec.clone();
            let rows = vec![(0..spec.prompt_len)
                .map(|j| (j % 256) as i32).collect::<Vec<i32>>()];
            outputs.push(reg.execute(&name, &rows).unwrap().tokens[0]
                         .clone());
        }
        assert_ne!(outputs[0], outputs[1]);
        assert_ne!(outputs[1], outputs[2]);
    });
}

#[test]
fn batch_choice_is_minimal_fit() {
    registry().with(|reg| {
        let spec = reg.entry("llama-sim").unwrap().spec.clone();
        let mk = |n: usize| vec![vec![1i32; spec.prompt_len]; n];
        assert_eq!(reg.execute("llama-sim", &mk(1)).unwrap().batch, 1);
        assert_eq!(reg.execute("llama-sim", &mk(2)).unwrap().batch, 2);
        assert_eq!(reg.execute("llama-sim", &mk(3)).unwrap().batch, 4);
        assert_eq!(reg.execute("llama-sim", &mk(4)).unwrap().batch, 4);
    });
}

#[test]
fn exec_time_grows_sublinearly_with_batch() {
    // throughput at batch 4 must beat batch 1 (the Fig 4 premise that
    // batching pays for itself)
    registry().with(|reg| {
        let spec = reg.entry("llama-sim").unwrap().spec.clone();
        let time_for = |n: usize| {
            let rows = vec![vec![1i32; spec.prompt_len]; n];
            reg.execute("llama-sim", &rows).unwrap(); // warm
            let mut best = f64::INFINITY;
            for _ in 0..3 {
                let t0 = std::time::Instant::now();
                reg.execute("llama-sim", &rows).unwrap();
                best = best.min(t0.elapsed().as_secs_f64());
            }
            best
        };
        let t1 = time_for(1);
        let t4 = time_for(4);
        assert!(t4 < 4.0 * t1,
                "batching gained nothing: b1={t1:.4}s b4={t4:.4}s");
    });
}

#[test]
fn manifest_weight_sizes_follow_table_ii() {
    let m = Manifest::load(&artifacts_dir()).unwrap();
    let get = |n: &str| m.family(n).unwrap().weight_bytes();
    assert!(get("granite-sim") > get("gemma-sim"));
    assert!(get("gemma-sim") > get("llama-sim"));
}
