//! Golden-summary regression: a small deterministic DES scenario
//! matrix (2 strategies × 2 CC modes × pipeline on/off) is rendered to
//! normalized `RunSummary` JSON and diffed against checked-in goldens
//! under `rust/tests/goldens/`.
//!
//! Everything in the matrix is virtual-time over a synthetic cost
//! table, so the JSON is bit-reproducible; any scheduling, costing or
//! summary-shape change shows up as a golden diff instead of slipping
//! through aggregate assertions.
//!
//! Workflow:
//! * missing golden → the test *seeds* it (writes the file) and passes;
//!   the CI goldens job flags unseeded/uncommitted files via
//!   `git status`, so seeded goldens must be committed to pin them;
//! * `UPDATE_GOLDENS=1 cargo test --test golden_summary` rewrites all
//!   goldens after an intentional behaviour change;
//! * otherwise any mismatch fails with both JSON strings.

mod common;

use std::path::PathBuf;
use std::sync::OnceLock;

use sincere::config::RunConfig;
use sincere::engine::EngineBuilder;
use sincere::runtime::Manifest;
use sincere::sim::calib::CostModel;
use sincere::util::json::Json;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn goldens_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/goldens")
}

fn manifest() -> &'static Manifest {
    static M: OnceLock<Manifest> = OnceLock::new();
    M.get_or_init(|| Manifest::load(&artifacts_dir()).expect(
        "artifacts missing: run tools/gen_artifacts.py"))
}

/// The shared synthetic cost table (`tests/common/mod.rs`): fixed
/// constants only (never measured), so the goldens do not depend on
/// host speed — and the same figures the parity/effect suites price.
fn golden_costs() -> CostModel {
    common::toy_costs(manifest())
}

/// Round every number to 1e-9 so the goldens stay stable against
/// benign float-formatting differences while still pinning behaviour.
fn normalize(j: &Json) -> Json {
    match j {
        Json::Num(n) => Json::Num((n * 1e9).round() / 1e9),
        Json::Arr(v) => Json::Arr(v.iter().map(normalize).collect()),
        Json::Obj(m) => Json::Obj(m.iter()
            .map(|(k, v)| (k.clone(), normalize(v)))
            .collect()),
        other => other.clone(),
    }
}

fn golden_cell(cfg: &RunConfig) -> String {
    let cm = golden_costs();
    let run = || -> String {
        let (summary, _) = EngineBuilder::new(cfg)
            .des(manifest(), &cm).unwrap().run().unwrap();
        normalize(&summary.to_json()).to_string()
    };
    let first = run();
    // determinism gate: the DES must reproduce itself within a process
    // before we compare across checkouts
    assert_eq!(first, run(),
               "DES run is nondeterministic for {}", cfg.label);
    first
}

/// The shared base of every golden cell.
fn golden_cfg(mode: &str, strategy: &str) -> RunConfig {
    let mut cfg = RunConfig {
        duration_s: 20.0,
        drain_s: 8.0,
        mean_rps: 4.0,
        sla_s: 6.0,
        strategy: strategy.to_string(),
        models: vec!["llama-sim".into(), "gemma-sim".into()],
        ..RunConfig::default()
    };
    cfg.set("mode", mode).unwrap();
    cfg.gpu.no_throttle = true;
    cfg
}

#[test]
fn golden_summaries_match() {
    let dir = goldens_dir();
    std::fs::create_dir_all(&dir).unwrap();
    let update = std::env::var("UPDATE_GOLDENS").as_deref() == Ok("1");
    let mut seeded = Vec::new();

    // the historical matrix: 2 strategies x 2 modes x pipeline on/off
    let mut cells = Vec::new();
    for strategy in ["select-batch+timer", "best-batch+timer"] {
        for mode in ["no-cc", "cc"] {
            for pipelined in [false, true] {
                let mut cfg = golden_cfg(mode, strategy);
                if pipelined {
                    cfg.gpu.pipeline_depth = 2;
                    cfg.prefetch = true;
                }
                cells.push(cfg);
            }
        }
    }
    // the data-path extension: CC batch I/O priced serialized and
    // pipelined (byte-sized from the models' payload shape, so the
    // goldens pin the bounce-budget pricing end to end)
    for depth in [0usize, 2] {
        let mut cfg = golden_cfg("cc", "select-batch+timer");
        cfg.data_path = true;
        cfg.gpu.pipeline_depth = depth;
        cells.push(cfg);
    }
    // the hardware-generation extension: the scaled-crypto + bridge
    // profile (b300-cc) and the coherent UMA profile (gh200-coherent),
    // so the goldens pin the profile pricing end to end (h100-cc needs
    // no cell of its own — it is byte-identical to the legacy CC cells
    // above, which a dedicated test asserts)
    for profile in ["b300-cc", "gh200-coherent"] {
        let mut cfg = golden_cfg("cc", "select-batch+timer");
        cfg.set("device-profiles", profile).unwrap();
        cells.push(cfg);
    }
    // the observability extension: one traced CC cell pins the
    // summary's phase_totals block (trace files land on disk only
    // when a results dir is set, so the golden pins the aggregate)
    {
        let mut cfg = golden_cfg("cc", "select-batch+timer");
        cfg.set("trace", "events").unwrap();
        cells.push(cfg);
    }
    // the tenancy extension: Zipf popularity + diurnal/flash traffic
    // + SLA classes behind each capped admission policy, so the
    // goldens pin the shed/goodput/fairness accounting end to end
    for admission in ["queue-cap", "deadline-infeasible",
                      "class-weighted"] {
        let mut cfg = golden_cfg("cc", "select-batch+timer");
        cfg.set("zipf-skew", "1.1").unwrap();
        cfg.set("admission", admission).unwrap();
        cfg.set("sla-classes", "on").unwrap();
        cfg.set("diurnal-amp", "0.3").unwrap();
        cfg.set("flash-mult", "2").unwrap();
        cfg.set("flash-start", "6").unwrap();
        cfg.set("flash-dur", "4").unwrap();
        cells.push(cfg);
    }

    // the pipeline-parallel extension: a 4-device fleet sharding both
    // models across 2-stage groups — once over sealed CC links, once
    // over the coherent UMA profile — so the goldens pin the shard
    // swap pricing, TTFT/bubble accounting and the sealed activation
    // framing end to end (stage-count 1 needs no cell of its own — it
    // is byte-identical to the legacy cells, which a dedicated test
    // asserts)
    for profile in [None, Some("gh200-coherent")] {
        let mut cfg = golden_cfg("cc", "select-batch+timer");
        cfg.devices = 4;
        cfg.set("placement", "pipeline-parallel").unwrap();
        cfg.set("pp-stages", "2").unwrap();
        if let Some(p) = profile {
            cfg.set("device-profiles", p).unwrap();
        }
        cells.push(cfg);
    }

    for mut cfg in cells {
        cfg.label = cfg.cell_label();
        let got = golden_cell(&cfg);
        let path = dir.join(format!("{}.json", cfg.label));
        if update || !path.exists() {
            std::fs::write(&path, &got).unwrap();
            seeded.push(cfg.label.clone());
            continue;
        }
        let want = std::fs::read_to_string(&path).unwrap();
        // compare normalized values, not raw text, so a golden
        // regenerated by a different writer still matches
        let want_norm = normalize(&Json::parse(want.trim()).unwrap())
            .to_string();
        assert_eq!(got, want_norm,
                   "golden mismatch for {} ({}): regenerate \
                    with UPDATE_GOLDENS=1 if intentional",
                   cfg.label, path.display());
    }
    if !seeded.is_empty() {
        eprintln!("[golden_summary] seeded {} golden(s): {:?} — commit \
                   rust/tests/goldens/ to pin them", seeded.len(), seeded);
    }
}

/// Byte-identity contract of `--data-path` (ISSUE 5 acceptance): with
/// the flag off the summary JSON must carry no data-path key at all —
/// i.e. it is byte-identical to what pre-data-path builds emitted —
/// and a No-CC run with the flag *on* must be byte-identical to the
/// same run with it off (the data path prices only the CC bounce
/// penalty).
#[test]
fn data_path_off_and_nocc_are_byte_identical() {
    // No-CC: flag on vs flag off, identical labels forced so the
    // comparison covers every byte of the document
    let mut on = golden_cfg("no-cc", "select-batch+timer");
    on.data_path = true;
    on.label = "nocc_probe".into();
    let mut off = golden_cfg("no-cc", "select-batch+timer");
    off.label = "nocc_probe".into();
    assert_eq!(golden_cell(&on), golden_cell(&off),
               "No-CC summaries must not change when the data path is \
                turned on");

    // flag off (the default): no data-path key may appear, CC included
    for mode in ["no-cc", "cc"] {
        let mut cfg = golden_cfg(mode, "select-batch+timer");
        cfg.label = cfg.cell_label();
        let text = golden_cell(&cfg);
        assert!(!text.contains("data_"),
                "{mode}: flag-off summary leaks data-path keys: {text}");
    }

    // and the flag on in CC mode *does* report the new block, with the
    // exposed figure bounded by the total
    let mut cc = golden_cfg("cc", "select-batch+timer");
    cc.data_path = true;
    cc.label = cc.cell_label();
    let text = golden_cell(&cc);
    assert!(text.contains("total_data_crypto_s")
            && text.contains("data_wire_bytes"),
            "CC data-path summary missing the batch-I/O block: {text}");
}

/// Pull one numeric field out of a summary document (NaN if absent),
/// matching on the public `Json` enum so the test does not depend on
/// accessor helpers.
fn num(j: &Json, key: &str) -> f64 {
    match j.get(key) {
        Some(Json::Num(n)) => *n,
        _ => f64::NAN,
    }
}

/// Byte-identity contract of the device profiles (ISSUE 8
/// acceptance): `--device-profiles h100-cc` must be a pure naming
/// layer over the legacy CC knobs — same RNG draws, same schedule,
/// same summary bytes — and profile-free summaries must carry no
/// bridge key at all.  The forward-looking profiles *do* change the
/// pricing: b300-cc splits the CC tax between scaled swap crypto and
/// a bridge residual, while gh200-coherent prices zero swap crypto
/// and pays only the bridge.
#[test]
fn h100_cc_profile_is_byte_identical_to_legacy_knobs() {
    // the named Hopper profiles vs the loose knobs they bundle,
    // identical labels forced so the comparison covers every byte
    for (profile, mode) in [("h100-cc", "cc"), ("h100-nocc", "no-cc")] {
        let mut named = golden_cfg(mode, "select-batch+timer");
        named.set("device-profiles", profile).unwrap();
        named.label = "profile_probe".into();
        let mut legacy = golden_cfg(mode, "select-batch+timer");
        legacy.label = "profile_probe".into();
        assert_eq!(golden_cell(&named), golden_cell(&legacy),
                   "{profile} must be byte-identical to the legacy \
                    {mode} knobs");
    }

    // profile-free runs: no bridge key may appear — this is what lets
    // CI grep the profile-free lab cells
    for mode in ["no-cc", "cc"] {
        let mut cfg = golden_cfg(mode, "select-batch+timer");
        cfg.label = cfg.cell_label();
        let text = golden_cell(&cfg);
        assert!(!text.contains("bridge") && !text.contains("_prof-"),
                "{mode}: profile-free summary leaks profile keys: {text}");
    }

    // b300-cc: both tax terms present — scaled swap crypto plus the
    // per-swap bridge residual
    let mut b300 = golden_cfg("cc", "select-batch+timer");
    b300.set("device-profiles", "b300-cc").unwrap();
    b300.label = b300.cell_label();
    let j = Json::parse(&golden_cell(&b300)).unwrap();
    assert!(num(&j, "total_crypto_s") > 0.0,
            "b300-cc must still price (scaled) swap crypto");
    assert!(num(&j, "total_bridge_s") > 0.0,
            "b300-cc must pay the bridge residual");

    // gh200-coherent: UMA swaps price zero crypto, so the whole
    // residual CC tax is the bridge constant
    let mut gh = golden_cfg("cc", "select-batch+timer");
    gh.set("device-profiles", "gh200-coherent").unwrap();
    gh.label = gh.cell_label();
    let j = Json::parse(&golden_cell(&gh)).unwrap();
    assert_eq!(num(&j, "total_crypto_s"), 0.0,
               "coherent memory must price no swap crypto");
    assert!(num(&j, "total_bridge_s") > 0.0,
            "the coherent bridge residual must be paid");
}

/// Byte-identity contract of `--trace` (ISSUE 9 acceptance): with
/// tracing off the summary JSON must be byte-identical to what
/// pre-trace builds emitted — spelling `--trace off` out must match
/// the untouched default byte for byte, and the off-path document must
/// carry no trace key at all.  With tracing on, the `phase_totals`
/// block appears and its phases account for the recorded latency.
#[test]
fn trace_off_is_byte_identical() {
    // explicit `--trace off` vs the untouched default, identical
    // labels forced so the comparison covers every byte
    let mut explicit = golden_cfg("cc", "select-batch+timer");
    explicit.set("trace", "off").unwrap();
    explicit.label = "trace_probe".into();
    let mut default = golden_cfg("cc", "select-batch+timer");
    default.label = "trace_probe".into();
    assert_eq!(golden_cell(&explicit), golden_cell(&default),
               "spelling --trace off out must not change a single byte");

    // trace off: no trace key (nor any phase key) may appear — this
    // is what lets CI grep the trace-off lab cells
    for mode in ["no-cc", "cc"] {
        let mut cfg = golden_cfg(mode, "select-batch+timer");
        cfg.label = cfg.cell_label();
        let text = golden_cell(&cfg);
        for key in ["phase_totals", "queue_wait", "_tr-"] {
            assert!(!text.contains(key),
                    "{mode}: trace-off summary leaks {key}: {text}");
        }
    }

    // trace on: the phase_totals block appears in both modes and its
    // per-request phase means sum to the mean recorded latency (the
    // waterfall identity, aggregated)
    for mode in ["no-cc", "cc"] {
        let mut cfg = golden_cfg(mode, "select-batch+timer");
        cfg.set("trace", "events").unwrap();
        cfg.label = cfg.cell_label();
        let j = Json::parse(&golden_cell(&cfg)).unwrap();
        let p = j.get("phase_totals").unwrap_or_else(
            || panic!("{mode}: traced summary missing phase_totals"));
        let f = |k: &str| num(p, k);
        let requests = f("requests");
        assert!(requests > 0.0, "{mode}: no traced requests");
        let phases = f("queue_wait_s") + f("swap_unload_s")
            + f("swap_load_s") + f("exec_s") + f("io_s");
        assert!((phases - f("latency_s")).abs() <= 1e-6 * requests,
                "{mode}: phase totals {phases} != latency {}",
                f("latency_s"));
        // the attribution slices live inside the load, never on top
        assert!(f("swap_bridge_s") + f("swap_crypto_exposed_s")
                    <= f("swap_load_s") + 1e-9,
                "{mode}: attribution exceeds the load it annotates");
    }
}

/// Byte-identity contract of `--pp-stages` (tentpole acceptance):
/// stage count 1 — and the flag left absent — must reduce the engine
/// to exactly the pre-pipeline code path: same RNG draws, same
/// schedule, same summary bytes, and no pipeline key anywhere in the
/// document.  With 2 stages the pipeline block appears: shard swaps,
/// sealed activation framing that amplifies the wire, bubble time
/// from stage imbalance, and a TTFT below the mean latency.
#[test]
fn pp_stage_1_is_byte_identical() {
    // explicit `--pp-stages 1` vs the untouched default under the
    // same placement, identical labels forced so the comparison
    // covers every byte
    let mut explicit = golden_cfg("cc", "select-batch+timer");
    explicit.devices = 4;
    explicit.set("placement", "pipeline-parallel").unwrap();
    explicit.set("pp-stages", "1").unwrap();
    explicit.label = "pp_probe".into();
    let mut default = golden_cfg("cc", "select-batch+timer");
    default.devices = 4;
    default.set("placement", "pipeline-parallel").unwrap();
    default.label = "pp_probe".into();
    assert_eq!(golden_cell(&explicit), golden_cell(&default),
               "spelling --pp-stages 1 out must not change a single \
                byte");

    // flag off: no pipeline key may appear — this is what lets CI
    // grep the stage-free lab cells
    for mode in ["no-cc", "cc"] {
        let mut cfg = golden_cfg(mode, "select-batch+timer");
        cfg.label = cfg.cell_label();
        let text = golden_cell(&cfg);
        for key in ["pp_stages", "ttft", "activation", "bubble", "_pp"] {
            assert!(!text.contains(key),
                    "{mode}: stage-free summary leaks {key}: {text}");
        }
    }

    // stages 2: the pipeline block appears, the sealed inter-stage
    // frames amplify the activation wire, imbalance leaves bubble
    // time, and the first token lands before the full latency
    let mut pp = golden_cfg("cc", "select-batch+timer");
    pp.devices = 4;
    pp.set("placement", "pipeline-parallel").unwrap();
    pp.set("pp-stages", "2").unwrap();
    pp.label = pp.cell_label();
    let j = Json::parse(&golden_cell(&pp)).unwrap();
    assert_eq!(num(&j, "pp_stages"), 2.0,
               "sharded summary missing the pipeline block");
    assert!(num(&j, "activation_bytes") > 0.0,
            "no activations priced");
    assert!(num(&j, "activation_wire_bytes")
                > num(&j, "activation_bytes"),
            "sealed nonce|ct|tag framing must amplify the wire");
    assert!(num(&j, "total_activation_crypto_s") > 0.0,
            "CC inter-stage links must pay activation crypto");
    assert!(num(&j, "total_bubble_s") > 0.0,
            "unequal layer shares must leave bubble time");
    assert!(num(&j, "ttft_mean_s") > 0.0
            && num(&j, "ttft_mean_s") < num(&j, "latency_mean_s"),
            "TTFT must land strictly inside the request latency");

    // the coherent profile moves activations in the clear: same
    // payload pricing, no sealing tax on the wire
    let mut gh = golden_cfg("cc", "select-batch+timer");
    gh.devices = 4;
    gh.set("placement", "pipeline-parallel").unwrap();
    gh.set("pp-stages", "2").unwrap();
    gh.set("device-profiles", "gh200-coherent").unwrap();
    gh.label = gh.cell_label();
    let j = Json::parse(&golden_cell(&gh)).unwrap();
    assert!(num(&j, "activation_bytes") > 0.0,
            "coherent run priced no activations");
    assert_eq!(num(&j, "activation_wire_bytes"),
               num(&j, "activation_bytes"),
               "coherent links must move activations unframed");
    assert_eq!(num(&j, "total_activation_crypto_s"), 0.0,
               "coherent links must price no activation crypto");
}

/// Byte-identity contract of the tenancy flags (ISSUE 6 acceptance):
/// `catalog off, zipf off, admission none, classes off` must reduce
/// the engine to exactly the pre-tenancy code path — same RNG draws,
/// same schedule, same summary bytes — and the off-path document must
/// carry no tenancy key at all.
#[test]
fn tenancy_off_is_byte_identical() {
    // explicitly-set off values vs the untouched defaults, identical
    // labels forced so the comparison covers every byte
    let mut explicit = golden_cfg("cc", "select-batch+timer");
    explicit.set("catalog", "0").unwrap();
    explicit.set("zipf-skew", "off").unwrap();
    explicit.set("admission", "none").unwrap();
    explicit.set("sla-classes", "off").unwrap();
    explicit.set("diurnal-amp", "0").unwrap();
    explicit.set("flash-mult", "1").unwrap();
    explicit.label = "tenancy_probe".into();
    let mut default = golden_cfg("cc", "select-batch+timer");
    default.label = "tenancy_probe".into();
    assert_eq!(golden_cell(&explicit), golden_cell(&default),
               "spelling the tenancy defaults out must not change a \
                single byte");

    // flags off: no tenancy key (nor any of its nested keys) may
    // appear — this is what lets CI grep admission-off lab cells
    for mode in ["no-cc", "cc"] {
        let mut cfg = golden_cfg(mode, "select-batch+timer");
        cfg.label = cfg.cell_label();
        let text = golden_cell(&cfg);
        for key in ["tenancy", "\"shed", "\"goodput", "fairness"] {
            assert!(!text.contains(key),
                    "{mode}: flag-off summary leaks {key}: {text}");
        }
    }

    // admission alone attaches the block (classes stay off: one
    // all-zero-impossible case — classes vec must then be empty)
    let mut gate = golden_cfg("cc", "select-batch+timer");
    gate.set("admission", "queue-cap").unwrap();
    gate.label = gate.cell_label();
    let text = golden_cell(&gate);
    assert!(text.contains("\"tenancy\"")
            && text.contains("\"shed_total\"")
            && text.contains("\"goodput_rps\"")
            && text.contains("\"classes\":[]"),
            "admission-only summary missing the tenancy block: {text}");

    // classes + admission: per-class rows appear with the fixed names
    let mut classes = golden_cfg("cc", "select-batch+timer");
    classes.set("admission", "class-weighted").unwrap();
    classes.set("sla-classes", "on").unwrap();
    classes.label = classes.cell_label();
    let text = golden_cell(&classes);
    for name in ["gold", "silver", "free"] {
        assert!(text.contains(name),
                "classes-on summary missing class {name}: {text}");
    }
}
