//! Shared fixtures for the integration-test binaries.

use sincere::runtime::Manifest;
use sincere::sim::calib::CostModel;

/// The synthetic cost table behind the parity matrix, the
/// pipeline/prefetch effect tests, the lab determinism suite and the
/// golden summaries — now defined once in the library
/// (`CostModel::synthetic`) so the CI lab smoke job prices the same
/// figures.  Those suites are only comparable because they price
/// identical costs; retuning a figure in `synthetic` moves all of
/// them together (goldens then need `UPDATE_GOLDENS=1`).
///
/// OBS is capped at the largest compiled batch (8), so the DES's
/// artifact choice and the registry's compiled-executable choice are
/// the same function of the batch row count; pipelined CC loads are
/// priced cheaper than serialized ones with most of the crypto hidden.
pub fn toy_costs(manifest: &Manifest) -> CostModel {
    CostModel::synthetic(manifest)
}
