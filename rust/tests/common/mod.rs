//! Shared fixtures for the integration-test binaries.

use sincere::runtime::Manifest;
use sincere::sim::calib::{CostModel, ModelCosts};

/// The synthetic cost table behind the parity matrix, the
/// pipeline/prefetch effect tests and the golden summaries.  One
/// definition on purpose: those suites are only comparable because
/// they price identical costs, so retuning a figure here moves all of
/// them together (goldens then need `UPDATE_GOLDENS=1`).
///
/// OBS is capped at the largest compiled batch (8), so the DES's
/// artifact choice and the registry's compiled-executable choice are
/// the same function of the batch row count; pipelined CC loads are
/// priced cheaper than serialized ones with most of the crypto hidden.
pub fn toy_costs(manifest: &Manifest) -> CostModel {
    let mut cm = CostModel {
        io_s_per_row_plain: 0.0004,
        io_s_per_row_cc: 0.0013,
        ..Default::default()
    };
    for f in &manifest.families {
        let size_factor = f.weights.total_bytes as f64 / 4e6;
        let mut mc = ModelCosts {
            load_s_plain: 0.30 * size_factor,
            load_s_cc: 0.85 * size_factor,
            load_s_cc_pipe: 0.50 * size_factor,
            load_crypto_s_cc: 0.42 * size_factor,
            load_crypto_exposed_s_cc_pipe: 0.07 * size_factor,
            unload_s: 0.006,
            obs: 8,
            ..Default::default()
        };
        for &b in &[1usize, 2, 4, 8] {
            mc.exec_s_by_batch.insert(
                b, 0.07 + 0.011 * b as f64 * size_factor);
        }
        cm.models.insert(f.name.clone(), mc);
    }
    cm
}
