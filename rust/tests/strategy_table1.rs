//! Table I unit tests: every `Strategy` implementation against
//! hand-built `SchedContext` snapshots, covering the edge cases the
//! paper's plan composition has to get right — empty queues, timer
//! expiry, partial-batch drain, and the SelectBatch headroom clamp.

use sincere::coordinator::strategy::{strategy_by_name, strategy_names,
                                     Decision, DeviceView, ModelView,
                                     SchedContext, SelectBatchTimer};
use sincere::gpu::CcMode;
use sincere::runtime::ModelId;

// Interned stand-ins for the old string models "a"/"b"/"c" (sorted
// intern order, so the ids mirror the lexicographic names).
const A: ModelId = ModelId(0);
const B: ModelId = ModelId(1);
const C: ModelId = ModelId(2);

fn device(id: usize, resident: Option<ModelId>) -> DeviceView {
    DeviceView {
        id,
        mode: CcMode::Off,
        resident,
        busy: false,
        busy_s: 0.0,
        dispatched: 0,
    }
}

fn view(model: ModelId, len: usize, wait_s: f64) -> ModelView {
    ModelView {
        model,
        len,
        oldest_wait_s: wait_s,
        obs: 8,
        rate_rps: 2.0,
        est_load_s: 0.5,
        est_exec_s: 0.5,
    }
}

fn ctx(resident: Option<ModelId>, queues: Vec<ModelView>) -> SchedContext {
    SchedContext {
        now_s: 100.0,
        devices: vec![device(0, resident)],
        queues,
        sla_s: 6.0,
        timeout_s: 3.0,
    }
}

fn process(model: ModelId, take: usize) -> Decision {
    Decision::Process { model, take, device: None }
}

// ------------------------------------------------------- empty queues

#[test]
fn empty_queues_always_wait() {
    for name in strategy_names() {
        let s = strategy_by_name(name).unwrap();
        assert_eq!(s.decide(&ctx(None, vec![])), Decision::Wait,
                   "{name} with no queues");
        assert_eq!(s.decide(&ctx(Some(A), vec![])), Decision::Wait,
                   "{name} with a resident but no queues");
    }
}

// -------------------------------------------------------- timer expiry

#[test]
fn timer_expiry_forces_undersized_batch() {
    // 3 queued (obs 8), head overdue: every timer strategy must fire
    // with exactly the queue contents, never wait for a full batch.
    for name in ["best-batch+timer", "select-batch+timer",
                 "best-batch+partial+timer"] {
        let s = strategy_by_name(name).unwrap();
        let c = ctx(None, vec![view(A, 3, 3.5)]);
        match s.decide(&c) {
            Decision::Process { model, take, .. } => {
                assert_eq!(model, A, "{name}");
                assert!(take >= 1 && take <= 3, "{name} take {take}");
            }
            Decision::Wait => panic!("{name} waited past the timer"),
        }
    }
}

#[test]
fn timer_expiry_is_longest_wait_first_not_resident_first() {
    // Both queues overdue; "b" has waited longer.  The resident
    // preference must NOT apply to the timer override (a saturated
    // resident queue would starve every other model forever).
    let c = ctx(Some(A),
                vec![view(A, 8, 3.2), view(B, 2, 5.0)]);
    for name in ["best-batch+timer", "select-batch+timer"] {
        let s = strategy_by_name(name).unwrap();
        match s.decide(&c) {
            Decision::Process { model, .. } => {
                assert_eq!(model, B, "{name} must honour the oldest \
                                        overdue head");
            }
            Decision::Wait => panic!("{name} waited"),
        }
    }
}

#[test]
fn exactly_at_timeout_fires() {
    // boundary: oldest_wait == timeout_s counts as overdue
    let s = strategy_by_name("best-batch+timer").unwrap();
    let c = ctx(None, vec![view(A, 2, 3.0)]);
    assert_eq!(s.decide(&c), process(A, 2));
}

#[test]
fn below_timeout_below_obs_waits() {
    let s = strategy_by_name("best-batch+timer").unwrap();
    let c = ctx(None, vec![view(A, 7, 2.9)]);
    assert_eq!(s.decide(&c), Decision::Wait);
}

// ------------------------------------------------- partial-batch drain

#[test]
fn partial_drains_resident_before_swapping_away() {
    // "b" is overdue (would force a swap); resident "a" still has two
    // queued — the Partial Batch plan drains them first, pinned to the
    // resident's device.
    let s = strategy_by_name("best-batch+partial+timer").unwrap();
    let c = ctx(Some(A), vec![view(A, 2, 0.5), view(B, 3, 4.0)]);
    assert_eq!(s.decide(&c),
               Decision::Process { model: A, take: 2,
                                   device: Some(0) });
}

#[test]
fn partial_drain_happens_once_per_residency() {
    // Same strategy *instance* across ticks: the first decision drains
    // the resident, the second must let the swap proceed (an
    // unconditional drain rule would pin the resident forever under
    // open-loop arrivals).
    let s = strategy_by_name("best-batch+partial+timer").unwrap();
    let c = ctx(Some(A), vec![view(A, 2, 0.5), view(B, 3, 4.0)]);
    assert_eq!(s.decide(&c),
               Decision::Process { model: A, take: 2,
                                   device: Some(0) });
    // resident queue refilled during the drain — swap must still win
    let c2 = ctx(Some(A), vec![view(A, 1, 0.1), view(B, 3, 4.2)]);
    assert_eq!(s.decide(&c2), process(B, 3));
}

#[test]
fn partial_without_resident_backlog_swaps_immediately() {
    let s = strategy_by_name("best-batch+partial+timer").unwrap();
    let c = ctx(Some(A), vec![view(B, 3, 4.0)]);
    assert_eq!(s.decide(&c), process(B, 3));
}

#[test]
fn partial_drain_targets_resident_on_second_device() {
    // Fleet: resident "a" on device 1; the drain decision must pin
    // device 1 so the engine does not place the batch elsewhere.
    let s = strategy_by_name("best-batch+partial+timer").unwrap();
    let mut c = ctx(None, vec![view(A, 2, 0.5), view(B, 3, 4.0)]);
    c.devices.push(device(1, Some(A)));
    assert_eq!(s.decide(&c),
               Decision::Process { model: A, take: 2,
                                   device: Some(1) });
}

#[test]
fn partial_drain_is_bounded_on_multi_device_fleets() {
    // Two residents (a on dev0, b on dev1) with refilling queues and an
    // overdue third model: each resident gets exactly one final drain,
    // then the swap to "c" must go through — a shared single drain slot
    // would let a and b ping-pong drains and starve "c" forever.
    let s = strategy_by_name("best-batch+partial+timer").unwrap();
    let fleet_ctx = |a_len: usize, b_len: usize| {
        let mut c = ctx(Some(A),
                        vec![view(A, a_len, 0.5), view(B, b_len, 0.6),
                             view(C, 3, 4.0)]);
        c.devices.push(device(1, Some(B)));
        c
    };
    assert_eq!(s.decide(&fleet_ctx(2, 2)),
               Decision::Process { model: A, take: 2,
                                   device: Some(0) });
    // a's queue refilled during its drain — b drains next, not a again
    assert_eq!(s.decide(&fleet_ctx(2, 2)),
               Decision::Process { model: B, take: 2,
                                   device: Some(1) });
    // both drained: the swap to the overdue model proceeds
    assert_eq!(s.decide(&fleet_ctx(1, 1)), process(C, 3));
}

// ------------------------------------------- select-batch headroom

#[test]
fn select_batch_sizes_from_rate_and_headroom() {
    // rate 2 rps, desired latency = 6 − 0.5 − 0.5 = 5 s → target 10,
    // clamped to OBS 8
    let v = view(A, 12, 0.1);
    assert_eq!(SelectBatchTimer::target_batch(&v, 6.0), 8);
    // tighter SLA 2 s → desired 1 s → target 2
    assert_eq!(SelectBatchTimer::target_batch(&v, 2.0), 2);
}

#[test]
fn select_batch_headroom_clamp_floors_infeasible_slas() {
    // est_load + est_exec exceed the SLA entirely: the naive formula
    // would go negative and degrade to batch-1 thrashing; the clamp
    // floors desired latency at 25% of the SLA.
    let mut v = view(A, 12, 0.1);
    v.est_load_s = 5.0;
    v.est_exec_s = 3.0;
    v.rate_rps = 4.0;
    // desired = max(6 − 8, 0.25 × 6) = 1.5 s → target 6
    assert_eq!(SelectBatchTimer::target_batch(&v, 6.0), 6);
}

#[test]
fn select_batch_unknown_rate_clamps_to_one() {
    let mut v = view(A, 12, 0.1);
    v.rate_rps = 0.0;
    assert_eq!(SelectBatchTimer::target_batch(&v, 6.0), 1,
               "no rate estimate must still make progress");
}

#[test]
fn select_batch_overdue_take_is_capped_by_queue_length() {
    let s = strategy_by_name("select-batch+timer").unwrap();
    // overdue head with only 3 queued while the target (rate 8 ×
    // desired 5 s → obs-clamped 8) is larger: take the whole queue
    let mut c = ctx(None, vec![view(A, 3, 4.0)]);
    c.queues[0].rate_rps = 8.0;
    assert_eq!(s.decide(&c), process(A, 3));
}

#[test]
fn select_batch_waits_below_target() {
    let s = strategy_by_name("select-batch+timer").unwrap();
    // rate 2, desired 5 → target 8 (obs clamp); queue of 7, not overdue
    // → wait for more arrivals... but only when below target:
    let c = ctx(None, vec![view(A, 7, 0.1)]);
    assert_eq!(s.decide(&c), Decision::Wait);
}
