//! Scenario-lab integration: the parallel runner is deterministic
//! across thread counts, the `paper-72` preset reproduces the legacy
//! hand-rolled serial sweep cell-for-cell, seed replicas aggregate,
//! and saved runs round-trip through disk.

mod common;

use std::path::PathBuf;
use std::sync::OnceLock;

use sincere::config::RunConfig;
use sincere::engine::EngineBuilder;
use sincere::gpu::CcMode;
use sincere::lab::{self, LabRunner};
use sincere::runtime::Manifest;
use sincere::sim::calib::CostModel;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn manifest() -> &'static Manifest {
    static M: OnceLock<Manifest> = OnceLock::new();
    M.get_or_init(|| Manifest::load(&artifacts_dir()).expect(
        "artifacts missing: run tools/gen_artifacts.py"))
}

fn costs() -> CostModel {
    common::toy_costs(manifest())
}

/// Short cells so the 72-cell equivalence matrix stays fast.
fn base_cfg() -> RunConfig {
    RunConfig {
        duration_s: 20.0,
        drain_s: 8.0,
        mean_rps: 4.0,
        models: vec!["llama-sim".into(), "gemma-sim".into()],
        ..RunConfig::default()
    }
}

/// The acceptance property: `--threads 1` and `--threads N` produce
/// byte-identical cells JSON (the CI `lab` job re-checks this through
/// the real binary).
#[test]
fn thread_count_never_changes_output_bytes() {
    let spec = lab::preset_by_name("smoke").unwrap();
    let grid = spec.expand(&RunConfig::default()).unwrap();
    let jobs = grid.jobs(grid.seeds);
    let cm = costs();
    let run = |threads: usize| -> String {
        let cells = LabRunner::new(manifest(), &cm)
            .threads(threads).quiet(true).run(&jobs).unwrap();
        lab::run_to_json(&cells).to_string()
    };
    let serial = run(1);
    assert_eq!(serial, run(2), "2 threads changed the bytes");
    assert_eq!(serial, run(8), "8 threads changed the bytes");
}

/// Same property on the tenancy preset — the hot-path stressor (Zipf
/// re-routing, admission gates, SLA classes, synthetic catalog cells
/// that exercise the shared expansion cache).  This is the grid the
/// interned-id/pooled-buffer refactor must not perturb by a byte at
/// any worker count.
#[test]
fn tenancy_preset_bytes_identical_across_threads() {
    let spec = lab::preset_by_name("tenancy").unwrap();
    let grid = spec.expand(&RunConfig::default()).unwrap();
    let jobs = grid.jobs(grid.seeds);
    let cm = costs();
    let run = |threads: usize| -> String {
        let cells = LabRunner::new(manifest(), &cm)
            .threads(threads).quiet(true).run(&jobs).unwrap();
        lab::run_to_json(&cells).to_string()
    };
    let serial = run(1);
    assert_eq!(serial, run(2), "2 threads changed the tenancy bytes");
    assert_eq!(serial, run(8), "8 threads changed the tenancy bytes");
}

/// `sweep` is an alias for this preset, so the grid must reproduce
/// the deleted hand-rolled loop exactly: same cell order, labels and
/// summary JSON.
#[test]
fn paper_72_grid_matches_the_legacy_serial_loop() {
    let cm = costs();
    let base = base_cfg();
    let spec = lab::preset_by_name("paper-72").unwrap();
    let grid = spec.expand(&base).unwrap();
    assert_eq!(grid.cells.len(), 72);
    let jobs = grid.jobs(grid.seeds);
    let cells = LabRunner::new(manifest(), &cm)
        .threads(0).quiet(true).run(&jobs).unwrap();

    // the legacy loop, verbatim from the old cmd_sweep
    let mut legacy = Vec::new();
    for mode in [CcMode::Off, CcMode::On] {
        for pattern in sincere::traffic::PATTERN_NAMES {
            for strategy in sincere::coordinator::strategy_names() {
                for &sla in sincere::config::SLA_LADDER {
                    let mut c = base.clone();
                    c.mode = mode;
                    c.gpu.mode = mode;
                    c.pattern = pattern.to_string();
                    c.strategy = strategy.to_string();
                    c.sla_s = sla;
                    c.label = c.cell_label();
                    c.results_dir = None;
                    let (s, _) = EngineBuilder::new(&c)
                        .des(manifest(), &cm).unwrap().run().unwrap();
                    legacy.push(s);
                }
            }
        }
    }

    assert_eq!(cells.len(), legacy.len());
    for (got, want) in cells.iter().zip(&legacy) {
        assert_eq!(got.label, want.label, "cell order drifted");
        assert_eq!(got.to_json().to_string(),
                   want.to_json().to_string(),
                   "cell {} differs from the legacy sweep", got.label);
    }
}

#[test]
fn seed_replicas_differ_and_aggregate() {
    let spec = lab::preset_by_name("smoke").unwrap();
    let grid = spec.expand(&RunConfig::default()).unwrap();
    assert_eq!(grid.seeds, 2);
    let jobs = grid.jobs(grid.seeds);
    let cm = costs();
    let cells = LabRunner::new(manifest(), &cm)
        .threads(2).quiet(true).run(&jobs).unwrap();
    assert_eq!(cells.len(), grid.cells.len() * 2);

    // replicas of one cell share the label but not the seed
    assert_eq!(cells[0].label, cells[1].label);
    assert_eq!(cells[0].seed, 42);
    assert_eq!(cells[1].seed, 43);

    let stats = lab::aggregate(&cells);
    assert_eq!(stats.len(), grid.cells.len());
    for s in &stats {
        assert_eq!(s.replicas, 2, "{}", s.label);
    }
    // different seeds draw different traffic, so at least one cell
    // must show cross-replica spread
    assert!(stats.iter().any(|s| s.latency_mean_s.stddev > 0.0),
            "identical replicas: seeds are not reaching the traffic");
    let table = lab::stats_table(&stats);
    assert!(table.contains(&stats[0].label), "{table}");
}

#[test]
fn bad_placement_name_reports_the_table() {
    let spec = lab::ScenarioSpec {
        name: "t".into(),
        description: String::new(),
        base: Vec::new(),
        axes: vec![("placement".into(),
                    vec!["teleport".into()])],
        exclude: Vec::new(),
        seeds: 1,
    };
    let err = spec.expand(&RunConfig::default()).unwrap_err()
        .to_string();
    assert!(err.contains("teleport") && err.contains("affinity"),
            "{err}");
}

#[test]
fn saved_runs_roundtrip_through_disk() {
    let spec = lab::preset_by_name("smoke").unwrap();
    let grid = spec.expand(&RunConfig::default()).unwrap();
    let jobs = grid.jobs(1);
    let cm = costs();
    let cells = LabRunner::new(manifest(), &cm)
        .threads(1).quiet(true).run(&jobs).unwrap();

    let dir = std::env::temp_dir().join("sincere_lab_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cells.json");
    std::fs::write(&path, lab::run_to_json(&cells).to_string())
        .unwrap();
    let back = lab::load_run(&path).unwrap();
    assert_eq!(back.len(), cells.len());
    for (a, b) in back.iter().zip(&cells) {
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }
}
