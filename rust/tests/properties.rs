//! Property-based tests over coordinator/substrate invariants, using the
//! in-repo `util::prop` framework (offline stand-in for proptest).

use sincere::config::RunConfig;
use sincere::coordinator::queues::ModelQueues;
use sincere::coordinator::request::Request;
use sincere::coordinator::strategy::{strategy_by_name, strategy_names,
                                     Decision, DeviceView, ModelView,
                                     SchedContext};
use sincere::gpu::cc::CcSession;
use sincere::gpu::CcMode;
use sincere::gpu::hbm::HbmAllocator;
use sincere::metrics::hist::Histogram;
use sincere::prop_assert;
use sincere::runtime::{ModelId, ModelTable};
use sincere::util::json::Json;
use sincere::util::prop::{forall, Gen};

// ------------------------------------------------------------- queues

/// FIFO per model under random interleavings of push/pop.
#[test]
fn prop_queues_fifo_per_model() {
    forall("queues fifo", 200, |g| {
        let models = ["a", "b", "c"];
        // sorted input: index i interns to ModelId(i)
        let mut q = ModelQueues::new(ModelTable::shared(models));
        let mut popped: Vec<Vec<u64>> = vec![Vec::new(); models.len()];
        let mut pushed: Vec<Vec<u64>> = vec![Vec::new(); models.len()];
        let mut next_id = 0u64;
        for _ in 0..g.usize_in(1, 60) {
            if g.bool() {
                let mi = g.usize_in(0, models.len() - 1);
                q.push(Request {
                    id: next_id,
                    model: ModelId(mi as u32),
                    tokens: vec![],
                    arrival_s: next_id as f64,
                    class: 0,
                });
                pushed[mi].push(next_id);
                next_id += 1;
            } else {
                let mi = g.usize_in(0, models.len() - 1);
                let n = g.usize_in(0, 5);
                for r in q.pop_n(ModelId(mi as u32), n) {
                    popped[mi].push(r.id);
                }
            }
        }
        // drain the rest
        for mi in 0..models.len() {
            for r in q.pop_n(ModelId(mi as u32), usize::MAX) {
                popped[mi].push(r.id);
            }
        }
        for mi in 0..models.len() {
            prop_assert!(popped[mi] == pushed[mi],
                         "model {} order: pushed {:?} popped {:?}",
                         models[mi], pushed[mi], popped[mi]);
        }
        Ok(())
    });
}

// -------------------------------------------------------------- strategy

/// Every strategy decision must reference a known queue and take a
/// positive number of requests no larger than the queue length.
#[test]
fn prop_strategy_decisions_valid() {
    forall("strategy decisions valid", 400, |g| {
        let n_queues = g.usize_in(1, 5);
        let queues: Vec<ModelView> = (0..n_queues).map(|i| ModelView {
            model: ModelId(i as u32),
            len: g.usize_in(1, 64),
            oldest_wait_s: g.f64_in(0.0, 12.0),
            obs: g.usize_in(1, 32),
            rate_rps: g.f64_in(0.0, 16.0),
            est_load_s: g.f64_in(0.0, 2.0),
            est_exec_s: g.f64_in(0.0, 2.0),
        }).collect();
        // a random small fleet with random residents; device 0 is
        // always free so strategies can dispatch
        let n_dev = g.usize_in(1, 3);
        let devices: Vec<DeviceView> = (0..n_dev).map(|d| DeviceView {
            id: d,
            mode: if g.bool() { CcMode::On } else { CcMode::Off },
            resident: if g.bool() {
                Some(ModelId(g.usize_in(0, n_queues - 1) as u32))
            } else {
                None
            },
            busy: d != 0 && g.bool(),
            busy_s: g.f64_in(0.0, 100.0),
            dispatched: g.u64() % 100,
        }).collect();
        let ctx = SchedContext {
            now_s: g.f64_in(0.0, 1000.0),
            devices,
            queues: queues.clone(),
            sla_s: g.f64_in(0.5, 10.0),
            timeout_s: g.f64_in(0.1, 5.0),
        };
        for name in strategy_names() {
            let s = strategy_by_name(name).unwrap();
            match s.decide(&ctx) {
                Decision::Wait => {}
                Decision::Process { model, take, device } => {
                    let v = queues.iter().find(|v| v.model == model);
                    prop_assert!(v.is_some(),
                                 "{name} chose unknown model {model:?}");
                    let v = v.unwrap();
                    prop_assert!(take >= 1, "{name} take=0");
                    prop_assert!(take <= v.len,
                                 "{name} take {take} > len {}", v.len);
                    prop_assert!(take <= v.obs.max(1),
                                 "{name} take {take} > obs {}", v.obs);
                    if let Some(d) = device {
                        prop_assert!(d < ctx.devices.len(),
                                     "{name} pinned unknown device {d}");
                        prop_assert!(!ctx.devices[d].busy,
                                     "{name} pinned a busy device {d}");
                    }
                }
            }
        }
        Ok(())
    });
}

/// Timer guarantee: if any head request is overdue, timer strategies
/// never answer Wait.
#[test]
fn prop_timer_never_waits_when_overdue() {
    forall("timer liveness", 300, |g| {
        let overdue_wait = g.f64_in(2.0, 20.0);
        let timeout = g.f64_in(0.1, 2.0);
        let queues = vec![ModelView {
            model: ModelId(0),
            len: g.usize_in(1, 32),
            oldest_wait_s: overdue_wait,
            obs: g.usize_in(1, 32),
            rate_rps: g.f64_in(0.0, 8.0),
            est_load_s: 0.3,
            est_exec_s: 0.2,
        }];
        let ctx = SchedContext {
            now_s: 50.0,
            devices: vec![DeviceView {
                id: 0,
                mode: CcMode::Off,
                resident: None,
                busy: false,
                busy_s: 0.0,
                dispatched: 0,
            }],
            queues,
            sla_s: 6.0,
            timeout_s: timeout,
        };
        for name in ["best-batch+timer", "select-batch+timer",
                     "best-batch+partial+timer"] {
            let s = strategy_by_name(name).unwrap();
            prop_assert!(s.decide(&ctx) != Decision::Wait,
                         "{name} waited with an overdue head \
                          (wait {overdue_wait} > timeout {timeout})");
        }
        Ok(())
    });
}

// ----------------------------------------------------------------- hbm

/// Allocator conservation + no-overlap under random alloc/free.
#[test]
fn prop_hbm_allocator_invariants() {
    forall("hbm invariants", 200, |g| {
        let capacity = 1u64 << g.usize_in(10, 20);
        let mut h = HbmAllocator::new(capacity);
        let mut live: Vec<sincere::gpu::hbm::HbmBuffer> = Vec::new();
        for _ in 0..g.usize_in(1, 80) {
            if g.bool() || live.is_empty() {
                let len = 1 + g.u64() % (capacity / 4);
                if let Ok(buf) = h.alloc(len) {
                    // no overlap with any live buffer
                    for other in &live {
                        let disjoint = buf.offset + buf.len
                            <= other.offset
                            || other.offset + other.len <= buf.offset;
                        prop_assert!(disjoint,
                                     "overlap {buf:?} vs {other:?}");
                    }
                    live.push(buf);
                }
            } else {
                let i = g.usize_in(0, live.len() - 1);
                h.free(live.swap_remove(i));
            }
            let used: u64 = live.iter().map(|b| b.len).sum();
            prop_assert!(h.in_use() == used,
                         "in_use {} != live {}", h.in_use(), used);
            prop_assert!(h.in_use() + h.free_bytes() == capacity,
                         "conservation violated");
            prop_assert!(h.fragmentation() >= 0.0
                         && h.fragmentation() <= 1.0,
                         "fragmentation out of range");
        }
        Ok(())
    });
}

// ---------------------------------------------------------------- crypto

/// seal∘open == id for arbitrary lengths; any single-bit flip is caught.
#[test]
fn prop_cc_seal_open_roundtrip_and_tamper() {
    let session = CcSession::establish(0xDEC0DE).unwrap();
    forall("cc aead", 120, |g| {
        let data: Vec<u8> = (0..g.usize_in(0, 4096))
            .map(|_| g.u64() as u8).collect();
        let sealed = session.seal(&data);
        let opened = session.open(&sealed).map_err(|e| e.to_string())?;
        prop_assert!(opened == data, "roundtrip mismatch at len {}",
                     data.len());
        if !sealed.is_empty() {
            let mut tampered = sealed.clone();
            let byte = g.usize_in(0, tampered.len() - 1);
            let bit = 1u8 << g.usize_in(0, 7);
            tampered[byte] ^= bit;
            prop_assert!(session.open(&tampered).is_err(),
                         "tamper at byte {byte} bit {bit} not caught");
        }
        Ok(())
    });
}

// ------------------------------------------------------------- histogram

/// Quantiles are monotone in q and bounded by min/max.
#[test]
fn prop_histogram_quantiles_monotone() {
    forall("hist quantiles", 150, |g| {
        let mut h = Histogram::new();
        for _ in 0..g.usize_in(1, 300) {
            h.record(g.f64_in(0.0, 100.0));
        }
        let qs = [0.0, 0.1, 0.5, 0.9, 0.99, 1.0];
        let vals: Vec<f64> = qs.iter().map(|&q| h.quantile(q)).collect();
        for w in vals.windows(2) {
            prop_assert!(w[0] <= w[1] + 1e-12,
                         "quantiles not monotone: {vals:?}");
        }
        prop_assert!(vals[0] >= h.min() - 1e-12, "q0 below min");
        prop_assert!(*vals.last().unwrap() <= h.max() + 1e-12,
                     "q1 above max");
        // mean within [min, max]
        prop_assert!(h.mean() >= h.min() - 1e-12
                     && h.mean() <= h.max() + 1e-12, "mean out of range");
        Ok(())
    });
}

// ----------------------------------------------------------------- json

fn random_json(g: &mut Gen, depth: usize) -> Json {
    match if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) } {
        0 => Json::Null,
        1 => Json::Bool(g.bool()),
        2 => Json::Num((g.u64() % 1_000_000) as f64
                       * if g.bool() { -1.0 } else { 1.0 }),
        3 => Json::Str((0..g.usize_in(0, 12))
            .map(|_| char::from(b'a' + (g.u64() % 26) as u8))
            .collect::<String>() + if g.bool() { "\"\\\n" } else { "" }),
        4 => Json::Arr((0..g.usize_in(0, 4))
            .map(|_| random_json(g, depth - 1)).collect()),
        _ => Json::Obj((0..g.usize_in(0, 4))
            .map(|i| (format!("k{i}"), random_json(g, depth - 1)))
            .collect()),
    }
}

/// parse(serialize(v)) == v for arbitrary JSON trees.
#[test]
fn prop_json_roundtrip() {
    forall("json roundtrip", 300, |g| {
        let v = random_json(g, 3);
        let text = v.to_string();
        let back = Json::parse(&text).map_err(|e| e.to_string())?;
        prop_assert!(back == v, "roundtrip mismatch: {text}");
        Ok(())
    });
}

// ------------------------------------------------------------- lab::spec

/// The axis pools a random spec draws from — every value is valid, so
/// expansion failures in these properties are real bugs, not typos.
const AXIS_POOLS: &[(&str, &[&str])] = &[
    ("mode", &["no-cc", "cc"]),
    ("pattern", &["gamma", "bursty", "ramp"]),
    ("strategy", &["best-batch", "select-batch+timer"]),
    ("sla", &["6", "12", "18"]),
    ("rps", &["3", "6", "9"]),
    ("devices", &["1", "2"]),
    ("placement", &["affinity", "round-robin", "least-loaded"]),
    ("pipeline-depth", &["0", "2", "4"]),
    ("prefetch", &["off", "on"]),
    ("data-path", &["off", "on"]),
    ("tokens-in", &["16", "128", "1024"]),
    ("tokens-out", &["50", "256"]),
    ("catalog-size", &["0", "4", "8"]),
    ("zipf-skew", &["off", "0.8", "1.2"]),
    ("admission", &["none", "queue-cap", "deadline-infeasible",
                    "class-weighted"]),
    ("sla-classes", &["off", "on"]),
];

/// A random spec over the valid-value pools: each axis is swept with
/// probability 1/2, with a random nonempty prefix-free subset of its
/// pool (subset order randomized so declaration order varies too).
fn random_spec(g: &mut Gen) -> sincere::lab::ScenarioSpec {
    let mut axes = Vec::new();
    for (name, pool) in AXIS_POOLS {
        if !g.bool() {
            continue;
        }
        let n = g.usize_in(1, pool.len());
        let mut vals: Vec<String> = pool.iter().map(|v| v.to_string())
            .collect();
        // random rotation, then truncate: a distinct, shuffled subset
        let rot = g.usize_in(0, vals.len() - 1);
        vals.rotate_left(rot);
        vals.truncate(n);
        axes.push((name.to_string(), vals));
    }
    sincere::lab::ScenarioSpec {
        name: "prop".into(),
        description: String::new(),
        base: Vec::new(),
        axes,
        exclude: Vec::new(),
        seeds: 1 + g.usize_in(0, 3),
    }
}

/// Expansion is canonical: the declaration order of the spec's axes is
/// irrelevant — the expanded labels, configs and seeds depend only on
/// the set of (axis, values) pairs.
#[test]
fn prop_lab_expansion_stable_under_axis_declaration_order() {
    forall("lab axis order", 60, |g| {
        let spec = random_spec(g);
        let base = RunConfig::default();
        let a = spec.expand(&base).map_err(|e| e.to_string())?;
        let mut shuffled = spec.clone();
        shuffled.axes.reverse();
        if g.bool() && shuffled.axes.len() > 1 {
            // an extra rotation so more than two orders are exercised
            let rot = g.usize_in(0, shuffled.axes.len() - 1);
            shuffled.axes.rotate_left(rot);
        }
        let b = shuffled.expand(&base).map_err(|e| e.to_string())?;
        prop_assert!(a.cells.len() == b.cells.len(),
                     "cell counts differ: {} vs {}", a.cells.len(),
                     b.cells.len());
        for (ca, cb) in a.cells.iter().zip(b.cells.iter()) {
            prop_assert!(ca.label == cb.label,
                         "label order drifted: {} vs {}", ca.label,
                         cb.label);
            prop_assert!(ca.cfg.seed == cb.cfg.seed, "seed drifted");
            prop_assert!(ca.assignment == cb.assignment,
                         "assignment drifted for {}", ca.label);
        }
        Ok(())
    });
}

/// Exclusion rules only ever *shrink* the grid: every surviving cell
/// was in the unexcluded expansion, order is preserved, and kept +
/// pruned add up to the raw grid.
#[test]
fn prop_lab_exclusions_only_shrink() {
    forall("lab exclusions shrink", 60, |g| {
        let mut spec = random_spec(g);
        spec.seeds = 1;
        let base = RunConfig::default();
        let full = spec.expand(&base).map_err(|e| e.to_string())?;

        // random rules drawn from the swept axes' own (valid) values
        let mut with_rules = spec.clone();
        for _ in 0..g.usize_in(1, 3) {
            if spec.axes.is_empty() {
                break;
            }
            let rule: Vec<(String, String)> = (0..g.usize_in(1, 2))
                .map(|_| {
                    let (name, vals) = g.choose(&spec.axes);
                    (name.clone(), g.choose(vals).clone())
                })
                .collect();
            with_rules.exclude.push(rule);
        }
        let pruned_grid = match with_rules.expand(&base) {
            Ok(grid) => grid,
            // shrinking to nothing is still shrinking — the hard error
            // is the lab refusing to run an empty grid
            Err(e) if e.to_string().contains("empty grid") => {
                return Ok(());
            }
            Err(e) => return Err(e.to_string()),
        };
        prop_assert!(pruned_grid.cells.len() <= full.cells.len(),
                     "exclusions grew the grid");
        prop_assert!(pruned_grid.cells.len() + pruned_grid.pruned
                     == full.cells.len() + full.pruned,
                     "kept + pruned must cover the raw grid");
        // surviving cells appear in the full grid, in the same order
        let full_labels: Vec<&str> = full.cells.iter()
            .map(|c| c.label.as_str()).collect();
        let mut cursor = 0usize;
        for c in &pruned_grid.cells {
            let pos = full_labels[cursor..].iter()
                .position(|l| *l == c.label);
            prop_assert!(pos.is_some(),
                         "cell {} not a subsequence of the full grid",
                         c.label);
            cursor += pos.unwrap() + 1;
        }
        Ok(())
    });
}

/// Replica seeds are unique per cell×replica: within a cell the seeds
/// are distinct with replica 0 keeping the base seed, and the flattened
/// (cell, replica) job list covers every pair exactly once.
#[test]
fn prop_lab_replica_seeds_unique_per_cell() {
    forall("lab replica seeds", 60, |g| {
        let spec = random_spec(g);
        let base = RunConfig { seed: g.u64(), ..RunConfig::default() };
        let grid = spec.expand(&base).map_err(|e| e.to_string())?;
        let seeds = 1 + g.usize_in(0, 4);
        let jobs = grid.jobs(seeds);
        prop_assert!(jobs.len() == grid.cells.len() * seeds,
                     "job count {} != cells {} x seeds {seeds}",
                     jobs.len(), grid.cells.len());
        let mut pairs = std::collections::BTreeSet::new();
        for job in &jobs {
            prop_assert!(pairs.insert((job.cell, job.replica)),
                         "duplicate (cell, replica) = ({}, {})",
                         job.cell, job.replica);
            prop_assert!(
                job.cfg.seed == sincere::lab::spec::replica_seed(
                    grid.cells[job.cell].cfg.seed, job.replica),
                "seed not derived from (base, replica)");
        }
        for ci in 0..grid.cells.len() {
            let cell_seeds: std::collections::BTreeSet<u64> = jobs.iter()
                .filter(|j| j.cell == ci).map(|j| j.cfg.seed).collect();
            prop_assert!(cell_seeds.len() == seeds,
                         "cell {ci}: {} distinct seeds for {seeds} \
                          replicas", cell_seeds.len());
        }
        // replica 0 reproduces the configured seed exactly
        prop_assert!(jobs[0].cfg.seed == grid.cells[0].cfg.seed,
                     "replica 0 must keep the base seed");
        Ok(())
    });
}

// ------------------------------------------------------------------ zipf

/// Zipf(0) is the uniform distribution: every weight is exactly 1/n.
#[test]
fn prop_zipf_skew_zero_is_uniform() {
    forall("zipf uniform at skew 0", 100, |g| {
        let n = g.usize_in(1, 40);
        let z = sincere::tenancy::zipf::Zipf::new(n, 0.0);
        let w = z.weights();
        prop_assert!(w.len() == n, "weight count");
        for (i, &wi) in w.iter().enumerate() {
            prop_assert!((wi - 1.0 / n as f64).abs() < 1e-12,
                         "rank {i} weight {wi} != 1/{n}");
        }
        Ok(())
    });
}

/// Raising the skew strictly concentrates mass on rank 1 (for any
/// catalog with at least two models).
#[test]
fn prop_zipf_higher_skew_concentrates_rank_one() {
    forall("zipf skew monotone", 100, |g| {
        let n = g.usize_in(2, 40);
        let lo = g.f64_in(0.0, 2.0);
        let hi = lo + g.f64_in(0.1, 2.0);
        let zl = sincere::tenancy::zipf::Zipf::new(n, lo);
        let zh = sincere::tenancy::zipf::Zipf::new(n, hi);
        prop_assert!(zh.weights()[0] > zl.weights()[0],
                     "n={n}: rank-1 mass {} at skew {hi} not above {} \
                      at skew {lo}", zh.weights()[0], zl.weights()[0]);
        // and within one distribution, weights never increase by rank
        for w in zh.weights().windows(2) {
            prop_assert!(w[0] >= w[1], "weights not rank-monotone");
        }
        Ok(())
    });
}

/// Sampling is deterministic in the seed: identical streams from
/// identical forks, divergent streams from different seeds.
#[test]
fn prop_zipf_sampling_deterministic_in_seed() {
    forall("zipf rng determinism", 60, |g| {
        let n = g.usize_in(2, 24);
        let skew = g.f64_in(0.1, 2.5);
        let seed = g.u64();
        let z = sincere::tenancy::zipf::Zipf::new(n, skew);
        let draw = |s: u64| -> Vec<usize> {
            let mut rng = sincere::traffic::rng::Pcg64::new(s);
            (0..200).map(|_| z.sample(&mut rng)).collect()
        };
        let a = draw(seed);
        prop_assert!(a == draw(seed), "same seed diverged");
        prop_assert!(a != draw(seed ^ 0x5A5A),
                     "different seeds gave identical rank streams");
        prop_assert!(a.iter().all(|&r| r < n), "rank out of range");
        Ok(())
    });
}

/// Weights are a probability distribution at every skew: they sum to 1.
#[test]
fn prop_zipf_weights_sum_to_one() {
    forall("zipf normalization", 100, |g| {
        let n = g.usize_in(1, 64);
        let skew = g.f64_in(0.0, 4.0);
        let z = sincere::tenancy::zipf::Zipf::new(n, skew);
        let sum: f64 = z.weights().iter().sum();
        prop_assert!((sum - 1.0).abs() < 1e-9,
                     "n={n} skew={skew}: weights sum to {sum}");
        prop_assert!(z.weights().iter().all(|&w| w > 0.0),
                     "every model must keep positive mass");
        Ok(())
    });
}

// --------------------------------------------------------------- traffic

/// All patterns: arrivals sorted, within range, and nonempty at sane
/// rates; realized mean within 35% on a single 600s draw.
#[test]
fn prop_traffic_patterns_sane() {
    forall("traffic sanity", 40, |g| {
        let names = ["gamma", "bursty", "ramp"];
        let name = *g.choose(&names);
        let mean = g.f64_in(0.5, 8.0);
        // bursty's ~32s on/off cycles need a much longer horizon before
        // a single draw's realized rate concentrates
        let dur = if name == "bursty" { 4000.0 } else { 600.0 };
        let p = sincere::traffic::pattern_by_name(name).unwrap();
        let mut rng = sincere::traffic::rng::Pcg64::new(g.u64());
        let models = vec!["m".to_string()];
        let arr = p.generate(dur, mean, &models, &mut rng);
        prop_assert!(!arr.is_empty(), "{name}@{mean}: empty");
        for w in arr.windows(2) {
            prop_assert!(w[0].at_s <= w[1].at_s, "{name}: unsorted");
        }
        prop_assert!(arr.iter().all(|a| (0.0..dur).contains(&a.at_s)),
                     "{name}: out of range");
        let realized = arr.len() as f64 / dur;
        prop_assert!((realized - mean).abs() / mean < 0.35,
                     "{name}@{mean}: realized {realized}");
        Ok(())
    });
}

/// Deterministic-RNG regression across every traffic generator: the
/// same seed must reproduce the arrival sequence *exactly* (times and
/// model assignments), and different seeds must diverge.  This is the
/// substrate of every replay guarantee in the repo — lab replica
/// seeding, the golden summaries, DES-vs-real parity.
#[test]
fn prop_traffic_generators_deterministic_in_seed() {
    let models = vec!["llama-sim".to_string(), "gemma-sim".to_string()];
    forall("traffic rng determinism", 30, |g| {
        let seed = g.u64();
        let mean = g.f64_in(0.5, 8.0);
        let dur = g.f64_in(60.0, 400.0);
        for name in sincere::traffic::PATTERN_NAMES {
            let p = sincere::traffic::pattern_by_name(name).unwrap();
            let a = p.generate(dur, mean,
                               &models,
                               &mut sincere::traffic::rng::Pcg64::new(seed));
            let b = p.generate(dur, mean,
                               &models,
                               &mut sincere::traffic::rng::Pcg64::new(seed));
            prop_assert!(a == b,
                         "{name}: same seed {seed} diverged \
                          ({} vs {} arrivals)", a.len(), b.len());
            let c = p.generate(
                dur, mean, &models,
                &mut sincere::traffic::rng::Pcg64::new(seed ^ 0x1));
            prop_assert!(a != c,
                         "{name}: seeds {seed} and {} gave identical \
                          sequences", seed ^ 0x1);
        }
        Ok(())
    });
}

/// Trace emit/replay is part of the determinism contract too: the same
/// seed writes byte-identical jsonl, and replay returns exactly what
/// was written.
#[test]
fn trace_roundtrip_deterministic_in_seed() {
    let models = vec!["llama-sim".to_string()];
    let dir = std::env::temp_dir().join("sincere_trace_prop");
    std::fs::create_dir_all(&dir).unwrap();
    let write = |seed: u64, path: &std::path::Path| {
        let p = sincere::traffic::pattern_by_name("gamma").unwrap();
        let arr = p.generate(
            120.0, 3.0, &models,
            &mut sincere::traffic::rng::Pcg64::new(seed));
        let mut prompts =
            sincere::workload::promptgen::PromptGen::new(seed ^ 0xBEEF, 24);
        sincere::traffic::trace::write_trace(path, &arr, &mut prompts)
            .unwrap();
        arr
    };
    let a = write(9, &dir.join("a.jsonl"));
    let b = write(9, &dir.join("b.jsonl"));
    assert_eq!(std::fs::read(dir.join("a.jsonl")).unwrap(),
               std::fs::read(dir.join("b.jsonl")).unwrap(),
               "same seed must write byte-identical traces");
    let c = write(10, &dir.join("c.jsonl"));
    assert_ne!(a, c, "different seeds must write different traces");
    assert_eq!(a, b);
    let back = sincere::traffic::trace::read_trace(&dir.join("a.jsonl"))
        .unwrap();
    assert_eq!(back.len(), a.len());
    for (t, arr) in back.iter().zip(&a) {
        assert!((t.at_s - arr.at_s).abs() < 1e-9);
        assert_eq!(t.model, arr.model);
        assert!(!t.prompt.is_empty(), "trace prompts must replay");
    }
}
