//! Integration: full real serve runs (ingest thread, scheduler, swap
//! manager, PJRT execution, monitor, CSV output) on short workloads.
//!
//! The DMA throttle is disabled so the runs are CPU-bound and fast;
//! these tests check *accounting and plumbing*, not the calibrated
//! timing regime (benches cover that).

use std::path::PathBuf;
use std::sync::OnceLock;

use sincere::config::RunConfig;
use sincere::coordinator::strategy_names;
use sincere::engine::EngineBuilder;
use sincere::runtime::registry::SharedRegistry;
use sincere::runtime::{Manifest, Registry};
use sincere::util::csvio::CsvTable;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn registry() -> &'static SharedRegistry {
    static REG: OnceLock<SharedRegistry> = OnceLock::new();
    REG.get_or_init(|| {
        let m = Manifest::load(&artifacts_dir()).expect(
            "run `make artifacts` before cargo test");
        SharedRegistry::new(Registry::load(
            &m, &["llama-sim".to_string(), "gemma-sim".to_string()],
            &[1, 2, 4, 8]).unwrap())
    })
}

fn fast_cfg(label: &str) -> RunConfig {
    let mut cfg = RunConfig {
        artifacts_dir: artifacts_dir(),
        duration_s: 6.0,
        drain_s: 4.0,
        mean_rps: 5.0,
        sla_s: 3.0,
        models: vec!["llama-sim".into(), "gemma-sim".into()],
        label: label.to_string(),
        ..RunConfig::default()
    };
    cfg.gpu.no_throttle = true;
    cfg
}

#[test]
fn serve_accounting_identities() {
    let (summary, recorder) = registry()
        .with(|reg| EngineBuilder::new(&fast_cfg("acct")).real(reg)
            .and_then(|b| b.run()))
        .unwrap();
    assert!(summary.generated > 10, "generated {}", summary.generated);
    // every completed request is recorded exactly once
    assert_eq!(summary.completed as usize, recorder.requests.len());
    assert!(summary.completed <= summary.generated);
    assert!(summary.sla_met <= summary.completed);
    // throughput consistent with totals
    let thr = summary.completed as f64 / summary.runtime_s;
    assert!((thr - summary.throughput_rps).abs() < 1e-9);
    // batches account for all completions
    let rows: usize = recorder.batches.iter().map(|b| b.rows).sum();
    assert_eq!(rows, recorder.requests.len());
    // latency is always positive and >= queue wait
    for (c, _) in &recorder.requests {
        assert!(c.latency_s() > 0.0);
        assert!(c.complete_s >= c.exec_start_s);
        assert!(c.exec_start_s >= c.arrival_s - 1e-6);
    }
}

#[test]
fn all_strategies_serve_and_complete() {
    for name in strategy_names() {
        let mut cfg = fast_cfg(&format!("strat_{name}"));
        cfg.strategy = name.to_string();
        let (summary, _) = registry()
            .with(|reg| EngineBuilder::new(&cfg).real(reg)
                .and_then(|b| b.run()))
            .unwrap();
        assert!(summary.completed > 0, "{name} completed nothing");
        if name != "best-batch" {
            // timer-bearing strategies must drain almost everything in
            // an unthrottled run ...
            assert!(summary.completed * 10 >= summary.generated * 8,
                    "{name}: only {}/{} completed", summary.completed,
                    summary.generated);
        } else {
            // ... while the paper's baseline legitimately strands
            // sub-OBS batches (no timer): it may leave up to one
            // partial batch per model queued.
            assert!(summary.generated - summary.completed <= 16,
                    "best-batch stranded too much: {}/{}",
                    summary.completed, summary.generated);
        }
    }
}

#[test]
fn two_device_fleet_serves_with_per_device_accounting() {
    let mut cfg = fast_cfg("fleet2");
    cfg.devices = 2;
    cfg.placement = "affinity".into();
    let (summary, recorder) = registry()
        .with(|reg| EngineBuilder::new(&cfg).real(reg)
            .and_then(|b| b.run()))
        .unwrap();
    assert!(summary.completed > 0);
    assert_eq!(summary.devices, 2);
    assert_eq!(summary.per_device.len(), 2);
    // per-device slices partition the fleet aggregates
    let completed: u64 = summary.per_device.iter()
        .map(|d| d.completed).sum();
    assert_eq!(completed, summary.completed);
    let swaps: u64 = summary.per_device.iter()
        .map(|d| d.swap_count).sum();
    assert_eq!(swaps, summary.swap_count);
    // two models on two devices under affinity: each model keeps its
    // own device, so residency churn stays minimal
    assert!(summary.swap_count <= 6,
            "affinity fleet thrashed: {} swaps", summary.swap_count);
    // every batch record names a real device
    assert!(recorder.batches.iter().all(|b| b.device < 2));
}

#[test]
fn mixed_mode_fleet_runs_for_real() {
    let mut cfg = fast_cfg("fleet_mixed");
    cfg.devices = 2;
    cfg.set("device-modes", "cc,no-cc").unwrap();
    let (summary, _) = registry()
        .with(|reg| EngineBuilder::new(&cfg).real(reg)
            .and_then(|b| b.run()))
        .unwrap();
    assert!(summary.completed > 0);
    assert_eq!(summary.mode, "mixed");
    assert_eq!(summary.per_device[0].mode, "cc");
    assert_eq!(summary.per_device[1].mode, "no-cc");
    // only the CC device can accrue crypto time, and if it swapped at
    // all it must have
    if summary.per_device[0].swap_count > 0 {
        assert!(summary.per_device[0].crypto_s > 0.0,
                "CC device swapped without paying crypto");
    }
    assert_eq!(summary.per_device[1].crypto_s, 0.0,
               "No-CC device must never pay crypto");
}

#[test]
fn cc_mode_serves_and_encrypts() {
    let mut cfg = fast_cfg("cc_serve");
    cfg.set("mode", "cc").unwrap();
    cfg.gpu.no_throttle = true;
    let (summary, _) = registry()
        .with(|reg| EngineBuilder::new(&cfg).real(reg)
            .and_then(|b| b.run()))
        .unwrap();
    assert!(summary.completed > 0);
    assert!(summary.total_crypto_s > 0.0,
            "CC run must spend time in AEAD");
    assert!(summary.swap_count >= 1);
}

#[test]
fn csvs_written_and_parse() {
    let dir = std::env::temp_dir().join("sincere_serve_csv_test");
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = fast_cfg("csv");
    cfg.results_dir = Some(dir.clone());
    let (summary, _) = registry()
        .with(|reg| EngineBuilder::new(&cfg).real(reg)
            .and_then(|b| b.run()))
        .unwrap();

    let reqs = CsvTable::read(&dir.join("csv_requests.csv")).unwrap();
    assert_eq!(reqs.rows.len() as u64, summary.completed);
    let lats = reqs.f64_col("latency_s").unwrap();
    assert!(lats.iter().all(|&l| l > 0.0));

    let batches = CsvTable::read(&dir.join("csv_batches.csv")).unwrap();
    assert_eq!(batches.rows.len(), summary.swap_count as usize
               + batches.rows.iter()
                   .filter(|r| r[batches.col("swapped").unwrap()]
                           == "false").count());

    let monitor = CsvTable::read(&dir.join("csv_monitor.csv")).unwrap();
    assert!(!monitor.rows.is_empty(), "monitor thread produced nothing");
    assert!(monitor.f64_col("gpu_util").unwrap().iter()
            .all(|&u| (0.0..=1.0).contains(&u)));

    let summary_json = std::fs::read_to_string(
        dir.join("csv_summary.json")).unwrap();
    let j = sincere::util::json::Json::parse(&summary_json).unwrap();
    assert_eq!(j.req("completed").unwrap().as_u64(),
               Some(summary.completed));
}

#[test]
fn zero_traffic_run_terminates() {
    let mut cfg = fast_cfg("zero");
    cfg.mean_rps = 0.02; // likely zero arrivals in 6 s window
    cfg.duration_s = 2.0;
    cfg.drain_s = 1.0;
    let (summary, _) = registry()
        .with(|reg| EngineBuilder::new(&cfg).real(reg)
            .and_then(|b| b.run()))
        .unwrap();
    // must terminate promptly and account cleanly either way
    assert!(summary.runtime_s < 10.0);
    assert!(summary.completed <= summary.generated);
}

#[test]
fn unknown_model_in_config_fails_fast() {
    let mut cfg = fast_cfg("bad_model");
    cfg.models = vec!["gpt-5".into()];
    assert!(registry()
        .with(|reg| EngineBuilder::new(&cfg).real(reg)
            .and_then(|b| b.run()))
        .is_err());
}
