//! Engine parity: the same seed + config through `DesBackend` and
//! through `RealBackend` with zeroed real-time sleeps (virtual clock +
//! modeled costs) must agree *exactly* on the aggregate outcome.
//!
//! This is the payoff of the `Engine`/`Clock`/`ExecBackend` split: the
//! serve loop exists once, so when both backends charge identical
//! costs, every decision — and therefore every count — must coincide.
//! The real backend still does all its real work underneath (residency
//! via `SwapManager`, batch assembly with the OOM guard, PJRT
//! execution, CC-sealed payload DMA); only its *reported times* come
//! from the shared cost table.
//!
//! Preconditions the contract rests on (and this config satisfies):
//! the cost table's OBS values name batch sizes the registry compiled,
//! and every (weights + largest-batch workspace) fits device memory —
//! the DES has no memory model, so real-side OOM halving would be the
//! one divergence source (see `engine::des` module docs).

use std::path::PathBuf;
use std::sync::OnceLock;

mod common;

use sincere::config::RunConfig;
use sincere::coordinator::strategy_names;
use sincere::engine::EngineBuilder;
use sincere::runtime::registry::SharedRegistry;
use sincere::runtime::{Manifest, Registry};
use sincere::sim::calib::CostModel;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn manifest() -> &'static Manifest {
    static M: OnceLock<Manifest> = OnceLock::new();
    M.get_or_init(|| Manifest::load(&artifacts_dir()).expect(
        "artifacts missing: run tools/gen_artifacts.py"))
}

fn registry() -> &'static SharedRegistry {
    static REG: OnceLock<SharedRegistry> = OnceLock::new();
    REG.get_or_init(|| SharedRegistry::new(Registry::load(
        manifest(),
        &["llama-sim".to_string(), "gemma-sim".to_string()],
        &[1, 2, 4, 8]).unwrap()))
}

/// The shared synthetic cost table (`tests/common/mod.rs`) — the same
/// one the pipeline-effect tests and golden summaries price, so the
/// suites stay comparable.
fn toy_costs() -> CostModel {
    common::toy_costs(manifest())
}

fn parity_cfg(mode: &str, strategy: &str) -> RunConfig {
    let mut cfg = RunConfig {
        duration_s: 20.0,
        drain_s: 8.0,
        mean_rps: 3.0,
        sla_s: 6.0,
        strategy: strategy.to_string(),
        models: vec!["llama-sim".into(), "gemma-sim".into()],
        ..RunConfig::default()
    };
    cfg.set("mode", mode).unwrap();
    cfg.gpu.no_throttle = true; // zero the real-time sleeps
    cfg
}

fn run_pair(cfg: &RunConfig) -> (sincere::engine::RunSummary,
                                 sincere::engine::RunSummary) {
    let cm = toy_costs();
    let des = EngineBuilder::new(cfg).des(manifest(), &cm).unwrap()
        .run().unwrap().0;
    let real = registry()
        .with(|reg| EngineBuilder::new(cfg).real_virtual(reg, &cm)
            .and_then(|b| b.run()))
        .unwrap().0;
    (des, real)
}

#[test]
fn des_and_real_backends_agree_exactly() {
    for mode in ["no-cc", "cc"] {
        let cfg = parity_cfg(mode, "select-batch+timer");
        let (des, real) = run_pair(&cfg);
        assert_eq!(des.generated, real.generated,
                   "{mode}: same seed must give the same schedule");
        assert_eq!(des.completed, real.completed,
                   "{mode}: completed diverged");
        assert_eq!(des.swap_count, real.swap_count,
                   "{mode}: swap_count diverged");
        assert!((des.sla_attainment - real.sla_attainment).abs() < 1e-9,
                "{mode}: attainment {} vs {}", des.sla_attainment,
                real.sla_attainment);
        // identical cost accounting means identical timelines
        assert!((des.latency_mean_s - real.latency_mean_s).abs() < 1e-9,
                "{mode}: latency {} vs {}", des.latency_mean_s,
                real.latency_mean_s);
        assert!((des.runtime_s - real.runtime_s).abs() < 1e-9,
                "{mode}: runtime {} vs {}", des.runtime_s,
                real.runtime_s);
        assert!((des.total_load_s - real.total_load_s).abs() < 1e-9,
                "{mode}: load totals diverged");
        assert!(des.completed > 0, "{mode}: degenerate parity run");
        assert!(des.swap_count > 0, "{mode}: no swaps exercised");
    }
}

#[test]
fn parity_holds_for_every_strategy() {
    for strategy in strategy_names() {
        let cfg = parity_cfg("cc", strategy);
        let (des, real) = run_pair(&cfg);
        assert_eq!(des.generated, real.generated, "{strategy}");
        assert_eq!(des.completed, real.completed, "{strategy}");
        assert_eq!(des.swap_count, real.swap_count, "{strategy}");
        assert!((des.sla_attainment - real.sla_attainment).abs() < 1e-9,
                "{strategy}: attainment {} vs {}", des.sla_attainment,
                real.sla_attainment);
    }
}

/// The pipeline/prefetch extension of the contract: CC loads priced
/// from the cost table's overlap figures and a staging slot per device
/// must leave the DES and the real execution path (which really stages
/// a second HBM buffer and really promotes it) in exact agreement,
/// across the {serialized, pipelined} × {prefetch on/off} matrix.
#[test]
fn parity_matrix_pipeline_and_prefetch() {
    for depth in [0usize, 2] {
        for prefetch in [false, true] {
            let mut cfg = parity_cfg("cc", "select-batch+timer");
            cfg.gpu.pipeline_depth = depth;
            cfg.prefetch = prefetch;
            let (des, real) = run_pair(&cfg);
            let tag = format!("depth={depth} prefetch={prefetch}");
            assert_eq!(des.generated, real.generated, "{tag}");
            assert_eq!(des.completed, real.completed, "{tag}");
            assert_eq!(des.swap_count, real.swap_count, "{tag}");
            assert_eq!(des.prefetch_count, real.prefetch_count, "{tag}");
            assert_eq!(des.promoted_count, real.promoted_count, "{tag}");
            assert!((des.sla_attainment - real.sla_attainment).abs()
                    < 1e-9, "{tag}: attainment {} vs {}",
                    des.sla_attainment, real.sla_attainment);
            assert!((des.latency_mean_s - real.latency_mean_s).abs()
                    < 1e-9, "{tag}: latency {} vs {}", des.latency_mean_s,
                    real.latency_mean_s);
            assert!((des.runtime_s - real.runtime_s).abs() < 1e-9,
                    "{tag}: runtime {} vs {}", des.runtime_s,
                    real.runtime_s);
            assert!((des.total_load_s - real.total_load_s).abs() < 1e-9,
                    "{tag}: load totals diverged");
            assert!((des.total_crypto_exposed_s
                     - real.total_crypto_exposed_s).abs() < 1e-9,
                    "{tag}: exposed crypto diverged");
            assert!(des.completed > 0, "{tag}: degenerate parity run");
            assert!(des.swap_count > 0, "{tag}: no swaps exercised");
        }
    }
}

/// The data-path extension of the contract (ISSUE 5 acceptance): with
/// per-batch payload I/O priced through the bounce budget — serialized
/// and pipelined, CC and No-CC — the DES and the real execution path
/// (which really ships the payload bytes through the sealed DMA
/// engine) must stay in exact agreement, data-crypto accounting
/// included.
#[test]
fn parity_matrix_data_path() {
    for mode in ["no-cc", "cc"] {
        for depth in [0usize, 2] {
            for data_path in [false, true] {
                let mut cfg = parity_cfg(mode, "select-batch+timer");
                cfg.gpu.pipeline_depth = depth;
                cfg.data_path = data_path;
                let (des, real) = run_pair(&cfg);
                let tag = format!(
                    "mode={mode} depth={depth} data-path={data_path}");
                assert_eq!(des.generated, real.generated, "{tag}");
                assert_eq!(des.completed, real.completed, "{tag}");
                assert_eq!(des.swap_count, real.swap_count, "{tag}");
                assert!((des.sla_attainment - real.sla_attainment).abs()
                        < 1e-9, "{tag}: attainment {} vs {}",
                        des.sla_attainment, real.sla_attainment);
                assert!((des.latency_mean_s - real.latency_mean_s).abs()
                        < 1e-9, "{tag}: latency {} vs {}",
                        des.latency_mean_s, real.latency_mean_s);
                assert!((des.runtime_s - real.runtime_s).abs() < 1e-9,
                        "{tag}: runtime {} vs {}", des.runtime_s,
                        real.runtime_s);
                assert!((des.total_data_crypto_s
                         - real.total_data_crypto_s).abs() < 1e-9,
                        "{tag}: data crypto {} vs {}",
                        des.total_data_crypto_s,
                        real.total_data_crypto_s);
                assert!((des.total_data_crypto_exposed_s
                         - real.total_data_crypto_exposed_s).abs()
                        < 1e-9, "{tag}: exposed data crypto diverged");
                assert_eq!(des.data_bytes, real.data_bytes, "{tag}");
                assert_eq!(des.data_wire_bytes, real.data_wire_bytes,
                           "{tag}");
                assert!(des.completed > 0, "{tag}: degenerate run");
                if data_path && mode == "cc" {
                    assert!(des.total_data_crypto_s > 0.0,
                            "{tag}: CC data path priced no crypto");
                    if depth >= 2 {
                        assert!(des.total_data_crypto_exposed_s
                                <= des.total_data_crypto_s + 1e-12,
                                "{tag}: exposed above total");
                    }
                } else {
                    assert_eq!(des.total_data_crypto_s, 0.0, "{tag}");
                }
            }
        }
    }
}

/// The fleet extension of the parity contract: a 4-device mixed
/// CC/No-CC fleet, with devices executing concurrently in virtual
/// time, must still agree *exactly* between the DES and the real
/// execution path — for every placement policy, since placement runs
/// in the shared engine and both backends report identical per-device
/// costs.
#[test]
fn fleet_parity_4_device_mixed() {
    for placement in ["affinity", "round-robin"] {
        let mut cfg = parity_cfg("cc", "select-batch+timer");
        cfg.devices = 4;
        cfg.set("device-modes", "cc,no-cc,cc,no-cc").unwrap();
        cfg.placement = placement.to_string();
        cfg.mean_rps = 6.0; // keep all four devices busy
        let (des, real) = run_pair(&cfg);
        assert_eq!(des.generated, real.generated, "{placement}");
        assert_eq!(des.completed, real.completed, "{placement}");
        assert_eq!(des.swap_count, real.swap_count, "{placement}");
        assert!((des.sla_attainment - real.sla_attainment).abs() < 1e-9,
                "{placement}: attainment {} vs {}", des.sla_attainment,
                real.sla_attainment);
        assert!((des.latency_mean_s - real.latency_mean_s).abs() < 1e-9,
                "{placement}: latency {} vs {}", des.latency_mean_s,
                real.latency_mean_s);
        assert!((des.total_load_s - real.total_load_s).abs() < 1e-9,
                "{placement}: load totals diverged");
        // per-device breakdowns must agree too
        assert_eq!(des.per_device.len(), 4, "{placement}");
        for (a, b) in des.per_device.iter().zip(real.per_device.iter()) {
            assert_eq!(a.mode, b.mode, "{placement} dev {}", a.device);
            assert_eq!(a.batches, b.batches,
                       "{placement} dev {}", a.device);
            assert_eq!(a.swap_count, b.swap_count,
                       "{placement} dev {}", a.device);
            assert_eq!(a.completed, b.completed,
                       "{placement} dev {}", a.device);
        }
        assert!(des.completed > 0, "{placement}: degenerate run");
        assert!(des.per_device.iter().filter(|d| d.batches > 0).count()
                >= 2, "{placement}: fleet never spread work");
    }
}

/// Acceptance pin: the 4-device mixed CC/No-CC fleet stays in exact
/// DES-vs-real agreement with the pipelined swap path *and* prefetch
/// enabled, per-device breakdowns included.
#[test]
fn fleet_parity_4_device_mixed_pipelined_prefetch() {
    for placement in ["affinity", "round-robin"] {
        let mut cfg = parity_cfg("cc", "select-batch+timer");
        cfg.devices = 4;
        cfg.set("device-modes", "cc,no-cc,cc,no-cc").unwrap();
        cfg.placement = placement.to_string();
        cfg.mean_rps = 6.0; // keep all four devices busy
        cfg.gpu.pipeline_depth = 2;
        cfg.prefetch = true;
        let (des, real) = run_pair(&cfg);
        assert_eq!(des.generated, real.generated, "{placement}");
        assert_eq!(des.completed, real.completed, "{placement}");
        assert_eq!(des.swap_count, real.swap_count, "{placement}");
        assert_eq!(des.prefetch_count, real.prefetch_count,
                   "{placement}");
        assert_eq!(des.promoted_count, real.promoted_count,
                   "{placement}");
        assert!((des.sla_attainment - real.sla_attainment).abs() < 1e-9,
                "{placement}: attainment {} vs {}", des.sla_attainment,
                real.sla_attainment);
        assert!((des.latency_mean_s - real.latency_mean_s).abs() < 1e-9,
                "{placement}: latency {} vs {}", des.latency_mean_s,
                real.latency_mean_s);
        assert!((des.total_load_s - real.total_load_s).abs() < 1e-9,
                "{placement}: load totals diverged");
        assert_eq!(des.per_device.len(), 4, "{placement}");
        for (a, b) in des.per_device.iter().zip(real.per_device.iter()) {
            assert_eq!(a.mode, b.mode, "{placement} dev {}", a.device);
            assert_eq!(a.batches, b.batches,
                       "{placement} dev {}", a.device);
            assert_eq!(a.swap_count, b.swap_count,
                       "{placement} dev {}", a.device);
            assert_eq!(a.prefetches, b.prefetches,
                       "{placement} dev {}", a.device);
            assert_eq!(a.promotions, b.promotions,
                       "{placement} dev {}", a.device);
            assert_eq!(a.completed, b.completed,
                       "{placement} dev {}", a.device);
        }
        assert!(des.completed > 0, "{placement}: degenerate run");
    }
}

/// The hardware-generation extension of the parity contract (ISSUE 8
/// acceptance): a mixed Hopper + coherent fleet — device 0 priced by
/// the `h100-cc` profile (legacy chunk-crypto recurrence), device 1 by
/// `gh200-coherent` (UMA: plain-rate swaps plus a per-swap bridge
/// residual, zero swap crypto) — must leave the DES and the real
/// execution path in exact agreement, bridge accounting included.
#[test]
fn fleet_parity_mixed_hardware_generations() {
    let mut cfg = parity_cfg("cc", "select-batch+timer");
    cfg.devices = 2;
    cfg.set("device-profiles", "h100-cc,gh200-coherent").unwrap();
    cfg.mean_rps = 6.0; // keep both generations busy
    let (des, real) = run_pair(&cfg);
    assert_eq!(des.generated, real.generated);
    assert_eq!(des.completed, real.completed);
    assert_eq!(des.swap_count, real.swap_count);
    assert!((des.sla_attainment - real.sla_attainment).abs() < 1e-9,
            "attainment {} vs {}", des.sla_attainment,
            real.sla_attainment);
    assert!((des.latency_mean_s - real.latency_mean_s).abs() < 1e-9,
            "latency {} vs {}", des.latency_mean_s, real.latency_mean_s);
    assert!((des.runtime_s - real.runtime_s).abs() < 1e-9,
            "runtime {} vs {}", des.runtime_s, real.runtime_s);
    assert!((des.total_load_s - real.total_load_s).abs() < 1e-9,
            "load totals diverged");
    assert!((des.total_bridge_s - real.total_bridge_s).abs() < 1e-9,
            "bridge totals diverged: {} vs {}", des.total_bridge_s,
            real.total_bridge_s);
    // per-device breakdowns must agree too
    assert_eq!(des.per_device.len(), 2);
    for (a, b) in des.per_device.iter().zip(real.per_device.iter()) {
        assert_eq!(a.mode, b.mode, "dev {}", a.device);
        assert_eq!(a.batches, b.batches, "dev {}", a.device);
        assert_eq!(a.swap_count, b.swap_count, "dev {}", a.device);
        assert_eq!(a.completed, b.completed, "dev {}", a.device);
        assert!((a.bridge_s - b.bridge_s).abs() < 1e-9,
                "dev {}: bridge diverged", a.device);
    }
    assert!(des.completed > 0, "degenerate parity run");
    assert!(des.swap_count > 0, "no swaps exercised");
    // the profile split shows in the accounting: the Hopper device
    // pays no bridge, the coherent device pays one per priced swap
    assert_eq!(des.per_device[0].bridge_s, 0.0,
               "h100-cc must not pay a bridge residual");
    assert!(des.per_device[1].swap_count > 0,
            "coherent device never swapped");
    assert!(des.per_device[1].bridge_s > 0.0,
            "coherent device must pay the bridge residual");
    assert!(des.total_bridge_s > 0.0);
}

/// The pipeline-parallel extension of the parity contract: a
/// mixed-generation 4-device fleet — one 2-stage Hopper group paying
/// sealed `nonce|ct|tag` activation frames on its inter-stage link,
/// one coherent Grace-Hopper group moving activations at plain rate —
/// must leave the DES and the real execution path (which really
/// stages each layer shard through its device's DMA engine, atomically
/// per group) in exact agreement: shard-swap accounting, per-stage
/// activation bytes, exposed activation crypto, TTFT, bubble time and
/// per-device breakdowns included.
#[test]
fn fleet_parity_pipeline_parallel_sharded() {
    let mut cfg = parity_cfg("cc", "select-batch+timer");
    cfg.devices = 4;
    cfg.set("device-profiles",
            "h100-cc,h100-cc,gh200-coherent,gh200-coherent").unwrap();
    cfg.set("placement", "pipeline-parallel").unwrap();
    cfg.set("pp-stages", "2").unwrap();
    cfg.mean_rps = 6.0; // keep both stage groups busy
    cfg.validate().unwrap();
    let (des, real) = run_pair(&cfg);
    assert_eq!(des.generated, real.generated);
    assert_eq!(des.completed, real.completed);
    assert_eq!(des.swap_count, real.swap_count);
    assert!((des.sla_attainment - real.sla_attainment).abs() < 1e-9,
            "attainment {} vs {}", des.sla_attainment,
            real.sla_attainment);
    assert!((des.latency_mean_s - real.latency_mean_s).abs() < 1e-9,
            "latency {} vs {}", des.latency_mean_s, real.latency_mean_s);
    assert!((des.runtime_s - real.runtime_s).abs() < 1e-9,
            "runtime {} vs {}", des.runtime_s, real.runtime_s);
    assert!((des.total_load_s - real.total_load_s).abs() < 1e-9,
            "shard load totals diverged");
    assert!((des.total_crypto_exposed_s
             - real.total_crypto_exposed_s).abs() < 1e-9,
            "exposed swap crypto diverged");
    // the pipeline block agrees field by field
    assert_eq!(des.pp_stages, 2);
    assert_eq!(real.pp_stages, 2);
    assert_eq!(des.activation_bytes, real.activation_bytes,
               "per-stage activation bytes diverged");
    assert_eq!(des.activation_wire_bytes, real.activation_wire_bytes,
               "sealed activation framing diverged");
    assert!((des.ttft_mean_s - real.ttft_mean_s).abs() < 1e-9,
            "ttft {} vs {}", des.ttft_mean_s, real.ttft_mean_s);
    assert!((des.token_throughput_tps
             - real.token_throughput_tps).abs() < 1e-9,
            "token throughput diverged");
    assert!((des.total_bubble_s - real.total_bubble_s).abs() < 1e-9,
            "bubble time diverged");
    assert!((des.total_activation_io_s
             - real.total_activation_io_s).abs() < 1e-9,
            "activation io diverged");
    assert!((des.total_activation_crypto_s
             - real.total_activation_crypto_s).abs() < 1e-9,
            "activation crypto diverged");
    assert!((des.total_activation_crypto_exposed_s
             - real.total_activation_crypto_exposed_s).abs() < 1e-9,
            "exposed activation crypto diverged");
    // per-device breakdowns must agree too
    assert_eq!(des.per_device.len(), 4);
    for (a, b) in des.per_device.iter().zip(real.per_device.iter()) {
        assert_eq!(a.batches, b.batches, "dev {}", a.device);
        assert_eq!(a.swap_count, b.swap_count, "dev {}", a.device);
        assert_eq!(a.completed, b.completed, "dev {}", a.device);
        assert!((a.load_s - b.load_s).abs() < 1e-9,
                "dev {}: shard loads diverged", a.device);
    }
    // the run exercised what it claims: both groups ran work, the
    // Hopper link sealed its activations, the wire grew past the
    // payload, and the coherent link added no activation crypto
    assert!(des.completed > 0 && des.swap_count > 0,
            "degenerate sharded run");
    assert!(des.per_device[0].batches > 0,
            "lead 0 (Hopper group) never dispatched");
    assert!(des.activation_bytes > 0, "no activations priced");
    assert!(des.activation_wire_bytes > des.activation_bytes,
            "sealed frames must amplify the activation wire");
    assert!(des.total_activation_crypto_s > 0.0,
            "the CC inter-stage link must pay activation crypto");
    assert!(des.total_bubble_s > 0.0,
            "unequal layer shares must leave bubble time");
}

/// Stage-count 1 is the off position: under the pipeline-parallel
/// placement, `--pp-stages 1` (and the flag left absent) must produce
/// byte-identical output to today's affinity run — same timeline, no
/// pp keys — because every device is its own stage group lead.
#[test]
fn pp_stage_1_is_byte_identical_to_no_pp() {
    let run = |placement: &str, set_pp: bool| {
        let mut cfg = parity_cfg("cc", "select-batch+timer");
        cfg.devices = 4;
        cfg.set("device-modes", "cc,no-cc,cc,no-cc").unwrap();
        cfg.set("placement", placement).unwrap();
        if set_pp {
            cfg.set("pp-stages", "1").unwrap();
        }
        cfg.mean_rps = 6.0;
        cfg.label = "pin".into();
        let cm = toy_costs();
        EngineBuilder::new(&cfg).des(manifest(), &cm).unwrap()
            .run().unwrap().0.to_json().to_string()
    };
    let explicit = run("pipeline-parallel", true);
    assert_eq!(run("pipeline-parallel", false), explicit,
               "--pp-stages 1 must equal the flag left absent, byte \
                for byte");
    // modulo the recorded placement name, the stage-1 pp run is the
    // affinity run: the placement degenerates to sticky/least-loaded
    // and the engine's group accounting reduces to per-device
    let affinity = run("affinity", false).replace(
        "\"placement\":\"affinity\"",
        "\"placement\":\"pipeline-parallel\"");
    assert_eq!(explicit, affinity,
               "stage-1 output must be byte-identical to affinity");
    for key in ["pp_stages", "ttft", "activation", "bubble"] {
        assert!(!explicit.contains(key),
                "stage-1 summary leaked pp key {key:?}");
    }
}

/// The tenancy extension of the parity contract (ISSUE 6 acceptance):
/// admission gating + Zipf popularity + diurnal/flash traffic + SLA
/// classes on a mixed 4-device fleet must leave the DES and the real
/// execution path in exact agreement — shed accounting, per-class
/// counters, goodput, fairness and swap churn included.  The gate
/// runs engine-side on time-domain-independent inputs (queue depths,
/// cost-table load estimates, the engine's own exec EWMA), so the
/// same requests are shed in both time domains.
#[test]
fn fleet_parity_4_device_tenancy() {
    for admission in ["queue-cap", "deadline-infeasible",
                      "class-weighted"] {
        let mut cfg = parity_cfg("cc", "select-batch+timer");
        cfg.devices = 4;
        cfg.set("device-modes", "cc,no-cc,cc,no-cc").unwrap();
        cfg.mean_rps = 6.0; // overload enough that the gate fires
        cfg.set("zipf-skew", "1.1").unwrap();
        cfg.set("admission", admission).unwrap();
        cfg.set("sla-classes", "on").unwrap();
        cfg.set("diurnal-amp", "0.3").unwrap();
        cfg.set("flash-mult", "2").unwrap();
        cfg.set("flash-start", "6").unwrap();
        cfg.set("flash-dur", "4").unwrap();
        let (des, real) = run_pair(&cfg);
        assert_eq!(des.generated, real.generated, "{admission}");
        assert_eq!(des.completed, real.completed, "{admission}");
        assert_eq!(des.swap_count, real.swap_count, "{admission}");
        assert!((des.sla_attainment - real.sla_attainment).abs() < 1e-9,
                "{admission}: attainment {} vs {}", des.sla_attainment,
                real.sla_attainment);
        assert!((des.latency_mean_s - real.latency_mean_s).abs() < 1e-9,
                "{admission}: latency {} vs {}", des.latency_mean_s,
                real.latency_mean_s);
        assert!((des.runtime_s - real.runtime_s).abs() < 1e-9,
                "{admission}: runtime diverged");

        let dt = des.tenancy.as_ref()
            .unwrap_or_else(|| panic!("{admission}: DES tenancy block \
                                       missing"));
        let rt = real.tenancy.as_ref()
            .unwrap_or_else(|| panic!("{admission}: real tenancy block \
                                       missing"));
        assert_eq!(dt.admission, admission, "{admission}");
        assert_eq!(dt.shed_total, rt.shed_total,
                   "{admission}: shed diverged");
        assert!((dt.goodput_rps - rt.goodput_rps).abs() < 1e-9,
                "{admission}: goodput {} vs {}", dt.goodput_rps,
                rt.goodput_rps);
        assert!((dt.fairness - rt.fairness).abs() < 1e-9,
                "{admission}: fairness {} vs {}", dt.fairness,
                rt.fairness);
        assert_eq!(dt.classes.len(), 3, "{admission}");
        for (a, b) in dt.classes.iter().zip(rt.classes.iter()) {
            assert_eq!(a.name, b.name, "{admission}");
            assert_eq!(a.generated, b.generated,
                       "{admission} class {}", a.name);
            assert_eq!(a.completed, b.completed,
                       "{admission} class {}", a.name);
            assert_eq!(a.met, b.met, "{admission} class {}", a.name);
            assert_eq!(a.shed, b.shed, "{admission} class {}", a.name);
            assert_eq!(a.expired, b.expired,
                       "{admission} class {}", a.name);
        }
        assert_eq!(dt.churn_by_model, rt.churn_by_model,
                   "{admission}: swap churn diverged");

        // per-device breakdowns must agree too
        assert_eq!(des.per_device.len(), 4, "{admission}");
        for (a, b) in des.per_device.iter().zip(real.per_device.iter()) {
            assert_eq!(a.mode, b.mode, "{admission} dev {}", a.device);
            assert_eq!(a.batches, b.batches,
                       "{admission} dev {}", a.device);
            assert_eq!(a.swap_count, b.swap_count,
                       "{admission} dev {}", a.device);
            assert_eq!(a.completed, b.completed,
                       "{admission} dev {}", a.device);
        }
        assert!(des.completed > 0, "{admission}: degenerate run");
        assert!(dt.classes.iter().map(|c| c.generated).sum::<u64>()
                == des.generated,
                "{admission}: per-class generated must cover the run");
    }
}

/// The observability extension of the parity contract (ISSUE 9
/// acceptance): with tracing on, the two virtual backends must record
/// *identical* span sequences on a mixed 4-device fleet — every shed,
/// swap, exec, and request event, in order, with identical timings —
/// because the spans are recorded by the shared engine loop from the
/// shared cost pricing.  The aggregated `phase_totals` block must then
/// agree too, and every waterfall row must satisfy the phase-sum
/// identity in both time domains.
#[test]
fn fleet_trace_span_sequences_match() {
    let mut cfg = parity_cfg("cc", "select-batch+timer");
    cfg.devices = 4;
    cfg.set("device-modes", "cc,no-cc,cc,no-cc").unwrap();
    cfg.mean_rps = 6.0; // keep all four devices busy
    cfg.set("trace", "full").unwrap();
    let cm = toy_costs();
    let (des_sum, des_rec) = EngineBuilder::new(&cfg)
        .des(manifest(), &cm).unwrap().run().unwrap();
    let (real_sum, real_rec) = registry()
        .with(|reg| EngineBuilder::new(&cfg).real_virtual(reg, &cm)
            .and_then(|b| b.run()))
        .unwrap();
    let dt = des_rec.trace.as_ref().expect("DES trace missing");
    let rt = real_rec.trace.as_ref().expect("real trace missing");
    assert!(!dt.events.is_empty(), "degenerate traced run");
    assert_eq!(dt.events.len(), rt.events.len(),
               "span counts diverged: {} vs {}", dt.events.len(),
               rt.events.len());
    for (i, (a, b)) in dt.events.iter().zip(rt.events.iter())
        .enumerate() {
        assert_eq!(a, b, "span {i} diverged");
    }
    assert_eq!(dt.waterfalls, rt.waterfalls, "waterfall rows diverged");
    assert_eq!(des_sum.phase_totals, real_sum.phase_totals,
               "phase_totals diverged");
    assert!(des_sum.phase_totals.is_some(),
            "traced run must attach phase_totals");
    // the waterfall identity holds request by request in both domains
    assert_eq!(dt.waterfalls.len() as u64, des_sum.completed,
               "every completed request must have a waterfall row");
    for w in &dt.waterfalls {
        assert!((w.phase_sum_s() - w.latency_s).abs() <= 1e-9,
                "request {}: phases {} != latency {}", w.id,
                w.phase_sum_s(), w.latency_s);
    }
}

#[test]
fn real_backend_still_does_real_work_under_virtual_time() {
    // The parity mode is not a second simulator: PJRT output tokens and
    // device accounting must still be produced by the real path.
    let cfg = parity_cfg("cc", "select-batch+timer");
    let cm = toy_costs();
    let (summary, recorder) = registry()
        .with(|reg| EngineBuilder::new(&cfg).real_virtual(reg, &cm)
            .and_then(|b| b.run()))
        .unwrap();
    assert!(summary.completed > 0);
    // resolve interned batch ids the way the backend interned them
    let table = registry().with(|reg| {
        sincere::runtime::ModelTable::new(reg.names())
    });
    // batches carry the modeled (not wall-measured) costs
    for b in &recorder.batches {
        let mc = cm.costs(table.name(b.model)).unwrap();
        assert!((b.exec_s - mc.exec_s(b.artifact_batch)).abs() < 1e-12,
                "batch exec_s {} not from the cost table", b.exec_s);
    }
}
