//! Effect tests for the CC-priced inference data path
//! (`--data-path on`): per-batch request/response payloads crossing
//! the sealed bounce buffers must *cost* something in CC mode, scale
//! with the priced payload shape (`--data-tokens-in/out`), overlap
//! under `--pipeline-depth` like swaps, and leave No-CC runs
//! untouched.  All runs are virtual-time DES over the shared synthetic
//! cost table, so every figure here is bit-reproducible.

mod common;

use std::path::PathBuf;
use std::sync::OnceLock;

use sincere::config::RunConfig;
use sincere::engine::{EngineBuilder, RunSummary};
use sincere::runtime::Manifest;
use sincere::sim::calib::CostModel;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn manifest() -> &'static Manifest {
    static M: OnceLock<Manifest> = OnceLock::new();
    M.get_or_init(|| Manifest::load(&artifacts_dir()).expect(
        "artifacts missing: run tools/gen_artifacts.py"))
}

fn toy_costs() -> CostModel {
    common::toy_costs(manifest())
}

fn base_cfg(mode: &str) -> RunConfig {
    let mut cfg = RunConfig {
        duration_s: 20.0,
        drain_s: 8.0,
        mean_rps: 4.0,
        sla_s: 6.0,
        models: vec!["llama-sim".into(), "gemma-sim".into()],
        ..RunConfig::default()
    };
    cfg.set("mode", mode).unwrap();
    cfg.gpu.no_throttle = true;
    // small bounce chunks so even token payloads span several chunks
    // and the pipeline has something to overlap
    cfg.gpu.bounce_bytes = 1024;
    cfg
}

fn run(cfg: &RunConfig) -> RunSummary {
    let cm = toy_costs();
    EngineBuilder::new(cfg).des(manifest(), &cm).unwrap().run()
        .unwrap().0
}

#[test]
fn cc_data_path_prices_batch_crypto() {
    let off = run(&base_cfg("cc"));
    let mut cfg = base_cfg("cc");
    cfg.data_path = true;
    let on = run(&cfg);
    assert_eq!(on.total_data_crypto_s, on.total_data_crypto_exposed_s,
               "serialized data path exposes every crypto second");
    assert!(on.total_data_crypto_s > 0.0,
            "CC batches must pay payload crypto");
    assert!(on.data_bytes > 0 && on.data_wire_bytes > on.data_bytes,
            "sealed chunks add framing on the wire: {} vs {}",
            on.data_wire_bytes, on.data_bytes);
    assert_eq!(off.total_data_crypto_s, 0.0);
    assert_eq!(off.data_bytes, 0, "flag off records no payload bytes");
    // the schedule itself is unchanged — only the payload pricing moved
    assert_eq!(on.generated, off.generated);
    // per-device accounting carries the batch crypto
    assert!((on.per_device[0].data_crypto_s
             - on.total_data_crypto_s).abs() < 1e-12);
}

#[test]
fn pipeline_hides_data_crypto_but_not_work() {
    let mut serial = base_cfg("cc");
    serial.data_path = true;
    // large payloads: many 1 KiB bounce chunks per transfer
    serial.data_tokens_in = Some(2048);
    serial.data_tokens_out = Some(1024);
    let mut pipe = serial.clone();
    pipe.gpu.pipeline_depth = 2;
    let s = run(&serial);
    let p = run(&pipe);
    assert_eq!(s.total_data_crypto_s, s.total_data_crypto_exposed_s,
               "serialized exposes all data crypto");
    assert!(p.total_data_crypto_exposed_s < p.total_data_crypto_s,
            "pipelined data path must hide crypto behind the link: \
             exposed {} vs total {}",
            p.total_data_crypto_exposed_s, p.total_data_crypto_s);
    assert!(p.total_data_crypto_exposed_s > 0.0,
            "the fill chunk cannot be hidden");
}

#[test]
fn data_crypto_scales_with_priced_payload_shape() {
    let mut small = base_cfg("cc");
    small.data_path = true;
    small.data_tokens_in = Some(16);
    small.data_tokens_out = Some(16);
    let mut large = small.clone();
    large.data_tokens_in = Some(1024);
    large.data_tokens_out = Some(1024);
    let s = run(&small);
    let l = run(&large);
    assert_eq!(s.generated, l.generated, "same schedule either way");
    assert!(l.data_bytes > s.data_bytes);
    assert!(l.total_data_crypto_s > 2.0 * s.total_data_crypto_s,
            "64x the tokens must dominate the crypto bill: {} vs {}",
            l.total_data_crypto_s, s.total_data_crypto_s);
    // wire amplification shrinks as chunks fill up: framing is
    // per-chunk, so big payloads amortize it better
    let amp = |c: &RunSummary| c.data_wire_bytes as f64
        / c.data_bytes as f64;
    assert!(amp(&l) < amp(&s),
            "framing overhead must amortize with payload size: \
             {} vs {}", amp(&l), amp(&s));
}

#[test]
fn nocc_run_is_identical_with_data_path_on() {
    let off = run(&base_cfg("no-cc"));
    let mut cfg = base_cfg("no-cc");
    cfg.data_path = true;
    cfg.data_tokens_in = Some(4096); // must be timing-inert in No-CC
    let on = run(&cfg);
    assert_eq!(on.generated, off.generated);
    assert_eq!(on.completed, off.completed);
    assert!((on.latency_mean_s - off.latency_mean_s).abs() < 1e-12,
            "No-CC latency moved: {} vs {}", on.latency_mean_s,
            off.latency_mean_s);
    assert!((on.runtime_s - off.runtime_s).abs() < 1e-12);
    assert_eq!(on.total_data_crypto_s, 0.0,
               "an unencrypted link has no bounce crypto to price");
    assert_eq!(on.data_bytes, 0,
               "No-CC devices record no data-path accounting at all — \
                that zero is what keeps the summary JSON byte-identical");
}
