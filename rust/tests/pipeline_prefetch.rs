//! Engine-level behaviour of the pipelined CC swap path and predictive
//! prefetch (the pieces `tests/engine_parity.rs` pins for *agreement*,
//! this file pins for *effect*):
//!
//! * pipelining measurably cuts CC load time while leaving No-CC runs
//!   bit-identical;
//! * prefetch stages the hinted model and promotes it without a second
//!   DMA, in both the DES and the real wall-clock path.

mod common;

use std::path::PathBuf;
use std::sync::OnceLock;

use sincere::config::RunConfig;
use sincere::engine::{EngineBuilder, RunSummary};
use sincere::runtime::registry::SharedRegistry;
use sincere::runtime::{Manifest, Registry};
use sincere::sim::calib::CostModel;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn manifest() -> &'static Manifest {
    static M: OnceLock<Manifest> = OnceLock::new();
    M.get_or_init(|| Manifest::load(&artifacts_dir()).expect(
        "artifacts missing: run tools/gen_artifacts.py"))
}

fn registry() -> &'static SharedRegistry {
    static REG: OnceLock<SharedRegistry> = OnceLock::new();
    REG.get_or_init(|| SharedRegistry::new(Registry::load(
        manifest(),
        &["llama-sim".to_string(), "gemma-sim".to_string()],
        &[1, 2, 4, 8]).unwrap()))
}

/// The shared synthetic cost table (`tests/common/mod.rs`); pipelined
/// CC loads price the overlap.
fn toy_costs() -> CostModel {
    common::toy_costs(manifest())
}

fn base_cfg(mode: &str) -> RunConfig {
    let mut cfg = RunConfig {
        duration_s: 30.0,
        drain_s: 10.0,
        mean_rps: 8.0, // two saturated queues: swaps alternate models
        sla_s: 6.0,
        models: vec!["llama-sim".into(), "gemma-sim".into()],
        ..RunConfig::default()
    };
    cfg.set("mode", mode).unwrap();
    cfg.gpu.no_throttle = true;
    cfg
}

fn run_des(cfg: &RunConfig) -> RunSummary {
    let cm = toy_costs();
    EngineBuilder::new(cfg).des(manifest(), &cm).unwrap()
        .run().unwrap().0
}

#[test]
fn pipelined_cc_cuts_load_time_in_the_des() {
    let serial = run_des(&base_cfg("cc"));
    let mut pipe_cfg = base_cfg("cc");
    pipe_cfg.gpu.pipeline_depth = 2;
    let pipe = run_des(&pipe_cfg);
    assert!(serial.swap_count > 0 && pipe.swap_count > 0);
    assert!(pipe.mean_load_s < 0.7 * serial.mean_load_s,
            "pipelined mean load {} did not undercut serialized {}",
            pipe.mean_load_s, serial.mean_load_s);
    // the overlap hides crypto rather than removing it
    assert!(pipe.total_crypto_exposed_s < serial.total_crypto_exposed_s,
            "exposed crypto must shrink: pipe {} vs serial {}",
            pipe.total_crypto_exposed_s, serial.total_crypto_exposed_s);
    assert!(pipe.total_crypto_s > 0.0);
    assert_eq!(pipe.pipeline_depth, 2, "summary must record the depth");
}

#[test]
fn pipeline_depth_leaves_no_cc_runs_bit_identical() {
    let a = run_des(&base_cfg("no-cc"));
    let mut cfg = base_cfg("no-cc");
    cfg.gpu.pipeline_depth = 2;
    let b = run_des(&cfg);
    assert_eq!(a.generated, b.generated);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.swap_count, b.swap_count);
    assert_eq!(a.latency_mean_s, b.latency_mean_s,
               "No-CC timelines must be bit-identical");
    assert_eq!(a.runtime_s, b.runtime_s);
    assert_eq!(a.total_load_s, b.total_load_s);
    assert_eq!(a.total_crypto_s, 0.0);
}

#[test]
fn prefetch_promotes_in_the_des() {
    let mut cfg = base_cfg("cc");
    cfg.gpu.pipeline_depth = 2;
    cfg.prefetch = true;
    let s = run_des(&cfg);
    assert!(s.prefetch_count > 0,
            "two saturated queues must trigger staging");
    assert!(s.promoted_count > 0,
            "alternating swaps must promote at least one staged model");
    assert!(s.promoted_count <= s.swap_count);
    assert!(s.prefetch_count >= s.promoted_count,
            "every promotion needs a prior staging");
    assert!(s.prefetch, "summary must record the prefetch flag");
    // promotions are free loads: the fleet mean over all swaps sits
    // strictly below the mean over demand loads alone
    let demand = s.swap_count - s.promoted_count;
    assert!(demand > 0, "run must also pay some demand loads");
    assert!(s.mean_load_s < s.total_load_s / demand as f64,
            "promotions must dilute the mean load");
    // and the batch records show it: a promoted swap with a zero load
    let cm = toy_costs();
    let mut cfg2 = base_cfg("cc");
    cfg2.gpu.pipeline_depth = 2;
    cfg2.prefetch = true;
    let (_, recorder) = EngineBuilder::new(&cfg2).des(manifest(), &cm)
        .unwrap().run().unwrap();
    assert!(recorder.batches.iter()
                .any(|b| b.promoted && b.swapped && b.load_s == 0.0),
            "promoted batches must carry a zero-cost load");
    assert!(recorder.batches.iter().any(|b| b.prefetch_s > 0.0),
            "staging must be visible in the batch records");
}

#[test]
fn prefetch_works_on_the_real_wall_clock_path() {
    let mut cfg = base_cfg("cc");
    cfg.duration_s = 6.0;
    cfg.drain_s = 4.0;
    cfg.mean_rps = 6.0;
    cfg.sla_s = 3.0;
    cfg.artifacts_dir = artifacts_dir();
    cfg.gpu.pipeline_depth = 2;
    cfg.prefetch = true;
    let (summary, recorder) = registry()
        .with(|reg| EngineBuilder::new(&cfg).real(reg)
            .and_then(|b| b.run()))
        .unwrap();
    assert!(summary.completed > 0);
    assert!(summary.prefetch_count >= summary.promoted_count);
    // staging really rode the DMA path: batches carry prefetch seconds
    // whenever staging happened
    if summary.prefetch_count > 0 {
        assert!(recorder.batches.iter().any(|b| b.prefetch_s > 0.0),
                "staging must be visible in the batch records");
    }
    if summary.promoted_count > 0 {
        assert!(recorder.batches.iter()
                    .any(|b| b.promoted && b.load_s == 0.0),
                "a promotion is a swap with a zero-cost load");
    }
}
