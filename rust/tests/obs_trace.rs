//! The waterfall identity (ISSUE 9 acceptance): with `--trace full`,
//! every completed request's phase columns — queue wait + swap unload
//! + swap load + exec + I/O — must sum to its recorded latency within
//! 1e-9, across CC/No-CC, pipeline depths, and hardware-generation
//! profiles.  The identity is structural (the virtual-time protocol
//! derives `complete_s` from exactly these terms), so any drift means
//! a phase was dropped or double-counted.
//!
//! The suite also pins the artifacts end to end: the Chrome trace JSON
//! parses, carries the schema version and a span per lane, and the
//! waterfall CSV re-checks the identity from the file itself.

mod common;

use std::path::PathBuf;
use std::sync::OnceLock;

use sincere::config::RunConfig;
use sincere::engine::EngineBuilder;
use sincere::runtime::Manifest;
use sincere::sim::calib::CostModel;
use sincere::util::csvio::CsvTable;
use sincere::util::json::Json;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn manifest() -> &'static Manifest {
    static M: OnceLock<Manifest> = OnceLock::new();
    M.get_or_init(|| Manifest::load(&artifacts_dir()).expect(
        "artifacts missing: run tools/gen_artifacts.py"))
}

fn toy_costs() -> CostModel {
    common::toy_costs(manifest())
}

/// One traced DES cell at golden scale.  The profile is applied before
/// the mode so the swept mode wins over the profile's bundled default,
/// exactly like the lab's `profile` axis.
fn traced_cfg(mode: &str, profile: Option<&str>, depth: usize)
              -> RunConfig {
    let mut cfg = RunConfig {
        duration_s: 20.0,
        drain_s: 8.0,
        mean_rps: 4.0,
        sla_s: 6.0,
        strategy: "select-batch+timer".to_string(),
        models: vec!["llama-sim".into(), "gemma-sim".into()],
        ..RunConfig::default()
    };
    if let Some(p) = profile {
        cfg.set("device-profiles", p).unwrap();
    }
    cfg.set("mode", mode).unwrap();
    cfg.gpu.pipeline_depth = depth;
    cfg.set("trace", "full").unwrap();
    cfg.gpu.no_throttle = true;
    cfg.label = cfg.cell_label();
    cfg
}

fn run_des(cfg: &RunConfig) -> (sincere::engine::RunSummary,
                                sincere::metrics::recorder::Recorder) {
    let cm = toy_costs();
    EngineBuilder::new(cfg).des(manifest(), &cm).unwrap().run().unwrap()
}

/// The acceptance matrix: every completed request's waterfall phases
/// sum to its recorded latency within 1e-9 in every cell, and the
/// aggregated `phase_totals` block re-tells the same totals.
#[test]
fn waterfall_phases_sum_to_latency_across_the_matrix() {
    for mode in ["no-cc", "cc"] {
        for depth in [0usize, 2] {
            for profile in [None, Some("b300-cc"),
                            Some("gh200-coherent")] {
                let cfg = traced_cfg(mode, profile, depth);
                let tag = &cfg.label;
                let (summary, rec) = run_des(&cfg);
                let tr = rec.trace.as_ref()
                    .unwrap_or_else(|| panic!("{tag}: trace missing"));
                assert!(!tr.waterfalls.is_empty(),
                        "{tag}: degenerate traced run");
                assert_eq!(tr.waterfalls.len() as u64, summary.completed,
                           "{tag}: a completed request has no row");
                let mut totals = (0.0, 0.0);
                for w in &tr.waterfalls {
                    assert!((w.phase_sum_s() - w.latency_s).abs()
                                <= 1e-9,
                            "{tag}: request {} phases {} != latency {}",
                            w.id, w.phase_sum_s(), w.latency_s);
                    // attribution stays inside the load it annotates
                    assert!(w.swap_bridge_s + w.swap_crypto_exposed_s
                                <= w.swap_load_s + 1e-9,
                            "{tag}: request {} attribution exceeds \
                             load", w.id);
                    totals.0 += w.phase_sum_s();
                    totals.1 += w.latency_s;
                }
                let p = summary.phase_totals.as_ref()
                    .unwrap_or_else(|| panic!(
                        "{tag}: phase_totals missing"));
                assert_eq!(p.requests, summary.completed, "{tag}");
                assert!((p.latency_s - totals.1).abs() <= 1e-6,
                        "{tag}: phase_totals latency diverged");
                assert!((totals.0 - totals.1).abs()
                            <= 1e-9 * tr.waterfalls.len() as f64,
                        "{tag}: aggregate identity broke");
            }
        }
    }
}

/// No-CC pays no swap crypto and no bridge; CC cells put seconds in
/// the load column that their No-CC twins do not — the attribution the
/// report's waterfall table turns into the CC-tax delta block.
#[test]
fn cc_tax_shows_up_in_the_load_phase() {
    let (_, nocc) = run_des(&traced_cfg("no-cc", None, 0));
    let (_, cc) = run_des(&traced_cfg("cc", None, 0));
    let load = |r: &sincere::metrics::recorder::Recorder| {
        r.trace.as_ref().unwrap().waterfalls.iter()
            .map(|w| w.swap_load_s).sum::<f64>()
    };
    assert!(load(&cc) > load(&nocc),
            "CC must pay more load seconds than No-CC ({} vs {})",
            load(&cc), load(&nocc));
    let nocc_tr = nocc.trace.as_ref().unwrap();
    assert!(nocc_tr.waterfalls.iter()
            .all(|w| w.swap_crypto_exposed_s == 0.0
                 && w.swap_bridge_s == 0.0),
            "No-CC rows must carry no CC attribution");
    // the coherent profile moves the whole tax into the bridge slice
    let (_, gh) = run_des(&traced_cfg("cc", Some("gh200-coherent"), 0));
    let gh_tr = gh.trace.as_ref().unwrap();
    assert!(gh_tr.waterfalls.iter().any(|w| w.swap_bridge_s > 0.0),
            "coherent cells must attribute bridge seconds");
    assert!(gh_tr.waterfalls.iter()
            .all(|w| w.swap_crypto_exposed_s == 0.0),
            "coherent memory prices no chunk crypto");
}

/// The on-disk artifacts: the Chrome trace JSON parses, carries the
/// schema version, label, and device + class lanes; the waterfall CSV
/// satisfies the identity when re-read from the file.
#[test]
fn trace_artifacts_land_on_disk_and_validate() {
    let dir = std::env::temp_dir().join("sincere_obs_trace_test");
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = traced_cfg("cc", None, 0);
    cfg.results_dir = Some(dir.clone());
    let (summary, _) = run_des(&cfg);
    assert!(summary.completed > 0);

    let label = &cfg.label;
    let text = std::fs::read_to_string(
        dir.join(format!("{label}_trace.json"))).unwrap();
    let j = Json::parse(&text).unwrap();
    assert_eq!(j.get("label").and_then(|v| v.as_str()),
               Some(label.as_str()));
    assert_eq!(j.get("schemaVersion").and_then(|v| v.as_u64()),
               Some(sincere::obs::TRACE_SCHEMA_VERSION as u64));
    let events = j.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
    assert!(!events.is_empty(), "empty trace");
    let tids: Vec<f64> = events.iter()
        .filter_map(|e| e.get("tid").and_then(|v| v.as_f64()))
        .collect();
    assert!(tids.contains(&0.0), "no device lane");
    assert!(tids.contains(&(sincere::obs::CLASS_TID_BASE as f64)),
            "no request lane");

    let t = CsvTable::read(
        &dir.join(format!("{label}_waterfall.csv"))).unwrap();
    assert_eq!(t.rows.len() as u64, summary.completed);
    let cols: Vec<Vec<f64>> = ["queue_wait_s", "swap_unload_s",
                               "swap_load_s", "exec_s", "io_s",
                               "latency_s"].iter()
        .map(|c| t.f64_col(c).unwrap()).collect();
    for i in 0..t.rows.len() {
        let phases: f64 = cols[..5].iter().map(|c| c[i]).sum();
        // 9-decimal CSV rounding: 5 columns x 5e-10 each, plus slack
        assert!((phases - cols[5][i]).abs() <= 5e-9,
                "row {i}: phases {phases} != latency {}", cols[5][i]);
    }

    // trace off writes nothing: same cell, tracing disabled
    let mut off = traced_cfg("cc", None, 0);
    off.set("trace", "off").unwrap();
    off.label = "off_probe".into();
    off.results_dir = Some(dir.clone());
    run_des(&off);
    assert!(!dir.join("off_probe_trace.json").exists()
            && !dir.join("off_probe_waterfall.csv").exists(),
            "trace-off run wrote trace artifacts");
}
