//! Integration: cost-model calibration + DES, including a DES-vs-real
//! cross-check on an unthrottled configuration.

use std::path::PathBuf;
use std::sync::OnceLock;

use sincere::config::RunConfig;
use sincere::engine::EngineBuilder;
use sincere::gpu::device::GpuConfig;
use sincere::gpu::CcMode;
use sincere::runtime::registry::SharedRegistry;
use sincere::runtime::{Manifest, Registry};
use sincere::sim::CostModel;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn manifest() -> &'static Manifest {
    static M: OnceLock<Manifest> = OnceLock::new();
    M.get_or_init(|| Manifest::load(&artifacts_dir()).expect(
        "run `make artifacts` before cargo test"))
}

fn registry() -> &'static SharedRegistry {
    static REG: OnceLock<SharedRegistry> = OnceLock::new();
    REG.get_or_init(|| SharedRegistry::new(Registry::load(
        manifest(),
        &["llama-sim".to_string(), "gemma-sim".to_string()],
        &[1, 2, 4, 8]).unwrap()))
}

fn measured_costs() -> &'static CostModel {
    static CM: OnceLock<CostModel> = OnceLock::new();
    CM.get_or_init(|| {
        let cfg = GpuConfig { no_throttle: true, ..GpuConfig::default() };
        registry().with(|reg| CostModel::measure(reg, &cfg, 1)).unwrap()
    })
}

#[test]
fn measure_produces_sane_costs() {
    let cm = measured_costs();
    for name in ["llama-sim", "gemma-sim"] {
        let mc = cm.costs(name).unwrap();
        assert!(mc.load_s_cc > mc.load_s_plain,
                "{name}: CC load {} <= plain {}", mc.load_s_cc,
                mc.load_s_plain);
        assert!(mc.unload_s < 0.1);
        assert!(!mc.exec_s_by_batch.is_empty());
        // exec time grows with batch but sublinearly
        let e1 = mc.exec_s(1);
        let e8 = mc.exec_s(8);
        assert!(e8 > e1 * 0.8, "{name}: exec b8 {e8} vs b1 {e1}");
        assert!(e8 < e1 * 8.0, "{name}: no batching benefit");
        assert!(mc.exec_s_by_batch.contains_key(&mc.obs));
    }
    // CC I/O is costlier than plain
    assert!(cm.io_s_per_row_cc >= cm.io_s_per_row_plain);
}

#[test]
fn costs_json_roundtrip_through_disk() {
    let cm = measured_costs();
    let path = std::env::temp_dir().join("sincere_cm_roundtrip.json");
    cm.save(&path).unwrap();
    let back = CostModel::load(&path).unwrap();
    for name in ["llama-sim", "gemma-sim"] {
        let a = cm.costs(name).unwrap();
        let b = back.costs(name).unwrap();
        assert!((a.load_s_cc - b.load_s_cc).abs() < 1e-9);
        assert_eq!(a.obs, b.obs);
        assert_eq!(a.exec_s_by_batch.len(), b.exec_s_by_batch.len());
    }
}

fn sim_cfg() -> RunConfig {
    let mut cfg = RunConfig {
        artifacts_dir: artifacts_dir(),
        duration_s: 60.0,
        drain_s: 6.0,
        mean_rps: 4.0,
        sla_s: 3.0,
        models: vec!["llama-sim".into(), "gemma-sim".into()],
        ..RunConfig::default()
    };
    cfg.gpu.no_throttle = true;
    cfg
}

#[test]
fn des_matches_real_serve_within_tolerance() {
    // Same unthrottled config, same seed: DES with measured costs should
    // land near the real run on the aggregate metrics.
    let mut cfg = sim_cfg();
    cfg.duration_s = 10.0;
    let (real, _) = registry()
        .with(|reg| EngineBuilder::new(&cfg).real(reg)
            .and_then(|b| b.run()))
        .unwrap();
    let des = EngineBuilder::new(&cfg)
        .des(manifest(), measured_costs()).unwrap()
        .run().unwrap().0;

    assert_eq!(des.generated, real.generated,
               "same seed must give the same schedule");
    let done_ratio = des.completed as f64 / real.completed.max(1) as f64;
    assert!((0.5..2.0).contains(&done_ratio),
            "completed: des {} vs real {}", des.completed, real.completed);
    if real.latency_mean_s > 0.0 && des.latency_mean_s > 0.0 {
        let lat_ratio = des.latency_mean_s / real.latency_mean_s;
        assert!((0.2..5.0).contains(&lat_ratio),
                "latency: des {:.3} vs real {:.3}", des.latency_mean_s,
                real.latency_mean_s);
    }
}

#[test]
fn des_sla_attainment_monotone_in_sla() {
    // A looser SLA can only improve attainment (same schedule/strategy).
    let cm = measured_costs();
    let mut prev = -1.0;
    for sla in [1.0, 3.0, 8.0] {
        let mut cfg = sim_cfg();
        cfg.sla_s = sla;
        cfg.drain_s = 8.0; // keep the served set comparable across SLAs
        let s = EngineBuilder::new(&cfg).des(manifest(), cm)
            .unwrap().run().unwrap().0;
        assert!(s.sla_attainment >= prev - 0.02,
                "attainment fell from {prev} to {} at sla {sla}",
                s.sla_attainment);
        prev = s.sla_attainment;
    }
}

#[test]
fn des_cc_consistently_worse_or_equal() {
    let cm = measured_costs();
    for pattern in ["gamma", "bursty", "ramp"] {
        let run = |mode: CcMode| {
            let mut cfg = sim_cfg();
            cfg.pattern = pattern.into();
            cfg.mode = mode;
            cfg.gpu.mode = mode;
            EngineBuilder::new(&cfg).des(manifest(), cm).unwrap()
                .run().unwrap().0
        };
        let cc = run(CcMode::On);
        let nc = run(CcMode::Off);
        assert!(cc.latency_mean_s >= nc.latency_mean_s * 0.95,
                "{pattern}: CC latency {} < No-CC {}", cc.latency_mean_s,
                nc.latency_mean_s);
    }
}

#[test]
fn des_rejects_unknown_model() {
    let mut cfg = sim_cfg();
    cfg.models = vec!["gpt-5".into()];
    assert!(EngineBuilder::new(&cfg)
        .des(manifest(), measured_costs())
        .and_then(|b| b.run())
        .is_err());
}
