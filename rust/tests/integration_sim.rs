//! Integration: cost-model calibration + DES (through `EngineBuilder`,
//! the single supported entry point), including a DES-vs-real
//! cross-check on an unthrottled configuration.

use std::path::PathBuf;
use std::sync::OnceLock;

use sincere::config::RunConfig;
use sincere::engine::{EngineBuilder, RunSummary};
use sincere::gpu::device::GpuConfig;
use sincere::gpu::CcMode;
use sincere::runtime::registry::SharedRegistry;
use sincere::runtime::{Manifest, Registry};
use sincere::sim::calib::ModelCosts;
use sincere::sim::CostModel;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn manifest() -> &'static Manifest {
    static M: OnceLock<Manifest> = OnceLock::new();
    M.get_or_init(|| Manifest::load(&artifacts_dir()).expect(
        "run `make artifacts` before cargo test"))
}

fn registry() -> &'static SharedRegistry {
    static REG: OnceLock<SharedRegistry> = OnceLock::new();
    REG.get_or_init(|| SharedRegistry::new(Registry::load(
        manifest(),
        &["llama-sim".to_string(), "gemma-sim".to_string()],
        &[1, 2, 4, 8]).unwrap()))
}

fn measured_costs() -> &'static CostModel {
    static CM: OnceLock<CostModel> = OnceLock::new();
    CM.get_or_init(|| {
        let cfg = GpuConfig { no_throttle: true, ..GpuConfig::default() };
        registry().with(|reg| CostModel::measure(reg, &cfg, 1)).unwrap()
    })
}

#[test]
fn measure_produces_sane_costs() {
    let cm = measured_costs();
    for name in ["llama-sim", "gemma-sim"] {
        let mc = cm.costs(name).unwrap();
        assert!(mc.load_s_cc > mc.load_s_plain,
                "{name}: CC load {} <= plain {}", mc.load_s_cc,
                mc.load_s_plain);
        assert!(mc.unload_s < 0.1);
        assert!(!mc.exec_s_by_batch.is_empty());
        // exec time grows with batch but sublinearly
        let e1 = mc.exec_s(1);
        let e8 = mc.exec_s(8);
        assert!(e8 > e1 * 0.8, "{name}: exec b8 {e8} vs b1 {e1}");
        assert!(e8 < e1 * 8.0, "{name}: no batching benefit");
        assert!(mc.exec_s_by_batch.contains_key(&mc.obs));
    }
    // CC I/O is costlier than plain
    assert!(cm.io_s_per_row_cc >= cm.io_s_per_row_plain);
}

#[test]
fn costs_json_roundtrip_through_disk() {
    let cm = measured_costs();
    let path = std::env::temp_dir().join("sincere_cm_roundtrip.json");
    cm.save(&path).unwrap();
    let back = CostModel::load(&path).unwrap();
    for name in ["llama-sim", "gemma-sim"] {
        let a = cm.costs(name).unwrap();
        let b = back.costs(name).unwrap();
        assert!((a.load_s_cc - b.load_s_cc).abs() < 1e-9);
        assert_eq!(a.obs, b.obs);
        assert_eq!(a.exec_s_by_batch.len(), b.exec_s_by_batch.len());
    }
}

fn sim_cfg() -> RunConfig {
    let mut cfg = RunConfig {
        artifacts_dir: artifacts_dir(),
        duration_s: 60.0,
        drain_s: 6.0,
        mean_rps: 4.0,
        sla_s: 3.0,
        models: vec!["llama-sim".into(), "gemma-sim".into()],
        ..RunConfig::default()
    };
    cfg.gpu.no_throttle = true;
    cfg
}

#[test]
fn des_matches_real_serve_within_tolerance() {
    // Same unthrottled config, same seed: DES with measured costs should
    // land near the real run on the aggregate metrics.
    let mut cfg = sim_cfg();
    cfg.duration_s = 10.0;
    let (real, _) = registry()
        .with(|reg| EngineBuilder::new(&cfg).real(reg)
            .and_then(|b| b.run()))
        .unwrap();
    let des = EngineBuilder::new(&cfg)
        .des(manifest(), measured_costs()).unwrap()
        .run().unwrap().0;

    assert_eq!(des.generated, real.generated,
               "same seed must give the same schedule");
    let done_ratio = des.completed as f64 / real.completed.max(1) as f64;
    assert!((0.5..2.0).contains(&done_ratio),
            "completed: des {} vs real {}", des.completed, real.completed);
    if real.latency_mean_s > 0.0 && des.latency_mean_s > 0.0 {
        let lat_ratio = des.latency_mean_s / real.latency_mean_s;
        assert!((0.2..5.0).contains(&lat_ratio),
                "latency: des {:.3} vs real {:.3}", des.latency_mean_s,
                real.latency_mean_s);
    }
}

#[test]
fn des_sla_attainment_monotone_in_sla() {
    // A looser SLA can only improve attainment (same schedule/strategy).
    let cm = measured_costs();
    let mut prev = -1.0;
    for sla in [1.0, 3.0, 8.0] {
        let mut cfg = sim_cfg();
        cfg.sla_s = sla;
        cfg.drain_s = 8.0; // keep the served set comparable across SLAs
        let s = EngineBuilder::new(&cfg).des(manifest(), cm)
            .unwrap().run().unwrap().0;
        assert!(s.sla_attainment >= prev - 0.02,
                "attainment fell from {prev} to {} at sla {sla}",
                s.sla_attainment);
        prev = s.sla_attainment;
    }
}

#[test]
fn des_cc_consistently_worse_or_equal() {
    let cm = measured_costs();
    for pattern in ["gamma", "bursty", "ramp"] {
        let run = |mode: CcMode| {
            let mut cfg = sim_cfg();
            cfg.pattern = pattern.into();
            cfg.mode = mode;
            cfg.gpu.mode = mode;
            EngineBuilder::new(&cfg).des(manifest(), cm).unwrap()
                .run().unwrap().0
        };
        let cc = run(CcMode::On);
        let nc = run(CcMode::Off);
        assert!(cc.latency_mean_s >= nc.latency_mean_s * 0.95,
                "{pattern}: CC latency {} < No-CC {}", cc.latency_mean_s,
                nc.latency_mean_s);
    }
}

#[test]
fn des_rejects_unknown_model() {
    let mut cfg = sim_cfg();
    cfg.models = vec!["gpt-5".into()];
    assert!(EngineBuilder::new(&cfg)
        .des(manifest(), measured_costs())
        .and_then(|b| b.run())
        .is_err());
}

// ---------------------------------------------------------------------
// DES behaviour on a hand-built toy cost table (ported from the old
// `sim::simulate` shim's tests when the deprecated entry point was
// removed; everything runs through `EngineBuilder` now).
// ---------------------------------------------------------------------

fn toy_costs(manifest: &Manifest) -> CostModel {
    let mut cm = CostModel {
        io_s_per_row_plain: 0.0005,
        io_s_per_row_cc: 0.0015,
        ..Default::default()
    };
    for f in &manifest.families {
        let size_factor = f.weights.total_bytes as f64 / 4e6;
        let mut mc = ModelCosts {
            load_s_plain: 0.35 * size_factor,
            load_s_cc: 1.0 * size_factor,
            unload_s: 0.006,
            obs: 16,
            ..Default::default()
        };
        for &b in &[1usize, 2, 4, 8, 16, 32] {
            mc.exec_s_by_batch.insert(
                b, 0.08 + 0.012 * b as f64 * size_factor);
        }
        cm.models.insert(f.name.clone(), mc);
    }
    cm
}

fn toy_cfg() -> RunConfig {
    RunConfig {
        duration_s: 120.0,
        drain_s: 10.0,
        mean_rps: 4.0,
        ..Default::default()
    }
}

fn toy_run(cfg: &RunConfig) -> RunSummary {
    let m = manifest();
    let costs = toy_costs(m);
    EngineBuilder::new(cfg).des(m, &costs).unwrap().run().unwrap().0
}

#[test]
fn simulation_completes_requests() {
    let s = toy_run(&toy_cfg());
    assert!(s.generated > 300, "generated {}", s.generated);
    assert!(s.completed > 0);
    assert!(s.completed + 50 > s.generated / 2,
            "too few completed: {}/{}", s.completed, s.generated);
    assert!(s.gpu_util > 0.0 && s.gpu_util < 1.0);
    assert!(s.swap_count > 1);
}

#[test]
fn cc_mode_is_slower_end_to_end() {
    let mut cc = toy_cfg();
    cc.set("mode", "cc").unwrap();
    let s_cc = toy_run(&cc);
    let s_plain = toy_run(&toy_cfg());
    assert!(s_cc.latency_mean_s > s_plain.latency_mean_s,
            "cc {} <= plain {}", s_cc.latency_mean_s,
            s_plain.latency_mean_s);
    assert!(s_cc.sla_attainment <= s_plain.sla_attainment + 0.05);
}

#[test]
fn deterministic_for_same_seed() {
    let a = toy_run(&toy_cfg());
    let b = toy_run(&toy_cfg());
    assert_eq!(a.completed, b.completed);
    assert!((a.latency_mean_s - b.latency_mean_s).abs() < 1e-12);
}

#[test]
fn all_strategies_run() {
    for name in sincere::coordinator::strategy_names() {
        let mut cfg = toy_cfg();
        cfg.strategy = name.to_string();
        let s = toy_run(&cfg);
        assert!(s.completed > 0, "{name} completed nothing");
    }
}

#[test]
fn accounting_identity_holds() {
    // generated == completed + unserved (via sla totals)
    let s = toy_run(&toy_cfg());
    assert!(s.sla_met <= s.completed);
    assert!(s.completed <= s.generated);
}

/// Satellite for `queues.rs::expire`: when expiry interleaves with
/// partial-batch drains (the partial+timer strategy under a tight
/// SLA), every generated request must be accounted exactly once —
/// attainment's denominator equals the generated count, so nothing is
/// double-counted between expiry, drain, and completion.
#[test]
fn expiry_interleaved_with_partial_drain_counts_once() {
    let mut cfg = toy_cfg();
    cfg.strategy = "best-batch+partial+timer".into();
    cfg.sla_s = 1.5; // tight: plenty of in-queue expiry
    cfg.mean_rps = 8.0;
    let s = toy_run(&cfg);
    assert!(s.completed > 0);
    assert!(s.sla_met > 0, "degenerate run: nothing met the SLA");
    assert!(s.completed < s.generated, "need some unfulfilled requests");
    // attainment = met / (met + missed); the denominator must be the
    // generated count — each request counted exactly once
    let total = (s.sla_met as f64 / s.sla_attainment).round() as u64;
    assert_eq!(total, s.generated,
               "unfulfilled accounting drifted: met={} att={} gen={}",
               s.sla_met, s.sla_attainment, s.generated);
}
