//! Integration: the HTTP front-end end-to-end over real sockets —
//! requests in, batched PJRT execution, JSON responses out.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use sincere::config::RunConfig;
use sincere::coordinator::http::{http_call, run_http};
use sincere::runtime::{Manifest, Registry};
use sincere::util::json::Json;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn http_serves_inference_over_sockets() {
    let manifest = Manifest::load(&artifacts_dir()).expect(
        "run `make artifacts` before cargo test");
    let registry = Registry::load(
        &manifest, &["llama-sim".to_string()], &[1, 2, 4]).unwrap();

    let mut cfg = RunConfig {
        artifacts_dir: artifacts_dir(),
        sla_s: 30.0,
        models: vec!["llama-sim".into()],
        ..RunConfig::default()
    };
    cfg.gpu.no_throttle = true;
    cfg.timeout_frac = 0.02; // dispatch promptly in the test

    let shutdown = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = mpsc::channel();

    // clients drive the server from worker threads; the scheduler runs
    // on this thread (xla types are !Send)
    let client_shutdown = shutdown.clone();
    let clients = std::thread::spawn(move || {
        let addr = addr_rx.recv().unwrap();

        // health + stats
        let (code, body) = http_call(&addr, "GET", "/healthz", None)
            .unwrap();
        assert_eq!(code, 200, "{body}");
        let (code, _) = http_call(&addr, "GET", "/stats", None).unwrap();
        assert_eq!(code, 200);

        // three concurrent inference calls -> should batch together
        let mut joins = Vec::new();
        for i in 0..3 {
            let addr = addr;
            joins.push(std::thread::spawn(move || {
                let body = format!(
                    "{{\"model\":\"llama-sim\",\"prompt\":\"request {i} \
                     summarize the confidential computing benchmark\"}}");
                http_call(&addr, "POST", "/infer", Some(&body)).unwrap()
            }));
        }
        let responses: Vec<(u16, String)> =
            joins.into_iter().map(|j| j.join().unwrap()).collect();
        for (code, body) in &responses {
            assert_eq!(*code, 200, "{body}");
            let j = Json::parse(body).unwrap();
            let tokens = j.req("tokens").unwrap().as_arr().unwrap();
            assert_eq!(tokens.len(), 50, "decode_len tokens");
            assert!(j.req("latency_s").unwrap().as_f64().unwrap() > 0.0);
        }
        // different prompts should generally produce different outputs
        assert!(responses.iter().any(|(_, b)| b != &responses[0].1)
                || responses.len() == 1);

        // bad requests are rejected cleanly
        let (code, _) = http_call(&addr, "POST", "/infer",
                                  Some("{not json")).unwrap();
        assert_eq!(code, 400);
        let (code, _) = http_call(
            &addr, "POST", "/infer",
            Some("{\"model\":\"gpt-5\",\"prompt\":\"x\"}")).unwrap();
        assert_eq!(code, 400);
        let (code, _) = http_call(&addr, "GET", "/nope", None).unwrap();
        assert_eq!(code, 404);

        client_shutdown.store(true, Ordering::Relaxed);
    });

    let stats = run_http(&cfg, &registry, "127.0.0.1:0", shutdown,
                         move |addr| {
                             addr_tx.send(addr).unwrap();
                         }).unwrap();
    clients.join().unwrap();
    assert_eq!(stats.completed.load(Ordering::Relaxed), 3);
    assert_eq!(stats.rejected.load(Ordering::Relaxed), 2);
    assert_eq!(stats.expired.load(Ordering::Relaxed), 0);
}
