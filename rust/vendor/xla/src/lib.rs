//! Offline PJRT stand-in for the `xla` crate (xla_extension bindings).
//!
//! The real project AOT-lowers a JAX transformer to HLO text and
//! executes it through PJRT.  This container has no XLA runtime, so
//! this crate executes the repo's HLO artifacts *behaviourally*: each
//! artifact carries a `// sincere.meta:` header (emitted by
//! `tools/gen_artifacts.py`) describing its shapes and calibrated work
//! factors, and `PjRtLoadedExecutable::execute` produces
//!
//! * deterministic decode tokens that are a pure per-row function of
//!   the prompt row and the weight fingerprint (so padding rows are
//!   inert and batch size never changes a row's output — the same
//!   contracts `python/tests` pin for the real kernels), and
//! * a deterministic amount of CPU work that grows sublinearly with
//!   batch size (fixed per-dispatch cost + small per-row cost), so
//!   profiling (Fig 4 / OBS discovery) sees the paper's shape.
//!
//! The API mirrors the exact subset of `xla` v0.5 the runtime layer
//! uses; swapping the real crate back in is a Cargo.toml change.

use std::fmt;

// ------------------------------------------------------------------ error

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn err(msg: impl Into<String>) -> Error {
    Error(msg.into())
}

// ---------------------------------------------------------------- literal

/// Typed flat payload of a [`Literal`] (public because the
/// `NativeType` conversion trait mentions it; not part of the real
/// xla API surface).
#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host-side tensor value (array or tuple), with a content fingerprint
/// computed once at construction so `execute` can cheaply mix weight
/// identity into its outputs.
#[derive(Debug, Clone)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
    fp: u64,
}

/// Element types `Literal::vec1`/`to_vec` accept.
pub trait NativeType: Sized + Copy {
    fn wrap(values: Vec<Self>) -> Payload;
    fn unwrap(payload: &Payload) -> Option<&[Self]>;
    fn hash_into(values: &[Self], h: &mut u64);
}

fn fnv_step(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x0100_0000_01b3);
    }
}

impl NativeType for f32 {
    fn wrap(values: Vec<Self>) -> Payload {
        Payload::F32(values)
    }

    fn unwrap(payload: &Payload) -> Option<&[Self]> {
        match payload {
            Payload::F32(v) => Some(v),
            _ => None,
        }
    }

    fn hash_into(values: &[Self], h: &mut u64) {
        for v in values {
            fnv_step(h, &v.to_bits().to_le_bytes());
        }
    }
}

impl NativeType for i32 {
    fn wrap(values: Vec<Self>) -> Payload {
        Payload::I32(values)
    }

    fn unwrap(payload: &Payload) -> Option<&[Self]> {
        match payload {
            Payload::I32(v) => Some(v),
            _ => None,
        }
    }

    fn hash_into(values: &[Self], h: &mut u64) {
        for v in values {
            fnv_step(h, &v.to_le_bytes());
        }
    }
}

impl Literal {
    /// Build a rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(values: &[T]) -> Literal {
        let mut fp = 0xcbf2_9ce4_8422_2325u64;
        T::hash_into(values, &mut fp);
        let dims = vec![values.len() as i64];
        Literal { payload: T::wrap(values.to_vec()), dims, fp }
    }

    /// Reinterpret the flat payload under new dimensions.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let numel: i64 = dims.iter().product();
        let have = match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
            Payload::Tuple(_) => {
                return Err(err("cannot reshape a tuple literal"));
            }
        };
        if numel < 0 || numel as usize != have {
            return Err(err(format!(
                "reshape {dims:?} ({numel} elements) on literal of {have}")));
        }
        Ok(Literal {
            payload: self.payload.clone(),
            dims: dims.to_vec(),
            fp: self.fp,
        })
    }

    /// Extract the flat payload as `T` elements.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.payload)
            .map(|v| v.to_vec())
            .ok_or_else(|| err("literal element type mismatch"))
    }

    /// Unwrap a 1-element tuple (the artifact output convention).
    pub fn to_tuple1(&self) -> Result<Literal> {
        match &self.payload {
            Payload::Tuple(elems) if elems.len() == 1 => {
                Ok(elems[0].clone())
            }
            Payload::Tuple(elems) => Err(err(format!(
                "expected 1-tuple, got {}-tuple", elems.len()))),
            _ => Err(err("expected tuple literal")),
        }
    }

    /// Wrap literals into a tuple.
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        let mut fp = 0x9e37_79b9_7f4a_7c15u64;
        for e in &elems {
            fp ^= e.fp;
            fp = splitmix(fp);
        }
        Literal { payload: Payload::Tuple(elems), dims: Vec::new(), fp }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Content fingerprint (stable across reshape).
    pub fn fingerprint(&self) -> u64 {
        self.fp
    }
}

// -------------------------------------------------------------- hlo meta

/// Metadata parsed from an artifact's `// sincere.meta:` header.
#[derive(Debug, Clone)]
struct HloMeta {
    name: String,
    batch: usize,
    prompt_len: usize,
    decode_len: usize,
    vocab: usize,
    /// Fixed per-dispatch work units (deterministic spin).
    work_base: u64,
    /// Additional work units per batch row.
    work_per_row: u64,
}

fn parse_meta(text: &str) -> Result<HloMeta> {
    let line = text.lines()
        .find_map(|l| l.trim().strip_prefix("// sincere.meta:"))
        .ok_or_else(|| err("no sincere.meta header in HLO artifact"))?;
    let mut meta = HloMeta {
        name: String::new(),
        batch: 0,
        prompt_len: 0,
        decode_len: 0,
        vocab: 0,
        work_base: 100_000,
        work_per_row: 10_000,
    };
    for kv in line.split_whitespace() {
        let Some((k, v)) = kv.split_once('=') else { continue };
        match k {
            "name" => meta.name = v.to_string(),
            "batch" => meta.batch = parse_num(k, v)?,
            "prompt_len" => meta.prompt_len = parse_num(k, v)?,
            "decode_len" => meta.decode_len = parse_num(k, v)?,
            "vocab" => meta.vocab = parse_num(k, v)?,
            "work_base" => meta.work_base = parse_num(k, v)? as u64,
            "work_per_row" => meta.work_per_row = parse_num(k, v)? as u64,
            _ => {}
        }
    }
    if meta.batch == 0 || meta.prompt_len == 0 || meta.decode_len == 0
        || meta.vocab < 2
    {
        return Err(err(format!("incomplete sincere.meta: {line}")));
    }
    Ok(meta)
}

fn parse_num(key: &str, value: &str) -> Result<usize> {
    value.parse::<usize>()
        .map_err(|_| err(format!("bad sincere.meta {key}={value:?}")))
}

/// Parsed HLO module (text artifact + metadata).
pub struct HloModuleProto {
    meta: HloMeta,
}

impl HloModuleProto {
    /// Parse an HLO text artifact from disk.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| err(format!("reading {path}: {e}")))?;
        Ok(HloModuleProto { meta: parse_meta(&text)? })
    }
}

/// A computation ready to compile.
pub struct XlaComputation {
    meta: HloMeta,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { meta: proto.meta.clone() }
    }
}

// ---------------------------------------------------------------- client

/// The PJRT client (CPU only in this stand-in).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, computation: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable { meta: computation.meta.clone() })
    }
}

/// Device-side buffer holding one execution output.
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

#[inline]
fn splitmix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Compiled executable: deterministic behavioural model of one
/// (family, batch) artifact.
pub struct PjRtLoadedExecutable {
    meta: HloMeta,
}

impl PjRtLoadedExecutable {
    /// Execute with `[prompt, weights...]` argument order (the aot.py
    /// contract).  Returns one result tuple per device, PJRT-style.
    pub fn execute(&self, args: &[&Literal])
                   -> Result<Vec<Vec<PjRtBuffer>>> {
        let m = &self.meta;
        let prompt = args.first()
            .ok_or_else(|| err("execute: missing prompt argument"))?;
        let want = [m.batch as i64, m.prompt_len as i64];
        if prompt.dims() != &want[..] {
            return Err(err(format!(
                "execute {}: prompt dims {:?} != {:?}", m.name,
                prompt.dims(), want)));
        }
        let tokens = prompt.to_vec::<i32>()
            .map_err(|e| err(format!("execute {}: {e}", m.name)))?;

        // Weight identity: fold every weight literal's fingerprint.
        let mut weights_fp = 0xcbf2_9ce4_8422_2325u64;
        for w in &args[1..] {
            weights_fp = splitmix(weights_fp ^ w.fingerprint());
        }

        // Deterministic dispatch cost: a fixed base plus a small
        // per-row term, so throughput grows with batch size and
        // batching pays for itself (Fig 4's premise).
        let iters = m.work_base
            .wrapping_add(m.work_per_row.wrapping_mul(m.batch as u64));
        let mut acc = weights_fp | 1;
        for _ in 0..iters {
            acc = splitmix(acc);
        }
        std::hint::black_box(acc);

        // Decode tokens: pure per-row function of (row, weights).
        let mut out = Vec::with_capacity(m.batch * m.decode_len);
        for row in tokens.chunks(m.prompt_len) {
            let mut h = 0xcbf2_9ce4_8422_2325u64;
            i32::hash_into(row, &mut h);
            h = splitmix(h ^ weights_fp);
            for j in 0..m.decode_len {
                let mixed = splitmix(h ^ (j as u64 + 1));
                out.push((mixed % m.vocab as u64) as i32);
            }
        }
        let literal = Literal::vec1(&out)
            .reshape(&[m.batch as i64, m.decode_len as i64])?;
        Ok(vec![vec![PjRtBuffer {
            literal: Literal::tuple(vec![literal]),
        }]])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const HLO: &str = "\
HloModule test_b2\n\
// sincere.meta: name=test batch=2 prompt_len=4 decode_len=6 \
vocab=128 work_base=1000 work_per_row=100\n\
ENTRY main { ROOT x = s32[2,6] parameter(0) }\n";

    fn exe() -> PjRtLoadedExecutable {
        let meta = parse_meta(HLO).unwrap();
        PjRtLoadedExecutable { meta }
    }

    fn prompt(rows: &[[i32; 4]]) -> Literal {
        let flat: Vec<i32> = rows.iter().flatten().copied().collect();
        Literal::vec1(&flat).reshape(&[2, 4]).unwrap()
    }

    fn weights() -> Literal {
        Literal::vec1(&[0.5f32, -1.0, 2.0]).reshape(&[3]).unwrap()
    }

    fn run(exe: &PjRtLoadedExecutable, p: &Literal, w: &Literal)
           -> Vec<i32> {
        let out = exe.execute(&[p, w]).unwrap();
        out[0][0].to_literal_sync().unwrap().to_tuple1().unwrap()
            .to_vec::<i32>().unwrap()
    }

    #[test]
    fn deterministic_and_in_vocab() {
        let e = exe();
        let p = prompt(&[[1, 2, 3, 4], [5, 6, 7, 8]]);
        let w = weights();
        let a = run(&e, &p, &w);
        let b = run(&e, &p, &w);
        assert_eq!(a, b);
        assert_eq!(a.len(), 12);
        assert!(a.iter().all(|&t| (0..128).contains(&t)));
    }

    #[test]
    fn rows_are_independent() {
        let e = exe();
        let w = weights();
        let a = run(&e, &prompt(&[[1, 2, 3, 4], [0, 0, 0, 0]]), &w);
        let b = run(&e, &prompt(&[[1, 2, 3, 4], [9, 9, 9, 9]]), &w);
        assert_eq!(a[..6], b[..6], "row 0 must not see row 1");
        assert_ne!(a[6..], b[6..]);
    }

    #[test]
    fn weights_change_outputs() {
        let e = exe();
        let p = prompt(&[[1, 2, 3, 4], [5, 6, 7, 8]]);
        let a = run(&e, &p, &weights());
        let w2 = Literal::vec1(&[9.9f32, -1.0, 2.0]).reshape(&[3]).unwrap();
        let b = run(&e, &p, &w2);
        assert_ne!(a, b);
    }

    #[test]
    fn dim_mismatch_rejected() {
        let e = exe();
        let bad = Literal::vec1(&[1i32; 4]).reshape(&[1, 4]).unwrap();
        assert!(e.execute(&[&bad, &weights()]).is_err());
    }

    #[test]
    fn meta_parsing_requires_fields() {
        assert!(parse_meta("HloModule x\n").is_err());
        assert!(parse_meta("// sincere.meta: name=x batch=0").is_err());
    }

    #[test]
    fn reshape_checks_numel() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert!(l.reshape(&[2, 2]).is_err());
        assert!(l.reshape(&[3, 1]).is_ok());
        assert_eq!(l.fingerprint(),
                   l.reshape(&[1, 3]).unwrap().fingerprint());
    }
}
