//! Minimal offline stand-in for the `anyhow` crate.
//!
//! Implements the subset this workspace uses: [`Error`], [`Result`],
//! and the `anyhow!` / `bail!` / `ensure!` macros.  Every constructor
//! funnels into a message string plus an optional boxed source, and any
//! `std::error::Error + Send + Sync` converts via `?` exactly as with
//! the real crate.

use std::fmt;

/// A dynamically typed error with a display message and optional source.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from anything displayable (what `anyhow!` emits).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap a concrete error, keeping it as the source.
    pub fn new<E>(error: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Prefix the message with context (the `Context` trait's verb).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: format!("{context}: {}", self.msg),
            source: self.source,
        }
    }

    /// Borrow the underlying source error, if any.
    pub fn source_ref(&self)
                      -> Option<&(dyn std::error::Error + Send + Sync)> {
        self.source.as_deref()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// `anyhow::Result<T>` — `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_and_debug() {
        let e = anyhow!("bad {} ({})", "thing", 7);
        assert_eq!(e.to_string(), "bad thing (7)");
        assert_eq!(format!("{e:?}"), "bad thing (7)");
        assert_eq!(format!("{e:#}"), "bad thing (7)");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
        assert!(e.source_ref().is_some());
    }

    #[test]
    fn bail_and_ensure() {
        fn b() -> Result<u32> {
            bail!("nope {}", 1);
        }
        fn e(x: u32) -> Result<u32> {
            ensure!(x > 2, "x too small: {x}");
            Ok(x)
        }
        fn bare(x: u32) -> Result<u32> {
            ensure!(x > 2);
            Ok(x)
        }
        assert_eq!(b().unwrap_err().to_string(), "nope 1");
        assert_eq!(e(1).unwrap_err().to_string(), "x too small: 1");
        assert_eq!(e(3).unwrap(), 3);
        assert!(bare(1).unwrap_err().to_string().contains("x > 2"));
    }

    #[test]
    fn context_prefixes() {
        let e = anyhow!("inner").context("outer");
        assert_eq!(e.to_string(), "outer: inner");
    }
}
