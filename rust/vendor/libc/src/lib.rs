//! Minimal offline `libc` shim: exactly the `sysconf` surface
//! `metrics::system` needs on Linux.

#![allow(non_camel_case_types)]

pub type c_int = i32;
pub type c_long = i64;

/// `_SC_CLK_TCK` on Linux/glibc.
pub const _SC_CLK_TCK: c_int = 2;
/// `_SC_PAGESIZE` on Linux/glibc.
pub const _SC_PAGESIZE: c_int = 30;

extern "C" {
    pub fn sysconf(name: c_int) -> c_long;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sysconf_returns_sane_values() {
        let ticks = unsafe { sysconf(_SC_CLK_TCK) };
        let page = unsafe { sysconf(_SC_PAGESIZE) };
        assert!(ticks > 0, "clock ticks {ticks}");
        assert!(page >= 4096, "page size {page}");
    }
}
