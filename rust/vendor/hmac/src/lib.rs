//! Minimal offline HMAC (RFC 2104) with the RustCrypto-style `Mac`
//! surface used by this workspace: `Hmac<Sha256>` with
//! `new_from_slice` / `update` / `finalize().into_bytes()`.
//!
//! Only SHA-256 is supported; the generic parameter exists to keep the
//! call sites (`Hmac<Sha256>`) source-compatible with the real crate.

use std::marker::PhantomData;

use sha2::{Digest, Sha256};

/// Error for over-long keys; never produced (long keys are hashed).
#[derive(Debug, Clone, Copy)]
pub struct InvalidLength;

impl std::fmt::Display for InvalidLength {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("invalid HMAC key length")
    }
}

impl std::error::Error for InvalidLength {}

/// Finalized tag, convertible into a byte array like `CtOutput`.
pub struct Output([u8; 32]);

impl Output {
    pub fn into_bytes(self) -> [u8; 32] {
        self.0
    }
}

/// Message-authentication-code interface (subset of the `digest`
/// crate's `Mac`).
pub trait Mac: Sized {
    fn new_from_slice(key: &[u8]) -> Result<Self, InvalidLength>;
    fn update(&mut self, data: &[u8]);
    fn finalize(self) -> Output;
}

/// HMAC keyed with `D` (only `Sha256` is implemented offline).
pub struct Hmac<D> {
    inner: Sha256,
    opad_key: [u8; Sha256::BLOCK_SIZE],
    _digest: PhantomData<D>,
}

impl Mac for Hmac<Sha256> {
    fn new_from_slice(key: &[u8]) -> Result<Self, InvalidLength> {
        let mut block = [0u8; Sha256::BLOCK_SIZE];
        if key.len() > Sha256::BLOCK_SIZE {
            block[..32].copy_from_slice(&sha2::sha256(key));
        } else {
            block[..key.len()].copy_from_slice(key);
        }
        let mut ipad_key = block;
        let mut opad_key = block;
        for b in ipad_key.iter_mut() {
            *b ^= 0x36;
        }
        for b in opad_key.iter_mut() {
            *b ^= 0x5C;
        }
        let mut inner = Sha256::new();
        inner.update(ipad_key);
        Ok(Hmac { inner, opad_key, _digest: PhantomData })
    }

    fn update(&mut self, data: &[u8]) {
        self.inner.update(data);
    }

    fn finalize(self) -> Output {
        let inner_digest = self.inner.finalize();
        let mut outer = Sha256::new();
        outer.update(self.opad_key);
        outer.update(inner_digest);
        Output(outer.finalize())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mac(key: &[u8], msg: &[u8]) -> [u8; 32] {
        let mut m = <Hmac<Sha256> as Mac>::new_from_slice(key).unwrap();
        m.update(msg);
        m.finalize().into_bytes()
    }

    fn hex(bytes: &[u8]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    #[test]
    fn rfc4231_case_1() {
        // key = 0x0b * 20, data = "Hi There"
        let tag = mac(&[0x0b; 20], b"Hi There");
        assert_eq!(
            hex(&tag),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c\
             2e32cff7");
    }

    #[test]
    fn rfc4231_case_2() {
        // key = "Jefe", data = "what do ya want for nothing?"
        let tag = mac(b"Jefe", b"what do ya want for nothing?");
        assert_eq!(
            hex(&tag),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b9\
             64ec3843");
    }

    #[test]
    fn long_key_is_hashed() {
        // RFC 4231 case 6: 131-byte key, "Test Using Larger Than
        // Block-Size Key - Hash Key First"
        let tag = mac(&[0xaa; 131],
                      b"Test Using Larger Than Block-Size Key - Hash \
                        Key First");
        assert_eq!(
            hex(&tag),
            "60e431591ee0b67f0d8a26aacbf5b77f8e0bc6213728c5140546040f\
             0ee37f54");
    }

    #[test]
    fn incremental_update_matches() {
        let one = mac(b"key", b"hello world");
        let mut m = <Hmac<Sha256> as Mac>::new_from_slice(b"key").unwrap();
        m.update(b"hello ");
        m.update(b"world");
        assert_eq!(m.finalize().into_bytes(), one);
    }
}
