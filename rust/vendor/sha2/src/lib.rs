//! Minimal offline SHA-256 (FIPS 180-4) exposing the small slice of the
//! RustCrypto `sha2`/`digest` API this workspace uses: `Sha256`, the
//! `Digest` trait with `new`/`update`/`finalize`, and a `[u8; 32]`
//! output (which converts into itself and iterates like the real
//! `GenericArray` call sites expect).
//!
//! The compression function and constants are validated against
//! `hashlib` test vectors (see the known-answer tests below).

/// Streaming digest interface (subset of the `digest` crate's trait).
pub trait Digest: Sized {
    fn new() -> Self;
    fn update(&mut self, data: impl AsRef<[u8]>);
    fn finalize(self) -> [u8; 32];
}

const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f,
    0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b,
    0x59f111f1, 0x923f82a4, 0xab1c5ed5, 0xd807aa98, 0x12835b01,
    0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7,
    0xc19bf174, 0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc,
    0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da, 0x983e5152,
    0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147,
    0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc,
    0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819,
    0xd6990624, 0xf40e3585, 0x106aa070, 0x19a4c116, 0x1e376c08,
    0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f,
    0x682e6ff3, 0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208,
    0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// SHA-256 streaming state.
#[derive(Clone)]
pub struct Sha256 {
    state: [u32; 8],
    buf: [u8; 64],
    buf_len: usize,
    /// Total message bytes absorbed (pre-padding).
    total: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Digest::new()
    }
}

impl Sha256 {
    /// Hash block size in bytes (HMAC needs it).
    pub const BLOCK_SIZE: usize = 64;

    fn compress(state: &mut [u32; 8], block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for (i, chunk) in block.chunks_exact(4).enumerate() {
            w[i] = u32::from_be_bytes(chunk.try_into().unwrap());
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7)
                ^ w[i - 15].rotate_right(18)
                ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17)
                ^ w[i - 2].rotate_right(19)
                ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] =
            *state;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11)
                ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13)
                ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        state[0] = state[0].wrapping_add(a);
        state[1] = state[1].wrapping_add(b);
        state[2] = state[2].wrapping_add(c);
        state[3] = state[3].wrapping_add(d);
        state[4] = state[4].wrapping_add(e);
        state[5] = state[5].wrapping_add(f);
        state[6] = state[6].wrapping_add(g);
        state[7] = state[7].wrapping_add(h);
    }

    /// Absorb bytes without touching the message-length counter
    /// (used for the padding itself).
    fn absorb_raw(&mut self, mut data: &[u8]) {
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take]
                .copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                Self::compress(&mut self.state, &block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 64 {
            let block: &[u8; 64] = data[..64].try_into().unwrap();
            Self::compress(&mut self.state, block);
            data = &data[64..];
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }
}

impl Digest for Sha256 {
    fn new() -> Sha256 {
        Sha256 { state: H0, buf: [0u8; 64], buf_len: 0, total: 0 }
    }

    fn update(&mut self, data: impl AsRef<[u8]>) {
        let data = data.as_ref();
        self.total = self.total.wrapping_add(data.len() as u64);
        self.absorb_raw(data);
    }

    fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total.wrapping_mul(8);
        let pad_len = if self.buf_len < 56 {
            56 - self.buf_len
        } else {
            120 - self.buf_len
        };
        let mut pad = [0u8; 72];
        pad[0] = 0x80;
        self.absorb_raw(&pad[..pad_len]);
        self.absorb_raw(&bit_len.to_be_bytes());
        debug_assert_eq!(self.buf_len, 0);
        let mut out = [0u8; 32];
        for (i, word) in self.state.iter().enumerate() {
            out[4 * i..4 * i + 4].copy_from_slice(&word.to_be_bytes());
        }
        out
    }
}

/// One-shot convenience.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(data);
    h.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(bytes: &[u8]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    #[test]
    fn known_answers() {
        assert_eq!(
            hex(&sha256(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b\
             7852b855");
        assert_eq!(
            hex(&sha256(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61\
             f20015ad");
        assert_eq!(
            hex(&sha256(
                b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopno\
                  pq")),
            // NIST vector for the 56-byte message
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd4\
             19db06c1");
    }

    #[test]
    fn streaming_matches_oneshot() {
        let data: Vec<u8> = (0..100_000u32).map(|i| (i % 251) as u8)
            .collect();
        let mut h = Sha256::new();
        for chunk in data.chunks(977) {
            h.update(chunk);
        }
        assert_eq!(h.finalize(), sha256(&data));
    }

    #[test]
    fn boundary_lengths() {
        for len in [55usize, 56, 57, 63, 64, 65, 119, 120, 128] {
            let data = vec![0xABu8; len];
            let mut h = Sha256::new();
            h.update(&data[..len / 2]);
            h.update(&data[len / 2..]);
            assert_eq!(h.finalize(), sha256(&data), "len {len}");
        }
    }
}
