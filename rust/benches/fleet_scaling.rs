//! Fleet-scaling bench: the scenario the paper could not run — N
//! devices serving the same multi-model traffic, including *mixed*
//! CC/No-CC fleets where the encrypted-load penalty becomes a routing
//! trade-off instead of two separate experiments.
//!
//! Three sweeps over the calibrated DES:
//!  A. 1→8 devices (affinity placement) under fixed overload —
//!     throughput/attainment scaling and the saturation knee.
//!  B. CC:No-CC mix ratio on a 4-device fleet — how much of the CC
//!     penalty a mixed fleet absorbs, per placement policy.
//!  C. Placement policies head-to-head on a 2-device fleet — swaps,
//!     latency, attainment (affinity's swap avoidance vs the
//!     residency-blind baselines).

use std::path::PathBuf;

use sincere::config::RunConfig;
use sincere::coordinator::placement_names;
use sincere::engine::EngineBuilder;
use sincere::gpu::device::GpuConfig;
use sincere::runtime::Manifest;
use sincere::sim::CostModel;

fn base_cfg() -> RunConfig {
    let mut c = RunConfig::default();
    c.duration_s = 120.0;
    c.drain_s = c.sla_s;
    c.mean_rps = 18.0; // overload a single device; 8 devices absorb it
    c
}

fn main() {
    let artifacts = PathBuf::from("artifacts");
    let manifest = Manifest::load(&artifacts)
        .expect("run `make artifacts` first");
    let cm = CostModel::load_or_measure(
        &artifacts, &PathBuf::from("results/cost_model.json"),
        &GpuConfig::default(), 3).unwrap();
    let run = |c: &RunConfig| {
        EngineBuilder::new(c).des(&manifest, &cm).unwrap()
            .run().unwrap().0
    };
    let t0 = std::time::Instant::now();

    // ---------------- A: device-count scaling -------------------------
    println!("# Fleet scaling A — 1..8 devices (affinity, {} rps)\n",
             base_cfg().mean_rps);
    println!("| devices | done/gen | thr (rps) | attain % | lat p99 (s) \
              | swaps | fleet util % |");
    println!("|---|---|---|---|---|---|---|");
    for devices in 1..=8usize {
        let mut c = base_cfg();
        c.devices = devices;
        let s = run(&c);
        println!("| {} | {}/{} | {:.2} | {:.1} | {:.2} | {} | {:.1} |",
                 devices, s.completed, s.generated, s.throughput_rps,
                 s.sla_attainment * 100.0, s.latency_p99_s,
                 s.swap_count, s.gpu_util * 100.0);
    }

    // ---------------- B: CC:No-CC mix on 4 devices --------------------
    println!("\n# Fleet scaling B — CC:No-CC mix on 4 devices\n");
    println!("| cc devices | placement | thr (rps) | attain % | \
              lat p99 (s) | swaps | cc load s | no-cc load s |");
    println!("|---|---|---|---|---|---|---|---|");
    for cc_devices in 0..=4usize {
        let modes: Vec<&str> = (0..4)
            .map(|d| if d < cc_devices { "cc" } else { "no-cc" })
            .collect();
        for placement in ["affinity", "cc-aware"] {
            let mut c = base_cfg();
            c.devices = 4;
            c.set("device-modes", &modes.join(",")).unwrap();
            c.placement = placement.to_string();
            let s = run(&c);
            let load = |mode: &str| -> f64 {
                s.per_device.iter().filter(|d| d.mode == mode)
                    .map(|d| d.load_s).sum()
            };
            println!("| {} | {} | {:.2} | {:.1} | {:.2} | {} | {:.2} | \
                      {:.2} |",
                     cc_devices, placement, s.throughput_rps,
                     s.sla_attainment * 100.0, s.latency_p99_s,
                     s.swap_count, load("cc"), load("no-cc"));
        }
    }

    // ---------------- C: placement head-to-head -----------------------
    println!("\n# Fleet scaling C — placement policies, 2 devices\n");
    println!("| placement | swaps | lat mean (s) | attain % | \
              thr (rps) |");
    println!("|---|---|---|---|---|");
    for placement in placement_names() {
        let mut c = base_cfg();
        c.devices = 2;
        c.mean_rps = 9.0;
        c.placement = placement.to_string();
        let s = run(&c);
        println!("| {} | {} | {:.2} | {:.1} | {:.2} |", placement,
                 s.swap_count, s.latency_mean_s,
                 s.sla_attainment * 100.0, s.throughput_rps);
    }

    eprintln!("\n[fleet_scaling] swept in {:.2}s",
              t0.elapsed().as_secs_f64());
    println!("\nexpected shape: throughput scales with devices until \
              arrivals are absorbed; mixed fleets recover most of the \
              No-CC throughput once half the fleet is No-CC; affinity \
              swaps least.");
}
