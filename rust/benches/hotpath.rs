//! Hot-path micro-benchmarks — the §Perf L3 profile targets.
//!
//! Everything the scheduler touches per tick or per batch: strategy
//! decisions, queue ops, rate estimation, histogram recording,
//! tokenization, CC seal/open throughput, and unthrottled DMA.

use std::time::Duration;

use sincere::bench::Bench;
use sincere::coordinator::queues::ModelQueues;
use sincere::coordinator::rate::RateEstimator;
use sincere::coordinator::request::Request;
use sincere::coordinator::strategy::{strategy_by_name, strategy_names,
                                     DeviceView, ModelView, SchedContext};
use sincere::gpu::cc::CcSession;
use sincere::gpu::device::{GpuConfig, SimGpu};
use sincere::gpu::dma::Dir;
use sincere::gpu::CcMode;
use sincere::metrics::hist::Histogram;
use sincere::runtime::{ModelId, ModelTable};
use sincere::traffic::rng::Pcg64;
use sincere::workload::tokenizer::tokenize;

fn main() {
    let mut b = Bench::from_env(50, 2000);

    // ---- strategy decide over a realistic fleet context ----
    let ctx = SchedContext {
        now_s: 100.0,
        devices: (0..4).map(|d| DeviceView {
            id: d,
            mode: if d % 2 == 0 { CcMode::On } else { CcMode::Off },
            resident: (d == 0).then_some(ModelId(0)),
            busy: d == 3,
            busy_s: 10.0 + d as f64,
            dispatched: 40 + d as u64,
        }).collect(),
        queues: (0..3).map(|i| ModelView {
            model: ModelId(i as u32),
            len: 7 + i,
            oldest_wait_s: 1.5,
            obs: 16,
            rate_rps: 2.5,
            est_load_s: 0.5,
            est_exec_s: 0.3,
        }).collect(),
        sla_s: 6.0,
        timeout_s: 3.0,
    };
    for name in strategy_names() {
        let s = strategy_by_name(name).unwrap();
        b.run(&format!("decide/{name}"), || {
            std::hint::black_box(s.decide(&ctx));
        });
    }

    // ---- placement over the same fleet context ----
    let free: Vec<usize> = vec![0, 1, 2];
    for entry in sincere::coordinator::PLACEMENTS {
        let p = (entry.make)();
        b.run(&format!("place/{}", entry.name), || {
            std::hint::black_box(p.place(&ctx, &ctx.queues[0], &free));
        });
    }

    // ---- queue churn (steady state: one queue + one drain buffer,
    // reused — the engine's allocation-free protocol) ----
    const M: ModelId = ModelId(0);
    let mut q = ModelQueues::new(ModelTable::shared(["m"]));
    let mut drain: Vec<Request> = Vec::with_capacity(16);
    b.run("queues/push+pop batch of 16", || {
        for i in 0..16u64 {
            q.push(Request {
                id: i,
                model: M,
                tokens: vec![1; 16],
                arrival_s: i as f64,
                class: 0,
            });
        }
        drain.clear();
        q.pop_n_into(M, 16, &mut drain);
        std::hint::black_box(drain.len());
    });

    // ---- rate estimator ----
    let mut est = RateEstimator::default();
    let mut t = 0.0;
    b.run("rate/on_arrival+query", || {
        t += 0.25;
        est.on_arrival(M, t);
        std::hint::black_box(est.rate_rps(M, t));
    });

    // ---- histogram ----
    let mut h = Histogram::new();
    let mut rng = Pcg64::new(1);
    b.run("hist/record+p99", || {
        h.record(rng.next_f64() * 4.0);
        std::hint::black_box(h.quantile(0.99));
    });

    // ---- tokenizer ----
    let prompt = "Summarize the following invoice and flag anomalies \
                  regarding a cloud infrastructure migration item-1 \
                  item-2 item-3 item-4";
    b.run("tokenize/24w->16", || {
        std::hint::black_box(tokenize(prompt, 16, 512));
    });

    // ---- CC crypto throughput (1 MB chunks) ----
    let session = CcSession::establish(7).unwrap();
    let payload = vec![0xA5u8; 1 << 20];
    let mut crypto = Bench::from_env(3, 30);
    let r = crypto.run("cc/seal+open 1MB", || {
        let sealed = session.seal(&payload);
        std::hint::black_box(session.open(&sealed).unwrap());
    });
    let mbps = 1.0 / r.mean_s();
    println!("\nCC seal+open throughput: {mbps:.0} MB/s \
              (bounce-buffer roundtrip)");

    // ---- unthrottled DMA upload (crypto + copy, no bandwidth sleep) ----
    for mode in [CcMode::Off, CcMode::On] {
        let mut gpu = SimGpu::new(GpuConfig {
            mode, no_throttle: true, ..GpuConfig::default()
        }).unwrap();
        let blob = vec![0x5Au8; 4 << 20];
        let r = crypto.run(&format!("dma/upload 4MB {}", mode.as_str()),
                           || {
            let (buf, _) = gpu.upload(&blob).unwrap();
            gpu.free(buf);
        });
        println!("DMA upload 4MB ({}): {:.1} MB/s unthrottled",
                 mode.as_str(), 4.0 / r.mean_s());
    }

    // ---- io transfer small payload ----
    let mut gpu = SimGpu::new(GpuConfig {
        mode: CcMode::On, no_throttle: true, ..GpuConfig::default()
    }).unwrap();
    let io = vec![0u8; 16 * 66 * 4];
    crypto.run("io/seal 4KB request payload", || {
        gpu.io_transfer(Dir::HostToDevice, &io).unwrap();
    });

    b.print_table("scheduler hot paths");
    crypto.print_table("crypto / DMA hot paths");

    // sanity floor: a decide must stay well under the 2 ms tick
    for r in b.results() {
        if r.name.starts_with("decide/") {
            assert!(r.mean < Duration::from_micros(200),
                    "{} too slow: {:?}", r.name, r.mean);
        }
    }
}
