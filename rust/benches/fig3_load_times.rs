//! Fig 3 bench: model load times in No-CC vs CC vs pipelined CC, real
//! DMA path.
//!
//! The bandwidth throttle is ON — these are the calibrated load times
//! the scheduler actually experiences.  Also reports the crypto share
//! of each CC load split into *total* work and the *exposed* part the
//! chunk pipeline cannot hide (the paper's identified bottleneck, and
//! what the pipelined swap path recovers).

use std::path::PathBuf;

use sincere::bench::{fmt_dur, Bench};
use sincere::gpu::device::{GpuConfig, SimGpu};
use sincere::gpu::CcMode;
use sincere::runtime::{Manifest, Registry};

fn main() {
    let artifacts = PathBuf::from("artifacts");
    let manifest = Manifest::load(&artifacts)
        .expect("run `make artifacts` first");
    // batch-1 graphs only: loads don't involve executables
    let registry = Registry::load(&manifest, &[], &[1]).unwrap();
    let mut b = Bench::from_env(1, 5);
    let iters = b.iters;

    let cases: &[(&str, CcMode, usize)] = &[
        ("no-cc", CcMode::Off, 0),
        ("cc", CcMode::On, 0),
        ("cc+pipe2", CcMode::On, 2),
    ];

    println!("# Fig 3 — model loading times, No-CC vs CC vs pipelined CC\n");
    println!("| model | mode | mean load | p99 load | crypto total | \
              crypto exposed | unload |");
    println!("|---|---|---|---|---|---|---|");
    for name in registry.names() {
        let entry = registry.entry(&name).unwrap();
        for &(label, mode, depth) in cases {
            let mut gpu = SimGpu::new(GpuConfig {
                mode, pipeline_depth: depth, ..GpuConfig::default()
            }).unwrap();
            let mut samples = Vec::new();
            let mut crypto_total = 0.0;
            let mut crypto_exposed = 0.0;
            let mut unload_total = std::time::Duration::ZERO;
            for _ in 0..iters {
                let (buf, rep) = gpu.upload(&entry.weights.raw).unwrap();
                samples.push(rep.elapsed);
                crypto_total += rep.crypto_total.as_secs_f64();
                crypto_exposed += rep.crypto_exposed.as_secs_f64();
                unload_total += gpu.unload(buf);
            }
            let r = b.push_samples(&format!("{name} {label}"), samples);
            let mean_s = r.mean.as_secs_f64().max(1e-12);
            let total_share = crypto_total / iters as f64 / mean_s;
            let exposed_share = crypto_exposed / iters as f64 / mean_s;
            println!("| {} | {} | {} | {} | {:.0}% | {:.0}% | {} |", name,
                     label, fmt_dur(r.mean), fmt_dur(r.p99),
                     total_share * 100.0, exposed_share * 100.0,
                     fmt_dur(unload_total / iters as u32));
        }
    }
    b.print_table("raw load-time samples");
    println!("\nexpected shape: serialized CC ≈ 2.5–3× No-CC with all \
              crypto exposed; the pipeline hides most of the crypto, \
              pulling CC loads toward the link floor.");
}
