//! Fig 3 bench: model load times in CC vs No-CC, real DMA path.
//!
//! The bandwidth throttle is ON — these are the calibrated load times
//! the scheduler actually experiences.  Also reports the crypto share
//! of each CC load (the paper's identified bottleneck).

use std::path::PathBuf;

use sincere::bench::{fmt_dur, Bench};
use sincere::gpu::device::{GpuConfig, SimGpu};
use sincere::gpu::CcMode;
use sincere::runtime::{Manifest, Registry};

fn main() {
    let artifacts = PathBuf::from("artifacts");
    let manifest = Manifest::load(&artifacts)
        .expect("run `make artifacts` first");
    // batch-1 graphs only: loads don't involve executables
    let registry = Registry::load(&manifest, &[], &[1]).unwrap();
    let mut b = Bench::from_env(1, 5);
    let iters = b.iters;

    println!("# Fig 3 — model loading times, CC vs No-CC\n");
    println!("| model | mode | mean load | p99 load | crypto share | \
              unload |");
    println!("|---|---|---|---|---|---|");
    for name in registry.names() {
        let entry = registry.entry(&name).unwrap();
        for mode in [CcMode::Off, CcMode::On] {
            let mut gpu = SimGpu::new(GpuConfig {
                mode, ..GpuConfig::default()
            }).unwrap();
            let mut samples = Vec::new();
            let mut crypto_total = 0.0;
            let mut unload_total = std::time::Duration::ZERO;
            for _ in 0..iters {
                let (buf, rep) = gpu.upload(&entry.weights.raw).unwrap();
                samples.push(rep.elapsed);
                crypto_total += rep.crypto.as_secs_f64();
                unload_total += gpu.unload(buf);
            }
            let r = b.push_samples(
                &format!("{name} {}", mode.as_str()), samples);
            let crypto_share = crypto_total / iters as f64
                / r.mean.as_secs_f64().max(1e-12);
            println!("| {} | {} | {} | {} | {:.0}% | {} |", name,
                     mode.as_str(), fmt_dur(r.mean), fmt_dur(r.p99),
                     crypto_share * 100.0,
                     fmt_dur(unload_total / iters as u32));
        }
    }
    b.print_table("raw load-time samples");
}
