//! Fig 5 bench: latency and SLA attainment across traffic patterns,
//! SLAs and strategies, CC vs No-CC — the calibrated DES grid slice
//! behind the paper's central figure.

use std::path::PathBuf;

use sincere::config::{RunConfig, SLA_LADDER};
use sincere::coordinator::strategy_names;
use sincere::gpu::device::GpuConfig;
use sincere::gpu::CcMode;
use sincere::runtime::Manifest;
use sincere::engine::EngineBuilder;
use sincere::sim::CostModel;
use sincere::traffic::PATTERN_NAMES;

fn main() {
    let artifacts = PathBuf::from("artifacts");
    let manifest = Manifest::load(&artifacts)
        .expect("run `make artifacts` first");
    let cm = CostModel::load_or_measure(
        &artifacts, &PathBuf::from("results/cost_model.json"),
        &GpuConfig::default(), 3).unwrap();

    println!("# Fig 5 — latency and SLA attainment (DES, 120s cells, \
              4 rps)\n");
    println!("| pattern | strategy | SLA | CC lat (s) | No-CC lat (s) | \
              CC att % | No-CC att % |");
    println!("|---|---|---|---|---|---|---|");
    let t0 = std::time::Instant::now();
    let mut cells = 0;
    for pattern in PATTERN_NAMES {
        for strategy in strategy_names() {
            for &sla in SLA_LADDER {
                let mut out: Vec<(f64, f64)> = Vec::new(); // (lat, att)
                for mode in [CcMode::On, CcMode::Off] {
                    let mut c = RunConfig::default();
                    c.mode = mode;
                    c.gpu.mode = mode;
                    c.pattern = pattern.to_string();
                    c.strategy = strategy.to_string();
                    c.sla_s = sla;
                    c.duration_s = 120.0;
                    c.drain_s = sla;
                    let s = EngineBuilder::new(&c).des(&manifest, &cm).unwrap()
                        .run().unwrap().0;
                    out.push((s.latency_mean_s, s.sla_attainment));
                    cells += 1;
                }
                println!("| {} | {} | {} | {:.2} | {:.2} | {:.1} | \
                          {:.1} |", pattern, strategy, sla, out[0].0,
                         out[1].0, out[0].1 * 100.0, out[1].1 * 100.0);
            }
        }
    }
    eprintln!("\n[fig5] {} DES cells in {:.2}s", cells,
              t0.elapsed().as_secs_f64());
}
