//! Fig 6 bench: throughput comparison between CC and No-CC at the
//! tightest SLA (paper: SLA 40 ≙ scaled 4 s), by pattern and strategy,
//! plus the processing-rate-during-inference invariant (§IV-B: equal
//! across modes — the bottleneck is swapping, not inference).

use std::path::PathBuf;

use sincere::config::{RunConfig, SLA_LADDER};
use sincere::coordinator::strategy_names;
use sincere::gpu::device::GpuConfig;
use sincere::gpu::CcMode;
use sincere::runtime::Manifest;
use sincere::engine::EngineBuilder;
use sincere::sim::CostModel;
use sincere::traffic::PATTERN_NAMES;

fn main() {
    let artifacts = PathBuf::from("artifacts");
    let manifest = Manifest::load(&artifacts)
        .expect("run `make artifacts` first");
    let cm = CostModel::load_or_measure(
        &artifacts, &PathBuf::from("results/cost_model.json"),
        &GpuConfig::default(), 3).unwrap();
    let sla = SLA_LADDER[0];

    println!("# Fig 6 — throughput, CC vs No-CC (SLA {sla})\n");
    println!("| pattern | strategy | CC thr (rps) | No-CC thr (rps) | \
              No-CC gain | CC proc rate | No-CC proc rate |");
    println!("|---|---|---|---|---|---|---|");
    for pattern in PATTERN_NAMES {
        for strategy in strategy_names() {
            let run = |mode: CcMode| {
                let mut c = RunConfig::default();
                c.mode = mode;
                c.gpu.mode = mode;
                c.pattern = pattern.to_string();
                c.strategy = strategy.to_string();
                c.sla_s = sla;
                c.duration_s = 120.0;
                c.drain_s = sla;
                EngineBuilder::new(&c).des(&manifest, &cm).unwrap()
                        .run().unwrap().0
            };
            let cc = run(CcMode::On);
            let nc = run(CcMode::Off);
            println!("| {} | {} | {:.2} | {:.2} | {:+.0}% | {:.1} | \
                      {:.1} |", pattern, strategy, cc.throughput_rps,
                     nc.throughput_rps,
                     (nc.throughput_rps / cc.throughput_rps.max(1e-9)
                      - 1.0) * 100.0,
                     cc.processing_rate_rps, nc.processing_rate_rps);
        }
    }
    println!("\npaper shape: No-CC throughput 45–70% higher; processing \
              rate during inference ~equal across modes.");
}
