//! Fig 7 bench: GPU utilization, CC vs No-CC, with the time breakdown
//! answering the paper's "where is the remaining time spent?" —
//! loading dominates, unload + scheduling are small, both modes stay
//! below 50% utilization.

use std::path::PathBuf;

use sincere::config::RunConfig;
use sincere::gpu::device::GpuConfig;
use sincere::gpu::CcMode;
use sincere::runtime::Manifest;
use sincere::engine::EngineBuilder;
use sincere::sim::CostModel;
use sincere::traffic::PATTERN_NAMES;

fn main() {
    let artifacts = PathBuf::from("artifacts");
    let manifest = Manifest::load(&artifacts)
        .expect("run `make artifacts` first");
    let cm = CostModel::load_or_measure(
        &artifacts, &PathBuf::from("results/cost_model.json"),
        &GpuConfig::default(), 3).unwrap();

    println!("# Fig 7 — GPU utilization, CC vs No-CC\n");
    println!("| pattern | mode | util % | load % | crypto exp % | \
              unload % | idle+sched % | swaps |");
    println!("|---|---|---|---|---|---|---|---|");
    for pattern in PATTERN_NAMES {
        for mode in [CcMode::On, CcMode::Off] {
            let mut c = RunConfig::default();
            c.mode = mode;
            c.gpu.mode = mode;
            c.pattern = pattern.to_string();
            c.duration_s = 120.0;
            c.drain_s = c.sla_s;
            let s = EngineBuilder::new(&c).des(&manifest, &cm).unwrap()
                        .run().unwrap().0;
            let load_frac = s.total_load_s / s.runtime_s;
            // the exposed figure, not total crypto work: overlapped
            // crypto does not occupy the timeline
            let crypto_frac = s.total_crypto_exposed_s / s.runtime_s;
            let unload_frac = s.total_unload_s / s.runtime_s;
            let idle = 1.0 - s.gpu_util - load_frac - unload_frac;
            println!("| {} | {} | {:.1} | {:.1} | {:.2} | {:.2} | {:.1} \
                      | {} |",
                     pattern, s.mode, s.gpu_util * 100.0,
                     load_frac * 100.0, crypto_frac * 100.0,
                     unload_frac * 100.0,
                     idle.max(0.0) * 100.0, s.swap_count);
        }
    }
    println!("\npaper shape: No-CC utilization ≈50% higher than CC; both \
              below 50%; the gap is model-loading time.");
}
