//! Pipelined-swap bench: how much of the CC-vs-No-CC gap the chunk
//! pipeline and predictive prefetch recover.
//!
//! Two sweeps:
//!  A. Real DMA load times per model — serialized CC vs pipeline depth
//!     2/4 vs No-CC (throttled; the scheduler's actual regime), with
//!     the exposed-crypto share that remains.
//!  B. Calibrated DES serving runs — CC {serialized, pipelined,
//!     pipelined+prefetch} against the No-CC baseline: throughput,
//!     attainment, mean load, promotions.  The "recovered %" column is
//!     the share of the No-CC−CC throughput gap won back.

use std::path::PathBuf;

use sincere::bench::{fmt_dur, Bench};
use sincere::config::RunConfig;
use sincere::engine::EngineBuilder;
use sincere::gpu::device::{GpuConfig, SimGpu};
use sincere::gpu::CcMode;
use sincere::runtime::{Manifest, Registry};
use sincere::sim::CostModel;

fn main() {
    let artifacts = PathBuf::from("artifacts");
    let manifest = Manifest::load(&artifacts)
        .expect("run `make artifacts` first");
    let t0 = std::time::Instant::now();

    // ---------------- A: real DMA load times ---------------------------
    let registry = Registry::load(&manifest, &[], &[1]).unwrap();
    let mut b = Bench::from_env(1, 3);
    let iters = b.iters;
    let cases: &[(&str, CcMode, usize)] = &[
        ("no-cc", CcMode::Off, 0),
        ("cc serialized", CcMode::On, 0),
        ("cc pipe2", CcMode::On, 2),
        ("cc pipe4", CcMode::On, 4),
    ];
    println!("# Pipelined swap A — real DMA load times (throttled)\n");
    println!("| model | path | mean load | vs no-cc | crypto exposed % |");
    println!("|---|---|---|---|---|");
    for name in registry.names() {
        let entry = registry.entry(&name).unwrap();
        let mut nocc_mean = 0.0f64;
        for &(label, mode, depth) in cases {
            let mut gpu = SimGpu::new(GpuConfig {
                mode, pipeline_depth: depth, ..GpuConfig::default()
            }).unwrap();
            let mut samples = Vec::new();
            let mut exposed = 0.0;
            for _ in 0..iters {
                let (buf, rep) = gpu.upload(&entry.weights.raw).unwrap();
                samples.push(rep.elapsed);
                exposed += rep.crypto_exposed.as_secs_f64();
                gpu.unload(buf);
            }
            let r = b.push_samples(&format!("{name} {label}"), samples);
            let mean = r.mean.as_secs_f64();
            if mode == CcMode::Off {
                nocc_mean = mean;
            }
            println!("| {} | {} | {} | {:.2}x | {:.0}% |", name, label,
                     fmt_dur(r.mean), mean / nocc_mean.max(1e-12),
                     exposed / iters as f64 / mean.max(1e-12) * 100.0);
        }
    }

    // ---------------- B: DES serving, recovered throughput -------------
    let cm = CostModel::load_or_measure(
        &artifacts, &PathBuf::from("results/cost_model.json"),
        &GpuConfig::default(), 3).unwrap();
    let run = |mode: &str, depth: usize, prefetch: bool| {
        let mut c = RunConfig::default();
        c.set("mode", mode).unwrap();
        c.duration_s = 120.0;
        c.drain_s = c.sla_s;
        c.gpu.pipeline_depth = depth;
        c.prefetch = prefetch;
        EngineBuilder::new(&c).des(&manifest, &cm).unwrap()
            .run().unwrap().0
    };
    let nocc = run("no-cc", 0, false);
    let cc_serial = run("cc", 0, false);
    let cc_pipe = run("cc", 2, false);
    let cc_pipe_pf = run("cc", 2, true);

    let recovered = |thr: f64| -> f64 {
        let gap = nocc.throughput_rps - cc_serial.throughput_rps;
        if gap.abs() < 1e-12 {
            0.0
        } else {
            (thr - cc_serial.throughput_rps) / gap * 100.0
        }
    };
    println!("\n# Pipelined swap B — DES serving, CC gap recovery\n");
    println!("| cell | thr (rps) | recovered % | attain % | mean load \
              (s) | swaps | promoted | crypto exposed (s) |");
    println!("|---|---|---|---|---|---|---|---|");
    for (label, s) in [("no-cc", &nocc),
                       ("cc serialized", &cc_serial),
                       ("cc pipe2", &cc_pipe),
                       ("cc pipe2+prefetch", &cc_pipe_pf)] {
        println!("| {} | {:.2} | {:.0} | {:.1} | {:.2} | {} | {} | \
                  {:.2} |",
                 label, s.throughput_rps, recovered(s.throughput_rps),
                 s.sla_attainment * 100.0, s.mean_load_s, s.swap_count,
                 s.promoted_count, s.total_crypto_exposed_s);
    }

    eprintln!("\n[pipelined_swap] swept in {:.2}s",
              t0.elapsed().as_secs_f64());
    println!("\nexpected shape: pipelining alone pulls CC loads toward \
              the link floor (recovering a large share of the \
              throughput gap); prefetch promotions then hide entire \
              loads behind execution, while No-CC cells are untouched \
              by either knob.");
}
