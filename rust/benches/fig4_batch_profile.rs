//! Fig 4 bench: inference throughput vs batch size (OBS discovery),
//! real PJRT execution per (family, batch) artifact, plus the OOM
//! boundary from the device memory model.

use std::path::PathBuf;
use std::time::Instant;

use sincere::bench::Bench;
use sincere::gpu::device::GpuConfig;
use sincere::runtime::{Manifest, Registry};

fn main() {
    let artifacts = PathBuf::from("artifacts");
    let manifest = Manifest::load(&artifacts)
        .expect("run `make artifacts` first");
    eprintln!("[fig4] compiling all executables ...");
    let registry = Registry::load(&manifest, &[], &[]).unwrap();
    let gpu_cfg = GpuConfig::default();
    let mut b = Bench::from_env(1, 5);
    let iters = b.iters;

    println!("# Fig 4 — inference throughput vs batch size\n");
    println!("| model | batch | mean exec (s) | throughput (req/s) | \
              note |");
    println!("|---|---|---|---|---|");
    for name in registry.names() {
        let entry = registry.entry(&name).unwrap();
        let mut measured: Vec<(usize, f64)> = Vec::new();
        let mut oom: Vec<(usize, u64)> = Vec::new();
        for &batch in entry.spec.batch_sizes().iter() {
            let need = entry.spec.weight_bytes()
                + entry.spec.batch_workspace_bytes(batch);
            if need > gpu_cfg.hbm_capacity {
                oom.push((batch, need));
                continue;
            }
            let rows: Vec<Vec<i32>> = (0..batch).map(|i| {
                (0..entry.spec.prompt_len)
                    .map(|j| ((i * 13 + j * 5) % entry.spec.vocab) as i32)
                    .collect()
            }).collect();
            registry.execute(&name, &rows).unwrap(); // warmup
            let mut samples = Vec::new();
            for _ in 0..iters {
                let t0 = Instant::now();
                registry.execute(&name, &rows).unwrap();
                samples.push(t0.elapsed());
            }
            let r = b.push_samples(&format!("{name} b{batch}"), samples);
            measured.push((batch, r.mean_s()));
        }
        let obs = measured.iter()
            .max_by(|a, b| (a.0 as f64 / a.1)
                    .partial_cmp(&(b.0 as f64 / b.1)).unwrap())
            .map(|&(b, _)| b).unwrap_or(0);
        for (batch, exec_s) in &measured {
            println!("| {} | {} | {:.3} | {:.2} | {} |", name, batch,
                     exec_s, *batch as f64 / exec_s,
                     if *batch == obs { "**OBS**" } else { "" });
        }
        for (batch, need) in &oom {
            println!("| {} | {} | — | — | OOM ({:.1} MB > {:.1} MB HBM) |",
                     name, batch, *need as f64 / 1e6,
                     gpu_cfg.hbm_capacity as f64 / 1e6);
        }
    }
    b.print_table("raw execution samples");
}
