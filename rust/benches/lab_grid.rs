//! Lab-runner scaling bench: the paper's 72-cell grid priced from the
//! synthetic cost table, swept across worker thread counts.
//!
//! Two tables: wall time + cells/second vs `--threads` (the
//! work-stealing pool's speedup over the old serial sweep), and a
//! byte-identity check confirming that parallelism never changes the
//! results the tables are built from.

use sincere::config::RunConfig;
use sincere::lab::{self, LabRunner};
use sincere::runtime::Manifest;
use sincere::sim::CostModel;

fn main() {
    let manifest = Manifest::load(&std::path::PathBuf::from("artifacts"))
        .expect("run `make artifacts` first");
    let cm = CostModel::synthetic(&manifest);

    // paper-72: the headline grid; tenancy: the hot-path stressor
    // (multi-tenant catalog + Zipf + classes, far more requests per
    // cell) whose sim-req/s is the trajectory figure BENCH_*.json pins
    for preset in ["paper-72", "tenancy"] {
        let spec = lab::preset_by_name(preset).unwrap();
        let grid = spec.expand(&RunConfig::default()).unwrap();
        let jobs = grid.jobs(grid.seeds);
        println!("# Lab grid scaling [{preset}] — {} cells x {} \
                  seed(s)\n",
                 grid.cells.len(), grid.seeds);

        println!("| threads | wall (s) | cells/s | sim req/s | \
                  speedup vs 1 |");
        println!("|---|---|---|---|---|");
        let mut serial_s = 0.0f64;
        let mut baseline: Option<String> = None;
        for threads in [1usize, 2, 4, 8] {
            let t0 = std::time::Instant::now();
            let cells = LabRunner::new(&manifest, &cm)
                .threads(threads).quiet(true).run(&jobs).unwrap();
            let wall = t0.elapsed().as_secs_f64();
            if threads == 1 {
                serial_s = wall;
            }
            let bytes = lab::run_to_json(&cells).to_string();
            match &baseline {
                None => baseline = Some(bytes),
                Some(b) => assert_eq!(
                    *b, bytes,
                    "{preset}: {threads} threads changed the output \
                     bytes"),
            }
            // simulated request volume the pool pushed through per
            // wall second — the grid-level analogue of cells/s
            let sim_reqs: u64 = cells.iter().map(|c| c.generated).sum();
            println!("| {} | {:.3} | {:.1} | {:.0} | {:.2}x |", threads,
                     wall, jobs.len() as f64 / wall.max(1e-9),
                     sim_reqs as f64 / wall.max(1e-9),
                     serial_s / wall.max(1e-9));
        }
        println!();
    }

    println!("expected shape: near-linear speedup until the core \
              count, identical output bytes throughout.");
}
