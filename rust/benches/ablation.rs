//! Ablations over the design choices DESIGN.md §8 calls out:
//!
//!  A. CC/No-CC link-bandwidth ratio → headline throughput/latency gaps
//!     (how sensitive is the paper's story to the encrypted-PCIe
//!     slowdown?).
//!  B. Timer timeout fraction → SLA attainment vs swap count (the
//!     latency/throughput dial inside every timer strategy).
//!  C. Bounce-buffer chunk size → real crypto throughput (the CC DMA
//!     hot path; measured, not simulated).

use std::path::PathBuf;

use sincere::config::RunConfig;
use sincere::gpu::cc::CcSession;
use sincere::gpu::CcMode;
use sincere::runtime::Manifest;
use sincere::engine::EngineBuilder;
use sincere::sim::CostModel;

fn main() {
    let artifacts = PathBuf::from("artifacts");
    let manifest = Manifest::load(&artifacts)
        .expect("run `make artifacts` first");
    let base_cm = CostModel::load_or_measure(
        &artifacts, &PathBuf::from("results/cost_model.json"),
        &Default::default(), 3).unwrap();

    // ---------------- A: CC slowdown ratio -----------------------------
    println!("# Ablation A — CC/No-CC load-time ratio (DES, gamma, \
              select-batch+timer, SLA 12)\n");
    println!("| CC/No-CC load ratio | CC thr (rps) | No-CC thr (rps) | \
              thr gap | CC att % | CC lat (s) |");
    println!("|---|---|---|---|---|---|");
    for ratio in [1.0, 1.5, 2.0, 2.73, 4.0, 6.0] {
        let mut cm = base_cm.clone();
        for mc in cm.models.values_mut() {
            mc.load_s_cc = mc.load_s_plain * ratio;
        }
        let run = |mode: CcMode| {
            let mut c = RunConfig::default();
            c.mode = mode;
            c.gpu.mode = mode;
            c.sla_s = 12.0;
            EngineBuilder::new(&c).des(&manifest, &cm).unwrap()
                        .run().unwrap().0
        };
        let cc = run(CcMode::On);
        let nc = run(CcMode::Off);
        println!("| {ratio:.2}x | {:.2} | {:.2} | {:+.0}% | {:.1} | \
                  {:.2} |",
                 cc.throughput_rps, nc.throughput_rps,
                 (nc.throughput_rps / cc.throughput_rps.max(1e-9) - 1.0)
                 * 100.0,
                 cc.sla_attainment * 100.0, cc.latency_mean_s);
    }
    println!("\nAt ratio 1.0 the modes must coincide (sanity); the \
              paper's ~2.7x encrypted-transfer slowdown sits where the \
              throughput gap enters the 45-70% band.\n");

    // ---------------- B: timer timeout fraction -------------------------
    println!("# Ablation B — timer timeout as a fraction of the SLA \
              (CC, gamma, best-batch+timer, SLA 18)\n");
    println!("| timeout frac | att % | thr (rps) | swaps | lat (s) |");
    println!("|---|---|---|---|---|");
    for frac in [0.2, 0.35, 0.5, 0.65, 0.8] {
        let mut c = RunConfig::default();
        c.mode = CcMode::On;
        c.gpu.mode = CcMode::On;
        c.strategy = "best-batch+timer".into();
        c.timeout_frac = frac;
        let s = EngineBuilder::new(&c).des(&manifest, &base_cm)
            .unwrap().run().unwrap().0;
        println!("| {frac:.2} | {:.1} | {:.2} | {} | {:.2} |",
                 s.sla_attainment * 100.0, s.throughput_rps,
                 s.swap_count, s.latency_mean_s);
    }
    println!("\nTighter timers dispatch smaller batches sooner: more \
              swaps, lower throughput — the Table I trade-off.\n");

    // ---------------- C: bounce-buffer size (real crypto) ---------------
    println!("# Ablation C — bounce-buffer chunk size vs CC crypto \
              throughput (measured)\n");
    println!("| chunk | seal+open MB/s |");
    println!("|---|---|");
    let session = CcSession::establish(7).unwrap();
    let payload = vec![0xA5u8; 4 << 20];
    for chunk_kb in [16usize, 64, 256, 1024] {
        let chunk = chunk_kb * 1024;
        let iters = 5;
        let t0 = std::time::Instant::now();
        let mut sealed = Vec::new();
        let mut dst = vec![0u8; chunk];
        for _ in 0..iters {
            for part in payload.chunks(chunk) {
                session.seal_into(part, &mut sealed);
                session.open_into(&sealed, &mut dst[..part.len()])
                    .unwrap();
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        let mbps = (4.0 * iters as f64) / secs;
        println!("| {chunk_kb} KiB | {mbps:.0} |");
    }
    println!("\nThroughput is flat above ~64 KiB chunks: per-chunk \
              overheads (nonce, tag, HMAC finalization) amortize out, \
              so the 256 KiB default is not a bottleneck.");
}
