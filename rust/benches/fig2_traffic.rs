//! Fig 2 bench: traffic-generator performance and equal-mean check.
//!
//! Regenerates the Fig 2 data (arrival series per pattern at equal mean)
//! and benchmarks schedule generation throughput.

use sincere::bench::Bench;
use sincere::traffic::rng::Pcg64;
use sincere::traffic::{pattern_by_name, PATTERN_NAMES};

fn main() {
    let models = vec!["llama-sim".to_string(), "gemma-sim".to_string(),
                      "granite-sim".to_string()];
    let mut b = Bench::from_env(3, 30);

    println!("# Fig 2 — input traffic distributions (mean 4 rps)");
    println!("\n| pattern | arrivals/600s | realized rps | max 10s-window \
              rps | min 10s-window rps |");
    println!("|---|---|---|---|---|");
    for name in PATTERN_NAMES {
        let p = pattern_by_name(name).unwrap();
        let mut rng = Pcg64::new(7);
        let arr = p.generate(600.0, 4.0, &models, &mut rng);
        let mut win = [0usize; 60];
        for a in &arr {
            win[(a.at_s / 10.0) as usize % 60] += 1;
        }
        println!("| {} | {} | {:.2} | {:.1} | {:.1} |", name, arr.len(),
                 arr.len() as f64 / 600.0,
                 *win.iter().max().unwrap() as f64 / 10.0,
                 *win.iter().min().unwrap() as f64 / 10.0);
    }

    for name in PATTERN_NAMES {
        let p = pattern_by_name(name).unwrap();
        let mut rng = Pcg64::new(7);
        b.run(&format!("generate 600s@4rps {name}"), || {
            let arr = p.generate(600.0, 4.0, &models, &mut rng);
            std::hint::black_box(arr);
        });
    }
    b.print_table("generator micro-bench");
}
