//! # sincere — relaxed batch LLM inference on a (simulated) confidential GPU
//!
//! Reproduction of *“Performance of Confidential Computing GPUs”*
//! (Martínez Ibarra et al., IEEE cs.PF 2025): a single-VM, single-GPU
//! serving system that multiplexes several LLMs on one device, swapping
//! models in and out of GPU memory under relaxed-inference SLAs, and the
//! CC-vs-No-CC comparison built on top of it.
//!
//! The crate is Layer 3 of a three-layer stack:
//!
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`), the
//!   transformer hot path, lowered at build time.
//! * **Layer 2** — the JAX decoder-only transformer
//!   (`python/compile/model.py`), AOT-lowered per (family, batch size) to
//!   HLO text artifacts.
//! * **Layer 3** — this crate: the PJRT runtime that compiles and executes
//!   those artifacts, the confidential-GPU device model (HBM allocator,
//!   DMA engine, AES-CTR+HMAC bounce buffers, attestation), the paper's
//!   scheduler/batcher/swap-manager, traffic generation, metrics, and the
//!   [`engine`] — the single serve loop behind both the real wall-clock
//!   path and the calibrated discrete-event mode (pluggable `Clock` +
//!   `ExecBackend`; see `DESIGN.md`).
//!
//! Python never runs at serve time: once `make artifacts` has produced
//! `artifacts/`, the `sincere` binary is self-contained.
//!
//! See `DESIGN.md` for the paper-to-module map and `EXPERIMENTS.md` for
//! the reproduced tables and figures.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod gpu;
pub mod lab;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod sim;
pub mod tenancy;
pub mod traffic;
pub mod util;
pub mod workload;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
