//! Ramp traffic (§III-C1): "gradually increases to a peak before
//! tapering off" — scheduled pipelines and system warm-ups.
//!
//! A triangular rate profile r(t) peaking at `peak_frac * duration`;
//! the peak height is 2x the mean so the time-integral equals
//! mean_rps * duration (§III-C2).  Arrivals are drawn from the
//! inhomogeneous Poisson process with rate r(t) via thinning.

use crate::traffic::{dist, finalize, pick_model, rng::Pcg64, Arrival,
                     TrafficPattern};

pub struct RampPattern {
    /// Where the peak sits, as a fraction of the duration (0, 1).
    pub peak_frac: f64,
}

impl Default for RampPattern {
    fn default() -> Self {
        RampPattern { peak_frac: 0.5 }
    }
}

impl RampPattern {
    /// Instantaneous rate at time t for the triangular profile.
    fn rate_at(&self, t: f64, duration_s: f64, mean_rps: f64) -> f64 {
        let peak_t = self.peak_frac * duration_s;
        let peak_rate = 2.0 * mean_rps; // triangle area == mean * duration
        if t <= peak_t {
            peak_rate * (t / peak_t.max(1e-9))
        } else {
            peak_rate * ((duration_s - t) / (duration_s - peak_t).max(1e-9))
        }
    }
}

impl TrafficPattern for RampPattern {
    fn name(&self) -> &'static str {
        "ramp"
    }

    fn generate(&self, duration_s: f64, mean_rps: f64, models: &[String],
                rng: &mut Pcg64) -> Vec<Arrival> {
        assert!(mean_rps > 0.0 && !models.is_empty());
        let lambda_max = 2.0 * mean_rps;
        let mut out = Vec::with_capacity((duration_s * mean_rps) as usize);
        let mut t = 0.0;
        // Lewis–Shedler thinning against the constant majorant
        while t < duration_s {
            t += dist::exponential(rng, lambda_max);
            if t >= duration_s {
                break;
            }
            let accept = rng.next_f64()
                < self.rate_at(t, duration_s, mean_rps) / lambda_max;
            if accept {
                out.push(Arrival { at_s: t, model: pick_model(models, rng) });
            }
        }
        finalize(out, duration_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_rises_then_falls() {
        let mut rng = Pcg64::new(6);
        let p = RampPattern::default();
        let dur = 600.0;
        let arr = p.generate(dur, 4.0, &["m".to_string()], &mut rng);
        // quarter-window counts: middle half must dominate the edges
        let count = |lo: f64, hi: f64| {
            arr.iter().filter(|a| a.at_s >= lo && a.at_s < hi).count()
        };
        let q = dur / 4.0;
        let first = count(0.0, q);
        let middle = count(q, 3.0 * q);
        let last = count(3.0 * q, dur);
        assert!(middle as f64 > 1.3 * (first + last) as f64,
                "triangle shape violated: {first} {middle} {last}");
    }

    #[test]
    fn peak_rate_is_double_mean() {
        let p = RampPattern::default();
        let peak = p.rate_at(300.0, 600.0, 4.0);
        assert!((peak - 8.0).abs() < 1e-9);
        assert_eq!(p.rate_at(0.0, 600.0, 4.0), 0.0);
    }
}
