//! Probability distributions used by the traffic generators.
//!
//! Gamma sampling via Marsaglia–Tsang (2000) with the Ahrens–Dieter
//! boost for shape < 1; exponential via inverse CDF; Poisson via
//! Knuth/inversion (small mean) or PTRS-free normal approximation
//! fallback for large mean.

use crate::traffic::rng::Pcg64;

/// Standard normal via Box–Muller (polar form avoided; the cached-pair
/// variant would make the generator stateful).
pub fn normal(rng: &mut Pcg64) -> f64 {
    let u1 = rng.next_f64_open();
    let u2 = rng.next_f64();
    (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
}

/// Exponential with the given rate (mean 1/rate).
pub fn exponential(rng: &mut Pcg64, rate: f64) -> f64 {
    assert!(rate > 0.0);
    -rng.next_f64_open().ln() / rate
}

/// Gamma(shape k, scale θ) — Marsaglia–Tsang squeeze method.
pub fn gamma(rng: &mut Pcg64, shape: f64, scale: f64) -> f64 {
    assert!(shape > 0.0 && scale > 0.0);
    if shape < 1.0 {
        // boost: Gamma(k) = Gamma(k+1) * U^(1/k)
        let u = rng.next_f64_open();
        return gamma(rng, shape + 1.0, scale) * u.powf(1.0 / shape);
    }
    let d = shape - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = normal(rng);
        let v = 1.0 + c * x;
        if v <= 0.0 {
            continue;
        }
        let v3 = v * v * v;
        let u = rng.next_f64_open();
        if u < 1.0 - 0.0331 * x * x * x * x {
            return d * v3 * scale;
        }
        if u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln()) {
            return d * v3 * scale;
        }
    }
}

/// Poisson with the given mean.
pub fn poisson(rng: &mut Pcg64, mean: f64) -> u64 {
    assert!(mean >= 0.0);
    if mean == 0.0 {
        return 0;
    }
    if mean < 30.0 {
        // Knuth: product of uniforms
        let l = (-mean).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= rng.next_f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }
    // normal approximation for large mean (adequate for burst sizing)
    let x = mean + mean.sqrt() * normal(rng);
    x.max(0.0).round() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_stats(mut f: impl FnMut(&mut Pcg64) -> f64, n: usize)
                    -> (f64, f64) {
        let mut rng = Pcg64::new(1234);
        let xs: Vec<f64> = (0..n).map(|_| f(&mut rng)).collect();
        (crate::util::mean(&xs), crate::util::stddev(&xs))
    }

    #[test]
    fn normal_moments() {
        let (m, s) = sample_stats(normal, 200_000);
        assert!(m.abs() < 0.01, "mean {m}");
        assert!((s - 1.0).abs() < 0.01, "std {s}");
    }

    #[test]
    fn exponential_moments() {
        let (m, s) = sample_stats(|r| exponential(r, 4.0), 200_000);
        assert!((m - 0.25).abs() < 0.005, "mean {m}");
        assert!((s - 0.25).abs() < 0.01, "std {s}");
    }

    #[test]
    fn gamma_moments_shape_above_one() {
        // Gamma(k=2, θ=3): mean 6, var 18
        let (m, s) = sample_stats(|r| gamma(r, 2.0, 3.0), 200_000);
        assert!((m - 6.0).abs() < 0.1, "mean {m}");
        assert!((s - 18f64.sqrt()).abs() < 0.1, "std {s}");
    }

    #[test]
    fn gamma_moments_shape_below_one() {
        // Gamma(k=0.5, θ=2): mean 1, var 2 — the irregular/spiky regime
        // the paper's gamma traffic uses.
        let (m, s) = sample_stats(|r| gamma(r, 0.5, 2.0), 200_000);
        assert!((m - 1.0).abs() < 0.05, "mean {m}");
        assert!((s - 2f64.sqrt()).abs() < 0.05, "std {s}");
    }

    #[test]
    fn gamma_always_positive() {
        let mut rng = Pcg64::new(5);
        for _ in 0..10_000 {
            assert!(gamma(&mut rng, 0.3, 1.0) > 0.0);
        }
    }

    #[test]
    fn poisson_small_mean() {
        let mut rng = Pcg64::new(6);
        let n = 100_000;
        let mean = 3.5;
        let total: u64 = (0..n).map(|_| poisson(&mut rng, mean)).sum();
        let m = total as f64 / n as f64;
        assert!((m - mean).abs() < 0.05, "mean {m}");
    }

    #[test]
    fn poisson_large_mean_normal_path() {
        let mut rng = Pcg64::new(7);
        let n = 50_000;
        let mean = 100.0;
        let total: u64 = (0..n).map(|_| poisson(&mut rng, mean)).sum();
        let m = total as f64 / n as f64;
        assert!((m - mean).abs() < 0.5, "mean {m}");
    }

    #[test]
    fn poisson_zero_mean() {
        let mut rng = Pcg64::new(8);
        assert_eq!(poisson(&mut rng, 0.0), 0);
    }
}
