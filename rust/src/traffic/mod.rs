//! Input-traffic generation: the paper's three arrival distributions
//! (§III-C1) plus trace emit/replay.
//!
//! All generators are normalized to the *same mean requests/second* over
//! the experiment duration (§III-C2, Fig 2) so CC-vs-No-CC and
//! cross-pattern comparisons see identical load.

pub mod bursty;
pub mod compose;
pub mod dist;
pub mod gamma;
pub mod ramp;
pub mod rng;
pub mod trace;

use crate::traffic::rng::Pcg64;

/// One scheduled request arrival, produced ahead of time (open-loop).
#[derive(Debug, Clone, PartialEq)]
pub struct Arrival {
    /// Offset from experiment start, seconds.
    pub at_s: f64,
    /// Which model family this request targets.
    pub model: String,
}

/// A named arrival-pattern generator.
pub trait TrafficPattern {
    /// Pattern name as used in CLI/CSV ("gamma" | "bursty" | "ramp").
    fn name(&self) -> &'static str;

    /// Generate the full arrival schedule for `duration_s` seconds at
    /// `mean_rps` mean requests/second, assigning each request a model
    /// drawn uniformly from `models`.  Arrivals are sorted by time.
    fn generate(&self, duration_s: f64, mean_rps: f64, models: &[String],
                rng: &mut Pcg64) -> Vec<Arrival>;
}

/// Instantiate a pattern by name.
pub fn pattern_by_name(name: &str) -> anyhow::Result<Box<dyn TrafficPattern>> {
    match name {
        "gamma" => Ok(Box::new(gamma::GammaPattern::default())),
        "bursty" => Ok(Box::new(bursty::BurstyPattern::default())),
        "ramp" => Ok(Box::new(ramp::RampPattern::default())),
        other => anyhow::bail!("unknown traffic pattern {other:?} \
                                (have gamma|bursty|ramp)"),
    }
}

pub const PATTERN_NAMES: &[&str] = &["gamma", "bursty", "ramp"];

/// Assign a model uniformly at random.
pub(crate) fn pick_model(models: &[String], rng: &mut Pcg64) -> String {
    models[(rng.next_u64() as usize) % models.len()].clone()
}

/// Clamp + sort arrivals into [0, duration) and enforce ordering.
pub(crate) fn finalize(mut arrivals: Vec<Arrival>, duration_s: f64)
                       -> Vec<Arrival> {
    arrivals.retain(|a| a.at_s >= 0.0 && a.at_s < duration_s);
    arrivals.sort_by(|a, b| a.at_s.partial_cmp(&b.at_s).unwrap());
    arrivals
}

#[cfg(test)]
mod tests {
    use super::*;

    fn models() -> Vec<String> {
        vec!["llama-sim".into(), "gemma-sim".into()]
    }

    /// §III-C2: every pattern must deliver the same mean rate.  Bursty
    /// traffic has ~32 s on/off cycles, so the averaging horizon must
    /// cover many cycles for the duty-cycle normalization to show.
    #[test]
    fn equal_mean_normalization() {
        let mut rng = Pcg64::new(7);
        for name in PATTERN_NAMES {
            let p = pattern_by_name(name).unwrap();
            let dur = 2400.0;
            let arr = p.generate(dur, 4.0, &models(), &mut rng);
            let rate = arr.len() as f64 / dur;
            assert!((rate - 4.0).abs() / 4.0 < 0.12,
                    "{name}: rate {rate} != 4.0");
        }
    }

    #[test]
    fn arrivals_sorted_and_in_range() {
        let mut rng = Pcg64::new(8);
        for name in PATTERN_NAMES {
            let p = pattern_by_name(name).unwrap();
            let arr = p.generate(60.0, 2.0, &models(), &mut rng);
            for w in arr.windows(2) {
                assert!(w[0].at_s <= w[1].at_s, "{name} not sorted");
            }
            assert!(arr.iter().all(|a| (0.0..60.0).contains(&a.at_s)));
        }
    }

    #[test]
    fn model_assignment_covers_fleet() {
        let mut rng = Pcg64::new(9);
        let p = pattern_by_name("gamma").unwrap();
        let arr = p.generate(120.0, 4.0, &models(), &mut rng);
        for m in models() {
            assert!(arr.iter().any(|a| a.model == m), "missing {m}");
        }
    }

    #[test]
    fn unknown_pattern_rejected() {
        assert!(pattern_by_name("poisson-ish").is_err());
    }

    #[test]
    fn zero_duration_empty() {
        let mut rng = Pcg64::new(1);
        for name in PATTERN_NAMES {
            let p = pattern_by_name(name).unwrap();
            assert!(p.generate(0.0, 4.0, &models(), &mut rng).is_empty());
        }
    }
}
