//! Arrival-trace emit/replay — the analogue of the paper's Instructlab
//! jsonl → json request files (§III-A step 1).
//!
//! A trace is a jsonl file with one arrival per line:
//! `{"at_s": 1.25, "model": "llama-sim", "prompt": "..."}`.
//! Traces make experiments exactly repeatable across modes: the same
//! trace is replayed in CC and No-CC so both see identical load.

use std::io::{BufRead, Write};
use std::path::Path;

use crate::traffic::Arrival;
use crate::util::json::Json;
use crate::workload::promptgen::PromptGen;

/// Write arrivals (with generated prompts) as a jsonl trace.
pub fn write_trace(path: &Path, arrivals: &[Arrival],
                   prompts: &mut PromptGen) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for a in arrivals {
        let line = Json::obj(vec![
            ("at_s", Json::num(a.at_s)),
            ("model", Json::str(a.model.clone())),
            ("prompt", Json::str(prompts.next_prompt(&a.model))),
        ]);
        writeln!(f, "{line}")?;
    }
    f.flush()?;
    Ok(())
}

/// One replayed trace entry.
#[derive(Debug, Clone)]
pub struct TraceEntry {
    pub at_s: f64,
    pub model: String,
    pub prompt: String,
}

/// Read a jsonl trace back.
pub fn read_trace(path: &Path) -> anyhow::Result<Vec<TraceEntry>> {
    let f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("opening trace {path:?}: {e}"))?;
    let mut out = Vec::new();
    for (i, line) in std::io::BufReader::new(f).lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(&line)
            .map_err(|e| anyhow::anyhow!("trace line {}: {e}", i + 1))?;
        out.push(TraceEntry {
            at_s: j.req("at_s")?.as_f64()
                .ok_or_else(|| anyhow::anyhow!("at_s not a number"))?,
            model: j.req("model")?.as_str()
                .ok_or_else(|| anyhow::anyhow!("model not a string"))?
                .to_string(),
            prompt: j.req("prompt")?.as_str().unwrap_or_default().to_string(),
        });
    }
    anyhow::ensure!(out.windows(2).all(|w| w[0].at_s <= w[1].at_s),
                    "trace not sorted by at_s");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traffic::rng::Pcg64;
    use crate::traffic::pattern_by_name;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("sincere_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");

        let mut rng = Pcg64::new(11);
        let p = pattern_by_name("gamma").unwrap();
        let arr = p.generate(30.0, 2.0, &["llama-sim".to_string()], &mut rng);
        let mut pg = PromptGen::new(42, 16);
        write_trace(&path, &arr, &mut pg).unwrap();

        let back = read_trace(&path).unwrap();
        assert_eq!(back.len(), arr.len());
        for (a, b) in arr.iter().zip(&back) {
            assert!((a.at_s - b.at_s).abs() < 1e-9);
            assert_eq!(a.model, b.model);
            assert!(!b.prompt.is_empty());
        }
    }

    #[test]
    fn rejects_unsorted() {
        let dir = std::env::temp_dir().join("sincere_trace_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.jsonl");
        std::fs::write(&path,
            "{\"at_s\":2.0,\"model\":\"m\",\"prompt\":\"x\"}\n\
             {\"at_s\":1.0,\"model\":\"m\",\"prompt\":\"y\"}\n").unwrap();
        assert!(read_trace(&path).is_err());
    }
}
