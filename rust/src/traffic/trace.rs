//! Arrival-trace emit/replay — the analogue of the paper's Instructlab
//! jsonl → json request files (§III-A step 1).
//!
//! A trace is a jsonl file.  Version 2 starts with a header line
//! `{"sincere_trace": 2}` followed by one arrival per line:
//! `{"at_s": 1.25, "model": "llama-sim", "prompt": "...",
//!   "tenant": "gold"}` — the `tenant` column is optional and names
//! the SLA class of a multi-tenant run.  Headerless version-1 traces
//! (no `tenant` column) still parse.  Traces make experiments exactly
//! repeatable across modes: the same trace is replayed in CC and
//! No-CC so both see identical load.

use std::io::{BufRead, Write};
use std::path::Path;

use crate::tenancy::{CLASS_NAMES, N_CLASSES};
use crate::traffic::Arrival;
use crate::util::json::Json;
use crate::workload::promptgen::PromptGen;

/// Current trace format version (the header line's value).
pub const TRACE_VERSION: u64 = 2;

/// Write arrivals (with generated prompts) as a jsonl trace.
pub fn write_trace(path: &Path, arrivals: &[Arrival],
                   prompts: &mut PromptGen) -> anyhow::Result<()> {
    write_trace_impl(path, arrivals, None, prompts)
}

/// Write a multi-tenant trace: `classes[i]` is arrival `i`'s SLA
/// class, emitted as a per-line `tenant` column.
pub fn write_trace_with_tenants(path: &Path, arrivals: &[Arrival],
                                classes: &[u8], prompts: &mut PromptGen)
                                -> anyhow::Result<()> {
    anyhow::ensure!(classes.len() == arrivals.len(),
                    "one class per arrival ({} classes, {} arrivals)",
                    classes.len(), arrivals.len());
    write_trace_impl(path, arrivals, Some(classes), prompts)
}

fn write_trace_impl(path: &Path, arrivals: &[Arrival],
                    classes: Option<&[u8]>, prompts: &mut PromptGen)
                    -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{}", Json::obj(vec![
        ("sincere_trace", Json::num(TRACE_VERSION as f64)),
    ]))?;
    for (i, a) in arrivals.iter().enumerate() {
        let mut fields = vec![
            ("at_s", Json::num(a.at_s)),
            ("model", Json::str(a.model.clone())),
            ("prompt", Json::str(prompts.next_prompt(&a.model))),
        ];
        if let Some(cs) = classes {
            let c = cs[i] as usize % N_CLASSES;
            fields.push(("tenant", Json::str(CLASS_NAMES[c].to_string())));
        }
        writeln!(f, "{}", Json::obj(fields))?;
    }
    f.flush()?;
    Ok(())
}

/// One replayed trace entry.
#[derive(Debug, Clone)]
pub struct TraceEntry {
    pub at_s: f64,
    pub model: String,
    pub prompt: String,
    /// SLA class name ("gold"/"silver"/"free"); None in single-tenant
    /// and version-1 traces.
    pub tenant: Option<String>,
}

impl TraceEntry {
    /// Class index of `tenant` (`CLASS_NAMES` order); 0 when absent
    /// or unknown, matching the engine's classes-off default.
    pub fn class(&self) -> u8 {
        self.tenant.as_deref()
            .and_then(|t| CLASS_NAMES.iter().position(|n| *n == t))
            .unwrap_or(0) as u8
    }
}

/// Read a jsonl trace back (any version up to [`TRACE_VERSION`]).
pub fn read_trace(path: &Path) -> anyhow::Result<Vec<TraceEntry>> {
    let f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("opening trace {path:?}: {e}"))?;
    let mut out: Vec<TraceEntry> = Vec::new();
    for (i, line) in std::io::BufReader::new(f).lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(&line)
            .map_err(|e| anyhow::anyhow!("trace line {}: {e}", i + 1))?;
        if out.is_empty() {
            if let Some(v) = j.get("sincere_trace") {
                let version = v.as_u64().unwrap_or(0);
                anyhow::ensure!(
                    (1..=TRACE_VERSION).contains(&version),
                    "trace {path:?} has unsupported version {version} \
                     (this build reads up to {TRACE_VERSION})");
                continue;
            }
        }
        out.push(TraceEntry {
            at_s: j.req("at_s")?.as_f64()
                .ok_or_else(|| anyhow::anyhow!("at_s not a number"))?,
            model: j.req("model")?.as_str()
                .ok_or_else(|| anyhow::anyhow!("model not a string"))?
                .to_string(),
            prompt: j.req("prompt")?.as_str().unwrap_or_default().to_string(),
            tenant: j.get("tenant").and_then(|t| t.as_str())
                .map(|t| t.to_string()),
        });
    }
    anyhow::ensure!(out.windows(2).all(|w| w[0].at_s <= w[1].at_s),
                    "trace not sorted by at_s");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traffic::rng::Pcg64;
    use crate::traffic::pattern_by_name;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("sincere_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");

        let mut rng = Pcg64::new(11);
        let p = pattern_by_name("gamma").unwrap();
        let arr = p.generate(30.0, 2.0, &["llama-sim".to_string()], &mut rng);
        let mut pg = PromptGen::new(42, 16);
        write_trace(&path, &arr, &mut pg).unwrap();

        // v2 writer emits the version header first
        let raw = std::fs::read_to_string(&path).unwrap();
        assert!(raw.lines().next().unwrap().contains("sincere_trace"));

        let back = read_trace(&path).unwrap();
        assert_eq!(back.len(), arr.len());
        for (a, b) in arr.iter().zip(&back) {
            assert!((a.at_s - b.at_s).abs() < 1e-9);
            assert_eq!(a.model, b.model);
            assert!(!b.prompt.is_empty());
            assert!(b.tenant.is_none(),
                    "single-tenant traces carry no tenant column");
        }
    }

    #[test]
    fn tenant_column_roundtrips() {
        let dir = std::env::temp_dir().join("sincere_trace_test_mt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mt.jsonl");

        let mut rng = Pcg64::new(12);
        let p = pattern_by_name("gamma").unwrap();
        let arr = p.generate(20.0, 2.0, &["llama-sim".to_string()], &mut rng);
        let classes: Vec<u8> =
            (0..arr.len()).map(|i| (i % N_CLASSES) as u8).collect();
        let mut pg = PromptGen::new(42, 16);
        write_trace_with_tenants(&path, &arr, &classes, &mut pg).unwrap();

        let back = read_trace(&path).unwrap();
        assert_eq!(back.len(), arr.len());
        for (i, e) in back.iter().enumerate() {
            let want = CLASS_NAMES[i % N_CLASSES];
            assert_eq!(e.tenant.as_deref(), Some(want));
            assert_eq!(e.class(), (i % N_CLASSES) as u8);
        }
        // length mismatch is rejected before anything is written
        assert!(write_trace_with_tenants(&path, &arr, &[0], &mut pg)
                .is_err());
    }

    #[test]
    fn headerless_v1_traces_still_parse() {
        let dir = std::env::temp_dir().join("sincere_trace_test_v1");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("v1.jsonl");
        std::fs::write(&path,
            "{\"at_s\":1.0,\"model\":\"m\",\"prompt\":\"x\"}\n\
             {\"at_s\":2.0,\"model\":\"m\",\"prompt\":\"y\"}\n").unwrap();
        let back = read_trace(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].model, "m");
        assert!(back[0].tenant.is_none());
        assert_eq!(back[0].class(), 0);
    }

    #[test]
    fn future_versions_rejected() {
        let dir = std::env::temp_dir().join("sincere_trace_test_v9");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("v9.jsonl");
        std::fs::write(&path,
            "{\"sincere_trace\":9}\n\
             {\"at_s\":1.0,\"model\":\"m\",\"prompt\":\"x\"}\n").unwrap();
        assert!(read_trace(&path).is_err());
    }

    #[test]
    fn rejects_unsorted() {
        let dir = std::env::temp_dir().join("sincere_trace_test2");
        std::fs::create_dir_all(&dir).unwrap();
        // with a v2 header and tenant columns...
        let path = dir.join("bad_v2.jsonl");
        std::fs::write(&path,
            "{\"sincere_trace\":2}\n\
             {\"at_s\":2.0,\"model\":\"m\",\"prompt\":\"x\",\
              \"tenant\":\"gold\"}\n\
             {\"at_s\":1.0,\"model\":\"m\",\"prompt\":\"y\",\
              \"tenant\":\"free\"}\n").unwrap();
        assert!(read_trace(&path).is_err());
        // ...and in the old headerless format
        let path = dir.join("bad.jsonl");
        std::fs::write(&path,
            "{\"at_s\":2.0,\"model\":\"m\",\"prompt\":\"x\"}\n\
             {\"at_s\":1.0,\"model\":\"m\",\"prompt\":\"y\"}\n").unwrap();
        assert!(read_trace(&path).is_err());
    }
}
