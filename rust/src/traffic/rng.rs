//! PCG64 (PCG-XSL-RR 128/64) pseudo-random generator.
//!
//! Deterministic, seedable, and good enough statistically for workload
//! generation (the `rand` crate is unavailable offline).  Reference:
//! O'Neill, "PCG: A Family of Simple Fast Space-Efficient Statistically
//! Good Algorithms for Random Number Generation".

const MUL: u128 = 0x2360ed051fc65da44385df649fccf645;
const INC: u128 = 0x5851f42d4c957f2d14057b7ef767814f;

/// PCG-XSL-RR 128/64.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
}

impl Pcg64 {
    /// Seed the generator; distinct seeds give independent-looking streams.
    pub fn new(seed: u64) -> Pcg64 {
        let mut r = Pcg64 { state: (seed as u128).wrapping_mul(0x9E3779B97F4A7C15) ^ 0xcafef00dd15ea5e5 };
        // advance a few steps so small seeds decorrelate
        for _ in 0..4 {
            r.next_u64();
        }
        r
    }

    /// Derive an independent child stream (for per-thread RNGs).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag.wrapping_mul(0xD1B54A32D192ED03))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MUL).wrapping_add(INC);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in (0, 1] — safe as a log() argument.
    pub fn next_f64_open(&mut self) -> f64 {
        1.0 - self.next_f64()
    }

    /// Uniform u64 in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(123);
        let mut b = Pcg64::new(123);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelate() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..1000).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval_and_uniform_ish() {
        let mut r = Pcg64::new(42);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_unbiased_small_range() {
        let mut r = Pcg64::new(9);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            // each bucket expects 10_000, allow ±5%
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn fork_independent() {
        let mut parent = Pcg64::new(5);
        let mut a = parent.fork(1);
        let mut b = parent.fork(2);
        let same = (0..1000).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn open_interval_never_zero() {
        let mut r = Pcg64::new(77);
        for _ in 0..100_000 {
            assert!(r.next_f64_open() > 0.0);
        }
    }
}
