//! Bursty on/off traffic (§III-C1): alternating periods of intense
//! activity and idle phases — promotional campaigns, sudden viral load.
//!
//! An on/off renewal process: exponentially-distributed burst and idle
//! durations; inside a burst, arrivals are Poisson at `burst_factor`
//! times the configured mean rate; idle phases emit nothing.  The duty
//! cycle is chosen so the long-run mean equals `mean_rps` (§III-C2).

use crate::traffic::{dist, finalize, pick_model, rng::Pcg64, Arrival,
                     TrafficPattern};

pub struct BurstyPattern {
    /// Rate multiplier inside a burst.
    pub burst_factor: f64,
    /// Mean burst length, seconds.
    pub mean_burst_s: f64,
}

impl Default for BurstyPattern {
    fn default() -> Self {
        BurstyPattern { burst_factor: 4.0, mean_burst_s: 8.0 }
    }
}

impl TrafficPattern for BurstyPattern {
    fn name(&self) -> &'static str {
        "bursty"
    }

    fn generate(&self, duration_s: f64, mean_rps: f64, models: &[String],
                rng: &mut Pcg64) -> Vec<Arrival> {
        assert!(mean_rps > 0.0 && !models.is_empty());
        assert!(self.burst_factor > 1.0);
        // duty cycle d with rate burst_factor*mean inside bursts:
        //   d * burst_factor * mean = mean  =>  d = 1 / burst_factor
        let duty = 1.0 / self.burst_factor;
        let mean_idle_s = self.mean_burst_s * (1.0 - duty) / duty;
        let burst_rate = mean_rps * self.burst_factor;

        let mut out = Vec::with_capacity((duration_s * mean_rps) as usize);
        let mut t = 0.0;
        // start in a random phase so experiment start isn't always a burst
        let mut in_burst = rng.next_f64() < duty;
        while t < duration_s {
            let phase_len = if in_burst {
                dist::exponential(rng, 1.0 / self.mean_burst_s)
            } else {
                dist::exponential(rng, 1.0 / mean_idle_s)
            };
            if in_burst {
                let mut bt = t + dist::exponential(rng, burst_rate);
                while bt < (t + phase_len).min(duration_s) {
                    out.push(Arrival { at_s: bt,
                                       model: pick_model(models, rng) });
                    bt += dist::exponential(rng, burst_rate);
                }
            }
            t += phase_len;
            in_burst = !in_burst;
        }
        finalize(out, duration_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn has_idle_gaps_and_dense_bursts() {
        let mut rng = Pcg64::new(4);
        let p = BurstyPattern::default();
        let arr = p.generate(600.0, 4.0, &["m".to_string()], &mut rng);
        let gaps: Vec<f64> = arr.windows(2)
            .map(|w| w[1].at_s - w[0].at_s).collect();
        let max_gap = gaps.iter().cloned().fold(0.0, f64::max);
        // idle phases mean multi-second silences must exist at 4 rps
        assert!(max_gap > 3.0, "expected idle gaps, max={max_gap}");
        // and bursts mean many sub-100ms gaps
        let tight = gaps.iter().filter(|g| **g < 0.1).count();
        assert!(tight as f64 / gaps.len() as f64 > 0.2,
                "expected dense bursts");
    }

    #[test]
    fn long_run_mean_preserved() {
        let mut rng = Pcg64::new(5);
        let p = BurstyPattern::default();
        // long horizon to average over many on/off cycles
        let arr = p.generate(3600.0, 4.0, &["m".to_string()], &mut rng);
        let rate = arr.len() as f64 / 3600.0;
        assert!((rate - 4.0).abs() / 4.0 < 0.10, "rate {rate}");
    }
}
