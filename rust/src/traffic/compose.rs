//! Traffic composition: diurnal sinusoid and flash-crowd multipliers
//! layered over any base pattern (gamma/bursty/ramp) by a
//! deterministic monotone time warp.
//!
//! The warp maps each arrival at base time `u` to the `t` where the
//! cumulative rate multiplier satisfies `C(t)/C(D) = u/D`.  Because
//! the multiplier `m(t)` is strictly positive, `C` is strictly
//! increasing: the warp preserves arrival count, ordering and the
//! `[0, D)` range, and draws **zero** RNG values — composed runs stay
//! byte-identical per seed and the off path is untouched.

use crate::traffic::Arrival;

/// Composition parameters (all off by default).
#[derive(Debug, Clone, Copy)]
pub struct Shape {
    /// Diurnal amplitude in [0, 1): 0 disables the sinusoid.
    pub diurnal_amp: f64,
    /// Diurnal period in seconds; <= 0 means one period per run.
    pub diurnal_period_s: f64,
    /// Flash-crowd rate multiplier (1 disables it).
    pub flash_mult: f64,
    /// Flash-crowd window start, seconds.
    pub flash_start_s: f64,
    /// Flash-crowd window length, seconds (0 disables it).
    pub flash_dur_s: f64,
}

impl Shape {
    /// True when any composition layer changes the rate.
    pub fn is_active(&self) -> bool {
        self.diurnal_amp > 0.0
            || (self.flash_mult != 1.0 && self.flash_dur_s > 0.0)
    }

    /// Instantaneous rate multiplier at `t`, strictly positive.
    fn mult_at(&self, t: f64, duration_s: f64) -> f64 {
        let period = if self.diurnal_period_s > 0.0 {
            self.diurnal_period_s
        } else {
            duration_s
        };
        let mut m = 1.0 + self.diurnal_amp
            * (2.0 * std::f64::consts::PI * t / period).sin();
        if self.flash_dur_s > 0.0
            && t >= self.flash_start_s
            && t < self.flash_start_s + self.flash_dur_s
        {
            m *= self.flash_mult;
        }
        m
    }
}

/// Grid resolution for the cumulative-rate table.
const GRID: usize = 2048;

/// Warp arrivals in place so their density follows `shape`'s rate
/// multiplier.  No-op on an empty schedule or inactive shape.
pub fn warp(arrivals: &mut [Arrival], duration_s: f64, shape: &Shape) {
    if arrivals.is_empty() || !shape.is_active() || duration_s <= 0.0 {
        return;
    }
    // cumulative multiplier C on a uniform grid (midpoint rule)
    let dt = duration_s / GRID as f64;
    let mut cum = Vec::with_capacity(GRID + 1);
    cum.push(0.0);
    let mut acc = 0.0;
    for k in 0..GRID {
        let mid = (k as f64 + 0.5) * dt;
        acc += shape.mult_at(mid, duration_s) * dt;
        cum.push(acc);
    }
    let total = *cum.last().unwrap();

    for a in arrivals.iter_mut() {
        let target = total * (a.at_s / duration_s);
        // first grid index with cum[i] >= target
        let i = cum.partition_point(|&c| c < target).max(1).min(GRID);
        let (c0, c1) = (cum[i - 1], cum[i]);
        let frac = if c1 > c0 { (target - c0) / (c1 - c0) } else { 0.0 };
        let t = ((i - 1) as f64 + frac) * dt;
        // clamp so the range contract of `finalize` survives float edges
        a.at_s = t.clamp(0.0, duration_s * (1.0 - 1e-12));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traffic::{pattern_by_name, rng::Pcg64};

    fn arrivals(seed: u64) -> Vec<Arrival> {
        let mut rng = Pcg64::new(seed);
        pattern_by_name("gamma").unwrap()
            .generate(120.0, 4.0, &["m".to_string()], &mut rng)
    }

    fn flat() -> Shape {
        Shape { diurnal_amp: 0.0, diurnal_period_s: 0.0, flash_mult: 1.0,
                flash_start_s: 0.0, flash_dur_s: 0.0 }
    }

    #[test]
    fn inactive_shape_is_identity() {
        let mut a = arrivals(5);
        let before = a.clone();
        warp(&mut a, 120.0, &flat());
        assert_eq!(a, before);
        assert!(!flat().is_active());
    }

    #[test]
    fn warp_preserves_count_order_and_range() {
        let mut a = arrivals(6);
        let n = a.len();
        let shape = Shape { diurnal_amp: 0.5, flash_mult: 3.0,
                            flash_start_s: 40.0, flash_dur_s: 20.0,
                            ..flat() };
        assert!(shape.is_active());
        warp(&mut a, 120.0, &shape);
        assert_eq!(a.len(), n);
        for w in a.windows(2) {
            assert!(w[0].at_s <= w[1].at_s, "warp must stay monotone");
        }
        assert!(a.iter().all(|x| (0.0..120.0).contains(&x.at_s)));
    }

    #[test]
    fn flash_window_concentrates_arrivals() {
        let mut a = arrivals(7);
        let total = a.len() as f64;
        let shape = Shape { flash_mult: 6.0, flash_start_s: 40.0,
                            flash_dur_s: 20.0, ..flat() };
        warp(&mut a, 120.0, &shape);
        let inside = a.iter()
            .filter(|x| (40.0..60.0).contains(&x.at_s)).count() as f64;
        // flat share of the window is 1/6; with a 6x multiplier the
        // window holds 6/11 of the mass
        assert!(inside / total > 0.35,
                "flash window got only {}", inside / total);
    }

    #[test]
    fn diurnal_peak_beats_trough() {
        let mut a = arrivals(8);
        let shape = Shape { diurnal_amp: 0.8, diurnal_period_s: 120.0,
                            ..flat() };
        warp(&mut a, 120.0, &shape);
        // sin peaks in the first half-period, troughs in the second
        let first = a.iter().filter(|x| x.at_s < 60.0).count();
        let second = a.len() - first;
        assert!(first > second,
                "peak half {first} must beat trough half {second}");
    }

    #[test]
    fn warp_is_deterministic() {
        let (mut a, mut b) = (arrivals(9), arrivals(9));
        let shape = Shape { diurnal_amp: 0.3, ..flat() };
        warp(&mut a, 120.0, &shape);
        warp(&mut b, 120.0, &shape);
        assert_eq!(a, b);
    }
}
