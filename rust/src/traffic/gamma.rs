//! Gamma-distributed inter-arrival traffic (§III-C1).
//!
//! "Characterized by irregular inter-arrival times, where some requests
//! occur in rapid succession while others are spaced apart" — we use a
//! shape parameter < 1, which produces exactly that clumpy behaviour
//! (CV = 1/sqrt(k) > 1).  The scale is set so the mean inter-arrival
//! time is 1/mean_rps, preserving the equal-mean normalization.

use crate::traffic::{dist, finalize, pick_model, rng::Pcg64, Arrival,
                     TrafficPattern};

pub struct GammaPattern {
    /// Gamma shape k; < 1 gives bursty-ish irregular arrivals (CV>1).
    pub shape: f64,
}

impl Default for GammaPattern {
    fn default() -> Self {
        GammaPattern { shape: 0.5 }
    }
}

impl TrafficPattern for GammaPattern {
    fn name(&self) -> &'static str {
        "gamma"
    }

    fn generate(&self, duration_s: f64, mean_rps: f64, models: &[String],
                rng: &mut Pcg64) -> Vec<Arrival> {
        assert!(mean_rps > 0.0 && !models.is_empty());
        // mean inter-arrival = shape * scale = 1 / mean_rps
        let scale = 1.0 / (mean_rps * self.shape);
        let mut t = 0.0;
        let mut out = Vec::with_capacity((duration_s * mean_rps) as usize);
        loop {
            t += dist::gamma(rng, self.shape, scale);
            if t >= duration_s {
                break;
            }
            out.push(Arrival { at_s: t, model: pick_model(models, rng) });
        }
        finalize(out, duration_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn irregular_interarrivals_cv_above_one() {
        let mut rng = Pcg64::new(3);
        let p = GammaPattern::default();
        let arr = p.generate(600.0, 4.0, &["m".to_string()], &mut rng);
        let gaps: Vec<f64> = arr.windows(2)
            .map(|w| w[1].at_s - w[0].at_s).collect();
        let m = crate::util::mean(&gaps);
        let cv = crate::util::stddev(&gaps) / m;
        assert!(cv > 1.1, "gamma traffic should be irregular, cv={cv}");
        assert!((m - 0.25).abs() < 0.02, "mean gap {m}");
    }
}
