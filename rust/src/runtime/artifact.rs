//! HLO-text artifact loading and compilation.

use std::path::Path;
use std::time::{Duration, Instant};

/// A compiled (family, batch) executable plus compile metadata.
pub struct CompiledArtifact {
    pub exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
    pub compile_time: Duration,
    pub hlo_bytes: usize,
}

/// Parse HLO text and compile it on the given client.
pub fn compile_hlo(client: &xla::PjRtClient, path: &Path, batch: usize)
                   -> anyhow::Result<CompiledArtifact> {
    let start = Instant::now();
    let hlo_bytes = std::fs::metadata(path)
        .map_err(|e| anyhow::anyhow!("missing artifact {path:?}: {e}"))?
        .len() as usize;
    let path_str = path.to_str()
        .ok_or_else(|| anyhow::anyhow!("non-utf8 path {path:?}"))?;
    let proto = xla::HloModuleProto::from_text_file(path_str)
        .map_err(|e| anyhow::anyhow!("parsing HLO {path:?}: {e}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp)
        .map_err(|e| anyhow::anyhow!("compiling {path:?}: {e}"))?;
    Ok(CompiledArtifact {
        exe,
        batch,
        compile_time: start.elapsed(),
        hlo_bytes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn compiles_real_artifact() {
        let client = xla::PjRtClient::cpu().unwrap();
        let art = compile_hlo(&client,
                              &artifacts_dir().join("llama-sim_b1.hlo.txt"),
                              1).unwrap();
        assert!(art.hlo_bytes > 10_000);
        assert!(art.compile_time > Duration::ZERO);
    }

    #[test]
    fn missing_artifact_errors_cleanly() {
        let client = xla::PjRtClient::cpu().unwrap();
        let err = match compile_hlo(&client, Path::new("/nope/x.hlo.txt"),
                                    1) {
            Err(e) => e.to_string(),
            Ok(_) => panic!("expected error"),
        };
        assert!(err.contains("missing artifact"));
    }
}
