//! Host-side model state: weights as XLA literals + raw bytes.
//!
//! Weights live on the *host* until the swap manager DMAs them into
//! simulated HBM; `WeightSet::literals` are what the PJRT executable is
//! fed at execute time.  The raw byte blob is what travels through the
//! (optionally encrypting) DMA path — the same bytes the literals were
//! built from, so the data flow mirrors the paper's load path.

use std::path::Path;

use crate::runtime::manifest::FamilySpec;

/// A family's weights, materialized host-side once at startup.
pub struct WeightSet {
    /// One literal per parameter, in `FamilySpec.weights.params` order —
    /// the HLO parameter order after the prompt.
    pub literals: Vec<xla::Literal>,
    /// The flat blob (what gets DMA'd on every model swap).
    pub raw: Vec<u8>,
}

impl WeightSet {
    /// Read and validate the weight blob; build literals.
    pub fn load(spec: &FamilySpec, artifacts_dir: &Path)
                -> anyhow::Result<WeightSet> {
        let path = artifacts_dir.join(&spec.weights.file);
        let raw = std::fs::read(&path)
            .map_err(|e| anyhow::anyhow!("reading weights {path:?}: {e}"))?;
        anyhow::ensure!(raw.len() == spec.weights.total_bytes,
                        "weight blob {} bytes, manifest says {}",
                        raw.len(), spec.weights.total_bytes);
        let digest = sha256_hex(&raw);
        anyhow::ensure!(digest == spec.weights.sha256,
                        "weight blob sha256 mismatch for {}", spec.name);

        let mut literals = Vec::with_capacity(spec.weights.params.len());
        for p in &spec.weights.params {
            let bytes = raw.get(p.offset_bytes..p.offset_bytes + p.size_bytes)
                .ok_or_else(|| anyhow::anyhow!(
                    "param {} out of blob range", p.name))?;
            anyhow::ensure!(bytes.len() % 4 == 0, "param {} unaligned",
                            p.name);
            let floats: Vec<f32> = bytes.chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            let numel: usize = p.shape.iter().product();
            anyhow::ensure!(floats.len() == numel,
                            "param {}: {} elements, shape {:?}", p.name,
                            floats.len(), p.shape);
            let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(&floats).reshape(&dims)
                .map_err(|e| anyhow::anyhow!(
                    "reshaping param {}: {e}", p.name))?);
        }
        Ok(WeightSet { literals, raw })
    }
}

fn sha256_hex(data: &[u8]) -> String {
    use sha2::{Digest, Sha256};
    let mut h = Sha256::new();
    h.update(data);
    let d = h.finalize();
    d.iter().map(|b| format!("{b:02x}")).collect()
}

/// Build the `[B, prompt_len]` i32 prompt literal from per-request token
/// rows, padding short batches with zero rows (padding rows are inert:
/// `test_batch_rows_are_independent` in python/tests guarantees row
/// isolation).
pub fn prompt_literal(rows: &[Vec<i32>], batch: usize, prompt_len: usize)
                      -> anyhow::Result<xla::Literal> {
    anyhow::ensure!(rows.len() <= batch,
                    "{} rows exceed batch {batch}", rows.len());
    let mut flat = Vec::with_capacity(batch * prompt_len);
    for row in rows {
        anyhow::ensure!(row.len() == prompt_len,
                        "prompt row len {} != {prompt_len}", row.len());
        flat.extend_from_slice(row);
    }
    flat.resize(batch * prompt_len, 0);
    Ok(xla::Literal::vec1(&flat)
        .reshape(&[batch as i64, prompt_len as i64])?)
}

/// Decode-token output of one execute: `rows x decode_len`.
pub fn tokens_from_literal(lit: &xla::Literal, rows: usize,
                           batch: usize, decode_len: usize)
                           -> anyhow::Result<Vec<Vec<i32>>> {
    let flat = lit.to_vec::<i32>()
        .map_err(|e| anyhow::anyhow!("decoding output literal: {e}"))?;
    anyhow::ensure!(flat.len() == batch * decode_len,
                    "output literal {} elements, want {}", flat.len(),
                    batch * decode_len);
    Ok(flat.chunks(decode_len).take(rows)
        .map(|c| c.to_vec()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_and_validates_weights() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let spec = m.family("llama-sim").unwrap();
        let ws = WeightSet::load(spec, &artifacts_dir()).unwrap();
        assert_eq!(ws.literals.len(), spec.weights.params.len());
        assert_eq!(ws.raw.len(), spec.weights.total_bytes);
    }

    #[test]
    fn corrupted_blob_rejected() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let spec = m.family("llama-sim").unwrap();
        // copy artifacts to temp, flip a byte
        let dir = std::env::temp_dir().join("sincere_ws_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut raw = std::fs::read(
            artifacts_dir().join(&spec.weights.file)).unwrap();
        raw[100] ^= 0xFF;
        std::fs::write(dir.join(&spec.weights.file), &raw).unwrap();
        let err = match WeightSet::load(spec, &dir) {
            Err(e) => e.to_string(),
            Ok(_) => panic!("expected error"),
        };
        assert!(err.contains("sha256 mismatch"), "{err}");
    }

    #[test]
    fn prompt_literal_pads_batch() {
        let rows = vec![vec![1i32; 16], vec![2i32; 16]];
        let lit = prompt_literal(&rows, 4, 16).unwrap();
        let flat = lit.to_vec::<i32>().unwrap();
        assert_eq!(flat.len(), 64);
        assert!(flat[32..].iter().all(|&t| t == 0));
    }

    #[test]
    fn prompt_literal_rejects_bad_rows() {
        assert!(prompt_literal(&[vec![1; 8]], 1, 16).is_err());
        assert!(prompt_literal(&vec![vec![1; 16]; 3], 2, 16).is_err());
    }
}
