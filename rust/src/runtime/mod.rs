//! PJRT runtime: load AOT artifacts, compile them once, execute batches.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1, CPU PJRT): HLO **text**
//! artifacts produced by `python/compile/aot.py` are parsed with
//! `HloModuleProto::from_text_file`, compiled per (family, batch size),
//! and executed with the family's weight literals plus the batch's
//! prompt tokens.  Python never runs here — this is the serve path.

pub mod artifact;
pub mod intern;
pub mod manifest;
pub mod model;
pub mod registry;

pub use intern::{ModelId, ModelTable};
pub use manifest::{FamilySpec, Manifest};
pub use registry::Registry;
