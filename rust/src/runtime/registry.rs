//! The model registry: every (family, batch size) executable, compiled
//! once at startup, plus host weights and OBS bookkeeping.

use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::runtime::artifact::compile_hlo;
use crate::runtime::manifest::{FamilySpec, Manifest};
use crate::runtime::model::{prompt_literal, tokens_from_literal, WeightSet};

/// One family's runtime state.
pub struct ModelEntry {
    pub spec: FamilySpec,
    pub weights: WeightSet,
    /// batch size -> compiled executable.
    pub executables: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    /// Optimal batch size (max-throughput), set from profiling; defaults
    /// to the largest compiled batch.
    pub obs: usize,
}

impl ModelEntry {
    /// Batch sizes actually compiled in this registry (may be a subset
    /// of the manifest's artifact list), ascending.
    pub fn compiled_batch_sizes(&self) -> Vec<usize> {
        self.executables.keys().copied().collect()
    }
}

/// Result of one batch execution.
#[derive(Debug, Clone)]
pub struct ExecReport {
    /// Decode tokens per *real* (non-padding) row.
    pub tokens: Vec<Vec<i32>>,
    /// Wall time of the PJRT execute + literal transfers.
    pub elapsed: Duration,
    /// The artifact batch size actually used (>= rows).
    pub batch: usize,
}

/// Registry over a PJRT CPU client.
pub struct Registry {
    pub client: xla::PjRtClient,
    pub artifacts_dir: PathBuf,
    entries: HashMap<String, ModelEntry>,
    pub total_compile_time: Duration,
}

impl Registry {
    /// Load manifest + weights and compile executables.
    ///
    /// `family_filter`/`batch_filter`: empty means "all"; tests restrict
    /// both to keep startup fast.
    pub fn load(manifest: &Manifest, family_filter: &[String],
                batch_filter: &[usize]) -> anyhow::Result<Registry> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e}"))?;
        let mut entries = HashMap::new();
        let mut total_compile = Duration::ZERO;
        for spec in &manifest.families {
            if !family_filter.is_empty()
                && !family_filter.contains(&spec.name)
            {
                continue;
            }
            let weights = WeightSet::load(spec, &manifest.dir)?;
            let mut executables = BTreeMap::new();
            for (&b, file) in &spec.artifacts {
                if !batch_filter.is_empty() && !batch_filter.contains(&b) {
                    continue;
                }
                let art = compile_hlo(&client, &manifest.dir.join(file), b)?;
                total_compile += art.compile_time;
                executables.insert(b, art.exe);
            }
            anyhow::ensure!(!executables.is_empty(),
                            "no executables compiled for {}", spec.name);
            let obs = *executables.keys().last().unwrap();
            entries.insert(spec.name.clone(), ModelEntry {
                spec: spec.clone(),
                weights,
                executables,
                obs,
            });
        }
        anyhow::ensure!(!entries.is_empty(), "registry is empty");
        Ok(Registry {
            client,
            artifacts_dir: manifest.dir.clone(),
            entries,
            total_compile_time: total_compile,
        })
    }

    pub fn entry(&self, name: &str) -> anyhow::Result<&ModelEntry> {
        self.entries.get(name).ok_or_else(|| anyhow::anyhow!(
            "model {name:?} not in registry (have {:?})",
            self.entries.keys().collect::<Vec<_>>()))
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.entries.keys().cloned().collect();
        v.sort();
        v
    }

    /// Record the profiled OBS for a family (§III-D2).
    pub fn set_obs(&mut self, name: &str, obs: usize) -> anyhow::Result<()> {
        let e = self.entries.get_mut(name).ok_or_else(
            || anyhow::anyhow!("model {name:?} not in registry"))?;
        anyhow::ensure!(e.executables.contains_key(&obs),
                        "OBS {obs} has no artifact for {name}");
        e.obs = obs;
        Ok(())
    }

    pub fn obs(&self, name: &str) -> anyhow::Result<usize> {
        Ok(self.entry(name)?.obs)
    }

    /// Execute `rows` prompts on `name` using the smallest artifact batch
    /// that fits them.  The swap manager is responsible for residency;
    /// this is pure compute.
    pub fn execute(&self, name: &str, rows: &[Vec<i32>])
                   -> anyhow::Result<ExecReport> {
        anyhow::ensure!(!rows.is_empty(), "empty batch for {name}");
        let entry = self.entry(name)?;
        // pick among *compiled* executables (a filtered registry may hold
        // fewer batch sizes than the manifest lists)
        let batch = entry.executables.keys().copied()
            .filter(|&b| b >= rows.len()).min()
            .ok_or_else(|| anyhow::anyhow!(
                "no compiled batch size fits {} rows for {name} \
                 (largest is {})", rows.len(),
                entry.executables.keys().last().unwrap()))?;
        let exe = entry.executables.get(&batch).unwrap();

        let start = Instant::now();
        let prompt = prompt_literal(rows, batch, entry.spec.prompt_len)?;
        // args: prompt then weights, positionally (aot.py contract)
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(
            1 + entry.weights.literals.len());
        args.push(&prompt);
        args.extend(entry.weights.literals.iter());
        let result = exe.execute(&args)
            .map_err(|e| anyhow::anyhow!("executing {name} b{batch}: {e}"))?;
        let lit = result[0][0].to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching output: {e}"))?;
        let out = lit.to_tuple1()
            .map_err(|e| anyhow::anyhow!("unwrapping tuple: {e}"))?;
        let tokens = tokens_from_literal(&out, rows.len(), batch,
                                         entry.spec.decode_len)?;
        Ok(ExecReport { tokens, elapsed: start.elapsed(), batch })
    }
}

/// A registry shareable across threads (tests, benches, multi-run
/// drivers) with all access serialized.
///
/// # Safety
///
/// The `xla` crate's types hold `Rc` internals and raw PJRT pointers, so
/// they are neither `Send` nor `Sync`.  The PJRT CPU runtime itself is
/// thread-safe, but `execute()` clones `Rc` client handles, so truly
/// concurrent calls would race the non-atomic refcounts.  This wrapper
/// is sound because (a) every access goes through the `Mutex`, so no two
/// threads touch the inner `Registry` (or clone its `Rc`s)
/// concurrently, and (b) `with()` cannot leak borrows of the inner
/// value past the lock guard.
pub struct SharedRegistry(std::sync::Mutex<Registry>);

unsafe impl Send for SharedRegistry {}
unsafe impl Sync for SharedRegistry {}

impl SharedRegistry {
    pub fn new(registry: Registry) -> SharedRegistry {
        SharedRegistry(std::sync::Mutex::new(registry))
    }

    /// Run `f` with exclusive access to the registry.
    pub fn with<T>(&self, f: impl FnOnce(&mut Registry) -> T) -> T {
        let mut guard = self.0.lock().unwrap();
        f(&mut guard)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn small_registry() -> Registry {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        Registry::load(&m, &["llama-sim".to_string()], &[1, 2, 4]).unwrap()
    }

    #[test]
    fn execute_returns_decode_tokens() {
        let reg = small_registry();
        let spec = &reg.entry("llama-sim").unwrap().spec;
        let rows = vec![vec![5i32; spec.prompt_len]];
        let rep = reg.execute("llama-sim", &rows).unwrap();
        assert_eq!(rep.batch, 1);
        assert_eq!(rep.tokens.len(), 1);
        assert_eq!(rep.tokens[0].len(), spec.decode_len);
        assert!(rep.tokens[0].iter()
                .all(|&t| (0..spec.vocab as i32).contains(&t)));
    }

    #[test]
    fn execute_is_deterministic() {
        let reg = small_registry();
        let spec = &reg.entry("llama-sim").unwrap().spec;
        let rows: Vec<Vec<i32>> = (0..2)
            .map(|i| vec![(i * 17 + 3) as i32; spec.prompt_len]).collect();
        let a = reg.execute("llama-sim", &rows).unwrap();
        let b = reg.execute("llama-sim", &rows).unwrap();
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn padding_rows_do_not_change_results() {
        // 3 rows in a batch-4 artifact must equal the same rows bit-for-bit
        // when run alone in smaller artifacts.
        let reg = small_registry();
        let spec = &reg.entry("llama-sim").unwrap().spec;
        let rows: Vec<Vec<i32>> = (0..3)
            .map(|i| {
                (0..spec.prompt_len)
                    .map(|j| ((i * 31 + j * 7) % spec.vocab) as i32)
                    .collect()
            }).collect();
        let padded = reg.execute("llama-sim", &rows).unwrap();
        assert_eq!(padded.batch, 4);
        let solo = reg.execute("llama-sim", &rows[..1]).unwrap();
        assert_eq!(padded.tokens[0], solo.tokens[0]);
    }

    #[test]
    fn oversized_batch_uses_largest_and_fails() {
        let reg = small_registry();
        let spec = &reg.entry("llama-sim").unwrap().spec;
        let rows = vec![vec![1i32; spec.prompt_len]; 5]; // > max batch 4
        assert!(reg.execute("llama-sim", &rows).is_err());
    }

    #[test]
    fn unknown_model_rejected() {
        let reg = small_registry();
        assert!(reg.execute("nope", &[vec![0; 16]]).is_err());
        assert!(reg.obs("nope").is_err());
    }

    #[test]
    fn set_obs_validates_artifact() {
        let mut reg = small_registry();
        assert!(reg.set_obs("llama-sim", 2).is_ok());
        assert_eq!(reg.obs("llama-sim").unwrap(), 2);
        assert!(reg.set_obs("llama-sim", 3).is_err());
    }
}
