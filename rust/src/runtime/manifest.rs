//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime.  Parsed from `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One weight array inside the flat `.bin` blob.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset_bytes: usize,
    pub size_bytes: usize,
}

/// The weight blob of a family.
#[derive(Debug, Clone)]
pub struct WeightsSpec {
    pub file: String,
    pub total_bytes: usize,
    pub sha256: String,
    pub params: Vec<ParamSpec>,
}

/// One servable model family (Table II analogue).
#[derive(Debug, Clone)]
pub struct FamilySpec {
    pub name: String,
    pub hf_name: String,
    pub paper_gb: f64,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub act: String,
    pub prompt_len: usize,
    pub decode_len: usize,
    pub cache_len: usize,
    pub kv_bytes_per_seq: u64,
    pub param_count: u64,
    pub weights: WeightsSpec,
    /// batch size -> HLO artifact file name.
    pub artifacts: BTreeMap<usize, String>,
}

impl FamilySpec {
    /// Device bytes needed to *load* this model (weights only).
    pub fn weight_bytes(&self) -> u64 {
        self.weights.total_bytes as u64
    }

    /// Device bytes needed to *run* a batch of `b`: KV cache plus an
    /// activation workspace estimate (logits + MLP intermediates).
    pub fn batch_workspace_bytes(&self, b: usize) -> u64 {
        let act = 4 * (self.vocab + 3 * self.d_ff + 4 * self.d_model);
        b as u64 * (self.kv_bytes_per_seq + act as u64)
    }

    /// Batch sizes with an AOT artifact, ascending.
    pub fn batch_sizes(&self) -> Vec<usize> {
        self.artifacts.keys().copied().collect()
    }

    /// Largest artifact batch size <= `n` (None if even the smallest
    /// exceeds n).
    pub fn batch_size_at_most(&self, n: usize) -> Option<usize> {
        self.artifacts.keys().copied().filter(|&b| b <= n).max()
    }

    /// Smallest artifact batch size >= `n`, else the largest available.
    pub fn batch_size_at_least(&self, n: usize) -> usize {
        self.artifacts.keys().copied().filter(|&b| b >= n).min()
            .unwrap_or_else(|| *self.artifacts.keys().last().unwrap())
    }
}

/// The whole artifact set.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub batch_sizes: Vec<usize>,
    pub families: Vec<FamilySpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let j = Json::parse_file(&dir.join("manifest.json"))?;
        anyhow::ensure!(j.req("format_version")?.as_u64() == Some(1),
                        "unsupported manifest format_version");
        let batch_sizes = j.req("batch_sizes")?.as_arr()
            .ok_or_else(|| anyhow::anyhow!("batch_sizes not an array"))?
            .iter().map(|b| b.as_usize()
                .ok_or_else(|| anyhow::anyhow!("bad batch size")))
            .collect::<anyhow::Result<Vec<_>>>()?;

        let mut families = Vec::new();
        for fj in j.req("families")?.as_arr().unwrap_or(&[]) {
            families.push(parse_family(fj)?);
        }
        anyhow::ensure!(!families.is_empty(), "manifest has no families");
        Ok(Manifest { dir: dir.to_path_buf(), batch_sizes, families })
    }

    pub fn family(&self, name: &str) -> anyhow::Result<&FamilySpec> {
        self.families.iter().find(|f| f.name == name)
            .ok_or_else(|| anyhow::anyhow!(
                "unknown model {name:?}; manifest has {:?}",
                self.families.iter().map(|f| &f.name).collect::<Vec<_>>()))
    }

    pub fn family_names(&self) -> Vec<String> {
        self.families.iter().map(|f| f.name.clone()).collect()
    }
}

fn parse_family(j: &Json) -> anyhow::Result<FamilySpec> {
    let s = |k: &str| -> anyhow::Result<String> {
        Ok(j.req(k)?.as_str()
            .ok_or_else(|| anyhow::anyhow!("{k} not a string"))?.to_string())
    };
    let n = |k: &str| -> anyhow::Result<usize> {
        j.req(k)?.as_usize()
            .ok_or_else(|| anyhow::anyhow!("{k} not a non-negative int"))
    };

    let wj = j.req("weights")?;
    let mut params = Vec::new();
    for pj in wj.req("params")?.as_arr().unwrap_or(&[]) {
        params.push(ParamSpec {
            name: pj.req("name")?.as_str().unwrap_or_default().to_string(),
            shape: pj.req("shape")?.as_arr().unwrap_or(&[]).iter()
                .map(|d| d.as_usize().unwrap_or(0)).collect(),
            offset_bytes: pj.req("offset_bytes")?.as_usize()
                .ok_or_else(|| anyhow::anyhow!("bad offset"))?,
            size_bytes: pj.req("size_bytes")?.as_usize()
                .ok_or_else(|| anyhow::anyhow!("bad size"))?,
        });
    }
    anyhow::ensure!(!params.is_empty(), "family has no params");

    let mut artifacts = BTreeMap::new();
    if let Some(obj) = j.req("artifacts")?.as_obj() {
        for (k, v) in obj {
            artifacts.insert(
                k.parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("bad batch key {k:?}"))?,
                v.as_str()
                    .ok_or_else(|| anyhow::anyhow!("artifact not a string"))?
                    .to_string());
        }
    }
    anyhow::ensure!(!artifacts.is_empty(), "family has no artifacts");

    Ok(FamilySpec {
        name: s("name")?,
        hf_name: s("hf_name")?,
        paper_gb: j.req("paper_gb")?.as_f64().unwrap_or(0.0),
        d_model: n("d_model")?,
        n_layers: n("n_layers")?,
        n_heads: n("n_heads")?,
        d_ff: n("d_ff")?,
        vocab: n("vocab")?,
        act: s("act")?,
        prompt_len: n("prompt_len")?,
        decode_len: n("decode_len")?,
        cache_len: n("cache_len")?,
        kv_bytes_per_seq: j.req("kv_bytes_per_seq")?.as_u64()
            .ok_or_else(|| anyhow::anyhow!("bad kv_bytes_per_seq"))?,
        param_count: j.req("param_count")?.as_u64().unwrap_or(0),
        weights: WeightsSpec {
            file: wj.req("file")?.as_str().unwrap_or_default().to_string(),
            total_bytes: wj.req("total_bytes")?.as_usize()
                .ok_or_else(|| anyhow::anyhow!("bad total_bytes"))?,
            sha256: wj.req("sha256")?.as_str().unwrap_or_default()
                .to_string(),
            params,
        },
        artifacts,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let m = Manifest::load(&artifacts_dir()).expect(
            "run `make artifacts` before cargo test");
        assert_eq!(m.families.len(), 3);
        let names = m.family_names();
        assert!(names.contains(&"llama-sim".to_string()));
        let g = m.family("granite-sim").unwrap();
        assert!(g.weight_bytes() > m.family("gemma-sim").unwrap()
                .weight_bytes());
        assert!(g.artifacts.len() >= 4);
    }

    #[test]
    fn batch_size_selection() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let f = m.family("llama-sim").unwrap();
        assert_eq!(f.batch_size_at_most(3), Some(2));
        assert_eq!(f.batch_size_at_most(32), Some(32));
        assert_eq!(f.batch_size_at_most(0), None);
        assert_eq!(f.batch_size_at_least(3), 4);
        assert_eq!(f.batch_size_at_least(1000), 32);
    }

    #[test]
    fn unknown_family_rejected() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        assert!(m.family("gpt-5").is_err());
    }

    #[test]
    fn workspace_bytes_scale_with_batch() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let f = m.family("llama-sim").unwrap();
        assert!(f.batch_workspace_bytes(8) > 4 * f.batch_workspace_bytes(1));
    }
}
