//! Model-name interning: every model a run can serve is assigned one
//! dense [`ModelId`] at startup, and the hot path moves `u32` copies
//! around instead of `String` clones.
//!
//! The table is built **sorted** (and deduplicated), which buys two
//! invariants the byte-identity contract leans on:
//!
//! * Iterating queues / per-model state by index visits models in the
//!   same lexicographic order the old `BTreeMap<String, _>` keyed by
//!   name did, so every table, CSV and golden stays byte-identical.
//! * `ModelId`'s derived `Ord` *is* the name order — tie-breaks that
//!   used to compare names (e.g. the prefetch predictor's
//!   `b.model.cmp(&a.model)`) compare ids and decide identically.
//!
//! The table is immutable after construction and shared by `Arc`: the
//! engine, backend, queues and recorder all point at the same one, so
//! an id minted anywhere resolves everywhere.

use std::sync::Arc;

/// A dense, table-scoped model identifier.
///
/// Ids are indices into the [`ModelTable`] that minted them; because
/// the table is sorted, `ModelId` ordering equals lexicographic name
/// ordering.  The inner index is public so tests and benches can
/// construct ids directly against a table they built.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ModelId(pub u32);

impl ModelId {
    /// The id's index into its table's dense per-model vectors.
    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// The immutable, sorted intern table for one run.
#[derive(Debug, Clone, Default)]
pub struct ModelTable {
    names: Vec<String>,
}

impl ModelTable {
    /// Build a table from any collection of names; duplicates collapse
    /// and the result is sorted, so construction order cannot leak
    /// into id assignment.
    pub fn new<I, S>(names: I) -> ModelTable
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut names: Vec<String> =
            names.into_iter().map(Into::into).collect();
        names.sort_unstable();
        names.dedup();
        ModelTable { names }
    }

    /// Shared-table convenience for the common construction site.
    pub fn shared<I, S>(names: I) -> Arc<ModelTable>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        Arc::new(ModelTable::new(names))
    }

    /// Intern lookup: `None` means the name was not in the run's model
    /// set (callers treat that as "unknown model").
    #[inline]
    pub fn id(&self, name: &str) -> Option<ModelId> {
        self.names.binary_search_by(|n| n.as_str().cmp(name)).ok()
            .map(|i| ModelId(i as u32))
    }

    /// Like [`ModelTable::id`] but with a descriptive error.
    pub fn require(&self, name: &str) -> anyhow::Result<ModelId> {
        self.id(name).ok_or_else(|| anyhow::anyhow!(
            "model {name:?} is not in the intern table {:?}", self.names))
    }

    /// Resolve an id back to its name (borrowed — the hot path never
    /// clones).
    #[inline]
    pub fn name(&self, id: ModelId) -> &str {
        &self.names[id.index()]
    }

    /// Number of interned models.
    #[inline]
    pub fn len(&self) -> usize {
        self.names.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// All ids in name (== index) order.
    pub fn ids(&self) -> impl Iterator<Item = ModelId> + '_ {
        (0..self.names.len()).map(|i| ModelId(i as u32))
    }

    /// All names in table (== lexicographic) order.
    pub fn names(&self) -> &[String] {
        &self.names
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    #[test]
    fn round_trips_names() {
        let t = ModelTable::new(["gemma-sim", "llama-sim", "granite-sim"]);
        for name in ["llama-sim", "gemma-sim", "granite-sim"] {
            let id = t.id(name).unwrap();
            assert_eq!(t.name(id), name);
        }
        assert_eq!(t.len(), 3);
        assert!(t.id("gpt-5").is_none());
        assert!(t.require("gpt-5").is_err());
    }

    #[test]
    fn table_order_matches_btreemap_iteration() {
        // The queues used to be a BTreeMap<String, _>; goldens depend
        // on visiting models in its iteration order.  The sorted table
        // must reproduce it exactly, whatever order names arrive in.
        let arrival_order = ["llama-sim", "gemma-sim", "zeta", "alpha",
                            "granite-sim"];
        let legacy: BTreeMap<String, ()> = arrival_order.iter()
            .map(|n| (n.to_string(), ())).collect();
        let t = ModelTable::new(arrival_order);
        let table_order: Vec<&str> = t.ids().map(|id| t.name(id)).collect();
        let legacy_order: Vec<&str> = legacy.keys()
            .map(String::as_str).collect();
        assert_eq!(table_order, legacy_order);
    }

    #[test]
    fn id_order_equals_name_order() {
        let t = ModelTable::new(["b", "c", "a"]);
        let a = t.id("a").unwrap();
        let b = t.id("b").unwrap();
        let c = t.id("c").unwrap();
        assert!(a < b && b < c);
        assert_eq!(a.index(), 0);
        assert_eq!(c.index(), 2);
    }

    #[test]
    fn duplicates_collapse() {
        let t = ModelTable::new(["m", "m", "m"]);
        assert_eq!(t.len(), 1);
        assert_eq!(t.id("m").unwrap(), ModelId(0));
    }

    #[test]
    fn empty_table_is_valid() {
        let t = ModelTable::new(Vec::<String>::new());
        assert!(t.is_empty());
        assert!(t.id("m").is_none());
        assert_eq!(t.ids().count(), 0);
    }
}
