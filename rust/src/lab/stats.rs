//! Seed-replica aggregation: fold a lab run's per-replica
//! `RunSummary` rows into per-cell [`CellStats`]
//! (mean / stddev / p50 / p95 over the replicas of each cell).
//!
//! Grouping is by cell label — replicas of one cell share it (only
//! their seeds differ), and labels are unique per cell by
//! construction (`spec::ScenarioSpec::expand`).  Cell order follows
//! first appearance, i.e. grid order.

use std::collections::BTreeMap;

use crate::engine::RunSummary;
use crate::util::{mean, quantile, stddev};

/// Summary statistics of one metric across seed replicas.
#[derive(Debug, Clone, Default)]
pub struct Stat {
    pub mean: f64,
    pub stddev: f64,
    pub p50: f64,
    pub p95: f64,
}

impl Stat {
    pub fn from_samples(xs: &[f64]) -> Stat {
        Stat {
            mean: mean(xs),
            stddev: stddev(xs),
            p50: quantile(xs, 0.5),
            p95: quantile(xs, 0.95),
        }
    }
}

/// One grid cell's metrics folded across its seed replicas.
#[derive(Debug, Clone)]
pub struct CellStats {
    pub label: String,
    pub mode: String,
    pub pattern: String,
    pub strategy: String,
    pub sla_s: f64,
    /// Seed replicas folded into this row.
    pub replicas: usize,
    pub latency_mean_s: Stat,
    pub latency_p99_s: Stat,
    pub sla_attainment: Stat,
    pub throughput_rps: Stat,
    pub gpu_util: Stat,
    pub swap_count: Stat,
}

fn stat_of(group: &[&RunSummary],
           f: impl Fn(&RunSummary) -> f64) -> Stat {
    let xs: Vec<f64> = group.iter().map(|c| f(c)).collect();
    Stat::from_samples(&xs)
}

/// Fold replicas into per-cell stats, preserving grid order.
pub fn aggregate(cells: &[RunSummary]) -> Vec<CellStats> {
    let mut order: Vec<String> = Vec::new();
    let mut groups: BTreeMap<String, Vec<&RunSummary>> = BTreeMap::new();
    for c in cells {
        if !groups.contains_key(&c.label) {
            order.push(c.label.clone());
        }
        groups.entry(c.label.clone()).or_default().push(c);
    }
    order.iter().map(|label| {
        let g = &groups[label];
        let first = g[0];
        CellStats {
            label: label.clone(),
            mode: first.mode.clone(),
            pattern: first.pattern.clone(),
            strategy: first.strategy.clone(),
            sla_s: first.sla_s,
            replicas: g.len(),
            latency_mean_s: stat_of(g, |c| c.latency_mean_s),
            latency_p99_s: stat_of(g, |c| c.latency_p99_s),
            sla_attainment: stat_of(g, |c| c.sla_attainment),
            throughput_rps: stat_of(g, |c| c.throughput_rps),
            gpu_util: stat_of(g, |c| c.gpu_util),
            swap_count: stat_of(g, |c| c.swap_count as f64),
        }
    }).collect()
}

/// Markdown table of per-cell replica statistics (mean ± stddev, and
/// the p95 of the p99 latency across seeds).
pub fn stats_table(stats: &[CellStats]) -> String {
    let mut out = String::from(
        "| cell | seeds | lat mean (s) | lat p99 p95 (s) | attain % | \
         thr (rps) | GPU util % | swaps |\n\
         |---|---|---|---|---|---|---|---|\n");
    for s in stats {
        out.push_str(&format!(
            "| {} | {} | {:.2} ± {:.2} | {:.2} | {:.1} ± {:.1} | \
             {:.2} ± {:.2} | {:.1} ± {:.1} | {:.1} ± {:.1} |\n",
            s.label, s.replicas,
            s.latency_mean_s.mean, s.latency_mean_s.stddev,
            s.latency_p99_s.p95,
            s.sla_attainment.mean * 100.0,
            s.sla_attainment.stddev * 100.0,
            s.throughput_rps.mean, s.throughput_rps.stddev,
            s.gpu_util.mean * 100.0, s.gpu_util.stddev * 100.0,
            s.swap_count.mean, s.swap_count.stddev));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn replica(label: &str, lat: f64, thr: f64) -> RunSummary {
        RunSummary {
            label: label.into(),
            mode: "cc".into(),
            pattern: "gamma".into(),
            strategy: "best-batch".into(),
            sla_s: 12.0,
            latency_mean_s: lat,
            latency_p99_s: lat * 2.0,
            sla_attainment: 0.5,
            throughput_rps: thr,
            gpu_util: 0.25,
            swap_count: 10,
            ..RunSummary::default()
        }
    }

    #[test]
    fn folds_replicas_by_label_in_order() {
        let cells = vec![
            replica("b", 2.0, 4.0),
            replica("b", 4.0, 6.0),
            replica("a", 1.0, 1.0),
        ];
        let stats = aggregate(&cells);
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].label, "b", "first appearance wins");
        assert_eq!(stats[0].replicas, 2);
        assert!((stats[0].latency_mean_s.mean - 3.0).abs() < 1e-12);
        assert!((stats[0].latency_mean_s.stddev - 1.0).abs() < 1e-12);
        assert!((stats[0].throughput_rps.mean - 5.0).abs() < 1e-12);
        assert_eq!(stats[1].replicas, 1);
        assert_eq!(stats[1].latency_mean_s.stddev, 0.0);
    }

    #[test]
    fn stat_quantiles() {
        let s = Stat::from_samples(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.p95, 5.0);
        assert_eq!(Stat::from_samples(&[]).mean, 0.0);
    }

    #[test]
    fn table_renders() {
        let stats = aggregate(&[replica("x", 2.0, 4.0)]);
        let t = stats_table(&stats);
        assert!(t.contains("| x | 1 |"), "{t}");
    }

    /// One seed: every spread statistic degenerates to the sample —
    /// stddev exactly 0, p50 == p95 == mean.
    #[test]
    fn single_seed_stddev_is_zero() {
        let stats = aggregate(&[replica("solo", 3.5, 7.0)]);
        assert_eq!(stats.len(), 1);
        let s = &stats[0];
        assert_eq!(s.replicas, 1);
        assert_eq!(s.latency_mean_s.stddev, 0.0);
        assert_eq!(s.throughput_rps.stddev, 0.0);
        assert_eq!(s.latency_mean_s.p50, 3.5);
        assert_eq!(s.latency_mean_s.p95, 3.5);
        assert_eq!(s.latency_mean_s.mean, 3.5);
    }

    /// Two seeds pin the quantile index rounding:
    /// `idx = round((n-1)·q)`, so with n = 2 both p50 (round(0.5) = 1,
    /// half away from zero) and p95 (round(0.95) = 1) land on the
    /// *larger* sample.
    #[test]
    fn two_seed_quantile_index_rounding() {
        let stats = aggregate(&[replica("pair", 2.0, 4.0),
                                replica("pair", 6.0, 8.0)]);
        let s = &stats[0];
        assert_eq!(s.replicas, 2);
        assert_eq!(s.latency_mean_s.p50, 6.0, "round half away from zero");
        assert_eq!(s.latency_mean_s.p95, 6.0);
        assert!((s.latency_mean_s.mean - 4.0).abs() < 1e-12);
        assert!((s.latency_mean_s.stddev - 2.0).abs() < 1e-12,
                "population stddev of {{2, 6}}");
        assert_eq!(s.throughput_rps.p50, 8.0);
    }

    /// Identical replicas must aggregate to exact, finite statistics —
    /// no NaN from 0/0 or a degenerate variance anywhere in the row.
    #[test]
    fn identical_replicas_aggregate_nan_free() {
        let cells: Vec<RunSummary> = (0..4)
            .map(|_| replica("same", 2.5, 5.0)).collect();
        let stats = aggregate(&cells);
        let s = &stats[0];
        assert_eq!(s.replicas, 4);
        for stat in [&s.latency_mean_s, &s.latency_p99_s,
                     &s.sla_attainment, &s.throughput_rps, &s.gpu_util,
                     &s.swap_count] {
            assert!(stat.mean.is_finite() && stat.stddev.is_finite()
                    && stat.p50.is_finite() && stat.p95.is_finite(),
                    "non-finite stat: {stat:?}");
            assert_eq!(stat.stddev, 0.0, "identical samples spread");
            assert_eq!(stat.p50, stat.p95, "quantiles of a constant");
        }
        assert_eq!(s.latency_mean_s.mean, 2.5);
        // the rendered table must carry no NaN either
        assert!(!stats_table(&stats).contains("NaN"));
    }
}
