//! `ScenarioSpec` — a declarative experiment grid over the run
//! configuration, parsed from JSON (or built in code by the presets).
//!
//! A spec names a set of *axes* (profile, mode, pattern, strategy,
//! SLA, rps, devices, placement, pipeline-depth, prefetch, data-path,
//! tokens-in/out), each with a list of
//! values; expansion takes the cross-product in the canonical
//! [`AXES`] order (mode varies slowest, exactly the legacy sweep's
//! nesting), prunes cells matched by *exclusion rules* (conjunctions
//! of axis=value), and replicates every surviving cell `seeds` times
//! with deterministic per-replica seeds ([`replica_seed`]).
//!
//! Determinism contract: the expanded cell list — order, labels,
//! per-cell configs and seeds — is a pure function of (spec, base
//! config).  The runner preserves that order whatever the thread
//! count, so a lab run's output bytes depend only on the spec, the
//! cost table and the base seed.
//!
//! Spec JSON schema (see `examples/lab_spec.json`):
//!
//! ```json
//! {
//!   "name": "my-experiment",
//!   "description": "optional free text",
//!   "base": {"duration": 30, "mean-rps": 6},
//!   "axes": {"mode": ["no-cc", "cc"], "sla": [12, 18, 24]},
//!   "exclude": [{"mode": "no-cc", "prefetch": "on"}],
//!   "seeds": 3
//! }
//! ```
//!
//! `base` entries are `RunConfig::set` key/value pairs applied on top
//! of the CLI config; axis values override both.  Unknown axis,
//! strategy, pattern or placement names fail expansion with the
//! valid-name table; an all-pruned grid is a hard error.

use std::collections::BTreeSet;
use std::path::Path;

use crate::config::RunConfig;
use crate::util::json::Json;

/// One sweepable axis: the spec-facing name and the `RunConfig::set`
/// key it drives, plus an optional name-table validator that runs per
/// distinct value at expansion time, so a bad name fails before any
/// cell runs.
pub struct AxisEntry {
    pub name: &'static str,
    pub key: &'static str,
    pub check: Option<fn(&str) -> anyhow::Result<()>>,
}

fn check_mode(v: &str) -> anyhow::Result<()> {
    crate::gpu::CcMode::parse(v).map(|_| ())
}

fn check_pattern(v: &str) -> anyhow::Result<()> {
    crate::traffic::pattern_by_name(v).map(|_| ())
}

fn check_strategy(v: &str) -> anyhow::Result<()> {
    crate::coordinator::strategy_by_name(v).map(|_| ())
}

fn check_placement(v: &str) -> anyhow::Result<()> {
    crate::coordinator::placement_by_name(v).map(|_| ())
}

fn check_admission(v: &str) -> anyhow::Result<()> {
    crate::tenancy::admission::admission_by_name(v).map(|_| ())
}

fn check_trace(v: &str) -> anyhow::Result<()> {
    crate::obs::TraceMode::parse(v).map(|_| ())
}

fn check_profile(v: &str) -> anyhow::Result<()> {
    for part in v.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        crate::gpu::profile::profile_by_name(part)?;
    }
    Ok(())
}

/// The axis table, in canonical cross-product order (first entry
/// varies slowest).  `profile` sits before `mode` so a swept mode is
/// applied after the profile and overrides its bundled default; the
/// mode/pattern/strategy/sla block matches the legacy hardcoded
/// sweep's loop nesting, so the `paper-72` preset reproduces its cell
/// order exactly.
pub const AXES: &[AxisEntry] = &[
    AxisEntry { name: "profile", key: "device-profiles",
                check: Some(check_profile) },
    AxisEntry { name: "mode", key: "mode", check: Some(check_mode) },
    AxisEntry { name: "pattern", key: "pattern",
                check: Some(check_pattern) },
    AxisEntry { name: "strategy", key: "strategy",
                check: Some(check_strategy) },
    AxisEntry { name: "sla", key: "sla", check: None },
    AxisEntry { name: "rps", key: "mean-rps", check: None },
    AxisEntry { name: "devices", key: "devices", check: None },
    AxisEntry { name: "placement", key: "placement",
                check: Some(check_placement) },
    AxisEntry { name: "stages", key: "pp-stages", check: None },
    AxisEntry { name: "pipeline-depth", key: "pipeline-depth",
                check: None },
    AxisEntry { name: "prefetch", key: "prefetch", check: None },
    AxisEntry { name: "data-path", key: "data-path", check: None },
    AxisEntry { name: "tokens-in", key: "data-tokens-in", check: None },
    AxisEntry { name: "tokens-out", key: "data-tokens-out", check: None },
    AxisEntry { name: "catalog-size", key: "catalog", check: None },
    AxisEntry { name: "zipf-skew", key: "zipf-skew", check: None },
    AxisEntry { name: "admission", key: "admission",
                check: Some(check_admission) },
    AxisEntry { name: "sla-classes", key: "sla-classes", check: None },
    AxisEntry { name: "trace", key: "trace", check: Some(check_trace) },
];

/// Valid axis names, in table order.
pub fn axis_names() -> Vec<&'static str> {
    AXES.iter().map(|a| a.name).collect()
}

/// Human hint for an axis's valid values (`lab list`).
pub fn axis_hint(name: &str) -> String {
    match name {
        "profile" => crate::gpu::profile::profile_names().join(" | "),
        "mode" => "no-cc | cc".to_string(),
        "pattern" => crate::traffic::PATTERN_NAMES.join(" | "),
        "strategy" => crate::coordinator::strategy_names().join(" | "),
        "sla" => "SLA seconds > 0 (paper ladder 12/18/24)".to_string(),
        "rps" => "mean requests/second > 0".to_string(),
        "devices" => "fleet size >= 1".to_string(),
        "placement" => crate::coordinator::placement_names().join(" | "),
        "stages" => {
            "pipeline-parallel stages per model (1 = off; needs \
             placement pipeline-parallel, devices % stages == 0)"
                .to_string()
        }
        "pipeline-depth" => {
            "0|1 = serialized, >= 2 = pipelined".to_string()
        }
        "prefetch" => "on | off".to_string(),
        "data-path" => {
            "on | off — price batch I/O through the CC bounce path"
                .to_string()
        }
        "tokens-in" => {
            "priced input tokens/request (default: model prompt_len)"
                .to_string()
        }
        "tokens-out" => {
            "priced output tokens/request (default: model decode_len)"
                .to_string()
        }
        "catalog-size" => {
            "0 = manifest models, N >= 1 = N-model synthetic catalog"
                .to_string()
        }
        "zipf-skew" => {
            "off | skew >= 0 — Zipf popularity over the model set"
                .to_string()
        }
        "admission" => {
            crate::tenancy::admission::admission_names().join(" | ")
        }
        "sla-classes" => {
            "on | off — gold/silver/free SLA classes".to_string()
        }
        "trace" => {
            "off | events | full — structured event trace (obs)"
                .to_string()
        }
        other => format!("unknown axis {other:?}"),
    }
}

/// Format a float the way `util::json` serializes it (`12`, not
/// `12.0`) — the canonical string form for axis values and labels.
pub fn fmt_num(x: f64) -> String {
    Json::num(x).to_string()
}

/// Read an axis's current value out of a config, in canonical string
/// form (the inverse of applying `AxisEntry::key` via `set`).
pub fn axis_value(cfg: &RunConfig, axis: &str) -> String {
    match axis {
        // unswept profile reads back as "" (no profile in force), so
        // profile-free grids keep their pre-profile labels and order
        "profile" => cfg.device_profiles.join(","),
        "mode" => cfg.mode.as_str().to_string(),
        "pattern" => cfg.pattern.clone(),
        "strategy" => cfg.strategy.clone(),
        "sla" => fmt_num(cfg.sla_s),
        "rps" => fmt_num(cfg.mean_rps),
        "devices" => cfg.devices.to_string(),
        "placement" => cfg.placement.clone(),
        "stages" => cfg.pp_stages.to_string(),
        "pipeline-depth" => cfg.gpu.pipeline_depth.to_string(),
        "prefetch" => {
            (if cfg.prefetch { "on" } else { "off" }).to_string()
        }
        "data-path" => {
            (if cfg.data_path { "on" } else { "off" }).to_string()
        }
        // unswept token axes read back as "" (no override in force);
        // swept values always canonicalize through `set`, so a rule
        // on these axes only ever matches swept cells
        "tokens-in" => cfg.data_tokens_in.map(|t| t.to_string())
            .unwrap_or_default(),
        "tokens-out" => cfg.data_tokens_out.map(|t| t.to_string())
            .unwrap_or_default(),
        "catalog-size" => cfg.catalog.to_string(),
        "zipf-skew" => cfg.zipf_skew.map(fmt_num)
            .unwrap_or_else(|| "off".to_string()),
        "admission" => cfg.admission.clone(),
        "sla-classes" => {
            (if cfg.sla_classes { "on" } else { "off" }).to_string()
        }
        "trace" => cfg.trace.as_str().to_string(),
        _ => String::new(),
    }
}

/// Deterministic seed of replica `r`: replica 0 is the configured
/// seed, so a 1-seed lab run reproduces the legacy serial sweep
/// exactly; further replicas use adjacent seeds, which `Pcg64::new`
/// decorrelates into independent streams.
pub fn replica_seed(base: u64, replica: usize) -> u64 {
    base.wrapping_add(replica as u64)
}

/// A declarative experiment grid (see the module docs for the JSON
/// schema).
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub name: String,
    pub description: String,
    /// `RunConfig::set` overrides applied before the axes.
    pub base: Vec<(String, String)>,
    /// Axis name -> value list; expansion order is canonical
    /// ([`AXES`]), not spec order.
    pub axes: Vec<(String, Vec<String>)>,
    /// Exclusion rules: a cell is pruned when *all* axis=value pairs
    /// of any rule match it.
    pub exclude: Vec<Vec<(String, String)>>,
    /// Seed-replication factor (>= 1).
    pub seeds: usize,
}

/// One expanded grid point: its unique label, ready-to-run config,
/// and the swept axis assignment that produced it.
#[derive(Debug, Clone)]
pub struct LabCell {
    pub label: String,
    pub cfg: RunConfig,
    pub assignment: Vec<(String, String)>,
}

/// One unit of runner work: a cell replica with its derived seed.
#[derive(Debug, Clone)]
pub struct LabJob {
    /// Index into [`Grid::cells`].
    pub cell: usize,
    pub replica: usize,
    pub cfg: RunConfig,
}

/// The expanded grid: cells in canonical order plus expansion
/// bookkeeping.
#[derive(Debug, Clone)]
pub struct Grid {
    pub spec_name: String,
    pub cells: Vec<LabCell>,
    /// Cells removed by exclusion rules.
    pub pruned: usize,
    /// The spec's replication factor (callers may override).
    pub seeds: usize,
}

impl Grid {
    /// Flatten the grid into runnable jobs, cell-major / replica-minor
    /// — the order every lab artifact (cells JSON, tables) uses.
    pub fn jobs(&self, seeds: usize) -> Vec<LabJob> {
        let seeds = seeds.max(1);
        let mut out = Vec::with_capacity(self.cells.len() * seeds);
        for (ci, cell) in self.cells.iter().enumerate() {
            for r in 0..seeds {
                let mut cfg = cell.cfg.clone();
                cfg.seed = replica_seed(cfg.seed, r);
                // replicas share the cell label, so only replica 0
                // writes the cell's on-disk artifacts (trace JSON,
                // waterfall CSV) — concurrent replicas must not race
                // on the same file names
                if r > 0 {
                    cfg.results_dir = None;
                }
                out.push(LabJob { cell: ci, replica: r, cfg });
            }
        }
        out
    }
}

fn stringify(v: &Json) -> String {
    match v {
        Json::Str(s) => s.clone(),
        other => other.to_string(),
    }
}

/// Canonicalize one axis value: apply it to a scratch config and read
/// it back, so `"12"`, `"12.0"` and `12` all normalize to the same
/// string (bad values error here, naming the axis).
fn canonical(base: &RunConfig, axis: &AxisEntry, value: &str)
             -> anyhow::Result<String> {
    let mut scratch = base.clone();
    scratch.set(axis.key, value)
        .map_err(|e| anyhow::anyhow!("axis {:?}: {e}", axis.name))?;
    Ok(axis_value(&scratch, axis.name))
}

impl ScenarioSpec {
    /// Parse a spec from its JSON form.
    pub fn parse(j: &Json) -> anyhow::Result<ScenarioSpec> {
        let obj = j.as_obj().ok_or_else(|| anyhow::anyhow!(
            "scenario spec must be a JSON object"))?;
        for k in obj.keys() {
            anyhow::ensure!(
                matches!(k.as_str(), "name" | "description" | "base"
                         | "axes" | "exclude" | "seeds"),
                "unknown spec key {k:?} \
                 (have name|description|base|axes|exclude|seeds)");
        }
        let name = j.get("name").and_then(|v| v.as_str())
            .unwrap_or("spec").to_string();
        let description = j.get("description").and_then(|v| v.as_str())
            .unwrap_or("").to_string();

        let mut base = Vec::new();
        if let Some(b) = j.get("base") {
            let bo = b.as_obj().ok_or_else(|| anyhow::anyhow!(
                "spec base must be an object of config overrides"))?;
            for (k, v) in bo {
                base.push((k.clone(), stringify(v)));
            }
        }

        let mut axes = Vec::new();
        if let Some(a) = j.get("axes") {
            let ao = a.as_obj().ok_or_else(|| anyhow::anyhow!(
                "spec axes must be an object of value arrays"))?;
            for (k, v) in ao {
                let arr = v.as_arr().ok_or_else(|| anyhow::anyhow!(
                    "axis {k:?} must be an array of values"))?;
                axes.push((k.clone(),
                           arr.iter().map(stringify).collect()));
            }
        }

        let mut exclude = Vec::new();
        if let Some(e) = j.get("exclude") {
            let arr = e.as_arr().ok_or_else(|| anyhow::anyhow!(
                "spec exclude must be an array of rule objects"))?;
            for r in arr {
                let ro = r.as_obj().ok_or_else(|| anyhow::anyhow!(
                    "each exclusion rule must be an object"))?;
                exclude.push(ro.iter()
                    .map(|(k, v)| (k.clone(), stringify(v)))
                    .collect());
            }
        }

        let seeds = match j.get("seeds") {
            None => 1,
            Some(v) => v.as_usize().ok_or_else(|| anyhow::anyhow!(
                "spec seeds must be a non-negative integer"))?,
        };

        Ok(ScenarioSpec { name, description, base, axes, exclude, seeds })
    }

    /// Parse a spec from a JSON file.
    pub fn from_file(path: &Path) -> anyhow::Result<ScenarioSpec> {
        Self::parse(&Json::parse_file(path)?)
    }

    /// Total cells before exclusions (product of axis value counts).
    pub fn raw_cells(&self) -> usize {
        self.axes.iter().map(|(_, v)| v.len().max(1)).product()
    }

    /// Expand the spec over a base config into the runnable grid.
    ///
    /// Errors on unknown axis names, bad axis values (with the
    /// valid-name table), empty axis value lists, duplicate cell
    /// labels, and grids where exclusions prune every cell.
    pub fn expand(&self, cli: &RunConfig) -> anyhow::Result<Grid> {
        anyhow::ensure!(self.seeds >= 1,
                        "spec {:?}: seeds must be >= 1", self.name);

        // spec base overrides on top of the CLI config
        let mut base = cli.clone();
        for (k, v) in &self.base {
            base.set(k, v).map_err(|e| anyhow::anyhow!(
                "spec {:?} base: {e}", self.name))?;
        }

        // every spec axis must be in the table, once
        let mut seen = BTreeSet::new();
        for (name, _) in &self.axes {
            anyhow::ensure!(AXES.iter().any(|a| a.name == name.as_str()),
                            "unknown axis {name:?} (have {:?})",
                            axis_names());
            anyhow::ensure!(seen.insert(name.clone()),
                            "axis {name:?} listed twice");
        }

        // per-axis canonical value lists, in canonical AXES order;
        // unswept axes contribute their base-config value
        let mut swept = Vec::with_capacity(AXES.len());
        let mut values: Vec<Vec<String>> = Vec::with_capacity(AXES.len());
        for ax in AXES {
            match self.axes.iter().find(|(n, _)| n.as_str() == ax.name) {
                Some((_, vals)) => {
                    anyhow::ensure!(!vals.is_empty(),
                                    "axis {:?} has no values", ax.name);
                    let mut canon = Vec::with_capacity(vals.len());
                    for v in vals {
                        if let Some(check) = ax.check {
                            check(v)?;
                        }
                        canon.push(canonical(&base, ax, v)?);
                    }
                    swept.push(true);
                    values.push(canon);
                }
                None => {
                    swept.push(false);
                    values.push(vec![axis_value(&base, ax.name)]);
                }
            }
        }

        // canonicalized exclusion rules as (axis index, value)
        let mut rules: Vec<Vec<(usize, String)>> = Vec::new();
        for rule in &self.exclude {
            anyhow::ensure!(!rule.is_empty(),
                            "spec {:?}: empty exclusion rule", self.name);
            let mut r = Vec::with_capacity(rule.len());
            for (name, v) in rule {
                let i = AXES.iter()
                    .position(|a| a.name == name.as_str())
                    .ok_or_else(|| anyhow::anyhow!(
                        "exclusion references unknown axis {name:?} \
                         (have {:?})", axis_names()))?;
                // rule values face the same name tables as axis values
                // — a typo must error, not silently never match
                if let Some(check) = AXES[i].check {
                    check(v)?;
                }
                r.push((i, canonical(&base, &AXES[i], v)?));
            }
            rules.push(r);
        }

        // cell_label() omits rps and placement; suffix them when swept
        // so every cell label stays unique
        let rps_i = AXES.iter().position(|a| a.name == "rps").unwrap();
        let plc_i = AXES.iter().position(|a| a.name == "placement")
            .unwrap();

        // odometer cross-product: AXES[0] varies slowest
        let mut cells = Vec::new();
        let mut pruned = 0usize;
        let mut labels = BTreeSet::new();
        let mut idx = vec![0usize; AXES.len()];
        'grid: loop {
            let excluded = rules.iter().any(|r| {
                r.iter().all(|(a, v)| values[*a][idx[*a]] == *v)
            });
            if excluded {
                pruned += 1;
            } else {
                let mut cfg = base.clone();
                for (a, ax) in AXES.iter().enumerate() {
                    if swept[a] {
                        cfg.set(ax.key, &values[a][idx[a]])?;
                    }
                }
                // like the legacy sweep, cells never write per-run
                // CSVs; the lab persists one aggregate artifact.
                // Traced cells are the exception: their trace files
                // (`<label>_trace.json`, `<label>_waterfall.csv`) only
                // exist on disk, so they keep the inherited results
                // dir — trace-off cells stay exactly as before
                if !cfg.trace.is_on() {
                    cfg.results_dir = None;
                }
                let mut label = cfg.cell_label();
                if swept[rps_i] {
                    label.push_str(
                        &format!("_rps{}", values[rps_i][idx[rps_i]]));
                }
                if swept[plc_i] {
                    label.push('_');
                    label.push_str(&cfg.placement);
                }
                cfg.label = label.clone();
                cfg.validate().map_err(|e| anyhow::anyhow!(
                    "cell {label}: {e}"))?;
                anyhow::ensure!(
                    labels.insert(label.clone()),
                    "duplicate cell label {label:?} — the swept axes do \
                     not distinguish these cells");
                let assignment = AXES.iter().enumerate()
                    .filter(|(a, _)| swept[*a])
                    .map(|(a, ax)| (ax.name.to_string(),
                                    values[a][idx[a]].clone()))
                    .collect();
                cells.push(LabCell { label, cfg, assignment });
            }

            // increment the odometer from the fastest axis
            let mut a = AXES.len();
            loop {
                if a == 0 {
                    break 'grid;
                }
                a -= 1;
                idx[a] += 1;
                if idx[a] < values[a].len() {
                    break;
                }
                idx[a] = 0;
            }
        }

        anyhow::ensure!(
            !cells.is_empty(),
            "spec {:?} expands to an empty grid (exclusions pruned all \
             {pruned} cells)", self.name);
        Ok(Grid {
            spec_name: self.name.clone(),
            cells,
            pruned,
            seeds: self.seeds,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn axis(name: &str, vals: &[&str]) -> (String, Vec<String>) {
        (name.to_string(),
         vals.iter().map(|v| v.to_string()).collect())
    }

    fn two_by_two() -> ScenarioSpec {
        ScenarioSpec {
            name: "t".into(),
            description: String::new(),
            base: Vec::new(),
            axes: vec![axis("mode", &["no-cc", "cc"]),
                       axis("sla", &["12", "18"])],
            exclude: Vec::new(),
            seeds: 1,
        }
    }

    #[test]
    fn canonical_order_mode_slowest() {
        let g = two_by_two().expand(&RunConfig::default()).unwrap();
        let labels: Vec<&str> =
            g.cells.iter().map(|c| c.label.as_str()).collect();
        assert_eq!(labels, vec![
            "no-cc_gamma_select-batch+timer_sla12",
            "no-cc_gamma_select-batch+timer_sla18",
            "cc_gamma_select-batch+timer_sla12",
            "cc_gamma_select-batch+timer_sla18",
        ]);
        assert_eq!(g.pruned, 0);
    }

    #[test]
    fn axis_values_normalize() {
        let mut s = two_by_two();
        s.axes[1] = axis("sla", &["12.0", "18"]);
        let g = s.expand(&RunConfig::default()).unwrap();
        assert_eq!(g.cells[0].assignment,
                   vec![("mode".to_string(), "no-cc".to_string()),
                        ("sla".to_string(), "12".to_string())]);
    }

    #[test]
    fn cells_apply_mode_to_gpu_too() {
        let g = two_by_two().expand(&RunConfig::default()).unwrap();
        let cc = &g.cells[2].cfg;
        assert_eq!(cc.mode, crate::gpu::CcMode::On);
        assert_eq!(cc.gpu.mode, crate::gpu::CcMode::On);
        assert!(cc.results_dir.is_none());
    }

    #[test]
    fn swept_rps_and_placement_reach_the_label() {
        let mut s = two_by_two();
        s.axes = vec![axis("rps", &["6", "9"]),
                      axis("devices", &["2"]),
                      axis("placement", &["affinity", "least-loaded"])];
        let g = s.expand(&RunConfig::default()).unwrap();
        assert_eq!(g.cells.len(), 4);
        assert!(g.cells[0].label.contains("_rps6"));
        assert!(g.cells[1].label.ends_with("least-loaded"),
                "{}", g.cells[1].label);
    }

    #[test]
    fn data_path_axes_reach_config_and_label() {
        let mut s = two_by_two();
        s.axes = vec![axis("data-path", &["off", "on"]),
                      axis("tokens-in", &["16", "512"]),
                      axis("tokens-out", &["50"])];
        let g = s.expand(&RunConfig::default()).unwrap();
        assert_eq!(g.cells.len(), 4);
        // canonical order: data-path varies slower than tokens-in
        assert!(!g.cells[0].cfg.data_path);
        assert_eq!(g.cells[0].cfg.data_tokens_in, Some(16));
        assert!(g.cells[0].label.ends_with("_tin16_tout50"),
                "{}", g.cells[0].label);
        let on = &g.cells[3];
        assert!(on.cfg.data_path);
        assert_eq!(on.cfg.data_tokens_in, Some(512));
        assert_eq!(on.cfg.data_tokens_out, Some(50));
        assert!(on.label.contains("_io_tin512_tout50"),
                "{}", on.label);
        assert_eq!(on.assignment, vec![
            ("data-path".to_string(), "on".to_string()),
            ("tokens-in".to_string(), "512".to_string()),
            ("tokens-out".to_string(), "50".to_string()),
        ]);
    }

    #[test]
    fn tenancy_axes_reach_config_and_label() {
        let mut s = two_by_two();
        s.axes = vec![axis("catalog-size", &["0", "6"]),
                      axis("zipf-skew", &["off", "1.1"]),
                      axis("admission", &["none", "queue-cap"]),
                      axis("sla-classes", &["off", "on"])];
        let g = s.expand(&RunConfig::default()).unwrap();
        assert_eq!(g.cells.len(), 16);
        // all-off corner is the plain legacy cell
        let first = &g.cells[0];
        assert_eq!(first.cfg.catalog, 0);
        assert!(first.cfg.zipf_skew.is_none());
        assert_eq!(first.cfg.admission, "none");
        assert!(!first.cfg.sla_classes);
        assert!(!first.label.contains("cat")
                && !first.label.contains("zipf")
                && !first.label.contains("adm"), "{}", first.label);
        // all-on corner carries every fragment
        let last = &g.cells[15];
        assert_eq!(last.cfg.catalog, 6);
        assert_eq!(last.cfg.zipf_skew, Some(1.1));
        assert_eq!(last.cfg.admission, "queue-cap");
        assert!(last.cfg.sla_classes);
        assert!(last.label.contains("_cat6")
                && last.label.contains("_zipf1.1")
                && last.label.contains("_adm-queue-cap")
                && last.label.ends_with("_cls"), "{}", last.label);
        assert_eq!(last.assignment, vec![
            ("catalog-size".to_string(), "6".to_string()),
            ("zipf-skew".to_string(), "1.1".to_string()),
            ("admission".to_string(), "queue-cap".to_string()),
            ("sla-classes".to_string(), "on".to_string()),
        ]);
        // bad admission names fail expansion with the name table
        s.axes = vec![axis("admission", &["vip-only"])];
        let err = s.expand(&RunConfig::default()).unwrap_err()
            .to_string();
        assert!(err.contains("vip-only") && err.contains("queue-cap"),
                "{err}");
    }

    #[test]
    fn profile_axis_reaches_config_and_label() {
        let mut s = two_by_two();
        s.axes = vec![axis("profile",
                           &["h100-cc", "b300-cc", "gh200-coherent"]),
                      axis("mode", &["no-cc", "cc"])];
        let g = s.expand(&RunConfig::default()).unwrap();
        assert_eq!(g.cells.len(), 6);
        // profile varies slowest; the swept mode is applied after the
        // profile and wins over its bundled default
        let first = &g.cells[0];
        assert_eq!(first.cfg.device_profiles,
                   vec!["h100-cc".to_string()]);
        assert_eq!(first.cfg.mode, crate::gpu::CcMode::Off,
                   "the swept mode wins over the profile's mode");
        assert!(first.label.starts_with("no-cc_")
                    && first.label.contains("_prof-h100-cc"),
                "{}", first.label);
        assert_eq!(first.assignment[0],
                   ("profile".to_string(), "h100-cc".to_string()));
        let last = &g.cells[5];
        assert_eq!(last.cfg.mode, crate::gpu::CcMode::On);
        assert!(last.cfg.fleet_configs()[0].uma);
        assert!(last.label.contains("_prof-gh200-coherent"),
                "{}", last.label);
        // bad profile names fail expansion with the table
        s.axes = vec![axis("profile", &["a100"])];
        let err = s.expand(&RunConfig::default()).unwrap_err()
            .to_string();
        assert!(err.contains("a100") && err.contains("b300-cc"),
                "{err}");
    }

    #[test]
    fn stages_axis_reaches_config_and_label() {
        let mut s = two_by_two();
        s.axes = vec![axis("mode", &["no-cc", "cc"]),
                      axis("devices", &["4"]),
                      axis("placement", &["pipeline-parallel"]),
                      axis("stages", &["1", "2", "4"])];
        let g = s.expand(&RunConfig::default()).unwrap();
        assert_eq!(g.cells.len(), 6);
        // stage 1 is off: no label fragment, exactly the legacy cell
        let off = &g.cells[0];
        assert_eq!(off.cfg.pp_stages, 1);
        assert!(!off.label.contains("_pp"), "{}", off.label);
        // swept stages reach the config and the label fragment
        let on = &g.cells[2];
        assert_eq!(on.cfg.pp_stages, 4);
        assert!(on.label.contains("_pp4"), "{}", on.label);
        assert_eq!(on.assignment[3],
                   ("stages".to_string(), "4".to_string()));
        // cells that violate the pp constraints fail expansion with
        // the cell label, not at run time
        s.axes = vec![axis("devices", &["4"]),
                      axis("stages", &["2"])];
        let err = s.expand(&RunConfig::default()).unwrap_err()
            .to_string();
        assert!(err.contains("pipeline-parallel"), "{err}");
    }

    #[test]
    fn trace_axis_reaches_config_and_label() {
        let mut s = two_by_two();
        s.axes = vec![axis("mode", &["no-cc", "cc"]),
                      axis("trace", &["off", "full"])];
        let g = s.expand(&RunConfig::default()).unwrap();
        assert_eq!(g.cells.len(), 4);
        // trace-off cells stay the plain legacy cell: no label fragment
        // and no results_dir, exactly like an untraced sweep
        let off = &g.cells[0];
        assert_eq!(off.cfg.trace, crate::obs::TraceMode::Off);
        assert!(!off.label.contains("_tr-"), "{}", off.label);
        assert!(off.cfg.results_dir.is_none());
        // traced cells carry the fragment and keep the inherited
        // results_dir — the trace artifacts only exist on disk
        let mut base = RunConfig::default();
        base.results_dir = Some(std::path::PathBuf::from("results-x"));
        let g = s.expand(&base).unwrap();
        let on = &g.cells[1];
        assert_eq!(on.cfg.trace, crate::obs::TraceMode::Full);
        assert!(on.label.ends_with("_tr-full"), "{}", on.label);
        assert_eq!(on.cfg.results_dir,
                   Some(std::path::PathBuf::from("results-x")));
        assert_eq!(on.assignment[1],
                   ("trace".to_string(), "full".to_string()));
        // replicas share the cell label, so only replica 0 keeps the
        // dir — no two jobs may race on the same artifact files
        let jobs = g.jobs(2);
        assert!(jobs[2].cfg.results_dir.is_some()
                    && jobs[3].cfg.results_dir.is_none(),
                "only replica 0 writes trace artifacts");
        // bad trace values fail expansion with the mode table
        s.axes = vec![axis("trace", &["verbose"])];
        let err = s.expand(&RunConfig::default()).unwrap_err()
            .to_string();
        assert!(err.contains("verbose") && err.contains("events"),
                "{err}");
    }

    #[test]
    fn replica_seed_zero_is_base() {
        assert_eq!(replica_seed(42, 0), 42);
        assert_eq!(replica_seed(42, 3), 45);
        assert_eq!(replica_seed(u64::MAX, 1), 0, "wraps, never panics");
    }

    #[test]
    fn jobs_multiply_cells_by_seeds() {
        let g = two_by_two().expand(&RunConfig::default()).unwrap();
        let jobs = g.jobs(3);
        assert_eq!(jobs.len(), 4 * 3);
        // cell-major, replica-minor; replica 0 keeps the base seed
        assert_eq!((jobs[0].cell, jobs[0].replica), (0, 0));
        assert_eq!((jobs[2].cell, jobs[2].replica), (0, 2));
        assert_eq!(jobs[0].cfg.seed, 42);
        assert_eq!(jobs[1].cfg.seed, 43);
        assert_eq!(jobs[3].cfg.seed, 42);
    }

    #[test]
    fn unknown_axis_lists_the_table() {
        let mut s = two_by_two();
        s.axes.push(axis("frequency", &["1"]));
        let err = s.expand(&RunConfig::default()).unwrap_err()
            .to_string();
        assert!(err.contains("frequency") && err.contains("mode")
                && err.contains("pipeline-depth"), "{err}");
    }

    #[test]
    fn bad_strategy_value_lists_the_table() {
        let mut s = two_by_two();
        s.axes.push(axis("strategy", &["nope"]));
        let err = s.expand(&RunConfig::default()).unwrap_err()
            .to_string();
        assert!(err.contains("nope")
                && err.contains("select-batch+timer"), "{err}");
    }

    #[test]
    fn exclusions_prune() {
        let mut s = two_by_two();
        s.exclude = vec![vec![("mode".into(), "cc".into()),
                              ("sla".into(), "12".into())]];
        let g = s.expand(&RunConfig::default()).unwrap();
        assert_eq!(g.cells.len(), 3);
        assert_eq!(g.pruned, 1);
        assert!(g.cells.iter()
            .all(|c| c.label != "cc_gamma_select-batch+timer_sla12"));
    }

    #[test]
    fn exclusion_rule_values_face_the_name_tables() {
        let mut s = two_by_two();
        s.exclude = vec![vec![("strategy".into(),
                               "bset-batch".into())]];
        let err = s.expand(&RunConfig::default()).unwrap_err()
            .to_string();
        assert!(err.contains("bset-batch")
                && err.contains("best-batch"), "{err}");
    }

    #[test]
    fn all_pruned_is_a_hard_error() {
        let mut s = two_by_two();
        s.exclude = vec![vec![("mode".into(), "no-cc".into())],
                         vec![("mode".into(), "cc".into())]];
        let err = s.expand(&RunConfig::default()).unwrap_err()
            .to_string();
        assert!(err.contains("empty grid"), "{err}");
    }

    #[test]
    fn empty_axis_is_a_hard_error() {
        let mut s = two_by_two();
        s.axes[0].1.clear();
        assert!(s.expand(&RunConfig::default()).is_err());
    }

    #[test]
    fn parse_roundtrips_the_schema() {
        let j = Json::parse(
            r#"{"name":"x","description":"d",
                "base":{"duration":30,"mean-rps":6},
                "axes":{"mode":["no-cc","cc"],"sla":[12,18]},
                "exclude":[{"mode":"cc","sla":12}],
                "seeds":3}"#).unwrap();
        let s = ScenarioSpec::parse(&j).unwrap();
        assert_eq!(s.name, "x");
        assert_eq!(s.seeds, 3);
        assert_eq!(s.raw_cells(), 4);
        let g = s.expand(&RunConfig::default()).unwrap();
        assert_eq!(g.cells.len(), 3);
        assert!((g.cells[0].cfg.duration_s - 30.0).abs() < 1e-12,
                "base override applies");
    }

    #[test]
    fn parse_rejects_unknown_keys() {
        let j = Json::parse(r#"{"name":"x","axis":{}}"#).unwrap();
        let err = ScenarioSpec::parse(&j).unwrap_err().to_string();
        assert!(err.contains("axis"), "{err}");
    }
}
