//! Built-in named scenario presets (`sincere lab run --preset NAME`).
//!
//! The table is the single source of truth for `preset_by_name`,
//! `lab list`, and the unknown-name error, like `STRATEGIES` and
//! `PLACEMENTS`.  `paper-72` is built from the same name tables the
//! legacy hardcoded sweep looped over (`strategy_names`,
//! `PATTERN_NAMES`, `SLA_LADDER`), so `sweep` — now an alias for this
//! preset — keeps its exact historical cell order.

use crate::config::SLA_LADDER;
use crate::coordinator::strategy_names;
use crate::lab::spec::{fmt_num, ScenarioSpec};
use crate::traffic::PATTERN_NAMES;

/// One named preset: CLI name, help blurb, and constructor.
pub struct PresetEntry {
    pub name: &'static str,
    pub blurb: &'static str,
    pub make: fn() -> ScenarioSpec,
}

/// The preset table, in display order.
pub const PRESETS: &[PresetEntry] = &[
    PresetEntry {
        name: "paper-72",
        blurb: "the paper's full grid: mode x pattern x strategy x SLA \
                (Fig 5-7)",
        make: paper_72,
    },
    PresetEntry {
        name: "smoke",
        blurb: "4 cells x 2 seeds in ~80 virtual seconds (CI + quick \
                sanity)",
        make: smoke,
    },
    PresetEntry {
        name: "fleet-mix",
        blurb: "placement policies across fleet sizes, CC vs No-CC \
                (exclusions drop the placement-invariant devices=1 \
                duplicates)",
        make: fleet_mix,
    },
    PresetEntry {
        name: "cc-recovery",
        blurb: "how much of the CC swap penalty the DMA pipeline and \
                predictive prefetch recover, 3 seeds",
        make: cc_recovery,
    },
    PresetEntry {
        name: "cc-io",
        blurb: "prompt/output-size sensitivity of the CC-priced batch \
                I/O data path (--data-path), vs No-CC and flag-off \
                baselines",
        make: cc_io,
    },
    PresetEntry {
        name: "tenancy",
        blurb: "multi-tenant smoke: catalog size x Zipf skew x \
                admission policy x SLA classes under diurnal traffic, \
                with plain-serving baselines",
        make: tenancy,
    },
    PresetEntry {
        name: "hw-gen",
        blurb: "the CC tax across hardware generations: device profile \
                (h100-cc, b300-cc, gh200-coherent) x mode x strategy \
                at smoke scale",
        make: hw_gen,
    },
    PresetEntry {
        name: "pp-scaling",
        blurb: "pipeline-parallel stage scaling: stage count x mode x \
                hardware generation on a 4-device fleet, feeding the \
                CC-tax-by-stage-count table",
        make: pp_scaling,
    },
    PresetEntry {
        name: "cc-attribution",
        blurb: "where the seconds go: full event tracing over mode x \
                profile x pipeline-depth at smoke scale, feeding the \
                latency-waterfall table and Perfetto traces",
        make: cc_attribution,
    },
];

/// Valid preset names, in table order.
pub fn preset_names() -> Vec<&'static str> {
    PRESETS.iter().map(|p| p.name).collect()
}

/// Instantiate a preset by CLI name.
pub fn preset_by_name(name: &str) -> anyhow::Result<ScenarioSpec> {
    PRESETS.iter().find(|p| p.name == name).map(|p| (p.make)())
        .ok_or_else(|| anyhow::anyhow!(
            "unknown preset {name:?} (have {:?})", preset_names()))
}

fn axis(name: &str, vals: &[&str]) -> (String, Vec<String>) {
    (name.to_string(), vals.iter().map(|v| v.to_string()).collect())
}

fn owned_axis(name: &str, vals: Vec<String>) -> (String, Vec<String>) {
    (name.to_string(), vals)
}

fn rule(pairs: &[(&str, &str)]) -> Vec<(String, String)> {
    pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
}

fn paper_72() -> ScenarioSpec {
    ScenarioSpec {
        name: "paper-72".into(),
        description: "the paper's full evaluation grid (Fig 5-7): \
                      2 modes x 3 patterns x 4 strategies x 3 SLAs"
            .into(),
        base: Vec::new(),
        axes: vec![
            axis("mode", &["no-cc", "cc"]),
            owned_axis("pattern", PATTERN_NAMES.iter()
                .map(|s| s.to_string()).collect()),
            owned_axis("strategy", strategy_names().iter()
                .map(|s| s.to_string()).collect()),
            owned_axis("sla", SLA_LADDER.iter().copied().map(fmt_num)
                .collect()),
        ],
        exclude: Vec::new(),
        seeds: 1,
    }
}

fn smoke() -> ScenarioSpec {
    ScenarioSpec {
        name: "smoke".into(),
        description: "tiny deterministic grid: 2 modes x 2 strategies, \
                      2 seeds, 20 virtual seconds per cell".into(),
        base: vec![
            ("duration".into(), "20".into()),
            ("drain".into(), "8".into()),
            ("mean-rps".into(), "4".into()),
            ("sla".into(), "6".into()),
            ("models".into(), "llama-sim,gemma-sim".into()),
        ],
        axes: vec![
            axis("mode", &["no-cc", "cc"]),
            axis("strategy", &["select-batch+timer",
                               "best-batch+timer"]),
        ],
        exclude: Vec::new(),
        seeds: 2,
    }
}

fn fleet_mix() -> ScenarioSpec {
    ScenarioSpec {
        name: "fleet-mix".into(),
        description: "fleet scaling under overload: placement policies \
                      x {1,2,4} devices x mode; devices=1 keeps only \
                      affinity (placement-invariant)".into(),
        base: vec![("mean-rps".into(), "18".into())],
        axes: vec![
            axis("mode", &["no-cc", "cc"]),
            axis("devices", &["1", "2", "4"]),
            axis("placement",
                 &["affinity", "round-robin", "least-loaded"]),
        ],
        exclude: vec![
            rule(&[("devices", "1"), ("placement", "round-robin")]),
            rule(&[("devices", "1"), ("placement", "least-loaded")]),
        ],
        seeds: 1,
    }
}

fn cc_recovery() -> ScenarioSpec {
    ScenarioSpec {
        name: "cc-recovery".into(),
        description: "CC swap-penalty recovery: DMA pipeline x \
                      predictive prefetch across two patterns".into(),
        base: vec![("mode".into(), "cc".into())],
        axes: vec![
            axis("pattern", &["gamma", "bursty"]),
            axis("pipeline-depth", &["0", "2"]),
            axis("prefetch", &["off", "on"]),
        ],
        exclude: Vec::new(),
        seeds: 3,
    }
}

fn cc_io() -> ScenarioSpec {
    ScenarioSpec {
        name: "cc-io".into(),
        description: "the second pillar of the CC gap: per-batch \
                      request/response payloads priced through the \
                      encrypted bounce path; sweeps prompt (tokens-in) \
                      and output (tokens-out) sizes in both modes, \
                      keeping one flag-off baseline cell per mode at \
                      the models' native payload shape".into(),
        base: vec![
            ("duration".into(), "30".into()),
            ("drain".into(), "12".into()),
            ("mean-rps".into(), "6".into()),
            ("models".into(), "llama-sim,gemma-sim".into()),
        ],
        axes: vec![
            axis("mode", &["no-cc", "cc"]),
            axis("data-path", &["off", "on"]),
            axis("tokens-in", &["16", "512", "4096"]),
            axis("tokens-out", &["50", "1024"]),
        ],
        // the flag-off baseline is payload-size-insensitive by
        // construction — keep exactly one off cell per mode
        exclude: vec![
            rule(&[("data-path", "off"), ("tokens-in", "512")]),
            rule(&[("data-path", "off"), ("tokens-in", "4096")]),
            rule(&[("data-path", "off"), ("tokens-out", "1024")]),
        ],
        seeds: 2,
    }
}

fn tenancy() -> ScenarioSpec {
    ScenarioSpec {
        name: "tenancy".into(),
        description: "multi-tenant catalog serving at smoke scale: \
                      {manifest, 6-model catalog} x Zipf popularity \
                      {off, 1.1} x every admission policy x SLA \
                      classes {off, on}, all under a diurnal sinusoid \
                      with a mid-run flash crowd; admission=none cells \
                      stay classes-off, so they are byte-identical \
                      plain-serving baselines with no tenancy keys"
            .into(),
        base: vec![
            ("duration".into(), "20".into()),
            ("drain".into(), "8".into()),
            ("mean-rps".into(), "4".into()),
            ("sla".into(), "6".into()),
            ("models".into(), "llama-sim,gemma-sim".into()),
            ("mode".into(), "cc".into()),
            ("diurnal-amp".into(), "0.3".into()),
            ("flash-mult".into(), "2".into()),
            ("flash-start".into(), "6".into()),
            ("flash-dur".into(), "4".into()),
        ],
        axes: vec![
            axis("catalog-size", &["0", "6"]),
            axis("zipf-skew", &["off", "1.1"]),
            axis("admission", &["none", "queue-cap",
                                "deadline-infeasible",
                                "class-weighted"]),
            axis("sla-classes", &["off", "on"]),
        ],
        // keep the gate-off cells tenancy-free: classes alone would
        // attach a tenancy block to an otherwise-baseline cell
        exclude: vec![
            rule(&[("admission", "none"), ("sla-classes", "on")]),
        ],
        seeds: 1,
    }
}

fn hw_gen() -> ScenarioSpec {
    ScenarioSpec {
        name: "hw-gen".into(),
        description: "how the CC tax moves across hardware \
                      generations: Hopper pays the full chunk-crypto \
                      recurrence, Blackwell shrinks it to a 25% \
                      residual plus a per-swap bridge constant, and \
                      coherent Grace-Hopper replaces swap crypto with \
                      the bridge constant alone; the swept mode gives \
                      every profile its No-CC twin for the gap table"
            .into(),
        base: vec![
            ("duration".into(), "20".into()),
            ("drain".into(), "8".into()),
            ("mean-rps".into(), "4".into()),
            ("sla".into(), "6".into()),
            ("models".into(), "llama-sim,gemma-sim".into()),
        ],
        axes: vec![
            axis("profile", &["h100-cc", "b300-cc", "gh200-coherent"]),
            axis("mode", &["no-cc", "cc"]),
            axis("strategy", &["select-batch+timer",
                               "best-batch+timer"]),
        ],
        exclude: Vec::new(),
        seeds: 1,
    }
}

fn pp_scaling() -> ScenarioSpec {
    ScenarioSpec {
        name: "pp-scaling".into(),
        description: "how the CC tax grows with pipeline-parallel \
                      stage count, and which hardware generation \
                      flattens it: every cell runs the smoke workload \
                      on a 4-device fleet under the pipeline-parallel \
                      placement; stages=1 is the unsharded baseline \
                      (byte-identical to a pp-free run), 2 and 4 shard \
                      each model's layers across stage groups and \
                      price the per-microbatch activation handoffs — \
                      sealed nonce|ct|tag frames on CC links, plain on \
                      No-CC, free on the coherent profile; the swept \
                      mode gives every (profile, stages) point its \
                      No-CC twin for the stage-count tax table".into(),
        base: vec![
            ("duration".into(), "20".into()),
            ("drain".into(), "8".into()),
            ("mean-rps".into(), "4".into()),
            ("sla".into(), "6".into()),
            ("models".into(), "llama-sim,gemma-sim".into()),
            ("devices".into(), "4".into()),
            ("placement".into(), "pipeline-parallel".into()),
        ],
        axes: vec![
            axis("profile", &["h100-cc", "b300-cc", "gh200-coherent"]),
            axis("mode", &["no-cc", "cc"]),
            axis("stages", &["1", "2", "4"]),
        ],
        exclude: Vec::new(),
        seeds: 1,
    }
}

fn cc_attribution() -> ScenarioSpec {
    ScenarioSpec {
        name: "cc-attribution".into(),
        description: "per-phase attribution of the CC tax: every cell \
                      runs with --trace full, so the report's latency \
                      waterfall splits the gap into queue wait, swap \
                      unload/load (with bridge and exposed-crypto \
                      attribution inside the load), exec, and data-path \
                      I/O; profiles move the tax between phases and the \
                      DMA pipeline shows how much of the load column it \
                      recovers; No-CC needs no pipeline cell and the \
                      coherent profile has no chunk crypto to pipeline"
            .into(),
        base: vec![
            ("duration".into(), "20".into()),
            ("drain".into(), "8".into()),
            ("mean-rps".into(), "4".into()),
            ("sla".into(), "6".into()),
            ("models".into(), "llama-sim,gemma-sim".into()),
            ("trace".into(), "full".into()),
        ],
        axes: vec![
            axis("profile", &["h100-cc", "b300-cc", "gh200-coherent"]),
            axis("mode", &["no-cc", "cc"]),
            axis("pipeline-depth", &["0", "2"]),
        ],
        exclude: vec![
            rule(&[("mode", "no-cc"), ("pipeline-depth", "2")]),
            rule(&[("profile", "gh200-coherent"),
                   ("pipeline-depth", "2")]),
        ],
        seeds: 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    #[test]
    fn preset_names_unique_and_resolvable() {
        let mut names = preset_names();
        let n = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), n);
        for p in PRESETS {
            preset_by_name(p.name).unwrap();
        }
        let err = preset_by_name("nope").unwrap_err().to_string();
        assert!(err.contains("paper-72"), "{err}");
    }

    #[test]
    fn every_preset_expands() {
        let cli = RunConfig::default();
        for p in PRESETS {
            let g = (p.make)().expand(&cli)
                .unwrap_or_else(|e| panic!("{}: {e}", p.name));
            assert!(!g.cells.is_empty(), "{}", p.name);
        }
    }

    #[test]
    fn paper_72_matches_the_legacy_sweep() {
        let g = paper_72().expand(&RunConfig::default()).unwrap();
        assert_eq!(g.cells.len(), 72);
        assert_eq!(g.seeds, 1);
        // the legacy loop nested mode > pattern > strategy > sla
        assert_eq!(g.cells[0].label, "no-cc_gamma_best-batch_sla12");
        assert_eq!(g.cells[1].label, "no-cc_gamma_best-batch_sla18");
        assert_eq!(g.cells[3].label,
                   "no-cc_gamma_best-batch+timer_sla12");
        assert_eq!(g.cells[36].label, "cc_gamma_best-batch_sla12");
        assert_eq!(g.cells[71].label,
                   "cc_ramp_best-batch+partial+timer_sla24");
    }

    #[test]
    fn smoke_is_4_cells_2_seeds() {
        let g = smoke().expand(&RunConfig::default()).unwrap();
        assert_eq!(g.cells.len(), 4);
        assert_eq!(g.seeds, 2);
        assert_eq!(g.jobs(g.seeds).len(), 8);
    }

    #[test]
    fn fleet_mix_prunes_devices_1_duplicates() {
        let g = fleet_mix().expand(&RunConfig::default()).unwrap();
        assert_eq!(g.pruned, 4);
        assert_eq!(g.cells.len(), 14);
    }

    #[test]
    fn tenancy_baselines_never_carry_classes() {
        let g = tenancy().expand(&RunConfig::default()).unwrap();
        // 2 catalog x 2 zipf x 4 admission x 2 classes, minus the
        // (none, classes-on) column
        assert_eq!(g.pruned, 4);
        assert_eq!(g.cells.len(), 28);
        assert_eq!(g.seeds, 1);
        let baselines: Vec<_> = g.cells.iter()
            .filter(|c| c.cfg.admission == "none").collect();
        assert_eq!(baselines.len(), 4, "one per catalog x zipf corner");
        assert!(baselines.iter().all(|c| !c.cfg.sla_classes),
                "admission-off cells must stay tenancy-off");
        // diurnal + flash ride along in every cell
        assert!(g.cells.iter().all(
            |c| c.cfg.diurnal_amp > 0.0 && c.cfg.flash_mult > 1.0));
        assert!(g.cells.iter().any(
            |c| c.cfg.catalog == 6 && c.cfg.zipf_skew == Some(1.1)
                && c.cfg.admission == "class-weighted"
                && c.cfg.sla_classes));
    }

    #[test]
    fn hw_gen_pairs_every_profile_with_a_no_cc_twin() {
        let g = hw_gen().expand(&RunConfig::default()).unwrap();
        // 3 profiles x 2 modes x 2 strategies
        assert_eq!(g.cells.len(), 12);
        assert_eq!(g.pruned, 0);
        assert_eq!(g.seeds, 1);
        // every cell carries exactly one profile and the _prof- tag
        assert!(g.cells.iter().all(
            |c| c.cfg.device_profiles.len() == 1
                && c.label.contains("_prof-")));
        // the swept mode overrides the profile's bundled CC default,
        // so each profile gets a No-CC twin
        for prof in ["h100-cc", "b300-cc", "gh200-coherent"] {
            let modes: Vec<_> = g.cells.iter()
                .filter(|c| c.cfg.device_profiles[0] == prof)
                .map(|c| c.cfg.mode).collect();
            assert!(modes.contains(&crate::gpu::CcMode::Off)
                        && modes.contains(&crate::gpu::CcMode::On),
                    "{prof} must appear in both modes");
        }
        // the coherent profile reaches the fleet config
        assert!(g.cells.iter().any(
            |c| c.cfg.fleet_configs()[0].uma));
    }

    #[test]
    fn pp_scaling_anchors_every_profile_at_one_stage() {
        let g = pp_scaling().expand(&RunConfig::default()).unwrap();
        // 3 profiles x 2 modes x 3 stage counts
        assert_eq!(g.cells.len(), 18);
        assert_eq!(g.pruned, 0);
        assert_eq!(g.seeds, 1);
        assert!(g.cells.iter().all(
            |c| c.cfg.devices == 4
                && c.cfg.placement == "pipeline-parallel"),
                "every cell runs the 4-device pp fleet");
        // stages=1 baselines carry no _pp fragment; sharded cells do
        let ones: Vec<_> = g.cells.iter()
            .filter(|c| c.cfg.pp_stages == 1).collect();
        assert_eq!(ones.len(), 6, "one baseline per profile x mode");
        assert!(ones.iter().all(|c| !c.label.contains("_pp1")));
        assert!(g.cells.iter().filter(|c| c.cfg.pp_stages == 4)
                .all(|c| c.label.contains("_pp4")));
        // each (profile, stages) point keeps its No-CC twin
        for prof in ["h100-cc", "b300-cc", "gh200-coherent"] {
            for st in [1usize, 2, 4] {
                let modes: Vec<_> = g.cells.iter()
                    .filter(|c| c.cfg.device_profiles[0] == prof
                            && c.cfg.pp_stages == st)
                    .map(|c| c.cfg.mode).collect();
                assert!(modes.contains(&crate::gpu::CcMode::Off)
                            && modes.contains(&crate::gpu::CcMode::On),
                        "{prof} x {st} must appear in both modes");
            }
        }
    }

    #[test]
    fn cc_attribution_traces_every_cell() {
        let g = cc_attribution().expand(&RunConfig::default()).unwrap();
        // 3 profiles x 2 modes x 2 depths, minus the no-cc pipeline
        // column (3) and the coherent pipeline cells (2, one shared)
        assert_eq!(g.cells.len(), 8);
        assert_eq!(g.pruned, 4);
        assert_eq!(g.seeds, 1);
        assert!(g.cells.iter().all(
            |c| c.cfg.trace == crate::obs::TraceMode::Full
                && c.label.ends_with("_tr-full")),
                "every cell records the full trace");
        // each profile keeps its No-CC twin for the delta block
        for prof in ["h100-cc", "b300-cc", "gh200-coherent"] {
            let modes: Vec<_> = g.cells.iter()
                .filter(|c| c.cfg.device_profiles[0] == prof)
                .map(|c| c.cfg.mode).collect();
            assert!(modes.contains(&crate::gpu::CcMode::Off)
                        && modes.contains(&crate::gpu::CcMode::On),
                    "{prof} must appear in both modes");
        }
        // the pipeline cells only exist where chunk crypto exists
        assert!(g.cells.iter()
            .filter(|c| c.cfg.gpu.pipeline_depth == 2)
            .all(|c| c.cfg.mode == crate::gpu::CcMode::On
                 && c.cfg.device_profiles[0] != "gh200-coherent"));
    }

    #[test]
    fn cc_io_keeps_one_off_baseline_per_mode() {
        let g = cc_io().expand(&RunConfig::default()).unwrap();
        // 2 modes x (1 off baseline + 3x2 on payload shapes)
        assert_eq!(g.cells.len(), 14);
        assert_eq!(g.pruned, 10);
        assert_eq!(g.seeds, 2);
        let off: Vec<_> = g.cells.iter()
            .filter(|c| !c.cfg.data_path).collect();
        assert_eq!(off.len(), 2, "one flag-off baseline per mode");
        assert!(off.iter().all(|c| c.cfg.data_tokens_in == Some(16)
                               && c.cfg.data_tokens_out == Some(50)));
        assert!(g.cells.iter().filter(|c| c.cfg.data_path)
                .any(|c| c.cfg.data_tokens_in == Some(4096)));
    }
}
