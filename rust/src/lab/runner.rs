//! The parallel grid runner: independent DES cells over a shared
//! work queue of `std::thread` workers.
//!
//! Scheduling is work-stealing in the flat-queue sense: every idle
//! worker steals the next pending job off one shared atomic cursor,
//! so a slow cell never blocks the rest of the grid behind it.
//! Determinism is by construction — each job's result lands in its
//! own pre-allocated slot, indexed by the job's position in the
//! expanded grid, and the returned `Vec<RunSummary>` reads those
//! slots in order.  Thread count and completion order therefore
//! *cannot* change the output: `--threads 1` and `--threads 8`
//! produce byte-identical cells JSON (pinned by `tests/lab.rs` and
//! the CI `lab` job).
//!
//! Every cell is one ordinary virtual-time `Engine` run
//! (`EngineBuilder::des`), which spawns no threads of its own, so the
//! only shared state between workers is the read-only manifest + cost
//! table and the per-job slots.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::engine::{EngineBuilder, RunSummary};
use crate::lab::spec::LabJob;
use crate::runtime::Manifest;
use crate::sim::CostModel;

/// Per-`run` cache of synthetic-catalog expansions, keyed by catalog
/// size and shared read-only across workers once built.  Expansion is
/// a pure function of (manifest, catalog), so caching cannot change
/// any cell's bytes — it only stops a 72-cell catalog grid from
/// re-deriving the same expanded manifest + cost table 72 times.
type CatalogCache = Mutex<HashMap<usize, Arc<(Manifest, CostModel)>>>;

/// Resolve a `--threads` request: 0 means every available core, and
/// there is never a point in more workers than jobs.
pub fn effective_threads(requested: usize, jobs: usize) -> usize {
    let t = if requested == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    };
    t.min(jobs.max(1)).max(1)
}

/// Remaining-time estimate after `done` of `total` cells in
/// `elapsed_s` seconds, with `workers` threads draining the queue.
/// The naive extrapolation (`mean × remaining`) assumes cells finish
/// serially; once the work-stealing cursor has handed the last cells
/// to idle workers they drain *in parallel*, so the estimate is
/// clamped by the number of parallel waves actually left:
/// `mean × ceil(remaining / workers)`.
fn eta_s(elapsed_s: f64, done: usize, total: usize,
         workers: usize) -> f64 {
    if done == 0 {
        return 0.0;
    }
    let mean = elapsed_s / done as f64;
    let remaining = total.saturating_sub(done);
    let serial = mean * remaining as f64;
    let waves = remaining.div_ceil(workers.max(1));
    serial.min(mean * waves as f64)
}

/// Per-cell progress lines on stderr:
/// `[lab k/N label ... done in Xs, ETA Ys]`.
struct Progress {
    total: usize,
    done: usize,
    workers: usize,
    started: Instant,
    enabled: bool,
}

impl Progress {
    fn new(total: usize, workers: usize, enabled: bool) -> Progress {
        Progress { total, done: 0, workers, started: Instant::now(),
                   enabled }
    }

    fn cell_done(&mut self, label: &str, cell_s: f64) {
        self.done += 1;
        if !self.enabled {
            return;
        }
        let elapsed = self.started.elapsed().as_secs_f64();
        let eta = eta_s(elapsed, self.done, self.total, self.workers);
        eprintln!("[lab {}/{} {} ... done in {:.2}s, ETA {:.1}s]",
                  self.done, self.total, label, cell_s, eta);
    }
}

/// Runs a grid of [`LabJob`]s against one manifest + cost table.
pub struct LabRunner<'a> {
    manifest: &'a Manifest,
    costs: &'a CostModel,
    threads: usize,
    quiet: bool,
}

impl<'a> LabRunner<'a> {
    pub fn new(manifest: &'a Manifest, costs: &'a CostModel)
               -> LabRunner<'a> {
        LabRunner { manifest, costs, threads: 0, quiet: false }
    }

    /// Worker count (0 = all available cores).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n;
        self
    }

    /// Suppress the per-cell stderr progress lines.
    pub fn quiet(mut self, q: bool) -> Self {
        self.quiet = q;
        self
    }

    /// Run every job; the result vector is in job order regardless of
    /// thread count.  The first failing cell (by job index) reports
    /// its label; later cells still ran.
    pub fn run(&self, jobs: &[LabJob])
               -> anyhow::Result<Vec<RunSummary>> {
        anyhow::ensure!(!jobs.is_empty(), "lab grid has no jobs to run");
        let n = jobs.len();
        let threads = effective_threads(self.threads, n);
        let cursor = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<anyhow::Result<RunSummary>>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        let progress = Mutex::new(Progress::new(n, threads, !self.quiet));
        let catalogs: CatalogCache = Mutex::new(HashMap::new());

        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let t0 = Instant::now();
                    let r = self.run_one(&jobs[i], &catalogs);
                    *slots[i].lock().unwrap() = Some(r);
                    progress.lock().unwrap().cell_done(
                        &jobs[i].cfg.label,
                        t0.elapsed().as_secs_f64());
                });
            }
        });

        let mut out = Vec::with_capacity(n);
        for (i, slot) in slots.into_iter().enumerate() {
            match slot.into_inner().unwrap() {
                Some(Ok(s)) => out.push(s),
                Some(Err(e)) => {
                    return Err(e.context(format!(
                        "lab cell {} (seed {})", jobs[i].cfg.label,
                        jobs[i].cfg.seed)));
                }
                None => anyhow::bail!(
                    "lab cell {} was never executed", jobs[i].cfg.label),
            }
        }
        Ok(out)
    }

    fn run_one(&self, job: &LabJob, catalogs: &CatalogCache)
               -> anyhow::Result<RunSummary> {
        if job.cfg.catalog > 0 {
            // synthetic-catalog cell: serve the expanded model set
            // instead of cfg.models, against a cost table priced from
            // the expanded manifest.  Both are pure functions of
            // (manifest, catalog), so worker identity cannot leak in —
            // and the grid shares one expansion per catalog size.
            let entry = catalogs.lock().unwrap()
                .entry(job.cfg.catalog)
                .or_insert_with(|| {
                    let expanded =
                        crate::tenancy::catalog::expand_manifest(
                            self.manifest, job.cfg.catalog);
                    let costs = CostModel::synthetic(&expanded);
                    Arc::new((expanded, costs))
                })
                .clone();
            let mut cfg = job.cfg.clone();
            cfg.models = crate::tenancy::catalog::catalog_models(
                job.cfg.catalog);
            let (summary, _rec) = EngineBuilder::new(&cfg)
                .des(&entry.0, &entry.1)?
                .run()?;
            return Ok(summary);
        }
        let (summary, _rec) = EngineBuilder::new(&job.cfg)
            .des(self.manifest, self.costs)?
            .run()?;
        Ok(summary)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_resolution() {
        assert_eq!(effective_threads(4, 100), 4);
        assert_eq!(effective_threads(16, 3), 3);
        assert_eq!(effective_threads(2, 0), 1);
        assert!(effective_threads(0, 100) >= 1);
    }

    #[test]
    fn eta_clamps_to_parallel_waves() {
        // serial regime: 1 worker, 2 of 4 done in 10s -> 2 more at
        // 5s each
        assert!((eta_s(10.0, 2, 4, 1) - 10.0).abs() < 1e-12);
        // parallel tail: 4 workers and 2 cells left drain in ONE wave
        // (~one mean), not two means — the old estimate overshot here
        assert!((eta_s(10.0, 2, 4, 4) - 5.0).abs() < 1e-12);
        // 8 remaining over 4 workers = 2 waves
        assert!((eta_s(20.0, 4, 12, 4) - 10.0).abs() < 1e-12);
        // the clamp never raises the estimate above the serial one
        for &(el, d, t, w) in &[(7.0, 3, 9, 2), (1.0, 1, 10, 3),
                                (30.0, 5, 6, 8)] {
            let mean = el / d as f64;
            assert!(eta_s(el, d, t, w)
                    <= mean * (t - d) as f64 + 1e-12);
        }
        // degenerate inputs stay finite
        assert_eq!(eta_s(5.0, 0, 4, 2), 0.0);
        assert_eq!(eta_s(5.0, 4, 4, 0), 0.0);
    }
}
