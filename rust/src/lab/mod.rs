//! The scenario lab: declarative experiment grids over the calibrated
//! DES, run in parallel with deterministic results.
//!
//! The paper's findings are sweep-shaped — every headline number is a
//! grid over traffic load, pattern, strategy and SLA — and the
//! ROADMAP's scenario axes (fleet size, placement, pipeline depth,
//! prefetch) multiply that grid further.  This module turns "run a
//! grid" into data instead of code:
//!
//! * [`spec`] — [`ScenarioSpec`]: axes / exclusions / `seeds: N`
//!   parsed from JSON, expanded into a [`Grid`] of labelled cells in
//!   a canonical order.
//! * [`presets`] — built-in named specs (`paper-72`, `smoke`,
//!   `fleet-mix`, `cc-recovery`); the `sweep` CLI command is now a
//!   thin alias for `paper-72`.
//! * [`runner`] — [`LabRunner`]: a shared-queue `std::thread` pool
//!   executing independent DES cells concurrently; results land in
//!   per-job slots so thread count never changes output bytes.
//! * [`stats`] — seed replicas folded into per-cell
//!   mean/stddev/p50/p95 [`CellStats`].
//!
//! Rendering (grouped tables, baseline-vs-candidate comparison, the
//! `paper-check` band verdict) lives in [`crate::metrics::report`],
//! next to the paper's other tables.
//!
//! Determinism contract: output bytes are a pure function of
//! (spec, base config, cost table).  Cell seeds derive from the base
//! seed and the replica index only ([`spec::replica_seed`]), never
//! from thread identity, completion order, or wall time.

pub mod presets;
pub mod runner;
pub mod spec;
pub mod stats;

use std::path::Path;

use crate::engine::RunSummary;
use crate::util::json::Json;

pub use presets::{preset_by_name, preset_names, PresetEntry, PRESETS};
pub use runner::LabRunner;
pub use spec::{axis_names, Grid, LabCell, LabJob, ScenarioSpec, AXES};
pub use stats::{aggregate, stats_table, CellStats, Stat};

/// Load a saved lab/sweep run (a JSON array of `RunSummary` cells, as
/// written by `lab run` and the legacy `sweep`).
pub fn load_run(path: &Path) -> anyhow::Result<Vec<RunSummary>> {
    let j = Json::parse_file(path)?;
    let arr = j.as_arr().ok_or_else(|| anyhow::anyhow!(
        "{path:?}: expected a JSON array of run summaries"))?;
    arr.iter().map(RunSummary::from_json).collect()
}

/// Serialize run summaries the way `lab run` persists them.
pub fn run_to_json(cells: &[RunSummary]) -> Json {
    Json::Arr(cells.iter().map(|c| c.to_json()).collect())
}
