//! Run configuration: defaults, JSON config files, CLI overrides.
//!
//! Time scaling (DESIGN.md §Substitutions): the paper ran 20-minute
//! experiments with SLAs of 40/60/80 s against GB-scale models whose CC
//! loads sit at roughly 12–25% of the SLA.  Our models load in 1.7–5.4 s
//! (CC) under the calibrated PCIe model, so a 0.3× scale — SLAs
//! 12/18/24 s, 60 s runs — reproduces the same load/SLA regime.  All
//! reported metrics are ratios, which the uniform scaling preserves;
//! `--sla`/`--duration` restore any other regime.

use std::path::PathBuf;
use std::time::Duration;

use crate::gpu::device::GpuConfig;
use crate::gpu::CcMode;
use crate::util::json::Json;

/// The paper's SLA ladder (seconds), time-scaled ×0.3.
pub const SLA_LADDER: &[f64] = &[12.0, 18.0, 24.0];

/// Full configuration of one serving run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub artifacts_dir: PathBuf,
    /// Where CSVs/summary go; None disables file output.
    pub results_dir: Option<PathBuf>,
    /// Label prefixing output files (derived from the grid cell).
    pub label: String,

    pub mode: CcMode,
    /// Traffic pattern name: gamma | bursty | ramp.
    pub pattern: String,
    pub mean_rps: f64,
    pub sla_s: f64,
    /// Strategy name, see `coordinator::strategy_names`.
    pub strategy: String,
    pub duration_s: f64,
    /// Extra drain time after arrivals stop before cutting off.
    pub drain_s: f64,
    pub seed: u64,
    /// Families to serve (empty = all in manifest).
    pub models: Vec<String>,
    /// Artifact batch sizes to compile (empty = all).
    pub batch_sizes: Vec<usize>,
    /// Timer plan timeout as a fraction of the SLA.
    pub timeout_frac: f64,
    /// Scheduler tick when idle.
    pub tick: Duration,
    /// Monitor sampling period.
    pub monitor_period: Duration,
    /// Base device config; each fleet device starts from this.
    pub gpu: GpuConfig,

    // ---- fleet (N-device) configuration ----
    /// Number of devices in the fleet (1 = the paper's single GPU).
    pub devices: usize,
    /// Per-device CC mode overrides (empty = every device uses `mode`;
    /// otherwise must name one mode per device).
    pub device_modes: Vec<CcMode>,
    /// Per-device HBM capacity overrides, MB (empty = `gpu.hbm_capacity`
    /// everywhere; otherwise one entry per device).
    pub device_hbm_mb: Vec<f64>,
    /// Per-device PCIe rate scale, multiplying both plain and CC
    /// bandwidth (empty = 1.0 everywhere; otherwise one per device).
    pub device_bw_scale: Vec<f64>,
    /// Named hardware-generation profiles, one per device (empty =
    /// the base `gpu` knobs everywhere; see `gpu::profile::PROFILES`).
    /// The first profile's bundled CC mode becomes the run default;
    /// `--mode` and `--device-modes` still override it, and the
    /// explicit per-device knob lists apply on top of the profile.
    pub device_profiles: Vec<String>,
    /// Fleet placement policy, see `coordinator::placement_names`.
    pub placement: String,
    /// Pipeline-parallel stage count: shard every model's layers over
    /// groups of N consecutive fleet devices, with per-microbatch
    /// activation tensors priced per inter-stage link (sealed framing
    /// on CC links, plain on No-CC/coherent ones).  1 = off — the
    /// single-stage path is byte-identical to pre-pp builds.
    pub pp_stages: usize,

    /// Predictive model prefetch: while a batch executes, decrypt-ahead
    /// the strategy's next-model hint into a staging buffer so the
    /// following swap promotes it without a second DMA
    /// (`coordinator::prefetch`).
    pub prefetch: bool,

    /// CC-priced inference data path: price every batch's
    /// request/response payload (`tokens_in`/`tokens_out` bytes)
    /// through the CC bounce-buffer budget — serialized by default,
    /// overlapped under `--pipeline-depth` exactly like swaps
    /// (`engine::backend::price_data_path`).  Off by default so all
    /// pre-existing timings and summaries stay byte-identical; No-CC
    /// runs are unchanged even with it on (an unencrypted link has no
    /// bounce serialization to price).
    pub data_path: bool,
    /// Priced input tokens per request on the data path (default:
    /// the model's `prompt_len`) — the prompt-size sensitivity axis.
    pub data_tokens_in: Option<usize>,
    /// Priced output tokens per request on the data path (default:
    /// the model's `decode_len`).
    pub data_tokens_out: Option<usize>,

    // ---- multi-tenant configuration (`tenancy` module) ----
    /// Synthetic catalog size: replace `models` with N `cat-*`
    /// families cloned from the manifest with cycled size multipliers
    /// (0 = off; DES/lab only — `serve` refuses it).
    pub catalog: usize,
    /// Zipf popularity skew over the model list, rank order = list
    /// order (None = the pre-tenancy uniform model draw).
    pub zipf_skew: Option<f64>,
    /// Admission policy name, see `tenancy::admission::ADMISSIONS`
    /// ("none" = queue everything, the pre-tenancy behavior).
    pub admission: String,
    /// Per-tenant SLA classes (gold/silver/free) with distinct
    /// deadlines and admission weights.
    pub sla_classes: bool,
    /// Diurnal sinusoid amplitude in [0, 1) composed over the base
    /// traffic pattern (0 = off).
    pub diurnal_amp: f64,
    /// Diurnal period, seconds (0 = one period per run).
    pub diurnal_period_s: f64,
    /// Flash-crowd rate multiplier inside the flash window (1 = off).
    pub flash_mult: f64,
    /// Flash-crowd window start, seconds.
    pub flash_start_s: f64,
    /// Flash-crowd window length, seconds (0 = off).
    pub flash_dur_s: f64,

    // ---- observability (`obs` module) ----
    /// Structured event-trace mode (`--trace off|events|full`).
    /// Virtual-time runs only; `off` leaves every output byte
    /// identical to pre-trace builds.
    pub trace: crate::obs::TraceMode,

    // ---- scenario-lab configuration (`lab` command) ----
    /// Built-in preset for `lab run` (`lab list` names them).
    pub lab_preset: Option<String>,
    /// Scenario spec file for `lab run` (overrides `lab_preset`).
    pub lab_spec: Option<PathBuf>,
    /// Lab worker threads (0 = all available cores).
    pub lab_threads: usize,
    /// Override the spec's `seeds` replication factor.
    pub lab_seeds: Option<usize>,
    /// Where `lab run` writes the cells JSON
    /// (default `<results>/sweep_cells.json`).
    pub lab_out: Option<PathBuf>,
    /// Price lab cells from the built-in synthetic cost table instead
    /// of a measured `cost_model.json` — deterministic and instant
    /// (the CI smoke job and the test suites use it).
    pub synthetic_costs: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            results_dir: None,
            label: "run".into(),
            mode: CcMode::Off,
            pattern: "gamma".into(),
            mean_rps: 9.0,
            sla_s: 18.0,
            strategy: "select-batch+timer".into(),
            duration_s: 60.0,
            drain_s: 240.0,
            seed: 42,
            models: Vec::new(),
            batch_sizes: Vec::new(),
            timeout_frac: 0.5,
            tick: Duration::from_millis(2),
            monitor_period: Duration::from_millis(250),
            gpu: GpuConfig::default(),
            devices: 1,
            device_modes: Vec::new(),
            device_hbm_mb: Vec::new(),
            device_bw_scale: Vec::new(),
            device_profiles: Vec::new(),
            placement: "affinity".into(),
            pp_stages: 1,
            prefetch: false,
            data_path: false,
            data_tokens_in: None,
            data_tokens_out: None,
            catalog: 0,
            zipf_skew: None,
            admission: "none".into(),
            sla_classes: false,
            diurnal_amp: 0.0,
            diurnal_period_s: 0.0,
            flash_mult: 1.0,
            flash_start_s: 0.0,
            flash_dur_s: 0.0,
            trace: crate::obs::TraceMode::Off,
            lab_preset: None,
            lab_spec: None,
            lab_threads: 0,
            lab_seeds: None,
            lab_out: None,
            synthetic_costs: false,
        }
    }
}

impl RunConfig {
    /// Timer timeout in seconds.
    pub fn timeout_s(&self) -> f64 {
        self.timeout_frac * self.sla_s
    }

    /// Apply one `--key value` override; returns Err on unknown keys.
    pub fn set(&mut self, key: &str, value: &str) -> anyhow::Result<()> {
        match key {
            "artifacts" => self.artifacts_dir = PathBuf::from(value),
            "results" => self.results_dir = Some(PathBuf::from(value)),
            "label" => self.label = value.to_string(),
            "mode" => {
                self.mode = CcMode::parse(value)?;
                self.gpu.mode = self.mode;
            }
            "pattern" => self.pattern = value.to_string(),
            "mean-rps" => self.mean_rps = parse_f64(key, value)?,
            "sla" => self.sla_s = parse_f64(key, value)?,
            "strategy" => self.strategy = value.to_string(),
            "duration" => self.duration_s = parse_f64(key, value)?,
            "drain" => self.drain_s = parse_f64(key, value)?,
            "seed" => self.seed = value.parse()
                .map_err(|_| anyhow::anyhow!("bad --seed {value:?}"))?,
            "models" => self.models = value.split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty()).collect(),
            "batch-sizes" => {
                self.batch_sizes = value.split(',')
                    .map(|s| s.trim().parse::<usize>())
                    .collect::<Result<_, _>>()
                    .map_err(|_| anyhow::anyhow!(
                        "bad --batch-sizes {value:?}"))?;
            }
            "timeout-frac" => self.timeout_frac = parse_f64(key, value)?,
            "devices" => {
                self.devices = value.parse().map_err(
                    |_| anyhow::anyhow!("bad --devices {value:?}"))?;
            }
            "device-modes" => {
                self.device_modes = value.split(',')
                    .map(|s| CcMode::parse(s.trim()))
                    .collect::<anyhow::Result<_>>()?;
            }
            "device-hbm-mb" => {
                self.device_hbm_mb = parse_f64_list(key, value)?;
            }
            "device-bw-scale" => {
                self.device_bw_scale = parse_f64_list(key, value)?;
            }
            "device-profiles" => {
                let mut names = Vec::new();
                for part in value.split(',') {
                    let part = part.trim();
                    if part.is_empty() {
                        continue;
                    }
                    let p = crate::gpu::profile::profile_by_name(part)?;
                    // the first profile's bundled mode is the run
                    // default; a later --mode or --device-modes
                    // override still wins
                    if names.is_empty() {
                        if let Some(m) = p.mode {
                            self.mode = m;
                            self.gpu.mode = m;
                        }
                    }
                    names.push(part.to_string());
                }
                self.device_profiles = names;
            }
            "placement" => self.placement = value.to_string(),
            "pp-stages" => {
                self.pp_stages = value.parse().map_err(
                    |_| anyhow::anyhow!("bad --pp-stages {value:?}"))?;
            }
            "pipeline-depth" => {
                self.gpu.pipeline_depth = value.parse().map_err(
                    |_| anyhow::anyhow!("bad --pipeline-depth {value:?}"))?;
            }
            "cc-crypto-frac" => {
                self.gpu.cc_crypto_frac = parse_f64(key, value)?;
            }
            "prefetch" => self.prefetch = parse_bool(key, value)?,
            "data-path" => self.data_path = parse_bool(key, value)?,
            "data-tokens-in" => {
                self.data_tokens_in = Some(value.parse().map_err(
                    |_| anyhow::anyhow!("bad --data-tokens-in {value:?}"))?);
            }
            "data-tokens-out" => {
                self.data_tokens_out = Some(value.parse().map_err(
                    |_| anyhow::anyhow!("bad --data-tokens-out {value:?}"))?);
            }
            "catalog" => {
                self.catalog = value.parse().map_err(
                    |_| anyhow::anyhow!("bad --catalog {value:?}"))?;
            }
            "zipf-skew" => {
                self.zipf_skew = match value.trim() {
                    "off" | "none" | "" => None,
                    v => Some(parse_f64(key, v)?),
                };
            }
            "admission" => self.admission = value.to_string(),
            "sla-classes" => self.sla_classes = parse_bool(key, value)?,
            "diurnal-amp" => self.diurnal_amp = parse_f64(key, value)?,
            "diurnal-period" => {
                self.diurnal_period_s = parse_f64(key, value)?;
            }
            "flash-mult" => self.flash_mult = parse_f64(key, value)?,
            "flash-start" => self.flash_start_s = parse_f64(key, value)?,
            "flash-dur" => self.flash_dur_s = parse_f64(key, value)?,
            "trace" => self.trace = crate::obs::TraceMode::parse(value)?,
            "preset" => self.lab_preset = Some(value.to_string()),
            "spec" => self.lab_spec = Some(PathBuf::from(value)),
            "threads" => {
                self.lab_threads = value.parse().map_err(
                    |_| anyhow::anyhow!("bad --threads {value:?}"))?;
            }
            "lab-seeds" => {
                self.lab_seeds = Some(value.parse().map_err(
                    |_| anyhow::anyhow!("bad --lab-seeds {value:?}"))?);
            }
            "out" => self.lab_out = Some(PathBuf::from(value)),
            "synthetic-costs" => {
                self.synthetic_costs = parse_bool(key, value)?;
            }
            "hbm-mb" => self.gpu.hbm_capacity =
                (parse_f64(key, value)? * 1024.0 * 1024.0) as u64,
            "bw-plain-mbps" => self.gpu.bw_plain =
                parse_f64(key, value)? * 1e6,
            "bw-cc-mbps" => self.gpu.bw_cc = parse_f64(key, value)? * 1e6,
            "tick-ms" => self.tick =
                Duration::from_millis(value.parse().map_err(
                    |_| anyhow::anyhow!("bad --tick-ms"))?),
            other => anyhow::bail!("unknown option --{other}"),
        }
        Ok(())
    }

    /// Load overrides from a JSON object file ({"sla": 6.0, ...}).
    pub fn apply_json_file(&mut self, path: &std::path::Path)
                           -> anyhow::Result<()> {
        let j = Json::parse_file(path)?;
        let obj = j.as_obj()
            .ok_or_else(|| anyhow::anyhow!("config must be an object"))?;
        for (k, v) in obj {
            let s = match v {
                Json::Str(s) => s.clone(),
                other => other.to_string(),
            };
            self.set(k, &s)
                .map_err(|e| anyhow::anyhow!("config {path:?}: {e}"))?;
        }
        Ok(())
    }

    /// Grid-cell label, e.g. `cc_gamma_select-batch+timer_sla6`
    /// (fleet runs append `_devN`; profile runs `_prof-<names>`;
    /// pipelined runs `_pipeN`; prefetch runs `_pf`; data-path runs
    /// `_io` plus `_tinN`/`_toutN` when the priced token counts are
    /// overridden).
    pub fn cell_label(&self) -> String {
        let mut base = format!("{}_{}_{}_sla{}", self.mode.as_str(),
                               self.pattern, self.strategy, self.sla_s);
        if self.devices > 1 {
            base.push_str(&format!("_dev{}", self.devices));
        }
        if !self.device_profiles.is_empty() {
            base.push_str(&format!("_prof-{}",
                                   self.device_profiles.join("+")));
        }
        if self.pp_stages > 1 {
            base.push_str(&format!("_pp{}", self.pp_stages));
        }
        if self.gpu.pipeline_depth >= 2 {
            base.push_str(&format!("_pipe{}", self.gpu.pipeline_depth));
        }
        if self.prefetch {
            base.push_str("_pf");
        }
        if self.data_path {
            base.push_str("_io");
        }
        if let Some(t) = self.data_tokens_in {
            base.push_str(&format!("_tin{t}"));
        }
        if let Some(t) = self.data_tokens_out {
            base.push_str(&format!("_tout{t}"));
        }
        if self.catalog > 0 {
            base.push_str(&format!("_cat{}", self.catalog));
        }
        if let Some(s) = self.zipf_skew {
            base.push_str(&format!("_zipf{s}"));
        }
        if self.diurnal_amp > 0.0 {
            base.push_str(&format!("_diu{}", self.diurnal_amp));
        }
        if self.flash_mult != 1.0 && self.flash_dur_s > 0.0 {
            base.push_str(&format!("_flash{}", self.flash_mult));
        }
        if self.admission != "none" {
            base.push_str(&format!("_adm-{}", self.admission));
        }
        if self.sla_classes {
            base.push_str("_cls");
        }
        if self.trace.is_on() {
            base.push_str(&format!("_tr-{}", self.trace.as_str()));
        }
        base
    }

    /// One `GpuConfig` per fleet device: the base `gpu` config with
    /// the per-device profile, then the mode / HBM / PCIe overrides,
    /// applied in that order.
    pub fn fleet_configs(&self) -> Vec<GpuConfig> {
        (0..self.devices.max(1)).map(|i| {
            let mut g = self.gpu.clone();
            // `mode` is the canonical experiment switch; per-device
            // overrides sit on top of it
            g.mode = self.mode;
            // the named profile rewrites link/HBM/pricing knobs but
            // never the mode (its bundled mode was folded into
            // `self.mode` at parse time); a single name broadcasts
            // to the whole fleet (homogeneous-generation shorthand)
            let prof = if self.device_profiles.len() == 1 {
                self.device_profiles.first()
            } else {
                self.device_profiles.get(i)
            };
            if let Some(name) = prof {
                if let Ok(p) = crate::gpu::profile::profile_by_name(name) {
                    g = p.apply(&g);
                }
            }
            if let Some(&m) = self.device_modes.get(i) {
                g.mode = m;
            }
            if let Some(&mb) = self.device_hbm_mb.get(i) {
                g.hbm_capacity = (mb * 1024.0 * 1024.0) as u64;
            }
            if let Some(&s) = self.device_bw_scale.get(i) {
                g.bw_plain *= s;
                g.bw_cc *= s;
            }
            g
        }).collect()
    }

    /// CC mode of every fleet device, in id order.
    pub fn fleet_modes(&self) -> Vec<CcMode> {
        self.fleet_configs().iter().map(|g| g.mode).collect()
    }

    /// Validate cross-field constraints early.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.mean_rps > 0.0, "mean-rps must be > 0");
        anyhow::ensure!(self.sla_s > 0.0, "sla must be > 0");
        anyhow::ensure!(self.duration_s > 0.0, "duration must be > 0");
        anyhow::ensure!((0.0..=1.0).contains(&self.timeout_frac),
                        "timeout-frac must be in [0,1]");
        anyhow::ensure!(self.devices >= 1, "devices must be >= 1");
        anyhow::ensure!(
            self.gpu.cc_crypto_frac.is_finite()
                && (0.0..=1.0).contains(&self.gpu.cc_crypto_frac),
            "cc-crypto-frac must be in [0,1]");
        for (name, len) in [("device-modes", self.device_modes.len()),
                            ("device-hbm-mb", self.device_hbm_mb.len()),
                            ("device-bw-scale",
                             self.device_bw_scale.len())] {
            anyhow::ensure!(len == 0 || len == self.devices,
                            "--{name} must list one entry per device \
                             ({} given, {} devices)", len, self.devices);
        }
        // profiles additionally allow a single name, broadcast to the
        // whole fleet (fleet_configs applies it to every device)
        let np = self.device_profiles.len();
        anyhow::ensure!(np <= 1 || np == self.devices,
                        "--device-profiles must list one profile per \
                         device, or a single fleet-wide name ({np} \
                         given, {} devices)", self.devices);
        for p in &self.device_profiles {
            crate::gpu::profile::profile_by_name(p)?;
        }
        anyhow::ensure!(self.pp_stages >= 1, "pp-stages must be >= 1");
        if self.pp_stages > 1 {
            anyhow::ensure!(
                self.devices % self.pp_stages == 0,
                "--pp-stages {} must evenly divide --devices {} (each \
                 stage group is a contiguous run of devices)",
                self.pp_stages, self.devices);
            anyhow::ensure!(
                self.placement == "pipeline-parallel",
                "--pp-stages > 1 requires --placement \
                 pipeline-parallel (shard groups stage atomically)");
            anyhow::ensure!(
                !self.prefetch,
                "--prefetch is not shard-aware; it cannot be combined \
                 with --pp-stages > 1");
        }
        if let Some(s) = self.lab_seeds {
            anyhow::ensure!(s >= 1, "lab-seeds must be >= 1");
        }
        if let Some(s) = self.zipf_skew {
            anyhow::ensure!(s.is_finite() && s >= 0.0,
                            "zipf-skew must be >= 0");
        }
        anyhow::ensure!(
            self.diurnal_amp.is_finite()
                && (0.0..1.0).contains(&self.diurnal_amp),
            "diurnal-amp must be in [0,1) so the rate stays positive");
        anyhow::ensure!(self.diurnal_period_s >= 0.0,
                        "diurnal-period must be >= 0");
        anyhow::ensure!(self.flash_mult.is_finite() && self.flash_mult > 0.0,
                        "flash-mult must be > 0");
        anyhow::ensure!(self.flash_start_s >= 0.0 && self.flash_dur_s >= 0.0,
                        "flash window must be non-negative");
        crate::traffic::pattern_by_name(&self.pattern)?;
        crate::coordinator::strategy_by_name(&self.strategy)?;
        crate::coordinator::placement_by_name(&self.placement)?;
        crate::tenancy::admission::admission_by_name(&self.admission)?;
        Ok(())
    }
}

fn parse_f64(key: &str, value: &str) -> anyhow::Result<f64> {
    value.parse::<f64>()
        .map_err(|_| anyhow::anyhow!("bad --{key} value {value:?}"))
}

fn parse_f64_list(key: &str, value: &str) -> anyhow::Result<Vec<f64>> {
    value.split(',')
        .map(|s| parse_f64(key, s.trim()))
        .collect()
}

fn parse_bool(key: &str, value: &str) -> anyhow::Result<bool> {
    match value.trim().to_ascii_lowercase().as_str() {
        "on" | "true" | "1" | "yes" => Ok(true),
        "off" | "false" | "0" | "no" => Ok(false),
        _ => anyhow::bail!("bad --{key} value {value:?} (want on|off)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn set_overrides() {
        let mut c = RunConfig::default();
        c.set("mode", "cc").unwrap();
        c.set("sla", "8").unwrap();
        c.set("models", "llama-sim,gemma-sim").unwrap();
        c.set("batch-sizes", "1,4,8").unwrap();
        c.set("bw-cc-mbps", "3.5").unwrap();
        assert_eq!(c.mode, CcMode::On);
        assert_eq!(c.gpu.mode, CcMode::On);
        assert_eq!(c.sla_s, 8.0);
        assert_eq!(c.models.len(), 2);
        assert_eq!(c.batch_sizes, vec![1, 4, 8]);
        assert!((c.gpu.bw_cc - 3.5e6).abs() < 1.0);
        assert!(c.set("bogus", "1").is_err());
        assert!(c.set("sla", "fast").is_err());
    }

    #[test]
    fn validation_catches_bad_fields() {
        let mut c = RunConfig::default();
        c.pattern = "nope".into();
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.strategy = "nope".into();
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.mean_rps = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn json_config_applies() {
        let dir = std::env::temp_dir().join("sincere_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.json");
        std::fs::write(&path,
            r#"{"mode":"cc","sla":4,"pattern":"bursty"}"#).unwrap();
        let mut c = RunConfig::default();
        c.apply_json_file(&path).unwrap();
        assert_eq!(c.mode, CcMode::On);
        assert_eq!(c.sla_s, 4.0);
        assert_eq!(c.pattern, "bursty");
    }

    #[test]
    fn cell_label_stable() {
        let c = RunConfig::default();
        assert_eq!(c.cell_label(),
                   "no-cc_gamma_select-batch+timer_sla18");
        let mut fleet = RunConfig::default();
        fleet.devices = 4;
        assert_eq!(fleet.cell_label(),
                   "no-cc_gamma_select-batch+timer_sla18_dev4");
    }

    #[test]
    fn fleet_overrides_parse_and_apply() {
        let mut c = RunConfig::default();
        c.set("devices", "3").unwrap();
        c.set("device-modes", "cc,no-cc,cc").unwrap();
        c.set("device-hbm-mb", "8,24,24").unwrap();
        c.set("device-bw-scale", "1.0,2.0,1.0").unwrap();
        c.set("placement", "least-loaded").unwrap();
        c.validate().unwrap();
        let fleet = c.fleet_configs();
        assert_eq!(fleet.len(), 3);
        assert_eq!(c.fleet_modes(),
                   vec![CcMode::On, CcMode::Off, CcMode::On]);
        assert_eq!(fleet[0].hbm_capacity, 8 * 1024 * 1024);
        assert!((fleet[1].bw_plain - 2.0 * c.gpu.bw_plain).abs() < 1.0);
        assert!((fleet[2].bw_cc - c.gpu.bw_cc).abs() < 1.0);
        assert!(c.set("devices", "zero").is_err());
        assert!(c.set("device-modes", "cc,tdx").is_err());
    }

    #[test]
    fn fleet_validation_catches_mismatched_lists() {
        let mut c = RunConfig::default();
        c.devices = 2;
        c.device_modes = vec![CcMode::On];
        assert!(c.validate().is_err(), "1 mode for 2 devices");
        let mut c = RunConfig::default();
        c.devices = 0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.placement = "nope".into();
        assert!(c.validate().is_err());
    }

    #[test]
    fn device_profiles_parse_label_and_fleet() {
        let mut c = RunConfig::default();
        c.set("devices", "2").unwrap();
        c.set("device-profiles", "h100-cc,gh200-coherent").unwrap();
        c.validate().unwrap();
        // the first profile's bundled mode becomes the run default
        assert_eq!(c.mode, CcMode::On);
        assert_eq!(c.gpu.mode, CcMode::On);
        assert_eq!(c.cell_label(),
                   "cc_gamma_select-batch+timer_sla18_dev2\
                    _prof-h100-cc+gh200-coherent");
        let fleet = c.fleet_configs();
        assert_eq!(fleet.len(), 2);
        assert_eq!(fleet[0].mode, CcMode::On);
        assert!(!fleet[0].uma, "h100-cc keeps the chunk recurrence");
        assert_eq!(fleet[0].bridge_residual_s, 0.0);
        assert!(fleet[1].uma, "gh200-coherent is coherent memory");
        assert!((fleet[1].bridge_residual_s - 0.12).abs() < 1e-12);
        assert!((fleet[1].bw_cc - 18.0e6).abs() < 1.0);
        // an explicit --mode after the profile wins
        c.set("mode", "no-cc").unwrap();
        assert_eq!(c.fleet_modes(), vec![CcMode::Off, CcMode::Off]);
    }

    #[test]
    fn device_profiles_errors_and_precedence() {
        let mut c = RunConfig::default();
        let err = c.set("device-profiles", "a100")
            .unwrap_err().to_string();
        assert!(err.contains("a100") && err.contains("h100-cc")
                    && err.contains("gh200-coherent"),
                "unknown profile must list the table: {err}");
        // custom bundles no mode, so it leaves the run default alone
        let mut c = RunConfig::default();
        c.set("device-profiles", "custom").unwrap();
        assert_eq!(c.mode, CcMode::Off, "custom bundles no mode");
        // a single profile broadcasts fleet-wide; partial lists error
        let mut c = RunConfig::default();
        c.devices = 2;
        c.device_profiles = vec!["gh200-coherent".into()];
        c.validate().unwrap();
        let fleet = c.fleet_configs();
        assert_eq!(fleet.len(), 2);
        assert!(fleet.iter().all(|g| g.uma),
                "one profile name applies to every device");
        let mut c = RunConfig::default();
        c.devices = 3;
        c.device_profiles = vec!["h100-cc".into(), "h100-cc".into()];
        assert!(c.validate().is_err(), "2 profiles for 3 devices");
        let mut c = RunConfig::default();
        c.device_profiles = vec!["a100".into()];
        assert!(c.validate().is_err(), "validate re-checks the names");
        // --device-modes still overrides the profile mode per device
        let mut c = RunConfig::default();
        c.set("devices", "2").unwrap();
        c.set("device-profiles", "h100-cc,h100-cc").unwrap();
        c.set("device-modes", "no-cc,cc").unwrap();
        assert_eq!(c.fleet_modes(), vec![CcMode::Off, CcMode::On]);
    }

    #[test]
    fn h100_profile_fleet_matches_legacy_knobs() {
        let mut a = RunConfig::default();
        a.set("mode", "cc").unwrap();
        let mut b = RunConfig::default();
        b.set("device-profiles", "h100-cc").unwrap();
        assert_eq!(format!("{:?}", a.fleet_configs()),
                   format!("{:?}", b.fleet_configs()),
                   "h100-cc is a name for the legacy CC knobs");
    }

    #[test]
    fn pipeline_and_prefetch_flags() {
        let mut c = RunConfig::default();
        c.set("pipeline-depth", "2").unwrap();
        c.set("cc-crypto-frac", "0.4").unwrap();
        c.set("prefetch", "on").unwrap();
        c.validate().unwrap();
        assert_eq!(c.gpu.pipeline_depth, 2);
        assert!((c.gpu.cc_crypto_frac - 0.4).abs() < 1e-12);
        assert!(c.prefetch);
        assert_eq!(c.cell_label(),
                   "no-cc_gamma_select-batch+timer_sla18_pipe2_pf");
        c.set("prefetch", "off").unwrap();
        assert!(!c.prefetch);
        assert!(c.set("pipeline-depth", "two").is_err());
        assert!(c.set("prefetch", "maybe").is_err());
        c.set("cc-crypto-frac", "1.5").unwrap();
        assert!(c.validate().is_err(), "frac above 1 must fail validation");
    }

    #[test]
    fn data_path_flags() {
        let mut c = RunConfig::default();
        assert!(!c.data_path, "data path must default off");
        c.set("data-path", "on").unwrap();
        c.set("data-tokens-in", "512").unwrap();
        c.set("data-tokens-out", "128").unwrap();
        c.validate().unwrap();
        assert!(c.data_path);
        assert_eq!(c.data_tokens_in, Some(512));
        assert_eq!(c.data_tokens_out, Some(128));
        assert_eq!(c.cell_label(),
                   "no-cc_gamma_select-batch+timer_sla18_io_tin512\
                    _tout128");
        c.set("data-path", "off").unwrap();
        c.data_tokens_in = None;
        c.data_tokens_out = None;
        assert_eq!(c.cell_label(),
                   "no-cc_gamma_select-batch+timer_sla18",
                   "flag off leaves every pre-existing label untouched");
        assert!(c.set("data-path", "maybe").is_err());
        assert!(c.set("data-tokens-in", "-3").is_err());
        assert!(c.set("data-tokens-out", "lots").is_err());
    }

    #[test]
    fn trace_flags() {
        let mut c = RunConfig::default();
        assert_eq!(c.trace, crate::obs::TraceMode::Off,
                   "trace must default off");
        c.set("trace", "events").unwrap();
        assert_eq!(c.trace, crate::obs::TraceMode::Events);
        assert_eq!(c.cell_label(),
                   "no-cc_gamma_select-batch+timer_sla18_tr-events");
        c.set("trace", "full").unwrap();
        assert_eq!(c.cell_label(),
                   "no-cc_gamma_select-batch+timer_sla18_tr-full");
        c.set("trace", "off").unwrap();
        assert_eq!(c.cell_label(),
                   "no-cc_gamma_select-batch+timer_sla18",
                   "flag off leaves every pre-existing label untouched");
        assert!(c.set("trace", "verbose").is_err());
    }

    #[test]
    fn tenancy_flags() {
        let mut c = RunConfig::default();
        assert_eq!(c.catalog, 0);
        assert_eq!(c.zipf_skew, None);
        assert_eq!(c.admission, "none");
        assert!(!c.sla_classes, "tenancy must default fully off");
        c.set("catalog", "12").unwrap();
        c.set("zipf-skew", "1.1").unwrap();
        c.set("admission", "class-weighted").unwrap();
        c.set("sla-classes", "on").unwrap();
        c.set("diurnal-amp", "0.4").unwrap();
        c.set("flash-mult", "3").unwrap();
        c.set("flash-start", "5").unwrap();
        c.set("flash-dur", "4").unwrap();
        c.validate().unwrap();
        assert_eq!(c.catalog, 12);
        assert_eq!(c.zipf_skew, Some(1.1));
        assert_eq!(c.cell_label(),
                   "no-cc_gamma_select-batch+timer_sla18_cat12_zipf1.1\
                    _diu0.4_flash3_adm-class-weighted_cls");
        c.set("zipf-skew", "off").unwrap();
        assert_eq!(c.zipf_skew, None);
        // everything off leaves pre-tenancy labels untouched
        let base = RunConfig::default();
        assert_eq!(base.cell_label(),
                   "no-cc_gamma_select-batch+timer_sla18");
        // bad values
        assert!(c.set("catalog", "many").is_err());
        assert!(c.set("sla-classes", "maybe").is_err());
        let mut bad = RunConfig::default();
        bad.admission = "fifo".into();
        assert!(bad.validate().is_err(), "unknown admission must fail");
        let mut bad = RunConfig::default();
        bad.diurnal_amp = 1.0;
        assert!(bad.validate().is_err(), "amp 1 would zero the rate");
        let mut bad = RunConfig::default();
        bad.zipf_skew = Some(-0.5);
        assert!(bad.validate().is_err());
        let mut bad = RunConfig::default();
        bad.flash_mult = 0.0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn lab_flags_parse() {
        let mut c = RunConfig::default();
        c.set("preset", "paper-72").unwrap();
        c.set("spec", "examples/lab_spec.json").unwrap();
        c.set("threads", "4").unwrap();
        c.set("lab-seeds", "3").unwrap();
        c.set("out", "results/run.json").unwrap();
        c.set("synthetic-costs", "on").unwrap();
        c.validate().unwrap();
        assert_eq!(c.lab_preset.as_deref(), Some("paper-72"));
        assert_eq!(c.lab_spec.as_deref(),
                   Some(std::path::Path::new("examples/lab_spec.json")));
        assert_eq!(c.lab_threads, 4);
        assert_eq!(c.lab_seeds, Some(3));
        assert!(c.synthetic_costs);
        assert!(c.set("threads", "many").is_err());
        assert!(c.set("lab-seeds", "-1").is_err());
        c.lab_seeds = Some(0);
        assert!(c.validate().is_err(), "0 seed replicas is meaningless");
    }

    #[test]
    fn pp_stage_flags() {
        let mut c = RunConfig::default();
        assert_eq!(c.pp_stages, 1, "pp must default off");
        assert_eq!(c.cell_label(),
                   "no-cc_gamma_select-batch+timer_sla18");
        c.set("pp-stages", "1").unwrap();
        c.validate().unwrap();
        assert_eq!(c.cell_label(),
                   "no-cc_gamma_select-batch+timer_sla18",
                   "pp-stages 1 leaves every pre-existing label \
                    untouched");
        c.set("devices", "4").unwrap();
        c.set("pp-stages", "2").unwrap();
        assert!(c.validate().is_err(),
                "pp > 1 needs the pipeline-parallel placement");
        c.set("placement", "pipeline-parallel").unwrap();
        c.validate().unwrap();
        assert_eq!(c.cell_label(),
                   "no-cc_gamma_select-batch+timer_sla18_dev4_pp2");
        c.set("pp-stages", "3").unwrap();
        assert!(c.validate().is_err(), "3 stages cannot tile 4 devices");
        c.set("pp-stages", "4").unwrap();
        c.set("prefetch", "on").unwrap();
        assert!(c.validate().is_err(), "prefetch is not shard-aware");
        c.set("prefetch", "off").unwrap();
        c.validate().unwrap();
        assert!(c.set("pp-stages", "two").is_err());
        c.pp_stages = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn single_device_fleet_is_the_base_gpu() {
        let c = RunConfig::default();
        let fleet = c.fleet_configs();
        assert_eq!(fleet.len(), 1);
        assert_eq!(fleet[0].mode, c.gpu.mode);
        assert_eq!(fleet[0].hbm_capacity, c.gpu.hbm_capacity);
    }
}
