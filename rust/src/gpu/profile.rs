//! Named hardware-generation device profiles (`--device-profiles`).
//!
//! The source paper measures the CC tax on exactly one H100; the
//! profile table encodes what the related work says about other
//! generations so the fleet can answer "which part of the CC tax
//! survives which hardware generation":
//!
//! * `h100-cc` / `h100-nocc` — the paper's device: serialized
//!   bounce-buffer crypto dominates the CC swap path ("Confidential
//!   Computing on NVIDIA Hopper GPUs", arxiv 2409.03992).  These are
//!   *pure names* over the legacy knob defaults: applying them changes
//!   no float, so profile runs stay byte-identical to legacy-knob runs
//!   (pinned by `tests/golden_summary.rs`).
//! * `b300-cc` — Blackwell GPU-CC: GPU-local performance is preserved
//!   and the cost concentrates in the CPU↔GPU bridge ("The Serialized
//!   Bridge", arxiv 2606.23969).  Encoded as a small `cc_excess_scale`
//!   on the Hopper-style bounce tax plus a per-swap
//!   `bridge_residual_s` constant.
//! * `gh200-coherent` — Grace-Hopper-class coherent/unified memory:
//!   no bounce-buffer sealing at all (swap crypto → 0, data path
//!   prices like No-CC); the residual CC cost is the per-swap
//!   bridge/attestation-side constant (`uma` pricing in
//!   `engine::backend::swap_load_s`).
//! * `custom` — the escape hatch: overrides nothing, so the legacy
//!   per-device knobs (`--device-hbm-mb`, `--device-bw-scale`, …)
//!   stay fully in charge.
//!
//! A profile's `mode` is a *parse-time default* only: `--device-profiles
//! b300-cc` defaults the run to CC, but an explicit `--mode` (or a
//! swept lab `mode` axis, which overrides the `profile` axis) still
//! wins — (b300-cc, no-cc) means "B300 hardware with CC off".

use crate::gpu::device::GpuConfig;
use crate::gpu::CcMode;

/// One named hardware generation: the `GpuConfig` overrides it
/// bundles.  `None` fields keep whatever the base config (CLI knobs)
/// says — which is how `h100-*` and `custom` stay pure names.
pub struct DeviceProfile {
    pub name: &'static str,
    pub blurb: &'static str,
    /// Parse-time default CC mode (`None` = leave the CLI mode alone).
    pub mode: Option<CcMode>,
    pub bw_plain: Option<f64>,
    pub bw_cc: Option<f64>,
    pub cc_crypto_frac: Option<f64>,
    pub pipeline_depth: Option<usize>,
    pub hbm_capacity: Option<u64>,
    pub uma: bool,
    pub bridge_residual_s: f64,
    pub cc_excess_scale: f64,
}

/// The profile table, in display order — the single source of truth
/// for `profile_by_name`, the CLI help, the lab `profile` axis and
/// the unknown-name error, like `STRATEGIES` and `PLACEMENTS`.
pub const PROFILES: &[DeviceProfile] = &[
    DeviceProfile {
        name: "h100-cc",
        blurb: "the paper's H100 in CC mode: serialized bounce-buffer \
                crypto (byte-identical to the legacy knob defaults)",
        mode: Some(CcMode::On),
        bw_plain: None,
        bw_cc: None,
        cc_crypto_frac: None,
        pipeline_depth: None,
        hbm_capacity: None,
        uma: false,
        bridge_residual_s: 0.0,
        cc_excess_scale: 1.0,
    },
    DeviceProfile {
        name: "h100-nocc",
        blurb: "the same H100 with CC off: raw DMA, no crypto",
        mode: Some(CcMode::Off),
        bw_plain: None,
        bw_cc: None,
        cc_crypto_frac: None,
        pipeline_depth: None,
        hbm_capacity: None,
        uma: false,
        bridge_residual_s: 0.0,
        cc_excess_scale: 1.0,
    },
    DeviceProfile {
        name: "b300-cc",
        blurb: "Blackwell GPU-CC: GPU-local crypto nearly free, the \
                tax concentrated in a per-swap CPU<->GPU bridge \
                residual",
        mode: Some(CcMode::On),
        bw_plain: Some(12.0e6),
        bw_cc: Some(10.0e6),
        cc_crypto_frac: Some(0.25),
        pipeline_depth: Some(2),
        hbm_capacity: Some(86 * 1024 * 1024),
        uma: false,
        bridge_residual_s: 0.35,
        cc_excess_scale: 0.25,
    },
    DeviceProfile {
        name: "gh200-coherent",
        blurb: "Grace-Hopper coherent/unified memory: no bounce-buffer \
                sealing (swap crypto -> 0), residual per-swap \
                bridge/attestation constant",
        mode: Some(CcMode::On),
        bw_plain: Some(18.0e6),
        bw_cc: Some(18.0e6),
        cc_crypto_frac: Some(0.0),
        pipeline_depth: Some(0),
        hbm_capacity: Some(29 * 1024 * 1024),
        uma: true,
        bridge_residual_s: 0.12,
        cc_excess_scale: 1.0,
    },
    DeviceProfile {
        name: "custom",
        blurb: "escape hatch: overrides nothing, the per-device knobs \
                stay in charge",
        mode: None,
        bw_plain: None,
        bw_cc: None,
        cc_crypto_frac: None,
        pipeline_depth: None,
        hbm_capacity: None,
        uma: false,
        bridge_residual_s: 0.0,
        cc_excess_scale: 1.0,
    },
];

/// Valid profile names, in table order.
pub fn profile_names() -> Vec<&'static str> {
    PROFILES.iter().map(|p| p.name).collect()
}

/// Look up a profile by CLI name; unknown names error with the
/// valid-name table.
pub fn profile_by_name(name: &str)
                       -> anyhow::Result<&'static DeviceProfile> {
    PROFILES.iter().find(|p| p.name == name).ok_or_else(|| {
        anyhow::anyhow!("unknown device profile {name:?} (have {:?})",
                        profile_names())
    })
}

impl DeviceProfile {
    /// Overlay this profile on a base device config.  Never touches
    /// `mode` — the run config owns mode precedence (CLI/axis override
    /// the profile's parse-time default) — and `None` fields keep the
    /// base value, so `h100-*`/`custom` return the base bit-for-bit.
    pub fn apply(&self, base: &GpuConfig) -> GpuConfig {
        let mut g = base.clone();
        if let Some(v) = self.bw_plain {
            g.bw_plain = v;
        }
        if let Some(v) = self.bw_cc {
            g.bw_cc = v;
        }
        if let Some(v) = self.cc_crypto_frac {
            g.cc_crypto_frac = v;
        }
        if let Some(v) = self.pipeline_depth {
            g.pipeline_depth = v;
        }
        if let Some(v) = self.hbm_capacity {
            g.hbm_capacity = v;
        }
        g.uma = self.uma;
        g.bridge_residual_s = self.bridge_residual_s;
        g.cc_excess_scale = self.cc_excess_scale;
        g
    }
}

/// Price one inter-stage activation transfer into the *downstream*
/// device of a pipeline-parallel link.  Confidential links (downstream
/// device in CC mode, bounce-buffer style — not coherent/UMA) seal
/// each activation tensor with the same `nonce‖ct‖tag` chunk framing
/// and budget as the weight-swap and data paths
/// (`gpu::dma::cc_budget_s`); No-CC and coherent links move the raw
/// bytes at the plain link rate with no crypto and no framing
/// overhead.  Returns `(io_s, crypto_total_s, crypto_exposed_s,
/// wire_bytes)`.
pub fn price_activation_link(downstream: &GpuConfig, bytes: usize)
                             -> (f64, f64, f64, u64) {
    if downstream.mode == CcMode::On && !downstream.uma {
        let (io_s, crypto_total, crypto_exposed) =
            crate::gpu::dma::cc_budget_s(
                bytes, downstream.bw_cc, downstream.bounce_bytes,
                downstream.pipeline_depth, downstream.cc_crypto_frac);
        let wire = crate::gpu::cc::wire_bytes(
            bytes, downstream.bounce_bytes) as u64;
        (io_s, crypto_total, crypto_exposed, wire)
    } else {
        (crate::gpu::dma::plain_budget_s(bytes, downstream.bw_plain),
         0.0, 0.0, bytes as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_names_unique_and_resolvable() {
        let mut names = profile_names();
        let n = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), n);
        for p in PROFILES {
            assert!(std::ptr::eq(profile_by_name(p.name).unwrap(),
                                 p as *const _));
        }
        let err = profile_by_name("a100").unwrap_err().to_string();
        assert!(err.contains("a100") && err.contains("h100-cc")
                && err.contains("gh200-coherent"), "{err}");
    }

    #[test]
    fn h100_and_custom_apply_are_identity() {
        let base = GpuConfig::default();
        for name in ["h100-cc", "h100-nocc", "custom"] {
            let out = profile_by_name(name).unwrap().apply(&base);
            assert_eq!(format!("{base:?}"), format!("{out:?}"), "{name}");
        }
    }

    #[test]
    fn b300_concentrates_the_tax_in_the_bridge() {
        let p = profile_by_name("b300-cc").unwrap();
        let g = p.apply(&GpuConfig::default());
        assert!(!g.uma);
        assert!(g.bridge_residual_s > 0.0);
        assert!(g.cc_excess_scale < 1.0);
        assert_eq!(g.pipeline_depth, 2);
        assert_eq!(g.hbm_capacity, 86 * 1024 * 1024);
        assert_eq!(p.mode, Some(CcMode::On));
    }

    #[test]
    fn activation_links_seal_only_bounce_buffered_cc() {
        let bytes = 1 << 20;
        let plain = GpuConfig { no_throttle: true,
                                ..GpuConfig::default() };
        let (io_p, ct_p, ce_p, w_p) =
            price_activation_link(&plain, bytes);
        assert!((io_p - bytes as f64 / plain.bw_plain).abs() < 1e-12);
        assert_eq!((ct_p, ce_p), (0.0, 0.0));
        assert_eq!(w_p, bytes as u64, "plain link carries raw bytes");

        let cc = GpuConfig { mode: CcMode::On, no_throttle: true,
                             ..GpuConfig::default() };
        let (io_c, ct_c, ce_c, w_c) = price_activation_link(&cc, bytes);
        assert!(io_c > io_p, "sealed link must cost more than plain");
        assert!(ct_c > 0.0 && ce_c > 0.0 && ce_c <= ct_c + 1e-12);
        assert!(w_c > bytes as u64,
                "nonce||ct||tag framing inflates the wire bytes");

        // a coherent CC device has no bounce buffer to seal
        let uma = profile_by_name("gh200-coherent").unwrap()
            .apply(&GpuConfig { mode: CcMode::On, no_throttle: true,
                                ..GpuConfig::default() });
        let (io_u, ct_u, _, w_u) = price_activation_link(&uma, bytes);
        assert_eq!(ct_u, 0.0, "coherent link pays no activation crypto");
        assert_eq!(w_u, bytes as u64);
        assert!((io_u - bytes as f64 / uma.bw_plain).abs() < 1e-12);
    }

    #[test]
    fn gh200_is_uma_with_equal_link_rates() {
        let g = profile_by_name("gh200-coherent").unwrap()
            .apply(&GpuConfig::default());
        assert!(g.uma);
        assert_eq!(g.bw_plain, g.bw_cc);
        assert_eq!(g.cc_crypto_frac, 0.0);
        assert!(g.bridge_residual_s > 0.0);
    }
}
