//! Device-memory (HBM) allocator with capacity + fragmentation stats.
//!
//! First-fit free-list allocator over a byte range.  Backs the paper's
//! memory behaviour: batch-size profiling grows batches "until the GPU
//! runs out of memory" (§III-D2) — the OOM comes from here — and the
//! monitor CSV reports allocation, peak usage and fragmentation ratio
//! (§V metrics list).

/// An allocation handle into simulated HBM.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HbmBuffer {
    pub offset: u64,
    pub len: u64,
}

/// Allocation failure — the GPU is out of memory.
#[derive(Debug, Clone, Copy)]
pub struct HbmOom {
    pub requested: u64,
    pub free: u64,
    pub largest: u64,
    pub capacity: u64,
}

impl std::fmt::Display for HbmOom {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f,
               "HBM OOM: requested {} B, free {} B (largest block {} B) \
                of {} B",
               self.requested, self.free, self.largest, self.capacity)
    }
}

impl std::error::Error for HbmOom {}

/// First-fit free-list allocator.
#[derive(Debug)]
pub struct HbmAllocator {
    capacity: u64,
    /// Sorted, coalesced (offset, len) free extents.
    free: Vec<(u64, u64)>,
    in_use: u64,
    peak: u64,
    allocs: u64,
    frees: u64,
}

impl HbmAllocator {
    pub fn new(capacity: u64) -> HbmAllocator {
        HbmAllocator {
            capacity,
            free: vec![(0, capacity)],
            in_use: 0,
            peak: 0,
            allocs: 0,
            frees: 0,
        }
    }

    /// Allocate `len` bytes, first-fit.
    pub fn alloc(&mut self, len: u64) -> Result<HbmBuffer, HbmOom> {
        assert!(len > 0, "zero-length HBM allocation");
        for i in 0..self.free.len() {
            let (off, flen) = self.free[i];
            if flen >= len {
                if flen == len {
                    self.free.remove(i);
                } else {
                    self.free[i] = (off + len, flen - len);
                }
                self.in_use += len;
                self.peak = self.peak.max(self.in_use);
                self.allocs += 1;
                return Ok(HbmBuffer { offset: off, len });
            }
        }
        Err(HbmOom {
            requested: len,
            free: self.free_bytes(),
            largest: self.largest_free(),
            capacity: self.capacity,
        })
    }

    /// Return a buffer to the free list, coalescing neighbours.
    pub fn free(&mut self, buf: HbmBuffer) {
        debug_assert!(buf.offset + buf.len <= self.capacity);
        let pos = self.free.partition_point(|&(o, _)| o < buf.offset);
        // guard against double-free overlapping an existing extent
        if let Some(&(o, l)) = self.free.get(pos) {
            assert!(buf.offset + buf.len <= o,
                    "HBM double free at {}..{} overlaps free {}..{}",
                    buf.offset, buf.offset + buf.len, o, o + l);
        }
        if pos > 0 {
            let (o, l) = self.free[pos - 1];
            assert!(o + l <= buf.offset,
                    "HBM double free at {} inside free extent", buf.offset);
        }
        self.free.insert(pos, (buf.offset, buf.len));
        self.in_use -= buf.len;
        self.frees += 1;
        self.coalesce();
    }

    fn coalesce(&mut self) {
        let mut i = 0;
        while i + 1 < self.free.len() {
            let (o1, l1) = self.free[i];
            let (o2, l2) = self.free[i + 1];
            if o1 + l1 == o2 {
                self.free[i] = (o1, l1 + l2);
                self.free.remove(i + 1);
            } else {
                i += 1;
            }
        }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn in_use(&self) -> u64 {
        self.in_use
    }

    pub fn peak(&self) -> u64 {
        self.peak
    }

    pub fn free_bytes(&self) -> u64 {
        self.free.iter().map(|&(_, l)| l).sum()
    }

    pub fn largest_free(&self) -> u64 {
        self.free.iter().map(|&(_, l)| l).max().unwrap_or(0)
    }

    /// Largest free extent *if* `buf` were returned first — exact,
    /// because freeing only coalesces with the (at most two) adjacent
    /// extents.  Lets callers decide whether reclaiming a buffer would
    /// make room before actually giving it up (prefetch restaging).
    pub fn largest_free_after(&self, buf: HbmBuffer) -> u64 {
        let mut merged_off = buf.offset;
        let mut merged_len = buf.len;
        for &(o, l) in &self.free {
            if o + l == merged_off {
                merged_off = o;
                merged_len += l;
            } else if merged_off + merged_len == o {
                merged_len += l;
            }
        }
        self.largest_free().max(merged_len)
    }

    /// Fragmentation ratio in [0, 1]: 1 − largest_free / total_free.
    /// 0 when free space is one extent (or none).
    pub fn fragmentation(&self) -> f64 {
        let total = self.free_bytes();
        if total == 0 {
            return 0.0;
        }
        1.0 - self.largest_free() as f64 / total as f64
    }

    pub fn alloc_count(&self) -> u64 {
        self.allocs
    }

    pub fn free_count(&self) -> u64 {
        self.frees
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_roundtrip() {
        let mut h = HbmAllocator::new(1000);
        let a = h.alloc(400).unwrap();
        let b = h.alloc(600).unwrap();
        assert_eq!(h.in_use(), 1000);
        assert!(h.alloc(1).is_err());
        h.free(a);
        h.free(b);
        assert_eq!(h.in_use(), 0);
        assert_eq!(h.free_bytes(), 1000);
        assert_eq!(h.largest_free(), 1000, "must coalesce");
        assert_eq!(h.fragmentation(), 0.0);
    }

    #[test]
    fn oom_reports_details() {
        let mut h = HbmAllocator::new(100);
        let _a = h.alloc(60).unwrap();
        let err = h.alloc(50).unwrap_err();
        assert_eq!(err.requested, 50);
        assert_eq!(err.free, 40);
        assert_eq!(err.capacity, 100);
    }

    #[test]
    fn fragmentation_tracked() {
        let mut h = HbmAllocator::new(300);
        let a = h.alloc(100).unwrap();
        let b = h.alloc(100).unwrap();
        let _c = h.alloc(100).unwrap();
        h.free(a); // hole at 0..100
        h.free(b); // adjacent -> coalesce to 0..200
        assert_eq!(h.largest_free(), 200);
        assert_eq!(h.fragmentation(), 0.0);

        let d = h.alloc(150).unwrap(); // splits the hole
        assert_eq!(d.offset, 0);
        // free extents: 150..200 (50). frag still 0 (one extent)
        assert_eq!(h.free_bytes(), 50);
    }

    #[test]
    fn largest_free_after_merges_both_neighbours() {
        let mut h = HbmAllocator::new(1000);
        let a = h.alloc(200).unwrap(); // 0..200
        let b = h.alloc(300).unwrap(); // 200..500
        let c = h.alloc(400).unwrap(); // 500..900, tail 900..1000 free
        h.free(a); // holes: 0..200, 900..1000
        assert_eq!(h.largest_free(), 200);
        // freeing b would coalesce with the left hole: 0..500
        assert_eq!(h.largest_free_after(b), 500);
        // freeing c coalesces with the tail only: 500..1000
        assert_eq!(h.largest_free_after(c), 500);
        // prediction matches reality
        h.free(b);
        assert_eq!(h.largest_free(), 500);
    }

    #[test]
    fn peak_is_monotonic() {
        let mut h = HbmAllocator::new(100);
        let a = h.alloc(80).unwrap();
        h.free(a);
        let _b = h.alloc(10).unwrap();
        assert_eq!(h.peak(), 80);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_detected() {
        let mut h = HbmAllocator::new(100);
        let a = h.alloc(50).unwrap();
        h.free(a);
        h.free(a);
    }

    #[test]
    fn first_fit_reuses_holes() {
        let mut h = HbmAllocator::new(1000);
        let a = h.alloc(100).unwrap();
        let _b = h.alloc(100).unwrap();
        h.free(a);
        let c = h.alloc(50).unwrap();
        assert_eq!(c.offset, 0, "first fit should reuse the hole");
    }
}
