//! `DeviceSet` — an N-device fleet of simulated confidential GPUs.
//!
//! The paper measures a single VM with one GPU; the interesting regime
//! it could not run is a *fleet* where CC and No-CC devices serve the
//! same traffic side-by-side, so the CC load-time penalty becomes a
//! live routing trade-off instead of two separate experiments (cf. the
//! multi-GPU CC serving regime of "The Serialized Bridge").  A
//! `DeviceSet` owns N independent [`SimGpu`]s, each with its own
//! [`CcMode`], HBM capacity and PCIe rates — per-device residency,
//! memory pressure and crypto accounting stay fully isolated.
//!
//! The fleet itself is policy-free: which device a batch lands on is
//! the placement policy's job (`coordinator::placement`), and device
//! concurrency (busy-until timelines) is the engine's.

use crate::gpu::device::{GpuConfig, SimGpu};
use crate::gpu::CcMode;

/// An ordered set of simulated devices; device ids are indexes.
pub struct DeviceSet {
    devices: Vec<SimGpu>,
}

impl DeviceSet {
    /// Bring up one device per config (CC devices pay their attestation
    /// handshake here, exactly as a single `SimGpu` would).
    pub fn new(configs: Vec<GpuConfig>) -> anyhow::Result<DeviceSet> {
        anyhow::ensure!(!configs.is_empty(),
                        "fleet needs at least one device");
        let devices = configs.into_iter()
            .map(SimGpu::new)
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(DeviceSet { devices })
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn get(&self, device: usize) -> &SimGpu {
        &self.devices[device]
    }

    pub fn get_mut(&mut self, device: usize) -> &mut SimGpu {
        &mut self.devices[device]
    }

    /// CC mode of every device, in id order.
    pub fn modes(&self) -> Vec<CcMode> {
        self.devices.iter().map(|g| g.mode()).collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &SimGpu> {
        self.devices.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(mode: CcMode) -> GpuConfig {
        GpuConfig { mode, no_throttle: true, ..GpuConfig::default() }
    }

    #[test]
    fn mixed_fleet_reports_per_device_modes() {
        let fleet = DeviceSet::new(vec![
            cfg(CcMode::On), cfg(CcMode::Off), cfg(CcMode::On),
        ]).unwrap();
        assert_eq!(fleet.len(), 3);
        assert_eq!(fleet.modes(),
                   vec![CcMode::On, CcMode::Off, CcMode::On]);
    }

    #[test]
    fn device_memory_is_isolated() {
        let mut fleet = DeviceSet::new(vec![
            cfg(CcMode::Off), cfg(CcMode::Off),
        ]).unwrap();
        let (buf, _) = fleet.get_mut(0).upload(&vec![7u8; 50_000]).unwrap();
        assert_eq!(fleet.get(0).mem_in_use(), 50_000);
        assert_eq!(fleet.get(1).mem_in_use(), 0,
                   "an upload on device 0 must not touch device 1");
        fleet.get_mut(0).unload(buf);
        assert_eq!(fleet.get(0).mem_in_use(), 0);
    }

    #[test]
    fn empty_fleet_rejected() {
        assert!(DeviceSet::new(Vec::new()).is_err());
    }

    #[test]
    fn per_device_capacity_respected() {
        let mut small = cfg(CcMode::Off);
        small.hbm_capacity = 64 * 1024;
        let mut fleet =
            DeviceSet::new(vec![small, cfg(CcMode::Off)]).unwrap();
        let blob = vec![1u8; 100_000];
        assert!(fleet.get_mut(0).upload(&blob).is_err(),
                "small device must OOM");
        assert!(fleet.get_mut(1).upload(&blob).is_ok(),
                "default-size device must fit the same blob");
    }
}
