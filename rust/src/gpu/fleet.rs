//! `DeviceSet` — an N-device fleet of simulated confidential GPUs.
//!
//! The paper measures a single VM with one GPU; the interesting regime
//! it could not run is a *fleet* where CC and No-CC devices serve the
//! same traffic side-by-side, so the CC load-time penalty becomes a
//! live routing trade-off instead of two separate experiments (cf. the
//! multi-GPU CC serving regime of "The Serialized Bridge").  A
//! `DeviceSet` owns N independent [`SimGpu`]s, each with its own
//! [`CcMode`], HBM capacity and PCIe rates — per-device residency,
//! memory pressure and crypto accounting stay fully isolated.
//!
//! The fleet itself is policy-free: which device a batch lands on is
//! the placement policy's job (`coordinator::placement`), and device
//! concurrency (busy-until timelines) is the engine's.

use crate::gpu::device::{GpuConfig, SimGpu};
use crate::gpu::CcMode;

/// An ordered set of simulated devices; device ids are indexes.
pub struct DeviceSet {
    devices: Vec<SimGpu>,
}

impl DeviceSet {
    /// Bring up one device per config (CC devices pay their attestation
    /// handshake here, exactly as a single `SimGpu` would).
    pub fn new(configs: Vec<GpuConfig>) -> anyhow::Result<DeviceSet> {
        anyhow::ensure!(!configs.is_empty(),
                        "fleet needs at least one device");
        let devices = configs.into_iter()
            .map(SimGpu::new)
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(DeviceSet { devices })
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn get(&self, device: usize) -> &SimGpu {
        &self.devices[device]
    }

    pub fn get_mut(&mut self, device: usize) -> &mut SimGpu {
        &mut self.devices[device]
    }

    /// CC mode of every device, in id order.
    pub fn modes(&self) -> Vec<CcMode> {
        self.devices.iter().map(|g| g.mode()).collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &SimGpu> {
        self.devices.iter()
    }
}

/// Pipeline-parallel stage topology over a fleet: devices are tiled
/// into groups of `stages` *consecutive* ids, each group serving one
/// sharded model instance.  Device `g*stages` is the group's *lead* —
/// the id the scheduler dispatches to; members `lead..lead+stages`
/// hold the layer slices, and activations flow lead → lead+1 → … over
/// per-link (optionally sealed) transfers.  With `stages == 1` every
/// device is its own lead and the topology is invisible — the
/// single-stage byte-identity contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StageTopology {
    stages: usize,
    n_devices: usize,
}

impl StageTopology {
    /// `stages` must tile `n_devices` exactly (validated at config
    /// parse time; asserted here for internal callers).
    pub fn new(stages: usize, n_devices: usize) -> StageTopology {
        let stages = stages.max(1);
        assert!(n_devices >= 1 && n_devices % stages == 0,
                "{stages} stages cannot tile {n_devices} devices");
        StageTopology { stages, n_devices }
    }

    pub fn stages(&self) -> usize {
        self.stages
    }

    /// True when the topology is more than one stage per group.
    pub fn is_pipelined(&self) -> bool {
        self.stages > 1
    }

    /// Lead device of the group containing `device`.
    pub fn lead_of(&self, device: usize) -> usize {
        device - device % self.stages
    }

    pub fn is_lead(&self, device: usize) -> bool {
        device % self.stages == 0
    }

    /// Group member ids for the group led by `lead`, in stage order.
    pub fn members(&self, lead: usize) -> std::ops::Range<usize> {
        debug_assert!(self.is_lead(lead));
        lead..lead + self.stages
    }

    /// All group leads, in id order.
    pub fn leads(&self) -> impl Iterator<Item = usize> {
        (0..self.n_devices).step_by(self.stages)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(mode: CcMode) -> GpuConfig {
        GpuConfig { mode, no_throttle: true, ..GpuConfig::default() }
    }

    #[test]
    fn mixed_fleet_reports_per_device_modes() {
        let fleet = DeviceSet::new(vec![
            cfg(CcMode::On), cfg(CcMode::Off), cfg(CcMode::On),
        ]).unwrap();
        assert_eq!(fleet.len(), 3);
        assert_eq!(fleet.modes(),
                   vec![CcMode::On, CcMode::Off, CcMode::On]);
    }

    #[test]
    fn device_memory_is_isolated() {
        let mut fleet = DeviceSet::new(vec![
            cfg(CcMode::Off), cfg(CcMode::Off),
        ]).unwrap();
        let (buf, _) = fleet.get_mut(0).upload(&vec![7u8; 50_000]).unwrap();
        assert_eq!(fleet.get(0).mem_in_use(), 50_000);
        assert_eq!(fleet.get(1).mem_in_use(), 0,
                   "an upload on device 0 must not touch device 1");
        fleet.get_mut(0).unload(buf);
        assert_eq!(fleet.get(0).mem_in_use(), 0);
    }

    #[test]
    fn empty_fleet_rejected() {
        assert!(DeviceSet::new(Vec::new()).is_err());
    }

    #[test]
    fn stage_topology_tiles_the_fleet() {
        let t = StageTopology::new(2, 4);
        assert!(t.is_pipelined());
        assert_eq!(t.leads().collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(t.lead_of(0), 0);
        assert_eq!(t.lead_of(1), 0);
        assert_eq!(t.lead_of(3), 2);
        assert!(t.is_lead(2) && !t.is_lead(3));
        assert_eq!(t.members(2).collect::<Vec<_>>(), vec![2, 3]);
        // single-stage topology is invisible: every device is a lead
        let t1 = StageTopology::new(1, 3);
        assert!(!t1.is_pipelined());
        assert_eq!(t1.leads().collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(t1.members(1).collect::<Vec<_>>(), vec![1]);
    }

    #[test]
    #[should_panic(expected = "cannot tile")]
    fn stage_topology_rejects_ragged_groups() {
        StageTopology::new(3, 4);
    }

    #[test]
    fn per_device_capacity_respected() {
        let mut small = cfg(CcMode::Off);
        small.hbm_capacity = 64 * 1024;
        let mut fleet =
            DeviceSet::new(vec![small, cfg(CcMode::Off)]).unwrap();
        let blob = vec![1u8; 100_000];
        assert!(fleet.get_mut(0).upload(&blob).is_err(),
                "small device must OOM");
        assert!(fleet.get_mut(1).upload(&blob).is_ok(),
                "default-size device must fit the same blob");
    }
}
