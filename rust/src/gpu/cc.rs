//! Confidential-computing session: attestation + DMA sealing.
//!
//! Models the H100 CC-mode data path (Fig 1 of the paper): after an
//! SPDM-style attested key exchange between the CVM and the GPU, every
//! CPU↔GPU transfer is staged through *bounce buffers* and encrypted,
//! because the PCIe link is visible to the untrusted hypervisor.
//!
//! The crypto is real (AES-128-CTR + HMAC-SHA256 encrypt-then-MAC over
//! actual buffers) so CC overhead has the right shape — linear in bytes,
//! CPU-bound — rather than being a fudge factor.  The *attestation* is
//! simulated: measurements are SHA-256 digests of fixed "firmware"
//! strings, and verification checks them against golden values, standing
//! in for the NVIDIA RIM service round-trip.

use aes::cipher::{BlockEncrypt, KeyInit};
use aes::Aes128;
use hmac::{Hmac, Mac};
use sha2::{Digest, Sha256};

type HmacSha256 = Hmac<Sha256>;

/// Byte length of the HMAC tag appended to each sealed chunk.
pub const TAG_LEN: usize = 32;
/// Byte length of the per-chunk nonce prepended to each sealed chunk.
pub const NONCE_LEN: usize = 8;

/// Bytes one sealed chunk occupies on the link for `plaintext_len`
/// payload bytes: `nonce || ciphertext || tag`.
pub fn sealed_len(plaintext_len: usize) -> usize {
    NONCE_LEN + plaintext_len + TAG_LEN
}

/// Total bytes crossing the PCIe link when a `payload_len`-byte
/// transfer is staged through `bounce_bytes`-sized sealed chunks:
/// every chunk carries its own nonce + MAC tag, so CC wire traffic is
/// amplified by `NONCE_LEN + TAG_LEN` per chunk.  Zero payloads move
/// no chunks (matching `DmaEngine::transfer`, whose chunk iterator is
/// empty then).  The timing model budgets *payload* bytes — this
/// figure is accounting (`RunSummary::data_wire_bytes`), quantifying
/// the framing overhead the bounce path adds on the wire.
pub fn wire_bytes(payload_len: usize, bounce_bytes: usize) -> usize {
    assert!(bounce_bytes > 0);
    payload_len + payload_len.div_ceil(bounce_bytes) * (NONCE_LEN + TAG_LEN)
}

/// Simulated GPU identity: what the device "measures" at secure boot.
#[derive(Debug, Clone)]
pub struct DeviceEvidence {
    /// SHA-256 of the (simulated) VBIOS/firmware image.
    pub firmware_digest: [u8; 32],
    /// SHA-256 of the (simulated) driver blob.
    pub driver_digest: [u8; 32],
    /// Attestation nonce echoed back, proving freshness.
    pub nonce: [u8; 32],
}

const SIM_FIRMWARE: &[u8] = b"sincere-sim-h100-vbios-96.00.30.00.01";
const SIM_DRIVER: &[u8] = b"sincere-sim-driver-550.54.14";

fn digest(data: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(data);
    h.finalize().into()
}

/// Golden measurements the verifier expects (RIM stand-in).
pub fn golden_measurements() -> ([u8; 32], [u8; 32]) {
    (digest(SIM_FIRMWARE), digest(SIM_DRIVER))
}

/// Simulated secure-boot measurement + evidence generation.
pub fn collect_evidence(nonce: [u8; 32]) -> DeviceEvidence {
    DeviceEvidence {
        firmware_digest: digest(SIM_FIRMWARE),
        driver_digest: digest(SIM_DRIVER),
        nonce,
    }
}

/// Verify evidence against golden values; returns the attestation
/// transcript hash that is mixed into the session key.
pub fn verify_evidence(ev: &DeviceEvidence, nonce: [u8; 32])
                       -> anyhow::Result<[u8; 32]> {
    let (fw, drv) = golden_measurements();
    anyhow::ensure!(ev.firmware_digest == fw, "firmware measurement mismatch");
    anyhow::ensure!(ev.driver_digest == drv, "driver measurement mismatch");
    anyhow::ensure!(ev.nonce == nonce, "stale attestation nonce");
    let mut h = Sha256::new();
    h.update(ev.firmware_digest);
    h.update(ev.driver_digest);
    h.update(ev.nonce);
    Ok(h.finalize().into())
}

/// HKDF-style expansion over HMAC-SHA256 (extract-then-expand, one block).
fn hkdf(ikm: &[u8], salt: &[u8], info: &[u8]) -> [u8; 32] {
    let mut mac = <HmacSha256 as Mac>::new_from_slice(salt).unwrap();
    mac.update(ikm);
    let prk = mac.finalize().into_bytes();
    let mut mac = <HmacSha256 as Mac>::new_from_slice(&prk).unwrap();
    mac.update(info);
    mac.update(&[0x01]);
    mac.finalize().into_bytes().into()
}

/// An established CC session: the keys protecting the PCIe link.
pub struct CcSession {
    enc: Aes128,
    mac_key: [u8; 32],
    /// Monotonic chunk counter — nonce uniqueness across the session.
    seq: std::cell::Cell<u64>,
}

impl CcSession {
    /// Run the (simulated) SPDM handshake and derive session keys.
    ///
    /// `host_secret` stands in for the CVM-side DH share; mixing in the
    /// attestation transcript binds keys to verified measurements.
    pub fn establish(host_secret: u64) -> anyhow::Result<CcSession> {
        let nonce = digest(&host_secret.to_le_bytes());
        let evidence = collect_evidence(nonce);
        let transcript = verify_evidence(&evidence, nonce)?;
        let ikm = [&host_secret.to_le_bytes()[..], &transcript[..]].concat();
        let enc_key = hkdf(&ikm, b"sincere-cc-salt", b"pcie-enc");
        let mac_key = hkdf(&ikm, b"sincere-cc-salt", b"pcie-mac");
        Ok(CcSession {
            enc: Aes128::new_from_slice(&enc_key[..16]).unwrap(),
            mac_key,
            seq: std::cell::Cell::new(0),
        })
    }

    fn keystream_xor(&self, nonce: u64, data: &mut [u8]) {
        // AES-128-CTR: counter block = nonce || block index.  Counter
        // blocks are encrypted in batches of 8 so the AES units pipeline
        // (measured ~2.3x over block-at-a-time on this host, §Perf).
        const PAR: usize = 8;
        let mut ctr = [aes::Block::default(); PAR];
        let mut i = 0u64;
        let mut off = 0usize;
        while off < data.len() {
            let n = ((data.len() - off) + 15) / 16;
            let n = n.min(PAR);
            for (j, blk) in ctr[..n].iter_mut().enumerate() {
                blk[..8].copy_from_slice(&nonce.to_le_bytes());
                blk[8..].copy_from_slice(&(i + j as u64).to_le_bytes());
            }
            self.enc.encrypt_blocks(&mut ctr[..n]);
            for blk in &ctr[..n] {
                let end = (off + 16).min(data.len());
                for (b, k) in data[off..end].iter_mut().zip(blk.iter()) {
                    *b ^= k;
                }
                off = end;
            }
            i += n as u64;
        }
    }

    fn tag(&self, nonce: u64, ct: &[u8]) -> [u8; TAG_LEN] {
        let mut mac =
            <HmacSha256 as Mac>::new_from_slice(&self.mac_key).unwrap();
        mac.update(&nonce.to_le_bytes());
        mac.update(ct);
        mac.finalize().into_bytes().into()
    }

    /// Seal one bounce-buffer chunk into `out` (cleared first):
    /// `nonce || ciphertext || tag`.  Allocation-free when `out` has
    /// capacity — the DMA engine reuses one bounce buffer per transfer.
    pub fn seal_into(&self, plaintext: &[u8], out: &mut Vec<u8>) {
        let nonce = self.seq.get();
        self.seq.set(nonce + 1);
        out.clear();
        out.reserve(NONCE_LEN + plaintext.len() + TAG_LEN);
        out.extend_from_slice(&nonce.to_le_bytes());
        out.extend_from_slice(plaintext);
        self.keystream_xor(nonce, &mut out[NONCE_LEN..]);
        let tag = self.tag(nonce, &out[NONCE_LEN..]);
        out.extend_from_slice(&tag);
    }

    /// Seal one chunk (allocating convenience wrapper).
    pub fn seal(&self, plaintext: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        self.seal_into(plaintext, &mut out);
        out
    }

    /// Open a sealed chunk directly into `dst` (the "device" side of the
    /// bounce buffer), authenticating before decrypting.
    pub fn open_into(&self, sealed: &[u8], dst: &mut [u8])
                     -> anyhow::Result<()> {
        anyhow::ensure!(sealed.len() >= NONCE_LEN + TAG_LEN,
                        "sealed chunk too short ({} bytes)", sealed.len());
        let nonce = u64::from_le_bytes(sealed[..NONCE_LEN].try_into()?);
        let (ct, tag) = sealed[NONCE_LEN..]
            .split_at(sealed.len() - NONCE_LEN - TAG_LEN);
        anyhow::ensure!(dst.len() == ct.len(),
                        "open_into dst {} != ct {}", dst.len(), ct.len());
        let want = self.tag(nonce, ct);
        // constant-time compare
        let mut diff = 0u8;
        for (a, b) in want.iter().zip(tag) {
            diff |= a ^ b;
        }
        anyhow::ensure!(diff == 0, "DMA authentication failure (tampered \
                                    bounce buffer)");
        dst.copy_from_slice(ct);
        self.keystream_xor(nonce, dst);
        Ok(())
    }

    /// Open a sealed chunk (allocating convenience wrapper).
    pub fn open(&self, sealed: &[u8]) -> anyhow::Result<Vec<u8>> {
        anyhow::ensure!(sealed.len() >= NONCE_LEN + TAG_LEN,
                        "sealed chunk too short ({} bytes)", sealed.len());
        let mut pt = vec![0u8; sealed.len() - NONCE_LEN - TAG_LEN];
        self.open_into(sealed, &mut pt)?;
        Ok(pt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn session() -> CcSession {
        CcSession::establish(0xA11CE).unwrap()
    }

    #[test]
    fn seal_open_roundtrip() {
        let s = session();
        for len in [0usize, 1, 15, 16, 17, 1000, 65536] {
            let data: Vec<u8> = (0..len).map(|i| (i * 7) as u8).collect();
            let sealed = s.seal(&data);
            assert_eq!(sealed.len(), NONCE_LEN + len + TAG_LEN);
            assert_eq!(s.open(&sealed).unwrap(), data);
        }
    }

    #[test]
    fn ciphertext_differs_from_plaintext() {
        let s = session();
        let data = vec![0xABu8; 256];
        let sealed = s.seal(&data);
        assert_ne!(&sealed[NONCE_LEN..NONCE_LEN + 256], &data[..]);
    }

    #[test]
    fn nonce_reuse_avoided() {
        let s = session();
        let a = s.seal(b"same plaintext");
        let b = s.seal(b"same plaintext");
        assert_ne!(a, b, "two seals of same data must differ (fresh nonce)");
    }

    #[test]
    fn tamper_detected() {
        let s = session();
        let mut sealed = s.seal(b"model weights chunk");
        let mid = sealed.len() / 2;
        sealed[mid] ^= 0x01;
        assert!(s.open(&sealed).is_err());
    }

    #[test]
    fn truncation_detected() {
        let s = session();
        let sealed = s.seal(b"data");
        assert!(s.open(&sealed[..sealed.len() - 1]).is_err());
        assert!(s.open(&sealed[..NONCE_LEN]).is_err());
    }

    #[test]
    fn wire_bytes_matches_actual_sealed_chunks() {
        // the accounting helper must agree with what sealing really
        // puts on the link, chunk for chunk
        let s = session();
        for (len, bounce) in [(0usize, 1024usize), (1, 1024), (1024, 1024),
                              (1025, 1024), (10_000, 1024), (10_000, 256)] {
            let payload = vec![0x5Au8; len];
            let on_wire: usize = payload.chunks(bounce)
                .map(|c| s.seal(c).len()).sum();
            assert_eq!(wire_bytes(len, bounce), on_wire,
                       "len {len} bounce {bounce}");
        }
        assert_eq!(sealed_len(100), NONCE_LEN + 100 + TAG_LEN);
        assert_eq!(wire_bytes(0, 4096), 0, "empty payloads move nothing");
    }

    #[test]
    fn attestation_rejects_bad_measurement() {
        let nonce = [7u8; 32];
        let mut ev = collect_evidence(nonce);
        ev.firmware_digest[0] ^= 1;
        assert!(verify_evidence(&ev, nonce).is_err());
    }

    #[test]
    fn attestation_rejects_stale_nonce() {
        let ev = collect_evidence([1u8; 32]);
        assert!(verify_evidence(&ev, [2u8; 32]).is_err());
    }

    #[test]
    fn sessions_with_same_secret_interoperate() {
        let a = CcSession::establish(42).unwrap();
        let b = CcSession::establish(42).unwrap();
        let sealed = a.seal(b"cross-session");
        assert_eq!(b.open(&sealed).unwrap(), b"cross-session");
    }

    #[test]
    fn sessions_with_different_secrets_reject() {
        let a = CcSession::establish(1).unwrap();
        let b = CcSession::establish(2).unwrap();
        let sealed = a.seal(b"cross-session");
        assert!(b.open(&sealed).is_err());
    }
}
