//! The simulated confidential GPU (H100 stand-in).
//!
//! The paper's performance story is entirely about *where device time
//! goes*: encrypted model-load DMA (CC ≫ No-CC, Fig 3), inference
//! compute vs batch size (Fig 4), and idle/scheduling gaps (Fig 7).
//! This module reproduces each component with real work:
//!
//! * [`hbm`] — device-memory allocator with capacity/fragmentation
//!   accounting (the OOM boundary that ends batch-size profiling).
//! * [`cc`] — the confidential-computing session: simulated SPDM-style
//!   attestation, HKDF key schedule, and AES-128-CTR + HMAC-SHA256
//!   bounce-buffer sealing of every DMA transfer (H100 CC mode's
//!   encrypted PCIe path).
//! * [`dma`] — the transfer engine that actually moves (and in CC mode
//!   actually encrypts/decrypts) every model byte through fixed-size
//!   bounce buffers, under a configurable PCIe bandwidth model.
//! * [`device`] — `SimGpu`, tying the above together with busy/idle
//!   occupancy accounting (the GPU-utilization metric of Fig 7).
//! * [`fleet`] — `DeviceSet`, N independent `SimGpu`s (per-device
//!   `CcMode`/HBM/PCIe) behind the engine's fleet scheduling.
//! * [`profile`] — named hardware-generation device profiles
//!   (`h100-cc`, `b300-cc`, `gh200-coherent`, …) bundling the
//!   per-device knobs, including the UMA/bridge-residual pricing of
//!   the newer generations.

pub mod cc;
pub mod device;
pub mod dma;
pub mod fleet;
pub mod hbm;
pub mod profile;

/// Confidential-computing mode of the device (the paper's CC / No-CC).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CcMode {
    /// H100 CC mode: attested init, every DMA sealed through bounce
    /// buffers.
    On,
    /// Plain mode: raw DMA.
    Off,
}

impl CcMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            CcMode::On => "cc",
            CcMode::Off => "no-cc",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<CcMode> {
        match s {
            "cc" | "on" | "CC" => Ok(CcMode::On),
            "no-cc" | "nocc" | "off" | "No-CC" => Ok(CcMode::Off),
            other => anyhow::bail!("unknown CC mode {other:?} (cc|no-cc)"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_roundtrip() {
        assert_eq!(CcMode::parse("cc").unwrap(), CcMode::On);
        assert_eq!(CcMode::parse("no-cc").unwrap(), CcMode::Off);
        assert_eq!(CcMode::parse(CcMode::On.as_str()).unwrap(), CcMode::On);
        assert!(CcMode::parse("tdx").is_err());
    }
}
