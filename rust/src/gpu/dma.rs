//! DMA transfer engine with a PCIe bandwidth model and CC bounce path.
//!
//! Every transfer *actually moves the bytes* into the device store; in
//! CC mode each bounce-buffer chunk is sealed (AES-CTR+HMAC) on the host
//! side and opened on the "device" side — the data at rest in simulated
//! HBM is the decrypted plaintext, matching the H100 model where HBM is
//! inside the trust boundary and only the PCIe link is protected.
//!
//! Bandwidth model: after doing the real work (copy + crypto) the engine
//! sleeps out the remainder of `len / bandwidth`, so configured GB/s are
//! an *upper* bound and CC crypto cost shows up organically when it
//! exceeds the budget.  Defaults are calibrated in `config` so load
//! times land in the paper's Fig 3 regime (CC ≈ 2.5–3× No-CC).

use std::time::{Duration, Instant};

use crate::gpu::cc::CcSession;

/// Counters the system monitor exports.
#[derive(Debug, Default, Clone)]
pub struct DmaStats {
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    pub h2d_transfers: u64,
    pub d2h_transfers: u64,
    /// Wall time spent inside transfers.
    pub busy: Duration,
    /// Portion of `busy` spent in seal/open (CC only).
    pub crypto: Duration,
}

/// Result of a single transfer.
#[derive(Debug, Clone, Copy)]
pub struct TransferReport {
    pub bytes: u64,
    pub elapsed: Duration,
    pub crypto: Duration,
}

/// Direction of a DMA transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    HostToDevice,
    DeviceToHost,
}

/// The transfer engine.
pub struct DmaEngine {
    /// Plain-mode PCIe bandwidth, bytes/second.
    pub bw_plain: f64,
    /// CC-mode effective link bandwidth, bytes/second (bounce-buffer
    /// staging halves usable bandwidth before crypto cost).
    pub bw_cc: f64,
    /// Bounce-buffer chunk size, bytes.
    pub bounce_bytes: usize,
    /// When true, skip the throttle sleeps (used by unit tests and the
    /// hot-path benches; experiment runs keep it on).
    pub no_throttle: bool,
    /// Reused sealed-chunk staging buffer (§Perf: one allocation per
    /// engine instead of two per chunk).
    bounce: Vec<u8>,
    stats: DmaStats,
}

impl DmaEngine {
    pub fn new(bw_plain: f64, bw_cc: f64, bounce_bytes: usize) -> DmaEngine {
        assert!(bw_plain > 0.0 && bw_cc > 0.0 && bounce_bytes > 0);
        DmaEngine { bw_plain, bw_cc, bounce_bytes, no_throttle: false,
                    bounce: Vec::new(), stats: DmaStats::default() }
    }

    /// Move `src` into `dst` (pre-sized by the caller), optionally
    /// through the CC bounce path, and account the time.
    pub fn transfer(&mut self, dir: Dir, src: &[u8], dst: &mut [u8],
                    cc: Option<&CcSession>) -> anyhow::Result<TransferReport> {
        anyhow::ensure!(src.len() == dst.len(),
                        "dma size mismatch: src {} dst {}", src.len(),
                        dst.len());
        let start = Instant::now();
        let mut crypto = Duration::ZERO;

        match cc {
            None => dst.copy_from_slice(src),
            Some(session) => {
                // Chunked: host seals into the reused bounce buffer, the
                // "device" side authenticates and decrypts straight into
                // its memory (zero extra copies, §Perf).
                for (s_chunk, d_chunk) in src.chunks(self.bounce_bytes)
                    .zip(dst.chunks_mut(self.bounce_bytes))
                {
                    let t0 = Instant::now();
                    session.seal_into(s_chunk, &mut self.bounce);
                    session.open_into(&self.bounce, d_chunk)?;
                    crypto += t0.elapsed();
                }
            }
        }

        // Bandwidth throttle: sleep out the remainder of the budget.
        let bw = if cc.is_some() { self.bw_cc } else { self.bw_plain };
        let target = Duration::from_secs_f64(src.len() as f64 / bw);
        let done = start.elapsed();
        if !self.no_throttle && target > done {
            std::thread::sleep(target - done);
        }

        let elapsed = start.elapsed();
        self.stats.busy += elapsed;
        self.stats.crypto += crypto;
        match dir {
            Dir::HostToDevice => {
                self.stats.h2d_bytes += src.len() as u64;
                self.stats.h2d_transfers += 1;
            }
            Dir::DeviceToHost => {
                self.stats.d2h_bytes += src.len() as u64;
                self.stats.d2h_transfers += 1;
            }
        }
        Ok(TransferReport { bytes: src.len() as u64, elapsed, crypto })
    }

    pub fn stats(&self) -> &DmaStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::cc::CcSession;

    fn engine_unthrottled() -> DmaEngine {
        let mut e = DmaEngine::new(1e9, 0.4e9, 64 * 1024);
        e.no_throttle = true;
        e
    }

    #[test]
    fn plain_transfer_moves_bytes() {
        let mut e = engine_unthrottled();
        let src: Vec<u8> = (0..100_000).map(|i| (i % 251) as u8).collect();
        let mut dst = vec![0u8; src.len()];
        let rep = e.transfer(Dir::HostToDevice, &src, &mut dst, None).unwrap();
        assert_eq!(dst, src);
        assert_eq!(rep.bytes, 100_000);
        assert_eq!(rep.crypto, Duration::ZERO);
        assert_eq!(e.stats().h2d_transfers, 1);
    }

    #[test]
    fn cc_transfer_decrypts_correctly_across_chunks() {
        let mut e = engine_unthrottled();
        e.bounce_bytes = 1024; // force many chunks
        let session = CcSession::establish(99).unwrap();
        let src: Vec<u8> = (0..10_000).map(|i| (i % 253) as u8).collect();
        let mut dst = vec![0u8; src.len()];
        let rep = e.transfer(Dir::HostToDevice, &src, &mut dst,
                             Some(&session)).unwrap();
        assert_eq!(dst, src, "plaintext must land in device memory");
        assert!(rep.crypto > Duration::ZERO);
    }

    #[test]
    fn throttle_enforces_bandwidth_floor() {
        let mut e = DmaEngine::new(10e6, 4e6, 64 * 1024); // 10 / 4 MB/s
        let src = vec![7u8; 1_000_000]; // 1 MB -> >=100 ms plain
        let mut dst = vec![0u8; src.len()];
        let rep = e.transfer(Dir::HostToDevice, &src, &mut dst, None).unwrap();
        assert!(rep.elapsed >= Duration::from_millis(95),
                "throttle too weak: {:?}", rep.elapsed);
    }

    #[test]
    fn cc_slower_than_plain_under_throttle() {
        // wide bandwidth separation so the assertion is robust even when
        // parallel tests steal CPU from the sleeping thread
        let mut e = DmaEngine::new(50e6, 5e6, 256 * 1024);
        let session = CcSession::establish(1).unwrap();
        let src = vec![3u8; 2_000_000]; // plain ~40 ms, cc ~400 ms
        let mut dst = vec![0u8; src.len()];
        let plain = e.transfer(Dir::HostToDevice, &src, &mut dst, None)
            .unwrap().elapsed;
        let cc = e.transfer(Dir::HostToDevice, &src, &mut dst,
                            Some(&session)).unwrap().elapsed;
        assert!(cc > plain, "cc {cc:?} <= plain {plain:?}");
        let ratio = cc.as_secs_f64() / plain.as_secs_f64();
        assert!(ratio > 3.0, "ratio {ratio} (want ~10 modulo load)");
    }

    #[test]
    fn size_mismatch_rejected() {
        let mut e = engine_unthrottled();
        let mut dst = vec![0u8; 10];
        assert!(e.transfer(Dir::HostToDevice, &[1, 2, 3], &mut dst, None)
                .is_err());
    }
}
