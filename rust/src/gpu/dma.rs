//! DMA transfer engine with a PCIe bandwidth model and CC bounce path.
//!
//! Every transfer *actually moves the bytes* into the device store; in
//! CC mode each bounce-buffer chunk is sealed (AES-CTR+HMAC) on the host
//! side and opened on the "device" side — the data at rest in simulated
//! HBM is the decrypted plaintext, matching the H100 model where HBM is
//! inside the trust boundary and only the PCIe link is protected.
//!
//! Bandwidth model: after doing the real work (copy + crypto) the engine
//! sleeps out the remainder of the *modeled* transfer budget, so
//! configured GB/s are an *upper* bound.  Defaults are calibrated in
//! `config` so load times land in the paper's Fig 3 regime
//! (CC ≈ 2.5–3× No-CC).
//!
//! ## The CC chunk pipeline
//!
//! The serialized CC budget per byte is `1/bw_cc`, split by
//! `cc_crypto_frac` into a crypto share (seal + open) and a link share
//! (bounce-buffer PCIe time).  With `pipeline_depth < 2` every chunk
//! pays `crypto + link` in sequence — the paper's serialized bounce
//! path.  With `pipeline_depth >= 2` staging buffers, sealing chunk
//! *k+1* overlaps the link time of chunk *k* (PipeLLM-style speculative
//! pipelined encryption):
//!
//! ```text
//! serialized:  [seal+open 0][link 0][seal+open 1][link 1]...
//! pipelined:   [seal+open 0][seal+open 1][seal+open 2]...
//!                           [link 0]     [link 1]     [link 2]...
//! ```
//!
//! Steady state the pipeline pays `max(crypto, link)` per chunk instead
//! of their sum; only the fill latency and any crypto overhang are
//! *exposed*.  `TransferReport`/`DmaStats` therefore split crypto time
//! into `crypto_total` (work done) and `crypto_exposed` (time not
//! hidden behind the link) — the two coincide exactly when serialized.
//!
//! The seal/open work itself still runs sequentially on the calling
//! thread (data fidelity); the overlap is expressed through the modeled
//! budget the throttle sleeps out, which is also what `sim::calib`
//! prices into the DES cost tables.

use std::time::{Duration, Instant};

use crate::gpu::cc::CcSession;

/// Counters the system monitor exports.
#[derive(Debug, Default, Clone)]
pub struct DmaStats {
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    pub h2d_transfers: u64,
    pub d2h_transfers: u64,
    /// Wall time spent inside transfers.
    pub busy: Duration,
    /// Modeled seal/open work across all CC transfers (budget domain).
    pub crypto_total: Duration,
    /// Crypto time not hidden behind the link; equals `crypto_total`
    /// when the pipeline is off.
    pub crypto_exposed: Duration,
}

/// Result of a single transfer.  The crypto figures are in the modeled
/// budget domain (what the throttle enforces), so they stay meaningful
/// when `no_throttle` skips the sleeps.
#[derive(Debug, Clone, Copy)]
pub struct TransferReport {
    pub bytes: u64,
    pub elapsed: Duration,
    /// Total modeled seal/open work for this transfer (CC only).
    pub crypto_total: Duration,
    /// Crypto time not overlapped with the link (== total when
    /// serialized; the pipeline fill + overhang when pipelined).
    pub crypto_exposed: Duration,
}

/// Direction of a DMA transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    HostToDevice,
    DeviceToHost,
}

/// The transfer engine.
pub struct DmaEngine {
    /// Plain-mode PCIe bandwidth, bytes/second.
    pub bw_plain: f64,
    /// CC-mode effective *serialized* bandwidth, bytes/second: the
    /// combined per-byte cost of bounce-buffer crypto + link time when
    /// chunks run strictly in sequence.
    pub bw_cc: f64,
    /// Bounce-buffer chunk size, bytes.
    pub bounce_bytes: usize,
    /// Staging buffers for the two-stage CC chunk pipeline: `< 2`
    /// serializes crypto and link per chunk; `>= 2` overlaps sealing
    /// chunk k+1 with the link time of chunk k.
    pub pipeline_depth: usize,
    /// Fraction of the serialized CC per-byte budget that is crypto
    /// (the rest is link time).  Only the split — not the serialized
    /// total — depends on this, so serialized runs are insensitive to
    /// it.
    pub cc_crypto_frac: f64,
    /// When true, skip the throttle sleeps (used by unit tests and the
    /// hot-path benches; experiment runs keep it on).
    pub no_throttle: bool,
    /// Reused sealed-chunk staging buffer (§Perf: one allocation per
    /// engine instead of two per chunk).
    bounce: Vec<u8>,
    stats: DmaStats,
}

/// Modeled plain-mode transfer budget for `len` bytes, seconds.
pub fn plain_budget_s(len: usize, bw_plain: f64) -> f64 {
    len as f64 / bw_plain
}

/// Modeled CC transfer budget for `len` bytes under an explicit
/// bounce/pipeline setting: total seconds plus the (total, exposed)
/// crypto split.  Serialized (`pipeline_depth < 2`) this is
/// `len/bw_cc` with crypto fully exposed; pipelined, chunk crypto
/// overlaps the previous chunk's link time and only the fill +
/// overhang is exposed.
///
/// This is the single definition of the CC per-transfer budget: the
/// real [`DmaEngine`] throttles against it, and the virtual-cost
/// backends price the inference data path from it
/// (`engine::backend::price_data_path`), so the two time domains
/// cannot drift.
pub fn cc_budget_s(len: usize, bw_cc: f64, bounce_bytes: usize,
                   pipeline_depth: usize, cc_crypto_frac: f64)
                   -> (f64, f64, f64) {
    let per_byte = 1.0 / bw_cc;
    let frac = cc_crypto_frac.clamp(0.0, 1.0);
    let crypto_pb = frac * per_byte;
    let link_pb = (1.0 - frac) * per_byte;
    let crypto_total = len as f64 * crypto_pb;
    let link_total = len as f64 * link_pb;
    if pipeline_depth < 2 {
        // strictly serialized: every chunk pays crypto + link
        return (len as f64 * per_byte, crypto_total, crypto_total);
    }
    // Two-stage pipeline with `pipeline_depth` staging buffers:
    // crypto for chunk k may start once buffer (k - depth) has
    // drained onto the link; the link takes chunks in order.
    let depth = pipeline_depth;
    let n_chunks = len.div_ceil(bounce_bytes).max(1);
    let mut link_ends: Vec<f64> = Vec::with_capacity(n_chunks);
    let mut crypto_end = 0.0f64;
    let mut link_end = 0.0f64;
    for k in 0..n_chunks {
        let chunk = if (k + 1) * bounce_bytes <= len {
            bounce_bytes
        } else {
            len - k * bounce_bytes
        };
        let c = chunk as f64 * crypto_pb;
        let l = chunk as f64 * link_pb;
        let buffer_free = if k >= depth {
            link_ends[k - depth]
        } else {
            0.0
        };
        crypto_end = crypto_end.max(buffer_free) + c;
        link_end = link_end.max(crypto_end) + l;
        link_ends.push(link_end);
    }
    let exposed = (link_end - link_total).max(0.0);
    (link_end, crypto_total, exposed)
}

impl DmaEngine {
    pub fn new(bw_plain: f64, bw_cc: f64, bounce_bytes: usize) -> DmaEngine {
        assert!(bw_plain > 0.0 && bw_cc > 0.0 && bounce_bytes > 0);
        DmaEngine { bw_plain, bw_cc, bounce_bytes, pipeline_depth: 0,
                    cc_crypto_frac: 0.5, no_throttle: false,
                    bounce: Vec::new(), stats: DmaStats::default() }
    }

    /// This engine's CC budget for `len` bytes (see [`cc_budget_s`]).
    fn cc_budget(&self, len: usize) -> (f64, f64, f64) {
        cc_budget_s(len, self.bw_cc, self.bounce_bytes,
                    self.pipeline_depth, self.cc_crypto_frac)
    }

    /// Move `src` into `dst` (pre-sized by the caller), optionally
    /// through the CC bounce path, and account the time.
    pub fn transfer(&mut self, dir: Dir, src: &[u8], dst: &mut [u8],
                    cc: Option<&CcSession>) -> anyhow::Result<TransferReport> {
        anyhow::ensure!(src.len() == dst.len(),
                        "dma size mismatch: src {} dst {}", src.len(),
                        dst.len());
        let start = Instant::now();

        let (target_s, crypto_total_s, crypto_exposed_s) = match cc {
            None => {
                dst.copy_from_slice(src);
                (plain_budget_s(src.len(), self.bw_plain), 0.0, 0.0)
            }
            Some(session) => {
                // Chunked: host seals into the reused bounce buffer, the
                // "device" side authenticates and decrypts straight into
                // its memory (zero extra copies, §Perf).  The work runs
                // sequentially; the budget below models the overlap.
                let mut bounce = std::mem::take(&mut self.bounce);
                for (s_chunk, d_chunk) in src.chunks(self.bounce_bytes)
                    .zip(dst.chunks_mut(self.bounce_bytes))
                {
                    session.seal_into(s_chunk, &mut bounce);
                    session.open_into(&bounce, d_chunk)?;
                }
                self.bounce = bounce;
                self.cc_budget(src.len())
            }
        };

        // Bandwidth throttle: sleep out the remainder of the budget.
        let target = Duration::from_secs_f64(target_s);
        let done = start.elapsed();
        if !self.no_throttle && target > done {
            std::thread::sleep(target - done);
        }

        let elapsed = start.elapsed();
        let crypto_total = Duration::from_secs_f64(crypto_total_s);
        let crypto_exposed = Duration::from_secs_f64(crypto_exposed_s);
        self.stats.busy += elapsed;
        self.stats.crypto_total += crypto_total;
        self.stats.crypto_exposed += crypto_exposed;
        match dir {
            Dir::HostToDevice => {
                self.stats.h2d_bytes += src.len() as u64;
                self.stats.h2d_transfers += 1;
            }
            Dir::DeviceToHost => {
                self.stats.d2h_bytes += src.len() as u64;
                self.stats.d2h_transfers += 1;
            }
        }
        Ok(TransferReport { bytes: src.len() as u64, elapsed, crypto_total,
                            crypto_exposed })
    }

    pub fn stats(&self) -> &DmaStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::cc::CcSession;

    fn engine_unthrottled() -> DmaEngine {
        let mut e = DmaEngine::new(1e9, 0.4e9, 64 * 1024);
        e.no_throttle = true;
        e
    }

    #[test]
    fn plain_transfer_moves_bytes() {
        let mut e = engine_unthrottled();
        let src: Vec<u8> = (0..100_000).map(|i| (i % 251) as u8).collect();
        let mut dst = vec![0u8; src.len()];
        let rep = e.transfer(Dir::HostToDevice, &src, &mut dst, None).unwrap();
        assert_eq!(dst, src);
        assert_eq!(rep.bytes, 100_000);
        assert_eq!(rep.crypto_total, Duration::ZERO);
        assert_eq!(rep.crypto_exposed, Duration::ZERO);
        assert_eq!(e.stats().h2d_transfers, 1);
    }

    #[test]
    fn cc_transfer_decrypts_correctly_across_chunks() {
        let mut e = engine_unthrottled();
        e.bounce_bytes = 1024; // force many chunks
        let session = CcSession::establish(99).unwrap();
        let src: Vec<u8> = (0..10_000).map(|i| (i % 253) as u8).collect();
        let mut dst = vec![0u8; src.len()];
        let rep = e.transfer(Dir::HostToDevice, &src, &mut dst,
                             Some(&session)).unwrap();
        assert_eq!(dst, src, "plaintext must land in device memory");
        assert!(rep.crypto_total > Duration::ZERO);
        // serialized: every crypto second is exposed
        assert_eq!(rep.crypto_total, rep.crypto_exposed);
    }

    #[test]
    fn pipelined_cc_transfer_still_decrypts() {
        let mut e = engine_unthrottled();
        e.bounce_bytes = 1024;
        e.pipeline_depth = 2;
        let session = CcSession::establish(99).unwrap();
        let src: Vec<u8> = (0..10_000).map(|i| (i % 241) as u8).collect();
        let mut dst = vec![0u8; src.len()];
        let rep = e.transfer(Dir::HostToDevice, &src, &mut dst,
                             Some(&session)).unwrap();
        assert_eq!(dst, src);
        // overlap hides most crypto: exposed strictly below total but
        // never zero (the fill chunk cannot be hidden)
        assert!(rep.crypto_exposed > Duration::ZERO);
        assert!(rep.crypto_exposed < rep.crypto_total,
                "pipeline must hide some crypto: exposed {:?} total {:?}",
                rep.crypto_exposed, rep.crypto_total);
    }

    #[test]
    fn throttle_enforces_bandwidth_floor() {
        let mut e = DmaEngine::new(10e6, 4e6, 64 * 1024); // 10 / 4 MB/s
        let src = vec![7u8; 1_000_000]; // 1 MB -> >=100 ms plain
        let mut dst = vec![0u8; src.len()];
        let rep = e.transfer(Dir::HostToDevice, &src, &mut dst, None).unwrap();
        assert!(rep.elapsed >= Duration::from_millis(95),
                "throttle too weak: {:?}", rep.elapsed);
    }

    #[test]
    fn cc_slower_than_plain_under_throttle() {
        // wide bandwidth separation so the assertion is robust even when
        // parallel tests steal CPU from the sleeping thread
        let mut e = DmaEngine::new(50e6, 5e6, 256 * 1024);
        let session = CcSession::establish(1).unwrap();
        let src = vec![3u8; 2_000_000]; // plain ~40 ms, cc ~400 ms
        let mut dst = vec![0u8; src.len()];
        let plain = e.transfer(Dir::HostToDevice, &src, &mut dst, None)
            .unwrap().elapsed;
        let cc = e.transfer(Dir::HostToDevice, &src, &mut dst,
                            Some(&session)).unwrap().elapsed;
        assert!(cc > plain, "cc {cc:?} <= plain {plain:?}");
        let ratio = cc.as_secs_f64() / plain.as_secs_f64();
        assert!(ratio > 3.0, "ratio {ratio} (want ~10 modulo load)");
    }

    #[test]
    fn pipelined_cc_faster_than_serialized_under_throttle() {
        // 1 MB at 5 MB/s serialized = ~200 ms; with depth 2 and an even
        // crypto/link split the steady state halves to ~100 ms + fill
        let src = vec![9u8; 1_000_000];
        let mut dst = vec![0u8; src.len()];
        let session = CcSession::establish(4).unwrap();
        let mut serial = DmaEngine::new(50e6, 5e6, 64 * 1024);
        let t_serial = serial.transfer(Dir::HostToDevice, &src, &mut dst,
                                       Some(&session)).unwrap().elapsed;
        let mut pipe = DmaEngine::new(50e6, 5e6, 64 * 1024);
        pipe.pipeline_depth = 2;
        let t_pipe = pipe.transfer(Dir::HostToDevice, &src, &mut dst,
                                   Some(&session)).unwrap().elapsed;
        assert!(t_pipe.as_secs_f64() < 0.8 * t_serial.as_secs_f64(),
                "pipeline did not recover time: pipe {t_pipe:?} vs \
                 serial {t_serial:?}");
        // but it can never beat the pure link share of the budget
        assert!(t_pipe.as_secs_f64() > 0.4 * t_serial.as_secs_f64(),
                "pipeline beat the link floor: {t_pipe:?}");
    }

    #[test]
    fn pipeline_budget_shape() {
        // budget arithmetic, no sleeping: equal chunks, frac 0.5
        let mut e = engine_unthrottled();
        e.bounce_bytes = 1000;
        e.cc_crypto_frac = 0.5;
        let len = 10_000; // 10 chunks
        let (serial, ct, ce) = e.cc_budget(len);
        assert!((serial - len as f64 / e.bw_cc).abs() < 1e-12);
        assert!((ct - 0.5 * serial).abs() < 1e-12);
        assert!((ce - ct).abs() < 1e-12, "serialized exposes all crypto");
        e.pipeline_depth = 2;
        let (pipe, ct2, ce2) = e.cc_budget(len);
        assert!((ct2 - ct).abs() < 1e-12, "work done is unchanged");
        // steady state: fill chunk + 10 link slots = 11/20 of serialized
        assert!((pipe - serial * 11.0 / 20.0).abs() < 1e-9,
                "pipe {pipe} vs serial {serial}");
        // exposed = exactly the fill chunk's crypto
        assert!((ce2 - serial * 0.05).abs() < 1e-9, "exposed {ce2}");
    }

    #[test]
    fn pipeline_depth_does_not_change_plain_mode() {
        let src = vec![1u8; 500_000];
        let mut dst = vec![0u8; src.len()];
        let mut a = DmaEngine::new(20e6, 5e6, 64 * 1024);
        let mut b = DmaEngine::new(20e6, 5e6, 64 * 1024);
        b.pipeline_depth = 4;
        let ta = a.transfer(Dir::HostToDevice, &src, &mut dst, None)
            .unwrap().elapsed;
        let tb = b.transfer(Dir::HostToDevice, &src, &mut dst, None)
            .unwrap().elapsed;
        // both sleep out the same plain budget (~25 ms); allow jitter
        let diff = (ta.as_secs_f64() - tb.as_secs_f64()).abs();
        assert!(diff < 0.02, "plain transfers diverged by {diff}s");
    }

    #[test]
    fn budget_free_functions_match_the_engine() {
        // the data-path pricing calls the free functions directly; they
        // must be the same arithmetic the engine throttles against
        let mut e = engine_unthrottled();
        e.bounce_bytes = 1000;
        e.pipeline_depth = 3;
        e.cc_crypto_frac = 0.4;
        assert_eq!(e.cc_budget(12_345),
                   cc_budget_s(12_345, e.bw_cc, 1000, 3, 0.4));
        e.pipeline_depth = 0;
        assert_eq!(e.cc_budget(12_345),
                   cc_budget_s(12_345, e.bw_cc, 1000, 0, 0.4));
        // zero-length payloads price to zero in both modes
        assert_eq!(cc_budget_s(0, e.bw_cc, 1000, 2, 0.5), (0.0, 0.0, 0.0));
        assert_eq!(plain_budget_s(0, 1e9), 0.0);
    }

    #[test]
    fn size_mismatch_rejected() {
        let mut e = engine_unthrottled();
        let mut dst = vec![0u8; 10];
        assert!(e.transfer(Dir::HostToDevice, &[1, 2, 3], &mut dst, None)
                .is_err());
    }
}
