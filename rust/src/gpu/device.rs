//! `SimGpu` — the device model the coordinator talks to.
//!
//! Owns the HBM allocator, the backing byte store, the DMA engine and
//! (in CC mode) the established confidential session, and accounts
//! compute-busy time for the Fig 7 GPU-utilization metric.
//!
//! Scaling: we model an "H100 80 GB" shrunk ~3000× so that our MB-scale
//! models exercise the same *relative* memory pressure the paper's
//! GB-scale models did — granite-sim OOMs at batch 32 just as the real
//! experiments hit OOM while growing batches (§III-D2).

use std::time::{Duration, Instant};

use crate::gpu::cc::CcSession;
use crate::gpu::dma::{Dir, DmaEngine, DmaStats, TransferReport};
use crate::gpu::hbm::{HbmAllocator, HbmBuffer, HbmOom};
use crate::gpu::CcMode;

/// Device configuration (defaults calibrated in DESIGN.md §Substitutions).
#[derive(Debug, Clone)]
pub struct GpuConfig {
    pub mode: CcMode,
    /// Simulated HBM capacity, bytes.
    pub hbm_capacity: u64,
    /// Plain-mode PCIe bandwidth, bytes/s.
    pub bw_plain: f64,
    /// CC-mode effective bandwidth, bytes/s.
    pub bw_cc: f64,
    /// Bounce-buffer chunk, bytes.
    pub bounce_bytes: usize,
    /// CC chunk-pipeline staging buffers (`gpu::dma`): `< 2` serializes
    /// seal/open and link per chunk; `>= 2` overlaps sealing chunk k+1
    /// with the link time of chunk k.
    pub pipeline_depth: usize,
    /// Fraction of the serialized CC per-byte budget that is crypto
    /// (the rest is link time); serialized totals are insensitive to it.
    pub cc_crypto_frac: f64,
    /// Unified/coherent memory (GH200-class, `gpu::profile`): model
    /// and payload bytes are never bounce-sealed, so CC swap loads
    /// price at the plain figure plus `bridge_residual_s` and the CC
    /// data path prices like No-CC.
    pub uma: bool,
    /// Per-swap bridge/attestation-side constant, seconds, added to
    /// every CC demand load and prefetch — the residual CC cost that
    /// survives GPU-local isolation ("The Serialized Bridge").
    pub bridge_residual_s: f64,
    /// Scale on the CC *excess* of a swap load over the plain figure
    /// (1.0 = the full Hopper-style bounce tax, 0.25 =
    /// Blackwell-class GPU-local crypto); load-crypto totals scale
    /// with it.
    pub cc_excess_scale: f64,
    /// Device-side free latency (paper: unloads 4–10 ms in both modes).
    pub unload_latency: Duration,
    /// One-time attestation handshake latency (CC only).
    pub attest_latency: Duration,
    /// Host secret for the simulated SPDM exchange.
    pub host_secret: u64,
    /// Disable throttle sleeps (tests/benches only).
    pub no_throttle: bool,
}

impl Default for GpuConfig {
    fn default() -> Self {
        GpuConfig {
            mode: CcMode::Off,
            hbm_capacity: 24 * 1024 * 1024,
            // PCIe model calibrated so CC loads sit at ~12-25% of the
            // scaled SLA ladder (matching the paper's regime) and the
            // CC/No-CC load ratio is ~2.7x (encrypted-transfer slowdown
            // reported for H100 CC mode)
            bw_plain: 6.0e6,
            bw_cc: 2.2e6,
            bounce_bytes: 256 * 1024,
            pipeline_depth: 0,
            cc_crypto_frac: 0.5,
            uma: false,
            bridge_residual_s: 0.0,
            cc_excess_scale: 1.0,
            unload_latency: Duration::from_millis(6),
            attest_latency: Duration::from_millis(50),
            host_secret: 0x51CE5E,
            no_throttle: false,
        }
    }
}

impl GpuConfig {
    /// Effective CC seconds-per-byte under the configured pipeline
    /// setting: the full serialized budget (`1/bw_cc`) when the
    /// pipeline is off, the steady-state `max(crypto, link)` share of
    /// it when on.  Load-time *estimates* (strategy headroom terms) use
    /// this; the DMA engine itself runs the exact chunk recurrence.
    pub fn cc_seconds_per_byte(&self) -> f64 {
        if self.uma {
            // coherent memory: the swap moves at the plain link rate
            // (the bridge residual is per-swap, not per-byte)
            return 1.0 / self.bw_plain;
        }
        let per_byte = 1.0 / self.bw_cc;
        if self.pipeline_depth >= 2 {
            let frac = self.cc_crypto_frac.clamp(0.0, 1.0);
            per_byte * frac.max(1.0 - frac)
        } else {
            per_byte
        }
    }
}

/// The simulated confidential GPU.
pub struct SimGpu {
    cfg: GpuConfig,
    hbm: HbmAllocator,
    store: Vec<u8>,
    dma: DmaEngine,
    cc: Option<CcSession>,
    created: Instant,
    compute_busy: Duration,
    compute_calls: u64,
}

impl SimGpu {
    /// Bring up the device; in CC mode this runs the attestation
    /// handshake (and pays its latency) before any DMA is allowed.
    pub fn new(cfg: GpuConfig) -> anyhow::Result<SimGpu> {
        let cc = match cfg.mode {
            CcMode::Off => None,
            CcMode::On => {
                if !cfg.no_throttle {
                    std::thread::sleep(cfg.attest_latency);
                }
                Some(CcSession::establish(cfg.host_secret)?)
            }
        };
        let mut dma = DmaEngine::new(cfg.bw_plain, cfg.bw_cc,
                                     cfg.bounce_bytes);
        dma.no_throttle = cfg.no_throttle;
        dma.pipeline_depth = cfg.pipeline_depth;
        dma.cc_crypto_frac = cfg.cc_crypto_frac;
        Ok(SimGpu {
            hbm: HbmAllocator::new(cfg.hbm_capacity),
            store: vec![0u8; cfg.hbm_capacity as usize],
            dma,
            cc,
            cfg,
            created: Instant::now(),
            compute_busy: Duration::ZERO,
            compute_calls: 0,
        })
    }

    pub fn mode(&self) -> CcMode {
        self.cfg.mode
    }

    pub fn config(&self) -> &GpuConfig {
        &self.cfg
    }

    // ------------------------------------------------------------ memory

    /// Allocate device memory without touching the DMA path (KV cache /
    /// activation workspace).
    pub fn alloc(&mut self, len: u64) -> Result<HbmBuffer, HbmOom> {
        self.hbm.alloc(len)
    }

    /// Free device memory (no latency — covers transient workspaces).
    pub fn free(&mut self, buf: HbmBuffer) {
        self.hbm.free(buf)
    }

    /// Upload host bytes into a fresh device buffer (model load path:
    /// alloc + DMA through the CC bounce buffers when in CC mode).
    pub fn upload(&mut self, bytes: &[u8])
                  -> anyhow::Result<(HbmBuffer, TransferReport)> {
        let buf = self.hbm.alloc(bytes.len() as u64)?;
        let dst = &mut self.store[buf.offset as usize
                                  ..(buf.offset + buf.len) as usize];
        let rep = self.dma.transfer(Dir::HostToDevice, bytes, dst,
                                    self.cc.as_ref())?;
        Ok((buf, rep))
    }

    /// Free a model buffer, paying the device-side unload latency
    /// (paper §III-D1: 4–10 ms, mode-independent).
    pub fn unload(&mut self, buf: HbmBuffer) -> Duration {
        let start = Instant::now();
        if !self.cfg.no_throttle {
            std::thread::sleep(self.cfg.unload_latency);
        }
        self.hbm.free(buf);
        start.elapsed()
    }

    /// Read device memory back (tests / verification).
    pub fn download(&mut self, buf: HbmBuffer) -> anyhow::Result<Vec<u8>> {
        let src = self.store[buf.offset as usize
                             ..(buf.offset + buf.len) as usize].to_vec();
        let mut out = vec![0u8; src.len()];
        self.dma.transfer(Dir::DeviceToHost, &src, &mut out,
                          self.cc.as_ref())?;
        Ok(out)
    }

    /// Verify uploaded content matches (plaintext at rest in HBM).
    pub fn peek(&self, buf: HbmBuffer) -> &[u8] {
        &self.store[buf.offset as usize..(buf.offset + buf.len) as usize]
    }

    // -------------------------------------------------------------- I/O

    /// Move a request/response payload across the link (CC seals it).
    /// Returns the transfer report; payloads are transient (no alloc).
    pub fn io_transfer(&mut self, dir: Dir, bytes: &[u8])
                       -> anyhow::Result<TransferReport> {
        let mut scratch = vec![0u8; bytes.len()];
        self.dma.transfer(dir, bytes, &mut scratch, self.cc.as_ref())
    }

    // ----------------------------------------------------------- compute

    /// Account a compute interval (the PJRT execute wall time).
    pub fn record_compute(&mut self, d: Duration) {
        self.compute_busy += d;
        self.compute_calls += 1;
    }

    pub fn compute_busy(&self) -> Duration {
        self.compute_busy
    }

    pub fn compute_calls(&self) -> u64 {
        self.compute_calls
    }

    /// Fraction of device lifetime spent computing — Fig 7's metric.
    pub fn utilization(&self) -> f64 {
        let total = self.created.elapsed().as_secs_f64();
        if total <= 0.0 {
            0.0
        } else {
            (self.compute_busy.as_secs_f64() / total).min(1.0)
        }
    }

    // ------------------------------------------------------------- stats

    pub fn dma_stats(&self) -> &DmaStats {
        self.dma.stats()
    }

    pub fn mem_in_use(&self) -> u64 {
        self.hbm.in_use()
    }

    pub fn mem_peak(&self) -> u64 {
        self.hbm.peak()
    }

    pub fn mem_capacity(&self) -> u64 {
        self.hbm.capacity()
    }

    pub fn mem_largest_free(&self) -> u64 {
        self.hbm.largest_free()
    }

    /// Largest free extent if `buf` were returned first (prefetch
    /// restaging decisions; see `HbmAllocator::largest_free_after`).
    pub fn mem_largest_free_after(&self, buf: HbmBuffer) -> u64 {
        self.hbm.largest_free_after(buf)
    }

    pub fn mem_fragmentation(&self) -> f64 {
        self.hbm.fragmentation()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(mode: CcMode) -> GpuConfig {
        GpuConfig { mode, no_throttle: true, ..GpuConfig::default() }
    }

    #[test]
    fn upload_lands_plaintext_in_both_modes() {
        for mode in [CcMode::Off, CcMode::On] {
            let mut gpu = SimGpu::new(cfg(mode)).unwrap();
            let data: Vec<u8> = (0..300_000).map(|i| (i % 249) as u8)
                .collect();
            let (buf, rep) = gpu.upload(&data).unwrap();
            assert_eq!(gpu.peek(buf), &data[..], "{mode:?}");
            assert_eq!(rep.bytes, data.len() as u64);
            if mode == CcMode::On {
                assert!(rep.crypto_total > Duration::ZERO);
                assert_eq!(rep.crypto_total, rep.crypto_exposed,
                           "serialized CC exposes all crypto");
            } else {
                assert_eq!(rep.crypto_total, Duration::ZERO);
            }
            let roundtrip = gpu.download(buf).unwrap();
            assert_eq!(roundtrip, data);
        }
    }

    #[test]
    fn oom_when_capacity_exceeded() {
        let mut c = cfg(CcMode::Off);
        c.hbm_capacity = 1024 * 1024;
        let mut gpu = SimGpu::new(c).unwrap();
        let data = vec![1u8; 600_000];
        let (_a, _) = gpu.upload(&data).unwrap();
        assert!(gpu.upload(&data).is_err(), "second upload must OOM");
    }

    #[test]
    fn unload_frees_memory() {
        let mut gpu = SimGpu::new(cfg(CcMode::Off)).unwrap();
        let (buf, _) = gpu.upload(&vec![2u8; 100_000]).unwrap();
        assert_eq!(gpu.mem_in_use(), 100_000);
        gpu.unload(buf);
        assert_eq!(gpu.mem_in_use(), 0);
        assert_eq!(gpu.mem_peak(), 100_000);
    }

    #[test]
    fn utilization_tracks_recorded_compute() {
        let mut gpu = SimGpu::new(cfg(CcMode::Off)).unwrap();
        assert_eq!(gpu.utilization(), 0.0);
        std::thread::sleep(Duration::from_millis(20));
        gpu.record_compute(Duration::from_millis(10));
        let u = gpu.utilization();
        assert!(u > 0.0 && u < 1.0, "utilization {u}");
        assert_eq!(gpu.compute_calls(), 1);
    }

    #[test]
    fn io_transfer_counts_in_dma_stats() {
        let mut gpu = SimGpu::new(cfg(CcMode::On)).unwrap();
        gpu.io_transfer(Dir::HostToDevice, &[0u8; 4096]).unwrap();
        gpu.io_transfer(Dir::DeviceToHost, &[0u8; 2048]).unwrap();
        let s = gpu.dma_stats();
        assert_eq!(s.h2d_bytes, 4096);
        assert_eq!(s.d2h_bytes, 2048);
        assert!(s.crypto_total > Duration::ZERO);
    }

    #[test]
    fn cc_seconds_per_byte_tracks_pipeline() {
        let mut c = cfg(CcMode::On);
        c.bw_cc = 2.0e6;
        let serial = c.cc_seconds_per_byte();
        assert!((serial - 0.5e-6).abs() < 1e-15);
        c.pipeline_depth = 2;
        c.cc_crypto_frac = 0.5;
        assert!((c.cc_seconds_per_byte() - 0.25e-6).abs() < 1e-15,
                "even split halves the steady-state cost");
        c.cc_crypto_frac = 0.75;
        assert!((c.cc_seconds_per_byte() - 0.375e-6).abs() < 1e-15,
                "crypto-heavy split is bounded by the crypto stage");
        c.uma = true;
        c.bw_plain = 4.0e6;
        assert!((c.cc_seconds_per_byte() - 0.25e-6).abs() < 1e-15,
                "coherent memory moves at the plain link rate");
    }
}
