//! Per-model arrival-rate estimation for SelectBatch (§III-C4: "an
//! estimate calculated from past request arrival frequency").
//!
//! EWMA over inter-arrival gaps: cheap, adapts within a few arrivals,
//! and degrades gracefully through idle phases by clamping the gap to
//! the elapsed silence when queried.
//!
//! State is a dense vector indexed by [`ModelId`] (grown on first
//! sight of an id), so the per-arrival hot path is an array index —
//! no hashing, no key clone.

use crate::runtime::ModelId;

/// EWMA inter-arrival estimator per model.
#[derive(Debug)]
pub struct RateEstimator {
    alpha: f64,
    state: Vec<Option<Ewma>>,
}

#[derive(Debug, Clone, Copy)]
struct Ewma {
    last_arrival_s: f64,
    mean_gap_s: f64,
    count: u64,
}

impl RateEstimator {
    pub fn new(alpha: f64) -> RateEstimator {
        assert!((0.0..=1.0).contains(&alpha));
        RateEstimator { alpha, state: Vec::new() }
    }

    /// Record one arrival at `now_s`.
    pub fn on_arrival(&mut self, model: ModelId, now_s: f64) {
        let i = model.index();
        if self.state.len() <= i {
            self.state.resize(i + 1, None);
        }
        match &mut self.state[i] {
            slot @ None => {
                *slot = Some(Ewma {
                    last_arrival_s: now_s,
                    mean_gap_s: 0.0,
                    count: 1,
                });
            }
            Some(e) => {
                let gap = (now_s - e.last_arrival_s).max(1e-6);
                e.mean_gap_s = if e.count == 1 {
                    gap
                } else {
                    self.alpha * gap + (1.0 - self.alpha) * e.mean_gap_s
                };
                e.last_arrival_s = now_s;
                e.count += 1;
            }
        }
    }

    /// Estimated arrival rate (req/s) for `model` as of `now_s`.
    /// Returns 0.0 until two arrivals have been seen.
    ///
    /// Pure EWMA over inter-arrival gaps ("an estimate calculated from
    /// past request arrival frequency", §III-C4).  Deliberately NOT
    /// decayed by current silence: during the post-generation drain (and
    /// bursty idle phases) the backlog must still be batched at the
    /// historical rate — a silence-decayed estimate collapses
    /// SelectBatch to batch-1 swap thrashing.
    pub fn rate_rps(&self, model: ModelId, _now_s: f64) -> f64 {
        let Some(Some(e)) = self.state.get(model.index()) else {
            return 0.0;
        };
        if e.count < 2 || e.mean_gap_s <= 0.0 {
            return 0.0;
        }
        1.0 / e.mean_gap_s
    }

    pub fn arrivals_seen(&self, model: ModelId) -> u64 {
        self.state.get(model.index())
            .and_then(|s| s.map(|e| e.count)).unwrap_or(0)
    }
}

impl Default for RateEstimator {
    fn default() -> Self {
        RateEstimator::new(0.3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const M: ModelId = ModelId(0);

    #[test]
    fn converges_to_steady_rate() {
        let mut est = RateEstimator::new(0.3);
        // 4 rps steady arrivals
        for i in 0..100 {
            est.on_arrival(M, i as f64 * 0.25);
        }
        let r = est.rate_rps(M, 25.0);
        assert!((r - 4.0).abs() < 0.4, "rate {r}");
    }

    #[test]
    fn needs_two_arrivals() {
        let mut est = RateEstimator::new(0.3);
        assert_eq!(est.rate_rps(M, 0.0), 0.0);
        est.on_arrival(M, 0.0);
        assert_eq!(est.rate_rps(M, 1.0), 0.0);
        est.on_arrival(M, 0.5);
        assert!(est.rate_rps(M, 0.6) > 0.0);
    }

    #[test]
    fn rate_stable_through_silence() {
        // drain-phase semantics: the historical rate must survive
        // arbitrary silence so backlog batching stays at size
        let mut est = RateEstimator::new(0.3);
        for i in 0..50 {
            est.on_arrival(M, i as f64 * 0.1); // 10 rps
        }
        let fresh = est.rate_rps(M, 5.0);
        let stale = est.rate_rps(M, 60.0); // 55s of silence
        assert!((fresh - stale).abs() < 1e-9,
                "fresh {fresh} != stale {stale}");
        assert!((fresh - 10.0).abs() < 1.0);
    }

    #[test]
    fn models_tracked_independently() {
        let fast = ModelId(0);
        let slow = ModelId(1);
        let mut est = RateEstimator::new(0.3);
        for i in 0..40 {
            est.on_arrival(fast, i as f64 * 0.1);
            est.on_arrival(slow, i as f64 * 1.0);
        }
        let f = est.rate_rps(fast, 4.0);
        let s = est.rate_rps(slow, 40.0);
        assert!(f > 5.0 * s, "fast {f} slow {s}");
    }

    #[test]
    fn sparse_ids_grow_on_demand() {
        let mut est = RateEstimator::new(0.3);
        let late = ModelId(7);
        assert_eq!(est.arrivals_seen(late), 0);
        est.on_arrival(late, 1.0);
        est.on_arrival(late, 1.5);
        assert_eq!(est.arrivals_seen(late), 2);
        assert_eq!(est.arrivals_seen(ModelId(3)), 0,
                   "untouched ids in the grown range stay empty");
    }
}
