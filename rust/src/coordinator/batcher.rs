//! Batch assembly with a device-memory guard.
//!
//! Takes the strategy's `Process { model, take }` decision and turns it
//! into an executable batch: pops requests, reserves the KV/activation
//! workspace on the device, and — if the workspace doesn't fit — halves
//! the batch and requeues the tail at the *front* of the queue,
//! preserving FIFO order (the paper grows batches "until the GPU runs
//! out of memory"; serving must therefore survive the OOM edge).

use crate::coordinator::queues::ModelQueues;
use crate::coordinator::request::Request;
use crate::gpu::device::SimGpu;
use crate::gpu::hbm::HbmBuffer;
use crate::runtime::{ModelId, Registry};

/// A ready-to-execute batch with its reserved workspace.
pub struct PreparedBatch {
    pub model: ModelId,
    pub requests: Vec<Request>,
    pub workspace: HbmBuffer,
    /// Artifact batch size that will be used (>= requests.len()).
    pub artifact_batch: usize,
}

/// Pop up to `take` requests for `model` and reserve device workspace,
/// shrinking on OOM.  Returns None if the queue was empty or even a
/// single-row workspace cannot fit.
pub fn prepare(queues: &mut ModelQueues, gpu: &mut SimGpu,
               registry: &Registry, model: ModelId, take: usize)
               -> anyhow::Result<Option<PreparedBatch>> {
    let table = queues.table().clone();
    let name = table.name(model);
    let entry = registry.entry(name)?;
    let mut reqs = queues.pop_n(model, take.max(1));
    if reqs.is_empty() {
        return Ok(None);
    }

    loop {
        let artifact_batch = entry.spec.batch_size_at_least(reqs.len());
        let ws_bytes = entry.spec.batch_workspace_bytes(artifact_batch);
        match gpu.alloc(ws_bytes) {
            Ok(workspace) => {
                return Ok(Some(PreparedBatch {
                    model,
                    requests: reqs,
                    workspace,
                    artifact_batch,
                }));
            }
            Err(_) if reqs.len() > 1 => {
                // halve and requeue the tail in order
                let keep = reqs.len() / 2;
                let tail = reqs.split_off(keep);
                queues.push_front(model, tail);
            }
            Err(e) => {
                // cannot even fit one row: requeue and report
                queues.push_front(model, reqs);
                anyhow::bail!("workspace OOM for {name} even at batch 1: \
                               {e}");
            }
        }
    }
}

/// Release a batch's workspace after execution.
pub fn release(gpu: &mut SimGpu, batch: PreparedBatch) -> Vec<Request> {
    gpu.free(batch.workspace);
    batch.requests
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::device::GpuConfig;
    use crate::runtime::manifest::Manifest;
    use crate::runtime::ModelTable;
    use std::path::PathBuf;

    // sole entry of the single-model test table
    const LLAMA: ModelId = ModelId(0);

    fn queues() -> ModelQueues {
        ModelQueues::new(ModelTable::shared(["llama-sim"]))
    }

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn registry() -> Registry {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        Registry::load(&m, &["llama-sim".to_string()], &[1, 2, 4, 8])
            .unwrap()
    }

    fn req(id: u64) -> Request {
        Request { id, model: LLAMA, tokens: vec![0; 16],
                  arrival_s: id as f64, class: 0 }
    }

    fn gpu(capacity: u64) -> SimGpu {
        SimGpu::new(GpuConfig {
            hbm_capacity: capacity, no_throttle: true, ..Default::default()
        }).unwrap()
    }

    #[test]
    fn prepares_full_batch() {
        let reg = registry();
        let mut gpu = gpu(24 * 1024 * 1024);
        let mut q = queues();
        for i in 0..5 {
            q.push(req(i));
        }
        let b = prepare(&mut q, &mut gpu, &reg, LLAMA, 4)
            .unwrap().unwrap();
        assert_eq!(b.requests.len(), 4);
        assert_eq!(b.artifact_batch, 4);
        assert_eq!(q.len(LLAMA), 1);
        assert!(gpu.mem_in_use() > 0);
        let back = release(&mut gpu, b);
        assert_eq!(back.len(), 4);
        assert_eq!(gpu.mem_in_use(), 0);
    }

    #[test]
    fn empty_queue_returns_none() {
        let reg = registry();
        let mut gpu = gpu(24 * 1024 * 1024);
        let mut q = queues();
        assert!(prepare(&mut q, &mut gpu, &reg, LLAMA, 4)
                .unwrap().is_none());
    }

    #[test]
    fn oom_halves_batch_and_preserves_order() {
        let reg = registry();
        let spec = &reg.entry("llama-sim").unwrap().spec;
        // capacity fits a 2-row workspace but not 8
        let cap = spec.batch_workspace_bytes(2) + 1024;
        let mut gpu = gpu(cap);
        let mut q = queues();
        for i in 0..8 {
            q.push(req(i));
        }
        let b = prepare(&mut q, &mut gpu, &reg, LLAMA, 8)
            .unwrap().unwrap();
        assert!(b.requests.len() <= 2, "shrunk to {}", b.requests.len());
        assert_eq!(b.requests[0].id, 0, "head preserved");
        // the requeued tail must still be in order behind the batch
        let rest: Vec<u64> = q.pop_n(LLAMA, 10).iter()
            .map(|r| r.id).collect();
        let expect: Vec<u64> = (b.requests.len() as u64..8).collect();
        assert_eq!(rest, expect);
    }

    #[test]
    fn oom_at_one_row_errors_and_requeues() {
        let reg = registry();
        let mut gpu = gpu(1024); // nothing fits
        let mut q = queues();
        q.push(req(0));
        assert!(prepare(&mut q, &mut gpu, &reg, LLAMA, 1).is_err());
        assert_eq!(q.len(LLAMA), 1, "request must be requeued");
    }
}
