//! Fleet placement policies: *where* a decided batch runs.
//!
//! Table I strategies pick *what* to run (model + batch size); on an
//! N-device fleet someone must pick *which device*.  Placement is the
//! fleet-level analogue of the strategies' swap-avoidance preference,
//! and it is where the paper's CC load-time penalty becomes a routing
//! trade-off: a swap onto a CC device costs ~2.7× the plain load, so
//! keeping models sticky (affinity) or steering SLA-tight work to
//! No-CC devices (cc-aware) changes throughput and attainment, not
//! just placement bookkeeping.
//!
//! Policies are pure functions over the same [`SchedContext`] snapshot
//! the strategies see, choosing among the *free* devices only (the
//! engine never dispatches to a busy device).  On a one-device fleet
//! every policy degenerates to "device 0", which is what keeps
//! `devices=1` runs bit-identical to the paper's single-GPU engine.
//!
//! The policy table ([`PLACEMENTS`]) is the single source of truth for
//! lookup, `--help`, and the unknown-name error message.

use std::cell::Cell;

use crate::coordinator::strategy::{ModelView, SchedContext};
use crate::gpu::CcMode;
use crate::runtime::ModelId;

/// A fleet placement policy.
pub trait Placement: Send {
    fn name(&self) -> &'static str;

    /// Pick a device for the batch the strategy decided: `view` is the
    /// chosen model's queue view, `free` the ids of free devices
    /// (non-empty, ascending).
    fn place(&self, ctx: &SchedContext, view: &ModelView, free: &[usize])
             -> usize;
}

/// One placement policy: CLI name, help blurb, constructor.
pub struct PlacementEntry {
    pub name: &'static str,
    pub blurb: &'static str,
    pub make: fn() -> Box<dyn Placement>,
}

fn make_affinity() -> Box<dyn Placement> {
    Box::new(Affinity)
}

fn make_round_robin() -> Box<dyn Placement> {
    Box::new(RoundRobin::default())
}

fn make_least_loaded() -> Box<dyn Placement> {
    Box::new(LeastLoaded)
}

fn make_cc_aware() -> Box<dyn Placement> {
    Box::new(CcAware)
}

fn make_pipeline_parallel() -> Box<dyn Placement> {
    Box::new(PipelineParallel)
}

/// The policy table — drives `placement_by_name`, `--help`, and the
/// unknown-name error, so the three cannot drift.
pub const PLACEMENTS: &[PlacementEntry] = &[
    PlacementEntry {
        name: "affinity",
        blurb: "route to the device where the model is resident \
                (fewest swaps)",
        make: make_affinity,
    },
    PlacementEntry {
        name: "round-robin",
        blurb: "cycle through devices regardless of residency",
        make: make_round_robin,
    },
    PlacementEntry {
        name: "least-loaded",
        blurb: "device with the least cumulative busy time",
        make: make_least_loaded,
    },
    PlacementEntry {
        name: "cc-aware",
        blurb: "prefer No-CC devices when the head request's SLA \
                headroom is tight",
        make: make_cc_aware,
    },
    PlacementEntry {
        name: "pipeline-parallel",
        blurb: "route to stage-group leads; the model's layer shards \
                stage atomically across the lead's group \
                (--pp-stages)",
        make: make_pipeline_parallel,
    },
];

/// Valid placement names, in table order.
pub fn placement_names() -> Vec<&'static str> {
    PLACEMENTS.iter().map(|e| e.name).collect()
}

/// Instantiate a placement policy by CLI name.
pub fn placement_by_name(name: &str) -> anyhow::Result<Box<dyn Placement>> {
    PLACEMENTS.iter().find(|e| e.name == name).map(|e| (e.make)())
        .ok_or_else(|| anyhow::anyhow!(
            "unknown placement {name:?} (have {:?})", placement_names()))
}

// ---------------------------------------------------------------- helpers

/// Free device with the least cumulative busy time (ties: lowest id).
fn least_loaded_of(ctx: &SchedContext, free: &[usize]) -> usize {
    *free.iter()
        .min_by(|&&a, &&b| {
            (ctx.devices[a].busy_s, a)
                .partial_cmp(&(ctx.devices[b].busy_s, b)).unwrap()
        })
        .expect("placement called with no free device")
}

/// Affinity step: resident free device if any, else least-loaded.
fn sticky_or_least_loaded(ctx: &SchedContext, model: ModelId,
                          free: &[usize]) -> usize {
    ctx.resident_on_free(model)
        .filter(|d| free.contains(d))
        .unwrap_or_else(|| least_loaded_of(ctx, free))
}

// ------------------------------------------------------------- policies

/// Route to the device where the model is already resident, avoiding
/// the (CC-expensive) swap; first placement of a model lands on the
/// least-loaded device, which naturally spreads models over the fleet.
pub struct Affinity;

impl Placement for Affinity {
    fn name(&self) -> &'static str {
        "affinity"
    }

    fn place(&self, ctx: &SchedContext, view: &ModelView, free: &[usize])
             -> usize {
        sticky_or_least_loaded(ctx, view.model, free)
    }
}

/// Classic round-robin over device ids, skipping busy devices; the
/// residency-blind baseline the affinity policy is measured against.
#[derive(Default)]
pub struct RoundRobin {
    cursor: Cell<usize>,
}

impl Placement for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn place(&self, ctx: &SchedContext, _view: &ModelView, free: &[usize])
             -> usize {
        let n = ctx.devices.len().max(1);
        let start = self.cursor.get();
        for i in 0..n {
            let d = (start + i) % n;
            if free.contains(&d) {
                self.cursor.set((d + 1) % n);
                return d;
            }
        }
        // `free` is non-empty and every id is < n, so the scan above
        // always returns
        unreachable!("place called with no free device")
    }
}

/// Always the free device with the least cumulative busy time —
/// utilization-balancing, residency-blind.
pub struct LeastLoaded;

impl Placement for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn place(&self, ctx: &SchedContext, _view: &ModelView, free: &[usize])
             -> usize {
        least_loaded_of(ctx, free)
    }
}

/// CC-aware routing: when the head request's SLA headroom is tight —
/// the wait already consumed, plus the estimated load + exec, would
/// pass half the SLA — prefer free No-CC devices (their loads are
/// ~2.7× cheaper); with comfortable headroom behave like affinity, so
/// the fleet still avoids needless swaps.
pub struct CcAware;

impl CcAware {
    fn tight(view: &ModelView, sla_s: f64) -> bool {
        view.oldest_wait_s + view.est_load_s + view.est_exec_s
            > 0.5 * sla_s
    }
}

impl Placement for CcAware {
    fn name(&self) -> &'static str {
        "cc-aware"
    }

    fn place(&self, ctx: &SchedContext, view: &ModelView, free: &[usize])
             -> usize {
        if Self::tight(view, ctx.sla_s) {
            let nocc: Vec<usize> = free.iter().copied()
                .filter(|&d| ctx.devices[d].mode == CcMode::Off)
                .collect();
            if !nocc.is_empty() {
                return sticky_or_least_loaded(ctx, view.model, &nocc);
            }
        }
        sticky_or_least_loaded(ctx, view.model, free)
    }
}

/// Pipeline-parallel routing: the engine pre-filters `free` to stage
/// *leads* whose whole group is idle (`StageTopology::leads`), so the
/// policy itself is the affinity step over that reduced set — sticky
/// to the lead whose group already holds the model's shards, else the
/// least-loaded lead.  With `--pp-stages 1` every device is its own
/// lead and this is exactly `affinity`, which is what keeps stage-1
/// runs byte-identical to pp-free ones.
pub struct PipelineParallel;

impl Placement for PipelineParallel {
    fn name(&self) -> &'static str {
        "pipeline-parallel"
    }

    fn place(&self, ctx: &SchedContext, view: &ModelView, free: &[usize])
             -> usize {
        sticky_or_least_loaded(ctx, view.model, free)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::strategy::DeviceView;

    // Sorted-table ids for a two-model test fleet ("a" < "b").
    const A: ModelId = ModelId(0);
    const B: ModelId = ModelId(1);

    fn device(id: usize, mode: CcMode, resident: Option<ModelId>,
              busy_s: f64) -> DeviceView {
        DeviceView {
            id,
            mode,
            resident,
            busy: false,
            busy_s,
            dispatched: 0,
        }
    }

    fn view(model: ModelId, wait: f64) -> ModelView {
        ModelView {
            model,
            len: 4,
            oldest_wait_s: wait,
            obs: 8,
            rate_rps: 2.0,
            est_load_s: 0.5,
            est_exec_s: 0.5,
        }
    }

    fn ctx(devices: Vec<DeviceView>) -> SchedContext {
        SchedContext {
            now_s: 10.0,
            devices,
            queues: vec![view(A, 0.1)],
            sla_s: 6.0,
            timeout_s: 3.0,
        }
    }

    #[test]
    fn affinity_routes_to_resident_device() {
        let c = ctx(vec![device(0, CcMode::Off, None, 5.0),
                         device(1, CcMode::Off, Some(A), 9.0)]);
        let p = Affinity;
        assert_eq!(p.place(&c, &view(A, 0.1), &[0, 1]), 1,
                   "resident device wins even when busier");
        assert_eq!(p.place(&c, &view(B, 0.1), &[0, 1]), 0,
                   "unplaced model goes least-loaded");
    }

    #[test]
    fn affinity_ignores_resident_outside_free_set() {
        let c = ctx(vec![device(0, CcMode::Off, None, 5.0),
                         device(1, CcMode::Off, Some(A), 9.0)]);
        assert_eq!(Affinity.place(&c, &view(A, 0.1), &[0]), 0);
    }

    #[test]
    fn round_robin_cycles_free_devices() {
        let c = ctx(vec![device(0, CcMode::Off, None, 0.0),
                         device(1, CcMode::Off, None, 0.0),
                         device(2, CcMode::Off, None, 0.0)]);
        let p = RoundRobin::default();
        let v = view(A, 0.1);
        assert_eq!(p.place(&c, &v, &[0, 1, 2]), 0);
        assert_eq!(p.place(&c, &v, &[0, 1, 2]), 1);
        assert_eq!(p.place(&c, &v, &[0, 1, 2]), 2);
        assert_eq!(p.place(&c, &v, &[0, 1, 2]), 0);
        // busy device 1 is skipped without stalling the cycle
        assert_eq!(p.place(&c, &v, &[0, 2]), 2,
                   "cursor at 1, but 1 is not free");
    }

    #[test]
    fn least_loaded_balances_busy_seconds() {
        let c = ctx(vec![device(0, CcMode::Off, None, 7.0),
                         device(1, CcMode::Off, None, 2.0),
                         device(2, CcMode::Off, None, 2.0)]);
        assert_eq!(LeastLoaded.place(&c, &view(A, 0.1), &[0, 1, 2]), 1,
                   "ties break to the lowest id");
    }

    #[test]
    fn cc_aware_steers_tight_requests_to_nocc() {
        let c = ctx(vec![device(0, CcMode::On, Some(A), 0.0),
                         device(1, CcMode::Off, None, 5.0)]);
        let p = CcAware;
        // comfortable headroom: affinity keeps "a" on the CC device
        assert_eq!(p.place(&c, &view(A, 0.1), &[0, 1]), 0);
        // tight headroom (wait 2.5 + load 0.5 + exec 0.5 > 3.0):
        // prefer the No-CC device even though it forces a swap
        assert_eq!(p.place(&c, &view(A, 2.5), &[0, 1]), 1);
    }

    #[test]
    fn cc_aware_falls_back_when_no_nocc_is_free() {
        let c = ctx(vec![device(0, CcMode::On, None, 1.0),
                         device(1, CcMode::On, None, 0.0)]);
        assert_eq!(CcAware.place(&c, &view(A, 5.0), &[0, 1]), 1);
    }

    #[test]
    fn pipeline_parallel_is_sticky_to_the_group_lead() {
        // 2-stage x 4-device fleet: the engine passes only leads 0
        // and 2 in `free`, and residency mirrors across each group
        let c = ctx(vec![device(0, CcMode::On, None, 5.0),
                         device(1, CcMode::On, None, 5.0),
                         device(2, CcMode::On, Some(A), 9.0),
                         device(3, CcMode::On, Some(A), 9.0)]);
        let p = PipelineParallel;
        assert_eq!(p.place(&c, &view(A, 0.1), &[0, 2]), 2,
                   "sticky to the lead whose group holds the shards");
        assert_eq!(p.place(&c, &view(B, 0.1), &[0, 2]), 0,
                   "unsharded model goes to the least-loaded lead");
    }

    #[test]
    fn single_device_fleet_always_places_on_device_zero() {
        // the devices=1 parity guarantee: every policy is a constant
        let c = ctx(vec![device(0, CcMode::Off, Some(A), 3.0)]);
        for entry in PLACEMENTS {
            let p = (entry.make)();
            assert_eq!(p.place(&c, &view(B, 4.0), &[0]), 0,
                       "{}", entry.name);
        }
    }

    #[test]
    fn placement_names_roundtrip() {
        for name in placement_names() {
            assert_eq!(placement_by_name(name).unwrap().name(), name);
        }
        let err = placement_by_name("random").unwrap_err().to_string();
        for name in placement_names() {
            assert!(err.contains(name),
                    "error message must list {name:?}: {err}");
        }
    }
}
