//! SLA accounting (§III-C3): requests must complete within the SLA or
//! they count as unfulfilled.  Attainment = fraction of *all generated*
//! requests that completed within the limit — requests still queued at
//! the end of the run count against attainment, exactly as the paper's
//! completion rates do.

use crate::coordinator::request::CompletedRequest;

/// Tracks attainment for one run.
#[derive(Debug, Clone)]
pub struct SlaTracker {
    pub sla_s: f64,
    met: u64,
    missed_late: u64,
    missed_unserved: u64,
}

impl SlaTracker {
    pub fn new(sla_s: f64) -> SlaTracker {
        assert!(sla_s > 0.0, "SLA must be positive");
        SlaTracker { sla_s, met: 0, missed_late: 0, missed_unserved: 0 }
    }

    /// Record a served request; returns true if it met the SLA.
    pub fn on_complete(&mut self, c: &CompletedRequest) -> bool {
        let ok = c.latency_s() <= self.sla_s;
        if ok {
            self.met += 1;
        } else {
            self.missed_late += 1;
        }
        ok
    }

    /// Record requests never served by the end of the run.
    pub fn on_unserved(&mut self, n: u64) {
        self.missed_unserved += n;
    }

    pub fn met(&self) -> u64 {
        self.met
    }

    pub fn missed(&self) -> u64 {
        self.missed_late + self.missed_unserved
    }

    pub fn total(&self) -> u64 {
        self.met + self.missed()
    }

    /// Attainment in [0, 1] (the paper's completion rate).
    pub fn attainment(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            self.met as f64 / self.total() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelId;

    fn done(latency: f64) -> CompletedRequest {
        CompletedRequest {
            id: 0,
            model: ModelId(0),
            arrival_s: 0.0,
            exec_start_s: latency * 0.8,
            complete_s: latency,
            batch: 1,
            batch_rows: 1,
            caused_swap: false,
            device: 0,
        }
    }

    #[test]
    fn attainment_counts_all_classes() {
        let mut t = SlaTracker::new(4.0);
        assert!(t.on_complete(&done(3.0)));
        assert!(t.on_complete(&done(4.0))); // boundary: met
        assert!(!t.on_complete(&done(4.01)));
        t.on_unserved(2);
        assert_eq!(t.met(), 2);
        assert_eq!(t.missed(), 3);
        assert!((t.attainment() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn empty_run_is_zero() {
        assert_eq!(SlaTracker::new(1.0).attainment(), 0.0);
    }

    #[test]
    #[should_panic(expected = "SLA must be positive")]
    fn zero_sla_rejected() {
        SlaTracker::new(0.0);
    }
}
