//! HTTP front-end — the paper's Flask inference API, in Rust.
//!
//! A minimal HTTP/1.1 server (std only; no frameworks exist in the
//! offline crate set) exposing the serving system over the network:
//!
//! * `POST /infer`   `{"model": "...", "prompt": "..."}` → queued,
//!   batched by the configured strategy, executed, answered with the
//!   generated tokens and timing.  Requests whose SLA expires in the
//!   queue get `408 Request Timeout` (§III-C3 unfulfilled semantics).
//! * `GET /stats`    live counters (completed, expired, swaps, util).
//! * `GET /healthz`  liveness.
//!
//! Connection handlers are one thread each (relaxed inference tolerates
//! thread-per-request); the scheduler runs on the caller's thread over
//! the *same* [`RealBackend`] and view-builder the batch engine uses —
//! the only difference from an experiment run is that arrivals come
//! from sockets instead of a precomputed schedule, and completions are
//! answered over a reply channel instead of recorded.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::config::RunConfig;
use crate::coordinator::placement::placement_by_name;
use crate::coordinator::queues::ModelQueues;
use crate::coordinator::rate::RateEstimator;
use crate::coordinator::request::Request;
use crate::coordinator::strategy::{strategy_by_name, Decision,
                                   SchedContext};
use crate::engine::{build_device_views, build_views, resolve_device,
                    Clock, ExecBackend, RealBackend, WallClock};
use crate::runtime::{ModelId, Registry};
use crate::util::json::Json;
use crate::workload::tokenizer::tokenize;

/// Reply to one inference call.
#[derive(Debug, Clone)]
pub enum Reply {
    /// Served: generated tokens + timings.
    Done { tokens: Vec<i32>, latency_s: f64, batch: usize },
    /// SLA expired while queued.
    Expired,
}

struct Job {
    req: Request,
    reply: mpsc::Sender<Reply>,
}

/// Live server counters, exported at `GET /stats`.
#[derive(Debug, Default)]
pub struct ServerStats {
    pub received: AtomicU64,
    pub completed: AtomicU64,
    pub expired: AtomicU64,
    pub rejected: AtomicU64,
}

impl ServerStats {
    fn to_json(&self, swaps: u64, util: f64) -> Json {
        Json::obj(vec![
            ("received", Json::num(
                self.received.load(Ordering::Relaxed) as f64)),
            ("completed", Json::num(
                self.completed.load(Ordering::Relaxed) as f64)),
            ("expired", Json::num(
                self.expired.load(Ordering::Relaxed) as f64)),
            ("rejected", Json::num(
                self.rejected.load(Ordering::Relaxed) as f64)),
            ("swaps", Json::num(swaps as f64)),
            ("gpu_util", Json::num(util)),
        ])
    }
}

/// Run the HTTP front-end until `shutdown` is set (checked between
/// scheduler ticks).  Returns total served counts.
///
/// `addr` may use port 0; the bound address is reported through
/// `on_bound` before serving starts (tests use this to learn the port).
pub fn run_http(cfg: &RunConfig, registry: &Registry, addr: &str,
                shutdown: Arc<AtomicBool>,
                on_bound: impl FnOnce(std::net::SocketAddr))
                -> anyhow::Result<ServerStats> {
    cfg.validate()?;
    let strategy = strategy_by_name(&cfg.strategy)?;
    let placement = placement_by_name(&cfg.placement)?;
    let listener = TcpListener::bind(addr)
        .map_err(|e| anyhow::anyhow!("binding {addr}: {e}"))?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);

    let stats = Arc::new(ServerStats::default());
    let (tx, rx) = mpsc::channel::<Job>();
    // arrival stamps and scheduler decisions share one time origin
    let mut clock = WallClock::new();
    let start = clock.origin();
    // the backend owns the run's intern table; connection handlers
    // resolve each arriving model name to its id exactly once
    let mut backend = RealBackend::new(cfg, registry)?;
    let table = backend.table().clone();

    // ---------------- accept loop (thread) -----------------------------
    let acceptor = {
        let shutdown = shutdown.clone();
        let stats = stats.clone();
        let known: Vec<(String, ModelId, usize, u32)> =
            registry.names().iter().map(|n| {
                let s = &registry.entry(n).unwrap().spec;
                (n.clone(), table.require(n).unwrap(),
                 s.prompt_len, s.vocab as u32)
            }).collect();
        let next_id = AtomicU64::new(0);
        std::thread::spawn(move || {
            while !shutdown.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let tx = tx.clone();
                        let stats = stats.clone();
                        let known = known.clone();
                        let id = next_id.fetch_add(1, Ordering::Relaxed);
                        let t0 = start;
                        std::thread::spawn(move || {
                            let _ = handle_conn(stream, id, t0, &known,
                                                tx, &stats);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock =>
                    {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            // tx drops here, closing the scheduler's channel
        })
    };

    // ---------------- scheduler loop (this thread) ---------------------
    // Same backend as the experiment engine: residency, batching (OOM
    // guard included), CC-sealed I/O, PJRT execution — over the whole
    // (possibly mixed CC/No-CC) fleet.  Wall-clock execution is
    // serialized on this thread, so every device is free at each
    // decision point; placement still spreads residency and load.
    let n_dev = backend.n_devices();
    let free: Vec<usize> = (0..n_dev).collect();
    let idle_until = vec![0.0f64; n_dev];
    let mut dev_busy_s = vec![0.0f64; n_dev];
    let mut dispatched = vec![0u64; n_dev];
    let mut queues = ModelQueues::new(table.clone());
    let mut rates = RateEstimator::default();
    // id-indexed exec-EWMA; NaN = never executed
    let mut exec_est: Vec<f64> = vec![f64::NAN; table.len()];
    let mut batch_buf: Vec<Request> = Vec::new();
    let mut replies: HashMap<u64, mpsc::Sender<Reply>> = HashMap::new();

    loop {
        loop {
            match rx.try_recv() {
                Ok(job) => {
                    rates.on_arrival(job.req.model, job.req.arrival_s);
                    replies.insert(job.req.id, job.reply);
                    queues.push(job.req);
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => break,
            }
        }
        let t = clock.now_s();
        for r in queues.expire(t, cfg.sla_s) {
            stats.expired.fetch_add(1, Ordering::Relaxed);
            if let Some(tx) = replies.remove(&r.id) {
                let _ = tx.send(Reply::Expired);
            }
        }
        if shutdown.load(Ordering::Relaxed) && queues.is_empty() {
            break;
        }

        let views = build_views(&queues, &rates, &backend, &exec_est, t,
                                &free);
        let ctx = SchedContext {
            now_s: t,
            devices: build_device_views(&backend, &idle_until,
                                        &dev_busy_s, &dispatched, t),
            queues: views,
            sla_s: cfg.sla_s,
            timeout_s: cfg.timeout_s(),
        };

        match strategy.decide(&ctx) {
            Decision::Wait => std::thread::sleep(cfg.tick),
            Decision::Process { model, take, device } => {
                let dev = resolve_device(&ctx, placement.as_ref(),
                                         model, device, &free);
                let swap = backend.ensure_resident(&mut clock, dev,
                                                   model)?;
                batch_buf.clear();
                let Some(out) = backend.execute_batch(&mut clock,
                                                      &mut queues, dev,
                                                      model, take,
                                                      &mut batch_buf)?
                else {
                    continue;
                };
                let complete = clock.now_s();
                dev_busy_s[dev] += swap.unload_s + swap.load_s
                    + out.exec_s + out.io_s;
                dispatched[dev] += 1;
                let e = &mut exec_est[model.index()];
                let prev = if e.is_nan() { out.exec_s } else { *e };
                *e = 0.3 * out.exec_s + 0.7 * prev;
                for (r, toks) in batch_buf.drain(..)
                    .zip(out.tokens.into_iter())
                {
                    stats.completed.fetch_add(1, Ordering::Relaxed);
                    if let Some(tx) = replies.remove(&r.id) {
                        let _ = tx.send(Reply::Done {
                            tokens: toks,
                            latency_s: complete - r.arrival_s,
                            batch: out.artifact_batch,
                        });
                    }
                }
            }
        }
    }

    backend.teardown();
    acceptor.join().ok();
    Ok(Arc::try_unwrap(stats).unwrap_or_default())
}

// ---------------------------------------------------------- connection

fn handle_conn(mut stream: TcpStream, id: u64, start: Instant,
               known: &[(String, ModelId, usize, u32)],
               tx: mpsc::Sender<Job>,
               stats: &ServerStats) -> anyhow::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    let mut reader = BufReader::new(stream.try_clone()?);

    // request line + headers
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let mut content_len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        if h.trim().is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase()
            .strip_prefix("content-length:")
            .map(|v| v.trim().to_string())
        {
            content_len = v.parse().unwrap_or(0);
        }
    }

    match (method.as_str(), path.as_str()) {
        ("GET", "/healthz") => respond(&mut stream, 200, "{\"ok\":true}"),
        ("GET", "/stats") => {
            let body = stats.to_json(0, 0.0).to_string();
            respond(&mut stream, 200, &body)
        }
        ("POST", "/infer") => {
            let mut body = vec![0u8; content_len.min(1 << 20)];
            reader.read_exact(&mut body)?;
            stats.received.fetch_add(1, Ordering::Relaxed);
            let j = match Json::parse(std::str::from_utf8(&body)?) {
                Ok(j) => j,
                Err(e) => {
                    stats.rejected.fetch_add(1, Ordering::Relaxed);
                    return respond(&mut stream, 400,
                                   &err_json(&format!("bad JSON: {e}")));
                }
            };
            let model = j.get("model").and_then(|m| m.as_str())
                .unwrap_or_default().to_string();
            let prompt = j.get("prompt").and_then(|p| p.as_str())
                .unwrap_or_default();
            let Some((_, mid, prompt_len, vocab)) =
                known.iter().find(|(n, _, _, _)| *n == model)
            else {
                stats.rejected.fetch_add(1, Ordering::Relaxed);
                return respond(&mut stream, 400,
                               &err_json(&format!("unknown model \
                                                   {model:?}")));
            };
            let req = Request {
                id,
                model: *mid,
                tokens: tokenize(prompt, *prompt_len, *vocab),
                arrival_s: start.elapsed().as_secs_f64(),
                class: 0,
            };
            let (rtx, rrx) = mpsc::channel();
            if tx.send(Job { req, reply: rtx }).is_err() {
                return respond(&mut stream, 503,
                               &err_json("server shutting down"));
            }
            match rrx.recv_timeout(Duration::from_secs(120)) {
                Ok(Reply::Done { tokens, latency_s, batch }) => {
                    let body = Json::obj(vec![
                        ("model", Json::str(model)),
                        ("tokens", Json::Arr(tokens.iter()
                            .map(|&t| Json::num(t as f64)).collect())),
                        ("latency_s", Json::num(latency_s)),
                        ("batch", Json::num(batch as f64)),
                    ]).to_string();
                    respond(&mut stream, 200, &body)
                }
                Ok(Reply::Expired) => respond(
                    &mut stream, 408,
                    &err_json("SLA expired before dispatch")),
                Err(_) => respond(&mut stream, 504,
                                  &err_json("timed out")),
            }
        }
        _ => respond(&mut stream, 404, &err_json("not found")),
    }
}

fn err_json(msg: &str) -> String {
    Json::obj(vec![("error", Json::str(msg))]).to_string()
}

fn respond(stream: &mut TcpStream, code: u16, body: &str)
           -> anyhow::Result<()> {
    let reason = match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        408 => "Request Timeout",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "",
    };
    write!(stream,
           "HTTP/1.1 {code} {reason}\r\ncontent-type: application/json\
            \r\ncontent-length: {}\r\nconnection: close\r\n\r\n{body}",
           body.len())?;
    stream.flush()?;
    Ok(())
}

/// Minimal blocking HTTP client for tests and the load-generator
/// example: one request per connection.
pub fn http_call(addr: &std::net::SocketAddr, method: &str, path: &str,
                 body: Option<&str>) -> anyhow::Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(130)))?;
    let body = body.unwrap_or("");
    write!(stream,
           "{method} {path} HTTP/1.1\r\nhost: sincere\r\n\
            content-length: {}\r\nconnection: close\r\n\r\n{body}",
           body.len())?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let code: u16 = status_line.split_whitespace().nth(1)
        .and_then(|c| c.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("bad status line {status_line:?}"))?;
    let mut content_len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        if h.trim().is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase()
            .strip_prefix("content-length:")
        {
            content_len = v.trim().parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; content_len];
    reader.read_exact(&mut body)?;
    Ok((code, String::from_utf8_lossy(&body).into_owned()))
}
