//! Predictive model prefetch: pick the model a device should
//! decrypt-ahead while its current batch executes.
//!
//! The CC swap path is expensive because the whole weight blob rides
//! the encrypted bounce path at swap time.  PipeLLM-style speculative
//! staging hides that cost: while device *d* executes a batch of model
//! *M*, the engine stages the predicted next model *H* into a second
//! device buffer through the (pipelined) DMA path, so a later swap to
//! *H* promotes the staged buffer without a second DMA.
//!
//! The staged-residency state machine itself lives in
//! [`crate::coordinator::swap::SwapManager`] (real path) and in the DES
//! backend's mirrored staging slots; this module is the *predictor* —
//! the default implementation behind [`Strategy::next_hint`]:
//!
//! ```text
//!             prefetch(H)             ensure_resident(H)
//!  (empty) ─────────────────▶ staged(H) ─────────────────▶ resident(H)
//!     ▲                          │                          (promoted,
//!     │   ensure_resident(X≠H)   │                           no DMA)
//!     └──────────────────────────┘
//!          wrong prediction: staged buffer dropped, normal swap
//! ```
//!
//! The prediction mirrors how every Table I strategy actually picks
//! work: the timer guarantee dispatches the longest-waiting head first,
//! so among the queues that would force a swap, the one whose head has
//! waited longest is the most likely next residency.  Ties break to the
//! longer queue, then lexicographically — and because the intern table
//! is sorted, comparing [`ModelId`]s decides those name ties
//! identically — so the hint is deterministic, a requirement for the
//! DES-vs-real parity contract.
//!
//! [`Strategy::next_hint`]: crate::coordinator::strategy::Strategy::next_hint

use crate::coordinator::strategy::SchedContext;
use crate::runtime::ModelId;

/// Predict the model most likely to be dispatched after `chosen`:
/// the longest-waiting other queue (timer order), ties to the longer
/// queue, then the lexicographically smallest name (== smallest id).
/// `None` when no other queue holds work.
pub fn predict_next(ctx: &SchedContext, chosen: ModelId)
                    -> Option<ModelId> {
    ctx.queues.iter()
        .filter(|v| v.model != chosen && v.len > 0)
        .max_by(|a, b| {
            a.oldest_wait_s.partial_cmp(&b.oldest_wait_s).unwrap()
                .then(a.len.cmp(&b.len))
                // max_by keeps the *greater* element: reverse the id
                // order so the smaller name wins ties
                .then(b.model.cmp(&a.model))
        })
        .map(|v| v.model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::strategy::ModelView;

    // Sorted-table ids: "a" < "b" < "c"; X is a model outside the
    // queue set (the currently dispatched one in some tests).
    const A: ModelId = ModelId(0);
    const B: ModelId = ModelId(1);
    const C: ModelId = ModelId(2);
    const X: ModelId = ModelId(9);

    fn view(model: ModelId, len: usize, wait: f64) -> ModelView {
        ModelView {
            model,
            len,
            oldest_wait_s: wait,
            obs: 8,
            rate_rps: 2.0,
            est_load_s: 0.5,
            est_exec_s: 0.5,
        }
    }

    fn ctx(queues: Vec<ModelView>) -> SchedContext {
        SchedContext {
            now_s: 10.0,
            devices: Vec::new(),
            queues,
            sla_s: 6.0,
            timeout_s: 3.0,
        }
    }

    #[test]
    fn predicts_longest_waiting_other_queue() {
        let c = ctx(vec![view(A, 4, 5.0), view(B, 2, 2.0),
                         view(C, 9, 4.0)]);
        assert_eq!(predict_next(&c, A), Some(C),
                   "A excluded; C has waited longest among the rest");
        assert_eq!(predict_next(&c, C), Some(A));
    }

    #[test]
    fn ties_break_to_longer_queue_then_name() {
        let c = ctx(vec![view(A, 1, 2.0), view(B, 5, 2.0)]);
        assert_eq!(predict_next(&c, X), Some(B));
        let c = ctx(vec![view(B, 3, 2.0), view(A, 3, 2.0)]);
        assert_eq!(predict_next(&c, X), Some(A),
                   "full tie is deterministic: smallest name wins");
    }

    #[test]
    fn no_other_work_means_no_hint() {
        assert_eq!(predict_next(&ctx(vec![]), A), None);
        let c = ctx(vec![view(A, 4, 1.0)]);
        assert_eq!(predict_next(&c, A), None,
                   "the dispatched model is never its own hint");
        let c = ctx(vec![view(B, 0, 0.0)]);
        assert_eq!(predict_next(&c, A), None, "empty queues don't hint");
    }
}
