//! Model swap manager: residency state machine + load/unload timing.
//!
//! "A single VM with one GPU ... capable of serving one model at a time"
//! (§III-A): at most one model's weights are resident.  A swap unloads
//! the current model (cheap, mode-independent) and DMAs the next model's
//! weight blob through the device's (optionally confidential) transfer
//! path — the expensive step whose CC overhead drives the paper's
//! headline results.

use crate::gpu::device::SimGpu;
use crate::gpu::hbm::HbmBuffer;
use crate::runtime::Registry;

/// Timing of one `ensure_resident` call.
#[derive(Debug, Clone, Copy, Default)]
pub struct SwapReport {
    /// True if a load (and possibly an unload) actually happened.
    pub swapped: bool,
    pub load_s: f64,
    pub unload_s: f64,
    /// Crypto share of the load (CC only).
    pub crypto_s: f64,
}

/// Per-model load/unload statistics for Fig 3.
#[derive(Debug, Clone, Default)]
pub struct SwapStats {
    pub swap_count: u64,
    pub total_load_s: f64,
    pub total_unload_s: f64,
    pub total_crypto_s: f64,
    /// (model, load_s) samples in order.
    pub load_samples: Vec<(String, f64)>,
}

/// The residency manager.
pub struct SwapManager {
    resident: Option<(String, HbmBuffer)>,
    stats: SwapStats,
}

impl Default for SwapManager {
    fn default() -> Self {
        Self::new()
    }
}

impl SwapManager {
    pub fn new() -> SwapManager {
        SwapManager { resident: None, stats: SwapStats::default() }
    }

    pub fn resident(&self) -> Option<&str> {
        self.resident.as_ref().map(|(m, _)| m.as_str())
    }

    pub fn stats(&self) -> &SwapStats {
        &self.stats
    }

    /// Make `model` resident, swapping if needed. Returns timing.
    pub fn ensure_resident(&mut self, gpu: &mut SimGpu, registry: &Registry,
                           model: &str) -> anyhow::Result<SwapReport> {
        if let Some((cur, _)) = &self.resident {
            if cur == model {
                return Ok(SwapReport::default());
            }
        }
        let mut report = SwapReport { swapped: true, ..Default::default() };

        // unload current (paper: 4–10 ms, similar in both modes)
        if let Some((_, buf)) = self.resident.take() {
            report.unload_s = gpu.unload(buf).as_secs_f64();
            self.stats.total_unload_s += report.unload_s;
        }

        // load next: weights blob through the (CC) DMA path
        let entry = registry.entry(model)?;
        let (buf, rep) = gpu.upload(&entry.weights.raw)
            .map_err(|e| anyhow::anyhow!("loading {model}: {e}"))?;
        report.load_s = rep.elapsed.as_secs_f64();
        report.crypto_s = rep.crypto.as_secs_f64();

        self.resident = Some((model.to_string(), buf));
        self.stats.swap_count += 1;
        self.stats.total_load_s += report.load_s;
        self.stats.total_crypto_s += report.crypto_s;
        self.stats.load_samples.push((model.to_string(), report.load_s));
        Ok(report)
    }

    /// Estimated load time for `model` in the device's mode — feeds the
    /// SelectBatch `desired_latency` term.
    pub fn estimate_load_s(gpu: &SimGpu, registry: &Registry, model: &str)
                           -> f64 {
        let Ok(entry) = registry.entry(model) else { return 0.0 };
        let bytes = entry.spec.weight_bytes() as f64;
        let bw = match gpu.mode() {
            crate::gpu::CcMode::On => gpu.config().bw_cc,
            crate::gpu::CcMode::Off => gpu.config().bw_plain,
        };
        bytes / bw
    }

    /// Drop residency (end of run), freeing device memory.
    pub fn evict(&mut self, gpu: &mut SimGpu) {
        if let Some((_, buf)) = self.resident.take() {
            gpu.unload(buf);
        }
    }
}

/// Mean load seconds per model from collected samples (Fig 3 rows).
pub fn mean_load_by_model(stats: &SwapStats)
                          -> Vec<(String, f64, usize)> {
    let mut agg: std::collections::BTreeMap<String, (f64, usize)> =
        Default::default();
    for (m, s) in &stats.load_samples {
        let e = agg.entry(m.clone()).or_default();
        e.0 += s;
        e.1 += 1;
    }
    agg.into_iter().map(|(m, (sum, n))| (m, sum / n as f64, n)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::device::{GpuConfig, SimGpu};
    use crate::gpu::CcMode;
    use crate::runtime::manifest::Manifest;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn registry() -> Registry {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        Registry::load(&m,
                       &["llama-sim".to_string(), "gemma-sim".to_string()],
                       &[1]).unwrap()
    }

    fn gpu() -> SimGpu {
        SimGpu::new(GpuConfig { no_throttle: true, ..Default::default() })
            .unwrap()
    }

    #[test]
    fn residency_state_machine() {
        let reg = registry();
        let mut gpu = gpu();
        let mut sm = SwapManager::new();
        assert_eq!(sm.resident(), None);

        let r1 = sm.ensure_resident(&mut gpu, &reg, "llama-sim").unwrap();
        assert!(r1.swapped && r1.load_s > 0.0 && r1.unload_s == 0.0);
        assert_eq!(sm.resident(), Some("llama-sim"));

        // idempotent
        let r2 = sm.ensure_resident(&mut gpu, &reg, "llama-sim").unwrap();
        assert!(!r2.swapped && r2.load_s == 0.0);
        assert_eq!(sm.stats().swap_count, 1);

        // swap unloads the old model
        let r3 = sm.ensure_resident(&mut gpu, &reg, "gemma-sim").unwrap();
        assert!(r3.swapped);
        assert_eq!(sm.resident(), Some("gemma-sim"));
        assert_eq!(sm.stats().swap_count, 2);
        // only gemma resident -> memory in use == its weights
        assert_eq!(gpu.mem_in_use(),
                   reg.entry("gemma-sim").unwrap().spec.weight_bytes());
    }

    #[test]
    fn unknown_model_fails_cleanly() {
        let reg = registry();
        let mut gpu = gpu();
        let mut sm = SwapManager::new();
        assert!(sm.ensure_resident(&mut gpu, &reg, "nope").is_err());
        assert_eq!(sm.resident(), None, "failed swap must not set resident");
    }

    #[test]
    fn evict_frees() {
        let reg = registry();
        let mut gpu = gpu();
        let mut sm = SwapManager::new();
        sm.ensure_resident(&mut gpu, &reg, "llama-sim").unwrap();
        sm.evict(&mut gpu);
        assert_eq!(sm.resident(), None);
        assert_eq!(gpu.mem_in_use(), 0);
    }

    #[test]
    fn load_estimate_scales_with_mode() {
        let reg = registry();
        let gpu_plain = gpu();
        let est_plain =
            SwapManager::estimate_load_s(&gpu_plain, &reg, "llama-sim");
        let gpu_cc = SimGpu::new(GpuConfig {
            mode: CcMode::On, no_throttle: true, ..Default::default()
        }).unwrap();
        let est_cc = SwapManager::estimate_load_s(&gpu_cc, &reg,
                                                  "llama-sim");
        assert!(est_cc > 2.0 * est_plain,
                "cc estimate {est_cc} vs plain {est_plain}");
    }

    #[test]
    fn mean_load_by_model_aggregates() {
        let mut stats = SwapStats::default();
        stats.load_samples = vec![
            ("a".into(), 1.0), ("a".into(), 3.0), ("b".into(), 2.0)];
        let rows = mean_load_by_model(&stats);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0], ("a".to_string(), 2.0, 2));
        assert_eq!(rows[1], ("b".to_string(), 2.0, 1));
    }
}
