//! Model swap manager: residency state machine + load/unload timing,
//! plus the *staged* residency slot behind predictive prefetch.
//!
//! "A single VM with one GPU ... capable of serving one model at a
//! time" (§III-A): at most one model's weights are resident.  A swap
//! unloads the current model (cheap, mode-independent) and DMAs the
//! next model's weight blob through the device's (optionally
//! confidential) transfer path — the expensive step whose CC overhead
//! drives the paper's headline results.
//!
//! Prefetch (`coordinator::prefetch`) adds one more slot: a *staged*
//! buffer holding a speculatively decrypted-ahead model.  `prefetch`
//! uploads the hinted model next to the resident one while a batch
//! executes; a later `ensure_resident` for that model *promotes* the
//! staged buffer — no second DMA — while a wrong prediction just frees
//! it and takes the normal swap path.

use std::sync::Arc;

use crate::gpu::device::SimGpu;
use crate::gpu::hbm::HbmBuffer;
use crate::runtime::{ModelId, ModelTable, Registry};

/// Timing of one `ensure_resident` call.
#[derive(Debug, Clone, Copy, Default)]
pub struct SwapReport {
    /// True if a residency change actually happened.
    pub swapped: bool,
    /// True when the load was satisfied by promoting a staged
    /// (prefetched) buffer — `load_s` is then zero: no second DMA.
    pub promoted: bool,
    /// True when a staged buffer for a *different* model was discarded
    /// (wrong prediction).
    pub dropped_staged: bool,
    pub load_s: f64,
    pub unload_s: f64,
    /// Total modeled crypto work of the load (CC only).
    pub crypto_total_s: f64,
    /// Crypto time not hidden behind the link (== total when the DMA
    /// pipeline is off; see `gpu::dma`).
    pub crypto_exposed_s: f64,
}
// Note: the serialized-bridge residual of the hardware-generation
// profiles has no field here — wall-mode swaps measure real transfers,
// while the bridge is a virtual-pricing attribution that
// `engine::backend::price_swap` folds into `SwapOutcome` (and the
// `obs` trace splits out of the load column) on virtual runs only.

/// Timing of one `prefetch` staging upload.
#[derive(Debug, Clone, Copy, Default)]
pub struct PrefetchReport {
    /// Staging cost: the (pipelined) DMA load of the hinted model.
    pub load_s: f64,
    /// Crypto work done ahead of time.  None of it is *exposed* at the
    /// swap — that is the point — so only the total is reported here;
    /// any part of the staging that outlives the batch it hides behind
    /// shows up on the engine's device timeline instead.
    pub crypto_total_s: f64,
    /// True when an older staged model was discarded to restage.
    pub dropped_staged: bool,
}

/// Per-model load/unload statistics for Fig 3.
#[derive(Debug, Clone, Default)]
pub struct SwapStats {
    pub swap_count: u64,
    pub total_load_s: f64,
    pub total_unload_s: f64,
    /// Total crypto work (demand loads + prefetch staging).
    pub total_crypto_s: f64,
    /// Crypto time exposed on the swap path (never includes staging).
    pub total_crypto_exposed_s: f64,
    /// Per-swap bridge/attestation residual seconds (profile devices
    /// with `bridge_residual_s > 0` only; always 0 on legacy knobs).
    pub total_bridge_s: f64,
    /// Staging uploads issued.
    pub prefetch_count: u64,
    /// Swaps satisfied by promoting a staged buffer (no second DMA).
    pub promoted_count: u64,
    /// Staged buffers discarded on a wrong prediction or restage.
    pub dropped_prefetches: u64,
    /// Seconds spent in staging uploads (overlapped with execution).
    pub total_prefetch_s: f64,
    /// (model, load_s) samples in order (demand loads only).  Interned
    /// ids — one `u32` copy per swap instead of a `String` clone.
    pub load_samples: Vec<(ModelId, f64)>,
}

/// The residency manager.
pub struct SwapManager {
    /// The run's intern table, for recording per-model samples without
    /// cloning names.
    table: Arc<ModelTable>,
    resident: Option<(String, HbmBuffer)>,
    /// Speculatively staged next model (prefetch target).
    staged: Option<(String, HbmBuffer)>,
    stats: SwapStats,
}

impl SwapManager {
    pub fn new(table: Arc<ModelTable>) -> SwapManager {
        SwapManager { table, resident: None, staged: None,
                      stats: SwapStats::default() }
    }

    pub fn resident(&self) -> Option<&str> {
        self.resident.as_ref().map(|(m, _)| m.as_str())
    }

    /// Model currently staged by prefetch, if any.
    pub fn staged(&self) -> Option<&str> {
        self.staged.as_ref().map(|(m, _)| m.as_str())
    }

    pub fn stats(&self) -> &SwapStats {
        &self.stats
    }

    /// Make `model` resident, swapping if needed. Returns timing.
    pub fn ensure_resident(&mut self, gpu: &mut SimGpu, registry: &Registry,
                           model: &str) -> anyhow::Result<SwapReport> {
        if let Some((cur, _)) = &self.resident {
            if cur == model {
                // staged state is untouched: the hint may still pay off
                return Ok(SwapReport::default());
            }
        }
        let mut report = SwapReport { swapped: true, ..Default::default() };

        // unload current (paper: 4–10 ms, similar in both modes)
        if let Some((_, buf)) = self.resident.take() {
            report.unload_s = gpu.unload(buf).as_secs_f64();
            self.stats.total_unload_s += report.unload_s;
        }

        // staged hit: promote the prefetched buffer — no second DMA
        if self.staged().is_some_and(|m| m == model) {
            self.resident = self.staged.take();
            report.promoted = true;
            self.stats.swap_count += 1;
            self.stats.promoted_count += 1;
            self.stats.load_samples.push((self.table.require(model)?, 0.0));
            return Ok(report);
        }
        // wrong prediction: the staged buffer is dead weight — free it
        // (no unload latency: it was never resident)
        if let Some((_, buf)) = self.staged.take() {
            gpu.free(buf);
            report.dropped_staged = true;
            self.stats.dropped_prefetches += 1;
        }

        // load next: weights blob through the (CC) DMA path
        let entry = registry.entry(model)?;
        let (buf, rep) = gpu.upload(&entry.weights.raw)
            .map_err(|e| anyhow::anyhow!("loading {model}: {e}"))?;
        report.load_s = rep.elapsed.as_secs_f64();
        report.crypto_total_s = rep.crypto_total.as_secs_f64();
        report.crypto_exposed_s = rep.crypto_exposed.as_secs_f64();

        self.resident = Some((model.to_string(), buf));
        self.stats.swap_count += 1;
        self.stats.total_load_s += report.load_s;
        self.stats.total_crypto_s += report.crypto_total_s;
        self.stats.total_crypto_exposed_s += report.crypto_exposed_s;
        self.stats.load_samples.push((self.table.require(model)?,
                                      report.load_s));
        Ok(report)
    }

    /// Make a `share`-sized layer shard of `model` resident (pipeline-
    /// parallel stages).  Identical residency state machine to
    /// [`SwapManager::ensure_resident`], but the DMA moves only the
    /// shard's slice of the weight blob.  A staged buffer is dropped as
    /// a wrong prediction (prefetch is validated off under pp, so this
    /// is defensive).
    pub fn ensure_resident_shard(&mut self, gpu: &mut SimGpu,
                                 registry: &Registry, model: &str,
                                 share: f64) -> anyhow::Result<SwapReport> {
        if let Some((cur, _)) = &self.resident {
            if cur == model {
                return Ok(SwapReport::default());
            }
        }
        let mut report = SwapReport { swapped: true, ..Default::default() };
        if let Some((_, buf)) = self.resident.take() {
            report.unload_s = gpu.unload(buf).as_secs_f64();
            self.stats.total_unload_s += report.unload_s;
        }
        if let Some((_, buf)) = self.staged.take() {
            gpu.free(buf);
            report.dropped_staged = true;
            self.stats.dropped_prefetches += 1;
        }
        let entry = registry.entry(model)?;
        let raw = &entry.weights.raw;
        let take = ((raw.len() as f64 * share).ceil() as usize)
            .clamp(1, raw.len());
        let (buf, rep) = gpu.upload(&raw[..take])
            .map_err(|e| anyhow::anyhow!("loading {model} shard: {e}"))?;
        report.load_s = rep.elapsed.as_secs_f64();
        report.crypto_total_s = rep.crypto_total.as_secs_f64();
        report.crypto_exposed_s = rep.crypto_exposed.as_secs_f64();
        self.resident = Some((model.to_string(), buf));
        self.stats.swap_count += 1;
        self.stats.total_load_s += report.load_s;
        self.stats.total_crypto_s += report.crypto_total_s;
        self.stats.total_crypto_exposed_s += report.crypto_exposed_s;
        self.stats.load_samples.push((self.table.require(model)?,
                                      report.load_s));
        Ok(report)
    }

    /// Decrypt-ahead: stage `model` in a second device buffer so a
    /// later swap can promote it without a DMA.  Returns `Ok(None)`
    /// when staging is pointless (already resident/staged) or the
    /// device lacks memory for a second blob (the speculation is
    /// simply skipped — residency is never disturbed).
    pub fn prefetch(&mut self, gpu: &mut SimGpu, registry: &Registry,
                    model: &str) -> anyhow::Result<Option<PrefetchReport>> {
        if self.resident().is_some_and(|m| m == model)
            || self.staged().is_some_and(|m| m == model)
        {
            return Ok(None);
        }
        let entry = registry.entry(model)?;
        let need = entry.weights.raw.len() as u64;
        // Exact capacity gate, decided before touching the staged
        // slot: the new blob must fit the largest hole *after*
        // reclaiming the current staged buffer (fragmentation
        // included), so a hint that cannot be staged never destroys a
        // live speculation.
        let fits = match &self.staged {
            Some((_, buf)) => need <= gpu.mem_largest_free_after(*buf),
            None => need <= gpu.mem_largest_free(),
        };
        if !fits {
            return Ok(None);
        }
        let mut report = PrefetchReport::default();
        if let Some((_, buf)) = self.staged.take() {
            gpu.free(buf);
            report.dropped_staged = true;
            self.stats.dropped_prefetches += 1;
        }
        // the allocation now cannot OOM (first-fit into a hole the
        // gate proved exists), so any upload error is a real DMA/CC
        // fault — exactly as fatal here as it is on the demand path
        let (buf, rep) = gpu.upload(&entry.weights.raw)
            .map_err(|e| anyhow::anyhow!("staging {model}: {e}"))?;
        report.load_s = rep.elapsed.as_secs_f64();
        report.crypto_total_s = rep.crypto_total.as_secs_f64();
        self.staged = Some((model.to_string(), buf));
        self.stats.prefetch_count += 1;
        self.stats.total_prefetch_s += report.load_s;
        self.stats.total_crypto_s += report.crypto_total_s;
        Ok(Some(report))
    }

    /// Estimated load time for `model` in the device's mode — feeds the
    /// SelectBatch `desired_latency` term.  A staged hit is free (the
    /// promotion needs no DMA); otherwise the PCIe model under the
    /// configured pipeline setting.
    pub fn estimate_load_s(&self, gpu: &SimGpu, registry: &Registry,
                           model: &str) -> f64 {
        if self.staged().is_some_and(|m| m == model) {
            return 0.0;
        }
        Self::estimate_cold_load_s(gpu, registry, model)
    }

    /// Load estimate ignoring staged state (profilers, cold paths).
    pub fn estimate_cold_load_s(gpu: &SimGpu, registry: &Registry,
                                model: &str) -> f64 {
        let Ok(entry) = registry.entry(model) else { return 0.0 };
        let bytes = entry.spec.weight_bytes() as f64;
        match gpu.mode() {
            crate::gpu::CcMode::On =>
                bytes * gpu.config().cc_seconds_per_byte(),
            crate::gpu::CcMode::Off => bytes / gpu.config().bw_plain,
        }
    }

    /// Drop residency and any staged buffer (end of run), freeing
    /// device memory.
    pub fn evict(&mut self, gpu: &mut SimGpu) {
        if let Some((_, buf)) = self.resident.take() {
            gpu.unload(buf);
        }
        if let Some((_, buf)) = self.staged.take() {
            gpu.free(buf);
        }
    }
}

/// Mean load seconds per model from collected samples (Fig 3 rows).
/// Rows come back in id order, which — the intern table being sorted —
/// is exactly the name order the old `BTreeMap<String, _>` produced.
pub fn mean_load_by_model(stats: &SwapStats)
                          -> Vec<(ModelId, f64, usize)> {
    let mut agg: std::collections::BTreeMap<ModelId, (f64, usize)> =
        Default::default();
    for &(m, s) in &stats.load_samples {
        let e = agg.entry(m).or_default();
        e.0 += s;
        e.1 += 1;
    }
    agg.into_iter().map(|(m, (sum, n))| (m, sum / n as f64, n)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::device::{GpuConfig, SimGpu};
    use crate::gpu::CcMode;
    use crate::runtime::manifest::Manifest;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn registry() -> Registry {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        Registry::load(&m,
                       &["llama-sim".to_string(), "gemma-sim".to_string()],
                       &[1]).unwrap()
    }

    fn table() -> Arc<ModelTable> {
        ModelTable::shared(["llama-sim", "gemma-sim", "granite-sim"])
    }

    fn manager() -> SwapManager {
        SwapManager::new(table())
    }

    fn gpu() -> SimGpu {
        SimGpu::new(GpuConfig { no_throttle: true, ..Default::default() })
            .unwrap()
    }

    #[test]
    fn residency_state_machine() {
        let reg = registry();
        let mut gpu = gpu();
        let mut sm = manager();
        assert_eq!(sm.resident(), None);

        let r1 = sm.ensure_resident(&mut gpu, &reg, "llama-sim").unwrap();
        assert!(r1.swapped && r1.load_s > 0.0 && r1.unload_s == 0.0);
        assert_eq!(sm.resident(), Some("llama-sim"));

        // idempotent
        let r2 = sm.ensure_resident(&mut gpu, &reg, "llama-sim").unwrap();
        assert!(!r2.swapped && r2.load_s == 0.0);
        assert_eq!(sm.stats().swap_count, 1);

        // swap unloads the old model
        let r3 = sm.ensure_resident(&mut gpu, &reg, "gemma-sim").unwrap();
        assert!(r3.swapped);
        assert_eq!(sm.resident(), Some("gemma-sim"));
        assert_eq!(sm.stats().swap_count, 2);
        // only gemma resident -> memory in use == its weights
        assert_eq!(gpu.mem_in_use(),
                   reg.entry("gemma-sim").unwrap().spec.weight_bytes());
    }

    #[test]
    fn unknown_model_fails_cleanly() {
        let reg = registry();
        let mut gpu = gpu();
        let mut sm = manager();
        assert!(sm.ensure_resident(&mut gpu, &reg, "nope").is_err());
        assert_eq!(sm.resident(), None, "failed swap must not set resident");
    }

    #[test]
    fn evict_frees() {
        let reg = registry();
        let mut gpu = gpu();
        let mut sm = manager();
        sm.ensure_resident(&mut gpu, &reg, "llama-sim").unwrap();
        sm.prefetch(&mut gpu, &reg, "gemma-sim").unwrap();
        sm.evict(&mut gpu);
        assert_eq!(sm.resident(), None);
        assert_eq!(sm.staged(), None);
        assert_eq!(gpu.mem_in_use(), 0, "evict must free staged too");
    }

    #[test]
    fn prefetch_then_promote_skips_the_second_dma() {
        let reg = registry();
        let mut gpu = gpu();
        let mut sm = manager();
        sm.ensure_resident(&mut gpu, &reg, "llama-sim").unwrap();
        let pf = sm.prefetch(&mut gpu, &reg, "gemma-sim").unwrap()
            .expect("staging must fit");
        assert!(pf.load_s > 0.0);
        assert_eq!(sm.staged(), Some("gemma-sim"));
        // both blobs resident while staged
        let both = reg.entry("llama-sim").unwrap().spec.weight_bytes()
            + reg.entry("gemma-sim").unwrap().spec.weight_bytes();
        assert_eq!(gpu.mem_in_use(), both);

        let uploads_before = gpu.dma_stats().h2d_transfers;
        let rep = sm.ensure_resident(&mut gpu, &reg, "gemma-sim").unwrap();
        assert!(rep.swapped && rep.promoted);
        assert_eq!(rep.load_s, 0.0, "promotion is DMA-free");
        assert_eq!(gpu.dma_stats().h2d_transfers, uploads_before,
                   "promotion must not issue a second DMA");
        assert_eq!(sm.resident(), Some("gemma-sim"));
        assert_eq!(sm.staged(), None);
        assert_eq!(sm.stats().promoted_count, 1);
        // the promoted buffer is the only thing left in memory
        assert_eq!(gpu.mem_in_use(),
                   reg.entry("gemma-sim").unwrap().spec.weight_bytes());
    }

    #[test]
    fn wrong_prediction_drops_staged_without_corrupting_residency() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let reg = Registry::load(&m, &["llama-sim".to_string(),
                                       "gemma-sim".to_string(),
                                       "granite-sim".to_string()],
                                 &[1]).unwrap();
        let mut gpu = gpu();
        let mut sm = manager();
        sm.ensure_resident(&mut gpu, &reg, "llama-sim").unwrap();
        sm.prefetch(&mut gpu, &reg, "gemma-sim").unwrap().unwrap();

        // the next demand is llama again: staged gemma stays parked
        let r = sm.ensure_resident(&mut gpu, &reg, "llama-sim").unwrap();
        assert!(!r.swapped);
        assert_eq!(sm.staged(), Some("gemma-sim"));

        // the demand then goes to a third model: gemma was a wrong
        // prediction — dropped, residency lands on the demanded model
        let r = sm.ensure_resident(&mut gpu, &reg, "granite-sim").unwrap();
        assert!(r.swapped && !r.promoted && r.dropped_staged);
        assert!(r.load_s > 0.0, "wrong prediction pays the full load");
        assert_eq!(sm.resident(), Some("granite-sim"));
        assert_eq!(sm.staged(), None);
        assert_eq!(sm.stats().dropped_prefetches, 1);
        assert_eq!(sm.stats().promoted_count, 0);
        assert_eq!(gpu.mem_in_use(),
                   reg.entry("granite-sim").unwrap().spec.weight_bytes(),
                   "dropped staged buffer must be freed");

        // restaging a different hint drops the old staged buffer too
        sm.prefetch(&mut gpu, &reg, "llama-sim").unwrap().unwrap();
        let pf = sm.prefetch(&mut gpu, &reg, "gemma-sim").unwrap().unwrap();
        assert!(pf.dropped_staged);
        assert_eq!(sm.staged(), Some("gemma-sim"));
        assert_eq!(sm.stats().dropped_prefetches, 2);
    }

    #[test]
    fn prefetch_oom_skips_speculation() {
        let reg = registry();
        let llama = reg.entry("llama-sim").unwrap().spec.weight_bytes();
        let mut small = GpuConfig { no_throttle: true,
                                    ..GpuConfig::default() };
        // room for one blob only
        small.hbm_capacity = llama + llama / 2;
        let mut gpu = SimGpu::new(small).unwrap();
        let mut sm = manager();
        sm.ensure_resident(&mut gpu, &reg, "llama-sim").unwrap();
        let pf = sm.prefetch(&mut gpu, &reg, "gemma-sim").unwrap();
        assert!(pf.is_none(), "OOM staging must be skipped, not fatal");
        assert_eq!(sm.staged(), None);
        assert_eq!(sm.resident(), Some("llama-sim"));
    }

    #[test]
    fn oversized_hint_never_destroys_live_speculation() {
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let reg = Registry::load(&m, &["llama-sim".to_string(),
                                       "gemma-sim".to_string(),
                                       "granite-sim".to_string()],
                                 &[1]).unwrap();
        let llama = reg.entry("llama-sim").unwrap().spec.weight_bytes();
        let granite =
            reg.entry("granite-sim").unwrap().spec.weight_bytes();
        // llama + gemma fit (granite is the largest family), but
        // granite can never fit next to llama — not even by
        // reclaiming the staged gemma
        let cfg = GpuConfig { no_throttle: true,
                              hbm_capacity: llama + granite - 1,
                              ..GpuConfig::default() };
        let mut gpu = SimGpu::new(cfg).unwrap();
        let mut sm = manager();
        sm.ensure_resident(&mut gpu, &reg, "llama-sim").unwrap();
        sm.prefetch(&mut gpu, &reg, "gemma-sim").unwrap()
            .expect("gemma staging must fit");
        let pf = sm.prefetch(&mut gpu, &reg, "granite-sim").unwrap();
        assert!(pf.is_none(), "too-big hint must be skipped");
        assert_eq!(sm.staged(), Some("gemma-sim"),
                   "live speculation must survive an oversized hint");
        assert_eq!(sm.stats().dropped_prefetches, 0);
    }

    #[test]
    fn load_estimate_scales_with_mode_and_pipeline() {
        let reg = registry();
        let gpu_plain = gpu();
        let sm = manager();
        let est_plain =
            sm.estimate_load_s(&gpu_plain, &reg, "llama-sim");
        let gpu_cc = SimGpu::new(GpuConfig {
            mode: CcMode::On, no_throttle: true, ..Default::default()
        }).unwrap();
        let est_cc = sm.estimate_load_s(&gpu_cc, &reg, "llama-sim");
        assert!(est_cc > 2.0 * est_plain,
                "cc estimate {est_cc} vs plain {est_plain}");
        let gpu_pipe = SimGpu::new(GpuConfig {
            mode: CcMode::On, pipeline_depth: 2, no_throttle: true,
            ..Default::default()
        }).unwrap();
        let est_pipe = sm.estimate_load_s(&gpu_pipe, &reg, "llama-sim");
        assert!(est_pipe < est_cc,
                "pipelined estimate {est_pipe} must undercut serialized \
                 {est_cc}");
        assert!(est_pipe > est_plain * 0.9,
                "pipelined CC cannot beat the plain link");
    }

    #[test]
    fn staged_model_estimates_as_free() {
        let reg = registry();
        let mut gpu = gpu();
        let mut sm = manager();
        sm.ensure_resident(&mut gpu, &reg, "llama-sim").unwrap();
        assert!(sm.estimate_load_s(&gpu, &reg, "gemma-sim") > 0.0);
        sm.prefetch(&mut gpu, &reg, "gemma-sim").unwrap().unwrap();
        assert_eq!(sm.estimate_load_s(&gpu, &reg, "gemma-sim"), 0.0,
                   "a staged model promotes for free");
    }

    #[test]
    fn mean_load_by_model_aggregates() {
        let mut stats = SwapStats::default();
        stats.load_samples = vec![
            (ModelId(0), 1.0), (ModelId(0), 3.0), (ModelId(1), 2.0)];
        let rows = mean_load_by_model(&stats);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0], (ModelId(0), 2.0, 2));
        assert_eq!(rows[1], (ModelId(1), 2.0, 1));
    }
}
