//! Per-model FIFO queues (§III-C4: "inference requests are queued in
//! order of arrival with one queue for every model").
//!
//! Queues are a dense `Vec<VecDeque<Request>>` indexed by interned
//! [`ModelId`] — no per-push map lookups or key clones.  Because the
//! intern table is sorted, iterating queues by index visits models in
//! exactly the lexicographic order the old `BTreeMap<String, _>` did,
//! so expiry order, drain order and every downstream table stay
//! byte-identical.
//!
//! The drain entry points come in two flavors: allocating (`pop_n`,
//! `expire`, `expire_by` — convenient for tests and cold paths) and
//! `_into` variants that fill a caller-owned buffer, which the engine
//! reuses across every tick so the steady-state loop allocates
//! nothing.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::coordinator::request::Request;
use crate::runtime::{ModelId, ModelTable};

/// The one expiry comparison: a request is expired iff `now` is
/// strictly past its absolute deadline.  Both the uniform-SLA path
/// (`expire`, deadline = arrival + sla) and the per-class path
/// (`expire_by`, caller-supplied deadline) route through this, so a
/// class deadline of exactly 1.0× the SLA is bit-for-bit identical to
/// the uniform path even at FP boundary values — the two used to
/// disagree (`now - arrival > sla` vs `now > deadline`) whenever
/// `arrival + sla` rounds differently from the subtraction.
#[inline]
pub fn past_deadline(now_s: f64, deadline_s: f64) -> bool {
    now_s > deadline_s
}

/// One FIFO per interned model, arrival order preserved within each
/// queue.
#[derive(Debug)]
pub struct ModelQueues {
    table: Arc<ModelTable>,
    queues: Vec<VecDeque<Request>>,
}

impl ModelQueues {
    /// Queues for every model in `table`; ids minted by that table are
    /// the only valid keys.
    pub fn new(table: Arc<ModelTable>) -> ModelQueues {
        let queues = (0..table.len()).map(|_| VecDeque::new()).collect();
        ModelQueues { table, queues }
    }

    /// The intern table the queues are addressed by.
    pub fn table(&self) -> &Arc<ModelTable> {
        &self.table
    }

    pub fn push(&mut self, req: Request) {
        self.queues[req.model.index()].push_back(req);
    }

    /// Pop up to `n` requests from `model`'s queue head.
    pub fn pop_n(&mut self, model: ModelId, n: usize) -> Vec<Request> {
        let mut out = Vec::new();
        self.pop_n_into(model, n, &mut out);
        out
    }

    /// Pop up to `n` requests from `model`'s queue head into `out`
    /// (appended; `out` is *not* cleared — callers own its lifecycle).
    pub fn pop_n_into(&mut self, model: ModelId, n: usize,
                      out: &mut Vec<Request>) {
        let q = &mut self.queues[model.index()];
        let take = n.min(q.len());
        out.extend(q.drain(..take));
    }

    /// Push requests back to the *front*, preserving their order — used
    /// when a batch had to shrink (OOM guard).
    pub fn push_front(&mut self, model: ModelId, reqs: Vec<Request>) {
        let q = &mut self.queues[model.index()];
        for r in reqs.into_iter().rev() {
            q.push_front(r);
        }
    }

    pub fn len(&self, model: ModelId) -> usize {
        self.queues[model.index()].len()
    }

    pub fn total_len(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.total_len() == 0
    }

    /// Arrival time of the head (oldest) request, if any.
    pub fn head_arrival_s(&self, model: ModelId) -> Option<f64> {
        self.queues[model.index()].front().map(|r| r.arrival_s)
    }

    /// Models with at least one queued request, in table (==
    /// lexicographic) order — an iterator, so the per-tick view build
    /// allocates nothing.
    pub fn nonempty_ids(&self) -> impl Iterator<Item = ModelId> + '_ {
        self.queues.iter().enumerate()
            .filter(|(_, q)| !q.is_empty())
            .map(|(i, _)| ModelId(i as u32))
    }

    /// Drain everything (end-of-run accounting of unserved requests).
    pub fn drain_all(&mut self) -> Vec<Request> {
        let mut out = Vec::new();
        for q in self.queues.iter_mut() {
            out.extend(q.drain(..));
        }
        out
    }

    /// Drop requests whose SLA has already expired while queued
    /// (§III-C3: "beyond which they are considered unfulfilled").
    /// Returns the expired requests for unfulfilled accounting.
    /// Keeps queues bounded under overload — the paper's mechanism that
    /// turns CC's slower swaps into lower throughput rather than
    /// unbounded latency.
    pub fn expire(&mut self, now_s: f64, sla_s: f64) -> Vec<Request> {
        let mut out = Vec::new();
        self.expire_into(now_s, sla_s, &mut out);
        out
    }

    /// Allocation-free [`expire`]: expired requests are appended to
    /// `out` in the same (queue-then-FIFO) order.
    pub fn expire_into(&mut self, now_s: f64, sla_s: f64,
                       out: &mut Vec<Request>) {
        for q in self.queues.iter_mut() {
            // FIFO per queue: expired requests are a prefix
            while q.front()
                .map(|r| past_deadline(now_s, r.arrival_s + sla_s))
                .unwrap_or(false)
            {
                out.push(q.pop_front().unwrap());
            }
        }
    }

    /// Per-class expiry: drop requests strictly past their own
    /// deadline (`deadline_at` maps a request to its absolute deadline
    /// in seconds).  Unlike [`expire`], deadlines differ per request,
    /// so expired entries are no longer a queue prefix — this scans
    /// each queue fully, preserving the order of survivors.  Only used
    /// when `--sla-classes` is on; the uniform path keeps the exact
    /// prefix-pop behavior golden runs pin.
    pub fn expire_by<F>(&mut self, now_s: f64, deadline_at: F)
                        -> Vec<Request>
    where
        F: Fn(&Request) -> f64,
    {
        let mut out = Vec::new();
        self.expire_by_into(now_s, deadline_at, &mut out);
        out
    }

    /// Allocation-free [`expire_by`]: instead of draining into a fresh
    /// `kept` deque per queue, rotate each queue through itself —
    /// survivors pop off the front and push back on, so after exactly
    /// `len` steps the queue holds the survivors in their original
    /// order and expired entries landed in `out`.
    pub fn expire_by_into<F>(&mut self, now_s: f64, deadline_at: F,
                             out: &mut Vec<Request>)
    where
        F: Fn(&Request) -> f64,
    {
        for q in self.queues.iter_mut() {
            for _ in 0..q.len() {
                let r = q.pop_front().unwrap();
                if past_deadline(now_s, deadline_at(&r)) {
                    out.push(r);
                } else {
                    q.push_back(r);
                }
            }
        }
    }

    /// Queued requests per tenant class (admission's `class-weighted`
    /// policy input).  Scans every queue — cheap at sim queue depths
    /// and identical in DES and real-virtual runs.
    /// A class byte outside `0..N_CLASSES` is corrupted state, never a
    /// value this crate mints — fail loudly in debug/test builds
    /// instead of silently wrapping it onto some other tenant's count;
    /// release builds drop the row rather than miscount.
    pub fn class_counts(&self) -> [u64; crate::tenancy::N_CLASSES] {
        let mut counts = [0u64; crate::tenancy::N_CLASSES];
        for q in &self.queues {
            for r in q {
                debug_assert!(
                    (r.class as usize) < crate::tenancy::N_CLASSES,
                    "corrupted tenant class {} on request {}",
                    r.class, r.id);
                match r.class as usize {
                    c if c < crate::tenancy::N_CLASSES => counts[c] += 1,
                    _ => {}
                }
            }
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> Arc<ModelTable> {
        ModelTable::shared(["a", "b"])
    }

    fn req(id: u64, model: ModelId, at: f64) -> Request {
        Request { id, model, tokens: vec![0; 4], arrival_s: at, class: 0 }
    }

    // With the sorted two-model table, "a" is id 0 and "b" is id 1.
    const A: ModelId = ModelId(0);
    const B: ModelId = ModelId(1);

    #[test]
    fn fifo_order_within_model() {
        let mut q = ModelQueues::new(table());
        q.push(req(1, A, 0.0));
        q.push(req(2, B, 0.1));
        q.push(req(3, A, 0.2));
        let got = q.pop_n(A, 10);
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(q.len(A), 0);
        assert_eq!(q.len(B), 1);
    }

    #[test]
    fn pop_n_respects_limit() {
        let mut q = ModelQueues::new(table());
        for i in 0..5 {
            q.push(req(i, A, i as f64));
        }
        assert_eq!(q.pop_n(A, 3).len(), 3);
        assert_eq!(q.len(A), 2);
        assert_eq!(q.pop_n(B, 3).len(), 0, "empty queue pops nothing");
    }

    #[test]
    fn pop_n_into_appends_without_clearing() {
        let mut q = ModelQueues::new(table());
        for i in 0..4 {
            q.push(req(i, A, i as f64));
        }
        let mut buf = Vec::new();
        q.pop_n_into(A, 2, &mut buf);
        q.pop_n_into(A, 10, &mut buf);
        let ids: Vec<u64> = buf.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn push_front_preserves_order() {
        let mut q = ModelQueues::new(table());
        q.push(req(3, A, 3.0));
        q.push_front(A, vec![req(1, A, 1.0), req(2, A, 2.0)]);
        let ids: Vec<u64> = q.pop_n(A, 10).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
    }

    #[test]
    fn head_arrival_and_nonempty() {
        let mut q = ModelQueues::new(table());
        assert!(q.head_arrival_s(A).is_none());
        q.push(req(1, A, 5.0));
        q.push(req(2, A, 6.0));
        assert_eq!(q.head_arrival_s(A), Some(5.0));
        assert_eq!(q.nonempty_ids().collect::<Vec<_>>(), vec![A]);
        assert_eq!(q.total_len(), 2);
    }

    #[test]
    fn nonempty_ids_in_table_order() {
        let mut q = ModelQueues::new(table());
        q.push(req(1, B, 0.0));
        q.push(req(2, A, 1.0));
        // table order, not arrival order — the old BTreeMap contract
        assert_eq!(q.nonempty_ids().collect::<Vec<_>>(), vec![A, B]);
    }

    #[test]
    fn drain_all_empties() {
        let mut q = ModelQueues::new(table());
        q.push(req(1, A, 0.0));
        q.push(req(2, B, 0.0));
        assert_eq!(q.drain_all().len(), 2);
        assert!(q.is_empty());
    }

    #[test]
    fn expire_drops_only_overdue_prefix() {
        let mut q = ModelQueues::new(table());
        q.push(req(1, A, 0.0));
        q.push(req(2, A, 5.0));
        q.push(req(3, B, 1.0));
        // now=9, sla=6: requests older than 9-6=3 expire -> ids 1, 3
        let dropped: Vec<u64> = q.expire(9.0, 6.0).iter()
            .map(|r| r.id).collect();
        assert_eq!(dropped, vec![1, 3]);
        assert_eq!(q.len(A), 1);
        assert_eq!(q.head_arrival_s(A), Some(5.0));
        // boundary: exactly at SLA is NOT expired
        assert!(q.expire(11.0, 6.0).is_empty());
        assert_eq!(q.expire(11.1, 6.0).len(), 1);
    }

    #[test]
    fn expire_exactly_at_deadline_keeps_request() {
        // §III-C3 boundary, matching SlaTracker::on_complete's
        // `latency <= sla` rule: a request whose age equals the SLA is
        // still servable, and only strictly-older requests expire.
        let mut q = ModelQueues::new(table());
        q.push(req(1, A, 4.0));
        assert!(q.expire(10.0, 6.0).is_empty(),
                "age == SLA must not expire");
        assert_eq!(q.len(A), 1);
        let dropped = q.expire(10.0 + 1e-9, 6.0);
        assert_eq!(dropped.len(), 1, "just past the deadline expires");
        assert!(q.is_empty());
    }

    #[test]
    fn expire_by_honors_per_class_deadlines() {
        let mut q = ModelQueues::new(table());
        let mut gold = req(1, A, 0.0);
        gold.class = 0; // deadline 3.0 at sla 6
        let mut free = req(2, A, 0.0);
        free.class = 2; // deadline 9.0
        q.push(gold);
        q.push(free);
        let sla = 6.0;
        let deadline = |r: &Request| {
            r.arrival_s + crate::tenancy::class_deadline_s(r.class, sla)
        };
        // t=3: gold exactly at its deadline — kept (boundary matches
        // `expire`'s strict comparison)
        assert!(q.expire_by(3.0, deadline).is_empty());
        // t=4: gold past its window, free (mid-queue survivor order
        // preserved) still live
        let dropped: Vec<u64> = q.expire_by(4.0, deadline).iter()
            .map(|r| r.id).collect();
        assert_eq!(dropped, vec![1]);
        assert_eq!(q.len(A), 1);
        assert_eq!(q.pop_n(A, 1)[0].id, 2);
    }

    #[test]
    fn expire_by_keeps_survivor_order_across_gaps() {
        // mixed deadlines mean expiry can hit the *middle* of a queue;
        // the rotation must keep FIFO order around the gap
        let mut q = ModelQueues::new(table());
        for (id, at, class) in [(1, 0.0, 2), (2, 1.0, 0), (3, 2.0, 2)] {
            let mut r = req(id, A, at);
            r.class = class;
            q.push(r);
        }
        let deadline = |r: &Request| {
            r.arrival_s + crate::tenancy::class_deadline_s(r.class, 6.0)
        };
        let dropped: Vec<u64> = q.expire_by(5.0, deadline).iter()
            .map(|r| r.id).collect();
        assert_eq!(dropped, vec![2], "only the gold in the middle dies");
        let rest: Vec<u64> = q.pop_n(A, 10).iter().map(|r| r.id)
            .collect();
        assert_eq!(rest, vec![1, 3]);
    }

    #[test]
    fn class_counts_cover_all_queues() {
        let mut q = ModelQueues::new(table());
        assert_eq!(q.class_counts(), [0, 0, 0]);
        for (id, model, class) in [(1, A, 0), (2, A, 2),
                                   (3, B, 2), (4, B, 1)] {
            let mut r = req(id, model, 0.0);
            r.class = class;
            q.push(r);
        }
        assert_eq!(q.class_counts(), [1, 1, 2]);
        q.pop_n(B, 2);
        assert_eq!(q.class_counts(), [1, 1, 0]);
    }

    #[test]
    fn expire_by_at_uniform_deadline_matches_expire_exactly() {
        // The unification contract: a per-class deadline of exactly
        // 1.0× the SLA must agree with the uniform path at FP
        // boundary values where `now - arrival > sla` and
        // `now > arrival + sla` round differently.  0.1 + 0.2 is the
        // canonical case: it evaluates to 0.30000000000000004, while
        // 0.3 - 0.1 is 0.19999999999999998 — under the old relative
        // comparison the two paths disagreed at now == arrival + sla.
        let cases = [
            (0.1, 0.2),            // arrival 0.1, sla 0.2
            (0.3, 0.6),            // 0.3 + 0.6 != 0.9 in binary
            (1e16, 1.0),           // sla below arrival's ulp
            (5.0, 6.0),            // exact in binary (sanity)
        ];
        for &(arrival, sla) in &cases {
            let boundary = arrival + sla;
            for &now in &[boundary, boundary * (1.0 + 1e-15),
                          boundary - sla * 1e-9] {
                let mut qa = ModelQueues::new(table());
                qa.push(req(1, A, arrival));
                let mut qb = ModelQueues::new(table());
                qb.push(req(1, A, arrival));
                let uniform = qa.expire(now, sla).len();
                let by = qb.expire_by(now, |r: &Request| {
                    r.arrival_s + sla
                }).len();
                assert_eq!(uniform, by,
                           "paths disagree at arrival={arrival} \
                            sla={sla} now={now}");
            }
        }
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore)]
    #[should_panic(expected = "corrupted tenant class")]
    fn class_counts_panics_on_corrupted_class_in_debug() {
        let mut q = ModelQueues::new(table());
        let mut r = req(1, A, 0.0);
        r.class = crate::tenancy::N_CLASSES as u8; // out of range
        q.push(r);
        q.class_counts();
    }

    #[test]
    fn expire_interleaved_with_partial_drain_counts_each_once() {
        // The partial-batch plan pops a sub-OBS batch and (on OOM) can
        // push a tail back to the queue front; expiry running between
        // those steps must see each request exactly once — either
        // popped for execution or expired, never both, none lost.
        let mut q = ModelQueues::new(table());
        for i in 0..6 {
            q.push(req(i, A, i as f64)); // arrivals at 0..5
        }
        // partial drain pops the two oldest
        let batch: Vec<u64> = q.pop_n(A, 2).iter().map(|r| r.id)
            .collect();
        assert_eq!(batch, vec![0, 1]);
        // OOM guard returns one row to the queue front
        q.push_front(A, vec![req(1, A, 1.0)]);
        // now=7.5, sla=6: ages 6.5/5.5/... -> only id 1 expires
        let expired: Vec<u64> = q.expire(7.5, 6.0).iter().map(|r| r.id)
            .collect();
        assert_eq!(expired, vec![1],
                   "only the requeued overdue head expires");
        // remaining queue is exactly the untouched tail, in order
        let rest: Vec<u64> = q.pop_n(A, 10).iter().map(|r| r.id)
            .collect();
        assert_eq!(rest, vec![2, 3, 4, 5]);
        // final accounting partition — executed {0} (id 1 was returned
        // by the OOM guard before executing), expired {1}, still
        // queued {2..5} — disjoint and complete: each counted once
        let mut all: Vec<u64> = vec![0];
        all.extend(&expired);
        all.extend(&rest);
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3, 4, 5]);
    }
}
