//! The serve loop: ingest → queues → strategy → swap → execute → record.
//!
//! Mirrors the paper's three components (§III-B) in one binary: the
//! request generator runs on an ingest thread walking a precomputed
//! arrival schedule (open-loop, so overload shows up as queueing, not
//! back-pressure on the generator); the scheduler/batcher/executor run
//! on the calling thread; a monitor thread samples system metrics.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::RunConfig;
use crate::coordinator::batcher;
use crate::coordinator::queues::ModelQueues;
use crate::coordinator::rate::RateEstimator;
use crate::coordinator::request::{CompletedRequest, Request};
use crate::coordinator::sla::SlaTracker;
use crate::coordinator::strategy::{strategy_by_name, Decision,
                                   ModelView, SchedContext};
use crate::coordinator::swap::{SwapManager, SwapStats};
use crate::gpu::device::SimGpu;
use crate::gpu::dma::Dir;
use crate::metrics::recorder::{BatchRecord, MonitorRecord, Recorder};
use crate::metrics::system::sample_proc;
use crate::runtime::Registry;
use crate::traffic::pattern_by_name;
use crate::traffic::rng::Pcg64;
use crate::util::json::Json;
use crate::workload::promptgen::PromptGen;
use crate::workload::tokenizer::tokenize;

/// Aggregated outcome of one run — one grid cell of the evaluation.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub label: String,
    pub mode: String,
    pub pattern: String,
    pub strategy: String,
    pub sla_s: f64,
    pub mean_rps: f64,
    pub duration_s: f64,
    /// Actual wall time of the serving phase (duration + drain used).
    pub runtime_s: f64,

    pub generated: u64,
    pub completed: u64,
    pub sla_met: u64,
    pub sla_attainment: f64,

    pub latency_mean_s: f64,
    pub latency_p50_s: f64,
    pub latency_p90_s: f64,
    pub latency_p99_s: f64,
    pub latency_max_s: f64,

    /// Completed requests / runtime (the paper's overall throughput).
    pub throughput_rps: f64,
    /// Completed requests / time spent actually executing — the paper's
    /// "processing rate during inference", which stays ~equal across
    /// modes (§IV-B).
    pub processing_rate_rps: f64,

    pub gpu_util: f64,
    pub swap_count: u64,
    pub total_load_s: f64,
    pub total_unload_s: f64,
    pub total_exec_s: f64,
    pub total_crypto_s: f64,
    pub mean_load_s: f64,
}

impl RunSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::str(self.label.clone())),
            ("mode", Json::str(self.mode.clone())),
            ("pattern", Json::str(self.pattern.clone())),
            ("strategy", Json::str(self.strategy.clone())),
            ("sla_s", Json::num(self.sla_s)),
            ("mean_rps", Json::num(self.mean_rps)),
            ("duration_s", Json::num(self.duration_s)),
            ("runtime_s", Json::num(self.runtime_s)),
            ("generated", Json::num(self.generated as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("sla_met", Json::num(self.sla_met as f64)),
            ("sla_attainment", Json::num(self.sla_attainment)),
            ("latency_mean_s", Json::num(self.latency_mean_s)),
            ("latency_p50_s", Json::num(self.latency_p50_s)),
            ("latency_p90_s", Json::num(self.latency_p90_s)),
            ("latency_p99_s", Json::num(self.latency_p99_s)),
            ("latency_max_s", Json::num(self.latency_max_s)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("processing_rate_rps", Json::num(self.processing_rate_rps)),
            ("gpu_util", Json::num(self.gpu_util)),
            ("swap_count", Json::num(self.swap_count as f64)),
            ("total_load_s", Json::num(self.total_load_s)),
            ("total_unload_s", Json::num(self.total_unload_s)),
            ("total_exec_s", Json::num(self.total_exec_s)),
            ("total_crypto_s", Json::num(self.total_crypto_s)),
            ("mean_load_s", Json::num(self.mean_load_s)),
        ])
    }

    /// One-line human summary.
    pub fn brief(&self) -> String {
        format!(
            "{:<6} {:<7} {:<26} sla={:<4} gen={:<5} done={:<5} \
             att={:>5.1}% lat(mean/p99)={:.2}/{:.2}s thr={:.2}rps \
             util={:>4.1}% swaps={}",
            self.mode, self.pattern, self.strategy, self.sla_s,
            self.generated, self.completed, self.sla_attainment * 100.0,
            self.latency_mean_s, self.latency_p99_s, self.throughput_rps,
            self.gpu_util * 100.0, self.swap_count)
    }
}

/// Device-state snapshot shared with the monitor thread.
#[derive(Debug, Clone, Default)]
struct DeviceSnapshot {
    gpu_util: f64,
    mem_in_use: u64,
    mem_peak: u64,
    fragmentation: f64,
    dma_h2d_bytes: u64,
    dma_crypto_s: f64,
    swaps: u64,
}

/// Run one serving experiment.  The registry is shared across runs (so
/// XLA compiles once per process); OBS values should already be set.
pub fn serve(cfg: &RunConfig, registry: &Registry)
             -> anyhow::Result<(RunSummary, Recorder)> {
    cfg.validate()?;
    let strategy = strategy_by_name(&cfg.strategy)?;
    let models: Vec<String> = if cfg.models.is_empty() {
        registry.names()
    } else {
        cfg.models.clone()
    };
    for m in &models {
        registry.entry(m)?; // fail fast on unknown models
    }

    // ---------------- arrival schedule (open loop, precomputed) --------
    let mut rng = Pcg64::new(cfg.seed);
    let pattern = pattern_by_name(&cfg.pattern)?;
    let arrivals = pattern.generate(cfg.duration_s, cfg.mean_rps, &models,
                                    &mut rng);
    let mut prompts = PromptGen::new(cfg.seed ^ 0xBEEF, 24);
    let schedule: Vec<Request> = arrivals.iter().enumerate().map(|(i, a)| {
        let spec = &registry.entry(&a.model).unwrap().spec;
        Request {
            id: i as u64,
            model: a.model.clone(),
            tokens: tokenize(&prompts.next_prompt(&a.model),
                             spec.prompt_len, spec.vocab as u32),
            arrival_s: a.at_s,
        }
    }).collect();
    let generated = schedule.len() as u64;

    // ---------------- device + shared state ----------------------------
    let mut gpu = SimGpu::new(cfg.gpu.clone())?;
    let snapshot = Arc::new(Mutex::new(DeviceSnapshot::default()));
    let stop = Arc::new(AtomicBool::new(false));

    let start = Instant::now();
    let now_s = move || start.elapsed().as_secs_f64();

    // ---------------- ingest thread ------------------------------------
    let (tx, rx) = mpsc::channel::<Request>();
    let ingest = {
        let stop = stop.clone();
        std::thread::spawn(move || {
            for req in schedule {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let target = Duration::from_secs_f64(req.arrival_s);
                let elapsed = start.elapsed();
                if target > elapsed {
                    std::thread::sleep(target - elapsed);
                }
                if tx.send(req).is_err() {
                    break;
                }
            }
            // channel closes when tx drops
        })
    };

    // ---------------- monitor thread -----------------------------------
    let monitor_records: Arc<Mutex<Vec<MonitorRecord>>> =
        Arc::new(Mutex::new(Vec::new()));
    let monitor = {
        let stop = stop.clone();
        let snapshot = snapshot.clone();
        let records = monitor_records.clone();
        let period = cfg.monitor_period;
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                let snap = snapshot.lock().unwrap().clone();
                let rec = MonitorRecord {
                    proc: sample_proc(start.elapsed().as_secs_f64()),
                    gpu_util: snap.gpu_util,
                    mem_in_use: snap.mem_in_use,
                    mem_peak: snap.mem_peak,
                    fragmentation: snap.fragmentation,
                    dma_h2d_bytes: snap.dma_h2d_bytes,
                    dma_crypto_s: snap.dma_crypto_s,
                    swaps: snap.swaps,
                };
                records.lock().unwrap().push(rec);
                std::thread::sleep(period);
            }
        })
    };

    // ---------------- scheduler loop ------------------------------------
    let mut queues = ModelQueues::new();
    let mut rates = RateEstimator::default();
    let mut swap_mgr = SwapManager::new();
    let mut sla = SlaTracker::new(cfg.sla_s);
    let mut recorder = Recorder::new();
    // EWMA of observed exec time per model (SelectBatch headroom term)
    let mut exec_est: std::collections::HashMap<String, f64> =
        Default::default();
    let mut ingest_open = true;
    let mut last_complete_s = 0.0f64;
    // instant of the last observable progress (arrival or completion);
    // drives the stall exit for strategies that legitimately strand a
    // sub-OBS remainder (plain best-batch has no timer)
    let mut last_progress_s = 0.0f64;
    // The paper's methodology: arrivals stop at duration_s but the
    // system drains its backlog; drain_s is a safety cap, and the
    // reported runtime extends to the last dispatched response.
    let hard_stop_s = cfg.duration_s + cfg.drain_s;

    loop {
        // drain the ingest channel
        loop {
            match rx.try_recv() {
                Ok(req) => {
                    rates.on_arrival(&req.model, req.arrival_s);
                    last_progress_s = now_s();
                    queues.push(req);
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    ingest_open = false;
                    break;
                }
            }
        }

        let t = now_s();
        // SLA expiry: overdue queued requests are unfulfilled (§III-C3)
        let expired = queues.expire(t, cfg.sla_s);
        if !expired.is_empty() {
            sla.on_unserved(expired.len() as u64);
            last_progress_s = t;
        }
        if t >= hard_stop_s {
            break;
        }
        if !ingest_open && queues.is_empty() {
            break;
        }
        // stall exit: nothing new can arrive and no timer will ever fire
        // for the stranded remainder
        if !ingest_open
            && t - last_progress_s > cfg.timeout_s() + 5.0 * cfg.sla_s
        {
            break;
        }

        // strategy snapshot
        let views: Vec<ModelView> = queues.nonempty_models().iter()
            .map(|m| {
                let entry = registry.entry(m).unwrap();
                ModelView {
                    model: m.to_string(),
                    len: queues.len(m),
                    oldest_wait_s: queues.head_arrival_s(m)
                        .map(|a| (t - a).max(0.0)).unwrap_or(0.0),
                    obs: entry.obs,
                    rate_rps: rates.rate_rps(m, t),
                    est_load_s: SwapManager::estimate_load_s(&gpu, registry,
                                                             m),
                    est_exec_s: *exec_est.get(*m).unwrap_or(&0.2),
                }
            }).collect();
        let ctx = SchedContext {
            now_s: t,
            resident: swap_mgr.resident().map(|s| s.to_string()),
            queues: views,
            sla_s: cfg.sla_s,
            timeout_s: cfg.timeout_s(),
        };

        match strategy.decide(&ctx) {
            Decision::Wait => {
                publish_snapshot(&snapshot, &gpu, swap_mgr.stats());
                std::thread::sleep(cfg.tick);
            }
            Decision::Process { model, take } => {
                // 1. residency (the expensive CC-sensitive step)
                let swap = swap_mgr.ensure_resident(&mut gpu, registry,
                                                    &model)?;
                // 2. batch assembly + workspace reservation
                let Some(batch) = batcher::prepare(&mut queues, &mut gpu,
                                                   registry, &model, take)?
                else {
                    continue;
                };
                // 3. request payload in (CC seals it)
                let io_start = Instant::now();
                let in_bytes: Vec<u8> = batch.requests.iter()
                    .flat_map(|r| r.tokens.iter()
                              .flat_map(|t| t.to_le_bytes()))
                    .collect();
                gpu.io_transfer(Dir::HostToDevice, &in_bytes)?;
                let mut io_s = io_start.elapsed().as_secs_f64();

                // 4. execute
                let rows: Vec<Vec<i32>> = batch.requests.iter()
                    .map(|r| r.tokens.clone()).collect();
                let exec_start_s = now_s();
                let rep = registry.execute(&model, &rows)?;
                gpu.record_compute(rep.elapsed);

                // 5. response payload out
                let io_start = Instant::now();
                let out_bytes: Vec<u8> = rep.tokens.iter()
                    .flat_map(|row| row.iter()
                              .flat_map(|t| t.to_le_bytes()))
                    .collect();
                gpu.io_transfer(Dir::DeviceToHost, &out_bytes)?;
                io_s += io_start.elapsed().as_secs_f64();

                // 6. bookkeeping
                let complete_s = now_s();
                last_complete_s = complete_s;
                last_progress_s = complete_s;
                let exec_s = rep.elapsed.as_secs_f64();
                let e = exec_est.entry(model.clone()).or_insert(exec_s);
                *e = 0.3 * exec_s + 0.7 * *e;

                let n_rows = batch.requests.len();
                let requests = batcher::release(&mut gpu, batch);
                for r in requests {
                    let c = CompletedRequest {
                        id: r.id,
                        model: r.model,
                        arrival_s: r.arrival_s,
                        exec_start_s,
                        complete_s,
                        batch: rep.batch,
                        batch_rows: n_rows,
                        caused_swap: swap.swapped,
                    };
                    let met = sla.on_complete(&c);
                    recorder.on_complete(c, met);
                }
                recorder.on_batch(BatchRecord {
                    at_s: exec_start_s,
                    model,
                    rows: n_rows,
                    artifact_batch: rep.batch,
                    swapped: swap.swapped,
                    load_s: swap.load_s,
                    unload_s: swap.unload_s,
                    exec_s,
                    io_s,
                });
                publish_snapshot(&snapshot, &gpu, swap_mgr.stats());
            }
        }
    }

    // ---------------- teardown ------------------------------------------
    stop.store(true, Ordering::Relaxed);
    drop(rx);
    // paper runtime: generation window + drain tail to last response
    let runtime_s = last_complete_s.max(cfg.duration_s);
    let unserved = queues.drain_all();
    sla.on_unserved(unserved.len() as u64);
    ingest.join().ok();
    monitor.join().ok();
    swap_mgr.evict(&mut gpu);

    for m in monitor_records.lock().unwrap().drain(..) {
        recorder.on_monitor(m);
    }

    // ---------------- summary -------------------------------------------
    let stats = swap_mgr.stats().clone();
    let summary = summarize(cfg, generated, runtime_s, &recorder, &sla,
                            &gpu, &stats);
    if let Some(dir) = &cfg.results_dir {
        recorder.write_csvs(dir, &cfg.label)?;
        std::fs::write(dir.join(format!("{}_summary.json", cfg.label)),
                       summary.to_json().to_string())?;
    }
    Ok((summary, recorder))
}

fn publish_snapshot(snapshot: &Arc<Mutex<DeviceSnapshot>>, gpu: &SimGpu,
                    swap_stats: &SwapStats) {
    let mut s = snapshot.lock().unwrap();
    s.gpu_util = gpu.utilization();
    s.mem_in_use = gpu.mem_in_use();
    s.mem_peak = gpu.mem_peak();
    s.fragmentation = gpu.mem_fragmentation();
    s.dma_h2d_bytes = gpu.dma_stats().h2d_bytes;
    s.dma_crypto_s = gpu.dma_stats().crypto.as_secs_f64();
    s.swaps = swap_stats.swap_count;
}

fn summarize(cfg: &RunConfig, generated: u64, runtime_s: f64,
             recorder: &Recorder, sla: &SlaTracker, gpu: &SimGpu,
             swap_stats: &SwapStats) -> RunSummary {
    let h = &recorder.latency_hist;
    let completed = recorder.requests.len() as u64;
    let exec_busy = recorder.exec_busy_s();
    RunSummary {
        label: cfg.label.clone(),
        mode: cfg.mode.as_str().to_string(),
        pattern: cfg.pattern.clone(),
        strategy: cfg.strategy.clone(),
        sla_s: cfg.sla_s,
        mean_rps: cfg.mean_rps,
        duration_s: cfg.duration_s,
        runtime_s,
        generated,
        completed,
        sla_met: sla.met(),
        sla_attainment: sla.attainment(),
        latency_mean_s: h.mean(),
        latency_p50_s: h.quantile(0.5),
        latency_p90_s: h.quantile(0.9),
        latency_p99_s: h.quantile(0.99),
        latency_max_s: h.max(),
        throughput_rps: if runtime_s > 0.0 {
            completed as f64 / runtime_s
        } else {
            0.0
        },
        processing_rate_rps: if exec_busy > 0.0 {
            completed as f64 / exec_busy
        } else {
            0.0
        },
        // utilization over the reported runtime (exec share of the run,
        // Fig 7's metric); gpu.utilization() covers device lifetime and
        // feeds the monitor CSV instead
        gpu_util: if runtime_s > 0.0 {
            (exec_busy / runtime_s).min(1.0)
        } else {
            gpu.utilization()
        },
        swap_count: swap_stats.swap_count,
        total_load_s: swap_stats.total_load_s,
        total_unload_s: swap_stats.total_unload_s,
        total_exec_s: exec_busy,
        total_crypto_s: swap_stats.total_crypto_s,
        mean_load_s: if swap_stats.swap_count > 0 {
            swap_stats.total_load_s / swap_stats.swap_count as f64
        } else {
            0.0
        },
    }
}
