//! The real serve entry point — a thin shim over the [`Engine`].
//!
//! The serve loop itself (ingest → queues → strategy → swap → execute
//! → record, §III-B) lives in [`crate::engine`], written once and
//! parameterized by `Clock` and `ExecBackend`.  This module keeps the
//! historical `coordinator::serve` API (and re-exports [`RunSummary`])
//! for existing callers; new code should use
//! [`EngineBuilder`](crate::engine::EngineBuilder) directly.
//!
//! [`Engine`]: crate::engine::Engine

use crate::config::RunConfig;
use crate::engine::EngineBuilder;
use crate::metrics::recorder::Recorder;
use crate::runtime::Registry;

pub use crate::engine::RunSummary;

/// Run one serving experiment for real: wall clock, `SimGpu`, PJRT
/// execution.  The registry is shared across runs (so XLA compiles
/// once per process); OBS values should already be set.
#[deprecated(
    since = "0.2.0",
    note = "use engine::EngineBuilder::new(cfg).real(registry)?.run()"
)]
pub fn serve(cfg: &RunConfig, registry: &Registry)
             -> anyhow::Result<(RunSummary, Recorder)> {
    EngineBuilder::new(cfg).real(registry)?.run()
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// The deprecated shim must stay behaviourally identical to the
    /// builder path (one release of compatibility).
    #[test]
    fn shim_matches_engine_builder() {
        let manifest = Manifest::load(&artifacts_dir()).unwrap();
        let registry = Registry::load(
            &manifest, &["llama-sim".to_string()], &[1, 2, 4]).unwrap();
        let mut cfg = RunConfig {
            duration_s: 2.0,
            drain_s: 2.0,
            mean_rps: 3.0,
            sla_s: 3.0,
            models: vec!["llama-sim".into()],
            ..RunConfig::default()
        };
        cfg.gpu.no_throttle = true;
        let (a, _) = serve(&cfg, &registry).unwrap();
        let (b, _) = EngineBuilder::new(&cfg).real(&registry).unwrap()
            .run().unwrap();
        assert_eq!(a.generated, b.generated,
                   "same seed, same schedule through both entry points");
        assert_eq!(a.mode, b.mode);
        assert_eq!(a.strategy, b.strategy);
    }
}
