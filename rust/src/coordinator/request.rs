//! Request lifecycle types.
//!
//! Latency is defined exactly as in §IV-A: "the time elapsed from when
//! a request is sent by the user until it is dispatched by the server
//! after completing inference".
//!
//! Requests carry an interned [`ModelId`] rather than a name: ingest
//! resolves the name once against the run's
//! [`ModelTable`](crate::runtime::ModelTable), and everything
//! downstream — queues, strategies, placement, swap accounting — moves
//! a `u32` instead of cloning a `String` per hop.

use crate::runtime::ModelId;

/// An inference request, tokenized at ingest.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Target model family, interned against the run's table.
    pub model: ModelId,
    /// Tokenized prompt, exactly `prompt_len` ids.
    pub tokens: Vec<i32>,
    /// Arrival time, seconds since experiment start.
    pub arrival_s: f64,
    /// Tenant SLA class (`tenancy::CLASS_NAMES` index, 0 = gold).
    /// Always 0 when `--sla-classes` is off, so pre-tenancy behavior
    /// is unchanged.
    pub class: u8,
}

/// A finished request with its measured timeline.
#[derive(Debug, Clone)]
pub struct CompletedRequest {
    pub id: u64,
    pub model: ModelId,
    pub arrival_s: f64,
    /// When the batch containing it started executing.
    pub exec_start_s: f64,
    /// When inference finished and the response was dispatched.
    pub complete_s: f64,
    /// Artifact batch size it rode in.
    pub batch: usize,
    /// Real rows in that batch (<= batch).
    pub batch_rows: usize,
    /// Whether the batch required a model swap first.
    pub caused_swap: bool,
    /// Fleet device the batch executed on.
    pub device: usize,
}

impl CompletedRequest {
    /// End-to-end latency (the paper's latency metric).
    pub fn latency_s(&self) -> f64 {
        self.complete_s - self.arrival_s
    }

    /// Time spent queued before execution began.
    pub fn queue_wait_s(&self) -> f64 {
        self.exec_start_s - self.arrival_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_accounting() {
        let c = CompletedRequest {
            id: 1,
            model: ModelId(0),
            arrival_s: 10.0,
            exec_start_s: 12.5,
            complete_s: 13.0,
            batch: 8,
            batch_rows: 5,
            caused_swap: true,
            device: 0,
        };
        assert!((c.latency_s() - 3.0).abs() < 1e-12);
        assert!((c.queue_wait_s() - 2.5).abs() < 1e-12);
    }
}
