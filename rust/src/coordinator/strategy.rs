//! Scheduling strategies (§III-C4, Table I).
//!
//! The four strategies compose three *plans*:
//!
//! * **Best Batch** — wait until a model's queue holds OBS requests.
//! * **Timer** — a maximum wait: once the head request has waited
//!   `timeout_s`, its batch is processed immediately at whatever size.
//! * **Partial Batch** — before swapping away, drain the resident
//!   model's incomplete batch.
//! * **Select Batch** — size batches dynamically from the arrival-rate
//!   estimate and the SLA headroom:
//!   `batch_size < arrival_rate × desired_latency`, where
//!   `desired_latency = SLA − est_load − est_exec` (§III-C4).
//!
//! Strategies are pure decision functions over a [`SchedContext`]
//! snapshot, which makes them unit-testable and reusable verbatim by the
//! discrete-event simulator.  On an N-device fleet the snapshot carries
//! one [`DeviceView`] per device; strategies pick *what* to run (model +
//! batch size) and normally leave *where* (`Decision::Process::device`)
//! to the placement policy (`coordinator::placement`) — only the
//! Partial Batch drain pins its device, because "the resident model"
//! is a per-device notion.
//!
//! Views carry interned [`ModelId`]s, so a strategy decision moves
//! `u32`s, never clones a name; because the intern table is sorted,
//! `ModelId` comparisons order exactly like the names they stand for.
//!
//! The strategy table ([`STRATEGIES`]) is the single source of truth
//! for lookup, `--help`, and the unknown-name error message, so CLI
//! docs and errors cannot drift.

use crate::gpu::CcMode;
use crate::runtime::ModelId;

/// Scheduler-visible state of one fleet device.
#[derive(Debug, Clone)]
pub struct DeviceView {
    /// Device id (index into the fleet).
    pub id: usize,
    /// The device's confidential-computing mode.
    pub mode: CcMode,
    /// Model currently resident on this device, if any.
    pub resident: Option<ModelId>,
    /// True while a previously dispatched batch is still executing
    /// (virtual time); busy devices cannot take new work.
    pub busy: bool,
    /// Cumulative seconds this device has spent swapping + executing.
    pub busy_s: f64,
    /// Batches dispatched to this device so far.
    pub dispatched: u64,
}

/// Scheduler-visible state of one model queue.
#[derive(Debug, Clone)]
pub struct ModelView {
    pub model: ModelId,
    /// Queued requests.
    pub len: usize,
    /// Seconds the head (oldest) request has waited.
    pub oldest_wait_s: f64,
    /// Profiled optimal batch size (§III-D2).
    pub obs: usize,
    /// Estimated arrival rate, req/s (0 when unknown).
    pub rate_rps: f64,
    /// Estimated model load time on the most favourable free device,
    /// seconds.
    pub est_load_s: f64,
    /// Estimated batch execution time at OBS, seconds.
    pub est_exec_s: f64,
}

/// Snapshot handed to a strategy each scheduling tick.
///
/// The `devices` and `queues` vectors are built into caller-pooled
/// buffers each tick (see `engine::build_views_into`), so the
/// steady-state loop reuses their capacity instead of allocating.
#[derive(Debug, Clone, Default)]
pub struct SchedContext {
    pub now_s: f64,
    /// One view per fleet device (a single entry on the paper's
    /// one-GPU system).
    pub devices: Vec<DeviceView>,
    /// Non-empty queues only.
    pub queues: Vec<ModelView>,
    /// The experiment SLA, seconds.
    pub sla_s: f64,
    /// Timer plan's maximum wait, seconds.
    pub timeout_s: f64,
}

impl SchedContext {
    /// Devices that can take a batch right now.
    pub fn free_devices(&self) -> impl Iterator<Item = &DeviceView> {
        self.devices.iter().filter(|d| !d.busy)
    }

    /// Id of a free device where `model` is already resident
    /// (dispatching there avoids a swap).
    pub fn resident_on_free(&self, model: ModelId) -> Option<usize> {
        self.free_devices()
            .find(|d| d.resident == Some(model))
            .map(|d| d.id)
    }

    /// Models resident on free devices, in device-id order.
    pub fn free_residents(&self) -> impl Iterator<Item = ModelId> + '_ {
        self.free_devices().filter_map(|d| d.resident)
    }
}

/// What to do this tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Nothing is ready; sleep a tick.
    Wait,
    /// Dispatch up to `take` requests from `model`'s queue.  `device`
    /// pins a fleet device; `None` delegates to the placement policy.
    Process { model: ModelId, take: usize, device: Option<usize> },
}

/// A scheduling strategy (Table I row).
pub trait Strategy: Send {
    fn name(&self) -> &'static str;
    fn decide(&self, ctx: &SchedContext) -> Decision;

    /// Next-model hint for predictive prefetch: the model this strategy
    /// is most likely to dispatch after `chosen`, or `None` to skip
    /// staging.  The default mirrors the timer guarantee every Table I
    /// strategy shares — the longest-waiting other queue — which is
    /// also deterministic, as the DES-vs-real parity contract requires
    /// (see `coordinator::prefetch`).
    fn next_hint(&self, ctx: &SchedContext, chosen: ModelId)
                 -> Option<ModelId> {
        crate::coordinator::prefetch::predict_next(ctx, chosen)
    }
}

/// One Table I strategy: CLI name + constructor.
pub struct StrategyEntry {
    pub name: &'static str,
    pub make: fn() -> Box<dyn Strategy>,
}

fn make_best_batch() -> Box<dyn Strategy> {
    Box::new(BestBatch)
}

fn make_best_batch_timer() -> Box<dyn Strategy> {
    Box::new(BestBatchTimer)
}

fn make_select_batch_timer() -> Box<dyn Strategy> {
    Box::new(SelectBatchTimer)
}

fn make_best_batch_partial_timer() -> Box<dyn Strategy> {
    Box::new(BestBatchPartialTimer::default())
}

/// The strategy table — drives `strategy_by_name`, `--help`, and the
/// unknown-name error, so the three cannot drift.
pub const STRATEGIES: &[StrategyEntry] = &[
    StrategyEntry { name: "best-batch",
                    make: make_best_batch },
    StrategyEntry { name: "best-batch+timer",
                    make: make_best_batch_timer },
    StrategyEntry { name: "select-batch+timer",
                    make: make_select_batch_timer },
    StrategyEntry { name: "best-batch+partial+timer",
                    make: make_best_batch_partial_timer },
];

/// Valid strategy names, in table order.
pub fn strategy_names() -> Vec<&'static str> {
    STRATEGIES.iter().map(|e| e.name).collect()
}

/// Instantiate a strategy by CLI name.
pub fn strategy_by_name(name: &str) -> anyhow::Result<Box<dyn Strategy>> {
    STRATEGIES.iter().find(|e| e.name == name).map(|e| (e.make)())
        .ok_or_else(|| anyhow::anyhow!(
            "unknown strategy {name:?} (have {:?})", strategy_names()))
}

// ---------------------------------------------------------------- helpers

/// Among *ready* (not overdue) candidates, prefer a model already
/// resident on a free device — avoiding a swap is free throughput —
/// then the longest-waiting head.
fn pick_ready<'a>(ctx: &'a SchedContext, candidates: &[&'a ModelView])
                  -> Option<&'a ModelView> {
    if let Some(v) = candidates.iter()
        .find(|v| ctx.resident_on_free(v.model).is_some())
    {
        return Some(v);
    }
    pick_oldest(candidates)
}

/// Among *overdue* candidates the timer guarantee rules: strict
/// longest-wait-first, no resident preference.  (With a resident
/// preference here, a saturated resident queue — always overdue — would
/// starve every other model forever; the Partial Batch plan is the
/// paper's sanctioned way to favour the resident.)
fn pick_oldest<'a>(candidates: &[&'a ModelView]) -> Option<&'a ModelView> {
    candidates.iter()
        .max_by(|a, b| a.oldest_wait_s.partial_cmp(&b.oldest_wait_s)
                .unwrap())
        .copied()
}

// ------------------------------------------------------------- strategies

/// Plan 1: "Best Batch — waits until the number of requests in a batch
/// matches the OBS for the corresponding model."  The paper's baseline.
pub struct BestBatch;

impl Strategy for BestBatch {
    fn name(&self) -> &'static str {
        "best-batch"
    }

    fn decide(&self, ctx: &SchedContext) -> Decision {
        let full: Vec<&ModelView> =
            ctx.queues.iter().filter(|v| v.len >= v.obs).collect();
        match pick_ready(ctx, &full) {
            Some(v) => Decision::Process { model: v.model,
                                           take: v.obs, device: None },
            None => Decision::Wait,
        }
    }
}

/// Strategy 2: Best Batch + Timer — full-OBS batches, but the timer
/// forces any over-age batch out immediately (§III-C4 Timer plan).
pub struct BestBatchTimer;

impl Strategy for BestBatchTimer {
    fn name(&self) -> &'static str {
        "best-batch+timer"
    }

    fn decide(&self, ctx: &SchedContext) -> Decision {
        // timer overrides: any queue whose head exceeded the timeout
        let overdue: Vec<&ModelView> = ctx.queues.iter()
            .filter(|v| v.oldest_wait_s >= ctx.timeout_s).collect();
        if let Some(v) = pick_oldest(&overdue) {
            return Decision::Process { model: v.model,
                                       take: v.len.min(v.obs),
                                       device: None };
        }
        BestBatch.decide(ctx)
    }
}

/// Strategy 3: Select Batch + Timer — dynamic batch sizing from the
/// arrival-rate estimate and SLA headroom; smaller, more frequent
/// batches (the paper's latency/SLA winner).
pub struct SelectBatchTimer;

impl SelectBatchTimer {
    /// Minimum SLA headroom fraction.  The paper's formula assumes
    /// `load + exec << SLA` (their loads are 12–25% of the SLA); when a
    /// pathological cell leaves no headroom the rule would degenerate to
    /// batch-1 thrashing, so we floor the headroom — beyond the floor
    /// the SLA is infeasible anyway and throughput is all that's left.
    const MIN_HEADROOM_FRAC: f64 = 0.25;

    /// The paper's sizing rule: batch_size < arrival_rate ×
    /// desired_latency, where desired_latency = SLA − est_load −
    /// est_exec, clamped to [1, OBS].
    pub fn target_batch(v: &ModelView, sla_s: f64) -> usize {
        let desired_latency = (sla_s - v.est_load_s - v.est_exec_s)
            .max(Self::MIN_HEADROOM_FRAC * sla_s);
        let sized = (v.rate_rps * desired_latency).floor() as usize;
        sized.clamp(1, v.obs)
    }
}

impl Strategy for SelectBatchTimer {
    fn name(&self) -> &'static str {
        "select-batch+timer"
    }

    fn decide(&self, ctx: &SchedContext) -> Decision {
        let overdue: Vec<&ModelView> = ctx.queues.iter()
            .filter(|v| v.oldest_wait_s >= ctx.timeout_s).collect();
        if let Some(v) = pick_oldest(&overdue) {
            let target = Self::target_batch(v, ctx.sla_s);
            return Decision::Process { model: v.model,
                                       take: v.len.min(target),
                                       device: None };
        }
        let ready: Vec<&ModelView> = ctx.queues.iter()
            .filter(|v| v.len >= Self::target_batch(v, ctx.sla_s))
            .collect();
        match pick_ready(ctx, &ready) {
            Some(v) => {
                let target = Self::target_batch(v, ctx.sla_s);
                Decision::Process { model: v.model,
                                    take: v.len.min(target),
                                    device: None }
            }
            None => Decision::Wait,
        }
    }
}

/// Strategy 4: Best Batch + Partial Batch + Timer — before a decision
/// would swap a device to another model, drain a resident model's
/// incomplete batch first ("always processes incomplete batches for the
/// currently loaded model before switching", §III-C4).
///
/// The drain happens at most ONCE per residency: with open-loop
/// arrivals the resident queue refills during the drain itself, and an
/// unconditional rule would pin the resident forever, starving every
/// other model (observed: 3 swaps per minute-long run, two models
/// expiring wholesale).  One final batch before the swap is the paper's
/// stated intent ("aiming to increase throughput while minimizing
/// swaps") without the livelock.  The drain pins its device — "the
/// resident" is a per-device notion on a fleet, and each free-device
/// resident gets at most one drain per imminent swap (a single shared
/// slot would let two residents ping-pong drains forever, starving the
/// incoming model).  The drain ledger clears when the swap finally
/// goes through; a residency that survives the swap (placement routed
/// it to another device) regains drain eligibility, which is the
/// conservative direction — one extra final batch, never a lost one.
pub struct BestBatchPartialTimer {
    /// Residencies already granted their final drain, cleared when the
    /// swap goes through.
    drained: std::cell::RefCell<std::collections::HashSet<ModelId>>,
}

impl Default for BestBatchPartialTimer {
    fn default() -> Self {
        BestBatchPartialTimer {
            drained: std::cell::RefCell::new(
                std::collections::HashSet::new()),
        }
    }
}

impl Strategy for BestBatchPartialTimer {
    fn name(&self) -> &'static str {
        "best-batch+partial+timer"
    }

    fn decide(&self, ctx: &SchedContext) -> Decision {
        let inner = BestBatchTimer.decide(ctx);
        if let Decision::Process { model, .. } = inner {
            if ctx.resident_on_free(model).is_none() {
                // a swap is imminent: drain one free-device resident
                // with queued work, once per residency
                for res in ctx.free_residents() {
                    if self.drained.borrow().contains(&res) {
                        continue;
                    }
                    if let Some(v) = ctx.queues.iter()
                        .find(|v| v.model == res && v.len > 0)
                    {
                        self.drained.borrow_mut().insert(res);
                        return Decision::Process {
                            model: res,
                            take: v.len.min(v.obs),
                            device: ctx.resident_on_free(res),
                        };
                    }
                }
                // every resident had its final batch: the swap goes
                // through and the next residencies drain afresh
                self.drained.borrow_mut().clear();
            }
        }
        inner
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Sorted-table ids for a two-model test fleet ("a" < "b").
    const A: ModelId = ModelId(0);
    const B: ModelId = ModelId(1);

    fn device(id: usize, resident: Option<ModelId>) -> DeviceView {
        DeviceView {
            id,
            mode: CcMode::Off,
            resident,
            busy: false,
            busy_s: 0.0,
            dispatched: 0,
        }
    }

    fn view(model: ModelId, len: usize, wait: f64) -> ModelView {
        ModelView {
            model,
            len,
            oldest_wait_s: wait,
            obs: 8,
            rate_rps: 2.0,
            est_load_s: 0.5,
            est_exec_s: 0.5,
        }
    }

    fn ctx(resident: Option<ModelId>, queues: Vec<ModelView>)
           -> SchedContext {
        SchedContext {
            now_s: 100.0,
            devices: vec![device(0, resident)],
            queues,
            sla_s: 6.0,
            timeout_s: 3.0,
        }
    }

    fn process(model: ModelId, take: usize) -> Decision {
        Decision::Process { model, take, device: None }
    }

    #[test]
    fn best_batch_waits_below_obs() {
        let c = ctx(None, vec![view(A, 7, 10.0)]);
        assert_eq!(BestBatch.decide(&c), Decision::Wait);
    }

    #[test]
    fn best_batch_fires_at_obs() {
        let c = ctx(None, vec![view(A, 8, 0.1)]);
        assert_eq!(BestBatch.decide(&c), process(A, 8));
    }

    #[test]
    fn best_batch_prefers_resident_on_tie() {
        let c = ctx(Some(B), vec![view(A, 9, 5.0), view(B, 8, 1.0)]);
        assert_eq!(BestBatch.decide(&c), process(B, 8));
    }

    #[test]
    fn busy_device_residency_does_not_count() {
        // B is resident only on a busy device: the swap-avoidance
        // preference must ignore it and pick the older head instead
        let mut c = ctx(Some(B), vec![view(A, 9, 5.0),
                                      view(B, 8, 1.0)]);
        c.devices[0].busy = true;
        c.devices.push(device(1, None));
        assert_eq!(BestBatch.decide(&c), process(A, 8));
    }

    #[test]
    fn timer_forces_partial_batch() {
        let c = ctx(None, vec![view(A, 3, 3.5)]);
        assert_eq!(BestBatchTimer.decide(&c), process(A, 3));
    }

    #[test]
    fn timer_respects_obs_cap() {
        let mut v = view(A, 20, 4.0);
        v.obs = 8;
        let c = ctx(None, vec![v]);
        assert_eq!(BestBatchTimer.decide(&c), process(A, 8));
    }

    #[test]
    fn timer_falls_back_to_best_batch() {
        let c = ctx(None, vec![view(A, 8, 0.5)]);
        assert_eq!(BestBatchTimer.decide(&c), process(A, 8));
    }

    #[test]
    fn select_batch_sizes_from_rate_and_headroom() {
        // rate 2 rps, desired latency = 6 - 0.5 - 0.5 = 5 -> target 10,
        // clamped to obs 8
        let v = view(A, 12, 0.1);
        assert_eq!(SelectBatchTimer::target_batch(&v, 6.0), 8);
        // tighter SLA 2.0 -> desired 1.0 -> target 2
        assert_eq!(SelectBatchTimer::target_batch(&v, 2.0), 2);
        // rate unknown -> clamp to 1 (process singly, don't starve)
        let mut v0 = v.clone();
        v0.rate_rps = 0.0;
        assert_eq!(SelectBatchTimer::target_batch(&v0, 6.0), 1);
    }

    #[test]
    fn select_batch_invariant_never_exceeds_rate_times_latency() {
        // property: target <= max(1, rate * (sla - load - exec))
        crate::util::prop::forall("select-batch invariant", 300, |g| {
            let v = ModelView {
                model: ModelId(0),
                len: g.usize_in(1, 64),
                oldest_wait_s: g.f64_in(0.0, 10.0),
                obs: g.usize_in(1, 32),
                rate_rps: g.f64_in(0.0, 20.0),
                est_load_s: g.f64_in(0.0, 3.0),
                est_exec_s: g.f64_in(0.0, 3.0),
            };
            let sla = g.f64_in(0.5, 10.0);
            let t = SelectBatchTimer::target_batch(&v, sla);
            let headroom = (sla - v.est_load_s - v.est_exec_s)
                .max(SelectBatchTimer::MIN_HEADROOM_FRAC * sla);
            let bound = (v.rate_rps * headroom).floor().max(1.0) as usize;
            crate::prop_assert!(t <= bound.max(1).min(v.obs.max(1)),
                                "target {t} exceeds bound {bound}");
            crate::prop_assert!(t >= 1, "target must be >= 1");
            Ok(())
        });
    }

    #[test]
    fn select_batch_fires_smaller_batches() {
        // queue of 3 at rate 2 with tight SLA: target 2 -> fire with 3? no:
        // take = min(len, target) = 2
        let mut v = view(A, 3, 0.1);
        v.rate_rps = 2.0;
        let mut c = ctx(None, vec![v]);
        c.sla_s = 2.0; // desired 1.0 -> target 2
        assert_eq!(SelectBatchTimer.decide(&c), process(A, 2));
    }

    #[test]
    fn partial_drains_resident_before_swap() {
        // B is overdue, but resident A still has 2 queued -> drain A
        let c = ctx(Some(A),
                    vec![view(A, 2, 0.5), view(B, 3, 4.0)]);
        assert_eq!(BestBatchPartialTimer::default().decide(&c),
                   Decision::Process { model: A, take: 2,
                                       device: Some(0) });
    }

    #[test]
    fn partial_swaps_once_resident_is_drained() {
        let c = ctx(Some(A), vec![view(B, 3, 4.0)]);
        assert_eq!(BestBatchPartialTimer::default().decide(&c),
                   process(B, 3));
    }

    #[test]
    fn partial_drain_pins_the_residents_device() {
        // resident A lives on device 1 of a 2-device fleet: the drain
        // must target that device, not defer to placement
        let mut c = ctx(None, vec![view(A, 2, 0.5), view(B, 3, 4.0)]);
        c.devices.push(device(1, Some(A)));
        assert_eq!(BestBatchPartialTimer::default().decide(&c),
                   Decision::Process { model: A, take: 2,
                                       device: Some(1) });
    }

    #[test]
    fn all_strategies_wait_on_empty() {
        let c = ctx(Some(A), vec![]);
        for entry in STRATEGIES {
            let s = (entry.make)();
            assert_eq!(s.decide(&c), Decision::Wait, "{}", entry.name);
        }
    }

    #[test]
    fn strategy_names_roundtrip() {
        for name in strategy_names() {
            assert_eq!(strategy_by_name(name).unwrap().name(), name);
        }
        let err = strategy_by_name("fifo").unwrap_err().to_string();
        for name in strategy_names() {
            assert!(err.contains(name),
                    "error message must list {name:?}: {err}");
        }
    }
}
