//! Scheduling strategies (§III-C4, Table I).
//!
//! The four strategies compose three *plans*:
//!
//! * **Best Batch** — wait until a model's queue holds OBS requests.
//! * **Timer** — a maximum wait: once the head request has waited
//!   `timeout_s`, its batch is processed immediately at whatever size.
//! * **Partial Batch** — before swapping away, drain the resident
//!   model's incomplete batch.
//! * **Select Batch** — size batches dynamically from the arrival-rate
//!   estimate and the SLA headroom:
//!   `batch_size < arrival_rate × desired_latency`, where
//!   `desired_latency = SLA − est_load − est_exec` (§III-C4).
//!
//! Strategies are pure decision functions over a [`SchedContext`]
//! snapshot, which makes them unit-testable and reusable verbatim by the
//! discrete-event simulator.

/// Scheduler-visible state of one model queue.
#[derive(Debug, Clone)]
pub struct ModelView {
    pub model: String,
    /// Queued requests.
    pub len: usize,
    /// Seconds the head (oldest) request has waited.
    pub oldest_wait_s: f64,
    /// Profiled optimal batch size (§III-D2).
    pub obs: usize,
    /// Estimated arrival rate, req/s (0 when unknown).
    pub rate_rps: f64,
    /// Estimated model load time in the current CC mode, seconds.
    pub est_load_s: f64,
    /// Estimated batch execution time at OBS, seconds.
    pub est_exec_s: f64,
}

/// Snapshot handed to a strategy each scheduling tick.
#[derive(Debug, Clone)]
pub struct SchedContext {
    pub now_s: f64,
    /// Currently resident model, if any.
    pub resident: Option<String>,
    /// Non-empty queues only.
    pub queues: Vec<ModelView>,
    /// The experiment SLA, seconds.
    pub sla_s: f64,
    /// Timer plan's maximum wait, seconds.
    pub timeout_s: f64,
}

/// What to do this tick.
#[derive(Debug, Clone, PartialEq)]
pub enum Decision {
    /// Nothing is ready; sleep a tick.
    Wait,
    /// Dispatch up to `take` requests from `model`'s queue.
    Process { model: String, take: usize },
}

/// A scheduling strategy (Table I row).
pub trait Strategy: Send {
    fn name(&self) -> &'static str;
    fn decide(&self, ctx: &SchedContext) -> Decision;
}

pub const STRATEGY_NAMES: &[&str] = &[
    "best-batch",
    "best-batch+timer",
    "select-batch+timer",
    "best-batch+partial+timer",
];

/// Instantiate a strategy by CLI name.
pub fn strategy_by_name(name: &str) -> anyhow::Result<Box<dyn Strategy>> {
    match name {
        "best-batch" => Ok(Box::new(BestBatch)),
        "best-batch+timer" => Ok(Box::new(BestBatchTimer)),
        "select-batch+timer" => Ok(Box::new(SelectBatchTimer)),
        "best-batch+partial+timer" =>
            Ok(Box::new(BestBatchPartialTimer::default())),
        other => anyhow::bail!(
            "unknown strategy {other:?} (have {STRATEGY_NAMES:?})"),
    }
}

// ---------------------------------------------------------------- helpers

/// Among *ready* (not overdue) candidates, prefer the resident model —
/// avoiding a swap is free throughput — then the longest-waiting head.
fn pick_ready<'a>(ctx: &'a SchedContext, candidates: &[&'a ModelView])
                  -> Option<&'a ModelView> {
    if let Some(res) = &ctx.resident {
        if let Some(v) = candidates.iter().find(|v| &v.model == res) {
            return Some(v);
        }
    }
    pick_oldest(candidates)
}

/// Among *overdue* candidates the timer guarantee rules: strict
/// longest-wait-first, no resident preference.  (With a resident
/// preference here, a saturated resident queue — always overdue — would
/// starve every other model forever; the Partial Batch plan is the
/// paper's sanctioned way to favour the resident.)
fn pick_oldest<'a>(candidates: &[&'a ModelView]) -> Option<&'a ModelView> {
    candidates.iter()
        .max_by(|a, b| a.oldest_wait_s.partial_cmp(&b.oldest_wait_s)
                .unwrap())
        .copied()
}

// ------------------------------------------------------------- strategies

/// Plan 1: "Best Batch — waits until the number of requests in a batch
/// matches the OBS for the corresponding model."  The paper's baseline.
pub struct BestBatch;

impl Strategy for BestBatch {
    fn name(&self) -> &'static str {
        "best-batch"
    }

    fn decide(&self, ctx: &SchedContext) -> Decision {
        let full: Vec<&ModelView> =
            ctx.queues.iter().filter(|v| v.len >= v.obs).collect();
        match pick_ready(ctx, &full) {
            Some(v) => Decision::Process { model: v.model.clone(),
                                           take: v.obs },
            None => Decision::Wait,
        }
    }
}

/// Strategy 2: Best Batch + Timer — full-OBS batches, but the timer
/// forces any over-age batch out immediately (§III-C4 Timer plan).
pub struct BestBatchTimer;

impl Strategy for BestBatchTimer {
    fn name(&self) -> &'static str {
        "best-batch+timer"
    }

    fn decide(&self, ctx: &SchedContext) -> Decision {
        // timer overrides: any queue whose head exceeded the timeout
        let overdue: Vec<&ModelView> = ctx.queues.iter()
            .filter(|v| v.oldest_wait_s >= ctx.timeout_s).collect();
        if let Some(v) = pick_oldest(&overdue) {
            return Decision::Process { model: v.model.clone(),
                                       take: v.len.min(v.obs) };
        }
        BestBatch.decide(ctx)
    }
}

/// Strategy 3: Select Batch + Timer — dynamic batch sizing from the
/// arrival-rate estimate and SLA headroom; smaller, more frequent
/// batches (the paper's latency/SLA winner).
pub struct SelectBatchTimer;

impl SelectBatchTimer {
    /// Minimum SLA headroom fraction.  The paper's formula assumes
    /// `load + exec << SLA` (their loads are 12–25% of the SLA); when a
    /// pathological cell leaves no headroom the rule would degenerate to
    /// batch-1 thrashing, so we floor the headroom — beyond the floor
    /// the SLA is infeasible anyway and throughput is all that's left.
    const MIN_HEADROOM_FRAC: f64 = 0.25;

    /// The paper's sizing rule: batch_size < arrival_rate ×
    /// desired_latency, where desired_latency = SLA − est_load −
    /// est_exec, clamped to [1, OBS].
    pub fn target_batch(v: &ModelView, sla_s: f64) -> usize {
        let desired_latency = (sla_s - v.est_load_s - v.est_exec_s)
            .max(Self::MIN_HEADROOM_FRAC * sla_s);
        let sized = (v.rate_rps * desired_latency).floor() as usize;
        sized.clamp(1, v.obs)
    }
}

impl Strategy for SelectBatchTimer {
    fn name(&self) -> &'static str {
        "select-batch+timer"
    }

    fn decide(&self, ctx: &SchedContext) -> Decision {
        let overdue: Vec<&ModelView> = ctx.queues.iter()
            .filter(|v| v.oldest_wait_s >= ctx.timeout_s).collect();
        if let Some(v) = pick_oldest(&overdue) {
            let target = Self::target_batch(v, ctx.sla_s);
            return Decision::Process { model: v.model.clone(),
                                       take: v.len.min(target) };
        }
        let ready: Vec<&ModelView> = ctx.queues.iter()
            .filter(|v| v.len >= Self::target_batch(v, ctx.sla_s))
            .collect();
        match pick_ready(ctx, &ready) {
            Some(v) => {
                let target = Self::target_batch(v, ctx.sla_s);
                Decision::Process { model: v.model.clone(),
                                    take: v.len.min(target) }
            }
            None => Decision::Wait,
        }
    }
}

/// Strategy 4: Best Batch + Partial Batch + Timer — before a decision
/// would swap to another model, drain the resident model's incomplete
/// batch first ("always processes incomplete batches for the currently
/// loaded model before switching", §III-C4).
///
/// The drain happens at most ONCE per residency: with open-loop
/// arrivals the resident queue refills during the drain itself, and an
/// unconditional rule would pin the resident forever, starving every
/// other model (observed: 3 swaps per minute-long run, two models
/// expiring wholesale).  One final batch before the swap is the paper's
/// stated intent ("aiming to increase throughput while minimizing
/// swaps") without the livelock.
pub struct BestBatchPartialTimer {
    /// Residency we already granted a final drain to.
    drained_for: std::cell::RefCell<Option<String>>,
}

impl Default for BestBatchPartialTimer {
    fn default() -> Self {
        BestBatchPartialTimer { drained_for: std::cell::RefCell::new(None) }
    }
}

impl Strategy for BestBatchPartialTimer {
    fn name(&self) -> &'static str {
        "best-batch+partial+timer"
    }

    fn decide(&self, ctx: &SchedContext) -> Decision {
        let inner = BestBatchTimer.decide(ctx);
        if let Decision::Process { model, .. } = &inner {
            if let Some(res) = &ctx.resident {
                if model != res
                    && self.drained_for.borrow().as_deref() != Some(res)
                {
                    // a swap is imminent: drain the resident once
                    if let Some(v) = ctx.queues.iter()
                        .find(|v| &v.model == res && v.len > 0)
                    {
                        *self.drained_for.borrow_mut() = Some(res.clone());
                        return Decision::Process {
                            model: res.clone(),
                            take: v.len.min(v.obs),
                        };
                    }
                }
            }
        }
        if let Decision::Process { model, .. } = &inner {
            // the swap goes through: the next residency gets a fresh drain
            if Some(model.as_str()) != ctx.resident.as_deref() {
                *self.drained_for.borrow_mut() = None;
            }
        }
        inner
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(model: &str, len: usize, wait: f64) -> ModelView {
        ModelView {
            model: model.into(),
            len,
            oldest_wait_s: wait,
            obs: 8,
            rate_rps: 2.0,
            est_load_s: 0.5,
            est_exec_s: 0.5,
        }
    }

    fn ctx(resident: Option<&str>, queues: Vec<ModelView>) -> SchedContext {
        SchedContext {
            now_s: 100.0,
            resident: resident.map(|s| s.to_string()),
            queues,
            sla_s: 6.0,
            timeout_s: 3.0,
        }
    }

    #[test]
    fn best_batch_waits_below_obs() {
        let c = ctx(None, vec![view("a", 7, 10.0)]);
        assert_eq!(BestBatch.decide(&c), Decision::Wait);
    }

    #[test]
    fn best_batch_fires_at_obs() {
        let c = ctx(None, vec![view("a", 8, 0.1)]);
        assert_eq!(BestBatch.decide(&c),
                   Decision::Process { model: "a".into(), take: 8 });
    }

    #[test]
    fn best_batch_prefers_resident_on_tie() {
        let c = ctx(Some("b"), vec![view("a", 9, 5.0), view("b", 8, 1.0)]);
        assert_eq!(BestBatch.decide(&c),
                   Decision::Process { model: "b".into(), take: 8 });
    }

    #[test]
    fn timer_forces_partial_batch() {
        let c = ctx(None, vec![view("a", 3, 3.5)]);
        assert_eq!(BestBatchTimer.decide(&c),
                   Decision::Process { model: "a".into(), take: 3 });
    }

    #[test]
    fn timer_respects_obs_cap() {
        let mut v = view("a", 20, 4.0);
        v.obs = 8;
        let c = ctx(None, vec![v]);
        assert_eq!(BestBatchTimer.decide(&c),
                   Decision::Process { model: "a".into(), take: 8 });
    }

    #[test]
    fn timer_falls_back_to_best_batch() {
        let c = ctx(None, vec![view("a", 8, 0.5)]);
        assert_eq!(BestBatchTimer.decide(&c),
                   Decision::Process { model: "a".into(), take: 8 });
    }

    #[test]
    fn select_batch_sizes_from_rate_and_headroom() {
        // rate 2 rps, desired latency = 6 - 0.5 - 0.5 = 5 -> target 10,
        // clamped to obs 8
        let v = view("a", 12, 0.1);
        assert_eq!(SelectBatchTimer::target_batch(&v, 6.0), 8);
        // tighter SLA 2.0 -> desired 1.0 -> target 2
        assert_eq!(SelectBatchTimer::target_batch(&v, 2.0), 2);
        // rate unknown -> clamp to 1 (process singly, don't starve)
        let mut v0 = v.clone();
        v0.rate_rps = 0.0;
        assert_eq!(SelectBatchTimer::target_batch(&v0, 6.0), 1);
    }

    #[test]
    fn select_batch_invariant_never_exceeds_rate_times_latency() {
        // property: target <= max(1, rate * (sla - load - exec))
        crate::util::prop::forall("select-batch invariant", 300, |g| {
            let v = ModelView {
                model: "m".into(),
                len: g.usize_in(1, 64),
                oldest_wait_s: g.f64_in(0.0, 10.0),
                obs: g.usize_in(1, 32),
                rate_rps: g.f64_in(0.0, 20.0),
                est_load_s: g.f64_in(0.0, 3.0),
                est_exec_s: g.f64_in(0.0, 3.0),
            };
            let sla = g.f64_in(0.5, 10.0);
            let t = SelectBatchTimer::target_batch(&v, sla);
            let headroom = (sla - v.est_load_s - v.est_exec_s)
                .max(SelectBatchTimer::MIN_HEADROOM_FRAC * sla);
            let bound = (v.rate_rps * headroom).floor().max(1.0) as usize;
            crate::prop_assert!(t <= bound.max(1).min(v.obs.max(1)),
                                "target {t} exceeds bound {bound}");
            crate::prop_assert!(t >= 1, "target must be >= 1");
            Ok(())
        });
    }

    #[test]
    fn select_batch_fires_smaller_batches() {
        // queue of 3 at rate 2 with tight SLA: target 2 -> fire with 3? no:
        // take = min(len, target) = 2
        let mut v = view("a", 3, 0.1);
        v.rate_rps = 2.0;
        let mut c = ctx(None, vec![v]);
        c.sla_s = 2.0; // desired 1.0 -> target 2
        assert_eq!(SelectBatchTimer.decide(&c),
                   Decision::Process { model: "a".into(), take: 2 });
    }

    #[test]
    fn partial_drains_resident_before_swap() {
        // "b" is overdue, but resident "a" still has 2 queued -> drain a
        let c = ctx(Some("a"),
                    vec![view("a", 2, 0.5), view("b", 3, 4.0)]);
        assert_eq!(BestBatchPartialTimer::default().decide(&c),
                   Decision::Process { model: "a".into(), take: 2 });
    }

    #[test]
    fn partial_swaps_once_resident_is_drained() {
        let c = ctx(Some("a"), vec![view("b", 3, 4.0)]);
        assert_eq!(BestBatchPartialTimer::default().decide(&c),
                   Decision::Process { model: "b".into(), take: 3 });
    }

    #[test]
    fn all_strategies_wait_on_empty() {
        let c = ctx(Some("a"), vec![]);
        for name in STRATEGY_NAMES {
            let s = strategy_by_name(name).unwrap();
            assert_eq!(s.decide(&c), Decision::Wait, "{name}");
        }
    }

    #[test]
    fn strategy_names_roundtrip() {
        for name in STRATEGY_NAMES {
            assert_eq!(strategy_by_name(name).unwrap().name(), *name);
        }
        assert!(strategy_by_name("fifo").is_err());
    }
}
