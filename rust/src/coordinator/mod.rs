//! The serving coordinator — the paper's system contribution (§III).
//!
//! Relaxed batch inference against multiple models on one device that
//! can hold a single model at a time: per-model FIFO queues, pluggable
//! scheduling strategies (Table I), a swap manager that moves weights
//! through the (optionally confidential) DMA path, SLA tracking, and
//! the serve loop tying it together.

pub mod batcher;
pub mod http;
pub mod queues;
pub mod rate;
pub mod request;
pub mod server;
pub mod sla;
pub mod strategy;
pub mod swap;

pub use request::{CompletedRequest, Request};
#[allow(deprecated)]
pub use server::serve;
pub use server::RunSummary;
pub use strategy::{strategy_by_name, Decision, SchedContext, Strategy,
                   STRATEGY_NAMES};
