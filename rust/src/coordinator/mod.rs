//! The serving coordinator — the paper's system contribution (§III).
//!
//! Relaxed batch inference against multiple models on a fleet of
//! devices, each of which can hold a single model at a time: per-model
//! FIFO queues, pluggable scheduling strategies (Table I), fleet
//! placement policies, a swap manager per device that moves weights
//! through the (optionally confidential) DMA path, and SLA tracking.
//! The serve loop itself lives in [`crate::engine`].

pub mod batcher;
pub mod http;
pub mod placement;
pub mod prefetch;
pub mod queues;
pub mod rate;
pub mod request;
pub mod sla;
pub mod strategy;
pub mod swap;

pub use placement::{placement_by_name, placement_names, Placement,
                    PLACEMENTS};
pub use request::{CompletedRequest, Request};
pub use strategy::{strategy_by_name, strategy_names, Decision, DeviceView,
                   SchedContext, Strategy, STRATEGIES};
