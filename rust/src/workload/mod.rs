//! Workload synthesis: prompts and tokenization.
//!
//! Stands in for the paper's Instructlab-generated jsonl corpus
//! (§III-A step 1): prompt *content* never reaches the measured path
//! (output length is fixed at `decode_len` tokens for consistency,
//! §III-D2), so a deterministic synthetic corpus preserves behaviour.

pub mod promptgen;
pub mod tokenizer;
