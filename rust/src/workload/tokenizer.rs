//! Deterministic byte-hash tokenizer.
//!
//! The serving path needs prompts as fixed-length `i32` token rows in
//! `[0, vocab)`.  Real subword vocabularies are irrelevant to the
//! measured path (the model is synthetic), so we hash whitespace-split
//! words into the vocabulary, then truncate/pad to `prompt_len` — stable
//! across runs and platforms.

/// Tokenize `text` into exactly `prompt_len` ids in `[0, vocab)`.
///
/// Padding uses token 0; truncation keeps the prompt head (instruction
/// prefix carries the task).
pub fn tokenize(text: &str, prompt_len: usize, vocab: u32) -> Vec<i32> {
    assert!(vocab > 1);
    let mut ids: Vec<i32> = text.split_whitespace()
        .map(|w| (fnv1a(w.as_bytes()) % (vocab as u64 - 1) + 1) as i32)
        .take(prompt_len)
        .collect();
    ids.resize(prompt_len, 0);
    ids
}

/// FNV-1a 64-bit — tiny, stable, good avalanche for short words.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_length_and_range() {
        let ids = tokenize("hello confidential computing world", 16, 512);
        assert_eq!(ids.len(), 16);
        assert!(ids.iter().all(|&t| (0..512).contains(&t)));
        // 4 real tokens then zero padding
        assert!(ids[..4].iter().all(|&t| t != 0));
        assert!(ids[4..].iter().all(|&t| t == 0));
    }

    #[test]
    fn truncates_long_input() {
        let text = (0..100).map(|i| format!("w{i}")).collect::<Vec<_>>()
            .join(" ");
        let ids = tokenize(&text, 8, 512);
        assert_eq!(ids.len(), 8);
        assert!(ids.iter().all(|&t| t != 0));
    }

    #[test]
    fn deterministic_and_word_sensitive() {
        let a = tokenize("alpha beta", 4, 768);
        let b = tokenize("alpha beta", 4, 768);
        let c = tokenize("alpha gamma", 4, 768);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn empty_input_is_all_padding() {
        assert!(tokenize("", 8, 512).iter().all(|&t| t == 0));
    }
}
