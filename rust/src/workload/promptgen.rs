//! Deterministic synthetic prompt generator (Instructlab stand-in).
//!
//! Emits natural-language-shaped instruction prompts from a template
//! grammar, seeded so traces are reproducible bit-for-bit.

use crate::traffic::rng::Pcg64;

const TASKS: &[&str] = &[
    "Summarize the following invoice and flag anomalies",
    "Extract line items and totals from this expense report",
    "Classify the sentiment of this customer review",
    "Draft a reply to the following support ticket",
    "Translate this paragraph into formal English",
    "List the action items from these meeting notes",
    "Explain the key risk factors in this filing excerpt",
    "Generate a title for the following abstract",
];

const SUBJECTS: &[&str] = &[
    "a cloud infrastructure migration",
    "quarterly revenue reporting",
    "a medical diagnosis pipeline",
    "weather model post-processing",
    "an e-commerce recommendation engine",
    "telemetry from IoT sensors",
    "a high-frequency trading audit",
    "confidential computing benchmarks",
];

/// Deterministic prompt stream, parameterized by target word count.
pub struct PromptGen {
    rng: Pcg64,
    words: usize,
    counter: u64,
}

impl PromptGen {
    pub fn new(seed: u64, words: usize) -> PromptGen {
        PromptGen { rng: Pcg64::new(seed), words: words.max(4), counter: 0 }
    }

    /// Next prompt for a request targeting `model`.
    pub fn next_prompt(&mut self, model: &str) -> String {
        self.counter += 1;
        let task = self.rng.below(TASKS.len() as u64) as usize;
        let subj = self.rng.below(SUBJECTS.len() as u64) as usize;
        let mut p = format!("[req {} for {}] {} regarding {}.",
                            self.counter, model, TASKS[task],
                            SUBJECTS[subj]);
        // pad with deterministic filler to the target length
        while p.split_whitespace().count() < self.words {
            let n = self.rng.below(9999);
            p.push_str(&format!(" item-{n}"));
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = PromptGen::new(7, 20);
        let mut b = PromptGen::new(7, 20);
        for _ in 0..50 {
            assert_eq!(a.next_prompt("m"), b.next_prompt("m"));
        }
    }

    #[test]
    fn prompts_distinct_and_long_enough() {
        let mut g = PromptGen::new(8, 24);
        let p1 = g.next_prompt("llama-sim");
        let p2 = g.next_prompt("llama-sim");
        assert_ne!(p1, p2);
        assert!(p1.split_whitespace().count() >= 24);
    }
}
