//! The `Engine` — the crate's single serve loop, fleet-aware.
//!
//! The paper's evaluation is one control loop — ingest → queues →
//! strategy → swap → execute → record (§III-B) — run in two time
//! domains: wall clock against the simulated GPU, and virtual time
//! against the calibrated cost model.  The engine owns that loop
//! *once*, parameterized by two seams:
//!
//! * [`Clock`] — wall vs virtual time ([`WallClock`], [`VirtualClock`]);
//! * [`ExecBackend`] — what a decision costs and produces
//!   ([`RealBackend`], [`DesBackend`]), for each of N fleet devices.
//!
//! Fleet semantics: every device has its own *busy-until* timeline.
//! A dispatch assigns a batch to a free device and (in virtual time)
//! extends that device's timeline by the reported swap + exec + I/O
//! costs without advancing global time, so devices execute
//! concurrently; the strategy is only consulted while at least one
//! device is free, and the placement policy
//! ([`crate::coordinator::placement`]) picks *which* free device runs
//! the batch.  On a `devices=1` fleet this reduces exactly to the
//! paper's single-GPU loop — same decision sequence, same timeline.
//!
//! [`EngineBuilder`] is the supported entry point:
//!
//! ```no_run
//! # use sincere::config::RunConfig;
//! # use sincere::engine::EngineBuilder;
//! # use sincere::runtime::Registry;
//! # fn demo(cfg: &RunConfig, registry: &Registry) -> anyhow::Result<()> {
//! let (summary, _recorder) = EngineBuilder::new(cfg)
//!     .real(registry)?      // or .des(&manifest, &costs)
//!     .run()?;
//! println!("{}", summary.brief());
//! # Ok(()) }
//! ```
//!
//! This module is the only place in the crate that reads or advances
//! experiment time.

pub mod backend;
pub mod clock;
mod des;
mod real;
mod summary;

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::RunConfig;
use crate::coordinator::placement::{placement_by_name, Placement};
use crate::coordinator::queues::ModelQueues;
use crate::coordinator::rate::RateEstimator;
use crate::coordinator::request::{CompletedRequest, Request};
use crate::coordinator::sla::SlaTracker;
use crate::coordinator::strategy::{strategy_by_name, Decision, DeviceView,
                                   ModelView, SchedContext, Strategy};
use crate::coordinator::swap::SwapStats;
use crate::gpu::CcMode;
use crate::metrics::recorder::{BatchRecord, MonitorRecord, Recorder};
use crate::metrics::system::sample_proc;
use crate::obs::{Trace, TraceMode};
use crate::runtime::ModelId;
use crate::tenancy::admission::{admission_by_name, queue_cap, AdmitCtx,
                                AdmissionPolicy};
use crate::tenancy::zipf::Zipf;
use crate::tenancy::{assign_class, class_deadline_s, jain_fairness,
                     TenancyStats, CLASS_NAMES, N_CLASSES};
use crate::traffic::compose;
use crate::traffic::pattern_by_name;
use crate::traffic::rng::Pcg64;
use crate::workload::promptgen::PromptGen;

pub use backend::{BatchOutcome, DataPathOutcome, DeviceSnapshot,
                  ExecBackend, PrefetchOutcome, SwapOutcome};
pub use clock::{Clock, VirtualClock, WallClock};
pub use des::DesBackend;
pub use real::RealBackend;
pub use summary::{ClassSummary, DeviceSummary, RunSummary,
                  TenancySummary};

use summary::summarize;

/// Builder for one serving run: pick a backend, then [`run`].
///
/// [`run`]: EngineBuilder::run
pub struct EngineBuilder<'a> {
    cfg: RunConfig,
    backend: Option<Box<dyn ExecBackend + 'a>>,
    virtual_time: bool,
}

impl<'a> EngineBuilder<'a> {
    pub fn new(cfg: &RunConfig) -> EngineBuilder<'a> {
        EngineBuilder { cfg: cfg.clone(), backend: None,
                        virtual_time: false }
    }

    /// Real execution on the wall clock: `SimGpu` fleet + PJRT + swap
    /// managers (the paper's measured system).
    pub fn real(mut self, registry: &'a crate::runtime::Registry)
                -> anyhow::Result<EngineBuilder<'a>> {
        anyhow::ensure!(self.cfg.pp_stages <= 1,
                        "--pp-stages shards are priced in virtual time \
                         only (des / lab / real_virtual); wall-clock \
                         serve cannot pipeline-parallel");
        if self.cfg.data_path
            && (self.cfg.data_tokens_in.is_some()
                || self.cfg.data_tokens_out.is_some())
        {
            eprintln!("[sincere] warning: wall-clock runs measure the \
                       actual request/response payloads — \
                       --data-tokens-in/--data-tokens-out only change \
                       the *priced* shape in DES / virtual-cost runs \
                       and are ignored here");
        }
        self.backend = Some(Box::new(RealBackend::new(&self.cfg,
                                                      registry)?));
        self.virtual_time = false;
        Ok(self)
    }

    /// Calibrated DES in virtual time (full-grid sweeps).
    pub fn des(mut self, manifest: &'a crate::runtime::Manifest,
               costs: &'a crate::sim::CostModel)
               -> anyhow::Result<EngineBuilder<'a>> {
        self.backend = Some(Box::new(DesBackend::new(&self.cfg, manifest,
                                                     costs)));
        self.virtual_time = true;
        Ok(self)
    }

    /// Real execution under virtual time with modeled costs — the
    /// backend-parity seam (see `tests/engine_parity.rs`).  Pair with
    /// `cfg.gpu.no_throttle = true` so the real work underneath takes
    /// negligible wall time.
    pub fn real_virtual(mut self,
                        registry: &'a crate::runtime::Registry,
                        costs: &crate::sim::CostModel)
                        -> anyhow::Result<EngineBuilder<'a>> {
        self.backend = Some(Box::new(RealBackend::with_virtual_costs(
            &self.cfg, registry, costs)?));
        self.virtual_time = true;
        Ok(self)
    }

    /// Construct the engine (validates config and models).
    pub fn build(self) -> anyhow::Result<Engine<'a>> {
        let cfg = self.cfg;
        cfg.validate()?;
        let backend = self.backend.ok_or_else(|| anyhow::anyhow!(
            "EngineBuilder: no backend configured \
             (call .real()/.des()/.real_virtual())"))?;
        let strategy = strategy_by_name(&cfg.strategy)?;
        let placement = placement_by_name(&cfg.placement)?;
        let models = if cfg.models.is_empty() {
            backend.model_names()
        } else {
            cfg.models.clone()
        };
        for model in &models {
            backend.check_model(model)?;
        }
        Ok(Engine {
            cfg,
            models,
            strategy,
            placement,
            backend,
            virtual_time: self.virtual_time,
        })
    }

    /// Build and run to completion.
    pub fn run(self) -> anyhow::Result<(RunSummary, Recorder)> {
        self.build()?.run()
    }
}

/// The serve loop, ready to run one experiment.
pub struct Engine<'a> {
    cfg: RunConfig,
    models: Vec<String>,
    strategy: Box<dyn Strategy>,
    placement: Box<dyn Placement>,
    backend: Box<dyn ExecBackend + 'a>,
    virtual_time: bool,
}

/// Arrival delivery into the loop: precomputed virtual schedule, or an
/// open-loop wall-clock ingest thread.
enum Ingest {
    Virtual(VecDeque<Request>),
    Wall {
        rx: mpsc::Receiver<Request>,
        open: bool,
        handle: Option<JoinHandle<()>>,
    },
}

impl Ingest {
    fn open(&self) -> bool {
        match self {
            Ingest::Virtual(pending) => !pending.is_empty(),
            Ingest::Wall { open, .. } => *open,
        }
    }

    fn next_arrival_s(&self) -> Option<f64> {
        match self {
            Ingest::Virtual(pending) => pending.front().map(|r| r.arrival_s),
            Ingest::Wall { .. } => None,
        }
    }
}

/// Monitor-thread plumbing (wall-clock runs only).
struct MonitorCtx {
    snapshot: Arc<Mutex<Vec<DeviceSnapshot>>>,
    records: Arc<Mutex<Vec<MonitorRecord>>>,
    handle: JoinHandle<()>,
}

/// Per-model exec-time EWMA, id-indexed.  `NaN` is the "never
/// executed" sentinel — exactly the states the old
/// `HashMap::entry/or_insert` pair distinguished, without the hashing
/// or the `String` keys.
#[inline]
fn exec_est_or(exec_est: &[f64], backend: &dyn ExecBackend, m: ModelId)
               -> f64 {
    let e = exec_est.get(m.index()).copied().unwrap_or(f64::NAN);
    if e.is_nan() {
        backend.initial_exec_est_s(m)
    } else {
        e
    }
}

/// Strategy-visible snapshot of the queues, built the same way for
/// every backend (the HTTP front-end reuses this).  `free` names the
/// devices available for dispatch; per-model load estimates take the
/// most favourable free device (on a one-device fleet this is just
/// that device's estimate).  Views are appended to the caller's
/// (cleared) buffer so the steady-state loop reuses one allocation.
pub fn build_views_into(queues: &ModelQueues, rates: &RateEstimator,
                        backend: &dyn ExecBackend, exec_est: &[f64],
                        now_s: f64, free: &[usize],
                        out: &mut Vec<ModelView>) {
    out.clear();
    for m in queues.nonempty_ids() {
        let mut best = f64::INFINITY;
        for &d in free {
            best = best.min(backend.est_load_s(m, d));
        }
        if !best.is_finite() {
            best = backend.est_load_s(m, 0);
        }
        out.push(ModelView {
            model: m,
            len: queues.len(m),
            oldest_wait_s: queues.head_arrival_s(m)
                .map(|a| (now_s - a).max(0.0)).unwrap_or(0.0),
            obs: backend.obs(m),
            rate_rps: rates.rate_rps(m, now_s),
            est_load_s: best,
            est_exec_s: exec_est_or(exec_est, backend, m),
        });
    }
}

/// Allocating convenience over [`build_views_into`].
pub fn build_views(queues: &ModelQueues, rates: &RateEstimator,
                   backend: &dyn ExecBackend, exec_est: &[f64],
                   now_s: f64, free: &[usize]) -> Vec<ModelView> {
    let mut out = Vec::new();
    build_views_into(queues, rates, backend, exec_est, now_s, free,
                     &mut out);
    out
}

/// One [`DeviceView`] per backend device, from the engine's busy-until
/// timelines (the HTTP front-end reuses this with always-free
/// devices), appended to the caller's (cleared) reusable buffer.
pub fn build_device_views_into(backend: &dyn ExecBackend,
                               busy_until: &[f64], busy_s: &[f64],
                               dispatched: &[u64], now_s: f64,
                               out: &mut Vec<DeviceView>) {
    out.clear();
    for d in 0..backend.n_devices() {
        out.push(DeviceView {
            id: d,
            mode: backend.mode(d),
            resident: backend.resident(d),
            busy: busy_until[d] > now_s,
            busy_s: busy_s[d],
            dispatched: dispatched[d],
        });
    }
}

/// Allocating convenience over [`build_device_views_into`].
pub fn build_device_views(backend: &dyn ExecBackend, busy_until: &[f64],
                          busy_s: &[f64], dispatched: &[u64], now_s: f64)
                          -> Vec<DeviceView> {
    let mut out = Vec::new();
    build_device_views_into(backend, busy_until, busy_s, dispatched,
                            now_s, &mut out);
    out
}

/// Resolve a decision's device target: honour a pinned free device,
/// otherwise ask the placement policy to pick among the free ones.
pub fn resolve_device(ctx: &SchedContext, placement: &dyn Placement,
                      model: ModelId, pinned: Option<usize>,
                      free: &[usize]) -> usize {
    if let Some(d) = pinned {
        if free.contains(&d) {
            return d;
        }
    }
    match ctx.queues.iter().find(|v| v.model == model) {
        Some(v) => placement.place(ctx, v, free),
        None => free.first().copied().unwrap_or(0),
    }
}

fn snapshot_all(backend: &dyn ExecBackend) -> Vec<DeviceSnapshot> {
    (0..backend.n_devices()).map(|d| backend.snapshot(d)).collect()
}

/// Assemble the admission gate's view of one arriving request.  Every
/// field derives from the virtual-time domain — queue lengths, cost
/// table estimates, the engine's own exec-EWMA — so DES and
/// real-virtual runs shed exactly the same requests (parity-pinned).
/// Load is estimated like [`build_views`]: the most favourable free
/// device, falling back to device 0 when the fleet is saturated.
#[allow(clippy::too_many_arguments)]
fn admit_ctx(r: &Request, now_s: f64, queues: &ModelQueues,
             cfg: &RunConfig, queue_cap: usize,
             backend: &dyn ExecBackend, exec_est: &[f64],
             busy_until: &[f64]) -> AdmitCtx {
    let mut est_load = f64::INFINITY;
    for d in 0..backend.n_devices() {
        if busy_until[d] <= now_s {
            est_load = est_load.min(backend.est_load_s(r.model, d));
        }
    }
    if !est_load.is_finite() {
        est_load = backend.est_load_s(r.model, 0);
    }
    AdmitCtx {
        now_s,
        arrival_s: r.arrival_s,
        class: r.class,
        sla_s: cfg.sla_s,
        classes_on: cfg.sla_classes,
        queue_len: queues.len(r.model),
        total_queued: queues.total_len(),
        class_queued: queues.class_counts(),
        queue_cap,
        est_load_s: est_load,
        est_exec_s: exec_est_or(exec_est, backend, r.model),
        obs: backend.obs(r.model),
    }
}

impl Engine<'_> {
    /// Run the experiment to completion and assemble the summary.
    ///
    /// The loop is the paper's §III-B control loop; the drain/backlog
    /// methodology (arrivals stop at `duration_s`, the backlog drains
    /// up to `drain_s` more, runtime extends to the last response) is
    /// implemented here once for both time domains.
    pub fn run(mut self) -> anyhow::Result<(RunSummary, Recorder)> {
        let cfg = self.cfg.clone();
        let n_dev = self.backend.n_devices();
        // The run's intern table: every model name is resolved to a
        // ModelId exactly once (at schedule build below); the loop
        // proper moves u32 copies only.
        let table = self.backend.table().clone();

        // ---------------- arrival schedule (open loop) ----------------
        let mut rng = Pcg64::new(cfg.seed);
        let pattern = pattern_by_name(&cfg.pattern)?;
        let mut arrivals = pattern.generate(cfg.duration_s, cfg.mean_rps,
                                            &self.models, &mut rng);
        // Zipf popularity: re-route each arrival to a rank drawn from
        // a dedicated forked stream (rank order = model-list order).
        // The fork draws from `rng`, so it only happens when the flag
        // is set — the off path touches no extra RNG state and stays
        // byte-identical.
        if let Some(skew) = cfg.zipf_skew {
            let zipf = Zipf::new(self.models.len(), skew);
            let mut zrng = rng.fork(0x21BF);
            for a in &mut arrivals {
                a.model = self.models[zipf.sample(&mut zrng)].clone();
            }
        }
        // diurnal/flash composition: a deterministic monotone time
        // warp over the base pattern — zero RNG draws, no-op when off
        let shape = compose::Shape {
            diurnal_amp: cfg.diurnal_amp,
            diurnal_period_s: cfg.diurnal_period_s,
            flash_mult: cfg.flash_mult,
            flash_start_s: cfg.flash_start_s,
            flash_dur_s: cfg.flash_dur_s,
        };
        if shape.is_active() {
            compose::warp(&mut arrivals, cfg.duration_s, &shape);
        }
        let generated = arrivals.len() as u64;
        let mut prompts = PromptGen::new(cfg.seed ^ 0xBEEF, 24);
        // tenant class assignment, again from a gated fork
        let mut crng = if cfg.sla_classes {
            Some(rng.fork(0xC1A5))
        } else {
            None
        };
        let schedule: Vec<Request> = arrivals.iter().enumerate()
            .map(|(i, a)| anyhow::Ok(Request {
                id: i as u64,
                model: table.require(&a.model)?,
                tokens: self.backend.tokenize_prompt(
                    &a.model, &prompts.next_prompt(&a.model)),
                arrival_s: a.at_s,
                class: crng.as_mut().map(assign_class).unwrap_or(0),
            })).collect::<anyhow::Result<_>>()?;

        // ---------------- tenancy state --------------------------------
        // the admission gate and per-class counters; active only when a
        // tenancy feature is on, so the summary of a plain run carries
        // no tenancy key (byte-identity contract)
        let mut gate: Option<Box<dyn AdmissionPolicy>> =
            if cfg.admission != "none" {
                Some(admission_by_name(&cfg.admission)?)
            } else {
                None
            };
        let tenancy_on = gate.is_some() || cfg.sla_classes;
        let qcap = queue_cap(cfg.mean_rps, cfg.sla_s);
        let mut tstats = TenancyStats::default();
        if tenancy_on {
            for r in &schedule {
                tstats.generated[r.class as usize % N_CLASSES] += 1;
            }
        }

        // ---------------- clock + ingest + monitor --------------------
        let stop = Arc::new(AtomicBool::new(false));
        let mut clock: Box<dyn Clock>;
        let mut ingest;
        let monitor_ctx;
        if self.virtual_time {
            clock = Box::new(VirtualClock::new());
            ingest = Ingest::Virtual(schedule.into_iter().collect());
            monitor_ctx = None;
        } else {
            let wall = WallClock::new();
            let origin = wall.origin();
            clock = Box::new(wall);
            let (rx, handle) = spawn_ingest(schedule, origin,
                                            stop.clone());
            ingest = Ingest::Wall { rx, open: true,
                                    handle: Some(handle) };
            monitor_ctx = Some(spawn_monitor(origin, stop.clone(),
                                             cfg.monitor_period, n_dev));
        }

        // ---------------- scheduler state ------------------------------
        let mut queues = ModelQueues::new(table.clone());
        let mut rates = RateEstimator::default();
        let mut sla = SlaTracker::new(cfg.sla_s);
        let mut recorder = Recorder::new();
        // Structured event trace (`--trace`): recorded only in virtual
        // time, where the engine computes every phase boundary itself.
        // The hooks below sit in the engine, not the backends, so both
        // virtual backends emit identical span sequences for identical
        // runs (the parity contract, tests/engine_parity.rs).
        if cfg.trace.is_on() {
            if self.virtual_time {
                recorder.trace = Some(Trace::new());
            } else {
                eprintln!("warning: --trace records virtual-time runs \
                           only (des / lab); wall-mode serve ignores it");
            }
        }
        // EWMA of observed exec time per model (SelectBatch headroom),
        // id-indexed; NaN = never executed (the old map's "absent")
        let mut exec_est: Vec<f64> = vec![f64::NAN; table.len()];
        // Steady-state buffer pool: the per-tick context views, the
        // free-device list, the per-batch request drain and the expiry
        // drain all reuse these across iterations — the loop proper
        // performs no per-dispatch allocation.
        let mut view_buf: Vec<ModelView> = Vec::new();
        let mut dev_buf: Vec<DeviceView> = Vec::new();
        let mut free: Vec<usize> = Vec::with_capacity(n_dev);
        let mut batch_buf: Vec<Request> = Vec::new();
        let mut expired_buf: Vec<Request> = Vec::new();
        let mut ingested: u64 = 0;
        let mut last_complete_s = 0.0f64;
        // instant of the last observable progress (arrival, expiry or
        // completion); drives the wall-clock stall exit for strategies
        // that legitimately strand a sub-OBS remainder
        let mut last_progress_s = 0.0f64;
        // Per-device fleet timelines: when each device frees up, its
        // cumulative busy seconds, and its dispatch count.  In wall
        // time execution is synchronous, so devices are free at every
        // decision point; in virtual time these ARE the concurrency.
        let mut busy_until = vec![0.0f64; n_dev];
        let mut busy_s = vec![0.0f64; n_dev];
        let mut dispatched = vec![0u64; n_dev];
        // The paper's methodology: arrivals stop at duration_s but the
        // system drains its backlog; drain_s is a safety cap, and the
        // reported runtime extends to the last dispatched response.
        let hard_stop_s = cfg.duration_s + cfg.drain_s;
        // Pipeline-parallel topology: group leads are the only
        // dispatch targets, and a lead is free only while its whole
        // group is (shards stage atomically or not at all, so a busy
        // member means the group is mid-batch).  With stages == 1
        // every device is its own lead and the free list below is
        // exactly the legacy one — the byte-identity contract.
        let topo = crate::gpu::fleet::StageTopology::new(
            cfg.pp_stages.max(1), n_dev);
        // pipeline aggregates: stay zero — and keep their summary keys
        // absent — on single-stage runs
        let mut pp_ttft_sum = 0.0f64;
        let mut pp_ttft_n = 0u64;
        let mut pp_bubble_s = 0.0f64;
        let mut pp_tokens = 0u64;
        let mut pp_act_bytes = 0u64;
        let mut pp_act_wire = 0u64;
        let mut pp_act_io_s = 0.0f64;
        let mut pp_act_crypto_s = 0.0f64;
        let mut pp_act_exposed_s = 0.0f64;

        loop {
            // ingest everything due by now; the admission gate sees
            // each request *before* it is queued and may shed it —
            // shed requests are ingested (counted, rated) but never
            // occupy a queue, and miss their SLA by definition
            match &mut ingest {
                Ingest::Virtual(pending) => {
                    let now = clock.now_s();
                    while pending.front().map(|r| r.arrival_s <= now)
                        .unwrap_or(false)
                    {
                        let r = pending.pop_front().unwrap();
                        rates.on_arrival(r.model, r.arrival_s);
                        ingested += 1;
                        if let Some(g) = gate.as_mut() {
                            let ctx = admit_ctx(
                                &r, now, &queues, &cfg, qcap,
                                self.backend.as_ref(), &exec_est,
                                &busy_until);
                            if !g.admit(&ctx) {
                                sla.on_unserved(1);
                                tstats.shed[r.class as usize
                                            % N_CLASSES] += 1;
                                if let Some(tr) = recorder.trace.as_mut() {
                                    tr.on_shed(now, r.id, r.model,
                                               r.class);
                                }
                                continue;
                            }
                        }
                        queues.push(r);
                    }
                }
                Ingest::Wall { rx, open, .. } => loop {
                    match rx.try_recv() {
                        Ok(r) => {
                            let now = clock.now_s();
                            rates.on_arrival(r.model, r.arrival_s);
                            ingested += 1;
                            last_progress_s = now;
                            let admit = match gate.as_mut() {
                                Some(g) => g.admit(&admit_ctx(
                                    &r, now, &queues, &cfg, qcap,
                                    self.backend.as_ref(), &exec_est,
                                    &busy_until)),
                                None => true,
                            };
                            if admit {
                                queues.push(r);
                            } else {
                                sla.on_unserved(1);
                                tstats.shed[r.class as usize
                                            % N_CLASSES] += 1;
                            }
                        }
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => {
                            *open = false;
                            break;
                        }
                    }
                },
            }

            let t = clock.now_s();
            // SLA expiry: overdue queued requests are unfulfilled
            // (§III-C3).  With SLA classes on, each request carries
            // its class deadline; the uniform path keeps the exact
            // prefix-pop behavior the goldens pin.
            expired_buf.clear();
            if cfg.sla_classes {
                let sla_s = cfg.sla_s;
                queues.expire_by_into(t, |r| {
                    r.arrival_s + class_deadline_s(r.class, sla_s)
                }, &mut expired_buf);
            } else {
                queues.expire_into(t, cfg.sla_s, &mut expired_buf);
            }
            if !expired_buf.is_empty() {
                sla.on_unserved(expired_buf.len() as u64);
                if tenancy_on {
                    for r in &expired_buf {
                        tstats.expired[r.class as usize % N_CLASSES] += 1;
                    }
                }
                if let Some(tr) = recorder.trace.as_mut() {
                    for r in &expired_buf {
                        tr.on_expired(t, r.id, r.model, r.class);
                    }
                }
                last_progress_s = t;
            }
            if t >= hard_stop_s {
                break;
            }
            if !ingest.open() && queues.is_empty() {
                break;
            }
            // wall-clock stall exit: nothing new can arrive and no
            // timer will ever fire for the stranded remainder (virtual
            // runs detect this exactly via Clock::idle instead)
            if !self.virtual_time && !ingest.open()
                && t - last_progress_s > cfg.timeout_s() + 5.0 * cfg.sla_s
            {
                break;
            }

            // the strategy is only consulted while a device can take
            // work; otherwise time simply advances to the next event.
            // The context borrows the pooled view buffers via
            // `mem::take` and hands them back before the dispatch, so
            // `Decision` (Copy) plus the resolved device/hint are all
            // that outlive it — no per-tick allocation.
            free.clear();
            free.extend(topo.leads().filter(|&l| topo.members(l)
                .all(|d| busy_until[d] <= t)));
            let mut decision = Decision::Wait;
            let mut dev = 0usize;
            let mut hint: Option<ModelId> = None;
            if !free.is_empty() {
                build_views_into(&queues, &rates, self.backend.as_ref(),
                                 &exec_est, t, &free, &mut view_buf);
                build_device_views_into(self.backend.as_ref(),
                                        &busy_until, &busy_s,
                                        &dispatched, t, &mut dev_buf);
                let ctx = SchedContext {
                    now_s: t,
                    devices: std::mem::take(&mut dev_buf),
                    queues: std::mem::take(&mut view_buf),
                    sla_s: cfg.sla_s,
                    timeout_s: cfg.timeout_s(),
                };
                decision = self.strategy.decide(&ctx);
                if let Decision::Process { model, device, .. } = decision {
                    // placement + predictive-prefetch target, decided
                    // from the same snapshot the dispatch came from
                    dev = resolve_device(&ctx, self.placement.as_ref(),
                                         model, device, &free);
                    hint = if cfg.prefetch {
                        self.strategy.next_hint(&ctx, model)
                            .filter(|h| *h != model)
                    } else {
                        None
                    };
                }
                dev_buf = ctx.devices;
                view_buf = ctx.queues;
            }

            match decision {
                Decision::Wait => {
                    if let Some(mc) = &monitor_ctx {
                        *mc.snapshot.lock().unwrap() =
                            snapshot_all(self.backend.as_ref());
                    }
                    // next actionable instant: the next arrival, the
                    // earliest not-yet-passed queue timer, or the next
                    // device completion (virtual time jumps there;
                    // wall time just sleeps a tick)
                    let next = if self.virtual_time {
                        let next_timer = queues.nonempty_ids()
                            .filter_map(|m| queues.head_arrival_s(m))
                            .flat_map(|a| {
                                [a + cfg.timeout_s(), a + cfg.sla_s]
                            })
                            .filter(|&x| x > t)
                            .fold(f64::INFINITY, f64::min);
                        let next_free = busy_until.iter().copied()
                            .filter(|&b| b > t)
                            .fold(f64::INFINITY, f64::min);
                        let n = ingest.next_arrival_s()
                            .unwrap_or(f64::INFINITY)
                            .min(next_timer).min(next_free);
                        n.is_finite().then_some(n.min(hard_stop_s))
                    } else {
                        None
                    };
                    if !clock.idle(next, cfg.tick) {
                        break;
                    }
                }
                Decision::Process { model, take, .. } => {
                    // 1. residency (the expensive CC-sensitive step);
                    // a staged hit promotes without a second DMA
                    let swap = self.backend.ensure_resident(
                        clock.as_mut(), dev, model)?;
                    // 2.-5. batch assembly + payload I/O + execution,
                    // costed by the backend; the batch drains into the
                    // pooled buffer
                    batch_buf.clear();
                    let Some(out) = self.backend.execute_batch(
                        clock.as_mut(), &mut queues, dev, model, take,
                        &mut batch_buf)?
                    else {
                        continue;
                    };

                    // 6. fold the costs into the device's timeline
                    let swap_cost = swap.unload_s + swap.load_s;
                    let (exec_start_s, complete_s) = if self.virtual_time {
                        let start = t + swap_cost;
                        (start, start + out.exec_s + out.io_s)
                    } else {
                        (out.exec_start_s, clock.now_s())
                    };

                    // 7. decrypt-ahead: stage the hinted model while the
                    // batch executes.  Responses complete at
                    // `complete_s` regardless; the staging occupies the
                    // *device* concurrently, so the device frees at
                    // max(batch end, staging end).  (Wall mode runs the
                    // staging inline after the batch — the host
                    // serializes the fleet anyway — so the device is
                    // busy until the clock's now either way.)
                    let mut prefetch_s = 0.0;
                    if let Some(h) = hint {
                        let pf = self.backend.prefetch(clock.as_mut(),
                                                       dev, h)?;
                        if pf.staged {
                            prefetch_s = pf.cost_s;
                        }
                    }
                    // in virtual time the staging is hidden behind the
                    // batch, so the device is busy for max(batch,
                    // staging) — charging the sum would overstate
                    // busy_s and skew least-loaded placement away from
                    // exactly the devices that can promote for free;
                    // in wall mode the host really ran it serially
                    let batch_tail = out.exec_s + out.io_s;
                    let busy_tail = if self.virtual_time {
                        batch_tail.max(prefetch_s)
                    } else {
                        batch_tail + prefetch_s
                    };
                    let free_at = if self.virtual_time {
                        complete_s.max(exec_start_s + prefetch_s)
                    } else {
                        clock.now_s()
                    };
                    // the whole stage group worked this batch: every
                    // member frees when the pipeline drains (a 1-stage
                    // group is just the device itself)
                    for d in topo.members(dev) {
                        busy_until[d] = free_at;
                        busy_s[d] += swap_cost + busy_tail;
                    }
                    dispatched[dev] += 1;
                    last_complete_s = last_complete_s.max(complete_s);
                    last_progress_s = clock.now_s();
                    // first observation seeds the EWMA then folds once
                    // (0.3x + 0.7x), exactly as the map-entry original
                    let e = &mut exec_est[model.index()];
                    let prev = if e.is_nan() { out.exec_s } else { *e };
                    *e = 0.3 * out.exec_s + 0.7 * prev;

                    let n_rows = batch_buf.len();
                    // device-lane spans: swap (if any) then exec; the
                    // gaps between spans on a lane are its idle time
                    if let Some(tr) = recorder.trace.as_mut() {
                        if swap.swapped {
                            tr.on_swap(dev, t, model, &swap);
                        }
                        tr.on_exec(dev, exec_start_s, model, n_rows,
                                   out.exec_s, out.io_s);
                        // pipeline runs also get one span per non-lead
                        // stage on the member lanes (the lead lane
                        // keeps the whole-batch span above); a stage's
                        // first work begins one microbatch latency
                        // after its upstream neighbour's
                        if let Some(pp) = &out.pp {
                            let m = n_rows.max(1) as f64;
                            let mut off = 0.0;
                            for (i, &es) in
                                pp.per_stage_exec_s.iter().enumerate()
                            {
                                if i > 0 {
                                    tr.on_stage_exec(
                                        dev + i, exec_start_s + off,
                                        model, n_rows, es);
                                }
                                off += es / m;
                            }
                        }
                    }
                    // pipeline aggregates: TTFT counts the queue wait,
                    // the shard swap, and the first microbatch's trip
                    // through every stage and sealed link
                    if let Some(pp) = &out.pp {
                        pp_bubble_s += pp.bubble_s;
                        pp_tokens += pp.tokens;
                        pp_act_bytes += pp.activation.bytes;
                        pp_act_wire += pp.activation.wire_bytes;
                        pp_act_io_s += pp.activation.io_s;
                        pp_act_crypto_s += pp.activation.crypto_total_s;
                        pp_act_exposed_s +=
                            pp.activation.crypto_exposed_s;
                        for r in &batch_buf {
                            pp_ttft_sum += (t - r.arrival_s).max(0.0)
                                + swap_cost + pp.first_out_s;
                        }
                        pp_ttft_n += n_rows as u64;
                    }
                    for r in &batch_buf {
                        let c = CompletedRequest {
                            id: r.id,
                            model: r.model,
                            arrival_s: r.arrival_s,
                            exec_start_s,
                            complete_s,
                            batch: out.artifact_batch,
                            batch_rows: n_rows,
                            caused_swap: swap.swapped,
                            device: dev,
                        };
                        let met = sla.on_complete(&c);
                        if tenancy_on {
                            let cls = r.class as usize % N_CLASSES;
                            tstats.completed[cls] += 1;
                            if met {
                                tstats.met[cls] += 1;
                            }
                        }
                        // class-lane span + waterfall row; `t` is the
                        // dispatch instant, so queue wait ends (and the
                        // swap begins) there
                        if let Some(tr) = recorder.trace.as_mut() {
                            tr.on_request(&c, r.class, met, t, &swap,
                                          out.exec_s, out.io_s,
                                          out.pp.as_ref()
                                              .map(|p| p.activation.io_s)
                                              .unwrap_or(0.0));
                        }
                        recorder.on_complete(c, met);
                    }
                    recorder.on_batch(BatchRecord {
                        at_s: exec_start_s,
                        model,
                        device: dev,
                        rows: n_rows,
                        artifact_batch: out.artifact_batch,
                        swapped: swap.swapped,
                        promoted: swap.promoted,
                        load_s: swap.load_s,
                        unload_s: swap.unload_s,
                        exec_s: out.exec_s,
                        io_s: out.io_s,
                        data_bytes: out.data.bytes,
                        data_wire_bytes: out.data.wire_bytes,
                        data_crypto_s: out.data.crypto_total_s,
                        data_crypto_exposed_s: out.data.crypto_exposed_s,
                        prefetch_s,
                    });
                    if let Some(mc) = &monitor_ctx {
                        *mc.snapshot.lock().unwrap() =
                            snapshot_all(self.backend.as_ref());
                    }
                }
            }
        }

        // ---------------- teardown -------------------------------------
        stop.store(true, Ordering::Relaxed);
        // paper runtime: generation window + drain tail to last response
        let runtime_s = last_complete_s.max(cfg.duration_s);
        // unserved = still queued + never ingested before the cutoff
        let drained = queues.drain_all().len() as u64;
        sla.on_unserved(drained + (generated - ingested));
        let ingest_handle = match &mut ingest {
            Ingest::Wall { handle, .. } => handle.take(),
            Ingest::Virtual(_) => None,
        };
        // dropping the receiver closes the channel, so a paced sender
        // exits at its next send; then join
        drop(ingest);
        if let Some(h) = ingest_handle {
            h.join().ok();
        }
        if let Some(mc) = monitor_ctx {
            mc.handle.join().ok();
            for m in mc.records.lock().unwrap().drain(..) {
                recorder.on_monitor(m);
            }
        }
        self.backend.teardown();

        // ---------------- summary --------------------------------------
        let dev_stats: Vec<SwapStats> = (0..n_dev)
            .map(|d| self.backend.swap_stats(d)).collect();
        let dev_modes: Vec<CcMode> = (0..n_dev)
            .map(|d| self.backend.mode(d)).collect();
        // tenancy block: only assembled when a tenancy feature ran, so
        // plain summaries carry no tenancy key at all
        let tenancy = tenancy_on.then(|| {
            // keyed by id; id order == sorted-name order, so the
            // resolved rows keep the old name-keyed BTreeMap order
            let mut churn: BTreeMap<ModelId, u64> = BTreeMap::new();
            for st in &dev_stats {
                for (m, load_s) in &st.load_samples {
                    if *load_s > 0.0 {
                        *churn.entry(*m).or_insert(0) += 1;
                    }
                }
            }
            let classes: Vec<ClassSummary> = if cfg.sla_classes {
                (0..N_CLASSES).map(|c| ClassSummary {
                    name: CLASS_NAMES[c].to_string(),
                    generated: tstats.generated[c],
                    completed: tstats.completed[c],
                    met: tstats.met[c],
                    shed: tstats.shed[c],
                    expired: tstats.expired[c],
                    attainment: if tstats.generated[c] == 0 {
                        0.0
                    } else {
                        tstats.met[c] as f64 / tstats.generated[c] as f64
                    },
                }).collect()
            } else {
                Vec::new()
            };
            let fairness = if cfg.sla_classes {
                let atts: Vec<f64> = classes.iter()
                    .filter(|c| c.generated > 0)
                    .map(|c| c.attainment).collect();
                jain_fairness(&atts)
            } else {
                1.0
            };
            TenancySummary {
                admission: cfg.admission.clone(),
                shed_total: tstats.shed_total(),
                goodput_rps: if runtime_s > 0.0 {
                    sla.met() as f64 / runtime_s
                } else {
                    0.0
                },
                fairness,
                classes,
                churn_by_model: churn.into_iter()
                    .map(|(m, n)| (table.name(m).to_string(), n))
                    .collect(),
            }
        });
        let mut summary = summarize(&cfg, generated, runtime_s, &recorder,
                                    &sla, &dev_stats, &dev_modes, tenancy);
        // "where the seconds go": present only when tracing ran, so
        // untraced summaries stay byte-identical
        summary.phase_totals = recorder.trace.as_ref()
            .map(|tr| tr.phase_totals());
        // pipeline-parallel block: attached only when the run actually
        // sharded, so single-stage summaries carry no pp key at all
        if topo.is_pipelined() {
            summary.pp_stages = topo.stages();
            summary.ttft_mean_s = if pp_ttft_n > 0 {
                pp_ttft_sum / pp_ttft_n as f64
            } else {
                0.0
            };
            summary.token_throughput_tps = if runtime_s > 0.0 {
                pp_tokens as f64 / runtime_s
            } else {
                0.0
            };
            summary.total_bubble_s = pp_bubble_s;
            summary.activation_bytes = pp_act_bytes;
            summary.activation_wire_bytes = pp_act_wire;
            summary.total_activation_io_s = pp_act_io_s;
            summary.total_activation_crypto_s = pp_act_crypto_s;
            summary.total_activation_crypto_exposed_s = pp_act_exposed_s;
        }
        if let Some(dir) = &cfg.results_dir {
            recorder.write_csvs(dir, &cfg.label, &table)?;
            if let Some(tr) = &recorder.trace {
                std::fs::write(
                    dir.join(format!("{}_trace.json", cfg.label)),
                    tr.to_chrome_json(&cfg.label, &table, &dev_modes,
                                      cfg.sla_classes).to_string())?;
                if cfg.trace == TraceMode::Full {
                    tr.write_waterfall_csv(dir, &cfg.label, &table)?;
                }
            }
            std::fs::write(
                dir.join(format!("{}_summary.json", cfg.label)),
                summary.to_json().to_string())?;
        }
        Ok((summary, recorder))
    }
}

/// Open-loop ingest thread: walks the precomputed schedule in wall
/// time, so overload shows up as queueing, not back-pressure on the
/// generator.
fn spawn_ingest(schedule: Vec<Request>, origin: Instant,
                stop: Arc<AtomicBool>)
                -> (mpsc::Receiver<Request>, JoinHandle<()>) {
    let (tx, rx) = mpsc::channel::<Request>();
    let handle = std::thread::spawn(move || {
        for req in schedule {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            let target = Duration::from_secs_f64(req.arrival_s);
            let elapsed = origin.elapsed();
            if target > elapsed {
                std::thread::sleep(target - elapsed);
            }
            if tx.send(req).is_err() {
                break;
            }
        }
        // channel closes when tx drops
    });
    (rx, handle)
}

/// Monitor thread: samples process counters plus every device's
/// snapshot at a fixed period (wall-clock runs only) — one record per
/// device per sample.
fn spawn_monitor(origin: Instant, stop: Arc<AtomicBool>,
                 period: Duration, n_dev: usize) -> MonitorCtx {
    let snapshot = Arc::new(Mutex::new(
        vec![DeviceSnapshot::default(); n_dev]));
    let records: Arc<Mutex<Vec<MonitorRecord>>> =
        Arc::new(Mutex::new(Vec::new()));
    let handle = {
        let snapshot = snapshot.clone();
        let records = records.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                let snaps = snapshot.lock().unwrap().clone();
                let proc = sample_proc(origin.elapsed().as_secs_f64());
                let mut recs = records.lock().unwrap();
                for (d, snap) in snaps.iter().enumerate() {
                    recs.push(MonitorRecord {
                        proc: proc.clone(),
                        device: d,
                        gpu_util: snap.gpu_util,
                        mem_in_use: snap.mem_in_use,
                        mem_peak: snap.mem_peak,
                        fragmentation: snap.fragmentation,
                        dma_h2d_bytes: snap.dma_h2d_bytes,
                        dma_crypto_total_s: snap.dma_crypto_total_s,
                        dma_crypto_exposed_s: snap.dma_crypto_exposed_s,
                        swaps: snap.swaps,
                    });
                }
                drop(recs);
                std::thread::sleep(period);
            }
        })
    };
    MonitorCtx { snapshot, records, handle }
}
