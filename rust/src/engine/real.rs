//! `RealBackend` — the execution backend over a [`DeviceSet`] of
//! `SimGpu`s + `Registry` + one `SwapManager` per device: real
//! (optionally CC-sealed) DMA, real PJRT execution, real per-device
//! occupancy.  A mixed CC/No-CC fleet is just a `DeviceSet` whose
//! configs differ.  The pipelined CC swap path and predictive prefetch
//! run for real here: staging uploads go through the actual DMA engine
//! into an actual second HBM buffer, and promotion really is just a
//! pointer swap (`SwapManager`).
//!
//! Two time modes:
//!
//! * **Wall** (default, used by `sincere serve` and the HTTP
//!   front-end): costs are whatever actually elapsed.  Execution is
//!   serialized on the scheduler thread — the host simulates the fleet
//!   — but residency, memory pressure and crypto accounting stay
//!   per-device.
//! * **Virtual costs** (`with_virtual_costs`): the same real execution
//!   path runs, but reported times come from a calibrated
//!   [`CostModel`]; the engine folds them into the device's busy-until
//!   timeline exactly as it does for a `DesBackend` — the seam the
//!   DES-vs-real parity test pins, now per device and inclusive of
//!   staging/promotion.

use std::sync::Arc;

use crate::config::RunConfig;
use crate::coordinator::batcher;
use crate::coordinator::queues::ModelQueues;
use crate::coordinator::request::Request;
use crate::coordinator::swap::{SwapManager, SwapStats};
use crate::engine::backend::{est_load_s_group, price_data_path,
                             price_pipeline, price_prefetch, price_swap,
                             price_swap_group, stage_shares, swap_load_s,
                             BatchOutcome, DataPathOutcome,
                             DeviceSnapshot, ExecBackend, PrefetchOutcome,
                             SwapEvent, SwapOutcome};
use crate::engine::clock::Clock;
use crate::gpu::dma::Dir;
use crate::gpu::fleet::DeviceSet;
use crate::gpu::CcMode;
use crate::runtime::{ModelId, ModelTable, Registry};
use crate::sim::CostModel;
use crate::workload::tokenizer::tokenize;

pub struct RealBackend<'a> {
    registry: &'a Registry,
    /// Sorted intern table over the registry's loaded model names.
    table: Arc<ModelTable>,
    fleet: DeviceSet,
    /// One residency manager per device.
    swaps: Vec<SwapManager>,
    /// Whether CC loads are priced pipelined in virtual-costs mode
    /// (the real DMA engine reads the same `GpuConfig` directly).
    pipelined: bool,
    /// Pipeline-parallel stage count (1 = off).  Virtual-costs mode
    /// only — the engine builder refuses wall-clock pp runs.
    pp_stages: usize,
    /// Per-device configs, cloned once so group pricing can slice
    /// them like the DES does (`&fleet_cfgs[lead..lead+stages]`).
    fleet_cfgs: Vec<crate::gpu::device::GpuConfig>,
    /// CC-priced inference data path (`--data-path`): wall mode
    /// surfaces the measured bounce-crypto of the payload transfers it
    /// already performs; virtual mode prices them via the shared
    /// `price_data_path` helper (the DES-parity seam).
    data_path: bool,
    /// Priced input tokens per request (None = model `prompt_len`).
    data_tokens_in: Option<usize>,
    /// Priced output tokens per request (None = model `decode_len`).
    data_tokens_out: Option<usize>,
    /// Modeled swap accounting per device, maintained only in
    /// virtual-costs mode (wall mode reads each swap manager's measured
    /// stats directly).
    stats: Vec<SwapStats>,
    virtual_costs: Option<CostModel>,
}

impl<'a> RealBackend<'a> {
    /// Wall-clock backend (the real experiment path).
    pub fn new(cfg: &RunConfig, registry: &'a Registry)
               -> anyhow::Result<RealBackend<'a>> {
        let fleet_cfgs = cfg.fleet_configs();
        let fleet = DeviceSet::new(cfg.fleet_configs())?;
        let n = fleet.len();
        let table = ModelTable::shared(registry.names());
        Ok(RealBackend {
            registry,
            fleet,
            swaps: (0..n).map(|_| SwapManager::new(table.clone()))
                .collect(),
            table,
            pipelined: cfg.gpu.pipeline_depth >= 2,
            pp_stages: cfg.pp_stages.max(1),
            fleet_cfgs,
            data_path: cfg.data_path,
            data_tokens_in: cfg.data_tokens_in,
            data_tokens_out: cfg.data_tokens_out,
            stats: vec![SwapStats::default(); n],
            virtual_costs: None,
        })
    }

    /// Real execution under virtual time: all reported costs come from
    /// `costs` and the engine owns the device timelines.  Combine with
    /// `cfg.gpu.no_throttle = true` so the real work underneath takes
    /// negligible wall time.
    pub fn with_virtual_costs(cfg: &RunConfig, registry: &'a Registry,
                              costs: &CostModel)
                              -> anyhow::Result<RealBackend<'a>> {
        let mut backend = RealBackend::new(cfg, registry)?;
        if backend.pipelined && costs.missing_pipeline_profile() {
            eprintln!("[sincere] warning: cost model has no pipelined CC \
                       load profile (cached before the pipeline \
                       existed?) — --pipeline-depth prices as \
                       serialized; delete the cached cost_model.json \
                       to re-measure");
        }
        backend.virtual_costs = Some(costs.clone());
        Ok(backend)
    }

    /// Shard-group swap: make `name`'s layer shards resident on every
    /// device of the lead's stage group — atomically.  The real DMA
    /// moves each stage's proportional slice of the weight blob; if
    /// any stage fails, the shards staged so far are evicted before
    /// the error propagates, so a partially-resident group can never
    /// exist (the invariant that keeps the admission gate live).
    /// Virtual-costs mode then re-prices the group through the shared
    /// `price_swap_group` — the same pricing the DES runs, which is
    /// the pp parity contract.
    fn ensure_resident_group(&mut self, lead: usize, model: ModelId,
                             name: &str, had_resident: bool)
                             -> anyhow::Result<SwapOutcome> {
        let n_layers = self.registry.entry(name)?.spec.n_layers;
        let shares = stage_shares(n_layers, self.pp_stages);
        let group = lead..lead + self.pp_stages;
        let mut swapped = false;
        for (i, d) in group.clone().enumerate() {
            let r = self.swaps[d].ensure_resident_shard(
                self.fleet.get_mut(d), self.registry, name, shares[i]);
            match r {
                Ok(rep) => swapped |= rep.swapped,
                Err(e) => {
                    // unwind: evict the shards this round staged
                    for u in lead..d {
                        let sm = &mut self.swaps[u];
                        sm.evict(self.fleet.get_mut(u));
                    }
                    return Err(e.context(format!(
                        "staging pp shard {i} of {name}")));
                }
            }
        }
        if !swapped {
            return Ok(SwapOutcome::default());
        }
        let mut out = SwapOutcome { swapped: true, ..Default::default() };
        if let Some(costs) = &self.virtual_costs {
            let mc = costs.costs(name)?;
            out = price_swap_group(
                mc, &self.fleet_cfgs[group.clone()], &shares,
                SwapEvent { model, had_resident, promoted: false,
                            dropped_staged: false },
                &mut self.stats[group]);
        }
        Ok(out)
    }
}

impl ExecBackend for RealBackend<'_> {
    fn kind(&self) -> &'static str {
        "real"
    }

    fn table(&self) -> &Arc<ModelTable> {
        &self.table
    }

    fn n_devices(&self) -> usize {
        self.fleet.len()
    }

    fn mode(&self, device: usize) -> CcMode {
        self.fleet.get(device).mode()
    }

    fn model_names(&self) -> Vec<String> {
        self.registry.names()
    }

    fn check_model(&self, model: &str) -> anyhow::Result<()> {
        self.registry.entry(model)?;
        if let Some(costs) = &self.virtual_costs {
            costs.costs(model)?;
        }
        Ok(())
    }

    fn tokenize_prompt(&self, model: &str, prompt: &str) -> Vec<i32> {
        match self.registry.entry(model) {
            Ok(entry) => tokenize(prompt, entry.spec.prompt_len,
                                  entry.spec.vocab as u32),
            Err(_) => Vec::new(),
        }
    }

    fn obs(&self, model: ModelId) -> usize {
        let model = self.table.name(model);
        // In virtual-costs mode the cost table is the single source of
        // truth for batch sizing (it must be for DES parity); it must
        // only name OBS values the registry actually compiled.
        match &self.virtual_costs {
            Some(costs) => costs.costs(model).map(|mc| mc.obs)
                .unwrap_or(1),
            None => self.registry.entry(model).map(|e| e.obs).unwrap_or(1),
        }
    }

    fn est_load_s(&self, model: ModelId, device: usize) -> f64 {
        let model = self.table.name(model);
        // a staged model promotes for free in either time domain (the
        // DES mirrors this, so parity requires it here too)
        if self.swaps[device].staged() == Some(model) {
            return 0.0;
        }
        if self.pp_stages > 1 {
            // estimate for `device`'s stage group (callers may name a
            // non-lead member): ready when the slowest shard load
            // finishes (pp runs are always virtual-costs)
            let device = device - device % self.pp_stages;
            let (Some(costs), Ok(entry)) =
                (&self.virtual_costs, self.registry.entry(model))
            else { return 0.0 };
            let Ok(mc) = costs.costs(model) else { return 0.0 };
            let shares = stage_shares(entry.spec.n_layers,
                                      self.pp_stages);
            return est_load_s_group(
                mc,
                &self.fleet_cfgs[device..device + self.pp_stages],
                &shares);
        }
        match &self.virtual_costs {
            Some(costs) => costs.costs(model)
                .map(|mc| swap_load_s(mc, self.fleet.get(device).config()))
                .unwrap_or(0.0),
            None => self.swaps[device].estimate_load_s(
                self.fleet.get(device), self.registry, model),
        }
    }

    fn initial_exec_est_s(&self, model: ModelId) -> f64 {
        let model = self.table.name(model);
        match &self.virtual_costs {
            Some(costs) => costs.costs(model)
                .map(|mc| mc.exec_s(mc.obs)).unwrap_or(0.2),
            // wall mode: optimistic prior, corrected by the EWMA after
            // the first batch (same constant the old serve loop used)
            None => 0.2,
        }
    }

    fn resident(&self, device: usize) -> Option<ModelId> {
        // the resident name always came from this table, so the id
        // lookup (a binary search, no clone) cannot miss
        self.swaps[device].resident().and_then(|s| self.table.id(s))
    }

    fn ensure_resident(&mut self, _clock: &mut dyn Clock, device: usize,
                       model: ModelId) -> anyhow::Result<SwapOutcome> {
        let table = self.table.clone();
        let name = table.name(model);
        let had_resident = self.swaps[device].resident().is_some();
        if self.pp_stages > 1 {
            return self.ensure_resident_group(device, model, name,
                                              had_resident);
        }
        let rep = self.swaps[device].ensure_resident(
            self.fleet.get_mut(device), self.registry, name)?;
        let mut out = SwapOutcome {
            swapped: rep.swapped,
            promoted: rep.promoted,
            dropped_staged: rep.dropped_staged,
            load_s: rep.load_s,
            unload_s: rep.unload_s,
            crypto_total_s: rep.crypto_total_s,
            crypto_exposed_s: rep.crypto_exposed_s,
            // wall mode measures real swaps; the bridge residual is a
            // virtual-pricing attribution term (and wall runs never
            // trace), so it stays zero here
            bridge_s: 0.0,
        };
        if !rep.swapped {
            return Ok(out);
        }
        if let Some(costs) = &self.virtual_costs {
            // virtual mode keeps its own stats: the swap manager's
            // wall-measured values are not in the engine's time
            // domain.  `price_swap` is the same pricing the DesBackend
            // runs — that shared definition is the parity contract.
            let mc = costs.costs(name)?;
            out = price_swap(
                mc, self.fleet.get(device).config(),
                SwapEvent { model, had_resident,
                            promoted: rep.promoted,
                            dropped_staged: rep.dropped_staged },
                &mut self.stats[device]);
        }
        Ok(out)
    }

    fn prefetch(&mut self, _clock: &mut dyn Clock, device: usize,
                model: ModelId) -> anyhow::Result<PrefetchOutcome> {
        let table = self.table.clone();
        let name = table.name(model);
        let rep = self.swaps[device].prefetch(
            self.fleet.get_mut(device), self.registry, name)?;
        let Some(rep) = rep else {
            // already resident/staged, or no room for a second blob
            return Ok(PrefetchOutcome::default());
        };
        let mut out = PrefetchOutcome {
            staged: true,
            cost_s: rep.load_s,
            dropped_staged: rep.dropped_staged,
        };
        if let Some(costs) = &self.virtual_costs {
            let mc = costs.costs(name)?;
            out = price_prefetch(mc, self.fleet.get(device).config(),
                                 rep.dropped_staged,
                                 &mut self.stats[device]);
        }
        Ok(out)
    }

    fn execute_batch(&mut self, clock: &mut dyn Clock,
                     queues: &mut ModelQueues, device: usize,
                     model: ModelId, take: usize,
                     out_requests: &mut Vec<Request>)
                     -> anyhow::Result<Option<BatchOutcome>> {
        let table = self.table.clone();
        let name = table.name(model);
        // 1. batch assembly + workspace reservation (OOM guard)
        let Some(batch) = batcher::prepare(queues,
                                           self.fleet.get_mut(device),
                                           self.registry, model, take)?
        else {
            return Ok(None);
        };

        // 2. request payload in (CC seals it)
        let io_start = clock.now_s();
        let in_bytes: Vec<u8> = batch.requests.iter()
            .flat_map(|r| r.tokens.iter().flat_map(|t| t.to_le_bytes()))
            .collect();
        let rep_in = self.fleet.get_mut(device)
            .io_transfer(Dir::HostToDevice, &in_bytes)?;
        let mut io_s = clock.now_s() - io_start;

        // 3. execute
        let rows: Vec<Vec<i32>> = batch.requests.iter()
            .map(|r| r.tokens.clone()).collect();
        let exec_start_s = clock.now_s();
        let rep = self.registry.execute(name, &rows)?;
        self.fleet.get_mut(device).record_compute(rep.elapsed);
        let mut exec_s = rep.elapsed.as_secs_f64();

        // 4. response payload out
        let out_bytes: Vec<u8> = rep.tokens.iter()
            .flat_map(|row| row.iter().flat_map(|t| t.to_le_bytes()))
            .collect();
        let io_start = clock.now_s();
        let rep_out = self.fleet.get_mut(device)
            .io_transfer(Dir::DeviceToHost, &out_bytes)?;
        io_s += clock.now_s() - io_start;

        let n_rows = batch.requests.len();
        let mut requests =
            batcher::release(self.fleet.get_mut(device), batch);
        out_requests.append(&mut requests);

        // 5. virtual mode: replace measured times with modeled costs
        //    (the engine folds them into the device timeline)
        let mut data = DataPathOutcome::default();
        if let Some(costs) = &self.virtual_costs {
            let mc = costs.costs(name)?;
            exec_s = mc.exec_s(rep.batch);
            if self.data_path {
                let spec = &self.registry.entry(name)?.spec;
                data = price_data_path(
                    costs, self.fleet.get(device).config(), n_rows,
                    self.data_tokens_in.unwrap_or(spec.prompt_len),
                    self.data_tokens_out.unwrap_or(spec.decode_len));
                io_s = data.io_s;
            } else {
                io_s = costs.io_s_per_row(self.fleet.get(device).mode())
                    * n_rows as f64;
            }
        } else if self.data_path
            && self.fleet.get(device).mode() == CcMode::On
        {
            // wall mode: the payloads really crossed the sealed bounce
            // path above — surface the measured-model crypto figures
            // instead of re-pricing anything.  A No-CC device
            // contributes no data-path accounting (see
            // `price_data_path`), matching the virtual backends.
            let gpu = self.fleet.get(device);
            data = DataPathOutcome {
                io_s,
                crypto_total_s: rep_in.crypto_total.as_secs_f64()
                    + rep_out.crypto_total.as_secs_f64(),
                crypto_exposed_s: rep_in.crypto_exposed.as_secs_f64()
                    + rep_out.crypto_exposed.as_secs_f64(),
                bytes: rep_in.bytes + rep_out.bytes,
                wire_bytes: (crate::gpu::cc::wire_bytes(
                    in_bytes.len(), gpu.config().bounce_bytes)
                    + crate::gpu::cc::wire_bytes(
                        out_bytes.len(), gpu.config().bounce_bytes))
                    as u64,
            };
        }

        // 6. pipeline-parallel: split the modeled exec across the
        //    stage group and price the sealed activation links through
        //    the shared helper — the same numbers the DES computes,
        //    which is the pp parity contract (pp runs are always
        //    virtual-costs; the builder refuses wall-clock pp).
        let mut pp = None;
        if self.pp_stages > 1 {
            let spec = &self.registry.entry(name)?.spec;
            let (d_model, decode_len, n_layers) =
                (spec.d_model, spec.decode_len, spec.n_layers);
            let shares = stage_shares(n_layers, self.pp_stages);
            let batch = price_pipeline(
                exec_s, d_model, n_rows, decode_len, &shares,
                &self.fleet_cfgs[device..device + self.pp_stages]);
            exec_s = batch.makespan_s;
            io_s += batch.activation.io_s;
            pp = Some(batch);
        }

        Ok(Some(BatchOutcome {
            tokens: rep.tokens,
            artifact_batch: rep.batch,
            exec_start_s,
            exec_s,
            io_s,
            data,
            pp,
        }))
    }

    fn snapshot(&self, device: usize) -> DeviceSnapshot {
        let gpu = self.fleet.get(device);
        DeviceSnapshot {
            gpu_util: gpu.utilization(),
            mem_in_use: gpu.mem_in_use(),
            mem_peak: gpu.mem_peak(),
            fragmentation: gpu.mem_fragmentation(),
            dma_h2d_bytes: gpu.dma_stats().h2d_bytes,
            dma_crypto_total_s: gpu.dma_stats().crypto_total.as_secs_f64(),
            dma_crypto_exposed_s:
                gpu.dma_stats().crypto_exposed.as_secs_f64(),
            swaps: self.swap_stats(device).swap_count,
        }
    }

    fn swap_stats(&self, device: usize) -> SwapStats {
        // Wall mode: the swap manager's measured stats are authoritative.
        // Virtual mode: the backend's modeled stats are.
        match &self.virtual_costs {
            Some(_) => self.stats[device].clone(),
            None => self.swaps[device].stats().clone(),
        }
    }

    fn teardown(&mut self) {
        for (d, sm) in self.swaps.iter_mut().enumerate() {
            sm.evict(self.fleet.get_mut(d));
        }
    }
}
