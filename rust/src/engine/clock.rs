//! The `Clock` seam: one serve loop, two time domains.
//!
//! The paper's evaluation runs the identical control loop in wall-clock
//! time (real execution) and in virtual time (calibrated DES).  This is
//! the only module in the crate that advances experiment time; backends
//! report costs and the engine moves the clock.

use std::time::{Duration, Instant};

/// Experiment time source.  `now_s` is seconds since run start.
pub trait Clock {
    fn now_s(&self) -> f64;

    /// Account a modeled cost.  Virtual time advances by `dt_s`; wall
    /// clocks ignore it (the cost was already paid in real sleeps).
    fn advance(&mut self, dt_s: f64);

    /// Idle until something can change the next decision.
    ///
    /// * Wall clock: sleep one scheduler tick, return `true`.
    /// * Virtual clock: jump to `next_event_s` when it is in the
    ///   future; return `false` when no future event exists (nothing
    ///   can ever change the decision — the run is over).
    fn idle(&mut self, next_event_s: Option<f64>, tick: Duration) -> bool;

    fn is_virtual(&self) -> bool;
}

/// Real time, measured from construction.
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    pub fn new() -> WallClock {
        WallClock { start: Instant::now() }
    }

    /// The instant this clock calls `t = 0` — lets ingest/monitor
    /// threads pace themselves against the same origin.
    pub fn origin(&self) -> Instant {
        self.start
    }
}

impl Default for WallClock {
    fn default() -> Self {
        WallClock::new()
    }
}

impl Clock for WallClock {
    fn now_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn advance(&mut self, _dt_s: f64) {}

    fn idle(&mut self, _next_event_s: Option<f64>, tick: Duration)
            -> bool {
        std::thread::sleep(tick);
        true
    }

    fn is_virtual(&self) -> bool {
        false
    }
}

/// Virtual time: advances only through `advance`/`idle`.
pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock { now: 0.0 }
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        VirtualClock::new()
    }
}

impl Clock for VirtualClock {
    fn now_s(&self) -> f64 {
        self.now
    }

    fn advance(&mut self, dt_s: f64) {
        if dt_s > 0.0 {
            self.now += dt_s;
        }
    }

    fn idle(&mut self, next_event_s: Option<f64>, _tick: Duration)
            -> bool {
        match next_event_s {
            Some(t) if t > self.now => {
                self.now = t;
                true
            }
            _ => false,
        }
    }

    fn is_virtual(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_advances_only_on_demand() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now_s(), 0.0);
        c.advance(1.5);
        assert_eq!(c.now_s(), 1.5);
        c.advance(-1.0); // negative costs are ignored
        assert_eq!(c.now_s(), 1.5);
        assert!(c.idle(Some(4.0), Duration::from_millis(1)));
        assert_eq!(c.now_s(), 4.0);
        // no future event -> cannot make progress
        assert!(!c.idle(Some(4.0), Duration::from_millis(1)));
        assert!(!c.idle(None, Duration::from_millis(1)));
    }

    #[test]
    fn wall_clock_moves_on_its_own() {
        let mut c = WallClock::new();
        let t0 = c.now_s();
        assert!(c.idle(None, Duration::from_millis(5)));
        assert!(c.now_s() >= t0 + 0.004);
        c.advance(100.0); // modeled costs don't move wall time
        assert!(c.now_s() < 50.0);
        assert!(!c.is_virtual());
    }
}
