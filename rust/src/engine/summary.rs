//! `RunSummary` — the aggregated outcome of one run (one grid cell of
//! the evaluation), assembled in exactly one place for both time
//! domains.

use crate::config::RunConfig;
use crate::coordinator::sla::SlaTracker;
use crate::coordinator::swap::SwapStats;
use crate::metrics::recorder::Recorder;
use crate::util::json::Json;

/// Aggregated outcome of one run — one grid cell of the evaluation.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub label: String,
    pub mode: String,
    pub pattern: String,
    pub strategy: String,
    pub sla_s: f64,
    pub mean_rps: f64,
    pub duration_s: f64,
    /// Actual runtime of the serving phase (duration + drain used).
    pub runtime_s: f64,

    pub generated: u64,
    pub completed: u64,
    pub sla_met: u64,
    pub sla_attainment: f64,

    pub latency_mean_s: f64,
    pub latency_p50_s: f64,
    pub latency_p90_s: f64,
    pub latency_p99_s: f64,
    pub latency_max_s: f64,

    /// Completed requests / runtime (the paper's overall throughput).
    pub throughput_rps: f64,
    /// Completed requests / time spent actually executing — the paper's
    /// "processing rate during inference", which stays ~equal across
    /// modes (§IV-B).
    pub processing_rate_rps: f64,

    pub gpu_util: f64,
    pub swap_count: u64,
    pub total_load_s: f64,
    pub total_unload_s: f64,
    pub total_exec_s: f64,
    pub total_crypto_s: f64,
    pub mean_load_s: f64,
}

impl RunSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::str(self.label.clone())),
            ("mode", Json::str(self.mode.clone())),
            ("pattern", Json::str(self.pattern.clone())),
            ("strategy", Json::str(self.strategy.clone())),
            ("sla_s", Json::num(self.sla_s)),
            ("mean_rps", Json::num(self.mean_rps)),
            ("duration_s", Json::num(self.duration_s)),
            ("runtime_s", Json::num(self.runtime_s)),
            ("generated", Json::num(self.generated as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("sla_met", Json::num(self.sla_met as f64)),
            ("sla_attainment", Json::num(self.sla_attainment)),
            ("latency_mean_s", Json::num(self.latency_mean_s)),
            ("latency_p50_s", Json::num(self.latency_p50_s)),
            ("latency_p90_s", Json::num(self.latency_p90_s)),
            ("latency_p99_s", Json::num(self.latency_p99_s)),
            ("latency_max_s", Json::num(self.latency_max_s)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("processing_rate_rps", Json::num(self.processing_rate_rps)),
            ("gpu_util", Json::num(self.gpu_util)),
            ("swap_count", Json::num(self.swap_count as f64)),
            ("total_load_s", Json::num(self.total_load_s)),
            ("total_unload_s", Json::num(self.total_unload_s)),
            ("total_exec_s", Json::num(self.total_exec_s)),
            ("total_crypto_s", Json::num(self.total_crypto_s)),
            ("mean_load_s", Json::num(self.mean_load_s)),
        ])
    }

    /// One-line human summary.
    pub fn brief(&self) -> String {
        format!(
            "{:<6} {:<7} {:<26} sla={:<4} gen={:<5} done={:<5} \
             att={:>5.1}% lat(mean/p99)={:.2}/{:.2}s thr={:.2}rps \
             util={:>4.1}% swaps={}",
            self.mode, self.pattern, self.strategy, self.sla_s,
            self.generated, self.completed, self.sla_attainment * 100.0,
            self.latency_mean_s, self.latency_p99_s, self.throughput_rps,
            self.gpu_util * 100.0, self.swap_count)
    }
}

/// Assemble the summary from a finished run's accounting — the single
/// home of the paper's metric definitions, shared by every backend.
pub(crate) fn summarize(cfg: &RunConfig, generated: u64, runtime_s: f64,
                        recorder: &Recorder, sla: &SlaTracker,
                        swap_stats: &SwapStats) -> RunSummary {
    let h = &recorder.latency_hist;
    let completed = recorder.requests.len() as u64;
    let exec_busy = recorder.exec_busy_s();
    RunSummary {
        label: cfg.label.clone(),
        mode: cfg.mode.as_str().to_string(),
        pattern: cfg.pattern.clone(),
        strategy: cfg.strategy.clone(),
        sla_s: cfg.sla_s,
        mean_rps: cfg.mean_rps,
        duration_s: cfg.duration_s,
        runtime_s,
        generated,
        completed,
        sla_met: sla.met(),
        sla_attainment: sla.attainment(),
        latency_mean_s: h.mean(),
        latency_p50_s: h.quantile(0.5),
        latency_p90_s: h.quantile(0.9),
        latency_p99_s: h.quantile(0.99),
        latency_max_s: h.max(),
        throughput_rps: if runtime_s > 0.0 {
            completed as f64 / runtime_s
        } else {
            0.0
        },
        processing_rate_rps: if exec_busy > 0.0 {
            completed as f64 / exec_busy
        } else {
            0.0
        },
        // utilization over the reported runtime (exec share of the run,
        // Fig 7's metric); the device's lifetime utilization feeds the
        // monitor CSV instead
        gpu_util: if runtime_s > 0.0 {
            (exec_busy / runtime_s).min(1.0)
        } else {
            0.0
        },
        swap_count: swap_stats.swap_count,
        total_load_s: swap_stats.total_load_s,
        total_unload_s: swap_stats.total_unload_s,
        total_exec_s: exec_busy,
        total_crypto_s: swap_stats.total_crypto_s,
        mean_load_s: if swap_stats.swap_count > 0 {
            swap_stats.total_load_s / swap_stats.swap_count as f64
        } else {
            0.0
        },
    }
}
