//! `RunSummary` — the aggregated outcome of one run (one grid cell of
//! the evaluation), assembled in exactly one place for both time
//! domains, with per-device breakdowns plus fleet aggregates.

use crate::config::RunConfig;
use crate::coordinator::sla::SlaTracker;
use crate::coordinator::swap::SwapStats;
use crate::gpu::CcMode;
use crate::metrics::recorder::Recorder;
use crate::util::json::Json;

/// Per-device slice of a run — one fleet device's share of the work.
#[derive(Debug, Clone, Default)]
pub struct DeviceSummary {
    pub device: usize,
    /// "cc" | "no-cc".
    pub mode: String,
    /// Batches dispatched to this device.
    pub batches: u64,
    /// Requests completed on this device.
    pub completed: u64,
    /// Seconds spent executing batches on this device.
    pub exec_s: f64,
    /// exec_s / runtime — this device's utilization (Fig 7 metric).
    pub util: f64,
    pub swap_count: u64,
    pub load_s: f64,
    pub unload_s: f64,
    /// Total crypto work on this device's swap + staging path.
    pub crypto_s: f64,
    /// Crypto time actually exposed on the swap path (== `crypto_s`
    /// without the DMA pipeline; see `gpu::dma`).
    pub crypto_exposed_s: f64,
    /// Staging uploads issued on this device (predictive prefetch).
    pub prefetches: u64,
    /// Swaps satisfied by promoting a staged buffer (no second DMA).
    pub promotions: u64,
    /// Per-swap bridge/attestation residual seconds (hardware-profile
    /// devices with a `bridge_residual_s`; 0 — and absent from the
    /// JSON — on legacy knobs).
    pub bridge_s: f64,
    /// Payload bytes this device shipped through the inference data
    /// path (`--data-path on`; 0 otherwise).
    pub data_bytes: u64,
    /// Total payload crypto on this device's batch I/O.
    pub data_crypto_s: f64,
    /// Payload crypto actually exposed (== total without the pipeline).
    pub data_crypto_exposed_s: f64,
}

impl DeviceSummary {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("device", Json::num(self.device as f64)),
            ("mode", Json::str(self.mode.clone())),
            ("batches", Json::num(self.batches as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("exec_s", Json::num(self.exec_s)),
            ("util", Json::num(self.util)),
            ("swap_count", Json::num(self.swap_count as f64)),
            ("load_s", Json::num(self.load_s)),
            ("unload_s", Json::num(self.unload_s)),
            ("crypto_s", Json::num(self.crypto_s)),
            ("crypto_exposed_s", Json::num(self.crypto_exposed_s)),
            ("prefetches", Json::num(self.prefetches as f64)),
            ("promotions", Json::num(self.promotions as f64)),
        ];
        // the bridge residual only exists on hardware-profile devices
        // — same byte-identity gate as the data-path block below
        if self.bridge_s > 0.0 {
            fields.push(("bridge_s", Json::num(self.bridge_s)));
        }
        // data-path keys appear only when this device shipped CC batch
        // I/O — the same bytes-or-crypto gate as the fleet block (see
        // the byte-identity note on `RunSummary::to_json`), so the two
        // levels can never disagree about whether the run priced I/O
        if self.data_bytes > 0 || self.data_crypto_s > 0.0 {
            fields.push(("data_bytes", Json::num(self.data_bytes as f64)));
            fields.push(("data_crypto_s", Json::num(self.data_crypto_s)));
            fields.push(("data_crypto_exposed_s",
                         Json::num(self.data_crypto_exposed_s)));
        }
        Json::obj(fields)
    }

    /// Parse one per-device row back from its `to_json` form (every
    /// field defaults, so partial rows from older files still load).
    pub fn from_json(d: &Json) -> DeviceSummary {
        let f = |key: &str| -> f64 {
            d.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0)
        };
        let u = |key: &str| -> u64 {
            d.get(key).and_then(|v| v.as_u64()).unwrap_or(0)
        };
        DeviceSummary {
            device: d.get("device").and_then(|v| v.as_usize())
                .unwrap_or(0),
            mode: d.get("mode").and_then(|v| v.as_str())
                .unwrap_or("").into(),
            batches: u("batches"),
            completed: u("completed"),
            exec_s: f("exec_s"),
            util: f("util"),
            swap_count: u("swap_count"),
            load_s: f("load_s"),
            unload_s: f("unload_s"),
            crypto_s: f("crypto_s"),
            crypto_exposed_s: f("crypto_exposed_s"),
            prefetches: u("prefetches"),
            promotions: u("promotions"),
            bridge_s: f("bridge_s"),
            data_bytes: u("data_bytes"),
            data_crypto_s: f("data_crypto_s"),
            data_crypto_exposed_s: f("data_crypto_exposed_s"),
        }
    }
}

/// Per-SLA-class slice of a tenancy run (gold/silver/free).
#[derive(Debug, Clone, Default)]
pub struct ClassSummary {
    pub name: String,
    pub generated: u64,
    pub completed: u64,
    /// Completions within the run's base SLA (the shared attainment
    /// metric; class deadlines govern queue expiry, not this figure).
    pub met: u64,
    /// Requests refused by the admission gate.
    pub shed: u64,
    /// Requests dropped from the queues past their class deadline.
    pub expired: u64,
    /// met / generated for this class.
    pub attainment: f64,
}

impl ClassSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("generated", Json::num(self.generated as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("met", Json::num(self.met as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("expired", Json::num(self.expired as f64)),
            ("attainment", Json::num(self.attainment)),
        ])
    }

    pub fn from_json(j: &Json) -> ClassSummary {
        let u = |k: &str| j.get(k).and_then(|v| v.as_u64()).unwrap_or(0);
        ClassSummary {
            name: j.get("name").and_then(|v| v.as_str())
                .unwrap_or("").into(),
            generated: u("generated"),
            completed: u("completed"),
            met: u("met"),
            shed: u("shed"),
            expired: u("expired"),
            attainment: j.get("attainment").and_then(|v| v.as_f64())
                .unwrap_or(0.0),
        }
    }
}

/// Multi-tenant accounting block, present in the JSON only when a
/// tenancy feature (admission gate or SLA classes) was active — the
/// byte-identity contract extends to it exactly like the data path.
#[derive(Debug, Clone, Default)]
pub struct TenancySummary {
    /// Admission policy name ("none" when only classes were on).
    pub admission: String,
    /// Requests refused by the gate, all classes.
    pub shed_total: u64,
    /// SLA-met completions per second of runtime (admitted *useful*
    /// work — the figure admission control is supposed to protect).
    pub goodput_rps: f64,
    /// Jain fairness index over per-class attainments (1.0 when
    /// classes are off or equally served).
    pub fairness: f64,
    /// Per-class breakdown (empty when `--sla-classes` is off).
    pub classes: Vec<ClassSummary>,
    /// Swap loads per model, sorted by model name — the swap-churn
    /// profile Zipf skew is supposed to flatten.
    pub churn_by_model: Vec<(String, u64)>,
}

impl TenancySummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("admission", Json::str(self.admission.clone())),
            ("shed_total", Json::num(self.shed_total as f64)),
            ("goodput_rps", Json::num(self.goodput_rps)),
            ("fairness", Json::num(self.fairness)),
            ("classes", Json::Arr(self.classes.iter()
                .map(|c| c.to_json()).collect())),
            ("churn_by_model", Json::Obj(self.churn_by_model.iter()
                .map(|(m, n)| (m.clone(), Json::num(*n as f64)))
                .collect())),
        ])
    }

    pub fn from_json(j: &Json) -> TenancySummary {
        TenancySummary {
            admission: j.get("admission").and_then(|v| v.as_str())
                .unwrap_or("none").into(),
            shed_total: j.get("shed_total").and_then(|v| v.as_u64())
                .unwrap_or(0),
            goodput_rps: j.get("goodput_rps").and_then(|v| v.as_f64())
                .unwrap_or(0.0),
            fairness: j.get("fairness").and_then(|v| v.as_f64())
                .unwrap_or(1.0),
            classes: j.get("classes").and_then(|v| v.as_arr())
                .map(|arr| arr.iter().map(ClassSummary::from_json)
                     .collect())
                .unwrap_or_default(),
            churn_by_model: j.get("churn_by_model")
                .and_then(|v| v.as_obj())
                .map(|m| m.iter().map(|(k, v)| {
                    (k.clone(), v.as_u64().unwrap_or(0))
                }).collect())
                .unwrap_or_default(),
        }
    }
}

/// Aggregated outcome of one run — one grid cell of the evaluation.
/// Totals (`swap_count`, `total_*`, throughput) are fleet aggregates;
/// `per_device` carries the breakdown.
#[derive(Debug, Clone, Default)]
pub struct RunSummary {
    pub label: String,
    /// "cc" | "no-cc", or "mixed" for a heterogeneous fleet.
    pub mode: String,
    pub pattern: String,
    pub strategy: String,
    pub sla_s: f64,
    pub mean_rps: f64,
    pub duration_s: f64,
    /// Actual runtime of the serving phase (duration + drain used).
    pub runtime_s: f64,
    /// Traffic RNG seed of this run — identifies seed replicas of one
    /// grid cell in lab runs (`lab::spec::replica_seed`).
    pub seed: u64,

    /// Fleet size.
    pub devices: usize,
    /// Placement policy name.
    pub placement: String,
    /// CC DMA pipeline staging buffers (0 = serialized swap path).
    pub pipeline_depth: usize,
    /// Whether predictive prefetch was enabled.
    pub prefetch: bool,

    pub generated: u64,
    pub completed: u64,
    pub sla_met: u64,
    pub sla_attainment: f64,

    pub latency_mean_s: f64,
    pub latency_p50_s: f64,
    pub latency_p90_s: f64,
    pub latency_p99_s: f64,
    pub latency_max_s: f64,

    /// Completed requests / runtime (the paper's overall throughput).
    pub throughput_rps: f64,
    /// Completed requests / time spent actually executing — the paper's
    /// "processing rate during inference", which stays ~equal across
    /// modes (§IV-B).
    pub processing_rate_rps: f64,

    /// Fleet-average utilization: exec seconds / (runtime × devices).
    pub gpu_util: f64,
    pub swap_count: u64,
    pub total_load_s: f64,
    pub total_unload_s: f64,
    pub total_exec_s: f64,
    /// Total crypto work across the fleet (swaps + staging).
    pub total_crypto_s: f64,
    /// Crypto time exposed on the swap path — the figure Fig 3/7-style
    /// reports should quote once the pipeline hides the rest.
    pub total_crypto_exposed_s: f64,
    /// Staging uploads across the fleet (predictive prefetch).
    pub prefetch_count: u64,
    /// Swaps satisfied by promotion (loads avoided entirely).
    pub promoted_count: u64,
    pub mean_load_s: f64,

    /// Per-swap bridge/attestation residual seconds across the fleet
    /// — the CC cost that survives GPU-local isolation on
    /// bridge-residual hardware profiles (`gpu::profile`); 0, and
    /// absent from the JSON, on legacy knobs.
    pub total_bridge_s: f64,

    /// Total payload crypto across the fleet's batch I/O (the
    /// inference data path, `--data-path on`; all four fields zero —
    /// and absent from the JSON — otherwise).
    pub total_data_crypto_s: f64,
    /// Payload crypto actually exposed on the batch path.
    pub total_data_crypto_exposed_s: f64,
    /// Payload bytes shipped through the data path (request+response).
    pub data_bytes: u64,
    /// Data-path bytes on the link, per-chunk AEAD framing included.
    pub data_wire_bytes: u64,

    /// Pipeline-parallel stage count (1 = off; every pp field below is
    /// then zero and the whole block is absent from the JSON, so
    /// single-stage summaries stay byte-identical).
    pub pp_stages: usize,
    /// Mean time-to-first-token: queue wait + shard swap + the first
    /// microbatch's trip through every stage and sealed link.
    pub ttft_mean_s: f64,
    /// Decoded tokens per second of runtime (per-token throughput —
    /// the figure pipelining is supposed to protect while TTFT pays).
    pub token_throughput_tps: f64,
    /// Pipeline bubble seconds across the fleet: stage-imbalance idle
    /// time, the price of uneven layer splits.
    pub total_bubble_s: f64,
    /// Raw activation bytes that crossed inter-stage links.
    pub activation_bytes: u64,
    /// Activation bytes on the wire, sealed-chunk framing included.
    pub activation_wire_bytes: u64,
    /// Seconds spent moving activations between stages.
    pub total_activation_io_s: f64,
    /// Total activation sealing work (CC links only).
    pub total_activation_crypto_s: f64,
    /// Activation crypto not hidden behind the link.
    pub total_activation_crypto_exposed_s: f64,

    /// Per-device breakdown, in device-id order.
    pub per_device: Vec<DeviceSummary>,

    /// Multi-tenant block — Some only when a tenancy feature
    /// (admission gate, SLA classes) was active; absent from the JSON
    /// otherwise so pre-tenancy summaries stay byte-identical.
    pub tenancy: Option<TenancySummary>,

    /// "Where the seconds go" — the trace layer's per-phase waterfall
    /// aggregate (`obs::PhaseTotals`); Some only when the run traced
    /// (`--trace events|full`), absent from the JSON otherwise so
    /// untraced summaries stay byte-identical.
    pub phase_totals: Option<crate::obs::PhaseTotals>,
}

impl RunSummary {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("label", Json::str(self.label.clone())),
            ("mode", Json::str(self.mode.clone())),
            ("pattern", Json::str(self.pattern.clone())),
            ("strategy", Json::str(self.strategy.clone())),
            ("sla_s", Json::num(self.sla_s)),
            ("mean_rps", Json::num(self.mean_rps)),
            ("duration_s", Json::num(self.duration_s)),
            ("runtime_s", Json::num(self.runtime_s)),
            // seeds beyond f64's exact-integer range go through a
            // string so the round-trip is lossless either way
            ("seed", if self.seed <= (1u64 << 53) {
                Json::num(self.seed as f64)
            } else {
                Json::str(self.seed.to_string())
            }),
            ("devices", Json::num(self.devices as f64)),
            ("placement", Json::str(self.placement.clone())),
            ("pipeline_depth", Json::num(self.pipeline_depth as f64)),
            ("prefetch", Json::Bool(self.prefetch)),
            ("generated", Json::num(self.generated as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("sla_met", Json::num(self.sla_met as f64)),
            ("sla_attainment", Json::num(self.sla_attainment)),
            ("latency_mean_s", Json::num(self.latency_mean_s)),
            ("latency_p50_s", Json::num(self.latency_p50_s)),
            ("latency_p90_s", Json::num(self.latency_p90_s)),
            ("latency_p99_s", Json::num(self.latency_p99_s)),
            ("latency_max_s", Json::num(self.latency_max_s)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("processing_rate_rps", Json::num(self.processing_rate_rps)),
            ("gpu_util", Json::num(self.gpu_util)),
            ("swap_count", Json::num(self.swap_count as f64)),
            ("total_load_s", Json::num(self.total_load_s)),
            ("total_unload_s", Json::num(self.total_unload_s)),
            ("total_exec_s", Json::num(self.total_exec_s)),
            ("total_crypto_s", Json::num(self.total_crypto_s)),
            ("total_crypto_exposed_s",
             Json::num(self.total_crypto_exposed_s)),
            ("prefetch_count", Json::num(self.prefetch_count as f64)),
            ("promoted_count", Json::num(self.promoted_count as f64)),
            ("mean_load_s", Json::num(self.mean_load_s)),
        ];
        // bridge residual: only hardware-profile devices accumulate
        // one, so the key's presence follows the same byte-identity
        // contract as the data-path block below
        if self.total_bridge_s > 0.0 {
            fields.push(("total_bridge_s",
                         Json::num(self.total_bridge_s)));
        }
        // pipeline-parallel block: present only when the run sharded
        // (stage count > 1) — single-stage runs stay byte-identical
        if self.pp_stages > 1 {
            fields.push(("pp_stages", Json::num(self.pp_stages as f64)));
            fields.push(("ttft_mean_s", Json::num(self.ttft_mean_s)));
            fields.push(("token_throughput_tps",
                         Json::num(self.token_throughput_tps)));
            fields.push(("total_bubble_s",
                         Json::num(self.total_bubble_s)));
            fields.push(("activation_bytes",
                         Json::num(self.activation_bytes as f64)));
            fields.push(("activation_wire_bytes",
                         Json::num(self.activation_wire_bytes as f64)));
            fields.push(("total_activation_io_s",
                         Json::num(self.total_activation_io_s)));
            fields.push(("total_activation_crypto_s",
                         Json::num(self.total_activation_crypto_s)));
            fields.push(("total_activation_crypto_exposed_s",
                         Json::num(
                             self.total_activation_crypto_exposed_s)));
        }
        // Byte-identity contract (tests/golden_summary.rs): the
        // data-path block appears only when the run actually shipped
        // CC batch I/O.  With `--data-path off` — and in No-CC mode
        // even with it on (No-CC devices record no data-path bytes at
        // all, see `price_data_path`) — these keys are absent and
        // every other value is untouched, so the JSON stays
        // byte-identical to pre-data-path builds.  Gating on bytes,
        // not crypto, keeps the block present for degenerate configs
        // like `--cc-crypto-frac 0` whose crypto share is zero.
        if self.data_bytes > 0 || self.total_data_crypto_s > 0.0 {
            fields.push(("total_data_crypto_s",
                         Json::num(self.total_data_crypto_s)));
            fields.push(("total_data_crypto_exposed_s",
                         Json::num(self.total_data_crypto_exposed_s)));
            fields.push(("data_bytes", Json::num(self.data_bytes as f64)));
            fields.push(("data_wire_bytes",
                         Json::num(self.data_wire_bytes as f64)));
        }
        // same contract for the tenancy block: the key exists only
        // when the engine ran with a tenancy feature on
        if let Some(t) = &self.tenancy {
            fields.push(("tenancy", t.to_json()));
        }
        // and for the trace layer's waterfall aggregate: present only
        // when the run actually traced
        if let Some(p) = &self.phase_totals {
            fields.push(("phase_totals", p.to_json()));
        }
        fields.push(("per_device", Json::Arr(self.per_device.iter()
            .map(|d| d.to_json()).collect())));
        Json::obj(fields)
    }

    /// Parse a summary back from its `to_json` form.  Fields that
    /// newer revisions added (fleet, pipeline, prefetch, seed) are
    /// optional, so summary files saved by older builds still load.
    pub fn from_json(c: &Json) -> anyhow::Result<RunSummary> {
        let opt_f64 = |key: &str, default: f64| -> f64 {
            c.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
        };
        let opt_u64 = |key: &str| -> u64 {
            c.get(key).and_then(|v| v.as_u64()).unwrap_or(0)
        };
        Ok(RunSummary {
            label: c.req("label")?.as_str().unwrap_or("").into(),
            mode: c.req("mode")?.as_str().unwrap_or("").into(),
            pattern: c.req("pattern")?.as_str().unwrap_or("").into(),
            strategy: c.req("strategy")?.as_str().unwrap_or("").into(),
            sla_s: c.req("sla_s")?.as_f64().unwrap_or(0.0),
            mean_rps: c.req("mean_rps")?.as_f64().unwrap_or(0.0),
            duration_s: c.req("duration_s")?.as_f64().unwrap_or(0.0),
            runtime_s: c.req("runtime_s")?.as_f64().unwrap_or(0.0),
            seed: c.get("seed").and_then(|v| {
                v.as_u64().or_else(|| v.as_str()
                    .and_then(|s| s.parse().ok()))
            }).unwrap_or(0),
            devices: c.get("devices").and_then(|v| v.as_usize())
                .unwrap_or(1),
            placement: c.get("placement").and_then(|v| v.as_str())
                .unwrap_or("affinity").into(),
            pipeline_depth: c.get("pipeline_depth")
                .and_then(|v| v.as_usize()).unwrap_or(0),
            prefetch: c.get("prefetch").and_then(|v| v.as_bool())
                .unwrap_or(false),
            generated: c.req("generated")?.as_u64().unwrap_or(0),
            completed: c.req("completed")?.as_u64().unwrap_or(0),
            sla_met: c.req("sla_met")?.as_u64().unwrap_or(0),
            sla_attainment: c.req("sla_attainment")?.as_f64()
                .unwrap_or(0.0),
            latency_mean_s: c.req("latency_mean_s")?.as_f64()
                .unwrap_or(0.0),
            latency_p50_s: c.req("latency_p50_s")?.as_f64()
                .unwrap_or(0.0),
            latency_p90_s: c.req("latency_p90_s")?.as_f64()
                .unwrap_or(0.0),
            latency_p99_s: c.req("latency_p99_s")?.as_f64()
                .unwrap_or(0.0),
            latency_max_s: c.req("latency_max_s")?.as_f64()
                .unwrap_or(0.0),
            throughput_rps: c.req("throughput_rps")?.as_f64()
                .unwrap_or(0.0),
            processing_rate_rps: c.req("processing_rate_rps")?.as_f64()
                .unwrap_or(0.0),
            gpu_util: c.req("gpu_util")?.as_f64().unwrap_or(0.0),
            swap_count: c.req("swap_count")?.as_u64().unwrap_or(0),
            total_load_s: c.req("total_load_s")?.as_f64().unwrap_or(0.0),
            total_unload_s: c.req("total_unload_s")?.as_f64()
                .unwrap_or(0.0),
            total_exec_s: c.req("total_exec_s")?.as_f64().unwrap_or(0.0),
            total_crypto_s: c.req("total_crypto_s")?.as_f64()
                .unwrap_or(0.0),
            total_crypto_exposed_s: opt_f64("total_crypto_exposed_s",
                                            0.0),
            prefetch_count: opt_u64("prefetch_count"),
            promoted_count: opt_u64("promoted_count"),
            mean_load_s: c.req("mean_load_s")?.as_f64().unwrap_or(0.0),
            total_bridge_s: opt_f64("total_bridge_s", 0.0),
            total_data_crypto_s: opt_f64("total_data_crypto_s", 0.0),
            total_data_crypto_exposed_s:
                opt_f64("total_data_crypto_exposed_s", 0.0),
            data_bytes: opt_u64("data_bytes"),
            data_wire_bytes: opt_u64("data_wire_bytes"),
            pp_stages: c.get("pp_stages").and_then(|v| v.as_usize())
                .unwrap_or(1),
            ttft_mean_s: opt_f64("ttft_mean_s", 0.0),
            token_throughput_tps: opt_f64("token_throughput_tps", 0.0),
            total_bubble_s: opt_f64("total_bubble_s", 0.0),
            activation_bytes: opt_u64("activation_bytes"),
            activation_wire_bytes: opt_u64("activation_wire_bytes"),
            total_activation_io_s: opt_f64("total_activation_io_s", 0.0),
            total_activation_crypto_s:
                opt_f64("total_activation_crypto_s", 0.0),
            total_activation_crypto_exposed_s:
                opt_f64("total_activation_crypto_exposed_s", 0.0),
            per_device: c.get("per_device").and_then(|v| v.as_arr())
                .map(|arr| arr.iter().map(DeviceSummary::from_json)
                     .collect())
                .unwrap_or_default(),
            tenancy: c.get("tenancy").map(TenancySummary::from_json),
            phase_totals: c.get("phase_totals")
                .map(crate::obs::PhaseTotals::from_json),
        })
    }

    /// One-line human summary.
    pub fn brief(&self) -> String {
        let fleet = if self.devices > 1 {
            format!(" devs={}({})", self.devices, self.placement)
        } else {
            String::new()
        };
        let mut pipe = String::new();
        if self.pipeline_depth >= 2 {
            pipe.push_str(&format!(" pipe={}", self.pipeline_depth));
        }
        if self.prefetch {
            pipe.push_str(&format!(" promo={}/{}", self.promoted_count,
                                   self.swap_count));
        }
        if self.total_bridge_s > 0.0 {
            pipe.push_str(&format!(" bridge={:.2}s", self.total_bridge_s));
        }
        if self.pp_stages > 1 {
            pipe.push_str(&format!(
                " pp={} ttft={:.2}s tok={:.1}tps bub={:.2}s",
                self.pp_stages, self.ttft_mean_s,
                self.token_throughput_tps, self.total_bubble_s));
        }
        if self.total_data_crypto_s > 0.0 {
            pipe.push_str(&format!(" dio={:.2}s",
                                   self.total_data_crypto_exposed_s));
        }
        if let Some(t) = &self.tenancy {
            pipe.push_str(&format!(" shed={} good={:.2}rps fair={:.2}",
                                   t.shed_total, t.goodput_rps,
                                   t.fairness));
        }
        format!(
            "{:<6} {:<7} {:<26} sla={:<4} gen={:<5} done={:<5} \
             att={:>5.1}% lat(mean/p99)={:.2}/{:.2}s thr={:.2}rps \
             util={:>4.1}% swaps={}{}{}",
            self.mode, self.pattern, self.strategy, self.sla_s,
            self.generated, self.completed, self.sla_attainment * 100.0,
            self.latency_mean_s, self.latency_p99_s, self.throughput_rps,
            self.gpu_util * 100.0, self.swap_count, fleet, pipe)
    }
}

/// Assemble the summary from a finished run's accounting — the single
/// home of the paper's metric definitions, shared by every backend.
/// `dev_stats`/`dev_modes` carry one entry per fleet device.
/// `tenancy` is pre-assembled by the engine (None for plain runs, so
/// the block never appears in pre-tenancy summaries).
#[allow(clippy::too_many_arguments)]
pub(crate) fn summarize(cfg: &RunConfig, generated: u64, runtime_s: f64,
                        recorder: &Recorder, sla: &SlaTracker,
                        dev_stats: &[SwapStats], dev_modes: &[CcMode],
                        tenancy: Option<TenancySummary>)
                        -> RunSummary {
    let h = &recorder.latency_hist;
    let completed = recorder.requests.len() as u64;
    let exec_busy = recorder.exec_busy_s();
    let n_dev = dev_modes.len().max(1);

    // fleet aggregates across devices
    let swap_count: u64 = dev_stats.iter().map(|s| s.swap_count).sum();
    let total_load_s: f64 = dev_stats.iter().map(|s| s.total_load_s).sum();
    let total_unload_s: f64 =
        dev_stats.iter().map(|s| s.total_unload_s).sum();
    let total_crypto_s: f64 =
        dev_stats.iter().map(|s| s.total_crypto_s).sum();
    let total_crypto_exposed_s: f64 =
        dev_stats.iter().map(|s| s.total_crypto_exposed_s).sum();
    let prefetch_count: u64 =
        dev_stats.iter().map(|s| s.prefetch_count).sum();
    let promoted_count: u64 =
        dev_stats.iter().map(|s| s.promoted_count).sum();
    let total_bridge_s: f64 =
        dev_stats.iter().map(|s| s.total_bridge_s).sum();

    // inference-data-path accounting, one pass over the per-batch
    // records (all zero with `--data-path off`): per-device
    // (bytes, crypto, exposed) triples plus the fleet wire total
    let mut dev_data = vec![(0u64, 0.0f64, 0.0f64); n_dev];
    let mut data_wire_bytes = 0u64;
    for b in &recorder.batches {
        if let Some(t) = dev_data.get_mut(b.device) {
            t.0 += b.data_bytes;
            t.1 += b.data_crypto_s;
            t.2 += b.data_crypto_exposed_s;
        }
        data_wire_bytes += b.data_wire_bytes;
    }
    let data_bytes: u64 = dev_data.iter().map(|t| t.0).sum();
    let total_data_crypto_s: f64 = dev_data.iter().map(|t| t.1).sum();
    let total_data_crypto_exposed_s: f64 =
        dev_data.iter().map(|t| t.2).sum();

    // heterogeneous fleets report "mixed"
    let mode = match dev_modes.split_first() {
        Some((first, rest)) if rest.iter().any(|m| m != first) =>
            "mixed".to_string(),
        Some((first, _)) => first.as_str().to_string(),
        None => cfg.mode.as_str().to_string(),
    };

    let per_device: Vec<DeviceSummary> = (0..n_dev).map(|d| {
        let exec_s = recorder.exec_busy_s_for(d);
        let batches = recorder.batches.iter()
            .filter(|b| b.device == d).count() as u64;
        let dev_completed = recorder.requests.iter()
            .filter(|(c, _)| c.device == d).count() as u64;
        let stats = dev_stats.get(d).cloned().unwrap_or_default();
        DeviceSummary {
            device: d,
            mode: dev_modes.get(d).map(|m| m.as_str())
                .unwrap_or(cfg.mode.as_str()).to_string(),
            batches,
            completed: dev_completed,
            exec_s,
            util: if runtime_s > 0.0 {
                (exec_s / runtime_s).min(1.0)
            } else {
                0.0
            },
            swap_count: stats.swap_count,
            load_s: stats.total_load_s,
            unload_s: stats.total_unload_s,
            crypto_s: stats.total_crypto_s,
            crypto_exposed_s: stats.total_crypto_exposed_s,
            prefetches: stats.prefetch_count,
            promotions: stats.promoted_count,
            bridge_s: stats.total_bridge_s,
            data_bytes: dev_data[d].0,
            data_crypto_s: dev_data[d].1,
            data_crypto_exposed_s: dev_data[d].2,
        }
    }).collect();

    RunSummary {
        label: cfg.label.clone(),
        mode,
        pattern: cfg.pattern.clone(),
        strategy: cfg.strategy.clone(),
        sla_s: cfg.sla_s,
        mean_rps: cfg.mean_rps,
        duration_s: cfg.duration_s,
        runtime_s,
        seed: cfg.seed,
        devices: n_dev,
        placement: cfg.placement.clone(),
        pipeline_depth: cfg.gpu.pipeline_depth,
        prefetch: cfg.prefetch,
        generated,
        completed,
        sla_met: sla.met(),
        sla_attainment: sla.attainment(),
        latency_mean_s: h.mean(),
        latency_p50_s: h.quantile(0.5),
        latency_p90_s: h.quantile(0.9),
        latency_p99_s: h.quantile(0.99),
        latency_max_s: h.max(),
        throughput_rps: if runtime_s > 0.0 {
            completed as f64 / runtime_s
        } else {
            0.0
        },
        processing_rate_rps: if exec_busy > 0.0 {
            completed as f64 / exec_busy
        } else {
            0.0
        },
        // utilization over the reported runtime, averaged over the
        // fleet (exec share of the run, Fig 7's metric); each device's
        // own share is in per_device
        gpu_util: if runtime_s > 0.0 {
            (exec_busy / (runtime_s * n_dev as f64)).min(1.0)
        } else {
            0.0
        },
        swap_count,
        total_load_s,
        total_unload_s,
        total_exec_s: exec_busy,
        total_crypto_s,
        total_crypto_exposed_s,
        prefetch_count,
        promoted_count,
        mean_load_s: if swap_count > 0 {
            total_load_s / swap_count as f64
        } else {
            0.0
        },
        total_bridge_s,
        total_data_crypto_s,
        total_data_crypto_exposed_s,
        data_bytes,
        data_wire_bytes,
        // pipeline-parallel aggregates: attached by the engine after
        // summarize, only on sharded runs
        pp_stages: 1,
        ttft_mean_s: 0.0,
        token_throughput_tps: 0.0,
        total_bubble_s: 0.0,
        activation_bytes: 0,
        activation_wire_bytes: 0,
        total_activation_io_s: 0.0,
        total_activation_crypto_s: 0.0,
        total_activation_crypto_exposed_s: 0.0,
        per_device,
        tenancy,
        // attached by the engine after summarize, only when a trace
        // was recorded
        phase_totals: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_preserves_every_field() {
        let s = RunSummary {
            label: "cc_gamma_best-batch_sla12".into(),
            mode: "cc".into(),
            pattern: "gamma".into(),
            strategy: "best-batch".into(),
            sla_s: 12.0,
            mean_rps: 9.0,
            duration_s: 60.0,
            runtime_s: 63.5,
            seed: 44,
            devices: 2,
            placement: "least-loaded".into(),
            pipeline_depth: 2,
            prefetch: true,
            generated: 540,
            completed: 500,
            sla_met: 450,
            sla_attainment: 450.0 / 540.0,
            latency_mean_s: 3.25,
            latency_p99_s: 9.5,
            throughput_rps: 7.87,
            processing_rate_rps: 30.0,
            gpu_util: 0.41,
            swap_count: 17,
            total_load_s: 12.5,
            total_crypto_s: 5.0,
            total_crypto_exposed_s: 0.75,
            prefetch_count: 6,
            promoted_count: 4,
            total_data_crypto_s: 1.5,
            total_data_crypto_exposed_s: 0.25,
            data_bytes: 123_456,
            data_wire_bytes: 131_072,
            per_device: vec![DeviceSummary {
                device: 1,
                mode: "cc".into(),
                batches: 40,
                completed: 250,
                exec_s: 20.0,
                util: 0.31,
                swap_count: 9,
                load_s: 7.0,
                crypto_s: 5.0,
                crypto_exposed_s: 0.75,
                prefetches: 6,
                promotions: 4,
                data_bytes: 123_456,
                data_crypto_s: 1.5,
                data_crypto_exposed_s: 0.25,
                ..DeviceSummary::default()
            }],
            ..RunSummary::default()
        };
        let back = RunSummary::from_json(&s.to_json()).unwrap();
        assert_eq!(back.label, s.label);
        assert_eq!(back.seed, 44);
        assert_eq!(back.devices, 2);
        assert_eq!(back.placement, "least-loaded");
        assert_eq!(back.pipeline_depth, 2);
        assert!(back.prefetch);
        assert_eq!(back.swap_count, 17);
        assert_eq!(back.prefetch_count, 6);
        assert_eq!(back.promoted_count, 4);
        assert!((back.sla_attainment - s.sla_attainment).abs() < 1e-12);
        assert!((back.total_crypto_exposed_s - 0.75).abs() < 1e-12);
        assert!((back.total_data_crypto_s - 1.5).abs() < 1e-12);
        assert!((back.total_data_crypto_exposed_s - 0.25).abs() < 1e-12);
        assert_eq!(back.data_bytes, 123_456);
        assert_eq!(back.data_wire_bytes, 131_072);
        assert_eq!(back.per_device.len(), 1);
        assert_eq!(back.per_device[0].device, 1);
        assert_eq!(back.per_device[0].promotions, 4);
        assert_eq!(back.per_device[0].data_bytes, 123_456);
        assert!((back.per_device[0].data_crypto_s - 1.5).abs() < 1e-12);
        assert!((back.per_device[0].util - 0.31).abs() < 1e-12);
    }

    /// The data-path keys are present exactly when the run priced CC
    /// batch I/O — a zero-crypto summary serializes without them, so
    /// `--data-path off` (and No-CC with it on) cannot change a single
    /// output byte.
    #[test]
    fn data_path_keys_absent_when_unused() {
        let off = RunSummary {
            per_device: vec![DeviceSummary::default()],
            ..RunSummary::default()
        };
        let text = off.to_json().to_string();
        assert!(!text.contains("data_"), "unexpected data keys: {text}");
        let on = RunSummary {
            total_data_crypto_s: 0.5,
            total_data_crypto_exposed_s: 0.5,
            data_bytes: 1000,
            data_wire_bytes: 1080,
            per_device: vec![DeviceSummary {
                data_bytes: 1000,
                data_crypto_s: 0.5,
                data_crypto_exposed_s: 0.5,
                ..DeviceSummary::default()
            }],
            ..RunSummary::default()
        };
        let text = on.to_json().to_string();
        assert!(text.contains("total_data_crypto_s"), "{text}");
        assert!(text.contains("data_wire_bytes"), "{text}");
        assert!(text.contains("\"data_crypto_s\""), "{text}");
        let back = RunSummary::from_json(&on.to_json()).unwrap();
        assert_eq!(back.data_bytes, 1000);
        assert_eq!(back.per_device[0].data_bytes, 1000);
        assert!((back.per_device[0].data_crypto_exposed_s - 0.5).abs()
                < 1e-12);
        // degenerate crypto-free pricing (--cc-crypto-frac 0): both
        // levels still report, on the same bytes-based gate
        let frac0 = RunSummary {
            data_bytes: 1000,
            data_wire_bytes: 1080,
            per_device: vec![DeviceSummary {
                data_bytes: 1000,
                ..DeviceSummary::default()
            }],
            ..RunSummary::default()
        };
        let text = frac0.to_json().to_string();
        assert!(text.contains("\"data_bytes\""), "{text}");
        assert!(text.contains("\"data_crypto_s\""),
                "per-device block must not drop out when crypto is \
                 zero but bytes moved: {text}");
    }

    /// Bridge mirror of the data-path contract: the residual keys
    /// appear only when a hardware profile actually accumulated one,
    /// and a populated figure round-trips losslessly.
    #[test]
    fn bridge_keys_absent_when_unused_and_roundtrip() {
        let off = RunSummary {
            per_device: vec![DeviceSummary::default()],
            ..RunSummary::default()
        };
        let text = off.to_json().to_string();
        assert!(!text.contains("bridge"), "leaked bridge key: {text}");

        let on = RunSummary {
            total_bridge_s: 1.4,
            per_device: vec![DeviceSummary {
                bridge_s: 1.4,
                ..DeviceSummary::default()
            }],
            ..RunSummary::default()
        };
        let text = on.to_json().to_string();
        assert!(text.contains("\"total_bridge_s\"")
                && text.contains("\"bridge_s\""), "{text}");
        let back = RunSummary::from_json(&on.to_json()).unwrap();
        assert!((back.total_bridge_s - 1.4).abs() < 1e-12);
        assert!((back.per_device[0].bridge_s - 1.4).abs() < 1e-12);
    }

    /// Pipeline-parallel mirror of the data-path contract: the whole
    /// block appears only when the run sharded (stage count > 1), and
    /// a populated block round-trips losslessly.
    #[test]
    fn pp_keys_absent_when_unused_and_roundtrip() {
        let off = RunSummary {
            pp_stages: 1,
            per_device: vec![DeviceSummary::default()],
            ..RunSummary::default()
        };
        let text = off.to_json().to_string();
        assert!(!text.contains("pp_stages") && !text.contains("ttft")
                && !text.contains("activation")
                && !text.contains("bubble"),
                "leaked pp keys: {text}");

        let on = RunSummary {
            pp_stages: 4,
            ttft_mean_s: 1.5,
            token_throughput_tps: 220.0,
            total_bubble_s: 3.75,
            activation_bytes: 65_536,
            activation_wire_bytes: 66_200,
            total_activation_io_s: 0.8,
            total_activation_crypto_s: 0.4,
            total_activation_crypto_exposed_s: 0.1,
            ..RunSummary::default()
        };
        let text = on.to_json().to_string();
        assert!(text.contains("\"pp_stages\"")
                && text.contains("\"ttft_mean_s\"")
                && text.contains("\"total_bubble_s\"")
                && text.contains("\"activation_wire_bytes\""), "{text}");
        let back = RunSummary::from_json(&on.to_json()).unwrap();
        assert_eq!(back.pp_stages, 4);
        assert!((back.ttft_mean_s - 1.5).abs() < 1e-12);
        assert!((back.token_throughput_tps - 220.0).abs() < 1e-12);
        assert!((back.total_bubble_s - 3.75).abs() < 1e-12);
        assert_eq!(back.activation_bytes, 65_536);
        assert_eq!(back.activation_wire_bytes, 66_200);
        assert!((back.total_activation_io_s - 0.8).abs() < 1e-12);
        assert!((back.total_activation_crypto_s - 0.4).abs() < 1e-12);
        assert!((back.total_activation_crypto_exposed_s - 0.1).abs()
                < 1e-12);
        // a legacy file with no pp key parses back to "off"
        let legacy = RunSummary::from_json(&off.to_json()).unwrap();
        assert_eq!(legacy.pp_stages, 1);
    }

    /// Tenancy mirror of the data-path contract: the key appears only
    /// when the engine attached a block, and a populated block
    /// round-trips losslessly.
    #[test]
    fn tenancy_keys_absent_when_unused_and_roundtrip() {
        let off = RunSummary {
            per_device: vec![DeviceSummary::default()],
            ..RunSummary::default()
        };
        let text = off.to_json().to_string();
        assert!(!text.contains("tenancy"), "leaked tenancy key: {text}");
        assert!(!text.contains("shed") && !text.contains("goodput"),
                "leaked tenancy sub-keys: {text}");

        let on = RunSummary {
            tenancy: Some(TenancySummary {
                admission: "class-weighted".into(),
                shed_total: 17,
                goodput_rps: 3.25,
                fairness: 0.91,
                classes: vec![ClassSummary {
                    name: "gold".into(),
                    generated: 40,
                    completed: 38,
                    met: 36,
                    shed: 1,
                    expired: 1,
                    attainment: 0.9,
                }],
                churn_by_model: vec![("gemma-sim".into(), 3),
                                     ("llama-sim".into(), 5)],
            }),
            ..RunSummary::default()
        };
        let text = on.to_json().to_string();
        assert!(text.contains("\"tenancy\"")
                && text.contains("\"goodput_rps\"")
                && text.contains("\"shed_total\""), "{text}");
        let back = RunSummary::from_json(&on.to_json()).unwrap();
        let t = back.tenancy.expect("tenancy block must parse back");
        assert_eq!(t.admission, "class-weighted");
        assert_eq!(t.shed_total, 17);
        assert!((t.goodput_rps - 3.25).abs() < 1e-12);
        assert!((t.fairness - 0.91).abs() < 1e-12);
        assert_eq!(t.classes.len(), 1);
        assert_eq!(t.classes[0].name, "gold");
        assert_eq!(t.classes[0].shed, 1);
        assert!((t.classes[0].attainment - 0.9).abs() < 1e-12);
        assert_eq!(t.churn_by_model,
                   vec![("gemma-sim".to_string(), 3),
                        ("llama-sim".to_string(), 5)]);
    }

    /// Trace mirror of the data-path contract: the `phase_totals` key
    /// appears only when the engine attached the trace aggregate, and
    /// a populated block round-trips losslessly.
    #[test]
    fn phase_totals_keys_absent_when_unused_and_roundtrip() {
        let off = RunSummary {
            per_device: vec![DeviceSummary::default()],
            ..RunSummary::default()
        };
        let text = off.to_json().to_string();
        assert!(!text.contains("phase"), "leaked phase key: {text}");
        assert!(!text.contains("queue_wait"),
                "leaked phase sub-keys: {text}");

        let on = RunSummary {
            phase_totals: Some(crate::obs::PhaseTotals {
                requests: 120,
                queue_wait_s: 14.0,
                swap_unload_s: 0.3,
                swap_load_s: 21.5,
                swap_bridge_s: 2.5,
                swap_crypto_exposed_s: 4.0,
                exec_s: 30.0,
                io_s: 0.9,
                activation_io_s: 0.0,
                latency_s: 66.7,
                queue_wait_p95_s: 0.4,
                swap_load_p95_s: 1.9,
                exec_p95_s: 0.35,
            }),
            ..RunSummary::default()
        };
        let text = on.to_json().to_string();
        assert!(text.contains("\"phase_totals\"")
                && text.contains("\"queue_wait_s\"")
                && text.contains("\"swap_bridge_s\""), "{text}");
        let back = RunSummary::from_json(&on.to_json()).unwrap();
        let p = back.phase_totals.expect("phase block must parse back");
        assert_eq!(p, on.phase_totals.unwrap());
        assert_eq!(p.requests, 120);
        assert!((p.swap_load_s - 21.5).abs() < 1e-12);
        assert!((p.queue_wait_p95_s - 0.4).abs() < 1e-12);
    }

    /// Seeds above 2^53 cannot ride an f64; the string fallback keeps
    /// the round-trip lossless.
    #[test]
    fn huge_seeds_roundtrip_losslessly() {
        let s = RunSummary { seed: u64::MAX - 1, ..Default::default() };
        let back = RunSummary::from_json(&s.to_json()).unwrap();
        assert_eq!(back.seed, u64::MAX - 1);
        let small = RunSummary { seed: 44, ..Default::default() };
        assert_eq!(RunSummary::from_json(&small.to_json()).unwrap().seed,
                   44);
    }

    /// Summary files from before the fleet/pipeline/seed fields must
    /// still parse, with those fields defaulted.
    #[test]
    fn legacy_summary_files_parse() {
        let mut j = RunSummary::default().to_json();
        if let Json::Obj(m) = &mut j {
            for k in ["seed", "devices", "placement", "pipeline_depth",
                      "prefetch", "total_crypto_exposed_s",
                      "prefetch_count", "promoted_count", "per_device"] {
                m.remove(k);
            }
        }
        let back = RunSummary::from_json(&j).unwrap();
        assert_eq!(back.seed, 0);
        assert_eq!(back.devices, 1);
        assert_eq!(back.placement, "affinity");
        assert!(!back.prefetch);
        assert!(back.per_device.is_empty());
    }
}
