//! The `ExecBackend` seam: what it means to *execute* a scheduling
//! decision on a fleet device.
//!
//! The engine owns ingest, queues, strategy + placement, per-device
//! busy-until timelines, SLA accounting and the `RunSummary`; a backend
//! owns residency, execution and occupancy/crypto accounting for N
//! devices addressed by id.  Two implementations ship:
//!
//! * [`crate::engine::RealBackend`] — a `DeviceSet` of `SimGpu`s +
//!   `Registry` + one `SwapManager` per device: real DMA (optionally
//!   CC-sealed), real PJRT execution.
//! * [`crate::engine::DesBackend`] — the calibrated [`CostModel`]:
//!   every cost is a table lookup, virtual time only.
//!
//! Time protocol: in wall-clock runs costs simply elapse inside the
//! backend calls.  In virtual-time runs the backend *reports* modeled
//! costs in [`SwapOutcome`]/[`BatchOutcome`] and never advances the
//! clock — the engine folds the costs into the dispatched device's
//! busy-until timeline, which is what lets N devices execute
//! concurrently in virtual time.
//!
//! Future backends (trace replay, remote pools) implement this trait
//! instead of hand-rolling another serve loop.
//!
//! [`CostModel`]: crate::sim::CostModel

use std::sync::Arc;

use crate::coordinator::queues::ModelQueues;
use crate::coordinator::request::Request;
use crate::coordinator::swap::SwapStats;
use crate::engine::clock::Clock;
use crate::gpu::device::GpuConfig;
use crate::gpu::CcMode;
use crate::runtime::{ModelId, ModelTable};
use crate::sim::calib::{CostModel, ModelCosts};

/// Timing of one residency change, in the run's time domain.
#[derive(Debug, Clone, Copy, Default)]
pub struct SwapOutcome {
    /// True if a load (and possibly an unload) actually happened.
    pub swapped: bool,
    /// True when the load promoted a prefetched (staged) buffer —
    /// `load_s` is then zero: no second DMA.
    pub promoted: bool,
    /// True when a wrong-prediction staged buffer was discarded.
    pub dropped_staged: bool,
    pub load_s: f64,
    pub unload_s: f64,
    /// Total modeled crypto work of the load (CC only).
    pub crypto_total_s: f64,
    /// Crypto time not hidden behind the DMA pipeline (== total when
    /// the pipeline is off; see `gpu::dma`).
    pub crypto_exposed_s: f64,
    /// Per-swap bridge/attestation residual slice of `load_s`
    /// (hardware-profile devices in CC mode only; 0 elsewhere).  An
    /// attribution term for the trace layer — already included in
    /// `load_s`, never added on top.
    pub bridge_s: f64,
}

/// Result of one decrypt-ahead staging attempt (predictive prefetch).
#[derive(Debug, Clone, Copy, Default)]
pub struct PrefetchOutcome {
    /// True when the model was staged.  The engine overlaps `cost_s`
    /// with the executing batch on the device timeline.
    pub staged: bool,
    /// Staging cost, seconds (a load without an unload).
    pub cost_s: f64,
    /// True when an older staged model was discarded to restage.
    pub dropped_staged: bool,
}

/// Payload I/O of one batch under the CC-priced inference data path
/// (`--data-path on`): the batch's request/response bytes cross the
/// same serialized — or pipelined — bounce path as model loads.
/// All-zero (the `Default`) when the data path is off.
#[derive(Debug, Clone, Copy, Default)]
pub struct DataPathOutcome {
    /// Modeled seconds of the request-in + response-out transfers
    /// (already folded into `BatchOutcome::io_s`).
    pub io_s: f64,
    /// Total modeled seal/open work of both transfers (CC only).
    pub crypto_total_s: f64,
    /// Crypto time not hidden behind the link (== total when the
    /// chunk pipeline is off; see `gpu::dma`).
    pub crypto_exposed_s: f64,
    /// Payload bytes moved, request + response.
    pub bytes: u64,
    /// Bytes on the link including per-chunk AEAD framing
    /// (`gpu::cc::wire_bytes`; == `bytes` in No-CC).
    pub wire_bytes: u64,
}

/// Inter-stage activation accounting of one pipeline-parallel batch,
/// aggregated over every link crossing of every microbatch.  Zeroes at
/// stage count 1 (no links).  `io_s` is already folded into
/// `BatchOutcome::io_s`; the crypto terms are attribution slices
/// *within* it, never added on top — same contract as
/// [`DataPathOutcome`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ActivationOutcome {
    pub io_s: f64,
    /// Total seal/open work on CC links (0 on plain/coherent links).
    pub crypto_total_s: f64,
    /// Crypto time not hidden behind the link pipeline.
    pub crypto_exposed_s: f64,
    /// Raw activation bytes moved between stages.
    pub bytes: u64,
    /// Bytes on the wire including per-chunk `nonce‖ct‖tag` framing
    /// on sealed links (== `bytes` on plain/coherent links).
    pub wire_bytes: u64,
}

/// Pipeline-parallel pricing of one batch (`--pp-stages` > 1 only;
/// `None` in `BatchOutcome` otherwise — the single-stage path carries
/// no trace of this struct).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PipelineBatch {
    /// Stage count the batch was priced at.
    pub stages: usize,
    /// Compute makespan of the microbatched pipeline — what the batch
    /// `exec_s` becomes (`Σ τ_i + (M−1) × max τ_i`; == the plain
    /// `exec_s` at one stage).
    pub makespan_s: f64,
    /// Pipeline-fill latency of the first microbatch: one traversal
    /// of every stage's compute slice plus every link — the exec-side
    /// component of TTFT.
    pub first_out_s: f64,
    /// Device-seconds the stage group idled due to stage imbalance:
    /// `stages × makespan − exec_total` (0 at one stage).
    pub bubble_s: f64,
    /// Compute seconds per stage over the whole batch
    /// (`exec_total × share_i`), in stage order — the per-stage spans.
    pub per_stage_exec_s: Vec<f64>,
    /// Inter-stage activation accounting.
    pub activation: ActivationOutcome,
    /// Decode tokens the batch represents (throughput numerator).
    pub tokens: u64,
}

/// One executed batch, in the run's time domain.
///
/// The batch's requests are not carried here: `execute_batch` drains
/// them into the caller-provided buffer, which the engine recycles
/// across batches so the steady-state loop allocates nothing per
/// dispatch.
#[derive(Debug, Clone)]
pub struct BatchOutcome {
    /// Generated tokens per request row (real execution only; empty
    /// when the backend models cost without producing output).
    pub tokens: Vec<Vec<i32>>,
    /// Artifact batch size used (>= requests.len()).
    pub artifact_batch: usize,
    /// When execution began, on the engine's clock (wall runs only;
    /// in virtual time the engine computes the device timeline from
    /// the reported costs and ignores this).
    pub exec_start_s: f64,
    pub exec_s: f64,
    pub io_s: f64,
    /// Data-path accounting for this batch (zeroes when off).
    pub data: DataPathOutcome,
    /// Pipeline-parallel pricing (`None` = single-stage batch).
    pub pp: Option<PipelineBatch>,
}

/// One modeled residency change, as a virtual-cost backend observed it
/// (what happened is the backend's business; what it *costs* is not).
pub(crate) struct SwapEvent {
    pub model: ModelId,
    pub had_resident: bool,
    pub promoted: bool,
    pub dropped_staged: bool,
}

/// True when `gpu` carries no profile-only pricing term (UMA, bridge
/// residual, non-unit CC excess scale) — every legacy knob
/// combination.  Legacy devices must keep the exact original
/// `load_s_for` arithmetic so their outputs stay byte-identical: the
/// profile arithmetic below never runs for them (no
/// `plain + 1.0·(cc − plain) + 0.0` float detours).
fn legacy_pricing(gpu: &GpuConfig) -> bool {
    !gpu.uma && gpu.bridge_residual_s == 0.0 && gpu.cc_excess_scale == 1.0
}

/// The per-swap bridge residual in force on `gpu` (0 in No-CC mode;
/// legacy devices carry `bridge_residual_s = 0` anyway).
fn bridge_s(gpu: &GpuConfig) -> f64 {
    match gpu.mode {
        CcMode::On => gpu.bridge_residual_s,
        CcMode::Off => 0.0,
    }
}

/// The modeled demand-load seconds for one swap on `gpu` — the single
/// figure [`price_swap`], [`price_prefetch`] and both backends'
/// `est_load_s` quote, so estimates and prices cannot disagree.
///
/// A UMA/coherent device (GH200-class) loads at the plain figure plus
/// the per-swap bridge constant — there is no bounce path to
/// serialize.  A scaled device (Blackwell-class) keeps
/// `cc_excess_scale` of the CC excess over plain, plus the bridge
/// constant.  Legacy devices take the untouched fast path.
pub(crate) fn swap_load_s(mc: &ModelCosts, gpu: &GpuConfig) -> f64 {
    let pipelined = gpu.pipeline_depth >= 2;
    if gpu.mode == CcMode::Off || legacy_pricing(gpu) {
        return mc.load_s_for(gpu.mode, pipelined);
    }
    let plain = mc.load_s_for(CcMode::Off, pipelined);
    if gpu.uma {
        plain + gpu.bridge_residual_s
    } else {
        let cc = mc.load_s_for(CcMode::On, pipelined);
        plain + gpu.cc_excess_scale * (cc - plain)
            + gpu.bridge_residual_s
    }
}

/// The (total, exposed) load-crypto split matching [`swap_load_s`]:
/// zero on a UMA device (nothing is sealed), scaled by
/// `cc_excess_scale` otherwise.
fn swap_load_crypto(mc: &ModelCosts, gpu: &GpuConfig) -> (f64, f64) {
    let pipelined = gpu.pipeline_depth >= 2;
    if gpu.mode == CcMode::Off || legacy_pricing(gpu) {
        return mc.load_crypto_for(gpu.mode, pipelined);
    }
    if gpu.uma {
        (0.0, 0.0)
    } else {
        let (ct, ce) = mc.load_crypto_for(CcMode::On, pipelined);
        (gpu.cc_excess_scale * ct, gpu.cc_excess_scale * ce)
    }
}

/// Price one residency change from the cost table and fold it into
/// `stats`.  This is the single definition of virtual swap pricing:
/// `DesBackend` and the virtual-costs `RealBackend` both call it, so
/// the exact DES-vs-real parity the tests pin is structural rather
/// than two hand-maintained copies.  The device's own `GpuConfig`
/// carries mode, pipeline capability and the profile pricing terms —
/// all per-device in a mixed fleet.
pub(crate) fn price_swap(mc: &ModelCosts, gpu: &GpuConfig, ev: SwapEvent,
                         stats: &mut SwapStats) -> SwapOutcome {
    let mut out = SwapOutcome {
        swapped: true,
        promoted: ev.promoted,
        dropped_staged: ev.dropped_staged,
        ..Default::default()
    };
    if ev.had_resident {
        out.unload_s = mc.unload_s;
    }
    stats.swap_count += 1;
    stats.total_unload_s += out.unload_s;
    if ev.promoted {
        // promotion is DMA-free: the crypto (and any bridge crossing)
        // was paid — and overlapped — at prefetch time
        stats.promoted_count += 1;
        stats.load_samples.push((ev.model, 0.0));
    } else {
        if ev.dropped_staged {
            stats.dropped_prefetches += 1;
        }
        out.load_s = swap_load_s(mc, gpu);
        let (ct, ce) = swap_load_crypto(mc, gpu);
        out.crypto_total_s = ct;
        out.crypto_exposed_s = ce;
        out.bridge_s = bridge_s(gpu);
        stats.total_load_s += out.load_s;
        stats.total_crypto_s += ct;
        stats.total_crypto_exposed_s += ce;
        stats.total_bridge_s += bridge_s(gpu);
        stats.load_samples.push((ev.model, out.load_s));
    }
    out
}

/// Footprint share of pipeline stage `stage` under a contiguous layer
/// split: over `L = max(n_layers, stages)` layers, the first
/// `L % stages` stages hold one extra layer.  Shares sum to exactly
/// 1.0 in rational terms and `stage_share(_, 1, 0) == 1.0` exactly.
pub(crate) fn stage_share(n_layers: usize, stages: usize,
                          stage: usize) -> f64 {
    let l = n_layers.max(stages).max(1);
    let base = l / stages;
    let extra = l % stages;
    let slice = base + usize::from(stage < extra);
    slice as f64 / l as f64
}

/// All stage shares for a model, in stage order.
pub(crate) fn stage_shares(n_layers: usize, stages: usize) -> Vec<f64> {
    (0..stages).map(|i| stage_share(n_layers, stages, i)).collect()
}

/// [`swap_load_s`] for one layer shard holding `share` of the model:
/// the DMA part scales with the shard's bytes, while the per-swap
/// bridge/attestation residual is a per-*device* constant every stage
/// pays in full.  `share == 1.0` takes the untouched full-model path,
/// so single-stage pricing stays bit-identical.
pub(crate) fn swap_load_s_shard(mc: &ModelCosts, gpu: &GpuConfig,
                                share: f64) -> f64 {
    if share == 1.0 {
        return swap_load_s(mc, gpu);
    }
    let dma = swap_load_s(mc, gpu) - bridge_s(gpu);
    share * dma + bridge_s(gpu)
}

/// The (total, exposed) load-crypto split for one shard — crypto work
/// is proportional to the sealed bytes, i.e. to `share`.
fn swap_load_crypto_shard(mc: &ModelCosts, gpu: &GpuConfig,
                          share: f64) -> (f64, f64) {
    let (ct, ce) = swap_load_crypto(mc, gpu);
    if share == 1.0 {
        (ct, ce)
    } else {
        (share * ct, share * ce)
    }
}

/// Price one stage's shard swap — [`price_swap`] scaled to the
/// shard's footprint share.  Unload scales with the shard too; the
/// bridge residual stays per-stage-constant (each device attests its
/// own crossing).
pub(crate) fn price_swap_shard(mc: &ModelCosts, gpu: &GpuConfig,
                               share: f64, ev: SwapEvent,
                               stats: &mut SwapStats) -> SwapOutcome {
    let mut out = SwapOutcome {
        swapped: true,
        promoted: ev.promoted,
        dropped_staged: ev.dropped_staged,
        ..Default::default()
    };
    if ev.had_resident {
        out.unload_s = if share == 1.0 { mc.unload_s }
                       else { share * mc.unload_s };
    }
    stats.swap_count += 1;
    stats.total_unload_s += out.unload_s;
    if ev.promoted {
        stats.promoted_count += 1;
        stats.load_samples.push((ev.model, 0.0));
    } else {
        if ev.dropped_staged {
            stats.dropped_prefetches += 1;
        }
        out.load_s = swap_load_s_shard(mc, gpu, share);
        let (ct, ce) = swap_load_crypto_shard(mc, gpu, share);
        out.crypto_total_s = ct;
        out.crypto_exposed_s = ce;
        out.bridge_s = bridge_s(gpu);
        stats.total_load_s += out.load_s;
        stats.total_crypto_s += ct;
        stats.total_crypto_exposed_s += ce;
        stats.total_bridge_s += bridge_s(gpu);
        stats.load_samples.push((ev.model, out.load_s));
    }
    out
}

/// Price a whole shard group's swap: every stage is priced (and its
/// device's stats charged) unconditionally — all shards stage
/// atomically or the error propagates before any residency changes —
/// and the returned outcome is the *critical* stage's (stages swap on
/// their own devices in parallel, so the group is ready when the
/// slowest `unload + load` finishes; ties keep the first stage).
/// `stats` holds the group's per-device stats in stage order.
pub(crate) fn price_swap_group(mc: &ModelCosts, gpus: &[GpuConfig],
                               shares: &[f64], ev: SwapEvent,
                               stats: &mut [SwapStats]) -> SwapOutcome {
    debug_assert!(gpus.len() == shares.len()
                  && gpus.len() == stats.len());
    let mut crit: Option<SwapOutcome> = None;
    for (i, gpu) in gpus.iter().enumerate() {
        let out = price_swap_shard(
            mc, gpu, shares[i],
            SwapEvent { model: ev.model,
                        had_resident: ev.had_resident,
                        promoted: ev.promoted,
                        dropped_staged: ev.dropped_staged },
            &mut stats[i]);
        let worse = crit.map_or(true, |c| {
            out.unload_s + out.load_s > c.unload_s + c.load_s
        });
        if worse {
            crit = Some(out);
        }
    }
    crit.unwrap_or_default()
}

/// The group-level load estimate matching [`price_swap_group`]: the
/// slowest stage's shard load.
pub(crate) fn est_load_s_group(mc: &ModelCosts, gpus: &[GpuConfig],
                               shares: &[f64]) -> f64 {
    gpus.iter().zip(shares)
        .map(|(g, &s)| swap_load_s_shard(mc, g, s))
        .fold(0.0, f64::max)
}

/// Price one pipeline-parallel batch: `rows` microbatches of one row
/// each flow through `shares.len()` stages whose compute slices are
/// `exec_total × share_i`, with each microbatch's activation tensor
/// (`d_model × 4` bytes — one row's hidden state) priced per link
/// into the downstream stage's device (`gpus[1..]`; sealed on CC
/// links, plain on No-CC/coherent — see
/// `gpu::profile::price_activation_link`).
///
/// The compute makespan of the microbatched pipeline is
/// `Σ τ_i + (M−1) × max τ_i` with `τ_i = exec_total × share_i / M` —
/// fill the pipe once, then the slowest stage paces every remaining
/// microbatch.  At one stage this collapses to `exec_total` exactly
/// and the bubble is zero.
pub(crate) fn price_pipeline(exec_total: f64, d_model: usize,
                             rows: usize, decode_len: usize,
                             shares: &[f64], gpus: &[GpuConfig])
                             -> PipelineBatch {
    let stages = shares.len().max(1);
    let m = rows.max(1);
    let taus: Vec<f64> =
        shares.iter().map(|s| exec_total * s / m as f64).collect();
    let tau_sum: f64 = taus.iter().sum();
    let tau_max = taus.iter().fold(0.0, |a: f64, &b| a.max(b));
    // one stage has no pipeline: the makespan IS the exec time,
    // bit-for-bit (M × (exec/M) would round)
    let makespan = if stages == 1 { exec_total }
                   else { tau_sum + (m - 1) as f64 * tau_max };
    let bubble = (stages as f64 * makespan - exec_total).max(0.0);

    // per-microbatch activation over each inter-stage link
    let act_bytes = d_model * 4;
    let mut act = ActivationOutcome::default();
    let mut link_s_sum = 0.0;
    for gpu in &gpus[1..] {
        let (io_s, ct, ce, wire) =
            crate::gpu::profile::price_activation_link(gpu, act_bytes);
        link_s_sum += io_s;
        act.io_s += io_s * m as f64;
        act.crypto_total_s += ct * m as f64;
        act.crypto_exposed_s += ce * m as f64;
        act.bytes += act_bytes as u64 * m as u64;
        act.wire_bytes += wire * m as u64;
    }

    PipelineBatch {
        stages,
        makespan_s: makespan,
        first_out_s: tau_sum + link_s_sum,
        bubble_s: bubble,
        per_stage_exec_s:
            shares.iter().map(|s| exec_total * s).collect(),
        activation: act,
        tokens: rows as u64 * decode_len as u64,
    }
}

/// Price one staging upload (a load without an unload) — the prefetch
/// counterpart of [`price_swap`], shared by both virtual-cost backends
/// for the same reason.  A bridge-residual device pays its per-swap
/// constant at staging time (the crossing happens then), which is
/// what keeps a later promotion free.
pub(crate) fn price_prefetch(mc: &ModelCosts, gpu: &GpuConfig,
                             dropped_staged: bool,
                             stats: &mut SwapStats) -> PrefetchOutcome {
    let out = PrefetchOutcome {
        staged: true,
        cost_s: swap_load_s(mc, gpu),
        dropped_staged,
    };
    if dropped_staged {
        stats.dropped_prefetches += 1;
    }
    let (ct, _) = swap_load_crypto(mc, gpu);
    stats.prefetch_count += 1;
    stats.total_prefetch_s += out.cost_s;
    stats.total_crypto_s += ct;
    stats.total_bridge_s += bridge_s(gpu);
    out
}

/// Price one batch's payload I/O through the inference data path.
/// Like [`price_swap`], this is the single definition both
/// virtual-cost backends call, so the exact DES-vs-real parity of the
/// data path is structural rather than two hand-maintained copies.
///
/// In No-CC mode the calibrated per-row figure stays authoritative —
/// the data path models the *CC bounce* penalty, and an unencrypted
/// link has no serialization to expose — so No-CC timings (and
/// therefore summaries) are bit-identical whether the flag is on or
/// off: a No-CC device contributes *no* data-path accounting at all
/// (bytes included), which is what keeps the summary's conditional
/// data-path block byte-identical too.  A UMA/coherent CC device
/// (GH200-class profiles) has no bounce path to seal and prices like
/// No-CC for the same reason.  In (discrete-memory) CC mode each
/// direction is priced from its byte count through the same chunk
/// budget the swap path uses (`gpu::dma::cc_budget_s`), pipeline
/// overlap included, with the total-vs-exposed crypto split accounted
/// per batch.
pub(crate) fn price_data_path(costs: &CostModel, gpu: &GpuConfig,
                              rows: usize, tokens_in: usize,
                              tokens_out: usize) -> DataPathOutcome {
    let bytes_in = rows * 4 * tokens_in;
    let bytes_out = rows * 4 * tokens_out;
    let bytes = (bytes_in + bytes_out) as u64;
    match gpu.mode {
        CcMode::Off => DataPathOutcome {
            io_s: costs.io_s_per_row(CcMode::Off) * rows as f64,
            ..Default::default()
        },
        // coherent memory: payloads are never bounce-sealed either —
        // a UMA CC device prices (and accounts) exactly like No-CC
        CcMode::On if gpu.uma => DataPathOutcome {
            io_s: costs.io_s_per_row(CcMode::Off) * rows as f64,
            ..Default::default()
        },
        CcMode::On => {
            let (in_s, in_ct, in_ce) = crate::gpu::dma::cc_budget_s(
                bytes_in, gpu.bw_cc, gpu.bounce_bytes,
                gpu.pipeline_depth, gpu.cc_crypto_frac);
            let (out_s, out_ct, out_ce) = crate::gpu::dma::cc_budget_s(
                bytes_out, gpu.bw_cc, gpu.bounce_bytes,
                gpu.pipeline_depth, gpu.cc_crypto_frac);
            let wire = crate::gpu::cc::wire_bytes(bytes_in,
                                                  gpu.bounce_bytes)
                + crate::gpu::cc::wire_bytes(bytes_out, gpu.bounce_bytes);
            DataPathOutcome {
                io_s: in_s + out_s,
                crypto_total_s: in_ct + out_ct,
                crypto_exposed_s: in_ce + out_ce,
                bytes,
                wire_bytes: wire as u64,
            }
        }
    }
}

/// Device occupancy published to the monitor thread.
#[derive(Debug, Clone, Default)]
pub struct DeviceSnapshot {
    pub gpu_util: f64,
    pub mem_in_use: u64,
    pub mem_peak: u64,
    pub fragmentation: f64,
    pub dma_h2d_bytes: u64,
    /// Total modeled crypto work so far (see `gpu::dma::DmaStats`).
    pub dma_crypto_total_s: f64,
    /// Crypto time not hidden behind the DMA pipeline.
    pub dma_crypto_exposed_s: f64,
    pub swaps: u64,
}

/// Pluggable execution backend behind the single serve loop.
///
/// Hot-path methods address models by interned [`ModelId`] — the ids
/// of the backend's own [`ModelTable`] (see [`table`]) — so per-tick
/// consultation costs an array index, never a key clone or a hash.
/// Startup-only methods (validation, tokenization) keep `&str`.
///
/// [`table`]: ExecBackend::table
pub trait ExecBackend {
    /// Short backend name for labels/diagnostics ("real" | "des").
    fn kind(&self) -> &'static str;

    /// The intern table every [`ModelId`] this backend understands
    /// comes from.  The engine clones the `Arc` once per run and
    /// interns each arrival's model name exactly once.
    fn table(&self) -> &Arc<ModelTable>;

    /// Number of fleet devices this backend drives.
    fn n_devices(&self) -> usize;

    /// CC mode of `device`.
    fn mode(&self, device: usize) -> CcMode;

    /// Every model this backend can serve, in the backend's native
    /// order (registry/manifest order, not intern order).
    fn model_names(&self) -> Vec<String>;

    /// Fail fast when `model` is unknown to the backend.
    fn check_model(&self, model: &str) -> anyhow::Result<()>;

    /// Tokenize a prompt for `model` (empty when payload content never
    /// reaches the backend, as in the DES).
    fn tokenize_prompt(&self, model: &str, prompt: &str) -> Vec<i32>;

    /// Profiled optimal batch size for `model` (§III-D2).
    fn obs(&self, model: ModelId) -> usize;

    /// Estimated load seconds for `model` in `device`'s CC mode
    /// (SelectBatch's `desired_latency` term).
    fn est_load_s(&self, model: ModelId, device: usize) -> f64;

    /// Seed value for the engine's per-model exec-time EWMA.
    fn initial_exec_est_s(&self, model: ModelId) -> f64;

    /// Model currently resident on `device`, if any.
    fn resident(&self, device: usize) -> Option<ModelId>;

    /// Make `model` resident on `device`, swapping if needed (the
    /// expensive CC-sensitive step).  A staged (prefetched) hit
    /// promotes without a second DMA.
    fn ensure_resident(&mut self, clock: &mut dyn Clock, device: usize,
                       model: ModelId) -> anyhow::Result<SwapOutcome>;

    /// Decrypt-ahead: stage `model` on `device` while the current batch
    /// executes, so a later swap promotes it without a DMA.  Backends
    /// without staging support keep the default no-op.
    fn prefetch(&mut self, _clock: &mut dyn Clock, _device: usize,
                _model: ModelId) -> anyhow::Result<PrefetchOutcome> {
        Ok(PrefetchOutcome::default())
    }

    /// Pop up to `take` requests for `model` into `out_requests`
    /// (appended; the caller clears and recycles the buffer) and
    /// execute them as one batch on `device`.  `Ok(None)` when the
    /// queue was empty — nothing is appended in that case.
    fn execute_batch(&mut self, clock: &mut dyn Clock,
                     queues: &mut ModelQueues, device: usize,
                     model: ModelId, take: usize,
                     out_requests: &mut Vec<Request>)
                     -> anyhow::Result<Option<BatchOutcome>>;

    /// Occupancy counters for `device` (monitor thread).
    fn snapshot(&self, device: usize) -> DeviceSnapshot;

    /// Swap/load/crypto totals for `device` (run summary).
    fn swap_stats(&self, device: usize) -> SwapStats;

    /// End of run: release residency and device state.
    fn teardown(&mut self);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_shares_cover_the_model() {
        // 32 layers over 4 stages: even split
        assert_eq!(stage_shares(32, 4), vec![0.25; 4]);
        // 10 layers over 4 stages: first two stages take the extras
        let s = stage_shares(10, 4);
        assert_eq!(s, vec![0.3, 0.3, 0.2, 0.2]);
        assert!((s.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        // fewer layers than stages: pad to one layer per stage
        let s = stage_shares(2, 4);
        assert!((s.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(s.iter().all(|&x| x > 0.0));
        // single stage is exactly the whole model
        assert_eq!(stage_share(32, 1, 0), 1.0);
    }

    #[test]
    fn single_stage_pipeline_collapses_exactly() {
        let gpu = GpuConfig::default();
        let pp = price_pipeline(0.42, 4096, 7, 128, &[1.0],
                                std::slice::from_ref(&gpu));
        assert_eq!(pp.makespan_s, 0.42,
                   "one stage must reproduce exec_s bit-for-bit");
        assert_eq!(pp.bubble_s, 0.0);
        assert_eq!(pp.activation, ActivationOutcome::default(),
                   "no links, no activation accounting");
        assert_eq!(pp.per_stage_exec_s, vec![0.42]);
    }

    #[test]
    fn pipeline_makespan_and_bubble() {
        let gpus = vec![GpuConfig::default(), GpuConfig::default()];
        // 2 even stages, 4 microbatches: tau = 1.0*0.5/4 = 0.125;
        // makespan = 0.25 + 3*0.125 = 0.625; bubble = 2*0.625 - 1.0
        let pp = price_pipeline(1.0, 4096, 4, 128, &[0.5, 0.5], &gpus);
        assert!((pp.makespan_s - 0.625).abs() < 1e-12);
        assert!((pp.bubble_s - 0.25).abs() < 1e-12);
        assert!(pp.activation.io_s > 0.0, "one link priced 4 times");
        assert_eq!(pp.activation.bytes, 4096 * 4 * 4);
        assert_eq!(pp.tokens, 4 * 128);
        // imbalance costs more: the slow stage paces the pipe
        let skew = price_pipeline(1.0, 4096, 4, 128, &[0.75, 0.25],
                                  &gpus);
        assert!(skew.makespan_s > pp.makespan_s);
        assert!(skew.bubble_s > pp.bubble_s);
        // first-out beats the full makespan once M > 1
        assert!(pp.first_out_s < pp.makespan_s + pp.activation.io_s);
    }

    #[test]
    fn sealed_links_tax_the_activation_path() {
        let plain = GpuConfig::default();
        let cc = GpuConfig { mode: CcMode::On, ..GpuConfig::default() };
        let shares = [0.5, 0.5];
        let a = price_pipeline(1.0, 4096, 4, 128, &shares,
                               &[plain.clone(), plain.clone()]);
        let b = price_pipeline(1.0, 4096, 4, 128, &shares,
                               &[cc.clone(), cc.clone()]);
        assert!(b.activation.io_s > a.activation.io_s,
                "sealed link must cost more than plain");
        assert!(b.activation.crypto_total_s > 0.0);
        assert_eq!(a.activation.crypto_total_s, 0.0);
        assert!(b.activation.wire_bytes > b.activation.bytes,
                "AEAD framing inflates sealed wire bytes");
        assert_eq!(a.activation.wire_bytes, a.activation.bytes);
        assert_eq!(a.makespan_s, b.makespan_s,
                   "links never change the compute makespan");
    }
}
