//! The `ExecBackend` seam: what it means to *execute* a scheduling
//! decision.
//!
//! The engine owns ingest, queues, strategy, SLA accounting and the
//! `RunSummary`; a backend owns residency, execution and
//! occupancy/crypto accounting.  Two implementations ship:
//!
//! * [`crate::engine::RealBackend`] — `SimGpu` + `Registry` +
//!   `SwapManager`: real DMA (optionally CC-sealed), real PJRT
//!   execution.
//! * [`crate::engine::DesBackend`] — the calibrated [`CostModel`]:
//!   every cost is a table lookup, virtual time only.
//!
//! Future backends (multi-GPU sharding, trace replay) implement this
//! trait instead of hand-rolling a third serve loop.
//!
//! [`CostModel`]: crate::sim::CostModel

use crate::coordinator::queues::ModelQueues;
use crate::coordinator::request::Request;
use crate::coordinator::swap::SwapStats;
use crate::engine::clock::Clock;

/// Timing of one residency change, in the run's time domain.
#[derive(Debug, Clone, Copy, Default)]
pub struct SwapOutcome {
    /// True if a load (and possibly an unload) actually happened.
    pub swapped: bool,
    pub load_s: f64,
    pub unload_s: f64,
    /// Crypto share of the load (CC only).
    pub crypto_s: f64,
}

/// One executed batch, in the run's time domain.
#[derive(Debug, Clone)]
pub struct BatchOutcome {
    /// The requests that rode in this batch (popped from the queue).
    pub requests: Vec<Request>,
    /// Generated tokens per request row (real execution only; empty
    /// when the backend models cost without producing output).
    pub tokens: Vec<Vec<i32>>,
    /// Artifact batch size used (>= requests.len()).
    pub artifact_batch: usize,
    /// When execution began, on the engine's clock.
    pub exec_start_s: f64,
    pub exec_s: f64,
    pub io_s: f64,
}

/// Device occupancy published to the monitor thread.
#[derive(Debug, Clone, Default)]
pub struct DeviceSnapshot {
    pub gpu_util: f64,
    pub mem_in_use: u64,
    pub mem_peak: u64,
    pub fragmentation: f64,
    pub dma_h2d_bytes: u64,
    pub dma_crypto_s: f64,
    pub swaps: u64,
}

/// Pluggable execution backend behind the single serve loop.
///
/// Time protocol: methods receive the engine's [`Clock`] and must
/// account their own costs through it — real backends let wall time
/// pass (and call `advance` only when running under virtual costs),
/// the DES backend advances virtual time by table lookups.
pub trait ExecBackend {
    /// Short backend name for labels/diagnostics ("real" | "des").
    fn kind(&self) -> &'static str;

    /// Every model this backend can serve.
    fn model_names(&self) -> Vec<String>;

    /// Fail fast when `model` is unknown to the backend.
    fn check_model(&self, model: &str) -> anyhow::Result<()>;

    /// Tokenize a prompt for `model` (empty when payload content never
    /// reaches the backend, as in the DES).
    fn tokenize_prompt(&self, model: &str, prompt: &str) -> Vec<i32>;

    /// Profiled optimal batch size for `model` (§III-D2).
    fn obs(&self, model: &str) -> usize;

    /// Estimated load seconds for `model` in the current CC mode
    /// (SelectBatch's `desired_latency` term).
    fn est_load_s(&self, model: &str) -> f64;

    /// Seed value for the engine's per-model exec-time EWMA.
    fn initial_exec_est_s(&self, model: &str) -> f64;

    /// Currently resident model, if any.
    fn resident(&self) -> Option<String>;

    /// Make `model` resident, swapping if needed (the expensive
    /// CC-sensitive step).
    fn ensure_resident(&mut self, clock: &mut dyn Clock, model: &str)
                       -> anyhow::Result<SwapOutcome>;

    /// Pop up to `take` requests for `model` and execute them as one
    /// batch.  `Ok(None)` when the queue was empty.
    fn execute_batch(&mut self, clock: &mut dyn Clock,
                     queues: &mut ModelQueues, model: &str, take: usize)
                     -> anyhow::Result<Option<BatchOutcome>>;

    /// Occupancy counters for the monitor thread.
    fn snapshot(&self) -> DeviceSnapshot;

    /// Swap/load/crypto totals for the run summary.
    fn swap_stats(&self) -> SwapStats;

    /// End of run: release residency and device state.
    fn teardown(&mut self);
}
