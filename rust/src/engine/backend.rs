//! The `ExecBackend` seam: what it means to *execute* a scheduling
//! decision on a fleet device.
//!
//! The engine owns ingest, queues, strategy + placement, per-device
//! busy-until timelines, SLA accounting and the `RunSummary`; a backend
//! owns residency, execution and occupancy/crypto accounting for N
//! devices addressed by id.  Two implementations ship:
//!
//! * [`crate::engine::RealBackend`] — a `DeviceSet` of `SimGpu`s +
//!   `Registry` + one `SwapManager` per device: real DMA (optionally
//!   CC-sealed), real PJRT execution.
//! * [`crate::engine::DesBackend`] — the calibrated [`CostModel`]:
//!   every cost is a table lookup, virtual time only.
//!
//! Time protocol: in wall-clock runs costs simply elapse inside the
//! backend calls.  In virtual-time runs the backend *reports* modeled
//! costs in [`SwapOutcome`]/[`BatchOutcome`] and never advances the
//! clock — the engine folds the costs into the dispatched device's
//! busy-until timeline, which is what lets N devices execute
//! concurrently in virtual time.
//!
//! Future backends (trace replay, remote pools) implement this trait
//! instead of hand-rolling another serve loop.
//!
//! [`CostModel`]: crate::sim::CostModel

use std::sync::Arc;

use crate::coordinator::queues::ModelQueues;
use crate::coordinator::request::Request;
use crate::coordinator::swap::SwapStats;
use crate::engine::clock::Clock;
use crate::gpu::device::GpuConfig;
use crate::gpu::CcMode;
use crate::runtime::{ModelId, ModelTable};
use crate::sim::calib::{CostModel, ModelCosts};

/// Timing of one residency change, in the run's time domain.
#[derive(Debug, Clone, Copy, Default)]
pub struct SwapOutcome {
    /// True if a load (and possibly an unload) actually happened.
    pub swapped: bool,
    /// True when the load promoted a prefetched (staged) buffer —
    /// `load_s` is then zero: no second DMA.
    pub promoted: bool,
    /// True when a wrong-prediction staged buffer was discarded.
    pub dropped_staged: bool,
    pub load_s: f64,
    pub unload_s: f64,
    /// Total modeled crypto work of the load (CC only).
    pub crypto_total_s: f64,
    /// Crypto time not hidden behind the DMA pipeline (== total when
    /// the pipeline is off; see `gpu::dma`).
    pub crypto_exposed_s: f64,
    /// Per-swap bridge/attestation residual slice of `load_s`
    /// (hardware-profile devices in CC mode only; 0 elsewhere).  An
    /// attribution term for the trace layer — already included in
    /// `load_s`, never added on top.
    pub bridge_s: f64,
}

/// Result of one decrypt-ahead staging attempt (predictive prefetch).
#[derive(Debug, Clone, Copy, Default)]
pub struct PrefetchOutcome {
    /// True when the model was staged.  The engine overlaps `cost_s`
    /// with the executing batch on the device timeline.
    pub staged: bool,
    /// Staging cost, seconds (a load without an unload).
    pub cost_s: f64,
    /// True when an older staged model was discarded to restage.
    pub dropped_staged: bool,
}

/// Payload I/O of one batch under the CC-priced inference data path
/// (`--data-path on`): the batch's request/response bytes cross the
/// same serialized — or pipelined — bounce path as model loads.
/// All-zero (the `Default`) when the data path is off.
#[derive(Debug, Clone, Copy, Default)]
pub struct DataPathOutcome {
    /// Modeled seconds of the request-in + response-out transfers
    /// (already folded into `BatchOutcome::io_s`).
    pub io_s: f64,
    /// Total modeled seal/open work of both transfers (CC only).
    pub crypto_total_s: f64,
    /// Crypto time not hidden behind the link (== total when the
    /// chunk pipeline is off; see `gpu::dma`).
    pub crypto_exposed_s: f64,
    /// Payload bytes moved, request + response.
    pub bytes: u64,
    /// Bytes on the link including per-chunk AEAD framing
    /// (`gpu::cc::wire_bytes`; == `bytes` in No-CC).
    pub wire_bytes: u64,
}

/// One executed batch, in the run's time domain.
///
/// The batch's requests are not carried here: `execute_batch` drains
/// them into the caller-provided buffer, which the engine recycles
/// across batches so the steady-state loop allocates nothing per
/// dispatch.
#[derive(Debug, Clone)]
pub struct BatchOutcome {
    /// Generated tokens per request row (real execution only; empty
    /// when the backend models cost without producing output).
    pub tokens: Vec<Vec<i32>>,
    /// Artifact batch size used (>= requests.len()).
    pub artifact_batch: usize,
    /// When execution began, on the engine's clock (wall runs only;
    /// in virtual time the engine computes the device timeline from
    /// the reported costs and ignores this).
    pub exec_start_s: f64,
    pub exec_s: f64,
    pub io_s: f64,
    /// Data-path accounting for this batch (zeroes when off).
    pub data: DataPathOutcome,
}

/// One modeled residency change, as a virtual-cost backend observed it
/// (what happened is the backend's business; what it *costs* is not).
pub(crate) struct SwapEvent {
    pub model: ModelId,
    pub had_resident: bool,
    pub promoted: bool,
    pub dropped_staged: bool,
}

/// True when `gpu` carries no profile-only pricing term (UMA, bridge
/// residual, non-unit CC excess scale) — every legacy knob
/// combination.  Legacy devices must keep the exact original
/// `load_s_for` arithmetic so their outputs stay byte-identical: the
/// profile arithmetic below never runs for them (no
/// `plain + 1.0·(cc − plain) + 0.0` float detours).
fn legacy_pricing(gpu: &GpuConfig) -> bool {
    !gpu.uma && gpu.bridge_residual_s == 0.0 && gpu.cc_excess_scale == 1.0
}

/// The per-swap bridge residual in force on `gpu` (0 in No-CC mode;
/// legacy devices carry `bridge_residual_s = 0` anyway).
fn bridge_s(gpu: &GpuConfig) -> f64 {
    match gpu.mode {
        CcMode::On => gpu.bridge_residual_s,
        CcMode::Off => 0.0,
    }
}

/// The modeled demand-load seconds for one swap on `gpu` — the single
/// figure [`price_swap`], [`price_prefetch`] and both backends'
/// `est_load_s` quote, so estimates and prices cannot disagree.
///
/// A UMA/coherent device (GH200-class) loads at the plain figure plus
/// the per-swap bridge constant — there is no bounce path to
/// serialize.  A scaled device (Blackwell-class) keeps
/// `cc_excess_scale` of the CC excess over plain, plus the bridge
/// constant.  Legacy devices take the untouched fast path.
pub(crate) fn swap_load_s(mc: &ModelCosts, gpu: &GpuConfig) -> f64 {
    let pipelined = gpu.pipeline_depth >= 2;
    if gpu.mode == CcMode::Off || legacy_pricing(gpu) {
        return mc.load_s_for(gpu.mode, pipelined);
    }
    let plain = mc.load_s_for(CcMode::Off, pipelined);
    if gpu.uma {
        plain + gpu.bridge_residual_s
    } else {
        let cc = mc.load_s_for(CcMode::On, pipelined);
        plain + gpu.cc_excess_scale * (cc - plain)
            + gpu.bridge_residual_s
    }
}

/// The (total, exposed) load-crypto split matching [`swap_load_s`]:
/// zero on a UMA device (nothing is sealed), scaled by
/// `cc_excess_scale` otherwise.
fn swap_load_crypto(mc: &ModelCosts, gpu: &GpuConfig) -> (f64, f64) {
    let pipelined = gpu.pipeline_depth >= 2;
    if gpu.mode == CcMode::Off || legacy_pricing(gpu) {
        return mc.load_crypto_for(gpu.mode, pipelined);
    }
    if gpu.uma {
        (0.0, 0.0)
    } else {
        let (ct, ce) = mc.load_crypto_for(CcMode::On, pipelined);
        (gpu.cc_excess_scale * ct, gpu.cc_excess_scale * ce)
    }
}

/// Price one residency change from the cost table and fold it into
/// `stats`.  This is the single definition of virtual swap pricing:
/// `DesBackend` and the virtual-costs `RealBackend` both call it, so
/// the exact DES-vs-real parity the tests pin is structural rather
/// than two hand-maintained copies.  The device's own `GpuConfig`
/// carries mode, pipeline capability and the profile pricing terms —
/// all per-device in a mixed fleet.
pub(crate) fn price_swap(mc: &ModelCosts, gpu: &GpuConfig, ev: SwapEvent,
                         stats: &mut SwapStats) -> SwapOutcome {
    let mut out = SwapOutcome {
        swapped: true,
        promoted: ev.promoted,
        dropped_staged: ev.dropped_staged,
        ..Default::default()
    };
    if ev.had_resident {
        out.unload_s = mc.unload_s;
    }
    stats.swap_count += 1;
    stats.total_unload_s += out.unload_s;
    if ev.promoted {
        // promotion is DMA-free: the crypto (and any bridge crossing)
        // was paid — and overlapped — at prefetch time
        stats.promoted_count += 1;
        stats.load_samples.push((ev.model, 0.0));
    } else {
        if ev.dropped_staged {
            stats.dropped_prefetches += 1;
        }
        out.load_s = swap_load_s(mc, gpu);
        let (ct, ce) = swap_load_crypto(mc, gpu);
        out.crypto_total_s = ct;
        out.crypto_exposed_s = ce;
        out.bridge_s = bridge_s(gpu);
        stats.total_load_s += out.load_s;
        stats.total_crypto_s += ct;
        stats.total_crypto_exposed_s += ce;
        stats.total_bridge_s += bridge_s(gpu);
        stats.load_samples.push((ev.model, out.load_s));
    }
    out
}

/// Price one staging upload (a load without an unload) — the prefetch
/// counterpart of [`price_swap`], shared by both virtual-cost backends
/// for the same reason.  A bridge-residual device pays its per-swap
/// constant at staging time (the crossing happens then), which is
/// what keeps a later promotion free.
pub(crate) fn price_prefetch(mc: &ModelCosts, gpu: &GpuConfig,
                             dropped_staged: bool,
                             stats: &mut SwapStats) -> PrefetchOutcome {
    let out = PrefetchOutcome {
        staged: true,
        cost_s: swap_load_s(mc, gpu),
        dropped_staged,
    };
    if dropped_staged {
        stats.dropped_prefetches += 1;
    }
    let (ct, _) = swap_load_crypto(mc, gpu);
    stats.prefetch_count += 1;
    stats.total_prefetch_s += out.cost_s;
    stats.total_crypto_s += ct;
    stats.total_bridge_s += bridge_s(gpu);
    out
}

/// Price one batch's payload I/O through the inference data path.
/// Like [`price_swap`], this is the single definition both
/// virtual-cost backends call, so the exact DES-vs-real parity of the
/// data path is structural rather than two hand-maintained copies.
///
/// In No-CC mode the calibrated per-row figure stays authoritative —
/// the data path models the *CC bounce* penalty, and an unencrypted
/// link has no serialization to expose — so No-CC timings (and
/// therefore summaries) are bit-identical whether the flag is on or
/// off: a No-CC device contributes *no* data-path accounting at all
/// (bytes included), which is what keeps the summary's conditional
/// data-path block byte-identical too.  A UMA/coherent CC device
/// (GH200-class profiles) has no bounce path to seal and prices like
/// No-CC for the same reason.  In (discrete-memory) CC mode each
/// direction is priced from its byte count through the same chunk
/// budget the swap path uses (`gpu::dma::cc_budget_s`), pipeline
/// overlap included, with the total-vs-exposed crypto split accounted
/// per batch.
pub(crate) fn price_data_path(costs: &CostModel, gpu: &GpuConfig,
                              rows: usize, tokens_in: usize,
                              tokens_out: usize) -> DataPathOutcome {
    let bytes_in = rows * 4 * tokens_in;
    let bytes_out = rows * 4 * tokens_out;
    let bytes = (bytes_in + bytes_out) as u64;
    match gpu.mode {
        CcMode::Off => DataPathOutcome {
            io_s: costs.io_s_per_row(CcMode::Off) * rows as f64,
            ..Default::default()
        },
        // coherent memory: payloads are never bounce-sealed either —
        // a UMA CC device prices (and accounts) exactly like No-CC
        CcMode::On if gpu.uma => DataPathOutcome {
            io_s: costs.io_s_per_row(CcMode::Off) * rows as f64,
            ..Default::default()
        },
        CcMode::On => {
            let (in_s, in_ct, in_ce) = crate::gpu::dma::cc_budget_s(
                bytes_in, gpu.bw_cc, gpu.bounce_bytes,
                gpu.pipeline_depth, gpu.cc_crypto_frac);
            let (out_s, out_ct, out_ce) = crate::gpu::dma::cc_budget_s(
                bytes_out, gpu.bw_cc, gpu.bounce_bytes,
                gpu.pipeline_depth, gpu.cc_crypto_frac);
            let wire = crate::gpu::cc::wire_bytes(bytes_in,
                                                  gpu.bounce_bytes)
                + crate::gpu::cc::wire_bytes(bytes_out, gpu.bounce_bytes);
            DataPathOutcome {
                io_s: in_s + out_s,
                crypto_total_s: in_ct + out_ct,
                crypto_exposed_s: in_ce + out_ce,
                bytes,
                wire_bytes: wire as u64,
            }
        }
    }
}

/// Device occupancy published to the monitor thread.
#[derive(Debug, Clone, Default)]
pub struct DeviceSnapshot {
    pub gpu_util: f64,
    pub mem_in_use: u64,
    pub mem_peak: u64,
    pub fragmentation: f64,
    pub dma_h2d_bytes: u64,
    /// Total modeled crypto work so far (see `gpu::dma::DmaStats`).
    pub dma_crypto_total_s: f64,
    /// Crypto time not hidden behind the DMA pipeline.
    pub dma_crypto_exposed_s: f64,
    pub swaps: u64,
}

/// Pluggable execution backend behind the single serve loop.
///
/// Hot-path methods address models by interned [`ModelId`] — the ids
/// of the backend's own [`ModelTable`] (see [`table`]) — so per-tick
/// consultation costs an array index, never a key clone or a hash.
/// Startup-only methods (validation, tokenization) keep `&str`.
///
/// [`table`]: ExecBackend::table
pub trait ExecBackend {
    /// Short backend name for labels/diagnostics ("real" | "des").
    fn kind(&self) -> &'static str;

    /// The intern table every [`ModelId`] this backend understands
    /// comes from.  The engine clones the `Arc` once per run and
    /// interns each arrival's model name exactly once.
    fn table(&self) -> &Arc<ModelTable>;

    /// Number of fleet devices this backend drives.
    fn n_devices(&self) -> usize;

    /// CC mode of `device`.
    fn mode(&self, device: usize) -> CcMode;

    /// Every model this backend can serve, in the backend's native
    /// order (registry/manifest order, not intern order).
    fn model_names(&self) -> Vec<String>;

    /// Fail fast when `model` is unknown to the backend.
    fn check_model(&self, model: &str) -> anyhow::Result<()>;

    /// Tokenize a prompt for `model` (empty when payload content never
    /// reaches the backend, as in the DES).
    fn tokenize_prompt(&self, model: &str, prompt: &str) -> Vec<i32>;

    /// Profiled optimal batch size for `model` (§III-D2).
    fn obs(&self, model: ModelId) -> usize;

    /// Estimated load seconds for `model` in `device`'s CC mode
    /// (SelectBatch's `desired_latency` term).
    fn est_load_s(&self, model: ModelId, device: usize) -> f64;

    /// Seed value for the engine's per-model exec-time EWMA.
    fn initial_exec_est_s(&self, model: ModelId) -> f64;

    /// Model currently resident on `device`, if any.
    fn resident(&self, device: usize) -> Option<ModelId>;

    /// Make `model` resident on `device`, swapping if needed (the
    /// expensive CC-sensitive step).  A staged (prefetched) hit
    /// promotes without a second DMA.
    fn ensure_resident(&mut self, clock: &mut dyn Clock, device: usize,
                       model: ModelId) -> anyhow::Result<SwapOutcome>;

    /// Decrypt-ahead: stage `model` on `device` while the current batch
    /// executes, so a later swap promotes it without a DMA.  Backends
    /// without staging support keep the default no-op.
    fn prefetch(&mut self, _clock: &mut dyn Clock, _device: usize,
                _model: ModelId) -> anyhow::Result<PrefetchOutcome> {
        Ok(PrefetchOutcome::default())
    }

    /// Pop up to `take` requests for `model` into `out_requests`
    /// (appended; the caller clears and recycles the buffer) and
    /// execute them as one batch on `device`.  `Ok(None)` when the
    /// queue was empty — nothing is appended in that case.
    fn execute_batch(&mut self, clock: &mut dyn Clock,
                     queues: &mut ModelQueues, device: usize,
                     model: ModelId, take: usize,
                     out_requests: &mut Vec<Request>)
                     -> anyhow::Result<Option<BatchOutcome>>;

    /// Occupancy counters for `device` (monitor thread).
    fn snapshot(&self, device: usize) -> DeviceSnapshot;

    /// Swap/load/crypto totals for `device` (run summary).
    fn swap_stats(&self, device: usize) -> SwapStats;

    /// End of run: release residency and device state.
    fn teardown(&mut self);
}
