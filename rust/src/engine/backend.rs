//! The `ExecBackend` seam: what it means to *execute* a scheduling
//! decision on a fleet device.
//!
//! The engine owns ingest, queues, strategy + placement, per-device
//! busy-until timelines, SLA accounting and the `RunSummary`; a backend
//! owns residency, execution and occupancy/crypto accounting for N
//! devices addressed by id.  Two implementations ship:
//!
//! * [`crate::engine::RealBackend`] — a `DeviceSet` of `SimGpu`s +
//!   `Registry` + one `SwapManager` per device: real DMA (optionally
//!   CC-sealed), real PJRT execution.
//! * [`crate::engine::DesBackend`] — the calibrated [`CostModel`]:
//!   every cost is a table lookup, virtual time only.
//!
//! Time protocol: in wall-clock runs costs simply elapse inside the
//! backend calls.  In virtual-time runs the backend *reports* modeled
//! costs in [`SwapOutcome`]/[`BatchOutcome`] and never advances the
//! clock — the engine folds the costs into the dispatched device's
//! busy-until timeline, which is what lets N devices execute
//! concurrently in virtual time.
//!
//! Future backends (trace replay, remote pools) implement this trait
//! instead of hand-rolling another serve loop.
//!
//! [`CostModel`]: crate::sim::CostModel

use crate::coordinator::queues::ModelQueues;
use crate::coordinator::request::Request;
use crate::coordinator::swap::SwapStats;
use crate::engine::clock::Clock;
use crate::gpu::CcMode;

/// Timing of one residency change, in the run's time domain.
#[derive(Debug, Clone, Copy, Default)]
pub struct SwapOutcome {
    /// True if a load (and possibly an unload) actually happened.
    pub swapped: bool,
    pub load_s: f64,
    pub unload_s: f64,
    /// Crypto share of the load (CC only).
    pub crypto_s: f64,
}

/// One executed batch, in the run's time domain.
#[derive(Debug, Clone)]
pub struct BatchOutcome {
    /// The requests that rode in this batch (popped from the queue).
    pub requests: Vec<Request>,
    /// Generated tokens per request row (real execution only; empty
    /// when the backend models cost without producing output).
    pub tokens: Vec<Vec<i32>>,
    /// Artifact batch size used (>= requests.len()).
    pub artifact_batch: usize,
    /// When execution began, on the engine's clock (wall runs only;
    /// in virtual time the engine computes the device timeline from
    /// the reported costs and ignores this).
    pub exec_start_s: f64,
    pub exec_s: f64,
    pub io_s: f64,
}

/// Device occupancy published to the monitor thread.
#[derive(Debug, Clone, Default)]
pub struct DeviceSnapshot {
    pub gpu_util: f64,
    pub mem_in_use: u64,
    pub mem_peak: u64,
    pub fragmentation: f64,
    pub dma_h2d_bytes: u64,
    pub dma_crypto_s: f64,
    pub swaps: u64,
}

/// Pluggable execution backend behind the single serve loop.
pub trait ExecBackend {
    /// Short backend name for labels/diagnostics ("real" | "des").
    fn kind(&self) -> &'static str;

    /// Number of fleet devices this backend drives.
    fn n_devices(&self) -> usize;

    /// CC mode of `device`.
    fn mode(&self, device: usize) -> CcMode;

    /// Every model this backend can serve.
    fn model_names(&self) -> Vec<String>;

    /// Fail fast when `model` is unknown to the backend.
    fn check_model(&self, model: &str) -> anyhow::Result<()>;

    /// Tokenize a prompt for `model` (empty when payload content never
    /// reaches the backend, as in the DES).
    fn tokenize_prompt(&self, model: &str, prompt: &str) -> Vec<i32>;

    /// Profiled optimal batch size for `model` (§III-D2).
    fn obs(&self, model: &str) -> usize;

    /// Estimated load seconds for `model` in `device`'s CC mode
    /// (SelectBatch's `desired_latency` term).
    fn est_load_s(&self, model: &str, device: usize) -> f64;

    /// Seed value for the engine's per-model exec-time EWMA.
    fn initial_exec_est_s(&self, model: &str) -> f64;

    /// Model currently resident on `device`, if any.
    fn resident(&self, device: usize) -> Option<String>;

    /// Make `model` resident on `device`, swapping if needed (the
    /// expensive CC-sensitive step).
    fn ensure_resident(&mut self, clock: &mut dyn Clock, device: usize,
                       model: &str) -> anyhow::Result<SwapOutcome>;

    /// Pop up to `take` requests for `model` and execute them as one
    /// batch on `device`.  `Ok(None)` when the queue was empty.
    fn execute_batch(&mut self, clock: &mut dyn Clock,
                     queues: &mut ModelQueues, device: usize, model: &str,
                     take: usize) -> anyhow::Result<Option<BatchOutcome>>;

    /// Occupancy counters for `device` (monitor thread).
    fn snapshot(&self, device: usize) -> DeviceSnapshot;

    /// Swap/load/crypto totals for `device` (run summary).
    fn swap_stats(&self, device: usize) -> SwapStats;

    /// End of run: release residency and device state.
    fn teardown(&mut self);
}
