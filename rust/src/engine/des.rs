//! `DesBackend` — the calibrated discrete-event execution backend.
//!
//! Every cost the real backend pays for real (DMA throttles, crypto,
//! PJRT execution) becomes a table lookup in the measured
//! [`CostModel`]; the engine folds the reported costs into the
//! dispatched device's busy-until timeline (see `engine::backend` time
//! protocol).  Payload content never exists here, which is what makes
//! full-grid sweeps (72 cells, Fig 5–7) take milliseconds instead of
//! hours.  Each fleet device has its own CC mode and residency, so a
//! mixed CC/No-CC fleet charges per-device load and I/O costs.
//!
//! Known abstraction boundary: the DES models no device *memory*, so
//! it always dispatches `batch_size_at_least(rows)` where the real
//! backend's batcher would halve a batch on workspace OOM.  The
//! DES-vs-real parity guarantee (`tests/engine_parity.rs`) therefore
//! holds for configurations that fit their largest batch workspace —
//! which every calibrated run does, because profiling marks
//! memory-infeasible batch sizes as `oom_batches` and caps OBS below
//! them.

use crate::config::RunConfig;
use crate::coordinator::queues::ModelQueues;
use crate::coordinator::swap::SwapStats;
use crate::engine::backend::{BatchOutcome, DeviceSnapshot, ExecBackend,
                             SwapOutcome};
use crate::engine::clock::Clock;
use crate::gpu::CcMode;
use crate::runtime::Manifest;
use crate::sim::CostModel;

pub struct DesBackend<'a> {
    manifest: &'a Manifest,
    costs: &'a CostModel,
    /// Per-device CC mode (the fleet's mix).
    modes: Vec<CcMode>,
    /// Per-device resident model.
    resident: Vec<Option<String>>,
    /// Per-device modeled swap accounting.
    stats: Vec<SwapStats>,
}

impl<'a> DesBackend<'a> {
    pub fn new(cfg: &RunConfig, manifest: &'a Manifest,
               costs: &'a CostModel) -> DesBackend<'a> {
        let modes = cfg.fleet_modes();
        let n = modes.len();
        DesBackend {
            manifest,
            costs,
            modes,
            resident: vec![None; n],
            stats: vec![SwapStats::default(); n],
        }
    }
}

impl ExecBackend for DesBackend<'_> {
    fn kind(&self) -> &'static str {
        "des"
    }

    fn n_devices(&self) -> usize {
        self.modes.len()
    }

    fn mode(&self, device: usize) -> CcMode {
        self.modes[device]
    }

    fn model_names(&self) -> Vec<String> {
        self.manifest.family_names()
    }

    fn check_model(&self, model: &str) -> anyhow::Result<()> {
        self.manifest.family(model)?;
        self.costs.costs(model)?;
        Ok(())
    }

    fn tokenize_prompt(&self, _model: &str, _prompt: &str) -> Vec<i32> {
        // content never affects the DES
        Vec::new()
    }

    fn obs(&self, model: &str) -> usize {
        self.costs.costs(model).map(|mc| mc.obs).unwrap_or(1)
    }

    fn est_load_s(&self, model: &str, device: usize) -> f64 {
        self.costs.costs(model)
            .map(|mc| mc.load_s(self.modes[device]))
            .unwrap_or(0.0)
    }

    fn initial_exec_est_s(&self, model: &str) -> f64 {
        self.costs.costs(model).map(|mc| mc.exec_s(mc.obs)).unwrap_or(0.2)
    }

    fn resident(&self, device: usize) -> Option<String> {
        self.resident[device].clone()
    }

    fn ensure_resident(&mut self, _clock: &mut dyn Clock, device: usize,
                       model: &str) -> anyhow::Result<SwapOutcome> {
        if self.resident[device].as_deref() == Some(model) {
            return Ok(SwapOutcome::default());
        }
        let mc = self.costs.costs(model)?;
        let mut out = SwapOutcome { swapped: true, ..Default::default() };
        if self.resident[device].is_some() {
            out.unload_s = mc.unload_s;
        }
        out.load_s = mc.load_s(self.modes[device]);
        self.resident[device] = Some(model.to_string());
        let stats = &mut self.stats[device];
        stats.swap_count += 1;
        stats.total_load_s += out.load_s;
        stats.total_unload_s += out.unload_s;
        stats.load_samples.push((model.to_string(), out.load_s));
        Ok(out)
    }

    fn execute_batch(&mut self, _clock: &mut dyn Clock,
                     queues: &mut ModelQueues, device: usize, model: &str,
                     take: usize) -> anyhow::Result<Option<BatchOutcome>> {
        let requests = queues.pop_n(model, take.max(1));
        if requests.is_empty() {
            return Ok(None);
        }
        let spec = self.manifest.family(model)?;
        let mc = self.costs.costs(model)?;
        let artifact_batch = spec.batch_size_at_least(requests.len());
        let exec_s = mc.exec_s(artifact_batch);
        let io_s = self.costs.io_s_per_row(self.modes[device])
            * requests.len() as f64;
        Ok(Some(BatchOutcome {
            requests,
            tokens: Vec::new(),
            artifact_batch,
            // the engine computes the device timeline from the costs
            exec_start_s: 0.0,
            exec_s,
            io_s,
        }))
    }

    fn snapshot(&self, device: usize) -> DeviceSnapshot {
        DeviceSnapshot {
            swaps: self.stats[device].swap_count,
            ..Default::default()
        }
    }

    fn swap_stats(&self, device: usize) -> SwapStats {
        self.stats[device].clone()
    }

    fn teardown(&mut self) {
        for r in self.resident.iter_mut() {
            *r = None;
        }
    }
}
