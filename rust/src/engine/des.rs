//! `DesBackend` — the calibrated discrete-event execution backend.
//!
//! Every cost the real backend pays for real (DMA throttles, crypto,
//! PJRT execution) becomes a table lookup in the measured
//! [`CostModel`], and the backend advances the engine's `VirtualClock`
//! by exactly those amounts.  Payload content never exists here, which
//! is what makes full-grid sweeps (72 cells, Fig 5–7) take milliseconds
//! instead of hours.
//!
//! Known abstraction boundary: the DES models no device *memory*, so
//! it always dispatches `batch_size_at_least(rows)` where the real
//! backend's batcher would halve a batch on workspace OOM.  The
//! DES-vs-real parity guarantee (`tests/engine_parity.rs`) therefore
//! holds for configurations that fit their largest batch workspace —
//! which every calibrated run does, because profiling marks
//! memory-infeasible batch sizes as `oom_batches` and caps OBS below
//! them.

use crate::config::RunConfig;
use crate::coordinator::queues::ModelQueues;
use crate::coordinator::swap::SwapStats;
use crate::engine::backend::{BatchOutcome, DeviceSnapshot, ExecBackend,
                             SwapOutcome};
use crate::engine::clock::Clock;
use crate::gpu::CcMode;
use crate::runtime::Manifest;
use crate::sim::CostModel;

pub struct DesBackend<'a> {
    manifest: &'a Manifest,
    costs: &'a CostModel,
    mode: CcMode,
    resident: Option<String>,
    stats: SwapStats,
}

impl<'a> DesBackend<'a> {
    pub fn new(cfg: &RunConfig, manifest: &'a Manifest,
               costs: &'a CostModel) -> DesBackend<'a> {
        DesBackend {
            manifest,
            costs,
            mode: cfg.mode,
            resident: None,
            stats: SwapStats::default(),
        }
    }
}

impl ExecBackend for DesBackend<'_> {
    fn kind(&self) -> &'static str {
        "des"
    }

    fn model_names(&self) -> Vec<String> {
        self.manifest.family_names()
    }

    fn check_model(&self, model: &str) -> anyhow::Result<()> {
        self.manifest.family(model)?;
        self.costs.costs(model)?;
        Ok(())
    }

    fn tokenize_prompt(&self, _model: &str, _prompt: &str) -> Vec<i32> {
        // content never affects the DES
        Vec::new()
    }

    fn obs(&self, model: &str) -> usize {
        self.costs.costs(model).map(|mc| mc.obs).unwrap_or(1)
    }

    fn est_load_s(&self, model: &str) -> f64 {
        self.costs.costs(model).map(|mc| mc.load_s(self.mode))
            .unwrap_or(0.0)
    }

    fn initial_exec_est_s(&self, model: &str) -> f64 {
        self.costs.costs(model).map(|mc| mc.exec_s(mc.obs)).unwrap_or(0.2)
    }

    fn resident(&self) -> Option<String> {
        self.resident.clone()
    }

    fn ensure_resident(&mut self, clock: &mut dyn Clock, model: &str)
                       -> anyhow::Result<SwapOutcome> {
        if self.resident.as_deref() == Some(model) {
            return Ok(SwapOutcome::default());
        }
        let mc = self.costs.costs(model)?;
        let mut out = SwapOutcome { swapped: true, ..Default::default() };
        if self.resident.is_some() {
            out.unload_s = mc.unload_s;
        }
        out.load_s = mc.load_s(self.mode);
        clock.advance(out.unload_s + out.load_s);
        self.resident = Some(model.to_string());
        self.stats.swap_count += 1;
        self.stats.total_load_s += out.load_s;
        self.stats.total_unload_s += out.unload_s;
        self.stats.load_samples.push((model.to_string(), out.load_s));
        Ok(out)
    }

    fn execute_batch(&mut self, clock: &mut dyn Clock,
                     queues: &mut ModelQueues, model: &str, take: usize)
                     -> anyhow::Result<Option<BatchOutcome>> {
        let requests = queues.pop_n(model, take.max(1));
        if requests.is_empty() {
            return Ok(None);
        }
        let spec = self.manifest.family(model)?;
        let mc = self.costs.costs(model)?;
        let artifact_batch = spec.batch_size_at_least(requests.len());
        let exec_s = mc.exec_s(artifact_batch);
        let io_s = self.costs.io_s_per_row(self.mode)
            * requests.len() as f64;
        let exec_start_s = clock.now_s();
        clock.advance(exec_s + io_s);
        Ok(Some(BatchOutcome {
            requests,
            tokens: Vec::new(),
            artifact_batch,
            exec_start_s,
            exec_s,
            io_s,
        }))
    }

    fn snapshot(&self) -> DeviceSnapshot {
        DeviceSnapshot {
            swaps: self.stats.swap_count,
            ..Default::default()
        }
    }

    fn swap_stats(&self) -> SwapStats {
        self.stats.clone()
    }

    fn teardown(&mut self) {
        self.resident = None;
    }
}
