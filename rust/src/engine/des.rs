//! `DesBackend` — the calibrated discrete-event execution backend.
//!
//! Every cost the real backend pays for real (DMA throttles, crypto,
//! PJRT execution) becomes a table lookup in the measured
//! [`CostModel`]; the engine folds the reported costs into the
//! dispatched device's busy-until timeline (see `engine::backend` time
//! protocol).  Payload content never exists here, which is what makes
//! full-grid sweeps (72 cells, Fig 5–7) take milliseconds instead of
//! hours.  Each fleet device has its own CC mode and residency, so a
//! mixed CC/No-CC fleet charges per-device load and I/O costs.
//!
//! Hot-path layout: model names are interned once at construction
//! into a sorted [`ModelTable`], and the per-model cost row + family
//! spec are resolved into id-indexed vectors.  Every per-dispatch
//! consult — residency compare, load estimate, OBS, exec pricing — is
//! then an array index on a `Copy` id: the steady-state loop clones no
//! strings and hashes no keys.
//!
//! The pipelined swap path and predictive prefetch are mirrored in
//! virtual time: CC loads price `load_s_for(mode, pipelined)` from the
//! cost table (steady-state `max(crypto, link)` per chunk when the
//! pipeline is on — see `sim::calib`), and each device keeps a staging
//! slot whose state machine is identical to the real
//! `SwapManager`'s — stage on `prefetch`, promote for free on a
//! correct prediction, drop on a wrong one.  That mirroring is what
//! keeps the DES-vs-real parity contract exact with the pipeline and
//! prefetch enabled (`tests/engine_parity.rs`).
//!
//! Observability rides the same structure for free: `--trace` hooks
//! live in the shared engine loop (gated on virtual time), not here,
//! so this backend and the real-virtual one record identical span
//! sequences from the identical priced outcomes — the span-parity
//! test compares whole `obs::Trace` values across the two.
//!
//! Known abstraction boundary: the DES models no device *memory*, so
//! it always dispatches `batch_size_at_least(rows)` where the real
//! backend's batcher would halve a batch on workspace OOM, and its
//! staging slot never OOMs where a real device without room for a
//! second blob skips the speculation.  The DES-vs-real parity
//! guarantee (`tests/engine_parity.rs`) therefore holds for
//! configurations whose device memory fits (weights + largest-batch
//! workspace) — plus a second weight blob when prefetch is on — which
//! every calibrated run does, because profiling marks
//! memory-infeasible batch sizes as `oom_batches` and caps OBS below
//! them.

use std::sync::Arc;

use crate::config::RunConfig;
use crate::coordinator::queues::ModelQueues;
use crate::coordinator::request::Request;
use crate::coordinator::swap::SwapStats;
use crate::engine::backend::{est_load_s_group, price_data_path,
                             price_pipeline, price_prefetch, price_swap,
                             price_swap_group, stage_shares, swap_load_s,
                             BatchOutcome, DataPathOutcome,
                             DeviceSnapshot, ExecBackend, PrefetchOutcome,
                             SwapEvent, SwapOutcome};
use crate::engine::clock::Clock;
use crate::gpu::device::GpuConfig;
use crate::gpu::CcMode;
use crate::runtime::manifest::FamilySpec;
use crate::runtime::{Manifest, ModelId, ModelTable};
use crate::sim::calib::ModelCosts;
use crate::sim::CostModel;

/// Id-indexed per-model lookups, resolved once at construction so the
/// hot path never goes back through a name-keyed map.  Entries stay
/// `None` for families without a cost/spec row — the cold fallback
/// then reproduces the original name-keyed error.
struct PerModel<'a> {
    spec: Option<&'a FamilySpec>,
    mc: Option<&'a ModelCosts>,
}

pub struct DesBackend<'a> {
    manifest: &'a Manifest,
    costs: &'a CostModel,
    /// Sorted intern table over the manifest's family names.
    table: Arc<ModelTable>,
    /// One row per interned id, in table order.
    by_id: Vec<PerModel<'a>>,
    /// Per-device GPU config (mode mix, bounce/pipeline/bandwidth,
    /// profile pricing terms) — what swap and per-batch I/O pricing
    /// read, per device.
    fleet: Vec<GpuConfig>,
    /// Pipeline-parallel stage count (1 = off; devices are tiled into
    /// groups of this many consecutive ids, each group serving one
    /// sharded model — see `gpu::fleet::StageTopology`).
    pp_stages: usize,
    /// CC-priced inference data path (`--data-path`).
    data_path: bool,
    /// Priced input tokens per request (None = model `prompt_len`).
    data_tokens_in: Option<usize>,
    /// Priced output tokens per request (None = model `decode_len`).
    data_tokens_out: Option<usize>,
    /// Per-device resident model.
    resident: Vec<Option<ModelId>>,
    /// Per-device staged (prefetched) model — mirrors the real
    /// `SwapManager`'s staging slot.
    staged: Vec<Option<ModelId>>,
    /// Per-device modeled swap accounting.
    stats: Vec<SwapStats>,
}

impl<'a> DesBackend<'a> {
    pub fn new(cfg: &RunConfig, manifest: &'a Manifest,
               costs: &'a CostModel) -> DesBackend<'a> {
        let fleet = cfg.fleet_configs();
        let n = fleet.len();
        let pipelined = cfg.gpu.pipeline_depth >= 2;
        if pipelined && costs.missing_pipeline_profile() {
            eprintln!("[sincere] warning: cost model has no pipelined CC \
                       load profile (cached before the pipeline \
                       existed?) — --pipeline-depth prices as \
                       serialized; delete the cached cost_model.json \
                       to re-measure");
        }
        let table = ModelTable::shared(manifest.family_names());
        let by_id = table.names().iter().map(|name| PerModel {
            spec: manifest.family(name).ok(),
            mc: costs.costs(name).ok(),
        }).collect();
        DesBackend {
            manifest,
            costs,
            table,
            by_id,
            fleet,
            pp_stages: cfg.pp_stages.max(1),
            data_path: cfg.data_path,
            data_tokens_in: cfg.data_tokens_in,
            data_tokens_out: cfg.data_tokens_out,
            resident: vec![None; n],
            staged: vec![None; n],
            stats: vec![SwapStats::default(); n],
        }
    }

    /// Cost row for `model`; the cold `None` path re-resolves by name
    /// so the error text matches the name-keyed original.
    fn mc(&self, model: ModelId) -> anyhow::Result<&'a ModelCosts> {
        match self.by_id.get(model.index()).and_then(|p| p.mc) {
            Some(mc) => Ok(mc),
            None => self.costs.costs(self.table.name(model)),
        }
    }

    /// Family spec for `model`, same cold-path contract as [`mc`].
    ///
    /// [`mc`]: DesBackend::mc
    fn spec(&self, model: ModelId) -> anyhow::Result<&'a FamilySpec> {
        match self.by_id.get(model.index()).and_then(|p| p.spec) {
            Some(spec) => Ok(spec),
            None => self.manifest.family(self.table.name(model)),
        }
    }
}

impl ExecBackend for DesBackend<'_> {
    fn kind(&self) -> &'static str {
        "des"
    }

    fn table(&self) -> &Arc<ModelTable> {
        &self.table
    }

    fn n_devices(&self) -> usize {
        self.fleet.len()
    }

    fn mode(&self, device: usize) -> CcMode {
        self.fleet[device].mode
    }

    fn model_names(&self) -> Vec<String> {
        self.manifest.family_names()
    }

    fn check_model(&self, model: &str) -> anyhow::Result<()> {
        self.manifest.family(model)?;
        self.costs.costs(model)?;
        Ok(())
    }

    fn tokenize_prompt(&self, _model: &str, _prompt: &str) -> Vec<i32> {
        // content never affects the DES
        Vec::new()
    }

    fn obs(&self, model: ModelId) -> usize {
        self.by_id.get(model.index()).and_then(|p| p.mc)
            .map(|mc| mc.obs).unwrap_or(1)
    }

    fn est_load_s(&self, model: ModelId, device: usize) -> f64 {
        if self.staged[device] == Some(model) {
            return 0.0; // a staged model promotes for free
        }
        if self.pp_stages > 1 {
            // estimate for `device`'s stage group (callers may name a
            // non-lead member): ready when the slowest shard load
            // finishes
            let device = device - device % self.pp_stages;
            let per = self.by_id.get(model.index());
            let (Some(mc), Some(spec)) =
                (per.and_then(|p| p.mc), per.and_then(|p| p.spec))
            else { return 0.0 };
            let shares = stage_shares(spec.n_layers, self.pp_stages);
            return est_load_s_group(
                mc, &self.fleet[device..device + self.pp_stages],
                &shares);
        }
        self.by_id.get(model.index()).and_then(|p| p.mc)
            .map(|mc| swap_load_s(mc, &self.fleet[device]))
            .unwrap_or(0.0)
    }

    fn initial_exec_est_s(&self, model: ModelId) -> f64 {
        self.by_id.get(model.index()).and_then(|p| p.mc)
            .map(|mc| mc.exec_s(mc.obs)).unwrap_or(0.2)
    }

    fn resident(&self, device: usize) -> Option<ModelId> {
        self.resident[device]
    }

    fn ensure_resident(&mut self, _clock: &mut dyn Clock, device: usize,
                       model: ModelId) -> anyhow::Result<SwapOutcome> {
        if self.resident[device] == Some(model) {
            // staged state is untouched: the hint may still pay off
            return Ok(SwapOutcome::default());
        }
        let mc = self.mc(model)?;
        let had_resident = self.resident[device].is_some();
        if self.pp_stages > 1 {
            // shard-group swap: every stage of the lead's group is
            // priced (and charged to its own device) before residency
            // flips — all shards stage atomically or none, so a
            // partially-resident group can never exist to deadlock
            // the admission gate.  Prefetch is validated off under
            // pp, so there is no staged slot to promote or drop.
            let spec = self.spec(model)?;
            let shares = stage_shares(spec.n_layers, self.pp_stages);
            let group = device..device + self.pp_stages;
            let out = price_swap_group(
                mc, &self.fleet[group.clone()], &shares,
                SwapEvent { model, had_resident, promoted: false,
                            dropped_staged: false },
                &mut self.stats[group.clone()]);
            for d in group {
                self.resident[d] = Some(model);
            }
            return Ok(out);
        }
        // staged hit promotes; anything else staged is a wrong
        // prediction and is dropped
        let promoted = self.staged[device] == Some(model);
        let dropped_staged =
            !promoted && self.staged[device].is_some();
        self.staged[device] = None;
        let out = price_swap(
            mc, &self.fleet[device],
            SwapEvent { model, had_resident, promoted, dropped_staged },
            &mut self.stats[device]);
        self.resident[device] = Some(model);
        Ok(out)
    }

    fn prefetch(&mut self, _clock: &mut dyn Clock, device: usize,
                model: ModelId) -> anyhow::Result<PrefetchOutcome> {
        if self.resident[device] == Some(model)
            || self.staged[device] == Some(model)
        {
            return Ok(PrefetchOutcome::default());
        }
        let mc = self.mc(model)?;
        let dropped_staged = self.staged[device].is_some();
        let out = price_prefetch(mc, &self.fleet[device], dropped_staged,
                                 &mut self.stats[device]);
        self.staged[device] = Some(model);
        Ok(out)
    }

    fn execute_batch(&mut self, _clock: &mut dyn Clock,
                     queues: &mut ModelQueues, device: usize,
                     model: ModelId, take: usize,
                     out_requests: &mut Vec<Request>)
                     -> anyhow::Result<Option<BatchOutcome>> {
        queues.pop_n_into(model, take.max(1), out_requests);
        if out_requests.is_empty() {
            return Ok(None);
        }
        let spec = self.spec(model)?;
        let mc = self.mc(model)?;
        let rows = out_requests.len();
        let artifact_batch = spec.batch_size_at_least(rows);
        let exec_s = mc.exec_s(artifact_batch);
        // Payload I/O: per-row calibrated figure by default; with the
        // data path on, the batch's byte count through the shared
        // bounce-budget pricing (identical per-row figure in No-CC —
        // see `price_data_path`).
        let (io_s, data) = if self.data_path {
            let d = price_data_path(
                self.costs, &self.fleet[device], rows,
                self.data_tokens_in.unwrap_or(spec.prompt_len),
                self.data_tokens_out.unwrap_or(spec.decode_len));
            (d.io_s, d)
        } else {
            (self.costs.io_s_per_row(self.fleet[device].mode)
                 * rows as f64,
             DataPathOutcome::default())
        };
        if self.pp_stages > 1 {
            // microbatch the rows through the lead's stage group;
            // activation tensors cross each inter-stage link
            let shares = stage_shares(spec.n_layers, self.pp_stages);
            let pp = price_pipeline(
                exec_s, spec.d_model, rows, spec.decode_len, &shares,
                &self.fleet[device..device + self.pp_stages]);
            return Ok(Some(BatchOutcome {
                tokens: Vec::new(),
                artifact_batch,
                exec_start_s: 0.0,
                exec_s: pp.makespan_s,
                io_s: io_s + pp.activation.io_s,
                data,
                pp: Some(pp),
            }));
        }
        Ok(Some(BatchOutcome {
            tokens: Vec::new(),
            artifact_batch,
            // the engine computes the device timeline from the costs
            exec_start_s: 0.0,
            exec_s,
            io_s,
            data,
            pp: None,
        }))
    }

    fn snapshot(&self, device: usize) -> DeviceSnapshot {
        DeviceSnapshot {
            swaps: self.stats[device].swap_count,
            ..Default::default()
        }
    }

    fn swap_stats(&self, device: usize) -> SwapStats {
        self.stats[device].clone()
    }

    fn teardown(&mut self) {
        for r in self.resident.iter_mut() {
            *r = None;
        }
        for s in self.staged.iter_mut() {
            *s = None;
        }
    }
}
