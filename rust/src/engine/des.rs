//! `DesBackend` — the calibrated discrete-event execution backend.
//!
//! Every cost the real backend pays for real (DMA throttles, crypto,
//! PJRT execution) becomes a table lookup in the measured
//! [`CostModel`]; the engine folds the reported costs into the
//! dispatched device's busy-until timeline (see `engine::backend` time
//! protocol).  Payload content never exists here, which is what makes
//! full-grid sweeps (72 cells, Fig 5–7) take milliseconds instead of
//! hours.  Each fleet device has its own CC mode and residency, so a
//! mixed CC/No-CC fleet charges per-device load and I/O costs.
//!
//! The pipelined swap path and predictive prefetch are mirrored in
//! virtual time: CC loads price `load_s_for(mode, pipelined)` from the
//! cost table (steady-state `max(crypto, link)` per chunk when the
//! pipeline is on — see `sim::calib`), and each device keeps a staging
//! slot whose state machine is identical to the real
//! `SwapManager`'s — stage on `prefetch`, promote for free on a
//! correct prediction, drop on a wrong one.  That mirroring is what
//! keeps the DES-vs-real parity contract exact with the pipeline and
//! prefetch enabled (`tests/engine_parity.rs`).
//!
//! Known abstraction boundary: the DES models no device *memory*, so
//! it always dispatches `batch_size_at_least(rows)` where the real
//! backend's batcher would halve a batch on workspace OOM, and its
//! staging slot never OOMs where a real device without room for a
//! second blob skips the speculation.  The DES-vs-real parity
//! guarantee (`tests/engine_parity.rs`) therefore holds for
//! configurations whose device memory fits (weights + largest-batch
//! workspace) — plus a second weight blob when prefetch is on — which
//! every calibrated run does, because profiling marks
//! memory-infeasible batch sizes as `oom_batches` and caps OBS below
//! them.

use crate::config::RunConfig;
use crate::coordinator::queues::ModelQueues;
use crate::coordinator::swap::SwapStats;
use crate::engine::backend::{price_data_path, price_prefetch, price_swap,
                             BatchOutcome, DataPathOutcome,
                             DeviceSnapshot, ExecBackend, PrefetchOutcome,
                             SwapEvent, SwapOutcome};
use crate::engine::clock::Clock;
use crate::gpu::device::GpuConfig;
use crate::gpu::CcMode;
use crate::runtime::Manifest;
use crate::sim::CostModel;

pub struct DesBackend<'a> {
    manifest: &'a Manifest,
    costs: &'a CostModel,
    /// Whether CC loads price the chunk pipeline (`--pipeline-depth`).
    pipelined: bool,
    /// Per-device GPU config (mode mix, bounce/pipeline/bandwidth) —
    /// what the data path prices per-batch I/O from.
    fleet: Vec<GpuConfig>,
    /// CC-priced inference data path (`--data-path`).
    data_path: bool,
    /// Priced input tokens per request (None = model `prompt_len`).
    data_tokens_in: Option<usize>,
    /// Priced output tokens per request (None = model `decode_len`).
    data_tokens_out: Option<usize>,
    /// Per-device resident model.
    resident: Vec<Option<String>>,
    /// Per-device staged (prefetched) model — mirrors the real
    /// `SwapManager`'s staging slot.
    staged: Vec<Option<String>>,
    /// Per-device modeled swap accounting.
    stats: Vec<SwapStats>,
}

impl<'a> DesBackend<'a> {
    pub fn new(cfg: &RunConfig, manifest: &'a Manifest,
               costs: &'a CostModel) -> DesBackend<'a> {
        let fleet = cfg.fleet_configs();
        let n = fleet.len();
        let pipelined = cfg.gpu.pipeline_depth >= 2;
        if pipelined && costs.missing_pipeline_profile() {
            eprintln!("[sincere] warning: cost model has no pipelined CC \
                       load profile (cached before the pipeline \
                       existed?) — --pipeline-depth prices as \
                       serialized; delete the cached cost_model.json \
                       to re-measure");
        }
        DesBackend {
            manifest,
            costs,
            pipelined,
            fleet,
            data_path: cfg.data_path,
            data_tokens_in: cfg.data_tokens_in,
            data_tokens_out: cfg.data_tokens_out,
            resident: vec![None; n],
            staged: vec![None; n],
            stats: vec![SwapStats::default(); n],
        }
    }
}

impl ExecBackend for DesBackend<'_> {
    fn kind(&self) -> &'static str {
        "des"
    }

    fn n_devices(&self) -> usize {
        self.fleet.len()
    }

    fn mode(&self, device: usize) -> CcMode {
        self.fleet[device].mode
    }

    fn model_names(&self) -> Vec<String> {
        self.manifest.family_names()
    }

    fn check_model(&self, model: &str) -> anyhow::Result<()> {
        self.manifest.family(model)?;
        self.costs.costs(model)?;
        Ok(())
    }

    fn tokenize_prompt(&self, _model: &str, _prompt: &str) -> Vec<i32> {
        // content never affects the DES
        Vec::new()
    }

    fn obs(&self, model: &str) -> usize {
        self.costs.costs(model).map(|mc| mc.obs).unwrap_or(1)
    }

    fn est_load_s(&self, model: &str, device: usize) -> f64 {
        if self.staged[device].as_deref() == Some(model) {
            return 0.0; // a staged model promotes for free
        }
        self.costs.costs(model)
            .map(|mc| mc.load_s_for(self.fleet[device].mode,
                                    self.pipelined))
            .unwrap_or(0.0)
    }

    fn initial_exec_est_s(&self, model: &str) -> f64 {
        self.costs.costs(model).map(|mc| mc.exec_s(mc.obs)).unwrap_or(0.2)
    }

    fn resident(&self, device: usize) -> Option<String> {
        self.resident[device].clone()
    }

    fn ensure_resident(&mut self, _clock: &mut dyn Clock, device: usize,
                       model: &str) -> anyhow::Result<SwapOutcome> {
        if self.resident[device].as_deref() == Some(model) {
            // staged state is untouched: the hint may still pay off
            return Ok(SwapOutcome::default());
        }
        let mc = self.costs.costs(model)?;
        let had_resident = self.resident[device].is_some();
        // staged hit promotes; anything else staged is a wrong
        // prediction and is dropped
        let promoted = self.staged[device].as_deref() == Some(model);
        let dropped_staged =
            !promoted && self.staged[device].is_some();
        self.staged[device] = None;
        let out = price_swap(
            mc, self.fleet[device].mode, self.pipelined,
            SwapEvent { model, had_resident, promoted, dropped_staged },
            &mut self.stats[device]);
        self.resident[device] = Some(model.to_string());
        Ok(out)
    }

    fn prefetch(&mut self, _clock: &mut dyn Clock, device: usize,
                model: &str) -> anyhow::Result<PrefetchOutcome> {
        if self.resident[device].as_deref() == Some(model)
            || self.staged[device].as_deref() == Some(model)
        {
            return Ok(PrefetchOutcome::default());
        }
        let mc = self.costs.costs(model)?;
        let dropped_staged = self.staged[device].is_some();
        let out = price_prefetch(mc, self.fleet[device].mode,
                                 self.pipelined, dropped_staged,
                                 &mut self.stats[device]);
        self.staged[device] = Some(model.to_string());
        Ok(out)
    }

    fn execute_batch(&mut self, _clock: &mut dyn Clock,
                     queues: &mut ModelQueues, device: usize, model: &str,
                     take: usize) -> anyhow::Result<Option<BatchOutcome>> {
        let requests = queues.pop_n(model, take.max(1));
        if requests.is_empty() {
            return Ok(None);
        }
        let spec = self.manifest.family(model)?;
        let mc = self.costs.costs(model)?;
        let artifact_batch = spec.batch_size_at_least(requests.len());
        let exec_s = mc.exec_s(artifact_batch);
        // Payload I/O: per-row calibrated figure by default; with the
        // data path on, the batch's byte count through the shared
        // bounce-budget pricing (identical per-row figure in No-CC —
        // see `price_data_path`).
        let (io_s, data) = if self.data_path {
            let d = price_data_path(
                self.costs, &self.fleet[device], requests.len(),
                self.data_tokens_in.unwrap_or(spec.prompt_len),
                self.data_tokens_out.unwrap_or(spec.decode_len));
            (d.io_s, d)
        } else {
            (self.costs.io_s_per_row(self.fleet[device].mode)
                 * requests.len() as f64,
             DataPathOutcome::default())
        };
        Ok(Some(BatchOutcome {
            requests,
            tokens: Vec::new(),
            artifact_batch,
            // the engine computes the device timeline from the costs
            exec_start_s: 0.0,
            exec_s,
            io_s,
            data,
        }))
    }

    fn snapshot(&self, device: usize) -> DeviceSnapshot {
        DeviceSnapshot {
            swaps: self.stats[device].swap_count,
            ..Default::default()
        }
    }

    fn swap_stats(&self, device: usize) -> SwapStats {
        self.stats[device].clone()
    }

    fn teardown(&mut self) {
        for r in self.resident.iter_mut() {
            *r = None;
        }
        for s in self.staged.iter_mut() {
            *s = None;
        }
    }
}
