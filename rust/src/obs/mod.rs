//! Structured event traces — the observability layer (`--trace`).
//!
//! The paper attributes the CC-vs-No-CC gap to model-load encryption,
//! and the hardware-generation profiles (`gpu::profile`) further split
//! that tax into chunk crypto vs a per-swap bridge residual.  The
//! summaries prove the totals; this module proves *where each second
//! of each request went*.  In virtual time the engine computes every
//! phase boundary itself (see the time protocol in `engine::backend`),
//! so both virtual backends — the DES and the real backend under
//! virtual costs — are traced by the same engine-level hooks and emit
//! identical span sequences for identical runs (the parity contract,
//! `tests/engine_parity.rs`).
//!
//! Three artifacts per traced run:
//!
//! * an in-memory [`Trace`]: typed request-lifecycle events (shed,
//!   expiry, swap, exec, completion) plus one [`Waterfall`] row per
//!   completed request;
//! * `<label>_trace.json` — Chrome trace-event JSON (Perfetto-loadable):
//!   one lane per fleet device carrying swap/exec spans (gaps = idle),
//!   plus one lane per SLA class (or a single `requests` lane) carrying
//!   per-request arrival→completion spans and shed/expiry instants;
//! * `<label>_waterfall.csv` (`--trace full` only) — the per-request
//!   latency decomposition.
//!
//! The waterfall identity: for every completed request,
//!
//! ```text
//! queue_wait + swap_unload + swap_load + exec + io == latency  (≤1e-9)
//! ```
//!
//! holds by construction of the virtual-time protocol — the engine
//! derives `complete_s` from exactly these terms — and is pinned as an
//! invariant test (`tests/obs_trace.rs`), not a rendering convention.
//! The bridge residual and exposed crypto are *attribution within*
//! `swap_load` (they are already part of the priced load seconds), so
//! they are carried as extra columns, never added to the sum.  The
//! pipeline-parallel activation phase follows the same rule: the
//! inter-stage transfer seconds are already inside `io`, and
//! `activation_io` attributes them (column present only when a run
//! actually sharded).
//!
//! Flag-off contract: `--trace off` (the default) records nothing,
//! writes nothing, and leaves every summary byte identical to
//! pre-trace builds (`tests/golden_summary.rs`).

use std::path::Path;

use crate::coordinator::request::CompletedRequest;
use crate::engine::SwapOutcome;
use crate::gpu::CcMode;
use crate::metrics::hist::Histogram;
use crate::runtime::{ModelId, ModelTable};
use crate::util::csvio::CsvWriter;
use crate::util::json::Json;

/// Version tag stamped into every `<label>_trace.json` so downstream
/// tooling can detect schema drift.  Bump when event kinds, lane
/// layout, or waterfall columns change.
pub const TRACE_SCHEMA_VERSION: u32 = 1;

/// Trace verbosity (`--trace off|events|full`).
///
/// * `off` — nothing recorded, byte-identical outputs (default);
/// * `events` — spans recorded, Chrome trace JSON written, summary
///   gains its `phase_totals` block;
/// * `full` — `events` plus the per-request waterfall CSV.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TraceMode {
    #[default]
    Off,
    Events,
    Full,
}

/// Valid `--trace` values, in help order.
pub const TRACE_MODE_NAMES: &[&str] = &["off", "events", "full"];

impl TraceMode {
    pub fn parse(s: &str) -> anyhow::Result<TraceMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" => Ok(TraceMode::Off),
            "events" => Ok(TraceMode::Events),
            "full" => Ok(TraceMode::Full),
            other => anyhow::bail!(
                "unknown --trace mode {other:?} (have {})",
                TRACE_MODE_NAMES.join("|")),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            TraceMode::Off => "off",
            TraceMode::Events => "events",
            TraceMode::Full => "full",
        }
    }

    /// True for any recording mode.
    pub fn is_on(&self) -> bool {
        *self != TraceMode::Off
    }
}

/// One typed lifecycle event.  Recorded in engine-loop order, which in
/// virtual time is a pure function of (config, seed, cost table) — the
/// parity test compares whole event sequences across backends.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// Refused by the admission gate — never queued.
    Shed { at_s: f64, id: u64, model: ModelId, class: u8 },
    /// Dropped from a queue past its (class) deadline.
    Expired { at_s: f64, id: u64, model: ModelId, class: u8 },
    /// One residency change on a device lane, `start_s` to
    /// `start_s + unload_s + load_s`.  The bridge residual and the
    /// exposed crypto attribute slices *within* `load_s`.
    Swap {
        device: usize,
        start_s: f64,
        model: ModelId,
        unload_s: f64,
        load_s: f64,
        bridge_s: f64,
        crypto_exposed_s: f64,
        promoted: bool,
    },
    /// One batch execution (exec + data-path I/O) on a device lane.
    Exec {
        device: usize,
        start_s: f64,
        model: ModelId,
        rows: usize,
        exec_s: f64,
        io_s: f64,
    },
    /// One pipeline stage's share of a batch on a member-device lane
    /// (pp runs only; the group lead keeps the whole-batch `Exec`
    /// span).
    StageExec {
        device: usize,
        start_s: f64,
        model: ModelId,
        rows: usize,
        exec_s: f64,
    },
    /// One completed request on its SLA-class lane, arrival to
    /// completion.
    Request {
        id: u64,
        model: ModelId,
        class: u8,
        device: usize,
        arrival_s: f64,
        complete_s: f64,
        sla_met: bool,
    },
}

/// Per-request latency decomposition.  The phase columns
/// (`queue_wait_s + swap_unload_s + swap_load_s + exec_s + io_s`) sum
/// to `latency_s` within 1e-9 — the module-level identity.  Batched
/// requests share their batch's swap/exec/io figures: the waterfall
/// answers "what was this request waiting on", not "what marginal cost
/// did it add".
#[derive(Debug, Clone, PartialEq)]
pub struct Waterfall {
    pub id: u64,
    pub model: ModelId,
    pub device: usize,
    pub class: u8,
    pub arrival_s: f64,
    /// Dispatch time minus arrival — time spent queued.
    pub queue_wait_s: f64,
    pub swap_unload_s: f64,
    /// Full priced load seconds (bridge + crypto slices included).
    pub swap_load_s: f64,
    /// Bridge-residual slice of `swap_load_s` (hardware profiles).
    pub swap_bridge_s: f64,
    /// Exposed-crypto slice of `swap_load_s`.
    pub swap_crypto_exposed_s: f64,
    /// The swap promoted a prefetched buffer (load was free).
    pub promoted: bool,
    pub exec_s: f64,
    pub io_s: f64,
    /// Inter-stage activation slice of `io_s` (pipeline-parallel runs
    /// only; an attribution column, never added to the phase sum).
    pub activation_io_s: f64,
    pub latency_s: f64,
}

impl Waterfall {
    /// Sum of the phase columns — equals `latency_s` within 1e-9.
    pub fn phase_sum_s(&self) -> f64 {
        self.queue_wait_s + self.swap_unload_s + self.swap_load_s
            + self.exec_s + self.io_s
    }
}

/// Aggregated "where the seconds go" block, attached to the summary
/// only when tracing ran (`RunSummary::phase_totals`) — the same
/// presence gate as every other optional block (byte-identity
/// contract).  Totals are summed over completed requests; the p95s
/// come from per-phase histograms over the same rows.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PhaseTotals {
    /// Completed (waterfall) requests aggregated here.
    pub requests: u64,
    pub queue_wait_s: f64,
    pub swap_unload_s: f64,
    pub swap_load_s: f64,
    pub swap_bridge_s: f64,
    pub swap_crypto_exposed_s: f64,
    pub exec_s: f64,
    pub io_s: f64,
    /// Inter-stage activation slice of `io_s` (pipeline-parallel runs
    /// only; 0 — and absent from the JSON — otherwise).
    pub activation_io_s: f64,
    /// Sum of recorded latencies (== sum of phase sums within 1e-9·n).
    pub latency_s: f64,
    pub queue_wait_p95_s: f64,
    pub swap_load_p95_s: f64,
    pub exec_p95_s: f64,
}

impl PhaseTotals {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("requests", Json::num(self.requests as f64)),
            ("queue_wait_s", Json::num(self.queue_wait_s)),
            ("swap_unload_s", Json::num(self.swap_unload_s)),
            ("swap_load_s", Json::num(self.swap_load_s)),
            ("swap_bridge_s", Json::num(self.swap_bridge_s)),
            ("swap_crypto_exposed_s",
             Json::num(self.swap_crypto_exposed_s)),
            ("exec_s", Json::num(self.exec_s)),
            ("io_s", Json::num(self.io_s)),
        ];
        // only pipeline-parallel runs accumulate an activation phase —
        // the key's presence follows the byte-identity contract
        if self.activation_io_s > 0.0 {
            fields.push(("activation_io_s",
                         Json::num(self.activation_io_s)));
        }
        fields.extend([
            ("latency_s", Json::num(self.latency_s)),
            ("queue_wait_p95_s", Json::num(self.queue_wait_p95_s)),
            ("swap_load_p95_s", Json::num(self.swap_load_p95_s)),
            ("exec_p95_s", Json::num(self.exec_p95_s)),
        ]);
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> PhaseTotals {
        let f = |k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        PhaseTotals {
            requests: j.get("requests").and_then(|v| v.as_u64())
                .unwrap_or(0),
            queue_wait_s: f("queue_wait_s"),
            swap_unload_s: f("swap_unload_s"),
            swap_load_s: f("swap_load_s"),
            swap_bridge_s: f("swap_bridge_s"),
            swap_crypto_exposed_s: f("swap_crypto_exposed_s"),
            exec_s: f("exec_s"),
            io_s: f("io_s"),
            activation_io_s: f("activation_io_s"),
            latency_s: f("latency_s"),
            queue_wait_p95_s: f("queue_wait_p95_s"),
            swap_load_p95_s: f("swap_load_p95_s"),
            exec_p95_s: f("exec_p95_s"),
        }
    }

    /// Mean seconds per request for one phase total.
    pub fn mean(&self, total: f64) -> f64 {
        if self.requests > 0 {
            total / self.requests as f64
        } else {
            0.0
        }
    }
}

/// Everything one traced run records: the typed event sequence plus
/// the per-request waterfalls.  `PartialEq` so the DES-vs-real parity
/// test can compare whole traces structurally.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
    pub waterfalls: Vec<Waterfall>,
}

impl Trace {
    pub fn new() -> Trace {
        Trace::default()
    }

    pub fn on_shed(&mut self, at_s: f64, id: u64, model: ModelId,
                   class: u8) {
        self.events.push(TraceEvent::Shed { at_s, id, model, class });
    }

    pub fn on_expired(&mut self, at_s: f64, id: u64, model: ModelId,
                      class: u8) {
        self.events.push(TraceEvent::Expired { at_s, id, model, class });
    }

    /// One residency change beginning at dispatch time `start_s`.
    pub fn on_swap(&mut self, device: usize, start_s: f64, model: ModelId,
                   swap: &SwapOutcome) {
        self.events.push(TraceEvent::Swap {
            device,
            start_s,
            model,
            unload_s: swap.unload_s,
            load_s: swap.load_s,
            bridge_s: swap.bridge_s,
            crypto_exposed_s: swap.crypto_exposed_s,
            promoted: swap.promoted,
        });
    }

    pub fn on_exec(&mut self, device: usize, start_s: f64, model: ModelId,
                   rows: usize, exec_s: f64, io_s: f64) {
        self.events.push(TraceEvent::Exec {
            device, start_s, model, rows, exec_s, io_s,
        });
    }

    /// One pipeline stage's slice of a batch on a member-device lane
    /// (pipeline-parallel runs only).
    pub fn on_stage_exec(&mut self, device: usize, start_s: f64,
                         model: ModelId, rows: usize, exec_s: f64) {
        self.events.push(TraceEvent::StageExec {
            device, start_s, model, rows, exec_s,
        });
    }

    /// One completed request: the class-lane span plus its waterfall
    /// row.  `dispatch_s` is the decision instant `t` (queue wait ends
    /// there; the swap begins there).  `activation_io_s` is the
    /// inter-stage slice already inside `io_s` (0 off pp).
    #[allow(clippy::too_many_arguments)]
    pub fn on_request(&mut self, c: &CompletedRequest, class: u8,
                      sla_met: bool, dispatch_s: f64, swap: &SwapOutcome,
                      exec_s: f64, io_s: f64, activation_io_s: f64) {
        self.events.push(TraceEvent::Request {
            id: c.id,
            model: c.model,
            class,
            device: c.device,
            arrival_s: c.arrival_s,
            complete_s: c.complete_s,
            sla_met,
        });
        self.waterfalls.push(Waterfall {
            id: c.id,
            model: c.model,
            device: c.device,
            class,
            arrival_s: c.arrival_s,
            queue_wait_s: (dispatch_s - c.arrival_s).max(0.0),
            swap_unload_s: swap.unload_s,
            swap_load_s: swap.load_s,
            swap_bridge_s: swap.bridge_s,
            swap_crypto_exposed_s: swap.crypto_exposed_s,
            promoted: swap.promoted,
            exec_s,
            io_s,
            activation_io_s,
            latency_s: c.latency_s(),
        });
    }

    /// Aggregate the waterfalls into the summary's `phase_totals`
    /// block.
    pub fn phase_totals(&self) -> PhaseTotals {
        let mut t = PhaseTotals {
            requests: self.waterfalls.len() as u64,
            ..PhaseTotals::default()
        };
        let mut qh = Histogram::new();
        let mut lh = Histogram::new();
        let mut eh = Histogram::new();
        for w in &self.waterfalls {
            t.queue_wait_s += w.queue_wait_s;
            t.swap_unload_s += w.swap_unload_s;
            t.swap_load_s += w.swap_load_s;
            t.swap_bridge_s += w.swap_bridge_s;
            t.swap_crypto_exposed_s += w.swap_crypto_exposed_s;
            t.exec_s += w.exec_s;
            t.io_s += w.io_s;
            t.activation_io_s += w.activation_io_s;
            t.latency_s += w.latency_s;
            qh.record(w.queue_wait_s.max(0.0));
            lh.record(w.swap_load_s.max(0.0));
            eh.record(w.exec_s.max(0.0));
        }
        t.queue_wait_p95_s = qh.quantile(0.95);
        t.swap_load_p95_s = lh.quantile(0.95);
        t.exec_p95_s = eh.quantile(0.95);
        t
    }

    /// Render the event sequence as Chrome trace-event JSON
    /// (Perfetto-loadable).  Lane layout: pid 0 throughout; device
    /// lanes at tid 0..D-1 (swap + exec spans; the gaps are idle
    /// time), SLA-class lanes at tid [`CLASS_TID_BASE`]+class — or a
    /// single `requests` lane when classes are off — carrying
    /// per-request spans plus shed/expiry instants.  Timestamps are
    /// virtual seconds scaled to microseconds (the format's unit).
    pub fn to_chrome_json(&self, label: &str, table: &ModelTable,
                          dev_modes: &[CcMode], classes_on: bool)
                          -> Json {
        let us = |s: f64| Json::num(s * 1e6);
        let mut events: Vec<Json> = Vec::new();
        for (d, mode) in dev_modes.iter().enumerate() {
            events.push(Json::obj(vec![
                ("ph", Json::str("M")),
                ("pid", Json::num(0.0)),
                ("tid", Json::num(d as f64)),
                ("name", Json::str("thread_name")),
                ("args", Json::obj(vec![("name", Json::str(format!(
                    "device {d} ({})", mode.as_str())))])),
            ]));
        }
        let class_lanes: &[&str] = if classes_on {
            &crate::tenancy::CLASS_NAMES
        } else {
            &["requests"]
        };
        for (c, name) in class_lanes.iter().enumerate() {
            events.push(Json::obj(vec![
                ("ph", Json::str("M")),
                ("pid", Json::num(0.0)),
                ("tid", Json::num((CLASS_TID_BASE + c) as f64)),
                ("name", Json::str("thread_name")),
                ("args", Json::obj(vec![("name",
                                         Json::str(name.to_string()))])),
            ]));
        }
        let class_tid = |class: u8| -> f64 {
            if classes_on {
                (CLASS_TID_BASE + class as usize) as f64
            } else {
                CLASS_TID_BASE as f64
            }
        };
        for ev in &self.events {
            events.push(match ev {
                TraceEvent::Shed { at_s, id, model, class } => {
                    instant("shed", table.name(*model), *at_s,
                            class_tid(*class), *id)
                }
                TraceEvent::Expired { at_s, id, model, class } => {
                    instant("expired", table.name(*model), *at_s,
                            class_tid(*class), *id)
                }
                TraceEvent::Swap { device, start_s, model, unload_s,
                                   load_s, bridge_s, crypto_exposed_s,
                                   promoted } => Json::obj(vec![
                    ("ph", Json::str("X")),
                    ("pid", Json::num(0.0)),
                    ("tid", Json::num(*device as f64)),
                    ("cat", Json::str("swap")),
                    ("name", Json::str(format!(
                        "swap:{}", table.name(*model)))),
                    ("ts", us(*start_s)),
                    ("dur", us(unload_s + load_s)),
                    ("args", Json::obj(vec![
                        ("unload_s", Json::num(*unload_s)),
                        ("load_s", Json::num(*load_s)),
                        ("bridge_s", Json::num(*bridge_s)),
                        ("crypto_exposed_s",
                         Json::num(*crypto_exposed_s)),
                        ("promoted", Json::Bool(*promoted)),
                    ])),
                ]),
                TraceEvent::Exec { device, start_s, model, rows, exec_s,
                                   io_s } => Json::obj(vec![
                    ("ph", Json::str("X")),
                    ("pid", Json::num(0.0)),
                    ("tid", Json::num(*device as f64)),
                    ("cat", Json::str("exec")),
                    ("name", Json::str(format!(
                        "exec:{}", table.name(*model)))),
                    ("ts", us(*start_s)),
                    ("dur", us(exec_s + io_s)),
                    ("args", Json::obj(vec![
                        ("rows", Json::num(*rows as f64)),
                        ("exec_s", Json::num(*exec_s)),
                        ("io_s", Json::num(*io_s)),
                    ])),
                ]),
                TraceEvent::StageExec { device, start_s, model, rows,
                                        exec_s } => Json::obj(vec![
                    ("ph", Json::str("X")),
                    ("pid", Json::num(0.0)),
                    ("tid", Json::num(*device as f64)),
                    ("cat", Json::str("exec")),
                    ("name", Json::str(format!(
                        "stage:{}", table.name(*model)))),
                    ("ts", us(*start_s)),
                    ("dur", us(*exec_s)),
                    ("args", Json::obj(vec![
                        ("rows", Json::num(*rows as f64)),
                        ("exec_s", Json::num(*exec_s)),
                    ])),
                ]),
                TraceEvent::Request { id, model, class, device,
                                      arrival_s, complete_s,
                                      sla_met } => Json::obj(vec![
                    ("ph", Json::str("X")),
                    ("pid", Json::num(0.0)),
                    ("tid", Json::num(class_tid(*class))),
                    ("cat", Json::str("request")),
                    ("name", Json::str(table.name(*model).to_string())),
                    ("ts", us(*arrival_s)),
                    ("dur", us(complete_s - arrival_s)),
                    ("args", Json::obj(vec![
                        ("id", Json::num(*id as f64)),
                        ("device", Json::num(*device as f64)),
                        ("sla_met", Json::Bool(*sla_met)),
                    ])),
                ]),
            });
        }
        Json::obj(vec![
            ("label", Json::str(label.to_string())),
            ("schemaVersion",
             Json::num(TRACE_SCHEMA_VERSION as f64)),
            ("traceEvents", Json::Arr(events)),
        ])
    }

    /// Write `<label>_waterfall.csv` (`--trace full`): one row per
    /// completed request, phase columns summing to `latency_s` within
    /// 1e-9.  Nine decimal places so the identity stays checkable from
    /// the file itself.
    pub fn write_waterfall_csv(&self, dir: &Path, label: &str,
                               table: &ModelTable) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        let cap = (self.waterfalls.len().max(64) * 160).min(1 << 22);
        // the activation column exists only when a run actually moved
        // inter-stage tensors (pipeline-parallel) — stage-free files
        // keep the exact legacy header
        let has_act =
            self.waterfalls.iter().any(|r| r.activation_io_s > 0.0);
        let mut header = vec![
            "id", "model", "device", "class", "arrival_s",
            "queue_wait_s", "swap_unload_s", "swap_load_s",
            "swap_bridge_s", "swap_crypto_exposed_s", "promoted",
            "exec_s", "io_s"];
        if has_act {
            header.push("activation_io_s");
        }
        header.push("latency_s");
        let mut w = CsvWriter::create_with_capacity(
            &dir.join(format!("{label}_waterfall.csv")), &header, cap)?;
        let f = |v: f64| format!("{v:.9}");
        for r in &self.waterfalls {
            let mut row = vec![
                r.id.to_string(), table.name(r.model).to_string(),
                r.device.to_string(), r.class.to_string(),
                f(r.arrival_s), f(r.queue_wait_s),
                f(r.swap_unload_s), f(r.swap_load_s),
                f(r.swap_bridge_s), f(r.swap_crypto_exposed_s),
                r.promoted.to_string(), f(r.exec_s), f(r.io_s)];
            if has_act {
                row.push(f(r.activation_io_s));
            }
            row.push(f(r.latency_s));
            w.row(&row)?;
        }
        w.flush()?;
        Ok(())
    }
}

/// First SLA-class lane id — device lanes occupy 0..D-1, and no fleet
/// approaches 100 devices.
pub const CLASS_TID_BASE: usize = 100;

fn instant(kind: &str, model: &str, at_s: f64, tid: f64, id: u64)
           -> Json {
    Json::obj(vec![
        ("ph", Json::str("i")),
        ("s", Json::str("t")),
        ("pid", Json::num(0.0)),
        ("tid", Json::num(tid)),
        ("cat", Json::str(kind.to_string())),
        ("name", Json::str(format!("{kind}:{model}"))),
        ("ts", Json::num(at_s * 1e6)),
        ("args", Json::obj(vec![("id", Json::num(id as f64))])),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn swap(unload: f64, load: f64) -> SwapOutcome {
        SwapOutcome {
            swapped: true,
            load_s: load,
            unload_s: unload,
            ..SwapOutcome::default()
        }
    }

    fn completed(id: u64, arrival: f64, dispatch: f64, swap_cost: f64,
                 exec: f64, io: f64) -> CompletedRequest {
        let start = dispatch + swap_cost;
        CompletedRequest {
            id,
            model: ModelId(0),
            arrival_s: arrival,
            exec_start_s: start,
            complete_s: start + exec + io,
            batch: 4,
            batch_rows: 1,
            caused_swap: swap_cost > 0.0,
            device: 0,
        }
    }

    #[test]
    fn trace_mode_parses_and_round_trips() {
        for name in TRACE_MODE_NAMES {
            assert_eq!(TraceMode::parse(name).unwrap().as_str(), *name);
        }
        assert_eq!(TraceMode::default(), TraceMode::Off);
        assert!(!TraceMode::Off.is_on());
        assert!(TraceMode::Events.is_on() && TraceMode::Full.is_on());
        let err = TraceMode::parse("verbose").unwrap_err().to_string();
        assert!(err.contains("verbose") && err.contains("events"),
                "{err}");
    }

    #[test]
    fn waterfall_identity_holds_by_construction() {
        let mut tr = Trace::new();
        let sw = swap(0.01, 1.7);
        let c = completed(7, 2.0, 3.5, 1.71, 0.2, 0.005);
        tr.on_request(&c, 0, true, 3.5, &sw, 0.2, 0.005, 0.0);
        assert_eq!(tr.waterfalls.len(), 1);
        let w = &tr.waterfalls[0];
        assert!((w.phase_sum_s() - w.latency_s).abs() <= 1e-9,
                "phases {} vs latency {}", w.phase_sum_s(), w.latency_s);
        assert!((w.queue_wait_s - 1.5).abs() < 1e-12);
    }

    #[test]
    fn phase_totals_sum_and_roundtrip() {
        let mut tr = Trace::new();
        let sw = swap(0.01, 1.0);
        for i in 0..4 {
            let c = completed(i, i as f64, i as f64 + 0.5, 1.01,
                              0.2, 0.01);
            tr.on_request(&c, 0, true, i as f64 + 0.5, &sw, 0.2, 0.01,
                          0.0);
        }
        let t = tr.phase_totals();
        assert_eq!(t.requests, 4);
        assert!((t.queue_wait_s - 2.0).abs() < 1e-9);
        assert!((t.swap_load_s - 4.0).abs() < 1e-9);
        assert!((t.exec_s - 0.8).abs() < 1e-9);
        let phase_sum = t.queue_wait_s + t.swap_unload_s + t.swap_load_s
            + t.exec_s + t.io_s;
        assert!((phase_sum - t.latency_s).abs() <= 4.0 * 1e-9);
        assert!(t.swap_load_p95_s > 0.9);
        let back = PhaseTotals::from_json(&t.to_json());
        assert_eq!(back, t);
    }

    #[test]
    fn chrome_json_carries_lanes_and_spans() {
        let mut tr = Trace::new();
        let sw = swap(0.0, 2.0);
        tr.on_swap(0, 1.0, ModelId(0), &sw);
        tr.on_exec(0, 3.0, ModelId(0), 2, 0.4, 0.01);
        let c = completed(1, 0.5, 1.0, 2.0, 0.4, 0.01);
        tr.on_request(&c, 0, true, 1.0, &sw, 0.4, 0.01, 0.0);
        tr.on_shed(4.0, 9, ModelId(0), 0);
        let table = ModelTable::new(["llama-sim"]);
        let j = tr.to_chrome_json("probe", &table,
                                  &[CcMode::On], false);
        let text = j.to_string();
        assert!(text.contains("\"traceEvents\""), "{text}");
        assert!(text.contains("\"schemaVersion\":1"), "{text}");
        assert!(text.contains("device 0 (cc)"), "{text}");
        assert!(text.contains("\"requests\""), "{text}");
        assert!(text.contains("swap:llama-sim"), "{text}");
        assert!(text.contains("exec:llama-sim"), "{text}");
        assert!(text.contains("shed:llama-sim"), "{text}");
        // swap span: ts 1s -> 1e6 µs, dur 2s -> 2e6 µs
        assert!(text.contains("\"ts\":1000000"), "{text}");
        assert!(text.contains("\"dur\":2000000"), "{text}");
        let n = j.get("traceEvents").and_then(|v| v.as_arr())
            .map(|a| a.len()).unwrap_or(0);
        // 2 metadata lanes + 4 recorded events
        assert_eq!(n, 6);
    }

    #[test]
    fn class_lanes_split_by_class_when_on() {
        let mut tr = Trace::new();
        let sw = swap(0.0, 0.0);
        let c = completed(1, 0.5, 1.0, 0.0, 0.4, 0.01);
        tr.on_request(&c, 2, true, 1.0, &sw, 0.4, 0.01, 0.0);
        let table = ModelTable::new(["llama-sim"]);
        let text = tr.to_chrome_json("probe", &table, &[CcMode::Off],
                                     true).to_string();
        assert!(text.contains("\"gold\"") && text.contains("\"free\""),
                "{text}");
        // class 2 rides lane CLASS_TID_BASE + 2
        assert!(text.contains(&format!("\"tid\":{}",
                                       CLASS_TID_BASE + 2)), "{text}");
    }

    #[test]
    fn stage_spans_ride_member_lanes() {
        let mut tr = Trace::new();
        tr.on_exec(0, 1.0, ModelId(0), 4, 0.8, 0.05);
        tr.on_stage_exec(1, 1.1, ModelId(0), 4, 0.4);
        let table = ModelTable::new(["llama-sim"]);
        let text = tr.to_chrome_json("probe", &table,
                                     &[CcMode::On, CcMode::On], false)
            .to_string();
        assert!(text.contains("stage:llama-sim"), "{text}");
        assert!(text.contains("exec:llama-sim"), "{text}");
        // the stage span sits on device lane 1
        assert!(text.contains("\"tid\":1"), "{text}");
    }

    #[test]
    fn activation_io_attributes_within_io() {
        let mut tr = Trace::new();
        let sw = swap(0.01, 1.0);
        // io 0.05 of which 0.02 is inter-stage activation transfer
        let c = completed(5, 0.0, 1.0, 1.01, 0.3, 0.05);
        tr.on_request(&c, 0, true, 1.0, &sw, 0.3, 0.05, 0.02);
        let w = &tr.waterfalls[0];
        assert!((w.activation_io_s - 0.02).abs() < 1e-12);
        assert!(w.activation_io_s < w.io_s);
        // attribution, not a new phase: the identity is unchanged
        assert!((w.phase_sum_s() - w.latency_s).abs() <= 1e-9);
        let t = tr.phase_totals();
        assert!((t.activation_io_s - 0.02).abs() < 1e-12);
        let text = t.to_json().to_string();
        assert!(text.contains("\"activation_io_s\""), "{text}");
        let back = PhaseTotals::from_json(&t.to_json());
        assert_eq!(back, t);
        // stage-free totals keep the key out entirely
        let mut plain = Trace::new();
        plain.on_request(&c, 0, true, 1.0, &sw, 0.3, 0.05, 0.0);
        let text = plain.phase_totals().to_json().to_string();
        assert!(!text.contains("activation"),
                "leaked activation key: {text}");
    }

    #[test]
    fn waterfall_csv_grows_activation_column_only_under_pp() {
        let table = ModelTable::new(["llama-sim"]);
        let dir = std::env::temp_dir().join("sincere_obs_pp_csv");
        let sw = swap(0.0, 0.5);
        let c = completed(1, 0.0, 1.0, 0.5, 0.2, 0.04);
        let mut plain = Trace::new();
        plain.on_request(&c, 0, true, 1.0, &sw, 0.2, 0.04, 0.0);
        plain.write_waterfall_csv(&dir, "plain", &table).unwrap();
        let tab = crate::util::csvio::CsvTable::read(
            &dir.join("plain_waterfall.csv")).unwrap();
        assert!(tab.col("activation_io_s").is_err(),
                "stage-free files must keep the legacy header");

        let mut pp = Trace::new();
        pp.on_request(&c, 0, true, 1.0, &sw, 0.2, 0.04, 0.015);
        pp.write_waterfall_csv(&dir, "pp", &table).unwrap();
        let tab = crate::util::csvio::CsvTable::read(
            &dir.join("pp_waterfall.csv")).unwrap();
        let col = tab.col("activation_io_s")
            .expect("pp files carry the activation column");
        assert!((tab.rows[0][col].parse::<f64>().unwrap() - 0.015).abs()
                < 1e-9);
        // attribution stays inside io_s: the file identity is unchanged
        let v = |name: &str| tab.f64_col(name).unwrap()[0];
        let sum = v("queue_wait_s") + v("swap_unload_s")
            + v("swap_load_s") + v("exec_s") + v("io_s");
        assert!((sum - v("latency_s")).abs() <= 1e-8);
    }

    #[test]
    fn waterfall_csv_writes_and_sums() {
        let mut tr = Trace::new();
        let sw = swap(0.01, 1.0);
        let c = completed(3, 1.0, 2.0, 1.01, 0.3, 0.02);
        tr.on_request(&c, 1, false, 2.0, &sw, 0.3, 0.02, 0.0);
        let dir = std::env::temp_dir().join("sincere_obs_test");
        let table = ModelTable::new(["llama-sim"]);
        tr.write_waterfall_csv(&dir, "t", &table).unwrap();
        let tab = crate::util::csvio::CsvTable::read(
            &dir.join("t_waterfall.csv")).unwrap();
        assert_eq!(tab.rows.len(), 1);
        let col = |name: &str| tab.f64_col(name).unwrap()[0];
        let sum = col("queue_wait_s") + col("swap_unload_s")
            + col("swap_load_s") + col("exec_s") + col("io_s");
        assert!((sum - col("latency_s")).abs() <= 1e-8,
                "file identity: {sum} vs {}", col("latency_s"));
        assert_eq!(tab.rows[0][tab.col("model").unwrap()], "llama-sim");
        assert_eq!(tab.rows[0][tab.col("class").unwrap()], "1");
    }
}
