//! In-repo micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use this with `harness = false`: warmup, timed
//! iterations, mean/p50/p99/stddev, and markdown table output that the
//! figure benches print in the shape of the paper's tables.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn mean_s(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    /// Row for a markdown table.
    pub fn row(&self) -> String {
        format!("| {} | {} | {} | {} | {} | {} |",
                self.name, fmt_dur(self.mean), fmt_dur(self.p50),
                fmt_dur(self.p99), fmt_dur(self.stddev), self.iters)
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Benchmark runner with fixed warmup/measure iteration counts.
pub struct Bench {
    pub warmup_iters: usize,
    pub iters: usize,
    results: Vec<BenchResult>,
}

impl Bench {
    pub fn new(warmup_iters: usize, iters: usize) -> Bench {
        assert!(iters > 0);
        Bench { warmup_iters, iters, results: Vec::new() }
    }

    /// Honour `SINCERE_BENCH_FAST=1` (CI smoke mode): divide iteration
    /// counts by 5.
    pub fn from_env(warmup: usize, iters: usize) -> Bench {
        if std::env::var("SINCERE_BENCH_FAST").as_deref() == Ok("1") {
            Bench::new((warmup / 5).max(1), (iters / 5).max(2))
        } else {
            Bench::new(warmup, iters)
        }
    }

    /// Time `f` (called once per iteration).
    pub fn run(&mut self, name: &str, mut f: impl FnMut()) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        self.push_samples(name, samples)
    }

    /// Record externally-measured samples (e.g. per-batch times).
    pub fn push_samples(&mut self, name: &str, mut samples: Vec<Duration>)
                        -> &BenchResult {
        assert!(!samples.is_empty());
        samples.sort();
        let n = samples.len();
        let sum: Duration = samples.iter().sum();
        let mean = sum / n as u32;
        let mean_s = mean.as_secs_f64();
        let var = samples.iter()
            .map(|d| (d.as_secs_f64() - mean_s).powi(2))
            .sum::<f64>() / n as f64;
        let result = BenchResult {
            name: name.to_string(),
            iters: n,
            mean,
            p50: samples[n / 2],
            p99: samples[(n * 99 / 100).min(n - 1)],
            stddev: Duration::from_secs_f64(var.sqrt()),
            min: samples[0],
            max: samples[n - 1],
        };
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Print all results as a markdown table.
    pub fn print_table(&self, title: &str) {
        println!("\n## {title}\n");
        println!("| case | mean | p50 | p99 | stddev | iters |");
        println!("|---|---|---|---|---|---|");
        for r in &self.results {
            println!("{}", r.row());
        }
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleep_roughly() {
        let mut b = Bench::new(1, 5);
        let r = b.run("sleep1ms",
                      || std::thread::sleep(Duration::from_millis(1)));
        assert!(r.mean >= Duration::from_millis(1));
        assert!(r.mean < Duration::from_millis(20));
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn stats_ordering() {
        let mut b = Bench::new(0, 1);
        let samples = vec![
            Duration::from_millis(1), Duration::from_millis(2),
            Duration::from_millis(3), Duration::from_millis(10)];
        let r = b.push_samples("s", samples);
        assert!(r.min <= r.p50 && r.p50 <= r.p99 && r.p99 <= r.max);
        assert_eq!(r.max, Duration::from_millis(10));
    }

    #[test]
    fn table_renders() {
        let mut b = Bench::new(0, 2);
        b.run("noop", || {});
        let row = b.results()[0].row();
        assert!(row.contains("noop"));
    }
}
