//! `sincere` — the serving coordinator CLI.
//!
//! Subcommands (the paper's workflow, §III-A) — this list is rendered
//! from the same [`COMMANDS`] table that drives dispatch and
//! `print_usage`, so docs and help cannot drift:
//!
//! * `profile` — measure model load/unload (Fig 3) and per-batch
//!   execution (Fig 4); writes `results/cost_model.json` and sets OBS.
//! * `serve` — run one serving experiment for real (one grid cell),
//!   via the `Engine` with the `RealBackend`.
//! * `serve-http` — long-running network front-end (the paper's Flask
//!   API analogue): `POST /infer`, `GET /stats`, `GET /healthz`.
//! * `sweep` — run the full evaluation grid via the `Engine` with the
//!   calibrated `DesBackend`.
//! * `report` — render paper-style tables from saved summaries.
//! * `gen-traffic` — emit an arrival trace (jsonl) for inspection.
//! * `models` — print the Table II analogue from the manifest.
//!
//! Options are `--key value` pairs; see `config::RunConfig::set`.

use std::path::{Path, PathBuf};

use sincere::config::RunConfig;
use sincere::coordinator::{placement_names, strategy_names};
use sincere::engine::EngineBuilder;
use sincere::gpu::CcMode;
use sincere::metrics::report;
use sincere::runtime::{Manifest, Registry};
use sincere::sim::CostModel;
use sincere::traffic::{pattern_by_name, PATTERN_NAMES};
use sincere::util::json::Json;

/// One CLI subcommand: name, help blurb, and entry point.  The single
/// source of truth for dispatch, `print_usage`, and the module doc.
struct Command {
    name: &'static str,
    blurb: &'static str,
    run: fn(RunConfig) -> anyhow::Result<()>,
}

const COMMANDS: &[Command] = &[
    Command {
        name: "profile",
        blurb: "measure load times (Fig 3) + batch throughput (Fig 4); \
                caches cost model",
        run: cmd_profile,
    },
    Command {
        name: "serve",
        blurb: "run one real serving experiment (Engine + RealBackend)",
        run: cmd_serve,
    },
    Command {
        name: "serve-http",
        blurb: "network front-end (POST /infer; SINCERE_HTTP_ADDR)",
        run: cmd_serve_http,
    },
    Command {
        name: "sweep",
        blurb: "run the full 72-cell grid (Engine + calibrated \
                DesBackend)",
        run: cmd_sweep,
    },
    Command {
        name: "report",
        blurb: "render tables from saved sweep results",
        run: cmd_report,
    },
    Command {
        name: "gen-traffic",
        blurb: "write an arrival trace (jsonl)",
        run: cmd_gen_traffic,
    },
    Command {
        name: "models",
        blurb: "print the model fleet (Table II)",
        run: cmd_models,
    },
];

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: Vec<String>) -> anyhow::Result<()> {
    let Some((cmd, rest)) = args.split_first() else {
        print_usage();
        return Ok(());
    };
    if matches!(cmd.as_str(), "help" | "--help" | "-h") {
        print_usage();
        return Ok(());
    }
    let mut cfg = RunConfig::default();
    let rest = apply_flags(&mut cfg, rest)?;
    anyhow::ensure!(rest.is_empty(), "unexpected arguments: {rest:?}");

    let command = COMMANDS.iter().find(|c| c.name == cmd.as_str())
        .ok_or_else(|| anyhow::anyhow!(
            "unknown command {cmd:?}; try `help`"))?;
    (command.run)(cfg)
}

/// Parse `--key value` flags into the config; `--config file.json` loads
/// a JSON config first.  Returns leftover positional args.
fn apply_flags(cfg: &mut RunConfig, args: &[String])
               -> anyhow::Result<Vec<String>> {
    let mut rest = Vec::new();
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(key) = a.strip_prefix("--") {
            let value = it.next().ok_or_else(
                || anyhow::anyhow!("--{key} needs a value"))?;
            if key == "config" {
                cfg.apply_json_file(Path::new(value))?;
            } else {
                cfg.set(key, value)?;
            }
        } else {
            rest.push(a.clone());
        }
    }
    Ok(rest)
}

fn results_dir(cfg: &RunConfig) -> PathBuf {
    cfg.results_dir.clone().unwrap_or_else(|| PathBuf::from("results"))
}

/// Load the registry and apply profiled OBS values if a cost model is
/// cached on disk.
fn load_registry(cfg: &RunConfig) -> anyhow::Result<(Manifest, Registry)> {
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    eprintln!("[sincere] compiling executables \
               (families={:?}, batches={:?}) ...",
              if cfg.models.is_empty() { manifest.family_names() }
              else { cfg.models.clone() },
              cfg.batch_sizes);
    let mut registry = Registry::load(&manifest, &cfg.models,
                                      &cfg.batch_sizes)?;
    eprintln!("[sincere] compiled in {:.1}s",
              registry.total_compile_time.as_secs_f64());
    let cm_path = results_dir(cfg).join("cost_model.json");
    if cm_path.exists() {
        let cm = CostModel::load(&cm_path)?;
        for name in registry.names() {
            if let Ok(mc) = cm.costs(&name) {
                let _ = registry.set_obs(&name, mc.obs);
            }
        }
        eprintln!("[sincere] applied OBS from {cm_path:?}");
    }
    Ok((manifest, registry))
}

// ------------------------------------------------------------------ serve

fn cmd_serve(mut cfg: RunConfig) -> anyhow::Result<()> {
    if cfg.results_dir.is_none() {
        cfg.results_dir = Some(PathBuf::from("results"));
    }
    if cfg.label == "run" {
        cfg.label = cfg.cell_label();
    }
    let (_manifest, registry) = load_registry(&cfg)?;
    eprintln!("[sincere] serving: {}", cfg.cell_label());
    let (summary, _rec) = EngineBuilder::new(&cfg).real(&registry)?
        .run()?;
    println!("{}", summary.brief());
    println!("{}", summary.to_json());
    Ok(())
}

// ------------------------------------------------------------- serve-http

/// Long-running network front-end (the paper's Flask API analogue):
/// `POST /infer`, `GET /stats`, `GET /healthz`.  Listens on
/// `SINCERE_HTTP_ADDR` (default 127.0.0.1:8080); stop with Ctrl-C.
fn cmd_serve_http(cfg: RunConfig) -> anyhow::Result<()> {
    let addr = std::env::var("SINCERE_HTTP_ADDR")
        .unwrap_or_else(|_| "127.0.0.1:8080".to_string());
    let (_manifest, registry) = load_registry(&cfg)?;
    let shutdown = std::sync::Arc::new(
        std::sync::atomic::AtomicBool::new(false));
    eprintln!("[sincere] http front-end on {addr} (mode={}, strategy={}, \
               sla={}s)", cfg.mode.as_str(), cfg.strategy, cfg.sla_s);
    let stats = sincere::coordinator::http::run_http(
        &cfg, &registry, &addr, shutdown, |bound| {
            eprintln!("[sincere] listening on {bound}");
        })?;
    eprintln!("[sincere] served {} requests",
              stats.completed.load(std::sync::atomic::Ordering::Relaxed));
    Ok(())
}

// ---------------------------------------------------------------- profile

fn cmd_profile(cfg: RunConfig) -> anyhow::Result<()> {
    let (_manifest, registry) = load_registry(&cfg)?;
    eprintln!("[sincere] profiling loads + batches (this sleeps through \
               DMA throttles) ...");
    let cm = CostModel::measure(&registry, &cfg.gpu, 3)?;

    println!("\n## Model load times (Fig 3)\n");
    println!("| model | No-CC load (s) | CC load (s) | CC/No-CC | \
              unload (s) |");
    println!("|---|---|---|---|---|");
    for (name, mc) in &cm.models {
        println!("| {} | {:.3} | {:.3} | {:.2}x | {:.4} |", name,
                 mc.load_s_plain, mc.load_s_cc,
                 mc.load_s_cc / mc.load_s_plain.max(1e-9), mc.unload_s);
    }

    println!("\n## Throughput vs batch size (Fig 4)\n");
    println!("| model | batch | exec (s) | throughput (req/s) | note |");
    println!("|---|---|---|---|---|");
    for (name, mc) in &cm.models {
        for (&b, &e) in &mc.exec_s_by_batch {
            let note = if b == mc.obs { "OBS" } else { "" };
            println!("| {} | {} | {:.3} | {:.2} | {} |", name, b, e,
                     b as f64 / e, note);
        }
        for &b in &mc.oom_batches {
            println!("| {} | {} | - | - | OOM |", name, b);
        }
    }

    let path = results_dir(&cfg).join("cost_model.json");
    cm.save(&path)?;
    eprintln!("\n[sincere] saved {path:?}");
    Ok(())
}

// ------------------------------------------------------------------ sweep

fn cmd_sweep(cfg: RunConfig) -> anyhow::Result<()> {
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let cm_path = results_dir(&cfg).join("cost_model.json");
    let cm = if cm_path.exists() {
        eprintln!("[sincere] using cached {cm_path:?}");
        CostModel::load(&cm_path)?
    } else {
        let (_m, registry) = load_registry(&cfg)?;
        let cm = CostModel::measure(&registry, &cfg.gpu, 3)?;
        cm.save(&cm_path)?;
        cm
    };

    let slas = sincere::config::SLA_LADDER;
    let mut cells = Vec::new();
    for mode in [CcMode::Off, CcMode::On] {
        for pattern in PATTERN_NAMES {
            for strategy in strategy_names() {
                for &sla in slas {
                    let mut c = cfg.clone();
                    c.mode = mode;
                    c.gpu.mode = mode;
                    c.pattern = pattern.to_string();
                    c.strategy = strategy.to_string();
                    c.sla_s = sla;
                    c.label = c.cell_label();
                    // the sweep persists one aggregate JSON below, not
                    // 72 sets of per-cell CSVs
                    c.results_dir = None;
                    let (s, _) = EngineBuilder::new(&c)
                        .des(&manifest, &cm)?.run()?;
                    println!("{}", s.brief());
                    cells.push(s);
                }
            }
        }
    }

    println!("\n{}", report::cells_table(&cells));
    println!("\n## Headline comparison (paper abstract)\n");
    println!("{}", report::headline_table(&report::headline_ratios(&cells)));

    // persist all summaries
    let out = results_dir(&cfg).join("sweep_cells.json");
    let arr = Json::Arr(cells.iter().map(|c| c.to_json()).collect());
    std::fs::write(&out, arr.to_string())?;
    eprintln!("[sincere] wrote {out:?}");
    Ok(())
}

// ----------------------------------------------------------------- report

fn cmd_report(cfg: RunConfig) -> anyhow::Result<()> {
    let path = results_dir(&cfg).join("sweep_cells.json");
    let j = Json::parse_file(&path)?;
    let cells = parse_cells(&j)?;
    println!("{}", report::cells_table(&cells));
    println!("{}", report::headline_table(&report::headline_ratios(&cells)));
    Ok(())
}

fn parse_cells(j: &Json) -> anyhow::Result<Vec<sincere::engine::RunSummary>> {
    let mut out = Vec::new();
    for c in j.as_arr().unwrap_or(&[]) {
        out.push(sincere::engine::RunSummary {
            label: c.req("label")?.as_str().unwrap_or("").into(),
            mode: c.req("mode")?.as_str().unwrap_or("").into(),
            pattern: c.req("pattern")?.as_str().unwrap_or("").into(),
            strategy: c.req("strategy")?.as_str().unwrap_or("").into(),
            sla_s: c.req("sla_s")?.as_f64().unwrap_or(0.0),
            mean_rps: c.req("mean_rps")?.as_f64().unwrap_or(0.0),
            duration_s: c.req("duration_s")?.as_f64().unwrap_or(0.0),
            runtime_s: c.req("runtime_s")?.as_f64().unwrap_or(0.0),
            // fleet/pipeline fields are optional for older summary files
            devices: c.get("devices").and_then(|v| v.as_usize())
                .unwrap_or(1),
            placement: c.get("placement").and_then(|v| v.as_str())
                .unwrap_or("affinity").into(),
            pipeline_depth: c.get("pipeline_depth")
                .and_then(|v| v.as_usize()).unwrap_or(0),
            prefetch: c.get("prefetch").and_then(|v| v.as_bool())
                .unwrap_or(false),
            generated: c.req("generated")?.as_u64().unwrap_or(0),
            completed: c.req("completed")?.as_u64().unwrap_or(0),
            sla_met: c.req("sla_met")?.as_u64().unwrap_or(0),
            sla_attainment: c.req("sla_attainment")?.as_f64().unwrap_or(0.0),
            latency_mean_s: c.req("latency_mean_s")?.as_f64().unwrap_or(0.0),
            latency_p50_s: c.req("latency_p50_s")?.as_f64().unwrap_or(0.0),
            latency_p90_s: c.req("latency_p90_s")?.as_f64().unwrap_or(0.0),
            latency_p99_s: c.req("latency_p99_s")?.as_f64().unwrap_or(0.0),
            latency_max_s: c.req("latency_max_s")?.as_f64().unwrap_or(0.0),
            throughput_rps: c.req("throughput_rps")?.as_f64().unwrap_or(0.0),
            processing_rate_rps: c.req("processing_rate_rps")?.as_f64()
                .unwrap_or(0.0),
            gpu_util: c.req("gpu_util")?.as_f64().unwrap_or(0.0),
            swap_count: c.req("swap_count")?.as_u64().unwrap_or(0),
            total_load_s: c.req("total_load_s")?.as_f64().unwrap_or(0.0),
            total_unload_s: c.req("total_unload_s")?.as_f64().unwrap_or(0.0),
            total_exec_s: c.req("total_exec_s")?.as_f64().unwrap_or(0.0),
            total_crypto_s: c.req("total_crypto_s")?.as_f64().unwrap_or(0.0),
            total_crypto_exposed_s: c.get("total_crypto_exposed_s")
                .and_then(|v| v.as_f64()).unwrap_or(0.0),
            prefetch_count: c.get("prefetch_count")
                .and_then(|v| v.as_u64()).unwrap_or(0),
            promoted_count: c.get("promoted_count")
                .and_then(|v| v.as_u64()).unwrap_or(0),
            mean_load_s: c.req("mean_load_s")?.as_f64().unwrap_or(0.0),
            per_device: parse_per_device(c),
        });
    }
    Ok(out)
}

fn parse_per_device(c: &Json) -> Vec<sincere::engine::DeviceSummary> {
    let Some(arr) = c.get("per_device").and_then(|v| v.as_arr()) else {
        return Vec::new();
    };
    arr.iter().map(|d| sincere::engine::DeviceSummary {
        device: d.get("device").and_then(|v| v.as_usize()).unwrap_or(0),
        mode: d.get("mode").and_then(|v| v.as_str()).unwrap_or("").into(),
        batches: d.get("batches").and_then(|v| v.as_u64()).unwrap_or(0),
        completed: d.get("completed").and_then(|v| v.as_u64())
            .unwrap_or(0),
        exec_s: d.get("exec_s").and_then(|v| v.as_f64()).unwrap_or(0.0),
        util: d.get("util").and_then(|v| v.as_f64()).unwrap_or(0.0),
        swap_count: d.get("swap_count").and_then(|v| v.as_u64())
            .unwrap_or(0),
        load_s: d.get("load_s").and_then(|v| v.as_f64()).unwrap_or(0.0),
        unload_s: d.get("unload_s").and_then(|v| v.as_f64())
            .unwrap_or(0.0),
        crypto_s: d.get("crypto_s").and_then(|v| v.as_f64())
            .unwrap_or(0.0),
        crypto_exposed_s: d.get("crypto_exposed_s")
            .and_then(|v| v.as_f64()).unwrap_or(0.0),
        prefetches: d.get("prefetches").and_then(|v| v.as_u64())
            .unwrap_or(0),
        promotions: d.get("promotions").and_then(|v| v.as_u64())
            .unwrap_or(0),
    }).collect()
}

// ------------------------------------------------------------ gen-traffic

fn cmd_gen_traffic(cfg: RunConfig) -> anyhow::Result<()> {
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let models = if cfg.models.is_empty() {
        manifest.family_names()
    } else {
        cfg.models.clone()
    };
    let mut rng = sincere::traffic::rng::Pcg64::new(cfg.seed);
    let pattern = pattern_by_name(&cfg.pattern)?;
    let arrivals = pattern.generate(cfg.duration_s, cfg.mean_rps, &models,
                                    &mut rng);
    let mut prompts =
        sincere::workload::promptgen::PromptGen::new(cfg.seed ^ 0xBEEF, 24);
    let path = results_dir(&cfg)
        .join(format!("trace_{}_{}rps.jsonl", cfg.pattern, cfg.mean_rps));
    sincere::traffic::trace::write_trace(&path, &arrivals, &mut prompts)?;
    println!("wrote {} arrivals to {path:?}", arrivals.len());
    Ok(())
}

// ----------------------------------------------------------------- models

fn cmd_models(cfg: RunConfig) -> anyhow::Result<()> {
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    println!("| model | stands in for | paper size | sim weights | \
              layers | d_model | heads | d_ff | vocab |");
    println!("|---|---|---|---|---|---|---|---|---|");
    for f in &manifest.families {
        println!("| {} | {} | {:.2} GB | {:.2} MB | {} | {} | {} | {} | \
                  {} |",
                 f.name, f.hf_name, f.paper_gb,
                 f.weight_bytes() as f64 / 1e6, f.n_layers, f.d_model,
                 f.n_heads, f.d_ff, f.vocab);
    }
    Ok(())
}

/// Build the usage text from the [`COMMANDS`] table.
fn usage_string() -> String {
    let mut out = String::from(
        "sincere — relaxed batch LLM inference on a simulated \
         confidential GPU\n\n\
         USAGE: sincere <command> [--key value ...]\n\n\
         COMMANDS:\n");
    for c in COMMANDS {
        out.push_str(&format!("  {:<12} {}\n", c.name, c.blurb));
    }
    out.push_str(&format!(
        "  {:<12} {}\n\n\
         COMMON OPTIONS:\n\
         \x20 --mode cc|no-cc        confidential mode (default no-cc)\n\
         \x20 --pattern {patterns}\n\
         \x20 --strategy {strategies}\n\
         \x20 --sla SECONDS          (default 18.0; ladder 12/18/24)\n\
         \x20 --mean-rps RPS         (default 9.0)\n\
         \x20 --duration SECONDS     (default 60)\n\
         \x20 --models a,b           restrict families\n\
         \x20 --batch-sizes 1,2,4    restrict compiled batches\n\
         \x20 --artifacts DIR --results DIR --seed N --config FILE.json\n\n\
         FLEET OPTIONS:\n\
         \x20 --devices N            fleet size (default 1)\n\
         \x20 --device-modes cc,no-cc,...   per-device CC mode mix\n\
         \x20 --device-hbm-mb a,b    per-device HBM capacity, MB\n\
         \x20 --device-bw-scale a,b  per-device PCIe rate scale\n\
         \x20 --placement {placements}\n\n\
         CC PIPELINE OPTIONS:\n\
         \x20 --pipeline-depth N     CC bounce-chunk staging buffers: \
         0|1 = serialized\n\
         \x20                        (default), >=2 overlaps sealing \
         with the link\n\
         \x20 --cc-crypto-frac F     crypto share of the serialized CC \
         budget (default 0.5)\n\
         \x20 --prefetch on|off      decrypt-ahead the predicted next \
         model while a batch\n\
         \x20                        executes; the swap promotes it \
         without a second DMA\n",
        "help", "show this help",
        patterns = PATTERN_NAMES.join("|"),
        strategies = strategy_names().join("|"),
        placements = placement_names().join("|")));
    out
}

fn print_usage() {
    print!("{}", usage_string());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn command_names_unique() {
        let mut names: Vec<&str> = COMMANDS.iter().map(|c| c.name)
            .collect();
        names.sort_unstable();
        let n = names.len();
        names.dedup();
        assert_eq!(names.len(), n, "duplicate command names");
    }

    /// Help text is generated from the dispatch table, so every
    /// routable command (serve-http included) must appear in it.
    #[test]
    fn usage_lists_every_command() {
        let usage = usage_string();
        for c in COMMANDS {
            assert!(usage.contains(c.name),
                    "usage text is missing {:?}", c.name);
        }
        assert!(usage.contains("serve-http"));
    }

    /// Strategy and placement options in the help text are rendered
    /// from the same tables that drive lookup, so the lists in docs
    /// and error messages cannot drift.
    #[test]
    fn usage_lists_every_strategy_and_placement() {
        let usage = usage_string();
        for name in strategy_names() {
            assert!(usage.contains(name), "usage missing strategy {name}");
        }
        for name in placement_names() {
            assert!(usage.contains(name),
                    "usage missing placement {name}");
        }
    }

    #[test]
    fn flags_parse_into_config() {
        let mut cfg = RunConfig::default();
        let rest = apply_flags(&mut cfg, &[
            "--mode".into(), "cc".into(),
            "--sla".into(), "12".into(),
            "positional".into(),
        ]).unwrap();
        assert_eq!(cfg.sla_s, 12.0);
        assert_eq!(cfg.mode, sincere::gpu::CcMode::On);
        assert_eq!(rest, vec!["positional".to_string()]);
        assert!(apply_flags(&mut cfg, &["--sla".into()]).is_err());
    }
}
