//! `sincere` — the serving coordinator CLI.
//!
//! Subcommands (the paper's workflow, §III-A) — this list is rendered
//! from the same [`COMMANDS`] table that drives dispatch and
//! `print_usage`, so docs and help cannot drift:
//!
//! * `profile` — measure model load/unload (Fig 3) and per-batch
//!   execution (Fig 4); writes `results/cost_model.json` and sets OBS.
//! * `serve` — run one serving experiment for real (one grid cell),
//!   via the `Engine` with the `RealBackend`.
//! * `serve-http` — long-running network front-end (the paper's Flask
//!   API analogue): `POST /infer`, `GET /stats`, `GET /healthz`.
//! * `sweep` — the paper's 72-cell grid; a thin alias for
//!   `lab run --preset paper-72`.
//! * `lab` — the scenario lab: `run` a declarative experiment grid in
//!   parallel over the calibrated DES, `list` presets and axes,
//!   `compare` two saved runs, `check` a run against the abstract's
//!   headline bands.
//! * `report` — render paper-style tables from saved summaries.
//! * `gen-traffic` — emit an arrival trace (jsonl) for inspection.
//! * `models` — print the Table II analogue from the manifest.
//!
//! Options are `--key value` pairs; see `config::RunConfig::set`.

use std::path::{Path, PathBuf};

use sincere::config::RunConfig;
use sincere::coordinator::{placement_names, strategy_names};
use sincere::engine::{EngineBuilder, RunSummary};
use sincere::lab::{self, LabRunner, ScenarioSpec};
use sincere::metrics::report;
use sincere::runtime::{Manifest, Registry};
use sincere::sim::CostModel;
use sincere::traffic::{pattern_by_name, PATTERN_NAMES};

/// One CLI subcommand: name, help blurb, and entry point.  The single
/// source of truth for dispatch, `print_usage`, and the module doc.
/// `rest` carries the positional arguments left after `--key value`
/// flag parsing (only `lab` and its subcommands use them).
struct Command {
    name: &'static str,
    blurb: &'static str,
    run: fn(RunConfig, Vec<String>) -> anyhow::Result<()>,
}

const COMMANDS: &[Command] = &[
    Command {
        name: "profile",
        blurb: "measure load times (Fig 3) + batch throughput (Fig 4); \
                caches cost model",
        run: cmd_profile,
    },
    Command {
        name: "serve",
        blurb: "run one real serving experiment (Engine + RealBackend)",
        run: cmd_serve,
    },
    Command {
        name: "serve-http",
        blurb: "network front-end (POST /infer; SINCERE_HTTP_ADDR)",
        run: cmd_serve_http,
    },
    Command {
        name: "sweep",
        blurb: "the paper's 72-cell grid (alias for `lab run --preset \
                paper-72`)",
        run: cmd_sweep,
    },
    Command {
        name: "lab",
        blurb: "scenario lab: run|list|compare|check declarative \
                experiment grids",
        run: cmd_lab,
    },
    Command {
        name: "report",
        blurb: "render tables from saved sweep results",
        run: cmd_report,
    },
    Command {
        name: "gen-traffic",
        blurb: "write an arrival trace (jsonl)",
        run: cmd_gen_traffic,
    },
    Command {
        name: "models",
        blurb: "print the model fleet (Table II)",
        run: cmd_models,
    },
];

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: Vec<String>) -> anyhow::Result<()> {
    let Some((cmd, rest)) = args.split_first() else {
        print_usage();
        return Ok(());
    };
    if matches!(cmd.as_str(), "help" | "--help" | "-h") {
        print_usage();
        return Ok(());
    }
    let mut cfg = RunConfig::default();
    let rest = apply_flags(&mut cfg, rest)?;

    let command = COMMANDS.iter().find(|c| c.name == cmd.as_str())
        .ok_or_else(|| anyhow::anyhow!(
            "unknown command {cmd:?}; try `help`"))?;
    (command.run)(cfg, rest)
}

/// Parse `--key value` flags into the config; `--config file.json` loads
/// a JSON config first.  Returns leftover positional args.
fn apply_flags(cfg: &mut RunConfig, args: &[String])
               -> anyhow::Result<Vec<String>> {
    let mut rest = Vec::new();
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(key) = a.strip_prefix("--") {
            let value = it.next().ok_or_else(
                || anyhow::anyhow!("--{key} needs a value"))?;
            if key == "config" {
                cfg.apply_json_file(Path::new(value))?;
            } else {
                cfg.set(key, value)?;
            }
        } else {
            rest.push(a.clone());
        }
    }
    Ok(rest)
}

/// Most commands take no positional arguments.
fn no_extra_args(rest: &[String]) -> anyhow::Result<()> {
    anyhow::ensure!(rest.is_empty(), "unexpected arguments: {rest:?}");
    Ok(())
}

fn results_dir(cfg: &RunConfig) -> PathBuf {
    cfg.results_dir.clone().unwrap_or_else(|| PathBuf::from("results"))
}

/// Load the registry and apply profiled OBS values if a cost model is
/// cached on disk.
fn load_registry(cfg: &RunConfig) -> anyhow::Result<(Manifest, Registry)> {
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    eprintln!("[sincere] compiling executables \
               (families={:?}, batches={:?}) ...",
              if cfg.models.is_empty() { manifest.family_names() }
              else { cfg.models.clone() },
              cfg.batch_sizes);
    let mut registry = Registry::load(&manifest, &cfg.models,
                                      &cfg.batch_sizes)?;
    eprintln!("[sincere] compiled in {:.1}s",
              registry.total_compile_time.as_secs_f64());
    let cm_path = results_dir(cfg).join("cost_model.json");
    if cm_path.exists() {
        let cm = CostModel::load(&cm_path)?;
        for name in registry.names() {
            if let Ok(mc) = cm.costs(&name) {
                let _ = registry.set_obs(&name, mc.obs);
            }
        }
        eprintln!("[sincere] applied OBS from {cm_path:?}");
    }
    Ok((manifest, registry))
}

// ------------------------------------------------------------------ serve

fn cmd_serve(mut cfg: RunConfig, rest: Vec<String>)
             -> anyhow::Result<()> {
    no_extra_args(&rest)?;
    anyhow::ensure!(
        cfg.catalog == 0,
        "--catalog expands synthetic model families that have no \
         compiled artifacts; it is DES-only (use `lab run`)");
    if cfg.results_dir.is_none() {
        cfg.results_dir = Some(PathBuf::from("results"));
    }
    if cfg.label == "run" {
        cfg.label = cfg.cell_label();
    }
    let (_manifest, registry) = load_registry(&cfg)?;
    eprintln!("[sincere] serving: {}", cfg.cell_label());
    let (summary, _rec) = EngineBuilder::new(&cfg).real(&registry)?
        .run()?;
    println!("{}", summary.brief());
    println!("{}", summary.to_json());
    Ok(())
}

// ------------------------------------------------------------- serve-http

/// Long-running network front-end (the paper's Flask API analogue):
/// `POST /infer`, `GET /stats`, `GET /healthz`.  Listens on
/// `SINCERE_HTTP_ADDR` (default 127.0.0.1:8080); stop with Ctrl-C.
fn cmd_serve_http(cfg: RunConfig, rest: Vec<String>)
                  -> anyhow::Result<()> {
    no_extra_args(&rest)?;
    let addr = std::env::var("SINCERE_HTTP_ADDR")
        .unwrap_or_else(|_| "127.0.0.1:8080".to_string());
    let (_manifest, registry) = load_registry(&cfg)?;
    let shutdown = std::sync::Arc::new(
        std::sync::atomic::AtomicBool::new(false));
    eprintln!("[sincere] http front-end on {addr} (mode={}, strategy={}, \
               sla={}s)", cfg.mode.as_str(), cfg.strategy, cfg.sla_s);
    let stats = sincere::coordinator::http::run_http(
        &cfg, &registry, &addr, shutdown, |bound| {
            eprintln!("[sincere] listening on {bound}");
        })?;
    eprintln!("[sincere] served {} requests",
              stats.completed.load(std::sync::atomic::Ordering::Relaxed));
    Ok(())
}

// ---------------------------------------------------------------- profile

fn cmd_profile(cfg: RunConfig, rest: Vec<String>) -> anyhow::Result<()> {
    no_extra_args(&rest)?;
    let (_manifest, registry) = load_registry(&cfg)?;
    eprintln!("[sincere] profiling loads + batches (this sleeps through \
               DMA throttles) ...");
    let cm = CostModel::measure(&registry, &cfg.gpu, 3)?;

    println!("\n## Model load times (Fig 3)\n");
    println!("| model | No-CC load (s) | CC load (s) | CC/No-CC | \
              unload (s) |");
    println!("|---|---|---|---|---|");
    for (name, mc) in &cm.models {
        println!("| {} | {:.3} | {:.3} | {:.2}x | {:.4} |", name,
                 mc.load_s_plain, mc.load_s_cc,
                 mc.load_s_cc / mc.load_s_plain.max(1e-9), mc.unload_s);
    }

    println!("\n## Throughput vs batch size (Fig 4)\n");
    println!("| model | batch | exec (s) | throughput (req/s) | note |");
    println!("|---|---|---|---|---|");
    for (name, mc) in &cm.models {
        for (&b, &e) in &mc.exec_s_by_batch {
            let note = if b == mc.obs { "OBS" } else { "" };
            println!("| {} | {} | {:.3} | {:.2} | {} |", name, b, e,
                     b as f64 / e, note);
        }
        for &b in &mc.oom_batches {
            println!("| {} | {} | - | - | OOM |", name, b);
        }
    }

    let path = results_dir(&cfg).join("cost_model.json");
    cm.save(&path)?;
    eprintln!("\n[sincere] saved {path:?}");
    Ok(())
}

// ------------------------------------------------------------------ sweep

/// The paper's evaluation grid.  Historically a hardcoded serial
/// 72-cell loop lived here; it is now the `paper-72` scenario preset,
/// run by the lab's parallel runner with identical cell order, labels
/// and output tables.
fn cmd_sweep(mut cfg: RunConfig, rest: Vec<String>) -> anyhow::Result<()> {
    no_extra_args(&rest)?;
    if cfg.lab_spec.is_none() && cfg.lab_preset.is_none() {
        cfg.lab_preset = Some("paper-72".to_string());
    }
    lab_run(cfg)
}

// -------------------------------------------------------------------- lab

fn cmd_lab(cfg: RunConfig, rest: Vec<String>) -> anyhow::Result<()> {
    match rest.first().map(|s| s.as_str()) {
        Some("run") => {
            no_extra_args(&rest[1..])?;
            lab_run(cfg)
        }
        Some("list") => {
            no_extra_args(&rest[1..])?;
            lab_list()
        }
        Some("compare") => {
            anyhow::ensure!(
                rest.len() == 3,
                "usage: lab compare BASELINE.json CANDIDATE.json");
            lab_compare(Path::new(&rest[1]), Path::new(&rest[2]))
        }
        Some("check") => {
            no_extra_args(rest.get(2..).unwrap_or(&[]))?;
            lab_check(&cfg, rest.get(1))
        }
        other => anyhow::bail!(
            "lab needs a subcommand: run|list|compare|check (got {:?})",
            other.unwrap_or("nothing")),
    }
}

/// Resolve the scenario to run: `--spec FILE` wins, then `--preset
/// NAME`, then the paper's grid.
fn lab_spec(cfg: &RunConfig) -> anyhow::Result<ScenarioSpec> {
    if let Some(path) = &cfg.lab_spec {
        return ScenarioSpec::from_file(path);
    }
    let name = cfg.lab_preset.as_deref().unwrap_or("paper-72");
    lab::preset_by_name(name)
}

/// Cost table for lab cells: the built-in synthetic table on
/// `--synthetic-costs on`, else the cached `cost_model.json`, else
/// measure-and-cache (exactly the legacy sweep behaviour).
fn lab_costs(cfg: &RunConfig, manifest: &Manifest)
             -> anyhow::Result<CostModel> {
    if cfg.synthetic_costs {
        eprintln!("[sincere] pricing cells from the built-in synthetic \
                   cost table");
        return Ok(CostModel::synthetic(manifest));
    }
    let cm_path = results_dir(cfg).join("cost_model.json");
    if cm_path.exists() {
        eprintln!("[sincere] using cached {cm_path:?}");
        CostModel::load(&cm_path)
    } else {
        let (_m, registry) = load_registry(cfg)?;
        let cm = CostModel::measure(&registry, &cfg.gpu, 3)?;
        cm.save(&cm_path)?;
        Ok(cm)
    }
}

fn lab_run(cfg: RunConfig) -> anyhow::Result<()> {
    let spec = lab_spec(&cfg)?;
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let cm = lab_costs(&cfg, &manifest)?;

    let grid = spec.expand(&cfg)?;
    let seeds = cfg.lab_seeds.unwrap_or(grid.seeds);
    let jobs = grid.jobs(seeds);
    let threads = sincere::lab::runner::effective_threads(
        cfg.lab_threads, jobs.len());
    eprintln!("[sincere] lab {}: {} cells x {} seed(s) = {} runs \
               ({} pruned) on {} thread(s)",
              grid.spec_name, grid.cells.len(), seeds, jobs.len(),
              grid.pruned, threads);

    let t0 = std::time::Instant::now();
    let cells = LabRunner::new(&manifest, &cm)
        .threads(cfg.lab_threads)
        .run(&jobs)?;
    eprintln!("[sincere] lab {} finished in {:.2}s", grid.spec_name,
              t0.elapsed().as_secs_f64());

    // every table is rendered exactly once and shared by stdout and
    // the markdown report; stdout mirrors the legacy sweep exactly
    // for 1-seed single-device grids (replica stats and per-device
    // tables appear only when the grid exercises those axes)
    let tables = LabTables::render(&spec, seeds, &cells);
    for c in &cells {
        println!("{}", c.brief());
    }
    println!("\n{}", tables.cells);
    if let Some(stats) = &tables.stats {
        println!("\n## Seed-replica statistics ({seeds} seeds/cell)\n");
        println!("{stats}");
    }
    if let Some(per_device) = &tables.per_device {
        println!("\n## Per-device breakdown\n");
        println!("{per_device}");
    }
    if let Some(data_path) = &tables.data_path {
        println!("\n## Batch I/O (CC data path)\n");
        println!("{data_path}");
    }
    if let Some(tenancy) = &tables.tenancy {
        println!("\n## Multi-tenant serving\n");
        println!("{tenancy}");
    }
    if let Some(hw_gen) = &tables.hw_gen {
        println!("\n## CC tax by hardware generation\n");
        println!("{hw_gen}");
    }
    if let Some(waterfall) = &tables.waterfall {
        println!("\n## Where the seconds go (latency waterfall)\n");
        println!("{waterfall}");
    }
    if let Some(pipeline) = &tables.pipeline {
        println!("\n## CC tax by stage count (pipeline parallel)\n");
        println!("{pipeline}");
    }
    if let Some(headline) = &tables.headline {
        println!("\n## Headline comparison (paper abstract)\n");
        println!("{headline}");
    }

    // persist all summaries (replicas included, job order); the
    // markdown report lands next to the cells file it describes
    let out = cells_out_path(&cfg);
    if let Some(dir) = out.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(&out, lab::run_to_json(&cells).to_string())?;
    eprintln!("[sincere] wrote {out:?}");

    let md_path = out.parent()
        .filter(|d| !d.as_os_str().is_empty())
        .map(|d| d.join("lab_report.md"))
        .unwrap_or_else(|| PathBuf::from("lab_report.md"));
    std::fs::write(&md_path, tables.markdown())?;
    eprintln!("[sincere] wrote {md_path:?}");
    Ok(())
}

/// Where `lab run` writes (and `lab check` reads) the cells JSON:
/// `--out` wins, else `<results>/sweep_cells.json`.
fn cells_out_path(cfg: &RunConfig) -> PathBuf {
    cfg.lab_out.clone()
        .unwrap_or_else(|| results_dir(cfg).join("sweep_cells.json"))
}

/// Every table of one lab run, rendered exactly once — the stdout
/// block and the markdown report both read these strings, so the two
/// outputs cannot drift and nothing is computed twice.
struct LabTables {
    title: String,
    description: String,
    seeds: usize,
    cells: String,
    /// Only for seed-replicated grids.
    stats: Option<String>,
    /// Only when some cell ran a multi-device fleet.
    per_device: Option<String>,
    /// Only when some cell priced the CC inference data path.
    data_path: Option<String>,
    /// Only when some cell ran with tenancy features (admission or
    /// SLA classes).
    tenancy: Option<String>,
    /// Only when some cell ran under a named device profile.
    hw_gen: Option<String>,
    /// Only when some cell recorded an event trace (`--trace`): the
    /// per-phase latency waterfall.
    waterfall: Option<String>,
    /// Only when some cell ran pipeline-parallel (`--pp-stages` > 1):
    /// the stage-count scaling table.
    pipeline: Option<String>,
    /// Only when the grid has both CC and No-CC cells — a one-mode
    /// grid has nothing to ratio against (`lab check` guards the
    /// same way).
    headline: Option<String>,
    bands: Option<String>,
}

impl LabTables {
    fn render(spec: &ScenarioSpec, seeds: usize, cells: &[RunSummary])
              -> LabTables {
        let both_modes = cells.iter().any(|c| c.mode == "cc")
            && cells.iter().any(|c| c.mode == "no-cc");
        let h = both_modes
            .then(|| report::headline_ratios(cells));
        LabTables {
            title: spec.name.clone(),
            description: spec.description.clone(),
            seeds,
            cells: report::cells_table(cells),
            stats: (seeds > 1).then(
                || lab::stats_table(&lab::aggregate(cells))),
            per_device: cells.iter()
                .any(|c| c.per_device.len() > 1)
                .then(|| report::per_device_table(cells)),
            data_path: report::has_data_path(cells)
                .then(|| report::data_path_table(cells)),
            tenancy: report::has_tenancy(cells)
                .then(|| report::tenancy_table(cells)),
            hw_gen: report::has_profiles(cells)
                .then(|| report::hw_gen_table(cells)),
            waterfall: report::has_waterfall(cells)
                .then(|| report::waterfall_table(cells)),
            pipeline: report::has_pipeline(cells)
                .then(|| report::pipeline_table(cells)),
            headline: h.as_ref().map(report::headline_table),
            bands: h.as_ref().map(
                |h| report::band_table(&report::paper_check(h))),
        }
    }

    /// The self-contained markdown report (CI uploads this).
    fn markdown(&self) -> String {
        let mut md = format!("# Lab report: {}\n\n{}\n\n## Cells\n\n{}",
                             self.title, self.description, self.cells);
        if let Some(stats) = &self.stats {
            md.push_str(&format!(
                "\n## Seed-replica statistics ({} seeds/cell)\n\n\
                 {stats}", self.seeds));
        }
        if let Some(per_device) = &self.per_device {
            md.push_str(&format!(
                "\n## Per-device breakdown\n\n{per_device}"));
        }
        if let Some(data_path) = &self.data_path {
            md.push_str(&format!(
                "\n## Batch I/O (CC data path)\n\n{data_path}"));
        }
        if let Some(tenancy) = &self.tenancy {
            md.push_str(&format!(
                "\n## Multi-tenant serving\n\n{tenancy}"));
        }
        if let Some(hw_gen) = &self.hw_gen {
            md.push_str(&format!(
                "\n## CC tax by hardware generation\n\n{hw_gen}"));
        }
        if let Some(waterfall) = &self.waterfall {
            md.push_str(&format!(
                "\n## Where the seconds go (latency waterfall)\n\n\
                 {waterfall}"));
        }
        if let Some(pipeline) = &self.pipeline {
            md.push_str(&format!(
                "\n## CC tax by stage count (pipeline parallel)\n\n\
                 {pipeline}"));
        }
        if let Some(headline) = &self.headline {
            md.push_str(&format!(
                "\n## Headline comparison (paper abstract)\n\n\
                 {headline}"));
        }
        if let Some(bands) = &self.bands {
            md.push_str(&format!("\n## Paper-check\n\n{bands}"));
        } else {
            md.push_str("\nSingle-mode grid: no CC vs No-CC headline \
                         comparison or paper-check applies.\n");
        }
        md
    }
}

fn lab_list() -> anyhow::Result<()> {
    let cli = RunConfig::default();
    println!("## Presets (`lab run --preset NAME`)\n");
    println!("| preset | cells | seeds | runs | description |");
    println!("|---|---|---|---|---|");
    for p in lab::PRESETS {
        let spec = (p.make)();
        let (cells, runs) = match spec.expand(&cli) {
            Ok(g) => (g.cells.len().to_string(),
                      (g.cells.len() * g.seeds).to_string()),
            Err(_) => ("?".to_string(), "?".to_string()),
        };
        println!("| {} | {} | {} | {} | {} |", p.name, cells,
                 spec.seeds, runs, p.blurb);
    }
    println!("\n## Axes (`axes` keys in a spec file)\n");
    println!("| axis | values |");
    println!("|---|---|");
    for name in lab::axis_names() {
        println!("| {} | {} |", name, lab::spec::axis_hint(name));
    }
    println!("\nSpec schema: see examples/lab_spec.json and DESIGN.md \
              \"The scenario lab\".");
    Ok(())
}

fn lab_compare(base: &Path, cand: &Path) -> anyhow::Result<()> {
    let b = lab::load_run(base)?;
    let c = lab::load_run(cand)?;
    println!("## Baseline {base:?} vs candidate {cand:?}\n");
    println!("{}", report::compare_table(&b, &c));
    Ok(())
}

fn lab_check(cfg: &RunConfig, path: Option<&String>)
             -> anyhow::Result<()> {
    let path = path.map(PathBuf::from)
        .unwrap_or_else(|| cells_out_path(cfg));
    let cells = lab::load_run(&path)?;
    anyhow::ensure!(
        cells.iter().any(|c| c.mode == "cc")
            && cells.iter().any(|c| c.mode == "no-cc"),
        "{path:?} has no CC vs No-CC cells to compare (run `sincere \
         lab run --preset paper-72` first)");
    let checks = report::paper_check(&report::headline_ratios(&cells));
    println!("## Paper-check: {} cells from {path:?}\n", cells.len());
    println!("{}", report::band_table(&checks));
    let in_band = checks.iter().filter(|c| c.in_band).count();
    println!("verdict: {in_band}/{} abstract bands in range",
             checks.len());
    Ok(())
}

// ----------------------------------------------------------------- report

fn cmd_report(cfg: RunConfig, rest: Vec<String>) -> anyhow::Result<()> {
    no_extra_args(&rest)?;
    let path = results_dir(&cfg).join("sweep_cells.json");
    let cells = lab::load_run(&path)?;
    println!("{}", report::cells_table(&cells));
    if cells.iter().any(|c| c.per_device.len() > 1) {
        println!("\n## Per-device breakdown\n");
        println!("{}", report::per_device_table(&cells));
    }
    if report::has_data_path(&cells) {
        println!("\n## Batch I/O (CC data path)\n");
        println!("{}", report::data_path_table(&cells));
    }
    if report::has_tenancy(&cells) {
        println!("\n## Multi-tenant serving\n");
        println!("{}", report::tenancy_table(&cells));
    }
    if report::has_profiles(&cells) {
        println!("\n## CC tax by hardware generation\n");
        println!("{}", report::hw_gen_table(&cells));
    }
    if report::has_waterfall(&cells) {
        println!("\n## Where the seconds go (latency waterfall)\n");
        println!("{}", report::waterfall_table(&cells));
    }
    if report::has_pipeline(&cells) {
        println!("\n## CC tax by stage count (pipeline parallel)\n");
        println!("{}", report::pipeline_table(&cells));
    }
    println!("{}", report::headline_table(&report::headline_ratios(&cells)));
    Ok(())
}

// ------------------------------------------------------------ gen-traffic

/// Emit an arrival trace, honouring the same tenancy pipeline as the
/// engine and in the same order — base pattern, then Zipf remap, then
/// the diurnal/flash time warp, then class assignment — from the same
/// gated RNG forks, so a generated trace matches what a live run with
/// identical flags would see.
fn cmd_gen_traffic(cfg: RunConfig, rest: Vec<String>)
                   -> anyhow::Result<()> {
    no_extra_args(&rest)?;
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let models = if cfg.catalog > 0 {
        sincere::tenancy::catalog::catalog_models(cfg.catalog)
    } else if cfg.models.is_empty() {
        manifest.family_names()
    } else {
        cfg.models.clone()
    };
    let mut rng = sincere::traffic::rng::Pcg64::new(cfg.seed);
    let pattern = pattern_by_name(&cfg.pattern)?;
    let mut arrivals = pattern.generate(cfg.duration_s, cfg.mean_rps,
                                        &models, &mut rng);
    if let Some(skew) = cfg.zipf_skew {
        let zipf = sincere::tenancy::zipf::Zipf::new(models.len(), skew);
        let mut zrng = rng.fork(0x21BF);
        for a in &mut arrivals {
            a.model = models[zipf.sample(&mut zrng)].clone();
        }
    }
    let shape = sincere::traffic::compose::Shape {
        diurnal_amp: cfg.diurnal_amp,
        diurnal_period_s: cfg.diurnal_period_s,
        flash_mult: cfg.flash_mult,
        flash_start_s: cfg.flash_start_s,
        flash_dur_s: cfg.flash_dur_s,
    };
    if shape.is_active() {
        sincere::traffic::compose::warp(&mut arrivals, cfg.duration_s,
                                        &shape);
    }
    let mut prompts =
        sincere::workload::promptgen::PromptGen::new(cfg.seed ^ 0xBEEF, 24);
    let path = results_dir(&cfg)
        .join(format!("trace_{}_{}rps.jsonl", cfg.pattern, cfg.mean_rps));
    if cfg.sla_classes {
        let mut crng = rng.fork(0xC1A5);
        let classes: Vec<u8> = arrivals.iter()
            .map(|_| sincere::tenancy::assign_class(&mut crng))
            .collect();
        sincere::traffic::trace::write_trace_with_tenants(
            &path, &arrivals, &classes, &mut prompts)?;
    } else {
        sincere::traffic::trace::write_trace(&path, &arrivals,
                                             &mut prompts)?;
    }
    println!("wrote {} arrivals to {path:?}", arrivals.len());
    Ok(())
}

// ----------------------------------------------------------------- models

fn cmd_models(cfg: RunConfig, rest: Vec<String>) -> anyhow::Result<()> {
    no_extra_args(&rest)?;
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    println!("| model | stands in for | paper size | sim weights | \
              layers | d_model | heads | d_ff | vocab |");
    println!("|---|---|---|---|---|---|---|---|---|");
    for f in &manifest.families {
        println!("| {} | {} | {:.2} GB | {:.2} MB | {} | {} | {} | {} | \
                  {} |",
                 f.name, f.hf_name, f.paper_gb,
                 f.weight_bytes() as f64 / 1e6, f.n_layers, f.d_model,
                 f.n_heads, f.d_ff, f.vocab);
    }
    Ok(())
}

/// Build the usage text from the [`COMMANDS`] table.
fn usage_string() -> String {
    let mut out = String::from(
        "sincere — relaxed batch LLM inference on a simulated \
         confidential GPU\n\n\
         USAGE: sincere <command> [--key value ...]\n\n\
         COMMANDS:\n");
    for c in COMMANDS {
        out.push_str(&format!("  {:<12} {}\n", c.name, c.blurb));
    }
    out.push_str(&format!(
        "  {:<12} {}\n\n\
         COMMON OPTIONS:\n\
         \x20 --mode cc|no-cc        confidential mode (default no-cc)\n\
         \x20 --pattern {patterns}\n\
         \x20 --strategy {strategies}\n\
         \x20 --sla SECONDS          (default 18.0; ladder 12/18/24)\n\
         \x20 --mean-rps RPS         (default 9.0)\n\
         \x20 --duration SECONDS     (default 60)\n\
         \x20 --models a,b           restrict families\n\
         \x20 --batch-sizes 1,2,4    restrict compiled batches\n\
         \x20 --artifacts DIR --results DIR --seed N --config FILE.json\n\n\
         FLEET OPTIONS:\n\
         \x20 --devices N            fleet size (default 1)\n\
         \x20 --device-modes cc,no-cc,...   per-device CC mode mix\n\
         \x20 --device-hbm-mb a,b    per-device HBM capacity, MB\n\
         \x20 --device-bw-scale a,b  per-device PCIe rate scale\n\
         \x20 --device-profiles a,b  named hardware-generation \
         profiles, one per device\n\
         \x20                        (a single name broadcasts \
         fleet-wide):\n\
         \x20                        {profiles}\n\
         \x20                        (bundle link rates, HBM, crypto \
         pricing; the first\n\
         \x20                        profile's CC mode is the default, \
         --mode overrides)\n\
         \x20 --placement {placements}\n\
         \x20 --pp-stages N          pipeline-parallel stages per model \
         (default 1 = off;\n\
         \x20                        N>1 shards each model's layers \
         over N-device groups,\n\
         \x20                        prices sealed inter-stage \
         activations on CC links,\n\
         \x20                        and reports TTFT / token \
         throughput / bubble time;\n\
         \x20                        needs --placement \
         pipeline-parallel, devices % N == 0,\n\
         \x20                        virtual time only)\n\n\
         CC PIPELINE OPTIONS:\n\
         \x20 --pipeline-depth N     CC bounce-chunk staging buffers: \
         0|1 = serialized\n\
         \x20                        (default), >=2 overlaps sealing \
         with the link\n\
         \x20 --cc-crypto-frac F     crypto share of the serialized CC \
         budget (default 0.5)\n\
         \x20 --prefetch on|off      decrypt-ahead the predicted next \
         model while a batch\n\
         \x20                        executes; the swap promotes it \
         without a second DMA\n\n\
         DATA-PATH OPTIONS:\n\
         \x20 --data-path on|off     price each batch's request/response \
         payload through the\n\
         \x20                        CC bounce path (default off; No-CC \
         timings unchanged\n\
         \x20                        either way)\n\
         \x20 --data-tokens-in N     priced input tokens per request \
         (default: model prompt_len)\n\
         \x20 --data-tokens-out N    priced output tokens per request \
         (default: model decode_len)\n\n\
         TENANCY OPTIONS (DES-only; all off by default, off is \
         byte-identical to before):\n\
         \x20 --catalog N            serve an N-model synthetic catalog \
         cloned from the\n\
         \x20                        manifest families (lab/gen-traffic \
         only)\n\
         \x20 --zipf-skew S|off      Zipf(S) popularity over the model \
         set (0 = uniform)\n\
         \x20 --admission NAME       admission gate before the queues: \
         {admissions}\n\
         \x20 --sla-classes on|off   gold/silver/free tenant classes \
         (deadlines + shed\n\
         \x20                        priority + per-class accounting)\n\
         \x20 --diurnal-amp A        sinusoidal rate modulation, \
         amplitude in [0,1)\n\
         \x20 --diurnal-period S     sinusoid period (default: one \
         period per run)\n\
         \x20 --flash-mult M --flash-start S --flash-dur S   flash-crowd \
         window\n\n\
         TRACE OPTIONS (virtual-time runs only — des / lab; off is \
         byte-identical to before):\n\
         \x20 --trace {traces}   structured event trace (schema v{tsv})\n\
         \x20                        events: per-request lifecycle \
         spans + device lanes,\n\
         \x20                        written as Perfetto-loadable \
         <label>_trace.json, plus a\n\
         \x20                        phase_totals summary block\n\
         \x20                        full: events + per-request \
         <label>_waterfall.csv whose\n\
         \x20                        phase columns sum exactly to the \
         recorded latency\n\n\
         LAB OPTIONS (lab run|list|compare|check):\n\
         \x20 --preset NAME          built-in scenario preset \
         (`lab list` names them)\n\
         \x20 --spec FILE.json       declarative grid: axes, \
         exclusions, seeds\n\
         \x20 --threads N            parallel DES workers \
         (default 0 = all cores)\n\
         \x20 --lab-seeds N          override the spec's seed \
         replication\n\
         \x20 --out FILE.json        cells output \
         (default results/sweep_cells.json)\n\
         \x20 --synthetic-costs on   price cells from the built-in \
         synthetic cost table\n",
        "help", "show this help",
        patterns = PATTERN_NAMES.join("|"),
        strategies = strategy_names().join("|"),
        placements = placement_names().join("|"),
        profiles = sincere::gpu::profile::profile_names().join("|"),
        admissions =
            sincere::tenancy::admission::admission_names().join("|"),
        traces = sincere::obs::TRACE_MODE_NAMES.join("|"),
        tsv = sincere::obs::TRACE_SCHEMA_VERSION));
    out
}

fn print_usage() {
    print!("{}", usage_string());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn command_names_unique() {
        let mut names: Vec<&str> = COMMANDS.iter().map(|c| c.name)
            .collect();
        names.sort_unstable();
        let n = names.len();
        names.dedup();
        assert_eq!(names.len(), n, "duplicate command names");
    }

    /// Help text is generated from the dispatch table, so every
    /// routable command (serve-http included) must appear in it.
    #[test]
    fn usage_lists_every_command() {
        let usage = usage_string();
        for c in COMMANDS {
            assert!(usage.contains(c.name),
                    "usage text is missing {:?}", c.name);
        }
        assert!(usage.contains("serve-http"));
        assert!(usage.contains("lab"));
    }

    /// Strategy and placement options in the help text are rendered
    /// from the same tables that drive lookup, so the lists in docs
    /// and error messages cannot drift.
    #[test]
    fn usage_lists_every_strategy_and_placement() {
        let usage = usage_string();
        for name in strategy_names() {
            assert!(usage.contains(name), "usage missing strategy {name}");
        }
        for name in placement_names() {
            assert!(usage.contains(name),
                    "usage missing placement {name}");
        }
    }

    #[test]
    fn usage_lists_the_lab_flags() {
        let usage = usage_string();
        for flag in ["--preset", "--spec", "--threads", "--lab-seeds",
                     "--out", "--synthetic-costs"] {
            assert!(usage.contains(flag), "usage missing {flag}");
        }
    }

    /// The profile flag and its name table render into the help text
    /// from the same `PROFILES` table that drives lookup.
    #[test]
    fn usage_lists_the_profile_flag_and_names() {
        let usage = usage_string();
        assert!(usage.contains("--device-profiles"));
        for name in sincere::gpu::profile::profile_names() {
            assert!(usage.contains(name),
                    "usage missing profile {name}");
        }
    }

    /// The pipeline-parallel flag and its constraints render into the
    /// help text; the placement it requires is named in the same
    /// block, so the two cannot drift apart.
    #[test]
    fn usage_lists_the_pp_flag_and_its_constraints() {
        let usage = usage_string();
        assert!(usage.contains("--pp-stages"));
        assert!(usage.contains("pipeline-parallel"));
        for word in ["sealed", "TTFT", "bubble", "virtual time only"] {
            assert!(usage.contains(word),
                    "usage missing pp detail {word:?}");
        }
    }

    #[test]
    fn usage_lists_the_data_path_flags() {
        let usage = usage_string();
        for flag in ["--data-path", "--data-tokens-in",
                     "--data-tokens-out"] {
            assert!(usage.contains(flag), "usage missing {flag}");
        }
    }

    /// The trace flag, its mode table, and the artifact names render
    /// into the help text from the same `obs` constants that drive
    /// parsing and the writers.
    #[test]
    fn usage_lists_the_trace_flag_and_modes() {
        let usage = usage_string();
        assert!(usage.contains("--trace"));
        for name in sincere::obs::TRACE_MODE_NAMES {
            assert!(usage.contains(name),
                    "usage missing trace mode {name}");
        }
        assert!(usage.contains("_trace.json")
                && usage.contains("_waterfall.csv"));
        assert!(usage.contains(&format!(
            "schema v{}", sincere::obs::TRACE_SCHEMA_VERSION)));
    }

    /// Tenancy flags and the admission name table both render into
    /// the help text, so docs cannot drift from the lookup tables.
    #[test]
    fn usage_lists_the_tenancy_flags_and_admissions() {
        let usage = usage_string();
        for flag in ["--catalog", "--zipf-skew", "--admission",
                     "--sla-classes", "--diurnal-amp",
                     "--diurnal-period", "--flash-mult"] {
            assert!(usage.contains(flag), "usage missing {flag}");
        }
        for name in sincere::tenancy::admission::admission_names() {
            assert!(usage.contains(name),
                    "usage missing admission policy {name}");
        }
    }

    /// `serve` compiles real artifacts, which synthetic catalog
    /// families do not have — the guard must fire before any load.
    #[test]
    fn serve_rejects_catalog_cells() {
        let mut cfg = RunConfig::default();
        cfg.catalog = 4;
        let err = cmd_serve(cfg, Vec::new()).unwrap_err().to_string();
        assert!(err.contains("DES-only"), "{err}");
    }

    #[test]
    fn flags_parse_into_config() {
        let mut cfg = RunConfig::default();
        let rest = apply_flags(&mut cfg, &[
            "--mode".into(), "cc".into(),
            "--sla".into(), "12".into(),
            "positional".into(),
        ]).unwrap();
        assert_eq!(cfg.sla_s, 12.0);
        assert_eq!(cfg.mode, sincere::gpu::CcMode::On);
        assert_eq!(rest, vec!["positional".to_string()]);
        assert!(apply_flags(&mut cfg, &["--sla".into()]).is_err());
    }

    #[test]
    fn lab_requires_a_known_subcommand() {
        let err = cmd_lab(RunConfig::default(), vec!["bogus".into()])
            .unwrap_err().to_string();
        assert!(err.contains("run|list|compare|check"), "{err}");
        let err = cmd_lab(RunConfig::default(), Vec::new())
            .unwrap_err().to_string();
        assert!(err.contains("subcommand"), "{err}");
    }

    #[test]
    fn lab_scenario_resolution() {
        let mut cfg = RunConfig::default();
        assert_eq!(lab_spec(&cfg).unwrap().name, "paper-72",
                   "bare `lab run` is the paper's grid");
        cfg.lab_preset = Some("smoke".into());
        assert_eq!(lab_spec(&cfg).unwrap().name, "smoke");
        cfg.lab_preset = Some("nope".into());
        assert!(lab_spec(&cfg).is_err());
    }

    #[test]
    fn positional_args_rejected_where_unused() {
        let err = cmd_models(RunConfig::default(),
                             vec!["stray".into()]);
        assert!(err.unwrap_err().to_string().contains("stray"));
    }
}
