//! CSV writing/reading for the three result-file classes the paper's
//! bash driver produced: request-level details, throughput metrics, and
//! system-monitor logs.  RFC-4180-style quoting, header-checked reads.

use std::io::Write;
use std::path::Path;

/// Incremental CSV writer with a fixed header.
pub struct CsvWriter {
    out: Box<dyn Write + Send>,
    cols: usize,
}

impl CsvWriter {
    /// Create a file-backed writer, writing the header immediately.
    pub fn create(path: &Path, header: &[&str]) -> anyhow::Result<CsvWriter> {
        Self::create_with_capacity(path, header, 8 * 1024)
    }

    /// [`create`] with an explicit buffer size — bulk dumps (the
    /// recorder's per-request tables can run to hundreds of thousands
    /// of rows) size the buffer once instead of flushing every 8 KiB.
    ///
    /// [`create`]: CsvWriter::create
    pub fn create_with_capacity(path: &Path, header: &[&str],
                                capacity: usize)
                                -> anyhow::Result<CsvWriter> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let f = std::fs::File::create(path)
            .map_err(|e| anyhow::anyhow!("creating {path:?}: {e}"))?;
        let buf = std::io::BufWriter::with_capacity(capacity, f);
        Self::from_writer(Box::new(buf), header)
    }

    /// Writer over any sink (used by tests with `Vec<u8>` buffers).
    pub fn from_writer(mut out: Box<dyn Write + Send>, header: &[&str])
                       -> anyhow::Result<CsvWriter> {
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, cols: header.len() })
    }

    /// Write one row; must match the header width.
    pub fn row(&mut self, fields: &[String]) -> anyhow::Result<()> {
        anyhow::ensure!(fields.len() == self.cols,
                        "row has {} fields, header has {}", fields.len(),
                        self.cols);
        let line: Vec<String> = fields.iter().map(|f| quote(f)).collect();
        writeln!(self.out, "{}", line.join(","))?;
        Ok(())
    }

    pub fn flush(&mut self) -> anyhow::Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// Quote a field if it contains a comma, quote or newline.
fn quote(f: &str) -> String {
    if f.contains(',') || f.contains('"') || f.contains('\n') {
        format!("\"{}\"", f.replace('"', "\"\""))
    } else {
        f.to_string()
    }
}

/// Parsed CSV: header plus rows of equal width.
#[derive(Debug, Clone)]
pub struct CsvTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn read(path: &Path) -> anyhow::Result<CsvTable> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path:?}: {e}"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> anyhow::Result<CsvTable> {
        let mut lines = split_records(text).into_iter();
        let header = parse_record(
            &lines.next().ok_or_else(|| anyhow::anyhow!("empty CSV"))?)?;
        let mut rows = Vec::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let row = parse_record(&line)?;
            anyhow::ensure!(row.len() == header.len(),
                            "row width {} != header width {}", row.len(),
                            header.len());
            rows.push(row);
        }
        Ok(CsvTable { header, rows })
    }

    /// Column index by name.
    pub fn col(&self, name: &str) -> anyhow::Result<usize> {
        self.header.iter().position(|h| h == name)
            .ok_or_else(|| anyhow::anyhow!("no CSV column {name:?}"))
    }

    /// All values of a column parsed as f64.
    pub fn f64_col(&self, name: &str) -> anyhow::Result<Vec<f64>> {
        let i = self.col(name)?;
        self.rows.iter()
            .map(|r| r[i].parse::<f64>()
                 .map_err(|e| anyhow::anyhow!("bad f64 {:?}: {e}", r[i])))
            .collect()
    }
}

/// Split on newlines, respecting quoted fields that contain newlines.
fn split_records(text: &str) -> Vec<String> {
    let mut records = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    for c in text.chars() {
        match c {
            '"' => {
                in_quotes = !in_quotes;
                cur.push(c);
            }
            '\n' if !in_quotes => {
                records.push(std::mem::take(&mut cur));
            }
            '\r' => {}
            c => cur.push(c),
        }
    }
    if !cur.is_empty() {
        records.push(cur);
    }
    records
}

fn parse_record(line: &str) -> anyhow::Result<Vec<String>> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut chars = line.chars().peekable();
    let mut in_quotes = false;
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes => {
                if chars.peek() == Some(&'"') {
                    cur.push('"');
                    chars.next();
                } else {
                    in_quotes = false;
                }
            }
            '"' => in_quotes = true,
            ',' if !in_quotes => fields.push(std::mem::take(&mut cur)),
            c => cur.push(c),
        }
    }
    anyhow::ensure!(!in_quotes, "unterminated quote in CSV record");
    fields.push(cur);
    Ok(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_with_quoting() {
        let dir = std::env::temp_dir().join("sincere_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&["plain".into(), "has,comma".into()]).unwrap();
            w.row(&["has\"quote".into(), "multi\nline".into()]).unwrap();
            w.flush().unwrap();
        }
        let t = CsvTable::read(&path).unwrap();
        assert_eq!(t.header, vec!["a", "b"]);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][1], "has,comma");
        assert_eq!(t.rows[1][0], "has\"quote");
        assert_eq!(t.rows[1][1], "multi\nline");
    }

    #[test]
    fn width_mismatch_rejected() {
        let dir = std::env::temp_dir().join("sincere_csv_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        assert!(w.row(&["only-one".into()]).is_err());
    }

    #[test]
    fn f64_column() {
        let t = CsvTable::parse("x,y\n1,2.5\n3,4.5\n").unwrap();
        assert_eq!(t.f64_col("y").unwrap(), vec![2.5, 4.5]);
        assert!(t.f64_col("z").is_err());
    }

    #[test]
    fn parse_rejects_ragged() {
        assert!(CsvTable::parse("a,b\n1\n").is_err());
    }
}
