//! In-repo property-testing mini-framework (proptest is unavailable in
//! the offline crate set).
//!
//! Provides deterministic seeded generators and a `forall` runner with
//! greedy input shrinking: when a case fails, the runner re-derives
//! smaller inputs from shrunken seeds/sizes and reports the smallest
//! failure it can find.  Used by unit tests across the coordinator,
//! traffic, and gpu modules, and by `rust/tests/properties.rs`.

use crate::traffic::rng::Pcg64;

/// Test-case generation context: a seeded RNG plus a size budget that
/// shrinks during failure minimization.
pub struct Gen {
    pub rng: Pcg64,
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Gen {
        Gen { rng: Pcg64::new(seed), size }
    }

    /// Uniform usize in [lo, hi] (inclusive), clamped by the size budget.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let hi = hi.min(lo + self.size);
        if hi <= lo {
            return lo;
        }
        lo + (self.rng.next_u64() as usize) % (hi - lo + 1)
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[(self.rng.next_u64() as usize) % xs.len()]
    }

    /// A vector of generated items with length in [0, max_len] scaled by
    /// the size budget.
    pub fn vec<T>(&mut self, max_len: usize,
                  mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = self.usize_in(0, max_len);
        (0..len).map(|_| f(self)).collect()
    }
}

/// Outcome of a single property case.
pub type CaseResult = Result<(), String>;

/// Run `cases` random cases of the property; on failure, attempt to
/// shrink by re-running with smaller size budgets, and panic with the
/// smallest failing seed/size so the case can be replayed.
pub fn forall(name: &str, cases: usize,
              prop: impl Fn(&mut Gen) -> CaseResult) {
    for case in 0..cases {
        let seed = 0x5EED ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let size = 4 + case * 97 % 256; // vary sizes deterministically
        let mut g = Gen::new(seed, size);
        if let Err(msg) = prop(&mut g) {
            // greedy shrink: smaller size budgets with the same seed
            let mut best: (usize, String) = (size, msg);
            let mut s = size / 2;
            loop {
                let mut g2 = Gen::new(seed, s);
                if let Err(m2) = prop(&mut g2) {
                    best = (s, m2);
                    if s == 0 {
                        break;
                    }
                    s /= 2;
                } else {
                    break;
                }
            }
            panic!(
                "property {name:?} failed (seed={seed:#x}, size={}):\n  {}",
                best.0, best.1,
            );
        }
    }
}

/// Assert helper returning `CaseResult` — keeps property bodies terse.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("usize_in bounds", 200, |g| {
            let x = g.usize_in(3, 10);
            prop_assert!((3..=10).contains(&x), "x={x} out of [3,10]");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn forall_reports_failure() {
        forall("always fails on big", 50, |g| {
            let x = g.usize_in(0, 100);
            prop_assert!(x < 2, "x={x} >= 2");
            Ok(())
        });
    }

    #[test]
    fn gen_is_deterministic() {
        let mut a = Gen::new(42, 10);
        let mut b = Gen::new(42, 10);
        for _ in 0..100 {
            assert_eq!(a.u64(), b.u64());
        }
    }

    #[test]
    fn f64_in_bounds() {
        let mut g = Gen::new(7, 8);
        for _ in 0..1000 {
            let x = g.f64_in(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&x));
        }
    }
}
