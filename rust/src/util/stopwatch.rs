//! Wall-clock stopwatch for *measurement* (profiling, benchmarking).
//!
//! Experiment time is owned exclusively by `engine::clock`; everything
//! else that needs to time an operation (cost-model calibration, the
//! HTTP front-end's arrival stamps) goes through this wrapper so the
//! raw monotonic clock has exactly two well-known homes.

use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_elapsed_time() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(sw.elapsed() >= Duration::from_millis(4));
        assert!(sw.elapsed_s() < 2.0);
    }
}
