//! Small self-contained utilities the offline environment forces us to
//! own: JSON, CSV, a property-testing mini-framework, and misc helpers.

pub mod csvio;
pub mod json;
pub mod prop;
pub mod stopwatch;

/// Format a `std::time::Duration` as fractional seconds with millisecond
/// precision — the unit used throughout logs and CSVs.
pub fn secs(d: std::time::Duration) -> f64 {
    d.as_secs_f64()
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

/// Population standard deviation of a slice.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// Exact quantile by sorting a copy; q in [0, 1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((v.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
    v[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((stddev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
        assert_eq!(stddev(&[5.0]), 0.0);
    }

    #[test]
    fn quantiles() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&[], 0.5), 0.0);
    }
}
